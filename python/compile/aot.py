"""AOT pipeline: lower every model variant to HLO **text** + a manifest.

Build-time only; never imported at runtime.  For each requested spec this
lowers the L2 functions (which call the L1 Pallas kernels) with
``jax.jit(...).lower(...)``, converts the StableHLO module to an
XlaComputation, and dumps ``as_hlo_text()``.  HLO *text* — not
``.serialize()`` — is the interchange format because the image's
xla_extension 0.5.1 rejects jax≥0.5 protos with 64-bit instruction ids; the
text parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Artifacts per spec (written under --out-dir):

    <spec>/lora_fwdbwd.hlo.txt    pre-training step, LoRA-adapted model
    <spec>/lora_eval.hlo.txt      eval loss, LoRA-adapted model
    <spec>/full_fwdbwd.hlo.txt    pre-training step, full-rank model
    <spec>/full_eval.hlo.txt      eval loss, full-rank model
    <spec>/cls_fwdbwd.hlo.txt     full fine-tuning step, classification head
    <spec>/cls_eval.hlo.txt       classification eval (loss + #correct)
    <spec>/manifest.json          parameter layout + metadata for Rust
    adam_<N>.hlo.txt              fused AdamW over flat padded N (shared)

Spec syntax: ``name[:rank=R][:seq=S][:batch=B]`` — overridden specs emit only
the lora/full pre-training artifacts (they exist for rank/seq ablations).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts \
            --specs tiny,s1m,s4m,s8m
"""

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs as C
from . import model as M
from .kernels import adam as AK


def to_hlo_text(lowered) -> str:
    """StableHLO module → XLA computation → HLO text (see module doc)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _param_args(spec):
    return [jax.ShapeDtypeStruct(pi.shape, jnp.float32) for pi in spec]


def lower_variant(cfg, variant):
    """Lower one (config, variant) to HLO text.  variant in the set above."""
    lora = variant.startswith("lora")
    if variant.endswith("fwdbwd") and not variant.startswith("cls"):
        fn, spec = M.make_fwdbwd(cfg, lora=lora)
        data = [jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)]
    elif variant.endswith("eval") and not variant.startswith("cls"):
        fn, spec = M.make_eval(cfg, lora=lora)
        data = [jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)]
    elif variant == "cls_fwdbwd":
        fn, spec = M.make_cls_fwdbwd(cfg, lora=False)
        data = [jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32),
                jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)]
    elif variant == "cls_eval":
        fn, spec = M.make_cls_eval(cfg, lora=False)
        data = [jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32),
                jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)]
    else:
        raise ValueError(variant)
    args = _param_args(spec) + data
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), spec


def lower_adam(n_padded: int) -> str:
    def fn(p, g, m, v, s, mask, hyper):
        return AK.adam_step(p, g, m, v, s, mask, hyper)

    vec = jax.ShapeDtypeStruct((n_padded,), jnp.float32)
    hyp = jax.ShapeDtypeStruct((5,), jnp.float32)
    lowered = jax.jit(fn).lower(vec, vec, vec, vec, vec, vec, hyp)
    return to_hlo_text(lowered)


def parse_spec(s: str):
    """``name[:key=val]*`` → (spec_name, ModelConfig, overridden?)."""
    parts = s.split(":")
    cfg = C.get(parts[0])
    overrides = {}
    for kv in parts[1:]:
        k, v = kv.split("=")
        overrides[k] = int(v)
    if not overrides:
        return cfg.name, cfg, False
    name = cfg.name + "".join(
        f"_{k[0]}{v}" for k, v in sorted(overrides.items()))
    if "rank" in overrides:
        overrides["lora_alpha"] = float(overrides["rank"])
    cfg = dataclasses.replace(cfg, name=name, **overrides)
    return name, cfg, True


def spec_json(spec):
    return [{"name": pi.name, "shape": list(pi.shape), "role": pi.role,
             "trainable": pi.trainable, "numel": pi.numel} for pi in spec]


def n_trainable(spec):
    return sum(pi.numel for pi in spec if pi.trainable)


def build_spec(out_dir: str, spec_name: str, cfg, overridden: bool,
               adam_sizes: set, force: bool) -> None:
    d = os.path.join(out_dir, spec_name)
    os.makedirs(d, exist_ok=True)
    manifest_path = os.path.join(d, "manifest.json")
    variants = (["lora_fwdbwd", "lora_eval", "full_fwdbwd", "full_eval"]
                if overridden else
                ["lora_fwdbwd", "lora_eval", "full_fwdbwd", "full_eval",
                 "cls_fwdbwd", "cls_eval"])
    if os.path.exists(manifest_path) and not force:
        with open(manifest_path) as f:
            man = json.load(f)
        if man.get("variants") == variants and all(
                os.path.exists(os.path.join(d, f"{v}.hlo.txt"))
                for v in variants):
            for key in ("adam_padded_lora", "adam_padded_full",
                        "adam_padded_cls"):
                if man.get(key):
                    adam_sizes.add(man[key])
            print(f"[aot] {spec_name}: up to date, skipping")
            return

    man = {"config": cfg.to_dict(), "variants": variants,
           "block": int(os.environ.get("SWITCHLORA_BLOCK", "0"))}
    specs = {}
    for v in variants:
        t0 = time.time()
        text, spec = lower_variant(cfg, v)
        with open(os.path.join(d, f"{v}.hlo.txt"), "w") as f:
            f.write(text)
        specs[v] = spec
        print(f"[aot] {spec_name}/{v}: {len(text)/1e6:.2f} MB HLO "
              f"in {time.time()-t0:.1f}s", flush=True)

    lora_spec = specs["lora_fwdbwd"]
    full_spec = specs["full_fwdbwd"]
    _, linears = M.param_spec(cfg, lora=True)
    man["params_lora"] = spec_json(lora_spec)
    man["params_full"] = spec_json(full_spec)
    man["linears"] = [{"name": li.name, "a": li.a, "b": li.b,
                       "m": li.out_dim, "n": li.in_dim} for li in linears]
    man["n_trainable_lora"] = n_trainable(lora_spec)
    man["n_trainable_full"] = n_trainable(full_spec)
    man["adam_padded_lora"] = AK.padded_size(man["n_trainable_lora"])
    man["adam_padded_full"] = AK.padded_size(man["n_trainable_full"])
    adam_sizes.add(man["adam_padded_lora"])
    adam_sizes.add(man["adam_padded_full"])
    if "cls_fwdbwd" in variants:
        cls_spec = specs["cls_fwdbwd"]
        man["params_cls"] = spec_json(cls_spec)
        man["n_trainable_cls"] = n_trainable(cls_spec)
        man["adam_padded_cls"] = AK.padded_size(man["n_trainable_cls"])
        adam_sizes.add(man["adam_padded_cls"])
    with open(manifest_path, "w") as f:
        json.dump(man, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--specs", default="tiny,s1m,s4m,s8m")
    ap.add_argument("--force", action="store_true")
    # Whole-matrix blocks (grid 1×1) by default for the shipped artifacts:
    # fastest choice under the Pallas interpreter on CPU; tests exercise the
    # tiled path.  See kernels/lora_matmul.py.
    ap.add_argument("--block", default=os.environ.get("SWITCHLORA_BLOCK",
                                                      "0"))
    args = ap.parse_args()
    os.environ["SWITCHLORA_BLOCK"] = str(args.block)

    os.makedirs(args.out_dir, exist_ok=True)
    adam_sizes: set = set()
    for s in args.specs.split(","):
        s = s.strip()
        if not s:
            continue
        name, cfg, overridden = parse_spec(s)
        build_spec(args.out_dir, name, cfg, overridden, adam_sizes,
                   args.force)

    for n in sorted(adam_sizes):
        path = os.path.join(args.out_dir, f"adam_{n}.hlo.txt")
        if os.path.exists(path) and not args.force:
            print(f"[aot] adam_{n}: up to date, skipping")
            continue
        t0 = time.time()
        text = lower_adam(n)
        with open(path, "w") as f:
            f.write(text)
        print(f"[aot] adam_{n}: {len(text)/1e6:.2f} MB HLO "
              f"in {time.time()-t0:.1f}s", flush=True)
    print("[aot] done")


if __name__ == "__main__":
    sys.exit(main())
