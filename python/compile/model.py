"""L2: LLaMA-family decoder with LoRA adapters, in JAX, calling L1 kernels.

This module defines everything the AOT pipeline lowers to HLO:

* ``param_spec(cfg, lora, cls)`` — the **canonical ordered parameter list**.
  aot.py serializes it into ``manifest.json``; the Rust coordinator builds
  its flat state layout from that manifest, so Python and Rust can never
  disagree about parameter order, shapes, roles or trainability.
* ``make_fwdbwd`` / ``make_eval`` — the pre-training step (loss + grads for
  the trainable subset) and the evaluation forward.
* ``make_cls_fwdbwd`` / ``make_cls_eval`` — the sequence-classification
  variant used for the GLUE-analog full fine-tuning experiments (paper
  Tables 7/8).

Architecture (matching the paper's LLaMA setup): token embedding, N decoder
blocks of [RMSNorm → causal multi-head attention with RoPE → residual,
RMSNorm → SwiGLU MLP → residual], final RMSNorm, linear LM head.  LoRA
adapters (paper Section 2.1: ``W + (alpha/r) B A``) are attached to **every
attention and MLP linear** as in Section 4.1; embeddings, norms and the LM
head remain directly trainable (the ReLoRA/SwitchLoRA convention).

Every linear goes through the L1 Pallas kernels (``kernels/lora_matmul.py``);
``use_pallas=False`` switches to the pure-jnp oracles from ``kernels/ref.py``
so tests can diff the full model fwd+bwd against a kernel-free reference.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import lora_matmul as K
from .kernels import ref as R


# ---------------------------------------------------------------------------
# Parameter specification
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamInfo:
    name: str
    shape: tuple
    role: str        # embed | norm | base | lora_a | lora_b | head | cls_head
    trainable: bool

    @property
    def numel(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass(frozen=True)
class LinearInfo:
    """One LoRA-adapted linear: metadata the switch algorithm needs."""
    name: str        # base weight param name
    a: str           # lora A param name ([r, in])
    b: str           # lora B param name ([out, r])
    out_dim: int     # m
    in_dim: int      # n


def _linears(cfg: ModelConfig):
    """(name, out_dim, in_dim) for every LoRA-adapted linear, in order."""
    h, ff = cfg.hidden, cfg.ff
    out = []
    for i in range(cfg.layers):
        for w in ("wq", "wk", "wv", "wo"):
            out.append((f"l{i}.{w}", h, h))
        out.append((f"l{i}.w_gate", ff, h))
        out.append((f"l{i}.w_up", ff, h))
        out.append((f"l{i}.w_down", h, ff))
    return out


def param_spec(cfg: ModelConfig, lora: bool, cls: bool = False):
    """The canonical ordered parameter list for a model variant.

    Returns (list[ParamInfo], list[LinearInfo]).
    """
    r = cfg.rank
    spec = [ParamInfo("embed", (cfg.vocab, cfg.hidden), "embed", True)]
    linears = []
    lin_dims = {name: (m, n) for name, m, n in _linears(cfg)}
    for i in range(cfg.layers):
        spec.append(ParamInfo(f"l{i}.attn_norm", (cfg.hidden,), "norm", True))
        for w in ("wq", "wk", "wv", "wo"):
            name = f"l{i}.{w}"
            m, n = lin_dims[name]
            spec.append(ParamInfo(name, (m, n), "base", not lora))
            if lora:
                spec.append(ParamInfo(f"{name}.a", (r, n), "lora_a", True))
                spec.append(ParamInfo(f"{name}.b", (m, r), "lora_b", True))
                linears.append(LinearInfo(name, f"{name}.a", f"{name}.b",
                                          m, n))
        spec.append(ParamInfo(f"l{i}.mlp_norm", (cfg.hidden,), "norm", True))
        for w in ("w_gate", "w_up", "w_down"):
            name = f"l{i}.{w}"
            m, n = lin_dims[name]
            spec.append(ParamInfo(name, (m, n), "base", not lora))
            if lora:
                spec.append(ParamInfo(f"{name}.a", (r, n), "lora_a", True))
                spec.append(ParamInfo(f"{name}.b", (m, r), "lora_b", True))
                linears.append(LinearInfo(name, f"{name}.a", f"{name}.b",
                                          m, n))
    spec.append(ParamInfo("final_norm", (cfg.hidden,), "norm", True))
    if cls:
        spec.append(ParamInfo("cls_head", (cfg.n_cls, cfg.hidden),
                              "cls_head", True))
    else:
        spec.append(ParamInfo("lm_head", (cfg.vocab, cfg.hidden), "head",
                              True))
    return spec, linears


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _rms_norm(x, w, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, positions):
    """Rotary embedding over the last dim of x[..., T, D]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1)


def _apply_linear(p, name, x2d, lora, use_pallas, scale):
    """Apply one (possibly LoRA-adapted) linear on [tokens, in] activations."""
    w = p[name]
    if lora:
        a, b = p[f"{name}.a"], p[f"{name}.b"]
        if use_pallas:
            return K.lora_linear(x2d, w, a, b, scale)
        return R.ref_lora_linear(x2d, w, a, b, scale)
    if use_pallas:
        return K.linear(x2d, w)
    return R.ref_linear(x2d, w)


def forward(cfg: ModelConfig, p: dict, tokens, *, lora: bool,
            use_pallas: bool = True):
    """Hidden states [B, T, H] for int32 tokens [B, T]."""
    Bsz, T = tokens.shape
    h, nh, hd = cfg.hidden, cfg.heads, cfg.head_dim
    scale = cfg.lora_scale
    x = jnp.take(p["embed"], tokens, axis=0)          # [B, T, H]
    positions = jnp.arange(T)
    causal = jnp.tril(jnp.ones((T, T), dtype=bool))

    def lin(name, t3d, out_dim):
        y = _apply_linear(p, name, t3d.reshape(Bsz * T, -1), lora,
                          use_pallas, scale)
        return y.reshape(Bsz, T, out_dim)

    for i in range(cfg.layers):
        # --- attention block ---
        xn = _rms_norm(x, p[f"l{i}.attn_norm"])
        q = lin(f"l{i}.wq", xn, h).reshape(Bsz, T, nh, hd)
        k = lin(f"l{i}.wk", xn, h).reshape(Bsz, T, nh, hd)
        v = lin(f"l{i}.wv", xn, h).reshape(Bsz, T, nh, hd)
        q = _rope(q.transpose(0, 2, 1, 3), positions)  # [B, nh, T, hd]
        k = _rope(k.transpose(0, 2, 1, 3), positions)
        v = v.transpose(0, 2, 1, 3)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(Bsz, T, h)
        x = x + lin(f"l{i}.wo", o, h)
        # --- MLP block (SwiGLU) ---
        xn = _rms_norm(x, p[f"l{i}.mlp_norm"])
        gate = lin(f"l{i}.w_gate", xn, cfg.ff)
        up = lin(f"l{i}.w_up", xn, cfg.ff)
        act = jax.nn.silu(gate) * up
        x = x + lin(f"l{i}.w_down", act, h)
    return _rms_norm(x, p["final_norm"])


def lm_loss(cfg: ModelConfig, p: dict, tokens, *, lora: bool,
            use_pallas: bool = True):
    """Mean next-token cross-entropy.  tokens: int32 [B, seq+1]."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    hdn = forward(cfg, p, inp, lora=lora, use_pallas=use_pallas)
    Bsz, T, H = hdn.shape
    flat = hdn.reshape(Bsz * T, H)
    if use_pallas:
        logits = K.linear(flat, p["lm_head"])
    else:
        logits = R.ref_linear(flat, p["lm_head"])
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt.reshape(-1, 1), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def cls_logits(cfg: ModelConfig, p: dict, tokens, *, lora: bool,
               use_pallas: bool = True):
    """Classification logits from the last-token hidden state."""
    hdn = forward(cfg, p, tokens, lora=lora, use_pallas=use_pallas)
    pooled = hdn[:, -1, :]                             # causal → last token
    if use_pallas:
        return K.linear(pooled, p["cls_head"])
    return R.ref_linear(pooled, p["cls_head"])


def cls_loss(cfg, p, tokens, labels, *, lora: bool, use_pallas: bool = True):
    logits = cls_logits(cfg, p, tokens, lora=lora, use_pallas=use_pallas)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels.reshape(-1, 1), axis=-1)[:, 0]
    return jnp.mean(lse - gold), logits


# ---------------------------------------------------------------------------
# AOT entry points: flat-argument functions with a stable signature.
# Argument order = param_spec order, then data arrays.  The returned tuple is
# (loss, grad_0, grad_1, ...) with grads in trainable-spec order — exactly
# what manifest.json tells the Rust side to expect.
# ---------------------------------------------------------------------------

def _split_args(spec, args):
    names = [pi.name for pi in spec]
    params = dict(zip(names, args[:len(names)]))
    rest = args[len(names):]
    return params, rest


def _grads_fn(spec, loss_of_params, params):
    names = [pi.name for pi in spec]
    t_idx = [i for i, pi in enumerate(spec) if pi.trainable]

    def f(tr):
        p2 = dict(params)
        for j, i in enumerate(t_idx):
            p2[names[i]] = tr[j]
        return loss_of_params(p2)

    tr0 = tuple(params[names[i]] for i in t_idx)
    return jax.value_and_grad(f)(tr0)


def make_fwdbwd(cfg: ModelConfig, lora: bool, use_pallas: bool = True):
    spec, _ = param_spec(cfg, lora=lora)

    def fwdbwd(*args):
        params, (tokens,) = _split_args(spec, args)
        loss, grads = _grads_fn(
            spec,
            lambda p: lm_loss(cfg, p, tokens, lora=lora,
                              use_pallas=use_pallas),
            params)
        return (loss,) + tuple(grads)

    return fwdbwd, spec


def make_eval(cfg: ModelConfig, lora: bool, use_pallas: bool = True):
    spec, _ = param_spec(cfg, lora=lora)

    def evaluate(*args):
        params, (tokens,) = _split_args(spec, args)
        return (lm_loss(cfg, params, tokens, lora=lora,
                        use_pallas=use_pallas),)

    return evaluate, spec


def make_cls_fwdbwd(cfg: ModelConfig, lora: bool, use_pallas: bool = True):
    spec, _ = param_spec(cfg, lora=lora, cls=True)

    def fwdbwd(*args):
        params, (tokens, labels) = _split_args(spec, args)
        loss, grads = _grads_fn(
            spec,
            lambda p: cls_loss(cfg, p, tokens, labels, lora=lora,
                               use_pallas=use_pallas)[0],
            params)
        return (loss,) + tuple(grads)

    return fwdbwd, spec


def make_cls_eval(cfg: ModelConfig, lora: bool, use_pallas: bool = True):
    spec, _ = param_spec(cfg, lora=lora, cls=True)

    def evaluate(*args):
        params, (tokens, labels) = _split_args(spec, args)
        loss, logits = cls_loss(cfg, params, tokens, labels, lora=lora,
                                use_pallas=use_pallas)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
        return (loss, correct)

    return evaluate, spec


# ---------------------------------------------------------------------------
# Initialization (paper Section 2.2 Eq. (3) / Appendix A Eq. (18)).
# The Rust coordinator owns real training init; this Python version exists
# for tests (kernel-free grad checks, init-law verification) and must match
# the Rust implementation in distribution.
# ---------------------------------------------------------------------------

def switchlora_stds(m: int, n: int, r: int, gain: float = 1.0):
    """(std_B, std_A) from paper Eq. (3): B is [m, r], A is [r, n]."""
    std_b = (r / (m * n) ** 0.5) ** 0.25 * gain ** 0.5
    std_a = ((m * r) ** 0.5 / (n * n ** 0.5)) ** 0.25 * gain ** 0.5
    return std_b, std_a


def init_params(cfg: ModelConfig, key, lora: bool, cls: bool = False,
                init: str = "switchlora", base_std: float = 0.02):
    """Random parameters for tests.  init in {switchlora, lora_default}."""
    spec, _ = param_spec(cfg, lora=lora, cls=cls)
    lin_dims = {name: (m, n) for name, m, n in _linears(cfg)}
    params = {}
    for pi in spec:
        key, sub = jax.random.split(key)
        if pi.role == "norm":
            params[pi.name] = jnp.ones(pi.shape, jnp.float32)
        elif pi.role in ("embed", "head", "cls_head", "base"):
            params[pi.name] = base_std * jax.random.normal(
                sub, pi.shape, jnp.float32)
        elif pi.role == "lora_a":
            base = pi.name[:-2]
            m, n = lin_dims[base]
            if init == "switchlora":
                _, std_a = switchlora_stds(m, n, cfg.rank)
                lim = (3.0 ** 0.5) * std_a     # uniform with that std
                params[pi.name] = jax.random.uniform(
                    sub, pi.shape, jnp.float32, -lim, lim)
            else:  # LoRA default: Kaiming-uniform on A
                lim = (6.0 / n) ** 0.5
                params[pi.name] = jax.random.uniform(
                    sub, pi.shape, jnp.float32, -lim, lim)
        elif pi.role == "lora_b":
            base = pi.name[:-2]
            m, n = lin_dims[base]
            if init == "switchlora":
                std_b, _ = switchlora_stds(m, n, cfg.rank)
                lim = (3.0 ** 0.5) * std_b
                params[pi.name] = jax.random.uniform(
                    sub, pi.shape, jnp.float32, -lim, lim)
            else:  # LoRA default: B = 0
                params[pi.name] = jnp.zeros(pi.shape, jnp.float32)
        else:
            raise ValueError(pi.role)
    return params
