"""Model configuration presets.

Two families live here:

* **Runnable configs** (``tiny`` … ``s8m``): laptop-scale LLaMA-family models
  used by every experiment driver in this repo.  The testbed is a single-core
  CPU PJRT client, so these are scaled-down analogs of the paper's 130M-1.3B
  models (see DESIGN.md "Substitutions").
* **Paper configs** (``p130m`` … ``p7b``): the exact architectures of the
  paper's Table 1 / Table 9.  These are *never lowered to HLO*; they drive
  the analytic parameter-count / memory / communication tables (Tables 4, 5,
  Appendix F), which the Rust side (``model/analytics.rs``) reproduces
  bit-for-bit from the same numbers.

This file is the single source of truth for architecture shapes: ``aot.py``
serializes the chosen config into ``manifest.json`` and the Rust coordinator
reads it from there — the two sides can never drift.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    ff: int
    seq: int          # training sequence length
    rank: int         # LoRA rank r
    lora_alpha: float  # LoRA alpha; scale applied is lora_alpha / rank
    batch: int        # per-step batch used for the AOT example shapes
    n_cls: int = 4    # classification head width for the GLUE-analog variant

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def lora_scale(self) -> float:
        return self.lora_alpha / self.rank

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


def _cfg(name, vocab, hidden, layers, heads, ff, seq, rank, batch, n_cls=4):
    # Paper sets alpha = r so that alpha/r = 1 (Section 2.1).
    return ModelConfig(
        name=name, vocab=vocab, hidden=hidden, layers=layers, heads=heads,
        ff=ff, seq=seq, rank=rank, lora_alpha=float(rank), batch=batch,
        n_cls=n_cls,
    )


# ---------------------------------------------------------------------------
# Runnable (lowered-to-HLO) configs.  rank defaults to hidden/4, the ratio
# used throughout the paper's Table 5; experiment drivers can request
# rank-variant artifacts (e.g. hidden/8) via aot.py --ranks.
# ---------------------------------------------------------------------------
CONFIGS = {
    "tiny": _cfg("tiny", vocab=256, hidden=64, layers=2, heads=4, ff=128,
                 seq=64, rank=16, batch=8),
    "s1m":  _cfg("s1m", vocab=512, hidden=128, layers=4, heads=4, ff=256,
                 seq=64, rank=32, batch=8),
    "s4m":  _cfg("s4m", vocab=512, hidden=256, layers=4, heads=8, ff=512,
                 seq=64, rank=64, batch=8),
    "s8m":  _cfg("s8m", vocab=1024, hidden=256, layers=8, heads=8, ff=512,
                 seq=128, rank=64, batch=4),
}

# ---------------------------------------------------------------------------
# Paper configs (Table 1 + Table 9), analytics only.
# ---------------------------------------------------------------------------
PAPER_CONFIGS = {
    "p130m": _cfg("p130m", vocab=32000, hidden=768, layers=12, heads=12,
                  ff=2048, seq=256, rank=128, batch=600),
    "p250m": _cfg("p250m", vocab=32000, hidden=768, layers=24, heads=16,
                  ff=2560, seq=512, rank=128, batch=1152),
    "p350m": _cfg("p350m", vocab=32000, hidden=1024, layers=24, heads=16,
                  ff=2736, seq=512, rank=128, batch=1152),
    "p1b":   _cfg("p1b", vocab=32000, hidden=2048, layers=24, heads=32,
                  ff=5461, seq=512, rank=512, batch=1536),
    "p3b":   _cfg("p3b", vocab=32000, hidden=2560, layers=32, heads=32,
                  ff=6826, seq=512, rank=640, batch=1536),
    "p7b":   _cfg("p7b", vocab=32000, hidden=4096, layers=32, heads=32,
                  ff=11008, seq=512, rank=1024, batch=1536),
}


def get(name: str) -> ModelConfig:
    if name in CONFIGS:
        return CONFIGS[name]
    if name in PAPER_CONFIGS:
        return PAPER_CONFIGS[name]
    raise KeyError(f"unknown config {name!r}; have {sorted(CONFIGS)} "
                   f"and paper configs {sorted(PAPER_CONFIGS)}")
