"""L1 Pallas kernels: tiled matmul, plain linear, and the fused LoRA linear.

These are the compute hot spots of the SwitchLoRA training step.  Pallas has
no built-in reverse-mode autodiff, so both ``linear`` and ``lora_linear`` are
wrapped in ``jax.custom_vjp`` with the backward pass *also* expressed in
Pallas kernels — the entire fwd+bwd graph of every linear layer therefore
lowers through the same tiled-matmul kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper trains on
A800 GPUs; here we think in the TPU model Pallas targets.  ``BlockSpec``
expresses the HBM→VMEM schedule: an (bm × K) x-tile and (K × bn) w-tile are
staged per grid step and contracted on the MXU via ``jnp.dot`` with
``preferred_element_type=float32``.  Default tile target is 128 — the MXU
systolic-array edge — clamped to divisors of the actual dims.  On this CPU
testbed kernels run with ``interpret=True`` (a Mosaic custom-call cannot
execute on the CPU PJRT plugin), so tiling is a *structural* property we
verify and cost-model rather than a wallclock win; set the environment
variable ``SWITCHLORA_BLOCK=0`` to lower whole-matrix blocks (grid 1×1, the
fastest choice under the interpreter) — ``aot.py`` does this for the shipped
artifacts and records the choice in the manifest.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile edge target.  128 matches the MXU; divisor-clamped per dimension.
_DEFAULT_BLOCK = 128


def block_target() -> int:
    """Tile-edge target; 0 means whole-matrix blocks (grid 1x1)."""
    return int(os.environ.get("SWITCHLORA_BLOCK", _DEFAULT_BLOCK))


def pick_block(dim: int, target: int | None = None) -> int:
    """Largest divisor of ``dim`` that is <= target (whole dim if target<=0).

    All model dims in this repo are powers of two, so this returns a power of
    two; for odd dims it degrades gracefully to the largest divisor.
    """
    if target is None:
        target = block_target()
    if target <= 0 or target >= dim:
        return dim
    best = 1
    d = 1
    while d * d <= dim:
        if dim % d == 0:
            if d <= target:
                best = max(best, d)
            q = dim // d
            if q <= target:
                best = max(best, q)
        d += 1
    return best


def _mm_kernel(x_ref, w_ref, o_ref):
    """One grid step: contract a (bm,K) tile with a (K,bn) tile on the MXU."""
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def _matmul_impl(x, w, bm, bn):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contract mismatch {x.shape} @ {w.shape}"
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(x, w)


def matmul(x, w, block: int | None = None):
    """Tiled Pallas matmul ``x @ w`` for 2-D f32 operands.

    VMEM working set per grid step is ``bm*K + K*bn + bm*bn`` f32 — with the
    default 128 target and K<=4096 this stays under 4.2 MiB, comfortably
    inside a 16 MiB VMEM budget (see EXPERIMENTS.md §Perf for the footprint
    table).
    """
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    bm = pick_block(x.shape[0], block)
    bn = pick_block(w.shape[1], block)
    return _matmul_impl(x, w, bm, bn)


# ---------------------------------------------------------------------------
# Plain linear:  y = x @ W^T   (W stored [out, in], torch convention)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def linear(x, w):
    """``x[: , in] @ w[out, in]^T`` with Pallas fwd and bwd."""
    return matmul(x, w.T)


def _linear_fwd(x, w):
    return matmul(x, w.T), (x, w)


def _linear_bwd(res, g):
    x, w = res
    dx = matmul(g, w)          # [m, out] @ [out, in] -> [m, in]
    dw = matmul(g.T, x)        # [out, m] @ [m, in]  -> [out, in]
    return dx, dw


linear.defvjp(_linear_fwd, _linear_bwd)


# ---------------------------------------------------------------------------
# Fused LoRA linear:  y = x W^T + s * (x A^T) B^T
#   W: [out, in] (frozen base), A: [r, in], B: [out, r], s = alpha / r
# The rank-r bottleneck means the LoRA branch stages only (bm*r + r*bn)
# extra VMEM per grid step — the reason LoRA's training cost is ~the base
# matmul (paper Table 5: LoRA/SwitchLoRA step time == full-rank).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lora_linear(x, w, a, b, scale):
    xa = matmul(x, a.T)
    return matmul(x, w.T) + scale * matmul(xa, b.T)


def _lora_fwd(x, w, a, b, scale):
    xa = matmul(x, a.T)                       # [m, r]
    y = matmul(x, w.T) + scale * matmul(xa, b.T)
    return y, (x, w, a, b, xa)


def _lora_bwd(scale, res, g):
    x, w, a, b, xa = res
    gb = matmul(g, b)                         # [m, r]
    dx = matmul(g, w) + scale * matmul(gb, a)
    # Base W is frozen during (Switch)LoRA training; its cotangent is still
    # produced for the full-rank/GaLore variants that differentiate w.
    dw = matmul(g.T, x)
    da = scale * matmul(gb.T, x)              # [r, in]
    db = scale * matmul(g.T, xa)              # [out, r]
    return dx, dw, da, db


lora_linear.defvjp(_lora_fwd, _lora_bwd)
