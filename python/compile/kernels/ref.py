"""Pure-jnp oracles for every Pallas kernel (the L1 correctness contract).

Each ``ref_*`` below is the mathematical definition the corresponding Pallas
kernel must match to float32 tolerance; ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts ``allclose`` against these.
"""

import jax.numpy as jnp


def ref_matmul(x, w):
    return jnp.asarray(x, jnp.float32) @ jnp.asarray(w, jnp.float32)


def ref_linear(x, w):
    """y = x @ W^T with W stored [out, in]."""
    return ref_matmul(x, w.T)


def ref_lora_linear(x, w, a, b, scale):
    """y = x W^T + scale * (x A^T) B^T."""
    return ref_matmul(x, w.T) + scale * ref_matmul(ref_matmul(x, a.T), b.T)


def ref_adam_step(p, g, m, v, s, mask, hyper):
    """Elementwise masked AdamW with per-element step counts (see adam.py)."""
    lr, b1, b2, eps, wd = [jnp.float32(h) for h in hyper]
    p, g, m, v, s, mask = [jnp.asarray(t, jnp.float32)
                           for t in (p, g, m, v, s, mask)]
    s_new = s + mask
    m_new = mask * (b1 * m + (1 - b1) * g) + (1 - mask) * m
    v_new = mask * (b2 * v + (1 - b2) * g * g) + (1 - mask) * v
    s_c = jnp.maximum(s_new, 1.0)  # see adam.py: frozen+reset lanes have s=0
    mhat = m_new / (1 - b1 ** s_c)
    vhat = v_new / (1 - b2 ** s_c)
    upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    return p - mask * lr * upd, m_new, v_new, s_new
