"""L1 Pallas kernel: fused AdamW step over the flattened trainable vector.

The paper (Appendix D) reshapes the optimizer's ``step`` state from a scalar
into a per-row/per-column vector so that switching can reset the states of
individual LoRA vectors.  We take that idea to its limit: the Rust
coordinator flattens every *trainable* tensor into one f32 vector and keeps
**per-element** ``step`` counts plus a 0/1 ``mask`` (the freeze mask of
Algorithm 2, line 8/13).  This single kernel then implements, elementwise:

    step' = step + mask
    m'    = mask ? b1*m + (1-b1)*g : m
    v'    = mask ? b2*v + (1-b2)*g^2 : v
    mhat  = m' / (1 - b1^step')
    vhat  = v' / (1 - b2^step')
    p'    = p - mask * lr * (mhat / (sqrt(vhat) + eps) + wd * p)

Frozen elements (mask=0) neither update nor advance their bias-correction
clock, and freshly-switched vectors restart from step=0 exactly as the
modified-AdamW of Appendix D does at row/column granularity.

The kernel is 1-D blocked; the flat vector is padded (by aot.py / the Rust
side) to a multiple of the block so every grid step is full.  Padding lanes
carry mask=0 and step=1 so they are inert — bias correction never divides by
zero.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D block for the flat vector.  8192 f32 * 7 arrays ~= 224 KiB VMEM/step.
BLOCK = 8192


def padded_size(n: int, block: int = BLOCK) -> int:
    return ((n + block - 1) // block) * block


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, s_ref, mask_ref, h_ref,
                 po_ref, mo_ref, vo_ref, so_ref):
    lr, b1, b2, eps, wd = (h_ref[0], h_ref[1], h_ref[2], h_ref[3], h_ref[4])
    p = p_ref[...]
    g = g_ref[...]
    m = m_ref[...]
    v = v_ref[...]
    s = s_ref[...]
    mask = mask_ref[...]
    s_new = s + mask
    m_new = mask * (b1 * m + (1.0 - b1) * g) + (1.0 - mask) * m
    v_new = mask * (b2 * v + (1.0 - b2) * g * g) + (1.0 - mask) * v
    # Frozen lanes can legitimately have s == 0 (a freshly reset-and-frozen
    # LoRA vector: reset zeroes s, the freeze zeroes mask).  Clamp the
    # bias-correction clock to >= 1 so 1-b^0 = 0 never divides; this never
    # changes live lanes, where mask == 1 implies s_new >= 1.
    s_c = jnp.maximum(s_new, 1.0)
    c1 = 1.0 - jnp.power(b1, s_c)
    c2 = 1.0 - jnp.power(b2, s_c)
    mhat = m_new / c1
    vhat = v_new / c2
    upd = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    po_ref[...] = p - mask * lr * upd
    mo_ref[...] = m_new
    vo_ref[...] = v_new
    so_ref[...] = s_new


@functools.partial(jax.jit, static_argnames=("block",))
def adam_step(p, g, m, v, s, mask, hyper, block: int = BLOCK):
    """One fused AdamW step over flat padded vectors.

    Args:
      p, g, m, v, s, mask: f32[N] with N % block == 0.
      hyper: f32[5] = (lr, beta1, beta2, eps, weight_decay).
    Returns:
      (p', m', v', s').
    """
    n = p.shape[0]
    assert n % block == 0, f"{n} not a multiple of {block}"
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    hspec = pl.BlockSpec((5,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 4
    return tuple(pl.pallas_call(
        _adam_kernel,
        out_shape=out_shape,
        grid=grid,
        in_specs=[spec, spec, spec, spec, spec, spec, hspec],
        out_specs=[spec, spec, spec, spec],
        interpret=True,
    )(p, g, m, v, s, mask, hyper))
