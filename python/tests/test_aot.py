"""AOT pipeline tests: lowering produces parseable HLO text with the
expected interface arity (the Rust loader's contract)."""

import jax.numpy as jnp
import pytest

from compile import aot
from compile import configs as C
from compile import model as M
from compile.kernels import adam as AK


def test_lower_adam_is_hlo_text():
    text = aot.lower_adam(AK.BLOCK)
    assert text.startswith("HloModule")
    # 7 inputs: p, g, m, v, s, mask, hyper
    assert text.count("parameter(") >= 7


def test_lower_variant_lora_fwdbwd_tiny():
    cfg = C.get("tiny")
    text, spec = aot.lower_variant(cfg, "lora_fwdbwd")
    assert text.startswith("HloModule")
    n_params = len(spec)
    # params + tokens
    assert text.count("parameter(") >= n_params + 1
    n_trainable = sum(p.trainable for p in spec)
    assert n_trainable < n_params


def test_lower_variant_rejects_unknown():
    cfg = C.get("tiny")
    with pytest.raises(ValueError):
        aot.lower_variant(cfg, "bogus")


def test_eval_fewer_outputs_than_fwdbwd():
    cfg = C.get("tiny")
    fwdbwd, spec = M.make_fwdbwd(cfg, lora=True)
    evalf, _ = M.make_eval(cfg, lora=True)
    import jax
    args = [jnp.zeros(p.shape, jnp.float32) for p in spec] + [
        jnp.zeros((cfg.batch, cfg.seq + 1), jnp.int32)]
    out_f = jax.eval_shape(fwdbwd, *args)
    out_e = jax.eval_shape(evalf, *args)
    assert len(out_e) == 1
    assert len(out_f) == 1 + sum(p.trainable for p in spec)
