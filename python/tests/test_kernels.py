"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and the tiling block target) and asserts
``assert_allclose`` against ``kernels/ref.py`` — the core correctness signal
for the compute hot path.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import lora_matmul as K
from compile.kernels import adam as AK
from compile.kernels import ref as R

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 32, 64, 96, 128])
BLOCKS = st.sampled_from([0, 8, 16, 32, 128])


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------

@given(dim=st.integers(1, 4096), target=st.integers(-4, 4096))
@settings(max_examples=200, deadline=None)
def test_pick_block_invariants(dim, target):
    b = K.pick_block(dim, target)
    assert 1 <= b <= dim
    assert dim % b == 0
    if target > 0:
        assert b <= max(target, 1) or b == 1 or dim % min(target, dim) != 0
    if target <= 0 or target >= dim:
        assert b == dim


def test_pick_block_power_of_two():
    assert K.pick_block(256, 128) == 128
    assert K.pick_block(64, 128) == 64
    assert K.pick_block(96, 128) == 96
    assert K.pick_block(96, 64) == 48


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@given(m=DIMS, k=DIMS, n=DIMS, block=BLOCKS)
@settings(max_examples=30, deadline=None)
def test_matmul_matches_ref(m, k, n, block):
    x, w = rand(m * 1000 + k, m, k), rand(n * 1000 + k, k, n)
    got = K.matmul(x, w, block=block)
    np.testing.assert_allclose(np.asarray(got), np.asarray(R.ref_matmul(x, w)),
                               rtol=2e-5, atol=2e-5)


def test_matmul_tiled_equals_whole():
    x, w = rand(1, 64, 32), rand(2, 32, 64)
    a = K.matmul(x, w, block=0)
    b = K.matmul(x, w, block=16)
    # f32 reduction order differs between tilings; bitwise equality is not
    # expected, only float32-level agreement.
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# linear fwd + bwd
# ---------------------------------------------------------------------------

@given(m=DIMS, inp=DIMS, out=DIMS)
@settings(max_examples=20, deadline=None)
def test_linear_fwd_bwd(m, inp, out):
    x, w = rand(3, m, inp), rand(4, out, inp)

    def f_pl(x, w):
        return (K.linear(x, w) ** 2).sum()

    def f_ref(x, w):
        return (R.ref_linear(x, w) ** 2).sum()

    lp, gp = jax.value_and_grad(f_pl, argnums=(0, 1))(x, w)
    lr, gr = jax.value_and_grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(float(lp), float(lr), rtol=1e-4)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# lora_linear fwd + bwd
# ---------------------------------------------------------------------------

@given(m=DIMS, inp=DIMS, out=DIMS, r=st.sampled_from([1, 2, 4, 8, 16]),
       scale=st.sampled_from([0.25, 1.0, 2.0]))
@settings(max_examples=20, deadline=None)
def test_lora_linear_fwd(m, inp, out, r, scale):
    x = rand(5, m, inp)
    w, a, b = rand(6, out, inp), rand(7, r, inp), rand(8, out, r)
    got = K.lora_linear(x, w, a, b, scale)
    want = R.ref_lora_linear(x, w, a, b, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@given(m=st.sampled_from([2, 8, 32]), inp=st.sampled_from([8, 32]),
       out=st.sampled_from([8, 48]), r=st.sampled_from([2, 8]))
@settings(max_examples=15, deadline=None)
def test_lora_linear_grads(m, inp, out, r):
    x = rand(9, m, inp)
    w, a, b = rand(10, out, inp), rand(11, r, inp), rand(12, out, r)
    t = rand(13, m, out)

    def f_pl(x, w, a, b):
        return ((K.lora_linear(x, w, a, b, 1.0) - t) ** 2).mean()

    def f_ref(x, w, a, b):
        return ((R.ref_lora_linear(x, w, a, b, 1.0) - t) ** 2).mean()

    gp = jax.grad(f_pl, argnums=(0, 1, 2, 3))(x, w, a, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w, a, b)
    for got, want in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


def test_lora_linear_zero_b_is_base_linear():
    """With B=0 the adapter contributes nothing (LoRA-default init)."""
    x, w = rand(14, 8, 16), rand(15, 12, 16)
    a, b = rand(16, 4, 16), jnp.zeros((12, 4), jnp.float32)
    got = K.lora_linear(x, w, a, b, 1.0)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(R.ref_linear(x, w)), rtol=1e-5,
                               atol=1e-5)


def test_lora_rank_additivity():
    """BA = sum of rank-1 outer products (paper Eq. (1))."""
    x = rand(17, 4, 8)
    w = jnp.zeros((6, 8), jnp.float32)
    a, b = rand(18, 3, 8), rand(19, 6, 3)
    full = K.lora_linear(x, w, a, b, 1.0)
    acc = jnp.zeros_like(full)
    for k in range(3):
        acc += K.lora_linear(x, w, a[k:k + 1], b[:, k:k + 1], 1.0)
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# fused Adam kernel
# ---------------------------------------------------------------------------

HYPER = st.tuples(st.sampled_from([1e-3, 1e-2]), st.just(0.9),
                  st.just(0.999), st.just(1e-8), st.sampled_from([0.0, 0.1]))


@given(nblocks=st.integers(1, 3), hyper=HYPER, seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_adam_matches_ref(nblocks, hyper, seed):
    n = nblocks * AK.BLOCK
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    p = jax.random.normal(ks[0], (n,))
    g = jax.random.normal(ks[1], (n,))
    m = jax.random.normal(ks[2], (n,)) * 0.01
    v = jax.random.uniform(ks[3], (n,)) * 0.01
    s = jnp.floor(jax.random.uniform(ks[4], (n,)) * 10) + 1
    mask = (jax.random.uniform(ks[5], (n,)) > 0.3).astype(jnp.float32)
    h = jnp.asarray(hyper, jnp.float32)
    got = AK.adam_step(p, g, m, v, s, mask, h)
    want = R.ref_adam_step(p, g, m, v, s, mask, hyper)
    for gg, ww in zip(got, want):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                   rtol=2e-5, atol=2e-6)


def test_adam_frozen_elements_inert():
    """mask=0 lanes keep p, m, v, s bit-identical (the freeze contract)."""
    n = AK.BLOCK
    p = jnp.arange(n, dtype=jnp.float32)
    g = jnp.ones((n,))
    m = jnp.full((n,), 0.5)
    v = jnp.full((n,), 0.25)
    s = jnp.ones((n,))
    mask = jnp.zeros((n,))
    h = jnp.asarray([1e-2, 0.9, 0.999, 1e-8, 0.1], jnp.float32)
    p2, m2, v2, s2 = AK.adam_step(p, g, m, v, s, mask, h)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(m))
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(v))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))


def test_adam_first_step_bias_correction():
    """From zero state with step 0, first update == -lr * sign-ish(g)."""
    n = AK.BLOCK
    g = jnp.full((n,), 2.0)
    zeros = jnp.zeros((n,))
    ones = jnp.ones((n,))
    h = jnp.asarray([1e-2, 0.9, 0.999, 1e-8, 0.0], jnp.float32)
    p2, m2, v2, s2 = AK.adam_step(zeros, g, zeros, zeros, zeros, ones, h)
    # mhat = g, vhat = g^2 -> update = lr * g/|g| = lr
    np.testing.assert_allclose(np.asarray(p2), -1e-2 * np.ones(n), rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(s2), np.ones(n))


def test_adam_reset_plus_frozen_lane_stays_finite():
    """Regression: a freshly reset (s=0, m=v=0) AND frozen (mask=0) lane —
    exactly what the switch op produces — must not go NaN via 0/0 bias
    correction multiplied by mask 0 (0·NaN = NaN)."""
    n = AK.BLOCK
    zeros = jnp.zeros((n,))
    mask = jnp.zeros((n,))
    h = jnp.asarray([1e-2, 0.9, 0.999, 1e-8, 0.0], jnp.float32)
    p = jnp.full((n,), 3.0)
    p2, m2, v2, s2 = AK.adam_step(p, jnp.ones((n,)), zeros, zeros, zeros,
                                  mask, h)
    assert np.all(np.isfinite(np.asarray(p2)))
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p))
    np.testing.assert_array_equal(np.asarray(s2), np.zeros(n))


def test_padded_size():
    assert AK.padded_size(1) == AK.BLOCK
    assert AK.padded_size(AK.BLOCK) == AK.BLOCK
    assert AK.padded_size(AK.BLOCK + 1) == 2 * AK.BLOCK
