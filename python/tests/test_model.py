"""L2 model correctness: shapes, pallas-vs-ref equivalence, loss semantics."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import configs as C
from compile import model as M

CFG = C.get("tiny")


@pytest.fixture(scope="module")
def lora_setup():
    p = M.init_params(CFG, jax.random.PRNGKey(0), lora=True)
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (2, CFG.seq + 1), 0, CFG.vocab)
    return p, tokens


@pytest.fixture(scope="module")
def full_setup():
    p = M.init_params(CFG, jax.random.PRNGKey(0), lora=False)
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (2, CFG.seq + 1), 0, CFG.vocab)
    return p, tokens


# ---------------------------------------------------------------------------
# param_spec
# ---------------------------------------------------------------------------

def test_param_spec_lora_structure():
    spec, linears = M.param_spec(CFG, lora=True)
    names = [pi.name for pi in spec]
    assert names[0] == "embed" and names[-1] == "lm_head"
    assert len(set(names)) == len(names), "duplicate param names"
    # 7 LoRA-adapted linears per layer
    assert len(linears) == 7 * CFG.layers
    for li in linears:
        byname = {pi.name: pi for pi in spec}
        assert byname[li.name].shape == (li.out_dim, li.in_dim)
        assert byname[li.a].shape == (CFG.rank, li.in_dim)
        assert byname[li.b].shape == (li.out_dim, CFG.rank)
        assert not byname[li.name].trainable
        assert byname[li.a].trainable and byname[li.b].trainable


def test_param_spec_full_has_no_lora():
    spec, linears = M.param_spec(CFG, lora=False)
    assert linears == []
    assert all(pi.trainable for pi in spec)
    assert all(pi.role not in ("lora_a", "lora_b") for pi in spec)


def test_param_spec_cls_swaps_head():
    spec, _ = M.param_spec(CFG, lora=False, cls=True)
    names = [pi.name for pi in spec]
    assert "cls_head" in names and "lm_head" not in names
    byname = {pi.name: pi for pi in spec}
    assert byname["cls_head"].shape == (CFG.n_cls, CFG.hidden)


def test_trainable_counts_lora_less_than_full():
    lora_spec, _ = M.param_spec(CFG, lora=True)
    full_spec, _ = M.param_spec(CFG, lora=False)
    n_lora = sum(p.numel for p in lora_spec if p.trainable)
    n_full = sum(p.numel for p in full_spec if p.trainable)
    assert n_lora < n_full


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def test_forward_shape(lora_setup):
    p, tokens = lora_setup
    h = M.forward(CFG, p, tokens[:, :-1], lora=True)
    assert h.shape == (2, CFG.seq, CFG.hidden)


def test_initial_loss_near_uniform(full_setup):
    """Random init ⇒ loss ≈ ln(vocab)."""
    p, tokens = full_setup
    loss = M.lm_loss(CFG, p, tokens, lora=False)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_pallas_matches_ref_full_model(lora_setup):
    """Entire fwd+bwd through Pallas kernels == pure-jnp reference."""
    p, tokens = lora_setup
    fn, spec = M.make_fwdbwd(CFG, lora=True, use_pallas=True)
    fn_ref, _ = M.make_fwdbwd(CFG, lora=True, use_pallas=False)
    args = [p[pi.name] for pi in spec] + [tokens]
    out = jax.jit(fn)(*args)
    out_ref = jax.jit(fn_ref)(*args)
    assert len(out) == 1 + sum(pi.trainable for pi in spec)
    for a, b in zip(out, out_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-5)


def test_causality(lora_setup):
    """Changing a future token must not change past hidden states."""
    p, tokens = lora_setup
    t1 = tokens[:, :-1]
    t2 = t1.at[:, -1].set((t1[:, -1] + 1) % CFG.vocab)
    h1 = M.forward(CFG, p, t1, lora=True)
    h2 = M.forward(CFG, p, t2, lora=True)
    np.testing.assert_allclose(np.asarray(h1[:, :-1]),
                               np.asarray(h2[:, :-1]), rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]))


def test_frozen_base_receives_no_grad(lora_setup):
    """fwdbwd in LoRA mode returns grads only for trainable params."""
    p, tokens = lora_setup
    fn, spec = M.make_fwdbwd(CFG, lora=True)
    args = [p[pi.name] for pi in spec] + [tokens]
    out = jax.jit(fn)(*args)
    t_spec = [pi for pi in spec if pi.trainable]
    assert len(out) == 1 + len(t_spec)
    for g, pi in zip(out[1:], t_spec):
        assert g.shape == pi.shape


def test_lora_merge_equivalence(lora_setup):
    """Merging W ← W + s·BA and zeroing the adapter preserves outputs —
    the invariant behind both the switch op (Alg. 1) and checkpoint merging."""
    p, tokens = lora_setup
    _, linears = M.param_spec(CFG, lora=True)
    merged = dict(p)
    for li in linears:
        merged[li.name] = p[li.name] + CFG.lora_scale * (p[li.b] @ p[li.a])
        merged[li.b] = jnp.zeros_like(p[li.b])
    l1 = M.lm_loss(CFG, p, tokens, lora=True)
    l2 = M.lm_loss(CFG, merged, tokens, lora=True)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


def test_cls_outputs(lora_setup):
    p = M.init_params(CFG, jax.random.PRNGKey(2), lora=False, cls=True)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (4, CFG.seq), 0,
                                CFG.vocab)
    labels = jnp.asarray([0, 1, 2, 3], jnp.int32)
    fn, spec = M.make_cls_eval(CFG, lora=False)
    args = [p[pi.name] for pi in spec] + [tokens, labels]
    loss, correct = jax.jit(fn)(*args)
    assert 0 <= float(correct) <= 4
    assert abs(float(loss) - np.log(CFG.n_cls)) < 1.0


def test_grad_direction_decreases_loss(full_setup):
    """One SGD step along -grad lowers the loss (sanity of the bwd pass)."""
    p, tokens = full_setup
    fn, spec = M.make_fwdbwd(CFG, lora=False)
    args = [p[pi.name] for pi in spec] + [tokens]
    out = jax.jit(fn)(*args)
    loss0 = float(out[0])
    lr = 0.1
    newp = dict(p)
    for g, pi in zip(out[1:], spec):
        newp[pi.name] = p[pi.name] - lr * g
    loss1 = float(M.lm_loss(CFG, newp, tokens, lora=False))
    assert loss1 < loss0
