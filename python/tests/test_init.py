"""Initialization law tests (paper Section 2.2 Eq. (3) / Appendix A)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import configs as C
from compile import model as M


def test_std_formulas():
    """Spot-check Eq. (3)/Eq. (18) against hand computation."""
    m, n, r, gain = 64, 128, 16, 1.0
    std_b, std_a = M.switchlora_stds(m, n, r, gain)
    assert std_b == pytest.approx((r / (m * n) ** 0.5) ** 0.25)
    assert std_a == pytest.approx(((m * r) ** 0.5 / (n * n ** 0.5)) ** 0.25)


def test_forward_variance_balance():
    """Eq. (14): std[(1/r)·B·A·x] for unit-variance input.

    Substituting the paper's closed forms (Eq. (18)) into the Eq. (14) chain
    sqrt(r)/r · std_B · std_A · sqrt(n) gives exactly gain·r^{-1/8} — i.e.
    the published formulas satisfy the forward condition up to a slowly
    varying r^{-1/8} factor (≈0.65 even at r=32).  Assert the exact identity
    and that it stays O(1)."""
    for (m, n, r) in [(64, 64, 8), (128, 128, 32), (512, 128, 16)]:
        std_b, std_a = M.switchlora_stds(m, n, r, gain=1.0)
        prod = (r ** 0.5 / r) * std_b * std_a * (n ** 0.5)
        assert prod == pytest.approx(r ** (-1.0 / 8.0), rel=1e-6)
        assert 0.4 < prod < 1.5


def test_grad_magnitude_balance():
    """Eq. (16): std[∇B·A] vs std[B·∇A] under the derived stds.

    With std[∇b] ∝ sqrt(n)·std_a and std[∇a] ∝ sqrt(m)·std_b (Eq. (15)),
    the published formulas give ratio (sqrt(n)·std_a²)/(sqrt(m)·std_b²)
    = r^{-1/4} exactly — balanced up to a factor that is shape-independent
    and mild in r.  Assert the identity (shape-independence is the point:
    the B-update and A-update magnitudes match across all layer shapes)."""
    for (m, n, r) in [(64, 64, 8), (128, 256, 32), (512, 128, 16)]:
        std_b, std_a = M.switchlora_stds(m, n, r)
        ratio = (n ** 0.5 * std_a * std_a) / (m ** 0.5 * std_b * std_b)
        assert ratio == pytest.approx(r ** -0.25, rel=1e-6)


@pytest.mark.parametrize("init", ["switchlora", "lora_default"])
def test_init_empirical_std(init):
    cfg = C.get("s1m")
    p = M.init_params(cfg, jax.random.PRNGKey(0), lora=True, init=init)
    _, linears = M.param_spec(cfg, lora=True)
    li = linears[0]
    a, b = np.asarray(p[li.a]), np.asarray(p[li.b])
    if init == "switchlora":
        std_b, std_a = M.switchlora_stds(li.out_dim, li.in_dim, cfg.rank)
        assert np.std(a) == pytest.approx(std_a, rel=0.1)
        assert np.std(b) == pytest.approx(std_b, rel=0.1)
        assert abs(np.mean(a)) < 0.05 and abs(np.mean(b)) < 0.05
    else:
        # LoRA default: B == 0, A Kaiming-uniform
        assert np.all(b == 0)
        assert np.std(a) == pytest.approx((6.0 / li.in_dim) ** 0.5 / 3 ** 0.5,
                                          rel=0.1)


def test_init_output_consistency():
    """LoRA-default init (B=0) leaves the model output == base model."""
    cfg = C.get("tiny")
    p = M.init_params(cfg, jax.random.PRNGKey(0), lora=True,
                      init="lora_default")
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq), 0,
                                cfg.vocab)
    h_lora = M.forward(cfg, p, tokens, lora=True)
    h_base = M.forward(cfg, p, tokens, lora=False)
    np.testing.assert_allclose(np.asarray(h_lora), np.asarray(h_base),
                               rtol=1e-5, atol=1e-6)
