"""Manifest integrity: the Python↔Rust contract emitted by aot.py.

These tests run against the artifacts/ directory if it exists (built by
``make artifacts``); they are skipped otherwise so `pytest` stays green on a
fresh checkout.
"""

import json
import os

import pytest

from compile import configs as C
from compile import model as M
from compile import aot
from compile.kernels import adam as AK

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifests():
    if not os.path.isdir(ART):
        return []
    out = []
    for d in sorted(os.listdir(ART)):
        mp = os.path.join(ART, d, "manifest.json")
        if os.path.exists(mp):
            out.append(mp)
    return out


MANIFESTS = _manifests()
pytestmark = pytest.mark.skipif(not MANIFESTS,
                                reason="artifacts/ not built")


@pytest.mark.parametrize("mp", MANIFESTS, ids=lambda p: p.split(os.sep)[-2])
def test_manifest_matches_spec(mp):
    with open(mp) as f:
        man = json.load(f)
    c = man["config"]
    cfg = C.ModelConfig(
        name=c["name"], vocab=c["vocab"], hidden=c["hidden"],
        layers=c["layers"], heads=c["heads"], ff=c["ff"], seq=c["seq"],
        rank=c["rank"], lora_alpha=c["lora_alpha"], batch=c["batch"],
        n_cls=c["n_cls"])
    for lora, key in ((True, "params_lora"), (False, "params_full")):
        spec, _ = M.param_spec(cfg, lora=lora)
        got = man[key]
        assert len(got) == len(spec)
        for gi, pi in zip(got, spec):
            assert gi["name"] == pi.name
            assert tuple(gi["shape"]) == pi.shape
            assert gi["role"] == pi.role
            assert gi["trainable"] == pi.trainable
            assert gi["numel"] == pi.numel
    # linears metadata drives the switch algorithm
    _, linears = M.param_spec(cfg, lora=True)
    assert len(man["linears"]) == len(linears)
    for gl, li in zip(man["linears"], linears):
        assert (gl["name"], gl["a"], gl["b"]) == (li.name, li.a, li.b)
        assert (gl["m"], gl["n"]) == (li.out_dim, li.in_dim)


@pytest.mark.parametrize("mp", MANIFESTS, ids=lambda p: p.split(os.sep)[-2])
def test_manifest_counts_and_padding(mp):
    with open(mp) as f:
        man = json.load(f)
    assert man["n_trainable_lora"] == sum(
        p["numel"] for p in man["params_lora"] if p["trainable"])
    assert man["n_trainable_full"] == sum(
        p["numel"] for p in man["params_full"] if p["trainable"])
    assert man["n_trainable_lora"] < man["n_trainable_full"]
    for key, pad in (("n_trainable_lora", "adam_padded_lora"),
                     ("n_trainable_full", "adam_padded_full")):
        assert man[pad] == AK.padded_size(man[key])
        assert man[pad] % AK.BLOCK == 0
        # the shared adam artifact for this size must exist
        assert os.path.exists(os.path.join(ART, f"adam_{man[pad]}.hlo.txt"))


@pytest.mark.parametrize("mp", MANIFESTS, ids=lambda p: p.split(os.sep)[-2])
def test_hlo_artifacts_exist_and_parse_header(mp):
    with open(mp) as f:
        man = json.load(f)
    d = os.path.dirname(mp)
    for v in man["variants"]:
        path = os.path.join(d, f"{v}.hlo.txt")
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), f"{path} is not HLO text"


def test_parse_spec_overrides():
    name, cfg, over = aot.parse_spec("s4m:rank=8")
    assert over and name == "s4m_r8" and cfg.rank == 8
    assert cfg.lora_alpha == 8.0
    name, cfg, over = aot.parse_spec("tiny")
    assert not over and name == "tiny"
    name, cfg, over = aot.parse_spec("s4m:seq=128")
    assert name == "s4m_s128" and cfg.seq == 128
