//! The kernel layer's determinism contract, end to end: threading only
//! partitions output rows across tasks and never changes any element's
//! accumulation order, so every result — gradients, loss curves, final
//! weights, comm bytes — is bitwise identical at any thread count, and
//! data-parallel workers on real OS threads reproduce the interleaved
//! schedule exactly.
//!
//! The tests toggle the process-global pool configuration, so they
//! serialize on a mutex (cargo's in-process test threads would otherwise
//! interleave `set_threads` calls; results would still match — that is
//! the point of the contract — but a failure would be confusing).

use std::sync::{Mutex, MutexGuard};

use switchlora::coordinator::trainer::{default_artifacts_dir, Method,
                                       RunResult, TrainConfig, Trainer};
use switchlora::data::dataset::synth_batches;
use switchlora::kernels::{set_threads, threads};
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::runtime::{Engine, NativeModel, StepRuntime};

static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the pool to whatever was configured (CLI/env/detected)
/// before the test toggled it, pass or fail — so a suite run under
/// `SWITCHLORA_THREADS=2` keeps exercising the pool after these tests.
struct Restore(usize);

impl Restore {
    fn arm() -> Restore {
        Restore(threads())
    }
}

impl Drop for Restore {
    fn drop(&mut self) {
        set_threads(self.0);
    }
}

fn manifest() -> Manifest {
    Manifest::for_spec(&default_artifacts_dir(), "tiny").unwrap()
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn curve_bits(c: &[(u64, f64)]) -> Vec<(u64, u64)> {
    c.iter().map(|&(s, l)| (s, l.to_bits())).collect()
}

fn assert_runs_match(a: &RunResult, b: &RunResult, what: &str) {
    assert_eq!(curve_bits(&a.train_curve), curve_bits(&b.train_curve),
               "{what}: train curves diverge");
    assert_eq!(curve_bits(&a.eval_curve), curve_bits(&b.eval_curve),
               "{what}: eval curves diverge");
    assert_eq!(a.comm.bytes, b.comm.bytes, "{what}: comm bytes diverge");
    assert_eq!(a.comm.rounds, b.comm.rounds,
               "{what}: comm rounds diverge");
    assert_eq!(a.counters, b.counters, "{what}: counters diverge");
}

fn quick_cfg(method: Method, steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", method, steps);
    cfg.eval_every = steps / 2;
    cfg.eval_batches = 2;
    cfg.warmup = 2;
    cfg
}

fn run_with_threads(cfg: &TrainConfig, nt: usize)
    -> (RunResult, ParamStore) {
    set_threads(nt);
    let mut engine = Engine::cpu().unwrap();
    Trainer::new(cfg.clone()).unwrap().run(&mut engine).unwrap()
}

#[test]
fn fwdbwd_grads_bitwise_identical_across_thread_counts() {
    let _g = lock();
    let _r = Restore::arm();
    let man = manifest();
    for variant in [Variant::Lora, Variant::Full] {
        let store = seeded_store(&man, variant, 7).unwrap();
        let model = NativeModel::new(man.clone(), variant).unwrap();
        let mut it = synth_batches(man.config.vocab, 3, 0,
                                   man.config.batch, man.config.seq);
        let b = it.next_batch();
        let runs: Vec<(f32, Vec<f32>)> = [1usize, 2, 4]
            .iter()
            .map(|&nt| {
                set_threads(nt);
                model
                    .fwdbwd(&store, &b.tokens, b.batch, b.seq_plus_1)
                    .unwrap()
            })
            .collect();
        let (loss1, ref grads1) = runs[0];
        for (nt, (loss, grads)) in
            [2usize, 4].iter().zip(runs.iter().skip(1))
        {
            assert_eq!(loss1.to_bits(), loss.to_bits(),
                       "{variant:?}: loss differs at {nt} threads");
            assert_eq!(bits32(grads1), bits32(grads),
                       "{variant:?}: grads differ at {nt} threads");
        }
        // eval and full-context logits ride the same kernels
        set_threads(1);
        let e1 = model
            .eval_loss(&store, &b.tokens, b.batch, b.seq_plus_1)
            .unwrap();
        set_threads(4);
        let e4 = model
            .eval_loss(&store, &b.tokens, b.batch, b.seq_plus_1)
            .unwrap();
        assert_eq!(e1.to_bits(), e4.to_bits(),
                   "{variant:?}: eval loss differs");
    }
}

#[test]
fn inference_logits_bitwise_identical_across_thread_counts() {
    let _g = lock();
    let _r = Restore::arm();
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 11).unwrap();
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let (b, t) = (2usize, 24usize);
    let toks: Vec<i32> =
        (0..b * t).map(|i| (i * 37 % man.config.vocab) as i32).collect();
    set_threads(1);
    let l1 = model.forward_logits(&store, &toks, b, t).unwrap();
    set_threads(4);
    let l4 = model.forward_logits(&store, &toks, b, t).unwrap();
    assert_eq!(bits32(&l1), bits32(&l4), "full-context logits differ");
}

#[test]
fn training_curves_bitwise_identical_for_all_five_methods() {
    let _g = lock();
    let _r = Restore::arm();
    for name in ["full", "lora", "switchlora", "relora", "galore"] {
        let method = Method::parse(name).unwrap();
        let cfg = quick_cfg(method, 6);
        let (r1, s1) = run_with_threads(&cfg, 1);
        let (r2, s2) = run_with_threads(&cfg, 2);
        assert_runs_match(&r1, &r2, name);
        assert_eq!(bits32(&s1.data), bits32(&s2.data),
                   "{name}: final weights diverge between 1 and 2 \
                    threads");
    }
}

#[test]
fn data_parallel_workers_threaded_matches_interleaved() {
    let _g = lock();
    let _r = Restore::arm();
    let mut cfg = quick_cfg(Method::parse("switchlora").unwrap(), 8);
    cfg.workers = 2;
    // threads=1: the interleaved single-thread schedule (the pre-thread
    // reference); threads=4: one OS thread per shard + threaded kernels
    let (r1, s1) = run_with_threads(&cfg, 1);
    let (r4, s4) = run_with_threads(&cfg, 4);
    assert_runs_match(&r1, &r4, "workers=2");
    assert_eq!(bits32(&s1.data), bits32(&s4.data),
               "workers=2: final weights diverge");
    // the ledger measured real ring traffic: gradients travel as the
    // fused-Adam-padded vector, once per step
    let padded = manifest().adam_padded(Variant::Lora).unwrap();
    let expected = switchlora::coordinator::data_parallel::
        expected_ring_bytes(padded, 2,
                            switchlora::tensor::dtype::DType::F32);
    assert_eq!(r1.comm.bytes, expected * 8, "ring bytes off for 8 steps");
}
