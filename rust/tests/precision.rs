//! Precision-layer integration tests: the dtype plumbing end to end.
//!
//! What is pinned here:
//! * the all-f32 default is *inert* — a policy-carrying model reproduces
//!   the legacy arithmetic bitwise;
//! * `--precision bf16` equals rounding the frozen base weights through
//!   bf16 and running the f32 kernels (the dequant-on-load contract);
//! * `--comm-dtype bf16` halves the measured ring bytes exactly, end to
//!   end through the trainer's ledger;
//! * a full bf16 policy trains, checkpoints and resumes bitwise;
//! * `--quantize-base int8` serves logits within a stated tolerance of
//!   the f32 reference from a ~4x smaller frozen base.
//!
//! Caveat: the inertness tests compare the refactored path against
//! itself, not against pre-refactor golden bits (cross-language goldens
//! are not bit-trustworthy, and none were minted before the refactor).
//! The continuity claim versus older code rests on the op-for-op
//! equivalence of `lin_fwd`/`lin_bwd` with the former
//! `lora_linear_fwd`/`lora_linear_bwd` — which still exist as
//! standalone ops, so `legacy_ops_agree_with_model_path` below pins the
//! refactored model path bitwise against those original kernels.

use switchlora::coordinator::trainer::{default_artifacts_dir, Method,
                                       TrainConfig, Trainer};
use switchlora::infer::{generate, merged_full_store, GenConfig};
use switchlora::methods::SwitchParams;
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, Variant};
use switchlora::model::packed::PackedStore;
use switchlora::runtime::{Engine, InferRuntime, NativeModel, StepRuntime};
use switchlora::tensor::dtype::{round_through, DType, PrecisionPolicy};
use switchlora::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::for_spec(&default_artifacts_dir(), "tiny").unwrap()
}

fn bf16_policy() -> PrecisionPolicy {
    PrecisionPolicy::from_flags(Some("bf16"), Some("bf16"), Some("bf16"),
                                None, None)
        .unwrap()
}

fn quick_cfg(method: Method, steps: u64, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", method, steps);
    cfg.eval_every = steps;
    cfg.eval_batches = 2;
    cfg.warmup = 3;
    cfg.workers = workers;
    cfg
}

fn one_batch(man: &Manifest) -> (Vec<i32>, usize, usize) {
    let mc = &man.config;
    let mut it = switchlora::data::dataset::synth_batches(
        mc.vocab, 1, 0, mc.batch, mc.seq);
    let b = it.next_batch();
    (b.tokens.clone(), b.batch, b.seq_plus_1)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---------------------------------------------------------------------
// Inertness of the default policy + the frozen-base rounding contract.
// ---------------------------------------------------------------------

#[test]
fn legacy_ops_agree_with_model_path() {
    // `lora_linear_fwd`/`lora_linear_bwd` are the UNTOUCHED pre-refactor
    // kernels; the model's `lin_fwd`/`lin_bwd` now compose the same math
    // from the packed primitives.  Transcribe that composition here and
    // demand bitwise agreement with the originals — the golden that
    // pins continuity with pre-precision-layer arithmetic.
    use switchlora::kernels::{addmm_nn, addmm_nn_packed, addmm_nt,
                              addmm_nt_packed, addmm_tn};
    use switchlora::runtime::native::{lora_linear_bwd, lora_linear_fwd};
    use switchlora::tensor::dtype::MatRef;
    let mut rng = Rng::new(17);
    let (rows, n_in, m, r, scale) = (9usize, 13usize, 11usize, 3usize,
                                     0.625f32);
    let randv = |n: usize, rng: &mut Rng| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
    };
    let x = randv(rows * n_in, &mut rng);
    let w = randv(m * n_in, &mut rng);
    let a = randv(r * n_in, &mut rng);
    let b = randv(m * r, &mut rng);
    let dy = randv(rows * m, &mut rng);
    // forward: legacy vs the lin_fwd composition
    let (y_old, xa_old) =
        lora_linear_fwd(&x, &w, &a, &b, scale, rows, n_in, m, r);
    let mut y = vec![0.0f32; rows * m];
    addmm_nt_packed(&mut y, &x, MatRef::F32(&w), rows, n_in, m);
    let mut xa = vec![0.0f32; rows * r];
    addmm_nt(&mut xa, &x, &a, rows, n_in, r);
    let mut yb = vec![0.0f32; rows * m];
    addmm_nt(&mut yb, &xa, &b, rows, r, m);
    for (yi, bi) in y.iter_mut().zip(&yb) {
        *yi += scale * bi;
    }
    assert_eq!(bits(&y), bits(&y_old), "forward drifted from legacy op");
    assert_eq!(bits(&xa), bits(&xa_old));
    // backward: legacy vs the lin_bwd composition
    let g_old = lora_linear_bwd(&dy, &x, &xa, &w, &a, &b, scale, rows,
                                n_in, m, r, false);
    let mut dx = vec![0.0f32; rows * n_in];
    addmm_nn_packed(&mut dx, &dy, MatRef::F32(&w), rows, m, n_in);
    let mut dyb = vec![0.0f32; rows * r];
    addmm_nn(&mut dyb, &dy, &b, rows, m, r);
    for v in dyb.iter_mut() {
        *v *= scale;
    }
    addmm_nn(&mut dx, &dyb, &a, rows, r, n_in);
    let mut da = vec![0.0f32; r * n_in];
    addmm_tn(&mut da, &dyb, &x, rows, r, n_in);
    let mut db = vec![0.0f32; m * r];
    addmm_tn(&mut db, &dy, &xa, rows, m, r);
    for v in db.iter_mut() {
        *v *= scale;
    }
    assert_eq!(bits(&dx), bits(&g_old.dx),
               "backward dx drifted from legacy op");
    assert_eq!(bits(&da), bits(&g_old.da.unwrap()));
    assert_eq!(bits(&db), bits(&g_old.db.unwrap()));
}

#[test]
fn default_policy_model_is_bitwise_legacy() {
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 7).unwrap();
    let (tokens, batch, sp1) = one_batch(&man);
    let legacy = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let explicit = NativeModel::with_policy(
        man.clone(), Variant::Lora, PrecisionPolicy::default()).unwrap();
    let (l1, g1) = legacy.fwdbwd(&store, &tokens, batch, sp1).unwrap();
    let (l2, g2) = explicit.fwdbwd(&store, &tokens, batch, sp1).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits());
    assert_eq!(bits(&g1), bits(&g2));
}

#[test]
fn bf16_frozen_base_equals_rounded_master_bitwise() {
    // The dequant-on-load contract, through the whole model: running
    // with frozen_base=bf16 must equal rounding every adapted linear's
    // base W through bf16 on the master store and running plain f32.
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 8).unwrap();
    let mut rounded = store.clone();
    for li in &man.linears {
        for x in rounded.slice_mut(&li.name).unwrap() {
            *x = round_through(*x, DType::Bf16);
        }
    }
    let (tokens, batch, sp1) = one_batch(&man);
    let policy = bf16_policy();
    let m_pol =
        NativeModel::with_policy(man.clone(), Variant::Lora, policy)
            .unwrap();
    let m_ref = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let (l1, g1) = m_pol.fwdbwd(&store, &tokens, batch, sp1).unwrap();
    let (l2, g2) = m_ref.fwdbwd(&rounded, &tokens, batch, sp1).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits(), "loss diverged");
    assert_eq!(bits(&g1), bits(&g2), "gradients diverged");
    // and it genuinely engaged: the rounded base changes the numbers
    let (l0, _) = m_ref.fwdbwd(&store, &tokens, batch, sp1).unwrap();
    assert_ne!(l0.to_bits(), l1.to_bits(),
               "bf16 frozen base was a silent no-op");
}

// ---------------------------------------------------------------------
// Communication: the ledger halving claim, through the trainer.
// ---------------------------------------------------------------------

#[test]
fn bf16_comm_dtype_halves_ledger_bytes_exactly() {
    let mut engine = Engine::cpu().unwrap();
    let steps = 6u64;
    let mut run = |comm: &str| {
        let mut cfg = quick_cfg(Method::lora(), steps, 2);
        cfg.precision =
            PrecisionPolicy::from_flags(None, Some(comm), None, None,
                                        None)
                .unwrap();
        Trainer::new(cfg).unwrap().run(&mut engine).unwrap().0
    };
    let f32_run = run("f32");
    let bf16_run = run("bf16");
    assert!(f32_run.comm.bytes > 0);
    assert_eq!(f32_run.comm.bytes, 2 * bf16_run.comm.bytes,
               "bf16 wire must move exactly half the f32 ring volume");
    assert_eq!(f32_run.comm.rounds, bf16_run.comm.rounds);
    // the compressed-gradient run still trains
    assert!(bf16_run.final_eval_loss.is_finite());
    assert!((f32_run.final_eval_loss - bf16_run.final_eval_loss).abs()
                < 0.5,
            "bf16 gradient wire diverged: {} vs {}",
            f32_run.final_eval_loss, bf16_run.final_eval_loss);
}

// ---------------------------------------------------------------------
// Full bf16 policy: trains, checkpoints, resumes bitwise.
// ---------------------------------------------------------------------

#[test]
fn bf16_policy_run_resumes_bitwise() {
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join("switchlora_precision_resume");
    std::fs::remove_dir_all(&dir).ok();
    let (steps, half) = (12u64, 6u64);
    let mut cfg = quick_cfg(
        Method::switchlora(SwitchParams { interval0: 5.0, ratio: 0.4,
                                          n_freeze: 2 }),
        steps, 2);
    cfg.eval_every = 4;
    cfg.ckpt_every = half;
    cfg.ckpt_path = Some(dir.join("snap_{step}.ckpt"));
    cfg.precision = bf16_policy();
    let (full, full_store) =
        Trainer::new(cfg.clone()).unwrap().run(&mut engine).unwrap();
    let mut rcfg = cfg.clone();
    rcfg.resume = Some(dir.join(format!("snap_{half}.ckpt")));
    rcfg.ckpt_path = Some(dir.join("resnap_{step}.ckpt"));
    let (res, res_store) =
        Trainer::new(rcfg).unwrap().run(&mut engine).unwrap();
    for (a, b) in full.train_curve[half as usize..]
        .iter()
        .zip(&res.train_curve)
    {
        assert_eq!(a, b, "train curve diverged at step {}", a.0);
    }
    assert_eq!(full.final_eval_loss, res.final_eval_loss);
    assert_eq!(full_store.data, res_store.data, "weights diverged");

    // resuming under a different moments dtype is refused loudly
    let mut wrong = cfg.clone();
    wrong.resume = Some(dir.join(format!("snap_{half}.ckpt")));
    wrong.ckpt_path = Some(dir.join("wrong_{step}.ckpt"));
    wrong.precision.moments = DType::F32;
    let err = Trainer::new(wrong)
        .unwrap()
        .run(&mut engine)
        .unwrap_err()
        .to_string();
    assert!(err.contains("moments"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Downgrade a v3 resumable checkpoint to the v2 byte format and resume
/// from it: pre-precision-layer checkpoints must keep resuming
/// identically (their moments are f32, their tensors untagged).
#[test]
fn v2_format_checkpoint_resumes_identically() {
    use std::io::Write as _;
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join("switchlora_precision_v2");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).ok();
    let (steps, half) = (10u64, 5u64);
    let cfg = {
        let mut c = quick_cfg(Method::lora(), steps, 1);
        c.eval_every = 5;
        c.ckpt_every = half;
        c.ckpt_path = Some(dir.join("snap_{step}.ckpt"));
        c
    };
    let (full, full_store) =
        Trainer::new(cfg.clone()).unwrap().run(&mut engine).unwrap();

    // rewrite the step-`half` snapshot in the v2 dialect
    let v3 = switchlora::coordinator::checkpoint::load(
        &dir.join(format!("snap_{half}.ckpt")))
        .unwrap();
    let v2_path = dir.join("downgraded_v2.ckpt");
    {
        let mut w = Vec::new();
        let put_str = |w: &mut Vec<u8>, s: &str| {
            w.extend_from_slice(&(s.len() as u32).to_le_bytes());
            w.extend_from_slice(s.as_bytes());
        };
        let put_f32s = |w: &mut Vec<u8>, xs: &[f32]| {
            w.extend_from_slice(&(xs.len() as u64).to_le_bytes());
            for x in xs {
                w.extend_from_slice(&x.to_le_bytes());
            }
        };
        w.extend_from_slice(b"SWLORA2\0");
        put_str(&mut w, &v3.config_name);
        w.extend_from_slice(&(v3.params.len() as u64).to_le_bytes());
        for (name, data) in &v3.params {
            put_str(&mut w, name);
            put_f32s(&mut w, data);
        }
        let o = v3.opt.as_ref().expect("resumable ckpt has moments");
        assert_eq!(o.moments_dtype, DType::F32);
        w.push(1);
        put_f32s(&mut w, &o.m);
        put_f32s(&mut w, &o.v);
        put_f32s(&mut w, &o.s);
        let m = v3.method.as_ref().expect("resumable ckpt has method");
        w.push(1);
        put_str(&mut w, &m.name);
        w.extend_from_slice(&m.version.to_le_bytes());
        w.extend_from_slice(&(m.payload.len() as u64).to_le_bytes());
        w.extend_from_slice(&m.payload);
        let t = v3.trainer.as_ref().expect("resumable ckpt has trainer");
        w.push(1);
        let mut payload = Vec::new();
        switchlora::util::bytes::put_u64(&mut payload, t.next_step);
        switchlora::util::bytes::put_rng(&mut payload, &t.rng);
        switchlora::util::bytes::put_f64(&mut payload, t.ema_value);
        switchlora::util::bytes::put_u8(&mut payload,
                                        u8::from(t.ema_primed));
        switchlora::util::bytes::put_u64(&mut payload, t.comm_bytes);
        switchlora::util::bytes::put_u64(&mut payload, t.comm_rounds);
        w.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        w.extend_from_slice(&payload);
        std::fs::File::create(&v2_path)
            .unwrap()
            .write_all(&w)
            .unwrap();
    }
    let mut rcfg = cfg.clone();
    rcfg.resume = Some(v2_path);
    rcfg.ckpt_path = Some(dir.join("resnap_{step}.ckpt"));
    let (res, res_store) =
        Trainer::new(rcfg).unwrap().run(&mut engine).unwrap();
    for (a, b) in full.train_curve[half as usize..]
        .iter()
        .zip(&res.train_curve)
    {
        assert_eq!(a, b, "v2 resume diverged at step {}", a.0);
    }
    assert_eq!(full_store.data, res_store.data,
               "v2 resume: weights diverged");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// int8 frozen-base serving.
// ---------------------------------------------------------------------

#[test]
fn quantized_base_serving_holds_logits_within_tolerance() {
    let man = manifest();
    let lora = seeded_store(&man, Variant::Lora, 9).unwrap();
    let merged = merged_full_store(&man, &lora).unwrap();
    let dense = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let mut rng = Rng::new(21);
    let ctx: Vec<i32> =
        (0..24).map(|_| rng.below(man.config.vocab) as i32).collect();
    let mut c0 = dense.new_cache(1, ctx.len() + 1);
    let l_ref = dense.prefill(&merged, &mut c0, 0, &ctx).unwrap();
    let max_abs = l_ref.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    // stated tolerances (fraction of the logit range + a floor): bf16
    // carries ~2^-9 relative weight error, int8 ~0.4% of each row's max
    for (dtype, tol) in [(DType::Bf16, 0.05f32), (DType::I8, 0.10f32)] {
        let packed =
            PackedStore::quantize_base(&merged, dtype).unwrap();
        let mut c = dense.new_cache(1, ctx.len() + 1);
        let l_q = dense.prefill(&packed, &mut c, 0, &ctx).unwrap();
        let max_diff = l_ref
            .iter()
            .zip(&l_q)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        assert!(max_diff <= tol * (max_abs + 1.0),
                "{dtype:?}: max|Δlogit| {max_diff} vs tolerance {} \
                 (|logit|max {max_abs})", tol * (max_abs + 1.0));
        assert!(max_diff > 0.0, "{dtype:?} quantization was a no-op");
    }
    // the int8 frozen base really is ~4x smaller
    let packed =
        PackedStore::quantize_base(&merged, DType::I8).unwrap();
    let (bp, bf) = packed.base_bytes();
    assert!((bp as f64) < bf as f64 / 3.5,
            "int8 base {bp} vs f32 {bf}: expected ~4x");

    // end-to-end greedy generation from the packed store: runs, and is
    // deterministic
    let rt: &dyn InferRuntime = &dense;
    let prompts = vec![ctx.clone(), ctx[..7].to_vec()];
    let cfg = GenConfig::greedy(8);
    let g1 = generate(rt, &packed, &prompts, &cfg).unwrap();
    let g2 = generate(rt, &packed, &prompts, &cfg).unwrap();
    assert_eq!(g1.sequences, g2.sequences);
    assert_eq!(g1.n_generated, vec![8, 8]);
}

// ---------------------------------------------------------------------
// Quantized KV cache through the policy (--kv-dtype).
// ---------------------------------------------------------------------

#[test]
fn kv_dtype_policy_serves_close_to_f32_and_generates() {
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 10).unwrap();
    let mut rng = Rng::new(23);
    let ctx: Vec<i32> =
        (0..24).map(|_| rng.below(man.config.vocab) as i32).collect();
    let f32_model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let mut c0 = f32_model.new_cache(1, ctx.len() + 1);
    let l_ref = f32_model.prefill(&store, &mut c0, 0, &ctx).unwrap();
    let max_abs = l_ref.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    // stated tolerances, same style as the frozen-base claim: bf16 K/V
    // carry ~2^-9 relative error, int8 ~0.4% of each row's max
    for (dtype, tol) in [(DType::Bf16, 0.05f32), (DType::I8, 0.15f32)] {
        let policy = PrecisionPolicy {
            kv_cache: dtype,
            ..PrecisionPolicy::default()
        };
        let model =
            NativeModel::with_policy(man.clone(), Variant::Lora, policy)
                .unwrap();
        let mut c = model.new_cache(1, ctx.len() + 1);
        let l_q = model.prefill(&store, &mut c, 0, &ctx).unwrap();
        let max_diff = l_ref
            .iter()
            .zip(&l_q)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        assert!(max_diff <= tol * (max_abs + 1.0),
                "{dtype:?} kv cache: max|Δlogit| {max_diff} vs \
                 tolerance {} (|logit|max {max_abs})",
                tol * (max_abs + 1.0));
        assert!(max_diff > 0.0, "{dtype:?} kv cache was a no-op");
        // end-to-end ragged-batch generation: runs, is deterministic
        let rt: &dyn InferRuntime = &model;
        let prompts = vec![ctx.clone(), ctx[..5].to_vec()];
        let cfg = GenConfig::greedy(6);
        let g1 = generate(rt, &store, &prompts, &cfg).unwrap();
        let g2 = generate(rt, &store, &prompts, &cfg).unwrap();
        assert_eq!(g1.sequences, g2.sequences);
        assert_eq!(g1.n_generated, vec![6, 6]);
    }
}
