//! Edge cases of the switch machinery (Algorithm 1/2).
//!
//! * `SwitchSchedule::switch_count` must never exceed the LoRA rank — the
//!   driver feeds it straight into `Rng::sample_distinct(rank, nb)`, which
//!   panics if asked for more than `rank` distinct indices.
//! * Switching the same vector index again while its counterpart's freeze
//!   window is still open must keep preserving the effective weight
//!   `W + s·BA` (the freeze windows overlap; the merges must still cancel
//!   exactly).

use std::sync::Arc;

use switchlora::model::layout::{Layout, LinearMeta, ParamMeta, ParamStore,
                                Role};
use switchlora::optim::adam::AdamState;
use switchlora::switchlora::candidates::{LinearCandidates, OffloadLedger};
use switchlora::switchlora::freeze::FreezeManager;
use switchlora::switchlora::schedule::SwitchSchedule;
use switchlora::switchlora::switcher::{switch_a, switch_b, LoraSpans,
                                       SwitchLora};
use switchlora::tensor::matmul::matmul;
use switchlora::tensor::Tensor;
use switchlora::util::prop::prop_check;
use switchlora::util::rng::Rng;

const M: usize = 10;
const N: usize = 6;
const R: usize = 3;

fn setup(seed: u64) -> (ParamStore, Vec<LinearMeta>, AdamState) {
    let layout = Layout::from_metas(vec![
        ParamMeta { name: "w".into(), shape: vec![M, N], role: Role::Base,
                    trainable: false, numel: M * N, offset: 0,
                    t_offset: None },
        ParamMeta { name: "w.a".into(), shape: vec![R, N],
                    role: Role::LoraA, trainable: true, numel: R * N,
                    offset: 0, t_offset: None },
        ParamMeta { name: "w.b".into(), shape: vec![M, R],
                    role: Role::LoraB, trainable: true, numel: M * R,
                    offset: 0, t_offset: None },
    ]);
    let mut store = ParamStore::zeros(Arc::new(layout));
    let mut rng = Rng::new(seed);
    for x in store.data.iter_mut() {
        *x = rng.normal_f32(0.0, 1.0);
    }
    let linears = vec![LinearMeta {
        name: "w".into(), a: "w.a".into(), b: "w.b".into(), m: M, n: N,
    }];
    let opt = AdamState::new(R * N + M * R, R * N + M * R);
    (store, linears, opt)
}

/// effective weight W + scale·B·A as a Tensor
fn effective(store: &ParamStore, scale: f32) -> Tensor {
    let w = store.tensor("w").unwrap();
    let a = store.tensor("w.a").unwrap();
    let b = store.tensor("w.b").unwrap();
    let mut ba = matmul(&b, &a);
    ba.scale(scale);
    let mut e = w.clone();
    e.axpy(1.0, &ba);
    e
}

#[test]
fn switch_count_never_exceeds_rank() {
    prop_check("switch_count <= rank for any schedule/step", 200, |rng| {
        // absurdly frequent schedules included: interval0 down to 0.001
        // pushes the expected count far past r, growing-frequency
        // (theta < 0) included too
        let interval0 = 10f64.powf(rng.uniform_range(-3.0, 2.0) as f64);
        let theta = rng.uniform_range(-0.05, 0.05) as f64;
        let sched = SwitchSchedule::new(interval0, theta);
        let rank = 1 + rng.below(64);
        let step = rng.below(10_000) as u64;
        let nb = sched.switch_count(step, rank, rng);
        if nb > rank {
            return Err(format!(
                "switch_count {nb} > rank {rank} \
                 (interval0={interval0}, theta={theta}, step={step})"));
        }
        // must also be a valid sample_distinct request
        let picked = rng.sample_distinct(rank, nb);
        if picked.len() != nb {
            return Err("sample_distinct returned wrong count".into());
        }
        Ok(())
    });
}

#[test]
fn apply_step_survives_saturating_schedule() {
    // Drive Algorithm 2 with an interval so small that the expected count
    // is ≫ rank every step: the clamp must hold and the effective weight
    // must still be preserved.
    let (mut store, linears, mut opt) = setup(21);
    let sched = SwitchSchedule::new(0.01, 0.0); // expected = 100·r
    let mut sl = SwitchLora::new(&linears, R, 1.0, sched, 3, 5);
    let before = effective(&store, 1.0);
    for step in 0..6 {
        sl.apply_step(step, &mut store, &mut opt, &linears);
    }
    let after = effective(&store, 1.0);
    assert!(before.max_abs_diff(&after) < 1e-3,
            "drift {}", before.max_abs_diff(&after));
    // fully saturated: exactly r switches per side per matrix per step
    assert_eq!(sl.total_switches, 6 * 2 * R as u64);
}

#[test]
fn double_switch_b_same_index_with_overlapping_freeze() {
    let (mut store, linears, mut opt) = setup(7);
    let li = &linears[0];
    let spans = LoraSpans::from_layout(&store, li, R);
    let mut rng = Rng::new(1);
    let mut cands = LinearCandidates::init(li, R, &mut rng);
    let mut ledger = OffloadLedger::default();
    let mut freeze = FreezeManager::new();
    // give the counterpart non-trivial optimizer state
    for x in opt.m.iter_mut() {
        *x = 1.0;
    }
    let before = effective(&store, 0.5);
    // first switch of B column 1 at step 0, freeze a_1 for steps < 6
    switch_b(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
             li, &spans, 1, 0, 0.5, 6);
    // second switch of the SAME column while the freeze window is open
    // (step 3, freeze until 9) — windows overlap
    switch_b(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
             li, &spans, 1, 2, 0.5, 9);
    let after = effective(&store, 0.5);
    assert!(before.max_abs_diff(&after) < 1e-4,
            "effective weight drifted by {}",
            before.max_abs_diff(&after));
    // counterpart state zeroed by both switches
    for i in spans.a_row(1).indices() {
        assert_eq!(opt.m[i], 0.0);
    }
    // overlapping windows: still frozen between the two expiries...
    let mut mask = vec![1.0f32; opt.len()];
    freeze.apply(7, &mut mask);
    for i in spans.a_row(1).indices() {
        assert_eq!(mask[i], 0.0, "freeze must extend to the later window");
    }
    // ...and released once the later window expires
    let mut mask = vec![1.0f32; opt.len()];
    freeze.apply(9, &mut mask);
    for i in spans.a_row(1).indices() {
        assert_eq!(mask[i], 1.0, "freeze must expire at the later window");
    }
}

#[test]
fn double_switch_a_same_index_with_overlapping_freeze() {
    let (mut store, linears, mut opt) = setup(8);
    let li = &linears[0];
    let spans = LoraSpans::from_layout(&store, li, R);
    let mut rng = Rng::new(2);
    let mut cands = LinearCandidates::init(li, R, &mut rng);
    let mut ledger = OffloadLedger::default();
    let mut freeze = FreezeManager::new();
    let before = effective(&store, 1.0);
    switch_a(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
             li, &spans, 0, 1, 1.0, 6);
    switch_a(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
             li, &spans, 0, 4, 1.0, 9);
    let after = effective(&store, 1.0);
    assert!(before.max_abs_diff(&after) < 1e-4,
            "effective weight drifted by {}",
            before.max_abs_diff(&after));
    let mut mask = vec![1.0f32; opt.len()];
    freeze.apply(7, &mut mask);
    for i in spans.b_col(0).indices() {
        assert_eq!(mask[i], 0.0);
    }
}

#[test]
fn switch_back_and_forth_returns_original_vector() {
    // Swapping with the same pool slot twice must return the original
    // column exactly (the pool conserves the vector population).
    let (mut store, linears, mut opt) = setup(9);
    let li = &linears[0];
    let spans = LoraSpans::from_layout(&store, li, R);
    let mut rng = Rng::new(3);
    let mut cands = LinearCandidates::init(li, R, &mut rng);
    let mut ledger = OffloadLedger::default();
    let mut freeze = FreezeManager::new();
    let b0 = store.tensor("w.b").unwrap();
    switch_b(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
             li, &spans, 2, 4, 1.0, 5);
    assert!(b0.max_abs_diff(&store.tensor("w.b").unwrap()) > 1e-4);
    switch_b(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
             li, &spans, 2, 4, 1.0, 5);
    let b2 = store.tensor("w.b").unwrap();
    assert!(b0.max_abs_diff(&b2) < 1e-6,
            "double swap with one slot must restore the column");
    assert_eq!(ledger.swaps, 2);
}
