//! Observability-layer tests.
//!
//! The load-bearing one is `traced_run_is_bitwise_identical`: the
//! telemetry subsystem's hard contract is that it never touches RNG
//! streams or math, so a traced run must reproduce an untraced run
//! bit for bit — losses, final weights, comm bytes.
//!
//! Tracing state is process-global and `cargo test` runs tests in this
//! binary on parallel threads, so every test that enables tracing
//! serializes on `TRACE_LOCK` (pure-helper tests don't need it).

use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard};

use switchlora::coordinator::trainer::{default_artifacts_dir, Method,
                                       TrainConfig, Trainer};
use switchlora::infer::{generate, GenConfig, KvCache};
use switchlora::methods::SwitchParams;
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::model::packed::PackedStore;
use switchlora::obs;
use switchlora::obs::report;
use switchlora::runtime::{load_infer, Engine};
use switchlora::tensor::dtype::DType;
use switchlora::util::json::Json;

static TRACE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("switchlora_obs_{name}"))
}

fn quick_cfg(steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::new(
        "tiny",
        Method::switchlora(SwitchParams {
            interval0: 4.0,
            ratio: 0.5,
            n_freeze: 2,
        }),
        steps,
    );
    cfg.workers = 2; // non-trivial ring ⇒ nonzero wire bytes
    cfg.eval_every = 3;
    cfg.eval_batches = 2;
    cfg.warmup = 2;
    cfg
}

fn run(cfg: TrainConfig)
    -> (switchlora::coordinator::trainer::RunResult, ParamStore) {
    let mut engine = Engine::cpu().unwrap();
    Trainer::new(cfg).unwrap().run(&mut engine).unwrap()
}

#[test]
fn traced_run_is_bitwise_identical() {
    let _g = lock();
    let (res_a, store_a) = run(quick_cfg(8));
    let trace = tmp("bitwise.jsonl");
    obs::enable(&trace, obs::TraceFormat::Jsonl).unwrap();
    let (res_b, store_b) = run(quick_cfg(8));
    obs::finish().unwrap();

    assert_eq!(res_a.train_curve, res_b.train_curve,
               "tracing changed the loss curve");
    assert_eq!(res_a.eval_curve, res_b.eval_curve);
    assert_eq!(res_a.comm.bytes, res_b.comm.bytes);
    assert_eq!(res_a.comm.rounds, res_b.comm.rounds);
    assert_eq!(res_a.counters, res_b.counters,
               "tracing changed switch/offload counters");
    let bits = |s: &ParamStore| -> Vec<u32> {
        s.data.iter().map(|x| x.to_bits()).collect()
    };
    assert_eq!(bits(&store_a), bits(&store_b),
               "tracing changed final weights");
    std::fs::remove_file(&trace).ok();
}

#[test]
fn trace_covers_phases_and_audits_switches() {
    let _g = lock();
    let trace = tmp("full.jsonl");
    let ckpt = tmp("full.ckpt");
    let mut cfg = quick_cfg(8);
    cfg.ckpt_every = 4;
    cfg.ckpt_path = Some(ckpt.clone());
    obs::enable(&trace, obs::TraceFormat::Jsonl).unwrap();
    let (res, _) = run(cfg);
    obs::finish().unwrap();

    let rep = report::summarize(&trace).unwrap();
    // all eight trainer phases fired and aggregated
    for ph in report::PHASES {
        let agg = rep.spans
                     .get(ph)
                     .unwrap_or_else(|| panic!("phase {ph:?} missing"));
        assert!(agg.count > 0, "phase {ph:?} has no spans");
        assert_eq!(agg.cat, "phase");
    }
    // the switch audit trail matches the method's own counters
    assert!(res.counter("switches") > 0, "run never switched");
    assert_eq!(rep.switches, res.counter("switches"),
               "audit events disagree with RunResult switch counter");
    assert!(!rep.switch_by_layer.is_empty());
    // comm reconciliation: per-round events sum to the ledger, and the
    // run summary restates the same total
    assert_eq!(rep.comm_round_bytes, res.comm.bytes);
    assert_eq!(rep.comm_rounds, res.comm.rounds);
    assert_eq!(rep.summary_comm_bytes, Some(res.comm.bytes));
    assert_eq!(rep.summary_comm_rounds, Some(res.comm.rounds));
    assert_eq!(rep.summary_steps, Some(8));
    // training memory ledger present with the expected decomposition
    let (rows, total) = rep.memory
                           .get("train")
                           .expect("train memory ledger missing");
    assert_eq!(rows.iter().map(|r| r.bytes).sum::<u64>(), *total);
    for comp in ["master", "adapter", "optimizer_moments",
                 "candidate_pool"] {
        assert!(rows.iter().any(|r| r.component == comp),
                "memory ledger missing {comp:?}");
    }
    // render is total-consistent and mentions the cross-check
    let text = rep.render();
    assert!(text.contains("per-phase step profile"), "{text}");
    assert!(text.contains("match"), "{text}");
    assert!(!text.contains("MISMATCH"), "{text}");

    std::fs::remove_file(&trace).ok();
    std::fs::remove_file(&ckpt).ok();
}

#[test]
fn jsonl_events_parse_and_roundtrip() {
    let _g = lock();
    let trace = tmp("schema.jsonl");
    obs::enable(&trace, obs::TraceFormat::Jsonl).unwrap();
    let sp = obs::span("test", "unit");
    std::thread::sleep(std::time::Duration::from_millis(2));
    assert!(sp.done() >= 0.001);
    obs::event("custom", vec![
        ("x", Json::num(3.0)),
        ("s", Json::str("quote\"and\\slash")),
    ]);
    obs::hist_record("lat_us", 42.0);
    obs::add("widgets", 7);
    obs::gauge("level", 0.5);
    obs::finish().unwrap();

    let text = std::fs::read_to_string(&trace).unwrap();
    let mut kinds = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("every line is one JSON object");
        // schema round-trip: parse(serialize(x)) == x
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert!(j.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(j.get("tid").unwrap().as_f64().unwrap() >= 1.0);
        kinds.push(j.get("kind").unwrap().as_str().unwrap().to_string());
    }
    for k in ["span", "custom", "counters", "gauges", "hist"] {
        assert!(kinds.iter().any(|x| x == k), "missing kind {k:?}");
    }
    std::fs::remove_file(&trace).ok();
}

#[test]
fn chrome_trace_is_a_loadable_event_array() {
    let _g = lock();
    let trace = tmp("chrome.json");
    obs::enable(&trace, obs::TraceFormat::Chrome).unwrap();
    obs::span("phase", "data").done();
    obs::event("switch", vec![("step", Json::num(1.0))]);
    obs::finish().unwrap();

    let j = Json::parse(&std::fs::read_to_string(&trace).unwrap())
        .expect("chrome trace must be one valid JSON document");
    let arr = j.as_arr().unwrap();
    assert!(arr.len() >= 3, "span + instant + counters dump expected");
    for e in arr {
        e.get("name").unwrap().as_str().unwrap();
        e.get("ph").unwrap().as_str().unwrap();
        e.get("ts").unwrap().as_f64().unwrap();
        e.get("pid").unwrap().as_f64().unwrap();
        e.get("tid").unwrap().as_f64().unwrap();
    }
    let span = arr.iter()
                  .find(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
                  .expect("no duration event");
    assert_eq!(span.get("name").unwrap().as_str().unwrap(), "data");
    assert_eq!(span.get("cat").unwrap().as_str().unwrap(), "phase");
    span.get("dur").unwrap().as_f64().unwrap();
    let inst = arr.iter()
                  .find(|e| {
                      e.get("name").unwrap().as_str().unwrap() == "switch"
                  })
                  .expect("no instant event");
    assert_eq!(inst.get("ph").unwrap().as_str().unwrap(), "i");
    inst.get("args").unwrap().get("step").unwrap().as_f64().unwrap();
    // report refuses chrome traces with a pointer, not a parse error
    let err = report::summarize(&trace).unwrap_err().to_string();
    assert!(err.contains("Perfetto"), "{err}");
    std::fs::remove_file(&trace).ok();
}

#[test]
fn memory_ledger_matches_resident_bytes_exactly() {
    // pure helpers — no tracing, no lock
    let man =
        Manifest::for_spec(&default_artifacts_dir(), "tiny").unwrap();
    let store = seeded_store(&man, Variant::Lora, 1).unwrap();
    let p = PackedStore::quantize_base(&store, DType::I8).unwrap();
    let rows = obs::packed_mem_rows(&p, DType::I8);
    assert_eq!(obs::mem_total(&rows) as usize, p.resident_bytes(),
               "serve ledger total must equal PackedStore residency");
    let fb = rows.iter().find(|r| r.component == "frozen_base").unwrap();
    assert_eq!(fb.bytes as usize, p.base_bytes().0);
    assert_eq!(fb.dtype, DType::I8);

    let cache = KvCache::with_dtype(2, 3, 4, 8, 16, DType::I8);
    let row = obs::kv_mem_row(&cache);
    assert_eq!(row.bytes as usize, cache.bytes(),
               "kv ledger row must equal KvCache residency");
    assert_eq!(row.dtype, DType::I8);
}

#[test]
fn traced_generation_records_decode_spans_and_kv() {
    let _g = lock();
    let man =
        Manifest::for_spec(&default_artifacts_dir(), "tiny").unwrap();
    let store = seeded_store(&man, Variant::Lora, 7).unwrap();
    let engine = Engine::cpu().unwrap();
    let rt = load_infer(&engine, man.clone(), Variant::Lora).unwrap();

    let trace = tmp("gen.jsonl");
    obs::enable(&trace, obs::TraceFormat::Jsonl).unwrap();
    let gen = generate(rt.as_ref(), &store,
                       &[vec![1, 2, 3], vec![4, 5]],
                       &GenConfig::greedy(6))
        .unwrap();
    obs::finish().unwrap();
    assert!(gen.decode_steps > 0);

    let text = std::fs::read_to_string(&trace).unwrap();
    let (mut prefill, mut decode, mut kv) = (0u64, 0u64, 0u64);
    let mut hist_count = 0u64;
    for line in text.lines() {
        let j = Json::parse(line).unwrap();
        match j.get("kind").unwrap().as_str().unwrap() {
            "span" => {
                let name = j.get("name").unwrap().as_str().unwrap();
                let cat = j.get("cat").unwrap().as_str().unwrap();
                if cat == "infer" && name == "prefill" {
                    prefill += 1;
                }
                if cat == "infer" && name == "decode" {
                    decode += 1;
                }
            }
            "kv" => {
                kv += 1;
                let used = j.get("used").unwrap().as_f64().unwrap();
                let cap = j.get("capacity").unwrap().as_f64().unwrap();
                assert!(used > 0.0 && used <= cap, "{used} vs {cap}");
                assert!(j.get("bytes").unwrap().as_f64().unwrap() > 0.0);
            }
            "hist" => {
                if j.get("name").unwrap().as_str().unwrap()
                    == "decode.token_us"
                {
                    hist_count =
                        j.get("count").unwrap().as_f64().unwrap() as u64;
                }
            }
            _ => {}
        }
    }
    assert_eq!(prefill, 2, "one prefill span per prompt");
    assert_eq!(decode as usize, gen.decode_steps);
    assert_eq!(kv as usize, gen.decode_steps,
               "one kv occupancy event per decode step");
    assert_eq!(hist_count as usize, gen.decode_steps,
               "decode latency histogram records once per decode");
    std::fs::remove_file(&trace).ok();
}
