//! Integration tests over the execution runtime: the engine contract.
//!
//! These run on the native backend with the builtin `tiny` manifest, so
//! they exercise the real fwd/bwd/adam step interfaces on any machine —
//! no Python, XLA or AOT artifacts needed.  (With `--features pjrt` and
//! artifacts built, the same contract holds for the PJRT backend.)

use std::sync::Arc;

use switchlora::data::dataset::synth_batches;
use switchlora::model::init::{init_store, InitMode};
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::optim::adam::{host_step, AdamState};
use switchlora::optim::AdamHyper;
use switchlora::runtime::{Engine, ModelRuntime};
use switchlora::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::builtin("tiny").unwrap()
}

fn init(man: &Manifest, variant: Variant, seed: u64) -> ParamStore {
    let layout = Arc::new(man.layout(variant).unwrap().clone());
    let mut store = ParamStore::zeros(layout);
    let mut rng = Rng::new(seed);
    init_store(&mut store, &man.linears, man.config.rank,
               InitMode::SwitchLora, &mut rng);
    store
}

#[test]
fn fwdbwd_loss_near_uniform_and_grads_shaped() {
    let man = manifest();
    let mut engine = Engine::native();
    let store = init(&man, Variant::Lora, 0);
    let rt = ModelRuntime::load(&mut engine, man.clone(), Variant::Lora)
        .unwrap();
    let mc = &man.config;
    let mut it = synth_batches(mc.vocab, 1, 0, mc.batch, mc.seq);
    let b = it.next_batch();
    let (loss, grads) = rt.fwdbwd(&store, &b.tokens, b.batch, b.seq_plus_1)
        .unwrap();
    // random init ⇒ loss ≈ ln(vocab)
    assert!((loss - (mc.vocab as f32).ln()).abs() < 0.6, "loss {loss}");
    assert_eq!(grads.len(), rt.padded);
    // gradients are non-trivial on live lanes, zero on padding
    let live = &grads[..man.lora.n_trainable];
    assert!(live.iter().any(|&g| g.abs() > 1e-6));
    assert!(grads[man.lora.n_trainable..].iter().all(|&g| g == 0.0));
    assert!(live.iter().all(|g| g.is_finite()));
    assert_eq!(rt.n_execs.get(), 1);
}

#[test]
fn eval_matches_between_variants_when_adapters_zero() {
    // With B=0 adapters, the lora model computes the same function as the
    // full model with identical base weights — a cross-check of the two
    // native code paths against each other.
    let man = manifest();
    let mut engine = Engine::native();
    let mut lora_store = init(&man, Variant::Lora, 3);
    for li in &man.linears {
        lora_store.slice_mut(&li.b).unwrap().fill(0.0);
    }
    let mut full_store = ParamStore::zeros(Arc::new(man.full.clone()));
    switchlora::model::init::copy_shared(&lora_store, &mut full_store);
    let rt_l = ModelRuntime::load(&mut engine, man.clone(), Variant::Lora)
        .unwrap();
    let rt_f = ModelRuntime::load(&mut engine, man.clone(), Variant::Full)
        .unwrap();
    let mc = &man.config;
    let mut it = synth_batches(mc.vocab, 2, 0, mc.batch, mc.seq);
    let b = it.next_batch();
    let ll = rt_l.eval_loss(&lora_store, &b.tokens, b.batch, b.seq_plus_1)
        .unwrap();
    let lf = rt_f.eval_loss(&full_store, &b.tokens, b.batch, b.seq_plus_1)
        .unwrap();
    assert!((ll - lf).abs() < 1e-4, "lora {ll} vs full {lf}");
}

#[test]
fn backend_adam_matches_host_adam() {
    // Differential test of the engine's adam_step against the host
    // reference, including masked and freshly-reset lanes.  (Trivial for
    // the native backend, a real kernel diff under `--features pjrt` —
    // either way it pins the contract the trainer relies on.)
    let man = manifest();
    let mut engine = Engine::native();
    let rt = ModelRuntime::load(&mut engine, man.clone(), Variant::Lora)
        .unwrap();
    let n = rt.padded;
    let mut rng = Rng::new(9);
    let mut p_h: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0))
        .collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut st_h = AdamState::new(n, n);
    // non-trivial state: random moments, mixed steps, mixed mask,
    // including the reset+frozen (s=0, mask=0) corner
    let mut mask = vec![1.0f32; n];
    for i in 0..n {
        st_h.m[i] = rng.normal_f32(0.0, 0.1);
        st_h.v[i] = rng.uniform_range(0.0, 0.01);
        st_h.s[i] = (rng.below(10)) as f32;
        if rng.bernoulli(0.3) {
            mask[i] = 0.0;
        }
        if rng.bernoulli(0.1) {
            st_h.m[i] = 0.0;
            st_h.v[i] = 0.0;
            st_h.s[i] = 0.0;
            mask[i] = 0.0;
        }
    }
    let mut p_k = p_h.clone();
    let mut st_k = st_h.clone();
    let hyper = AdamHyper { weight_decay: 0.1, ..AdamHyper::new(2e-2) };
    rt.adam_step(&mut p_k, &g, &mut st_k, &mask, &hyper).unwrap();
    host_step(&mut p_h, &g, &mut st_h, &mask, &hyper);
    let close = |a: &[f32], b: &[f32], what: &str| {
        for i in 0..n {
            assert!(a[i].is_finite() && b[i].is_finite(),
                    "{what}[{i}] not finite: {} vs {}", a[i], b[i]);
            let tol = 1e-5 + 1e-4 * b[i].abs();
            assert!((a[i] - b[i]).abs() < tol,
                    "{what}[{i}]: kernel {} vs host {}", a[i], b[i]);
        }
    };
    close(&p_k, &p_h, "p");
    close(&st_k.m, &st_h.m, "m");
    close(&st_k.v, &st_h.v, "v");
    close(&st_k.s, &st_h.s, "s");
}

#[test]
fn cls_eval_counts_correct() {
    let man = manifest();
    let mut engine = Engine::native();
    let store = init(&man, Variant::Cls, 5);
    let rt = ModelRuntime::load(&mut engine, man.clone(), Variant::Cls)
        .unwrap();
    let mc = &man.config;
    let mut gen = switchlora::data::tasks::TaskGen::new(
        switchlora::data::tasks::Task::Majority, mc.vocab, mc.seq, 7);
    let (toks, labels) = gen.batch(mc.batch);
    let (loss, correct) =
        rt.cls_eval(&store, &toks, &labels, mc.batch, mc.seq).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=mc.batch as f32).contains(&correct));
    // random head ⇒ loss near ln(n_cls)
    assert!((loss - (mc.n_cls as f32).ln()).abs() < 1.0, "loss {loss}");
}

#[test]
fn cls_step_requires_cls_variant() {
    let man = manifest();
    let mut engine = Engine::native();
    let store = init(&man, Variant::Lora, 6);
    let rt = ModelRuntime::load(&mut engine, man.clone(), Variant::Lora)
        .unwrap();
    let toks = vec![0i32; man.config.seq];
    assert!(rt.cls_eval(&store, &toks, &[0], 1, man.config.seq).is_err());
    assert!(rt.cls_fwdbwd(&store, &toks, &[0], 1, man.config.seq)
        .is_err());
}

#[test]
fn grad_descent_through_runtime_decreases_loss() {
    let man = manifest();
    let mut engine = Engine::native();
    let mut store = init(&man, Variant::Lora, 11);
    let rt = ModelRuntime::load(&mut engine, man.clone(), Variant::Lora)
        .unwrap();
    let mc = &man.config;
    let mut it = synth_batches(mc.vocab, 4, 0, mc.batch, mc.seq);
    let b = it.next_batch();
    let (loss0, _) =
        rt.fwdbwd(&store, &b.tokens, b.batch, b.seq_plus_1).unwrap();
    let n = rt.padded;
    let mut opt = AdamState::new(man.lora.n_trainable, n);
    let mut mask = vec![0.0f32; n];
    for x in mask.iter_mut().take(man.lora.n_trainable) {
        *x = 1.0;
    }
    let hyper = AdamHyper::new(1e-2);
    // five Adam steps on the same batch must overfit it
    let mut last = loss0;
    for _ in 0..5 {
        let (loss, g) =
            rt.fwdbwd(&store, &b.tokens, b.batch, b.seq_plus_1).unwrap();
        last = loss;
        let mut flat = store.gather_trainable(n);
        rt.adam_step(&mut flat, &g, &mut opt, &mask, &hyper).unwrap();
        store.scatter_trainable(&flat);
    }
    assert!(last < loss0 - 0.1, "loss {loss0} -> {last}");
}
