//! Integration tests of the serving subsystem (ISSUE 8 acceptance):
//!
//! * the unmerged per-sequence adapter overlay is BITWISE identical to
//!   decoding from the LoRA-variant store it was extracted from;
//! * a mixed-adapter continuous batch (two tenants + the bare base)
//!   reproduces, per sequence, exactly the tokens of a solo run with
//!   that adapter merged into the dense weights;
//! * a reclaimed KV-cache slot decodes bitwise identically to a fresh
//!   cache (free-slot list, satellite of the continuous batcher);
//! * the serve memory ledger's total equals `resident_bytes()` exactly,
//!   and adding a tenant leaves every frozen-base row byte-identical —
//!   the zero-base-duplication claim;
//! * the scheduler serves queued requests token-identically to solo
//!   `generate_adapted` runs (same seed convention), through mid-flight
//!   admission and slot reuse;
//! * the HTTP server streams those tokens over chunked NDJSON and
//!   drains cleanly on `POST /admin/drain`.
//!
//! ISSUE 9 (paged KV + chunked prefill + keep-alive) additions:
//!
//! * a paged cache decodes bitwise identically to a one-block-per-slot
//!   (contiguous-equivalent) cache through the full model forward, for
//!   all three KV dtypes;
//! * chunked prefill streams exactly the tokens of monolithic prefill;
//! * slot churn through real decodes returns every block to the pool
//!   and reuses them instead of growing it;
//! * one TCP connection serves several requests back to back
//!   (keep-alive) and still honors `Connection: close`;
//! * the serve memory ledger's KV row tracks the paged pool exactly
//!   (`blocks_allocated × block_bytes`).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::thread;
use std::time::Instant;

use switchlora::infer::kv_cache::KvCache;
use switchlora::infer::{argmax, generate_adapted, merged_full_store,
                        AdapterSet, GenConfig, Sampler};
use switchlora::model::init::{copy_shared, seeded_store};
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::model::packed::PackedStore;
use switchlora::obs::{mem_total, serve_mem_rows, MemRow};
use switchlora::runtime::{InferRuntime, NativeModel};
use switchlora::serve::http::decode_chunked;
use switchlora::serve::{AdapterRegistry, BaseSource, Queue,
                        SamplingSpec, Scheduler, ServeConfig,
                        ServeRequest, ServeStats, Server, TokenEvent};
use switchlora::tensor::dtype::DType;
use switchlora::util::json::Json;
use switchlora::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::builtin("tiny").unwrap()
}

fn rand_prompt(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

/// The serving base: a Full-variant store holding exactly the dense
/// weights of `lora_store` (embeddings, norms, frozen `W`s, head) and
/// no adapters.
fn base_from(man: &Manifest, lora_store: &ParamStore) -> ParamStore {
    let layout = std::sync::Arc::new(
        man.layout(Variant::Full).unwrap().clone());
    let mut full = ParamStore::zeros(layout);
    let copied = copy_shared(lora_store, &mut full);
    assert!(copied > 0, "no shared tensors copied");
    full
}

/// `target`'s adapters replaced by `donor`'s — a LoRA store that decodes
/// "donor's task over target's base".
fn with_adapters_of(man: &Manifest, target: &ParamStore,
                    donor: &ParamStore) -> ParamStore {
    let mut out = target.clone();
    for li in &man.linears {
        let a = donor.slice(&li.a).unwrap().to_vec();
        let b = donor.slice(&li.b).unwrap().to_vec();
        out.slice_mut(&li.a).unwrap().copy_from_slice(&a);
        out.slice_mut(&li.b).unwrap().copy_from_slice(&b);
    }
    out
}

#[test]
fn adapter_overlay_is_bitwise_the_lora_store_forward() {
    // overlay over the (byte-identical) dense base == decoding from the
    // LoRA-variant store, bit for bit — the parity the serving path is
    // built on
    let man = manifest();
    let lora_store = seeded_store(&man, Variant::Lora, 21).unwrap();
    let base = base_from(&man, &lora_store);
    let ad = AdapterSet::from_store(&man, &lora_store, "t").unwrap();
    let lora_rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let full_rt = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let prompt = rand_prompt(man.config.vocab, 6, 3);
    let mut c1 = lora_rt.new_cache(1, 16);
    let mut c2 = full_rt.new_cache(1, 16);
    let mut y1 =
        lora_rt.prefill(&lora_store, &mut c1, 0, &prompt).unwrap();
    let mut y2 = full_rt
        .prefill_adapted(&base, Some(&ad), &mut c2, 0, &prompt)
        .unwrap();
    for step in 0..8 {
        let bits = |v: &[f32]| -> Vec<u32> {
            v.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(bits(&y1), bits(&y2),
                   "overlay logits diverge at step {step}");
        let tok = argmax(&y1) as i32;
        y1 = lora_rt
            .decode(&lora_store, &mut c1, &[0], &[tok])
            .unwrap();
        y2 = full_rt
            .decode_adapted(&base, &[Some(&ad)], &mut c2, &[0], &[tok])
            .unwrap();
    }
}

#[test]
fn mixed_adapter_batch_matches_merged_solo_decodes() {
    let man = manifest();
    let vocab = man.config.vocab;
    let lora1 = seeded_store(&man, Variant::Lora, 21).unwrap();
    let lora2 = seeded_store(&man, Variant::Lora, 22).unwrap();
    let base = base_from(&man, &lora1);
    let ad1 = AdapterSet::from_store(&man, &lora1, "a").unwrap();
    let ad2 = AdapterSet::from_store(&man, &lora2, "b").unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let prompts = vec![
        rand_prompt(vocab, 3, 31),
        rand_prompt(vocab, 7, 32),
        rand_prompt(vocab, 5, 33),
    ];
    // greedy: rng-free, so token equality is exact equality of argmax
    // chains
    let cfg = GenConfig::greedy(9);
    let ads: Vec<Option<&AdapterSet>> =
        vec![Some(&ad1), None, Some(&ad2)];
    let batch =
        generate_adapted(&rt, &base, &ads, &prompts, &cfg).unwrap();

    // (1) bitwise claim: each sequence solo, same unmerged code path
    for (s, p) in prompts.iter().enumerate() {
        let solo = generate_adapted(&rt, &base, &[ads[s]],
                                    &[p.clone()], &cfg)
            .unwrap();
        assert_eq!(batch.sequences[s], solo.sequences[0],
                   "seq {s}: batch composition changed its tokens");
    }

    // (2) cross-implementation claim: solo decode with the adapter
    // MERGED into the dense weights (a different float evaluation
    // order) picks the same greedy tokens
    let merged1 = merged_full_store(&man, &lora1).unwrap();
    let merged2 = merged_full_store(
        &man, &with_adapters_of(&man, &lora1, &lora2)).unwrap();
    for (s, reference) in
        [(0usize, Some(&merged1)), (1, None), (2, Some(&merged2))]
    {
        let store = reference.unwrap_or(&base);
        let solo = generate_adapted(&rt, store, &[None],
                                    &[prompts[s].clone()], &cfg)
            .unwrap();
        assert_eq!(batch.sequences[s], solo.sequences[0],
                   "seq {s}: unmerged overlay disagrees with merged \
                    solo decode");
    }
}

#[test]
fn reclaimed_kv_slot_decodes_bitwise_like_fresh() {
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 9).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let vocab = man.config.vocab;
    let warm = rand_prompt(vocab, 8, 41);
    let probe = rand_prompt(vocab, 5, 42);
    let run = |cache: &mut KvCache, slot: usize|
        -> (Vec<i32>, Vec<u32>) {
        let mut toks = Vec::new();
        let mut bits = Vec::new();
        let mut y =
            rt.prefill(&store, cache, slot, &probe).unwrap();
        for _ in 0..6 {
            bits.extend(y.iter().map(|x| x.to_bits()));
            let t = argmax(&y) as i32;
            toks.push(t);
            y = rt.decode(&store, cache, &[slot], &[t]).unwrap();
        }
        (toks, bits)
    };
    // dirty a slot, retire it, reuse it
    let mut used = rt.new_cache(2, 32);
    let s0 = used.acquire().unwrap();
    rt.prefill(&store, &mut used, s0, &warm).unwrap();
    rt.decode(&store, &mut used, &[s0], &[warm[0]]).unwrap();
    used.release(s0);
    let s1 = used.acquire().unwrap();
    assert_eq!(s1, s0, "freed slot must be reused");
    let (toks_reused, bits_reused) = run(&mut used, s1);
    // reference: the same prompt in a never-touched cache
    let mut fresh = rt.new_cache(2, 32);
    let f = fresh.acquire().unwrap();
    let (toks_fresh, bits_fresh) = run(&mut fresh, f);
    assert_eq!(toks_reused, toks_fresh);
    assert_eq!(bits_reused, bits_fresh,
               "stale KV rows leaked into a reclaimed slot");
}

#[test]
fn serve_ledger_total_is_exact_and_base_rows_never_grow() {
    let man = manifest();
    let full = seeded_store(&man, Variant::Full, 5).unwrap();
    let packed = PackedStore::quantize_base(&full, DType::I8).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let cache = rt.new_cache(4, 64);
    let mk_ad = |seed: u64, name: &str| -> (String, u64) {
        let store = seeded_store(&man, Variant::Lora, seed).unwrap();
        let ad = AdapterSet::from_store(&man, &store, name).unwrap();
        (name.to_string(), ad.resident_bytes() as u64)
    };
    let two = vec![mk_ad(21, "a"), mk_ad(22, "b")];
    let three =
        vec![mk_ad(21, "a"), mk_ad(22, "b"), mk_ad(23, "c")];
    let rows2 = serve_mem_rows(&packed, DType::I8, &two, &cache);
    let rows3 = serve_mem_rows(&packed, DType::I8, &three, &cache);
    // the ledger accounts every resident byte exactly, no estimates
    let expect = |ads: &[(String, u64)]| -> u64 {
        packed.resident_bytes() as u64
            + ads.iter().map(|(_, b)| b).sum::<u64>()
            + cache.bytes() as u64
    };
    assert_eq!(mem_total(&rows2), expect(&two));
    assert_eq!(mem_total(&rows3), expect(&three));
    // one frozen-base copy no matter how many tenants: the non-adapter
    // rows are byte-identical across registry sizes
    let base_rows = |rows: &[MemRow]| -> Vec<(String, String, u64)> {
        rows.iter()
            .filter(|r| !r.component.starts_with("adapter:"))
            .map(|r| (r.component.clone(), r.dtype.name().to_string(),
                      r.bytes))
            .collect()
    };
    assert_eq!(base_rows(&rows2), base_rows(&rows3));
    assert_eq!(rows3.len(), rows2.len() + 1,
               "a new tenant must add exactly one ledger row");

    // the KV row tracks the paged pool exactly: zero before any token,
    // then blocks_allocated × block_bytes — never the dense-slab size
    assert_eq!(cache.bytes(), 0, "paged cache pre-reserved memory");
    let mut grown = rt.new_cache_blocked(4, 64, 8);
    let row = vec![0.5f32;
                   man.config.heads * man.config.head_dim() * 11];
    grown.append(0, 0, &row, &row, 11);
    assert_eq!(grown.bytes(),
               grown.blocks_allocated() * grown.block_bytes());
    let rows = serve_mem_rows(&packed, DType::I8, &two, &grown);
    let kv = rows.iter().find(|r| r.component == "kv_cache").unwrap();
    assert_eq!(kv.bytes, grown.bytes() as u64);
    assert!(kv.bytes < grown.slab_bytes() as u64,
            "pool should be smaller than the retired dense slab");
}

#[test]
fn scheduler_serves_queued_requests_identically_to_solo_runs() {
    let man = manifest();
    let vocab = man.config.vocab;
    let lora1 = seeded_store(&man, Variant::Lora, 21).unwrap();
    let lora2 = seeded_store(&man, Variant::Lora, 22).unwrap();
    let base = base_from(&man, &lora1);
    let mut adapters = BTreeMap::new();
    adapters.insert("a".to_string(),
                    AdapterSet::from_store(&man, &lora1, "a").unwrap());
    adapters.insert("b".to_string(),
                    AdapterSet::from_store(&man, &lora2, "b").unwrap());
    let rt = NativeModel::new(man.clone(), Variant::Full).unwrap();
    // batch of 2 slots for 3 requests: the third joins mid-flight in a
    // reclaimed slot
    let cache = rt.new_cache(2, 64);
    let queue = Queue::new(8);
    let stats = ServeStats::default();
    let reqs: Vec<(Option<&str>, Vec<i32>, u64, usize)> = vec![
        (Some("a"), rand_prompt(vocab, 3, 51), 5, 4),
        (None, rand_prompt(vocab, 6, 52), 6, 8),
        (Some("b"), rand_prompt(vocab, 4, 53), 7, 6),
    ];
    let sampler = Sampler::top_k(8, 0.9);
    let mut rxs = Vec::new();
    for (i, (name, prompt, seed, max_new)) in reqs.iter().enumerate() {
        let (tx, rx) = channel();
        queue.push(ServeRequest {
            id: i as u64,
            adapter: name.map(str::to_string),
            prompt: prompt.clone(),
            spec: SamplingSpec {
                sampler,
                seed: *seed,
                max_new: *max_new,
                stop_tokens: Vec::new(),
            },
            tx,
            enqueued: Instant::now(),
        });
        rxs.push(rx);
    }
    // pre-filled queue + drain: the scheduler serves everything already
    // queued, then exits
    queue.begin_drain();
    Scheduler::new(&rt, &base, &adapters, cache).run(&queue, &stats);
    for (i, ((name, prompt, seed, max_new), rx)) in
        reqs.iter().zip(&rxs).enumerate()
    {
        let mut toks = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.try_recv() {
            match ev {
                TokenEvent::Token(t) => toks.push(t),
                TokenEvent::Done { finish, n_generated } => {
                    done = Some((finish, n_generated));
                }
                TokenEvent::Error(e) => panic!("request {i}: {e}"),
            }
        }
        let (finish, n_generated) =
            done.unwrap_or_else(|| panic!("request {i} never finished"));
        assert_eq!(n_generated, *max_new);
        assert_eq!(finish.as_str(), "length");
        // the request's stream is exactly a solo generate_adapted run
        // with the same seed (both use the seed's fork(0) stream)
        let cfg = GenConfig {
            max_new: *max_new,
            sampler,
            stop_tokens: Vec::new(),
            seed: *seed,
            max_context: None,
        };
        let ad = name.map(|n| &adapters[n]);
        let solo = generate_adapted(&rt, &base, &[ad],
                                    &[prompt.clone()], &cfg)
            .unwrap();
        assert_eq!(toks, solo.sequences[0][prompt.len()..].to_vec(),
                   "request {i}: served tokens diverge from solo run");
    }
    use std::sync::atomic::Ordering;
    assert_eq!(stats.completed.load(Ordering::Relaxed), 3);
    assert_eq!(stats.tokens_streamed.load(Ordering::Relaxed),
               (4 + 8 + 6) as u64);
    let counts = stats.adapter_counts();
    assert_eq!(counts.get("a"), Some(&1));
    assert_eq!(counts.get("b"), Some(&1));
    assert_eq!(counts.get("base"), Some(&1));
}

/// One blocking HTTP exchange against `addr`; returns (status, head,
/// raw body bytes).  Sends `Connection: close` so the (keep-alive by
/// default) server closes after the response and EOF delimits it.
fn http_roundtrip(addr: &str, request: &str) -> (u16, String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    split_response(&buf)
}

fn split_response(buf: &[u8]) -> (u16, String, Vec<u8>) {
    let head_end = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response without header terminator")
        + 4;
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .unwrap();
    (status, head, buf[head_end..].to_vec())
}

fn get(addr: &str, path: &str) -> (u16, String, Vec<u8>) {
    http_roundtrip(addr, &format!(
        "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n"))
}

fn post(addr: &str, path: &str, body: &str) -> (u16, String, Vec<u8>) {
    http_roundtrip(addr, &format!(
        "POST {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Type: application/json\r\nContent-Length: \
         {}\r\n\r\n{body}",
        body.len()))
}

#[test]
fn http_server_streams_tokens_and_drains_cleanly() {
    let man = manifest();
    let vocab = man.config.vocab;
    let lora1 = seeded_store(&man, Variant::Lora, 21).unwrap();
    let base_store = base_from(&man, &lora1);
    let mut registry = AdapterRegistry::new();
    registry.load_spec(&man, "a=seed:21").unwrap();
    registry.load_spec(&man, "b=seed:22").unwrap();
    let rt: Box<dyn InferRuntime> =
        Box::new(NativeModel::new(man.clone(), Variant::Full).unwrap());
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0, // kernel-assigned; local_addr() resolves it
        max_batch: 2,
        queue_depth: 4,
        max_context: 64,
        default_max_new: 8,
        ..ServeConfig::default()
    };
    let server = Server::bind(cfg, rt,
                              BaseSource::Master(base_store.clone()),
                              registry, vocab)
        .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = thread::spawn(move || server.run());

    // liveness + adapter listing
    let (status, _, body) = get(&addr, "/healthz");
    assert_eq!(status, 200);
    let health =
        Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(health.get("ok").unwrap().as_bool().unwrap());
    assert!(health.opt("queued_by_tenant").is_some());
    let (status, _, body) = get(&addr, "/v1/adapters");
    assert_eq!(status, 200);
    let ads = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(ads.as_arr().unwrap().len(), 2);

    // a streamed generation: NDJSON token lines over chunked encoding
    let (status, head, body) = post(
        &addr, "/v1/generate",
        r#"{"tokens":[1,2,3],"adapter":"a","max_new":5,"seed":9}"#);
    assert_eq!(status, 200, "head: {head}");
    assert!(head.contains("Transfer-Encoding: chunked"));
    let nd = decode_chunked(&body).unwrap();
    let nd = String::from_utf8(nd).unwrap();
    let lines: Vec<&str> =
        nd.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 6, "5 token lines + 1 done line: {nd}");
    let mut toks = Vec::new();
    for l in &lines[..5] {
        let j = Json::parse(l).unwrap();
        toks.push(j.get("token").unwrap().as_usize().unwrap() as i32);
    }
    let done = Json::parse(lines[5]).unwrap();
    assert!(done.get("done").unwrap().as_bool().unwrap());
    assert_eq!(done.get("finish").unwrap().as_str().unwrap(), "length");
    assert_eq!(done.get("n_generated").unwrap().as_usize().unwrap(), 5);

    // the stream equals a solo in-process run with the same seed
    let rt2 = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let ad = AdapterSet::from_store(&man, &lora1, "a").unwrap();
    let cfg = GenConfig {
        max_new: 5,
        sampler: Sampler::greedy(),
        stop_tokens: Vec::new(),
        seed: 9,
        max_context: None,
    };
    let solo = generate_adapted(&rt2, &base_store, &[Some(&ad)],
                                &[vec![1, 2, 3]], &cfg)
        .unwrap();
    assert_eq!(toks, solo.sequences[0][3..].to_vec());

    // validation surfaces as 400, not a dead socket
    let (status, _, _) =
        post(&addr, "/v1/generate", r#"{"adapter":"nope"}"#);
    assert_eq!(status, 400);

    // graceful drain: the run() thread exits cleanly
    let (status, _, body) = post(&addr, "/admin/drain", "");
    assert_eq!(status, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("draining").unwrap().as_bool().unwrap());
    handle.join().unwrap().unwrap();
}

#[test]
fn paged_decode_is_bitwise_contiguous_for_every_kv_dtype() {
    // the full model forward through a finely-paged cache must emit the
    // exact bits of a coarse one whose single block degenerates to the
    // old contiguous slab — for every KV dtype, not just f32
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 13).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let mc = &man.config;
    let prompt = rand_prompt(mc.vocab, 7, 61);
    for dtype in [DType::F32, DType::Bf16, DType::I8] {
        let run = |block: usize| -> Vec<u32> {
            let mut cache = KvCache::with_layout(
                mc.layers, 1, mc.heads, mc.head_dim(), 32, dtype,
                block);
            let mut bits = Vec::new();
            let mut y =
                rt.prefill(&store, &mut cache, 0, &prompt).unwrap();
            for _ in 0..10 {
                bits.extend(y.iter().map(|x| x.to_bits()));
                let t = argmax(&y) as i32;
                y = rt.decode(&store, &mut cache, &[0], &[t]).unwrap();
            }
            bits
        };
        assert_eq!(run(4), run(32),
                   "{dtype}: block layout changed decode logits");
    }
}

#[test]
fn chunked_prefill_streams_identical_tokens_to_monolithic() {
    let man = manifest();
    let vocab = man.config.vocab;
    let lora1 = seeded_store(&man, Variant::Lora, 21).unwrap();
    let base = base_from(&man, &lora1);
    let mut adapters = BTreeMap::new();
    adapters.insert("a".to_string(),
                    AdapterSet::from_store(&man, &lora1, "a").unwrap());
    let rt = NativeModel::new(man.clone(), Variant::Full).unwrap();
    // prompts longer than the chunk, equal to it, and shorter
    let reqs: Vec<(Option<&str>, usize, u64)> =
        vec![(Some("a"), 11, 5), (None, 4, 6), (Some("a"), 2, 7)];
    let run = |chunk: usize| -> Vec<Vec<i32>> {
        let cache = rt.new_cache_blocked(2, 64, 4);
        let queue = Queue::new(8);
        let stats = ServeStats::default();
        let mut rxs = Vec::new();
        for (i, (name, len, seed)) in reqs.iter().enumerate() {
            let (tx, rx) = channel();
            queue.push(ServeRequest {
                id: i as u64,
                adapter: name.map(str::to_string),
                prompt: rand_prompt(vocab, *len, 70 + i as u64),
                spec: SamplingSpec {
                    sampler: Sampler::top_k(8, 0.9),
                    seed: *seed,
                    max_new: 6,
                    stop_tokens: Vec::new(),
                },
                tx,
                enqueued: Instant::now(),
            });
            rxs.push(rx);
        }
        queue.begin_drain();
        Scheduler::new(&rt, &base, &adapters, cache)
            .with_prefill_chunk(chunk)
            .run(&queue, &stats);
        rxs.iter()
            .map(|rx| {
                let mut toks = Vec::new();
                while let Ok(ev) = rx.try_recv() {
                    if let TokenEvent::Token(t) = ev {
                        toks.push(t);
                    }
                }
                toks
            })
            .collect()
    };
    let mono = run(0); // 0 = whole prompt in one pass
    assert!(mono.iter().all(|t| t.len() == 6));
    assert_eq!(mono, run(4),
               "prefill chunking changed the streamed tokens");
    assert_eq!(mono, run(3),
               "a chunk size not dividing the prompts changed tokens");
}

#[test]
fn block_pool_recycles_under_slot_churn() {
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 9).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let vocab = man.config.vocab;
    let mut cache = rt.new_cache_blocked(2, 32, 4);
    assert_eq!(cache.bytes(), 0, "nothing is pre-reserved");
    let mut high_water = 0usize;
    for wave in 0..3u64 {
        let s0 = cache.acquire().unwrap();
        let s1 = cache.acquire().unwrap();
        for (i, s) in [s0, s1].into_iter().enumerate() {
            let p = rand_prompt(vocab, 6 + i, 80 + 2 * wave + i as u64);
            let mut y = rt.prefill(&store, &mut cache, s, &p).unwrap();
            for _ in 0..5 {
                let t = argmax(&y) as i32;
                y = rt.decode(&store, &mut cache, &[s], &[t]).unwrap();
            }
        }
        assert!(cache.blocks_live() > 0);
        cache.release(s0);
        cache.release(s1);
        // O(blocks) retire: every block is back on the free list
        assert_eq!(cache.blocks_live(), 0, "wave {wave} leaked blocks");
        assert_eq!(cache.blocks_free(), cache.blocks_allocated());
        if wave == 0 {
            high_water = cache.blocks_allocated();
            assert!(high_water > 0);
        } else {
            assert_eq!(cache.blocks_allocated(), high_water,
                       "churn grew the pool instead of recycling");
        }
    }
    assert_eq!(cache.bytes(), high_water * cache.block_bytes());
    assert!(cache.bytes() < cache.slab_bytes(),
            "pool high-water should undercut the dense slab");
}

/// Read exactly one HTTP response off a kept-alive socket: headers,
/// then a `Content-Length` body or a chunked body up to its terminator.
fn read_one_response(s: &mut TcpStream) -> (u16, String, Vec<u8>) {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        assert!(s.read(&mut byte).unwrap() > 0,
                "EOF inside response head");
        head.push(byte[0]);
    }
    let head = String::from_utf8_lossy(&head).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .unwrap();
    let lower = head.to_ascii_lowercase();
    let mut body = Vec::new();
    if let Some(pos) = lower.find("content-length:") {
        let n: usize = lower[pos + "content-length:".len()..]
            .lines()
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        body.resize(n, 0);
        s.read_exact(&mut body).unwrap();
    } else if lower.contains("transfer-encoding: chunked") {
        while !body.ends_with(b"\r\n0\r\n\r\n") {
            assert!(s.read(&mut byte).unwrap() > 0,
                    "EOF inside chunked body");
            body.push(byte[0]);
        }
    }
    (status, head, body)
}

#[test]
fn keep_alive_serves_sequential_requests_on_one_connection() {
    let man = manifest();
    let vocab = man.config.vocab;
    let lora1 = seeded_store(&man, Variant::Lora, 21).unwrap();
    let base_store = base_from(&man, &lora1);
    let rt: Box<dyn InferRuntime> =
        Box::new(NativeModel::new(man.clone(), Variant::Full).unwrap());
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        max_batch: 2,
        queue_depth: 4,
        max_context: 64,
        default_max_new: 8,
        ..ServeConfig::default()
    };
    let server = Server::bind(cfg, rt,
                              BaseSource::Master(base_store),
                              AdapterRegistry::new(), vocab)
        .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let handle = thread::spawn(move || server.run());

    let mut s = TcpStream::connect(&addr).unwrap();
    // 1: HTTP/1.1 defaults to keep-alive — no Connection header sent
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    let (status, head, _) = read_one_response(&mut s);
    assert_eq!(status, 200);
    assert!(head.contains("Connection: keep-alive"), "head: {head}");
    // 2: a full streamed generation on the SAME socket
    let body = r#"{"tokens":[1,2,3],"max_new":4,"seed":3}"#;
    s.write_all(format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: \
         {}\r\n\r\n{body}", body.len()).as_bytes())
        .unwrap();
    let (status, head, raw) = read_one_response(&mut s);
    assert_eq!(status, 200, "head: {head}");
    assert!(head.contains("Transfer-Encoding: chunked"));
    assert!(head.contains("Connection: keep-alive"));
    let nd = String::from_utf8(decode_chunked(&raw).unwrap()).unwrap();
    assert_eq!(nd.lines().filter(|l| !l.is_empty()).count(), 5,
               "4 token lines + 1 done line: {nd}");
    // 3: a non-streamed generation, still the same socket
    let body = r#"{"tokens":[5],"max_new":2,"stream":false}"#;
    s.write_all(format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: \
         {}\r\n\r\n{body}", body.len()).as_bytes())
        .unwrap();
    let (status, _, raw) = read_one_response(&mut s);
    assert_eq!(status, 200);
    let j = Json::parse(std::str::from_utf8(&raw).unwrap()).unwrap();
    assert_eq!(j.get("n_generated").unwrap().as_usize().unwrap(), 2);
    // 4: Connection: close is honored with an EOF after the response
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: \
                  close\r\n\r\n")
        .unwrap();
    let (status, head, _) = read_one_response(&mut s);
    assert_eq!(status, 200);
    assert!(head.contains("Connection: close"), "head: {head}");
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(),
            "server kept the socket open after Connection: close");

    let (status, _, _) = post(&addr, "/admin/drain", "");
    assert_eq!(status, 200);
    handle.join().unwrap().unwrap();
}

#[test]
fn prefix_warm_decode_is_bitwise_cold_for_every_dtype_and_block() {
    // ISSUE 10 acceptance: splicing sealed blocks from the prefix pool
    // must reproduce the cold path's logits bit for bit, because the
    // pool holds exactly the dtype-tagged rows a deterministic prefill
    // would recompute — for every KV dtype and more than one block size
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 13).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let mc = &man.config;
    let prompt = rand_prompt(mc.vocab, 13, 62);
    for dtype in [DType::F32, DType::Bf16, DType::I8] {
        for block in [4usize, 8] {
            let mut cache = KvCache::with_layout(
                mc.layers, 1, mc.heads, mc.head_dim(), 64, dtype,
                block);
            cache.enable_prefix(16);
            let run = |cache: &mut KvCache| -> (usize, Vec<u32>) {
                let s = cache.acquire().unwrap();
                let reused = cache.admit_prefix(s, "t", &prompt);
                let mut bits = Vec::new();
                let mut y = rt
                    .prefill(&store, cache, s, &prompt[reused..])
                    .unwrap();
                cache.note_tokens(s, &prompt[reused..]);
                for _ in 0..10 {
                    bits.extend(y.iter().map(|x| x.to_bits()));
                    let t = argmax(&y) as i32;
                    y = rt.decode(&store, cache, &[s], &[t]).unwrap();
                    cache.note_tokens(s, &[t]);
                }
                cache.release(s);
                (reused, bits)
            };
            let (cold_reused, cold) = run(&mut cache);
            assert_eq!(cold_reused, 0, "{dtype}/{block}: cold run hit");
            let (warm_reused, warm) = run(&mut cache);
            // every whole block strictly before the final prompt token
            // is eligible, and the cold run sealed all of them
            assert_eq!(warm_reused, (prompt.len() - 1) / block * block,
                       "{dtype}/{block}: short prefix match");
            assert_eq!(warm, cold,
                       "{dtype}/{block}: prefix-warm logits diverge \
                        from cold prefill");
        }
    }
}

#[test]
fn prefix_evict_then_readmit_decodes_identically() {
    // a pool too small to retain the prefix forces eviction; the next
    // admission must degrade to a cold prefill (not wrong K/V) and then
    // re-seal, after which sharing works again
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 13).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let mc = &man.config;
    let block = 4usize;
    let prompt = rand_prompt(mc.vocab, 13, 63); // 3 sealable blocks
    let other = rand_prompt(mc.vocab, 13, 64);
    let mut cache = KvCache::with_layout(
        mc.layers, 2, mc.heads, mc.head_dim(), 64, DType::F32, block);
    cache.enable_prefix(2); // < 3: `prompt`'s chain cannot survive
    let run = |cache: &mut KvCache, p: &[i32]| -> (usize, Vec<i32>) {
        let s = cache.acquire().unwrap();
        let reused = cache.admit_prefix(s, "t", p);
        let mut y =
            rt.prefill(&store, cache, s, &p[reused..]).unwrap();
        cache.note_tokens(s, &p[reused..]);
        let mut toks = Vec::new();
        for _ in 0..8 {
            let t = argmax(&y) as i32;
            toks.push(t);
            y = rt.decode(&store, cache, &[s], &[t]).unwrap();
            cache.note_tokens(s, &[t]);
        }
        cache.release(s);
        (reused, toks)
    };
    let (_, cold) = run(&mut cache, &prompt);
    assert!(cache.prefix_stats().evicted > 0,
            "a 2-block pool must have evicted");
    // churn with a different prompt to evict whatever survived
    let (_, _) = run(&mut cache, &other);
    let (reused, again) = run(&mut cache, &prompt);
    assert_eq!(again, cold,
               "decode after evict-then-readmit changed tokens");
    // and once re-sealed, the *retained* tail of the chain can hit
    let (reused2, third) = run(&mut cache, &prompt);
    assert_eq!(third, cold);
    assert!(reused2 >= reused,
            "re-sealed prefix should match at least as far");
    assert!(cache.prefix_stats().pool_blocks <= 2,
            "pool exceeded its budget");
}

#[test]
fn prefix_sharing_keeps_refcounts_and_ledger_exact_under_churn() {
    let man = manifest();
    let store = seeded_store(&man, Variant::Lora, 13).unwrap();
    let full = seeded_store(&man, Variant::Full, 5).unwrap();
    let packed = PackedStore::quantize_base(&full, DType::I8).unwrap();
    let rt = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let mc = &man.config;
    let block = 4usize;
    let shared_prefix = rand_prompt(mc.vocab, 8, 65);
    let mut cache = KvCache::with_layout(
        mc.layers, 3, mc.heads, mc.head_dim(), 64, DType::F32, block);
    cache.enable_prefix(8);
    let ads = vec![("a".to_string(), 1024u64)];
    let feed = |cache: &mut KvCache, tail_seed: u64| -> usize {
        let mut p = shared_prefix.clone();
        p.extend(rand_prompt(mc.vocab, 5, tail_seed));
        let s = cache.acquire().unwrap();
        let reused = cache.admit_prefix(s, "t", &p);
        let y = rt.prefill(&store, cache, s, &p[reused..]).unwrap();
        cache.note_tokens(s, &p[reused..]);
        let t = argmax(&y) as i32;
        rt.decode(&store, cache, &[s], &[t]).unwrap();
        cache.note_tokens(s, &[t]);
        s
    };
    // three live sequences over one shared 2-block prefix
    let s0 = feed(&mut cache, 90);
    let s1 = feed(&mut cache, 91);
    let s2 = feed(&mut cache, 92);
    let st = cache.prefix_stats();
    assert_eq!(st.shared_blocks, 2,
               "both whole prefix blocks should be shared 3 ways");
    assert_eq!(st.hit_blocks, 4, "two warm admissions x two blocks");
    // ledger: total is exact, and the kv_cache + kv_prefix_pool rows
    // decompose bytes() with nothing pooled while everything is live
    let rows = serve_mem_rows(&packed, DType::I8, &ads, &cache);
    assert_eq!(mem_total(&rows),
               packed.resident_bytes() as u64 + 1024
                   + cache.bytes() as u64);
    assert!(rows.iter().all(|r| r.component != "kv_prefix_pool"),
            "no pooled blocks yet: the pool row must be absent");
    // release everything: sealed blocks park in the pool (retained,
    // not freed), refcounts drop to zero, totals stay exact
    cache.release(s0);
    cache.release(s1);
    cache.release(s2);
    let st = cache.prefix_stats();
    assert_eq!(st.shared_blocks, 0);
    assert!(st.pool_blocks > 0 && st.pool_blocks <= 8);
    let rows = serve_mem_rows(&packed, DType::I8, &ads, &cache);
    assert_eq!(mem_total(&rows),
               packed.resident_bytes() as u64 + 1024
                   + cache.bytes() as u64,
               "pooled blocks fell out of the ledger");
    let pool_row = rows.iter()
        .find(|r| r.component == "kv_prefix_pool")
        .expect("pooled blocks must get their own ledger row");
    assert_eq!(pool_row.bytes,
               st.pool_blocks as u64 * cache.block_bytes() as u64);
    // readmitting pulls blocks back out of the pool: refcounts return
    let s = cache.acquire().unwrap();
    let reused = cache.admit_prefix(s, "t", &shared_prefix);
    assert_eq!(reused, 4, "one whole block of the 8-token prefix");
    assert_eq!(cache.prefix_stats().pool_blocks, st.pool_blocks - 1);
    cache.release(s);
}

#[test]
fn scheduler_prefix_cache_off_is_noop_and_on_streams_same_tokens() {
    // the scheduler path: prefix sharing on must stream exactly the
    // tokens of prefix sharing off (which itself is the pre-prefix
    // code path), while prefilling strictly fewer suffix tokens
    let man = manifest();
    let vocab = man.config.vocab;
    let lora1 = seeded_store(&man, Variant::Lora, 21).unwrap();
    let base = base_from(&man, &lora1);
    let mut adapters = BTreeMap::new();
    adapters.insert("a".to_string(),
                    AdapterSet::from_store(&man, &lora1, "a").unwrap());
    let rt = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let prefix = rand_prompt(vocab, 12, 66);
    let reqs: Vec<(u64, u64)> = vec![(5, 100), (6, 101), (7, 102)];
    let run = |prefix_on: bool| -> (Vec<Vec<i32>>, u64, u64) {
        // max_batch 1 serializes the requests, so later admissions see
        // the earlier request's sealed blocks in the pool
        let mut cache = rt.new_cache_blocked(1, 64, 4);
        if prefix_on {
            cache.enable_prefix(16);
        }
        let queue = Queue::new(8);
        let stats = ServeStats::default();
        let mut rxs = Vec::new();
        for (i, (seed, tail_seed)) in reqs.iter().enumerate() {
            let mut p = prefix.clone();
            p.extend(rand_prompt(vocab, 3, *tail_seed));
            let (tx, rx) = channel();
            queue.push(ServeRequest {
                id: i as u64,
                adapter: Some("a".to_string()),
                prompt: p,
                spec: SamplingSpec {
                    sampler: Sampler::top_k(8, 0.9),
                    seed: *seed,
                    max_new: 6,
                    stop_tokens: Vec::new(),
                },
                tx,
                enqueued: Instant::now(),
            });
            rxs.push(rx);
        }
        queue.begin_drain();
        Scheduler::new(&rt, &base, &adapters, cache)
            .with_prefill_chunk(5)
            .run(&queue, &stats);
        use std::sync::atomic::Ordering;
        let toks = rxs.iter()
            .map(|rx| {
                let mut toks = Vec::new();
                while let Ok(ev) = rx.try_recv() {
                    if let TokenEvent::Token(t) = ev {
                        toks.push(t);
                    }
                }
                toks
            })
            .collect();
        (toks,
         stats.prefilled_tokens.load(Ordering::Relaxed),
         stats.prefix_hit_blocks.load(Ordering::Relaxed))
    };
    let (cold_toks, cold_prefilled, cold_hits) = run(false);
    assert!(cold_toks.iter().all(|t| t.len() == 6));
    assert_eq!(cold_hits, 0, "--prefix-cache off must never hit");
    let (warm_toks, warm_prefilled, warm_hits) = run(true);
    assert_eq!(warm_toks, cold_toks,
               "prefix sharing changed the streamed tokens");
    assert!(warm_hits > 0,
            "identical 12-token prefixes never hit the cache");
    assert!(warm_prefilled < cold_prefilled,
            "warm requests should prefill only the uncached suffix \
             ({warm_prefilled} vs {cold_prefilled} tokens)");
}
