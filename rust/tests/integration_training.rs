//! Integration tests over the full training loop: Trainer invariants,
//! checkpointing, data parallelism, fine-tuning.  These run end-to-end on
//! the native CPU backend with the builtin `tiny` spec, so `cargo test`
//! genuinely trains all five methods on a clean machine.

use std::path::PathBuf;

use switchlora::coordinator::checkpoint;
use switchlora::coordinator::trainer::{default_artifacts_dir, Method,
                                       TrainConfig, Trainer};
use switchlora::methods::{ReLoraParams, SwitchParams};
use switchlora::model::layout::{Manifest, Variant};
use switchlora::runtime::Engine;

fn manifest() -> Manifest {
    Manifest::for_spec(&default_artifacts_dir(), "tiny").unwrap()
}

fn quick_cfg(method: Method, steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", method, steps);
    cfg.eval_every = steps;
    cfg.eval_batches = 2;
    cfg.warmup = 5;
    cfg
}

#[test]
fn all_methods_train_and_reduce_loss() {
    let mut engine = Engine::cpu().unwrap();
    let uniform = (256f64).ln();
    for method in [
        Method::full(),
        Method::lora(),
        Method::switchlora(SwitchParams { interval0: 10.0, ratio: 0.3,
                                          n_freeze: 3 }),
        Method::relora(ReLoraParams { reset_interval: 15, rewarm: 5 }),
        Method::parse("galore").unwrap(),
    ] {
        let name = method.name().to_string();
        let (res, _) = Trainer::new(quick_cfg(method, 40))
            .unwrap()
            .run(&mut engine)
            .unwrap();
        assert!(res.final_eval_loss.is_finite(), "{name} diverged");
        assert!(res.final_eval_loss < uniform - 0.2,
                "{name}: eval {} not below uniform {uniform}",
                res.final_eval_loss);
        assert_eq!(res.train_curve.len(), 40);
    }
}

#[test]
fn switchlora_switches_and_ledgers() {
    let mut engine = Engine::cpu().unwrap();
    let cfg = quick_cfg(
        Method::switchlora(SwitchParams { interval0: 8.0, ratio: 0.5,
                                          n_freeze: 2 }),
        20,
    );
    let (res, _) = Trainer::new(cfg).unwrap().run(&mut engine).unwrap();
    let switches = res.counter("switches");
    let offload = res.counter("offload_bytes");
    assert!(switches > 0);
    assert!(offload > 0);
    // offload accounting: 2 swapped vectors per switch, 2 bytes/elem —
    // bounded by 2 * 2bytes * max(m,n) per switch
    let man = manifest();
    let max_dim = man.linears.iter().map(|l| l.m.max(l.n)).max().unwrap();
    assert!(offload <= switches * 2 * 2 * max_dim as u64);
}

#[test]
fn data_parallel_traffic_scales_with_trainable() {
    let mut engine = Engine::cpu().unwrap();
    let mut run = |method: Method| {
        let mut cfg = quick_cfg(method, 4);
        cfg.workers = 4;
        let (res, _) =
            Trainer::new(cfg).unwrap().run(&mut engine).unwrap();
        res
    };
    let full = run(Method::full());
    let lora = run(Method::lora());
    assert!(full.comm.bytes > 0 && lora.comm.bytes > 0);
    let ratio = lora.comm.bytes as f64 / full.comm.bytes as f64;
    let want = lora.n_trainable as f64 / full.n_trainable as f64;
    // measured ring traffic tracks trainable-parameter ratio (padding adds
    // a little slack)
    assert!((ratio - want).abs() < 0.15, "ratio {ratio} vs want {want}");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let mut engine = Engine::cpu().unwrap();
    let cfg = quick_cfg(Method::lora(), 10);
    let trainer = Trainer::new(cfg).unwrap();
    let (res, store) = trainer.run(&mut engine).unwrap();
    let dir = std::env::temp_dir().join("switchlora_it_ckpt");
    let path = dir.join("t.ckpt");
    checkpoint::save(&path, "tiny", &store, None).unwrap();
    // reload into a fresh store and re-evaluate
    let man = manifest();
    let mut fresh = switchlora::model::layout::ParamStore::zeros(
        std::sync::Arc::new(man.lora.clone()));
    let ck = checkpoint::load(&path).unwrap();
    let rep = ck.restore_into(&mut fresh);
    assert_eq!(rep.missing, 0);
    assert_eq!(rep.mismatched, 0);
    assert_eq!(rep.loaded, man.lora.params.len());
    let rt = switchlora::runtime::ModelRuntime::load(
        &mut engine, man.clone(), Variant::Lora).unwrap();
    let set = switchlora::data::dataset::EvalSet::synth(
        man.config.vocab, 42, man.config.batch, man.config.seq, 2);
    let loss = switchlora::coordinator::eval::eval_loss(&rt, &fresh, &set)
        .unwrap();
    assert!((loss as f64 - res.final_eval_loss).abs() < 1e-4,
            "{loss} vs {}", res.final_eval_loss);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn full_warmup_carries_into_lora_phase() {
    let mut engine = Engine::cpu().unwrap();
    let mut cfg = quick_cfg(
        Method::switchlora(SwitchParams::default()), 15);
    cfg.full_warmup_steps = 10;
    let (res, _) = Trainer::new(cfg).unwrap().run(&mut engine).unwrap();
    assert!(res.final_eval_loss.is_finite());
    // warm-started run should already be better than uniform quickly
    assert!(res.final_eval_loss < (256f64).ln() - 0.3,
            "eval {}", res.final_eval_loss);
}

#[test]
fn finetune_improves_over_chance() {
    let mut engine = Engine::cpu().unwrap();
    // brief pretrain, then fine-tune on the easiest task
    let (_, store) = Trainer::new(quick_cfg(Method::lora(), 15))
        .unwrap()
        .run(&mut engine)
        .unwrap();
    let man = manifest();
    let results = switchlora::exp::finetune::glue_suite(
        &mut engine, &man, &store, Variant::Lora,
        &[switchlora::data::tasks::Task::Majority], 250, 3e-3, 1).unwrap();
    let acc = results[0].accuracy;
    // majority over 4 classes: chance = 0.25
    assert!(acc > 0.45, "majority accuracy {acc} not above chance");
}

#[test]
fn metrics_csv_is_written() {
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join("switchlora_it_csv");
    let path: PathBuf = dir.join("curve.csv");
    let mut cfg = quick_cfg(Method::lora(), 6);
    cfg.metrics_csv = Some(path.clone());
    Trainer::new(cfg).unwrap().run(&mut engine).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text.lines().count(), 7); // header + 6 steps
    assert!(text.starts_with("step,loss,ema,lr,eval_loss"));
    std::fs::remove_dir_all(&dir).ok();
}
