//! Integration tests of the inference subsystem (ISSUE 2 acceptance):
//!
//! * KV-cached incremental decode reproduces a full-context re-forward's
//!   logits at EVERY step (≤1e-5, both adapter variants);
//! * adapter merging: merged forward matches the dense `W + s·B·A`
//!   composition, the in-place and export merge paths agree bitwise, and
//!   unmerge restores the original store bitwise;
//! * batched ragged-prompt generation matches single-sequence runs
//!   token-for-token, with per-sequence stop handling;
//! * determinism: same seed + same sampling params ⇒ identical streams.

use switchlora::infer::{argmax, generate, merge_adapters,
                        merged_full_store, unmerge_adapters, GenConfig,
                        Sampler};
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::runtime::{InferRuntime, NativeModel};
use switchlora::util::prop::assert_close;
use switchlora::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::builtin("tiny").unwrap()
}

fn init(man: &Manifest, variant: Variant, seed: u64) -> ParamStore {
    seeded_store(man, variant, seed).unwrap()
}

fn rand_prompt(vocab: usize, len: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

#[test]
fn cached_decode_matches_full_reforward_at_every_step() {
    let man = manifest();
    let vocab = man.config.vocab;
    for (variant, seed) in [(Variant::Lora, 3), (Variant::Full, 4)] {
        let store = init(&man, variant, seed);
        let model = NativeModel::new(man.clone(), variant).unwrap();
        let prompt = rand_prompt(vocab, 9, seed);
        let n_steps = 16;
        let mut cache = model.new_cache(1, prompt.len() + n_steps + 1);
        let mut cached = model
            .prefill(&store, &mut cache, 0, &prompt)
            .unwrap();
        let mut toks = prompt.clone();
        for step in 0..n_steps {
            // reference: full re-forward over the whole context
            let t = toks.len();
            let full =
                model.forward_last_logits(&store, &toks, 1, t).unwrap();
            assert_eq!(full.len(), vocab);
            assert_close(&cached, &full, 1e-5, 1e-5).unwrap_or_else(
                |e| panic!("{:?} step {step} (ctx {t}): {e}", variant));
            let next = argmax(&cached) as i32;
            toks.push(next);
            cached = model
                .decode(&store, &mut cache, &[0], &[next])
                .unwrap();
        }
    }
}

#[test]
fn chunked_prefill_matches_one_shot_prefill() {
    // continuation chunks (prefill called twice) must land at the right
    // absolute RoPE positions
    let man = manifest();
    let store = init(&man, Variant::Lora, 5);
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let prompt = rand_prompt(man.config.vocab, 12, 5);
    let mut one = model.new_cache(1, 16);
    let logits_one =
        model.prefill(&store, &mut one, 0, &prompt).unwrap();
    let mut two = model.new_cache(1, 16);
    model.prefill(&store, &mut two, 0, &prompt[..7]).unwrap();
    let logits_two =
        model.prefill(&store, &mut two, 0, &prompt[7..]).unwrap();
    assert_eq!(one.len(0), two.len(0));
    assert_close(&logits_two, &logits_one, 1e-5, 1e-6).unwrap();
}

#[test]
fn merged_forward_matches_adapter_composition() {
    let man = manifest();
    let store = init(&man, Variant::Lora, 7);
    let lora = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let dense = NativeModel::new(man.clone(), Variant::Full).unwrap();
    let toks = rand_prompt(man.config.vocab, 2 * 12, 7);
    let (b, t) = (2, 12);

    // unmerged LoRA forward vs the merged dense function: same math
    // modulo float reassociation of the W·x + s·B·A·x split
    let y_lora = lora.forward_logits(&store, &toks, b, t).unwrap();
    let merged = merged_full_store(&man, &store).unwrap();
    let y_merged = dense.forward_logits(&merged, &toks, b, t).unwrap();
    assert_close(&y_merged, &y_lora, 1e-4, 1e-4).unwrap();

    // in-place merge (B zeroed) through the LoRA forward is the same
    // dense function exactly
    let mut inplace = store.clone();
    let state = merge_adapters(&mut inplace, &man).unwrap();
    assert_eq!(state.n_merged(), man.linears.len());
    let y_inplace = lora.forward_logits(&inplace, &toks, b, t).unwrap();
    assert_close(&y_inplace, &y_merged, 0.0, 0.0).unwrap();

    // unmerge restores the original store bitwise
    unmerge_adapters(&mut inplace, &state).unwrap();
    assert_eq!(inplace.data, store.data);
}

#[test]
fn batched_ragged_generation_matches_single_runs() {
    let man = manifest();
    let store = init(&man, Variant::Lora, 9);
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let vocab = man.config.vocab;
    let prompts = vec![
        rand_prompt(vocab, 3, 21),
        rand_prompt(vocab, 7, 22),
        rand_prompt(vocab, 5, 23),
    ];
    let cfg = GenConfig {
        max_new: 10,
        sampler: Sampler::top_k(16, 0.8),
        stop_tokens: Vec::new(),
        seed: 31,
        max_context: None,
    };
    let batched = generate(&model, &store, &prompts, &cfg).unwrap();
    assert_eq!(batched.prefill_tokens, 3 + 7 + 5);
    assert_eq!(batched.decode_steps, cfg.max_new - 1);
    for (s, prompt) in prompts.iter().enumerate() {
        // per-(seed, index) sampling streams: a sequence's continuation
        // must not depend on what else shares the batch, so a solo run
        // at the same index-0 slot only matches for s == 0...
        assert_eq!(batched.n_generated[s], cfg.max_new);
        assert_eq!(&batched.sequences[s][..prompt.len()], &prompt[..]);
    }
    // ...so check slot 0 exactly, and greedy (sampler-independent) for
    // the full batch
    let solo = generate(&model, &store, &prompts[..1], &cfg).unwrap();
    assert_eq!(solo.sequences[0], batched.sequences[0]);
    let gcfg = GenConfig::greedy(8);
    let gb = generate(&model, &store, &prompts, &gcfg).unwrap();
    for (s, prompt) in prompts.iter().enumerate() {
        let gs = generate(&model, &store,
                          std::slice::from_ref(prompt), &gcfg).unwrap();
        assert_eq!(gs.sequences[0], gb.sequences[s],
                   "greedy batched vs solo diverged for sequence {s}");
    }
}

#[test]
fn per_sequence_stop_handling() {
    let man = manifest();
    let store = init(&man, Variant::Lora, 13);
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let vocab = man.config.vocab;
    let prompts =
        vec![rand_prompt(vocab, 4, 41), rand_prompt(vocab, 6, 42)];
    // probe run: find what greedy emits, then stop on a token that
    // appears mid-stream for at least one sequence
    let probe =
        generate(&model, &store, &prompts, &GenConfig::greedy(12))
            .unwrap();
    let stream0 = &probe.sequences[0][prompts[0].len()..];
    let stop = stream0[2];
    let mut cfg = GenConfig::greedy(12);
    cfg.stop_tokens = vec![stop];
    let out = generate(&model, &store, &prompts, &cfg).unwrap();
    for s in 0..prompts.len() {
        let stream = &probe.sequences[s][prompts[s].len()..];
        let expect = stream
            .iter()
            .position(|&t| t == stop)
            .map(|i| i + 1)
            .unwrap_or(cfg.max_new);
        assert_eq!(out.n_generated[s], expect,
                   "sequence {s}: stop handling diverged");
        // a stopped sequence ends with the stop token
        if expect < cfg.max_new {
            assert_eq!(*out.sequences[s].last().unwrap(), stop);
        }
    }
    // stop was taken from within seq 0's first three generated tokens,
    // so that sequence must have stopped early
    assert!(out.n_generated[0] <= 3,
            "seq 0 generated {} tokens past its stop", out.n_generated[0]);
}

#[test]
fn max_context_clamps_generation_instead_of_panicking() {
    // ISSUE 6 S3: a full KV cache used to abort the whole batch via the
    // KvCache::append overflow assert; with --max-context the loop
    // retires a full sequence cleanly and the rest keep going.
    let man = manifest();
    let store = init(&man, Variant::Lora, 29);
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let vocab = man.config.vocab;
    let prompts =
        vec![rand_prompt(vocab, 5, 61), rand_prompt(vocab, 3, 62)];
    let mut cfg = GenConfig::greedy(10);
    cfg.max_context = Some(8);
    let out = generate(&model, &store, &prompts, &cfg).unwrap();
    // each sequence fills its cache to exactly max_context rows, then
    // emits one final token from that last decode before retiring:
    // generated = 1 + (max_context - prompt_len)
    assert_eq!(out.n_generated, vec![4, 6]);
    assert_eq!(out.sequences[0].len(), 9);
    assert_eq!(out.sequences[1].len(), 9);
    // the clamped run matches an unclamped run token-for-token up to
    // the point of retirement
    let free = generate(&model, &store, &prompts, &GenConfig::greedy(10))
        .unwrap();
    for s in 0..prompts.len() {
        assert_eq!(&out.sequences[s][..],
                   &free.sequences[s][..out.sequences[s].len()],
                   "clamped stream diverged for sequence {s}");
    }
    // a ceiling that still fits everything changes nothing
    let mut roomy = GenConfig::greedy(10);
    roomy.max_context = Some(64);
    let r = generate(&model, &store, &prompts, &roomy).unwrap();
    assert_eq!(r.sequences, free.sequences);
    // a prompt longer than the ceiling is a loud error, not a panic
    let mut tight = GenConfig::greedy(4);
    tight.max_context = Some(4);
    let err = generate(&model, &store, &prompts, &tight).unwrap_err();
    assert!(format!("{err}").contains("max-context"), "{err}");
}

#[test]
fn same_seed_same_stream_across_runs() {
    let man = manifest();
    let store = init(&man, Variant::Lora, 17);
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let prompts = vec![rand_prompt(man.config.vocab, 5, 51)];
    let cfg = GenConfig {
        max_new: 32,
        sampler: Sampler { temperature: 1.0, top_k: 0, top_p: 1.0 },
        stop_tokens: Vec::new(),
        seed: 99,
        max_context: None,
    };
    let a = generate(&model, &store, &prompts, &cfg).unwrap();
    let b = generate(&model, &store, &prompts, &cfg).unwrap();
    assert_eq!(a.sequences, b.sequences,
               "same seed must reproduce the stream exactly");
    let mut cfg2 = cfg.clone();
    cfg2.seed = 100;
    let c = generate(&model, &store, &prompts, &cfg2).unwrap();
    assert_ne!(a.sequences, c.sequences,
               "different seeds should diverge (vocab-256 stream of 32 \
                sampled tokens)");
}

#[test]
fn inference_rejects_misuse() {
    let man = manifest();
    let store = init(&man, Variant::Lora, 19);
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    // cls variant has no LM head
    let cls = NativeModel::new(man.clone(), Variant::Cls).unwrap();
    let cls_store = init(&man, Variant::Cls, 19);
    let mut cache = cls.new_cache(1, 8);
    assert!(cls.prefill(&cls_store, &mut cache, 0, &[1, 2]).is_err());
    // decode before prefill, malformed sequence lists, token out of
    // vocab — all rejected without corrupting the cache
    let mut cache = model.new_cache(2, 8);
    assert!(model.decode(&store, &mut cache, &[0, 1], &[1, 2]).is_err());
    model.prefill(&store, &mut cache, 0, &[1, 2, 3]).unwrap();
    model.prefill(&store, &mut cache, 1, &[4]).unwrap();
    assert!(model.decode(&store, &mut cache, &[0, 1], &[1]).is_err());
    assert!(model.decode(&store, &mut cache, &[1, 0], &[1, 2]).is_err());
    assert!(model.decode(&store, &mut cache, &[0, 0], &[1, 2]).is_err());
    assert!(model.decode(&store, &mut cache, &[2], &[1]).is_err());
    assert!(model.decode(&store, &mut cache, &[], &[]).is_err());
    assert!(model
        .decode(&store, &mut cache, &[0, 1],
                &[1, man.config.vocab as i32])
        .is_err());
    assert!(model.decode(&store, &mut cache, &[0, 1], &[1, 2]).is_ok());
    // a partial active set only advances the listed sequence
    let (l0, l1) = (cache.len(0), cache.len(1));
    assert!(model.decode(&store, &mut cache, &[1], &[5]).is_ok());
    assert_eq!((cache.len(0), cache.len(1)), (l0, l1 + 1));
    // empty prompts are rejected by the generation loop
    assert!(generate(&model, &store, &[vec![]], &GenConfig::greedy(4))
        .is_err());
    assert!(generate(&model, &store, &[], &GenConfig::greedy(4)).is_err());
}
