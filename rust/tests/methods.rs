//! The `TrainingMethod` plugin API end to end: every registered method
//! trains through the same generic leader loop, the layerwise hybrid
//! proves the API generalizes past the seed methods, and the warm-start
//! wrapper composes with arbitrary inner methods.

use switchlora::coordinator::trainer::{Method, TrainConfig, Trainer};
use switchlora::methods::{self, MethodCtx, PreLoraParams, SwitchParams};
use switchlora::model::layout::Manifest;
use switchlora::runtime::Engine;

fn manifest() -> Manifest {
    Manifest::for_spec(
        &switchlora::coordinator::trainer::default_artifacts_dir(),
        "tiny")
        .unwrap()
}

fn quick_cfg(method: Method, steps: u64) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", method, steps);
    cfg.eval_every = steps;
    cfg.eval_batches = 2;
    cfg.warmup = 5;
    cfg
}

#[test]
fn registry_covers_all_seed_methods_and_hybrids() {
    let names: Vec<&str> =
        methods::registry().iter().map(|m| m.name).collect();
    for want in ["full", "lora", "switchlora", "relora", "galore",
                 "prelora", "warmstart"] {
        assert!(names.contains(&want), "{want} missing from registry");
    }
    assert!(Method::parse("definitely-not-a-method").is_none());
}

#[test]
fn prelora_hybrid_trains_end_to_end() {
    let mut engine = Engine::cpu().unwrap();
    let uniform = (256f64).ln();
    let (res, store) = Trainer::new(quick_cfg(
        Method::prelora(PreLoraParams { full_layers: 1 }), 40))
        .unwrap()
        .run(&mut engine)
        .unwrap();
    assert!(res.final_eval_loss.is_finite(), "prelora diverged");
    assert!(res.final_eval_loss < uniform - 0.2,
            "prelora eval {} not below uniform", res.final_eval_loss);
    // hybrid trainable mass sits strictly between pure lora and full
    let man = manifest();
    assert!(res.n_trainable > man.lora.n_trainable);
    assert!(res.n_trainable < man.full.n_trainable);
    // counters report the layer split (7 linears per layer)
    assert_eq!(res.counter("full_layers"), 1);
    assert_eq!(res.counter("dense_linears"), 7);
    assert_eq!(res.counter("adapted_linears"),
               (man.linears.len() - 7) as u64);
    // the store mixes dense trainable linears (no adapters) with
    // adapted ones (frozen base)
    assert!(store.layout.meta("l0.wq").unwrap().trainable);
    assert!(store.layout.meta("l0.wq.a").is_err());
    let last = man.config.layers - 1;
    assert!(!store.layout.meta(&format!("l{last}.wq")).unwrap().trainable);
    assert!(store.layout.meta(&format!("l{last}.wq.a")).is_ok());
}

#[test]
fn warmstart_composes_with_any_inner_method() {
    let mut engine = Engine::cpu().unwrap();
    // explicit spec: warmstart wrapping switchlora with inner options
    let method = Method::switchlora(SwitchParams {
        interval0: 8.0,
        ratio: 0.5,
        n_freeze: 2,
    })
    .warm_started(6);
    let (res, _) = Trainer::new(quick_cfg(method, 15))
        .unwrap()
        .run(&mut engine)
        .unwrap();
    assert!(res.final_eval_loss.is_finite());
    assert!(res.final_eval_loss < (256f64).ln() - 0.3,
            "warm-started eval {}", res.final_eval_loss);
    // the inner method ran (switching happened) and the wrapper
    // reported its warm phase
    assert!(res.counter("switches") > 0);
    assert_eq!(res.counter("warm_steps"), 6);
}

#[test]
fn warmstart_parses_from_registry_with_default_inner() {
    let mut engine = Engine::cpu().unwrap();
    let method = Method::parse("warmstart").unwrap().with("warm-steps", 5);
    let (res, _) = Trainer::new(quick_cfg(method, 12))
        .unwrap()
        .run(&mut engine)
        .unwrap();
    assert!(res.final_eval_loss.is_finite());
    assert_eq!(res.counter("warm_steps"), 5);
}

#[test]
fn default_lrs_follow_the_paper() {
    let man = manifest();
    let ctx = MethodCtx { manifest: &man, steps: 100, seed: 0 };
    let lr = |name: &str| {
        methods::build(&Method::new(name), &ctx).unwrap().default_lr()
    };
    assert_eq!(lr("full"), 1e-3);
    assert_eq!(lr("lora"), 1e-2);
    assert_eq!(lr("switchlora"), 2e-2);
    assert_eq!(lr("relora"), 1e-2);
    assert_eq!(lr("galore"), 1e-2);
    // the warm-start wrapper inherits its inner method's lr
    let ws = methods::build(
        &Method::switchlora(SwitchParams::default()).warm_started(10),
        &ctx)
        .unwrap();
    assert_eq!(ws.default_lr(), 2e-2);
    assert_eq!(ws.name(), "warmstart+switchlora");
}

#[test]
fn cli_spec_roundtrip_through_registry() {
    // the CLI path: --method switchlora --interval0 4 --nfreeze 1
    let args = switchlora::cli::Args::parse(
        "pretrain --method switchlora --interval0 4 --nfreeze 1"
            .split_whitespace()
            .map(String::from),
    );
    let spec = methods::from_args(&args).unwrap();
    let man = manifest();
    let ctx = MethodCtx { manifest: &man, steps: 50, seed: 0 };
    let method = methods::build(&spec, &ctx).unwrap();
    assert_eq!(method.name(), "switchlora");
    // and it actually trains
    let mut engine = Engine::cpu().unwrap();
    let (res, _) = Trainer::new(quick_cfg(spec, 10))
        .unwrap()
        .run(&mut engine)
        .unwrap();
    assert!(res.counter("switches") > 0);
}
