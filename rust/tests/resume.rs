//! Resume-equivalence tests: a run killed at step N and resumed from a
//! `--ckpt-every` checkpoint must reproduce the uninterrupted run's
//! losses *step for step* — for the methods with internal cross-step
//! state (SwitchLoRA's freeze timers / candidate pools / switch RNG,
//! ReLoRA's reset clock and the leader RNG its re-inits draw from),
//! this exercises the whole `save_state`/`load_state` surface.
//!
//! The trick for testing without actually killing a process: run the
//! full 2N steps once with `ckpt_every = N` and a `{step}`-templated
//! checkpoint path (so the step-N snapshot survives), then resume a
//! second run from that snapshot with the *same* config and compare the
//! overlapping curve tails exactly.

use std::path::PathBuf;

use switchlora::coordinator::checkpoint::{self, MethodState, TrainerState};
use switchlora::coordinator::trainer::{Method, RunResult, TrainConfig,
                                       Trainer};
use switchlora::methods::{ReLoraParams, SwitchParams};
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::optim::adam::AdamState;
use switchlora::runtime::Engine;
use switchlora::util::rng::RngState;

const STEPS: u64 = 16;
const HALF: u64 = 8;

fn base_cfg(method: Method, dir: &std::path::Path) -> TrainConfig {
    let mut cfg = TrainConfig::new("tiny", method, STEPS);
    cfg.warmup = 4;
    cfg.eval_every = 4;
    cfg.eval_batches = 2;
    cfg.ckpt_every = HALF;
    cfg.ckpt_path = Some(dir.join("snap_{step}.ckpt"));
    cfg
}

fn run(engine: &mut Engine, cfg: TrainConfig) -> (RunResult, ParamStore) {
    Trainer::new(cfg).unwrap().run(engine).unwrap()
}

/// Train 2N uninterrupted (checkpointing at N), resume from the step-N
/// snapshot, and demand bitwise-equal train/eval curves on the tail.
fn assert_resume_equivalent(method: Method, tag: &str) {
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join(format!("switchlora_resume_{tag}"));
    std::fs::remove_dir_all(&dir).ok();
    let cfg = base_cfg(method, &dir);

    let (full, full_store) = run(&mut engine, cfg.clone());
    assert_eq!(full.train_curve.len() as u64, STEPS);

    let mut rcfg = cfg.clone();
    rcfg.resume = Some(dir.join(format!("snap_{HALF}.ckpt")));
    rcfg.ckpt_path = Some(dir.join("resnap_{step}.ckpt"));
    let (res, res_store) = run(&mut engine, rcfg);

    // the resumed run covers exactly the tail
    assert_eq!(res.train_curve.len() as u64, STEPS - HALF, "{tag}");
    assert_eq!(res.train_curve.first().unwrap().0, HALF, "{tag}");
    // per-step EMA losses must match the uninterrupted run bit for bit
    // (the EMA folds in every post-resume raw loss, so equality here
    // implies the raw losses match too)
    for (a, b) in full.train_curve[HALF as usize..]
        .iter()
        .zip(&res.train_curve)
    {
        assert_eq!(a, b, "{tag}: train curve diverged at step {}", a.0);
    }
    // eval losses of the overlap match exactly
    let full_tail: Vec<_> = full
        .eval_curve
        .iter()
        .filter(|&&(s, _)| s >= HALF)
        .collect();
    let res_tail: Vec<_> = res.eval_curve.iter().collect();
    assert_eq!(full_tail, res_tail, "{tag}: eval curves diverged");
    assert_eq!(full.final_eval_loss, res.final_eval_loss, "{tag}");
    // final weights identical
    assert_eq!(full_store.data, res_store.data, "{tag}: weights diverged");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn switchlora_resume_matches_uninterrupted() {
    // aggressive switching so plenty of freeze windows and pool swaps
    // straddle the kill point
    assert_resume_equivalent(
        Method::switchlora(SwitchParams {
            interval0: 2.0,
            ratio: 0.5,
            n_freeze: 3,
        }),
        "switchlora",
    );
}

#[test]
fn relora_resume_matches_uninterrupted() {
    // resets at 6 and 12: one before the kill point, one after — the
    // second draws re-init values from the restored leader RNG
    assert_resume_equivalent(
        Method::relora(ReLoraParams { reset_interval: 6, rewarm: 3 }),
        "relora",
    );
}

#[test]
fn galore_resume_matches_uninterrupted() {
    // projection refresh at step 6 lands before the kill point, so the
    // restored run must carry the projection + projected moments over
    assert_resume_equivalent(
        Method::parse("galore").unwrap().with("update-freq", 6),
        "galore",
    );
}

#[test]
fn resume_rejects_wrong_method() {
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join("switchlora_resume_wrongm");
    std::fs::remove_dir_all(&dir).ok();
    let cfg = base_cfg(Method::lora(), &dir);
    run(&mut engine, cfg.clone());
    let mut rcfg = base_cfg(
        Method::switchlora(SwitchParams::default()), &dir);
    rcfg.resume = Some(dir.join(format!("snap_{HALF}.ckpt")));
    rcfg.ckpt_every = 0;
    rcfg.ckpt_path = None;
    let err = Trainer::new(rcfg)
        .unwrap()
        .run(&mut engine)
        .unwrap_err()
        .to_string();
    assert!(err.contains("lora") && err.contains("switchlora"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_foreign_optimizer_padding() {
    // a mid-run checkpoint whose fused-Adam buffers were padded for a
    // different runtime must be refused, not silently scattered
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join("switchlora_resume_pad");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let man = Manifest::for_spec(
        &switchlora::coordinator::trainer::default_artifacts_dir(),
        "tiny")
        .unwrap();
    let store = switchlora::model::init::seeded_store(
        &man, Variant::Lora, 0).unwrap();
    let bad_opt = AdamState::new(4, 16); // nothing like the real padding
    let ms = MethodState {
        name: "lora".into(),
        version: 1,
        payload: Vec::new(),
    };
    let ts = TrainerState {
        next_step: 2,
        rng: RngState { s: [1, 2, 3, 4], spare_normal: None },
        ema_value: 0.0,
        ema_primed: false,
        comm_bytes: 0,
        comm_rounds: 0,
    };
    let path: PathBuf = dir.join("bad.ckpt");
    checkpoint::save_full(&path, "tiny", &store, Some(&bad_opt),
                          Some(&ms), Some(&ts))
        .unwrap();
    let mut cfg = TrainConfig::new("tiny", Method::lora(), 4);
    cfg.resume = Some(path);
    let err = Trainer::new(cfg)
        .unwrap()
        .run(&mut engine)
        .unwrap_err()
        .to_string();
    assert!(err.contains("padd"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn weights_only_checkpoint_resumes_as_warm_init() {
    // resuming from a plain (v2, sections-absent) weights checkpoint
    // starts at step 0 with a fresh optimizer — a warm initialization,
    // not a mid-run continuation
    let mut engine = Engine::cpu().unwrap();
    let dir = std::env::temp_dir().join("switchlora_resume_weights");
    std::fs::remove_dir_all(&dir).ok();
    let mut cfg = TrainConfig::new("tiny", Method::lora(), 6);
    cfg.eval_every = 6;
    cfg.eval_batches = 2;
    cfg.warmup = 2;
    let (_, store) = run(&mut engine, cfg.clone());
    let path = dir.join("weights.ckpt");
    checkpoint::save(&path, "tiny", &store, None).unwrap();
    let mut rcfg = cfg.clone();
    rcfg.resume = Some(path);
    let (res, _) = run(&mut engine, rcfg);
    assert_eq!(res.train_curve.len(), 6); // full run, from step 0
    assert!(res.final_eval_loss.is_finite());
    std::fs::remove_dir_all(&dir).ok();
}
