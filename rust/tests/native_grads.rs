//! Gradient verification of the native backend.
//!
//! Every analytic backward is checked against central-difference numerical
//! gradients (f32, eps=1e-2 — tolerances follow from f32 loss precision):
//! per-op property tests for the LoRA linear, RMSNorm, the causal
//! attention path and softmax cross-entropy, then a whole-model check of
//! `fwdbwd` for all three variants, plus bitwise-determinism tests.

use switchlora::model::config::ModelConfig;
use switchlora::model::init::{init_store, InitMode};
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::runtime::native::{causal_attention_bwd,
                                  causal_attention_fwd, lora_linear_bwd,
                                  lora_linear_fwd, rms_norm_bwd,
                                  rms_norm_fwd, rope_bwd, rope_fwd,
                                  softmax_xent, NativeModel};
use switchlora::runtime::StepRuntime;
use switchlora::util::prop::prop_check;
use switchlora::util::rng::Rng;

const EPS: f32 = 1e-2;

fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect()
}

fn dot(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Central-difference dL/dx_i where `f` maps the full buffer to a scalar.
fn num_grad(f: &mut dyn FnMut(&[f32]) -> f32, x: &[f32], i: usize) -> f32 {
    let mut xp = x.to_vec();
    xp[i] = x[i] + EPS;
    let lp = f(&xp);
    xp[i] = x[i] - EPS;
    let lm = f(&xp);
    (lp - lm) / (2.0 * EPS)
}

fn close(num: f32, ana: f32, what: &str) -> Result<(), String> {
    let tol = 0.05 * (ana.abs() + 0.02);
    if (num - ana).abs() > tol {
        return Err(format!("{what}: numerical {num} vs analytic {ana}"));
    }
    Ok(())
}

#[test]
fn lora_linear_gradients_match_numerical() {
    prop_check("lora linear dx/da/db/dw vs central difference", 10, |rng| {
        let (rows, n_in, m, r) = (1 + rng.below(4), 1 + rng.below(6),
                                  1 + rng.below(6), 1 + rng.below(3));
        let x = randv(rows * n_in, rng);
        let w = randv(m * n_in, rng);
        let a = randv(r * n_in, rng);
        let b = randv(m * r, rng);
        let dy = randv(rows * m, rng);
        let scale = 0.8f32;
        let (_, xa) = lora_linear_fwd(&x, &w, &a, &b, scale, rows, n_in, m,
                                      r);
        let g = lora_linear_bwd(&dy, &x, &xa, &w, &a, &b, scale, rows,
                                n_in, m, r, true);
        let loss_of = |x_: &[f32], w_: &[f32], a_: &[f32], b_: &[f32]| {
            let (y, _) = lora_linear_fwd(x_, w_, a_, b_, scale, rows, n_in,
                                         m, r);
            dot(&y, &dy)
        };
        for i in 0..x.len().min(4) {
            let mut f = |v: &[f32]| loss_of(v, &w, &a, &b);
            close(num_grad(&mut f, &x, i), g.dx[i], "dx")?;
        }
        let dw = g.dw.as_ref().unwrap();
        for i in 0..w.len().min(4) {
            let mut f = |v: &[f32]| loss_of(&x, v, &a, &b);
            close(num_grad(&mut f, &w, i), dw[i], "dw")?;
        }
        let da = g.da.as_ref().unwrap();
        for i in 0..a.len().min(4) {
            let mut f = |v: &[f32]| loss_of(&x, &w, v, &b);
            close(num_grad(&mut f, &a, i), da[i], "da")?;
        }
        let db = g.db.as_ref().unwrap();
        for i in 0..b.len().min(4) {
            let mut f = |v: &[f32]| loss_of(&x, &w, &a, v);
            close(num_grad(&mut f, &b, i), db[i], "db")?;
        }
        Ok(())
    });
}

#[test]
fn rms_norm_gradients_match_numerical() {
    prop_check("rms norm dx/dg vs central difference", 10, |rng| {
        let (rows, h) = (1 + rng.below(4), 2 + rng.below(8));
        let x = randv(rows * h, rng);
        let g = randv(h, rng);
        let dy = randv(rows * h, rng);
        let (_, inv) = rms_norm_fwd(&x, &g, rows, h);
        let (dx, dg) = rms_norm_bwd(&dy, &x, &inv, &g, rows, h);
        let loss_of = |x_: &[f32], g_: &[f32]| {
            let (y, _) = rms_norm_fwd(x_, g_, rows, h);
            dot(&y, &dy)
        };
        for i in 0..x.len().min(6) {
            let mut f = |v: &[f32]| loss_of(v, &g);
            close(num_grad(&mut f, &x, i), dx[i], "dx")?;
        }
        for i in 0..h.min(6) {
            let mut f = |v: &[f32]| loss_of(&x, v);
            close(num_grad(&mut f, &g, i), dg[i], "dg")?;
        }
        Ok(())
    });
}

#[test]
fn attention_path_gradients_match_numerical() {
    // The full attention path including RoPE: perturb the *pre-rotation*
    // q/k (as the model does), rotate, attend, dot with a cotangent.
    prop_check("rope+attention dq/dk/dv vs central difference", 8, |rng| {
        let (bh, t) = (1 + rng.below(2), 2 + rng.below(3));
        let hd = 4;
        let q0 = randv(bh * t * hd, rng);
        let k0 = randv(bh * t * hd, rng);
        let v = randv(bh * t * hd, rng);
        let dy = randv(bh * t * hd, rng);
        let rot = |x: &[f32]| {
            let mut r = x.to_vec();
            rope_fwd(&mut r, bh, t, hd);
            r
        };
        let (q, k) = (rot(&q0), rot(&k0));
        let (_, att) = causal_attention_fwd(&q, &k, &v, bh, t, hd);
        let (mut dq, mut dk, dv) =
            causal_attention_bwd(&dy, &q, &k, &v, &att, bh, t, hd);
        rope_bwd(&mut dq, bh, t, hd);
        rope_bwd(&mut dk, bh, t, hd);
        let loss_of = |q_: &[f32], k_: &[f32], v_: &[f32]| {
            let (o, _) =
                causal_attention_fwd(&rot(q_), &rot(k_), v_, bh, t, hd);
            dot(&o, &dy)
        };
        for i in 0..(bh * t * hd).min(6) {
            let mut f = |x: &[f32]| loss_of(x, &k0, &v);
            close(num_grad(&mut f, &q0, i), dq[i], "dq")?;
            let mut f = |x: &[f32]| loss_of(&q0, x, &v);
            close(num_grad(&mut f, &k0, i), dk[i], "dk")?;
            let mut f = |x: &[f32]| loss_of(&q0, &k0, x);
            close(num_grad(&mut f, &v, i), dv[i], "dv")?;
        }
        Ok(())
    });
}

#[test]
fn cross_entropy_gradients_match_numerical() {
    prop_check("softmax xent dlogits vs central difference", 10, |rng| {
        let (rows, v) = (1 + rng.below(4), 2 + rng.below(10));
        let logits = randv(rows * v, rng);
        let targets: Vec<i32> =
            (0..rows).map(|_| rng.below(v) as i32).collect();
        let (_, dl, _) = softmax_xent(&logits, &targets, rows, v);
        for i in 0..logits.len().min(8) {
            let mut f = |x: &[f32]| softmax_xent(x, &targets, rows, v).0;
            close(num_grad(&mut f, &logits, i), dl[i], "dlogits")?;
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Whole-model checks on a synthesized micro config.
// ---------------------------------------------------------------------

fn micro_config() -> ModelConfig {
    ModelConfig {
        name: "micro".into(),
        vocab: 24,
        hidden: 8,
        layers: 2,
        heads: 2,
        ff: 12,
        seq: 6,
        rank: 2,
        lora_alpha: 2.0,
        batch: 2,
        n_cls: 4,
    }
}

fn micro_store(man: &Manifest, variant: Variant, seed: u64) -> ParamStore {
    let layout =
        std::sync::Arc::new(man.layout(variant).unwrap().clone());
    let mut store = ParamStore::zeros(layout);
    let mut rng = Rng::new(seed);
    init_store(&mut store, &man.linears, man.config.rank,
               InitMode::SwitchLora, &mut rng);
    store
}

fn check_model_grads(variant: Variant) {
    let man = Manifest::synthesize(micro_config());
    let model = NativeModel::new(man.clone(), variant).unwrap();
    let store = micro_store(&man, variant, 7);
    let mc = &man.config;
    let mut rng = Rng::new(13);
    let cls = variant == Variant::Cls;
    let tokens: Vec<i32> = (0..mc.batch * (mc.seq + usize::from(!cls)))
        .map(|_| rng.below(mc.vocab) as i32)
        .collect();
    let labels: Vec<i32> =
        (0..mc.batch).map(|_| rng.below(mc.n_cls) as i32).collect();
    let (_, grads) = if cls {
        model.cls_fwdbwd(&store, &tokens, &labels, mc.batch, mc.seq)
            .unwrap()
    } else {
        model.fwdbwd(&store, &tokens, mc.batch, mc.seq + 1).unwrap()
    };
    let loss_at = |s: &ParamStore| -> f32 {
        if cls {
            model.cls_eval(s, &tokens, &labels, mc.batch, mc.seq)
                .unwrap()
                .0
        } else {
            model.eval_loss(s, &tokens, mc.batch, mc.seq + 1).unwrap()
        }
    };
    let mut perturbed = store.clone();
    let mut checked = 0usize;
    for p in man.layout(variant).unwrap().trainable() {
        let t0 = p.t_offset.unwrap();
        // probe 3 deterministic indices per parameter
        for probe in 0..3usize.min(p.numel) {
            let j = (probe * 97) % p.numel;
            let idx = p.offset + j;
            let orig = store.data[idx];
            perturbed.data[idx] = orig + EPS;
            let lp = loss_at(&perturbed);
            perturbed.data[idx] = orig - EPS;
            let lm = loss_at(&perturbed);
            perturbed.data[idx] = orig;
            let num = (lp - lm) / (2.0 * EPS);
            let ana = grads[t0 + j];
            let tol = 0.08 * (ana.abs() + 1e-3) + 5e-4;
            assert!((num - ana).abs() < tol,
                    "{}[{j}] ({variant:?}): numerical {num} vs analytic \
                     {ana}", p.name);
            checked += 1;
        }
    }
    assert!(checked > 30, "too few probes: {checked}");
}

#[test]
fn model_gradients_match_numerical_lora() {
    check_model_grads(Variant::Lora);
}

#[test]
fn model_gradients_match_numerical_full() {
    check_model_grads(Variant::Full);
}

#[test]
fn model_gradients_match_numerical_cls() {
    check_model_grads(Variant::Cls);
}

// ---------------------------------------------------------------------
// Determinism.
// ---------------------------------------------------------------------

#[test]
fn fwdbwd_is_bitwise_deterministic() {
    let man = Manifest::synthesize(micro_config());
    let model = NativeModel::new(man.clone(), Variant::Lora).unwrap();
    let store = micro_store(&man, Variant::Lora, 3);
    let mc = &man.config;
    let mut rng = Rng::new(1);
    let tokens: Vec<i32> = (0..mc.batch * (mc.seq + 1))
        .map(|_| rng.below(mc.vocab) as i32)
        .collect();
    let (l1, g1) =
        model.fwdbwd(&store, &tokens, mc.batch, mc.seq + 1).unwrap();
    let (l2, g2) =
        model.fwdbwd(&store, &tokens, mc.batch, mc.seq + 1).unwrap();
    assert_eq!(l1.to_bits(), l2.to_bits());
    assert_eq!(g1.len(), g2.len());
    for (a, b) in g1.iter().zip(&g2) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn training_is_bitwise_deterministic_from_seed() {
    use switchlora::coordinator::trainer::{Method, TrainConfig, Trainer};
    use switchlora::runtime::Engine;
    let run = || {
        let mut cfg = TrainConfig::new(
            "tiny", Method::parse("switchlora").unwrap(), 6);
        cfg.eval_every = 6;
        cfg.eval_batches = 1;
        cfg.warmup = 2;
        cfg.seed = 77;
        let mut engine = Engine::native();
        let (res, store) =
            Trainer::new(cfg).unwrap().run(&mut engine).unwrap();
        (res, store)
    };
    let (r1, s1) = run();
    let (r2, s2) = run();
    assert_eq!(r1.train_curve.len(), r2.train_curve.len());
    for ((_, a), (_, b)) in r1.train_curve.iter().zip(&r2.train_curve) {
        assert_eq!(a.to_bits(), b.to_bits(), "train curve diverged");
    }
    assert_eq!(r1.final_eval_loss.to_bits(), r2.final_eval_loss.to_bits());
    for (a, b) in s1.data.iter().zip(&s2.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "final params diverged");
    }
}
