//! Inference benchmarks (`harness = false`): prefill throughput, and the
//! headline table — KV-cached decode vs uncached full re-forward per
//! generated token.  The cached path is O(T) per token where the
//! uncached path is O(T²), so the gap must widen as context grows; the
//! acceptance check in ISSUE 2 reads off exactly that.  A second table
//! measures the adapter-merge claim: merged dense decode vs unmerged
//! LoRA decode at the same context.

use std::time::Instant;

use switchlora::coordinator::trainer::default_artifacts_dir;
use switchlora::infer::merged_full_store;
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::runtime::{InferRuntime, NativeModel};
use switchlora::util::rng::Rng;

fn lora_setup(spec: &str) -> Option<(Manifest, ParamStore, NativeModel)> {
    let man = Manifest::for_spec(&default_artifacts_dir(), spec).ok()?;
    let store = seeded_store(&man, Variant::Lora, 0).ok()?;
    let model = NativeModel::new(man.clone(), Variant::Lora).ok()?;
    Some((man, store, model))
}

fn prompt(vocab: usize, len: usize) -> Vec<i32> {
    let mut rng = Rng::new(9);
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

/// ms per generated token with the KV cache: prefill once, then time
/// `n_new` decode steps.
fn cached_ms_per_tok(model: &NativeModel, store: &ParamStore,
                     ctx: &[i32], n_new: usize) -> f64 {
    let mut cache = model.new_cache(1, ctx.len() + n_new + 1);
    let logits = model.prefill(store, &mut cache, 0, ctx).unwrap();
    let mut tok = switchlora::infer::argmax(&logits) as i32;
    let t0 = Instant::now();
    for _ in 0..n_new {
        let logits =
            model.decode(store, &mut cache, &[0], &[tok]).unwrap();
        tok = switchlora::infer::argmax(&logits) as i32;
    }
    1e3 * t0.elapsed().as_secs_f64() / n_new as f64
}

/// ms per generated token without cache reuse: every new token re-runs
/// the whole (growing) context through a fresh throwaway cache — the
/// same inference kernels as the cached path, none of the reuse, so the
/// table isolates exactly what the KV cache buys.
fn uncached_ms_per_tok(model: &NativeModel, store: &ParamStore,
                       ctx: &[i32], n_new: usize) -> f64 {
    let mut toks = ctx.to_vec();
    let t0 = Instant::now();
    for _ in 0..n_new {
        let mut cache = model.new_cache(1, toks.len());
        let logits =
            model.prefill(store, &mut cache, 0, &toks).unwrap();
        let next = switchlora::infer::argmax(&logits) as i32;
        toks.push(next);
    }
    1e3 * t0.elapsed().as_secs_f64() / n_new as f64
}

fn bench_cached_vs_uncached(spec: &str) {
    let Some((man, store, model)) = lora_setup(spec) else {
        println!("({spec} spec unavailable)");
        return;
    };
    let vocab = man.config.vocab;
    println!("\n-- {spec}: decode ms/token, cached vs full re-forward --");
    println!("{:>8} {:>14} {:>14} {:>10}", "context", "uncached",
             "kv-cached", "speedup");
    let n_new = 8;
    for ctx_len in [16usize, 32, 64, 128] {
        let ctx = prompt(vocab, ctx_len);
        let cached = cached_ms_per_tok(&model, &store, &ctx, n_new);
        let uncached = uncached_ms_per_tok(&model, &store, &ctx, n_new);
        println!("{:>8} {:>12.3}ms {:>12.3}ms {:>9.1}x", ctx_len,
                 uncached, cached, uncached / cached.max(1e-9));
    }
}

fn bench_prefill(spec: &str) {
    let Some((man, store, model)) = lora_setup(spec) else { return };
    let vocab = man.config.vocab;
    println!("\n-- {spec}: prefill throughput --");
    for len in [32usize, 128] {
        let ctx = prompt(vocab, len);
        let mut cache = model.new_cache(1, len + 1);
        let t0 = Instant::now();
        model.prefill(&store, &mut cache, 0, &ctx).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!("   prefill {len:>4} tokens: {:>8.2}ms  \
                  ({:>7.0} tok/s)", 1e3 * dt, len as f64 / dt.max(1e-9));
    }
}

fn bench_merge_overhead(spec: &str) {
    let Some((man, store, model)) = lora_setup(spec) else { return };
    let vocab = man.config.vocab;
    let merged = merged_full_store(&man, &store).unwrap();
    let dense = NativeModel::new(man.clone(), Variant::Full).unwrap();
    println!("\n-- {spec}: adapter overhead at decode (merge claim) --");
    let ctx = prompt(vocab, 64);
    let n_new = 16;
    let lora_ms = cached_ms_per_tok(&model, &store, &ctx, n_new);
    let dense_ms = cached_ms_per_tok(&dense, &merged, &ctx, n_new);
    println!("   unmerged LoRA {lora_ms:.3}ms/tok   merged dense \
              {dense_ms:.3}ms/tok   adapter overhead {:.1}%",
             100.0 * (lora_ms - dense_ms) / dense_ms.max(1e-9));
}

fn main() {
    switchlora::util::logging::init();
    for spec in ["tiny", "s1m"] {
        bench_cached_vs_uncached(spec);
        bench_prefill(spec);
        bench_merge_overhead(spec);
    }
    println!("\nbench_infer complete");
}
