//! Inference benchmarks (`harness = false`): prefill throughput, and the
//! headline table — KV-cached decode vs uncached full re-forward per
//! generated token.  The cached path is O(T) per token where the
//! uncached path is O(T²), so the gap must widen as context grows; the
//! acceptance check in ISSUE 2 reads off exactly that.  A second table
//! measures the adapter-merge claim: merged dense decode vs unmerged
//! LoRA decode at the same context; a third measures the
//! `--quantize-base int8` serving claim — resident bytes ~4x down on
//! the frozen base, logits within tolerance, decode speed comparable.
//! A fourth table measures `--kv-dtype`: decode speed, cache bytes, and
//! logit deviation per KV-cache dtype.
//!
//! `--json <path>` writes a machine-readable report (the committed
//! `BENCH_infer.json` holds the current trajectory point), including
//! the flat `tracked` table — decode ms/token per spec at the largest
//! benched context — that `tools/bench_check.py` gates CI on.

use std::path::PathBuf;
use std::time::Instant;

use switchlora::coordinator::trainer::default_artifacts_dir;
use switchlora::infer::merged_full_store;
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::model::packed::{PackedStore, ParamSource};
use switchlora::runtime::{InferRuntime, NativeModel};
use switchlora::tensor::dtype::{DType, PrecisionPolicy};
use switchlora::util::json::Json;
use switchlora::util::rng::Rng;

fn lora_setup(spec: &str) -> Option<(Manifest, ParamStore, NativeModel)> {
    let man = Manifest::for_spec(&default_artifacts_dir(), spec).ok()?;
    let store = seeded_store(&man, Variant::Lora, 0).ok()?;
    let model = NativeModel::new(man.clone(), Variant::Lora).ok()?;
    Some((man, store, model))
}

fn prompt(vocab: usize, len: usize) -> Vec<i32> {
    let mut rng = Rng::new(9);
    (0..len).map(|_| rng.below(vocab) as i32).collect()
}

/// ms per generated token with the KV cache: prefill once, then time
/// `n_new` decode steps.
fn cached_ms_per_tok(model: &NativeModel, store: &dyn ParamSource,
                     ctx: &[i32], n_new: usize) -> f64 {
    let mut cache = model.new_cache(1, ctx.len() + n_new + 1);
    let logits = model.prefill(store, &mut cache, 0, ctx).unwrap();
    let mut tok = switchlora::infer::argmax(&logits) as i32;
    let t0 = Instant::now();
    for _ in 0..n_new {
        let logits =
            model.decode(store, &mut cache, &[0], &[tok]).unwrap();
        tok = switchlora::infer::argmax(&logits) as i32;
    }
    1e3 * t0.elapsed().as_secs_f64() / n_new as f64
}

/// ms per generated token without cache reuse: every new token re-runs
/// the whole (growing) context through a fresh throwaway cache — the
/// same inference kernels as the cached path, none of the reuse, so the
/// table isolates exactly what the KV cache buys.
fn uncached_ms_per_tok(model: &NativeModel, store: &dyn ParamSource,
                       ctx: &[i32], n_new: usize) -> f64 {
    let mut toks = ctx.to_vec();
    let t0 = Instant::now();
    for _ in 0..n_new {
        let mut cache = model.new_cache(1, toks.len());
        let logits =
            model.prefill(store, &mut cache, 0, &toks).unwrap();
        let next = switchlora::infer::argmax(&logits) as i32;
        toks.push(next);
    }
    1e3 * t0.elapsed().as_secs_f64() / n_new as f64
}

/// Returns the cached decode ms/token at the largest benched context —
/// the headline number the `tracked` trajectory table carries.
fn bench_cached_vs_uncached(spec: &str) -> Option<f64> {
    let Some((man, store, model)) = lora_setup(spec) else {
        println!("({spec} spec unavailable)");
        return None;
    };
    let vocab = man.config.vocab;
    println!("\n-- {spec}: decode ms/token, cached vs full re-forward --");
    println!("{:>8} {:>14} {:>14} {:>10}", "context", "uncached",
             "kv-cached", "speedup");
    let n_new = 8;
    let mut last_cached = None;
    for ctx_len in [16usize, 32, 64, 128] {
        let ctx = prompt(vocab, ctx_len);
        let cached = cached_ms_per_tok(&model, &store, &ctx, n_new);
        let uncached = uncached_ms_per_tok(&model, &store, &ctx, n_new);
        println!("{:>8} {:>12.3}ms {:>12.3}ms {:>9.1}x", ctx_len,
                 uncached, cached, uncached / cached.max(1e-9));
        last_cached = Some(cached);
    }
    last_cached
}

fn bench_prefill(spec: &str) {
    let Some((man, store, model)) = lora_setup(spec) else { return };
    let vocab = man.config.vocab;
    println!("\n-- {spec}: prefill throughput --");
    for len in [32usize, 128] {
        let ctx = prompt(vocab, len);
        let mut cache = model.new_cache(1, len + 1);
        let t0 = Instant::now();
        model.prefill(&store, &mut cache, 0, &ctx).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!("   prefill {len:>4} tokens: {:>8.2}ms  \
                  ({:>7.0} tok/s)", 1e3 * dt, len as f64 / dt.max(1e-9));
    }
}

fn bench_merge_overhead(spec: &str) {
    let Some((man, store, model)) = lora_setup(spec) else { return };
    let vocab = man.config.vocab;
    let merged = merged_full_store(&man, &store).unwrap();
    let dense = NativeModel::new(man.clone(), Variant::Full).unwrap();
    println!("\n-- {spec}: adapter overhead at decode (merge claim) --");
    let ctx = prompt(vocab, 64);
    let n_new = 16;
    let lora_ms = cached_ms_per_tok(&model, &store, &ctx, n_new);
    let dense_ms = cached_ms_per_tok(&dense, &merged, &ctx, n_new);
    println!("   unmerged LoRA {lora_ms:.3}ms/tok   merged dense \
              {dense_ms:.3}ms/tok   adapter overhead {:.1}%",
             100.0 * (lora_ms - dense_ms) / dense_ms.max(1e-9));
}

/// The int8 frozen-base serving table: merged dense f32 vs int8-packed
/// base — resident bytes, decode speed, and worst-case logit deviation.
/// Returns the JSON rows for the `--json` report.
fn bench_quantized_base(spec: &str) -> Vec<Json> {
    let Some((man, store, _)) = lora_setup(spec) else {
        return Vec::new();
    };
    let vocab = man.config.vocab;
    let merged = merged_full_store(&man, &store).unwrap();
    let dense = NativeModel::new(man.clone(), Variant::Full).unwrap();
    println!("\n-- {spec}: int8 frozen base (QLoRA-style serving) --");
    let ctx = prompt(vocab, 48);
    let n_new = 16;
    let mut rows = Vec::new();
    let f32_ms = cached_ms_per_tok(&dense, &merged, &ctx, n_new);
    let f32_bytes = 4 * merged.layout.total;
    for dtype in [DType::Bf16, DType::I8] {
        let Ok(packed) = PackedStore::quantize_base(&merged, dtype)
        else { continue };
        let (bp, bf) = packed.base_bytes();
        let q_ms = cached_ms_per_tok(&dense, &packed, &ctx, n_new);
        // worst-case logit deviation vs the f32 reference at the last
        // prompt position
        let mut c1 = dense.new_cache(1, ctx.len() + 1);
        let l_ref = dense.prefill(&merged, &mut c1, 0, &ctx).unwrap();
        let mut c2 = dense.new_cache(1, ctx.len() + 1);
        let l_q = dense.prefill(&packed, &mut c2, 0, &ctx).unwrap();
        let max_abs = l_ref.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        let max_diff = l_ref
            .iter()
            .zip(&l_q)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        println!("   {:<5} base {:>9}B (f32 {:>9}B, {:.2}x)  \
                  {q_ms:.3}ms/tok (f32 {f32_ms:.3})  max|Δlogit| \
                  {max_diff:.4} (|logit|max {max_abs:.2})",
                 dtype.name(), bp, bf, bf as f64 / bp.max(1) as f64);
        rows.push(Json::obj(vec![
            ("spec", Json::str(spec)),
            ("frozen_base", Json::str(dtype.name())),
            ("base_bytes", Json::num(bp as f64)),
            ("base_bytes_f32", Json::num(bf as f64)),
            ("total_bytes", Json::num(packed.resident_bytes() as f64)),
            ("total_bytes_f32", Json::num(f32_bytes as f64)),
            ("ms_per_tok", Json::num(q_ms)),
            ("ms_per_tok_f32", Json::num(f32_ms)),
            ("max_logit_diff", Json::num(max_diff as f64)),
            ("max_logit_abs", Json::num(max_abs as f64)),
        ]));
    }
    rows
}

/// The `--kv-dtype` table: decode speed, resident cache bytes, and
/// worst-case prefill-logit deviation per KV-cache dtype (f32 is the
/// reference row).
fn bench_kv_dtypes(spec: &str) -> Vec<Json> {
    let Some((man, store, _)) = lora_setup(spec) else {
        return Vec::new();
    };
    let vocab = man.config.vocab;
    let ctx = prompt(vocab, 64);
    let n_new = 16;
    println!("\n-- {spec}: KV-cache dtype (--kv-dtype) --");
    let mut rows = Vec::new();
    let mut l_ref: Vec<f32> = Vec::new();
    for dtype in [DType::F32, DType::Bf16, DType::I8] {
        let policy = PrecisionPolicy {
            kv_cache: dtype,
            ..PrecisionPolicy::default()
        };
        let Ok(model) =
            NativeModel::with_policy(man.clone(), Variant::Lora, policy)
        else { continue };
        let ms = cached_ms_per_tok(&model, &store, &ctx, n_new);
        let mut cache = model.new_cache(1, ctx.len() + 1);
        let logits =
            model.prefill(&store, &mut cache, 0, &ctx).unwrap();
        let bytes = cache.bytes();
        if dtype == DType::F32 {
            l_ref = logits.clone();
        }
        let max_diff = l_ref
            .iter()
            .zip(&logits)
            .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
        println!("   {:<5} {ms:.3}ms/tok  cache {:>8}B  max|Δlogit| \
                  {max_diff:.4}", dtype.name(), bytes);
        rows.push(Json::obj(vec![
            ("spec", Json::str(spec)),
            ("kv_dtype", Json::str(dtype.name())),
            ("ms_per_tok", Json::num(ms)),
            ("cache_bytes", Json::num(bytes as f64)),
            ("max_logit_diff", Json::num(max_diff as f64)),
        ]));
    }
    rows
}

fn main() {
    switchlora::util::logging::init();
    let args = switchlora::cli::Args::parse(std::env::args().skip(1));
    let json_path = args.get("json").map(PathBuf::from);
    if json_path.is_some() {
        switchlora::bench::record_results();
    }
    let mut quant_rows = Vec::new();
    let mut kv_rows = Vec::new();
    let mut tracked = Vec::new();
    for spec in ["tiny", "s1m"] {
        if let Some(ms) = bench_cached_vs_uncached(spec) {
            // leak is fine: a handful of static-lifetime key strings
            let key: &'static str =
                Box::leak(format!("decode_{spec}_ms_per_tok")
                    .into_boxed_str());
            tracked.push((key, Json::num(ms)));
        }
        bench_prefill(spec);
        bench_merge_overhead(spec);
        quant_rows.extend(bench_quantized_base(spec));
        kv_rows.extend(bench_kv_dtypes(spec));
    }
    if let Some(path) = json_path {
        switchlora::bench::write_json(&path, "bench_infer", vec![
            ("tracked", Json::obj(tracked)),
            ("quantized_base", Json::Arr(quant_rows)),
            ("kv_cache", Json::Arr(kv_rows)),
        ])
        .expect("writing bench json");
        println!("json report: {}", path.display());
    }
    println!("\nbench_infer complete");
}
