//! `cargo bench` target regenerating the paper's **system tables**:
//!
//! * Table 4 — trainable parameters (analytic, paper-exact architectures).
//! * Table 5 — per-GPU memory model + **measured** step time / offload
//!   traffic at testbed scale (full vs LoRA vs SwitchLoRA must be ≈equal
//!   for the two LoRA variants, the paper's "nearly identical training
//!   time" claim).
//! * Appendix D — offloaded bytes/step: closed-form vs ledger-measured.
//! * Appendix F — data-parallel traffic: closed-form vs ring-measured.
//!
//! Harness-free (`harness = false`); statistical timing via `bench::*`.

use switchlora::bench::bench_budget;
use switchlora::coordinator::trainer::{default_artifacts_dir, Method,
                                       TrainConfig, Trainer};
use switchlora::model::analytics as an;
use switchlora::model::config::ModelConfig;
use switchlora::runtime::Engine;
use switchlora::util::{human_bytes, human_params};

fn table4() {
    println!("\n===== Table 4: trainable parameters (paper configs) =====");
    println!("{:<8} {:>12} {:>14} {:>14} {:>12}", "model", "full",
             "lora r=h/8", "lora r=h/4", "paper full");
    let paper_full = [("p130m", "134M"), ("p250m", "247.5M"),
                      ("p350m", "368.2M"), ("p1b", "1339.5M"),
                      ("p3b", "2686M"), ("p7b", "6739M")];
    for c in ModelConfig::paper_presets() {
        let full = an::full_params(&c);
        let want = paper_full.iter().find(|(n, _)| *n == c.name)
            .map(|(_, w)| *w).unwrap_or("-");
        println!("{:<8} {:>12} {:>14} {:>14} {:>12}", c.name,
                 human_params(full),
                 human_params(an::lora_trainable_params(
                     &c, (c.hidden / 8) as u64)),
                 human_params(an::lora_trainable_params(
                     &c, (c.hidden / 4) as u64)),
                 want);
    }
}

fn table5_analytic() {
    println!("\n===== Table 5: memory model (paper configs, 4 GPUs) =====");
    println!("{:<8} {:>4} {:<11} {:>12} {:>10} {:>10}", "model", "bs",
             "method", "trainable", "mem(model)", "mem(paper)");
    let paper = [("p1b", 16u64, 36.1, 31.9), ("p3b", 4, 37.4, 27.1),
                 ("p7b", 1, 78.0, 47.3)];
    for (name, bs, want_full, want_lora) in paper {
        let c = ModelConfig::paper_preset(name).unwrap();
        let r = (c.hidden / 4) as u64;
        for (meth, tr, want) in [
            ("full", an::full_params(&c), want_full),
            ("switchlora", an::lora_trainable_params(&c, r), want_lora),
        ] {
            let mem = an::memory_model(&c, tr, bs, 4).total();
            println!("{:<8} {:>4} {:<11} {:>12} {:>10} {:>9.1}G", name, bs,
                     meth, human_params(tr), human_bytes(mem), want);
        }
    }
    println!("(model calibrated on the full-rank 1.3B row only; all other \
              cells are predictions)");
}

fn table5_measured(engine: &mut Engine) {
    println!("\n===== Table 5 (measured at testbed scale): step time =====");
    let spec = "s1m";
    println!("{:<12} {:>10} {:>12} {:>14}", "method", "step_ms",
             "trainable", "offload/step");
    for m in [Method::full(), Method::lora(),
              Method::parse("switchlora").unwrap()] {
        let mut cfg = TrainConfig::new(spec, m, 30);
        cfg.eval_every = 30;
        cfg.eval_batches = 1;
        let (res, _) = Trainer::new(cfg).unwrap().run(engine).unwrap();
        println!("{:<12} {:>10.1} {:>12} {:>14}", res.method,
                 res.mean_step_ms,
                 human_params(res.n_trainable as u64),
                 human_bytes((res.counter("offload_bytes") as f64 / 30.0)
                             as u64));
    }
    println!("(claim under test: lora ≈ switchlora step time; full-rank \
              pays the larger optimizer+comm)");
}

fn appendix_d(engine: &mut Engine) {
    println!("\n===== Appendix D: offload traffic, formula vs measured \
              =====");
    // formula at paper scale
    let c = ModelConfig::paper_preset("p1b").unwrap();
    let f = an::offload_bytes_per_step(&c, 512, 1.0 / 40.0);
    println!("paper scale: 1.3B r=512 freq 1/40 → {} /step \
              (paper ≈ 16.25MB)", human_bytes(f));
    // measured at testbed scale
    let spec = "tiny";
    {
        let mut cfg = TrainConfig::new(
            spec, Method::parse("switchlora").unwrap(), 40);
        cfg.eval_every = 40;
        cfg.eval_batches = 1;
        let (res, _) = Trainer::new(cfg).unwrap().run(engine).unwrap();
        let man = switchlora::model::layout::Manifest::for_spec(
            &default_artifacts_dir(), spec).unwrap();
        let mc = &man.config;
        // Appendix D formula applied to this config, summed over the decay
        // schedule ≈ freq(avg) * r/h * params * 2B * 2 (both pools swap)
        let measured = res.counter("offload_bytes") as f64 / 40.0;
        let freq0 = 1.0 / 40.0;
        let formula = 2.0 * freq0 * (mc.rank as f64 / mc.hidden as f64)
            * an::full_params(mc) as f64 * 2.0;
        println!("testbed ({spec}): measured {}/step vs formula {}/step \
                  at initial frequency", human_bytes(measured as u64),
                 human_bytes(formula as u64));
    }
}

fn appendix_f() {
    println!("\n===== Appendix F: DP communication =====");
    let c = ModelConfig::paper_preset("p1b").unwrap();
    println!("1.3B r=512: full {}/step vs switchlora {}/step per worker \
              (8 workers) → saving {:.1}% (paper: 54%)",
             human_bytes(an::dp_comm_bytes_per_step(an::full_params(&c),
                                                    8)),
             human_bytes(an::dp_comm_bytes_per_step(
                 an::lora_trainable_params(&c, 512), 8)),
             100.0 * an::comm_saving_fraction(&c, 512));
    // measured ring volume matches the closed form, at both wire dtypes
    use switchlora::coordinator::data_parallel::{expected_ring_bytes,
                                                 ring_all_reduce,
                                                 CommLedger};
    use switchlora::tensor::dtype::DType;
    let n = 100_000;
    for w in [2usize, 4, 8] {
        for wire in [DType::F32, DType::Bf16] {
            let mut grads: Vec<Vec<f32>> =
                (0..w).map(|i| vec![i as f32; n]).collect();
            let mut ledger = CommLedger::default();
            let moved = ring_all_reduce(&mut grads, &mut ledger, wire);
            let want = expected_ring_bytes(n, w, wire);
            println!("ring w={w} {}: measured {} vs closed-form {} ({})",
                     wire, human_bytes(moved), human_bytes(want),
                     if moved == want { "exact" } else { "MISMATCH" });
        }
    }
}

fn marshal_bench(engine: &mut Engine) {
    println!("\n===== coordinator overhead (L3 perf target) =====");
    let spec = "tiny";
    let man = switchlora::model::layout::Manifest::for_spec(
        &default_artifacts_dir(), spec).unwrap();
    let layout = std::sync::Arc::new(man.lora.clone());
    let mut store = switchlora::model::layout::ParamStore::zeros(layout);
    let mut rng = switchlora::util::rng::Rng::new(0);
    switchlora::model::init::init_store(
        &mut store, &man.linears, man.config.rank,
        switchlora::model::init::InitMode::SwitchLora, &mut rng);
    let rt = switchlora::runtime::ModelRuntime::load(
        engine, man.clone(), switchlora::model::layout::Variant::Lora)
        .unwrap();
    let mc = man.config.clone();
    let mut it = switchlora::data::dataset::synth_batches(
        mc.vocab, 1, 0, mc.batch, mc.seq);
    let b = it.next_batch();
    let r = bench_budget("fwdbwd executable (tiny)", 1500.0, || {
        rt.fwdbwd(&store, &b.tokens, b.batch, b.seq_plus_1).unwrap();
    });
    println!("{}", r.row());
    let padded = rt.padded;
    let flat = store.gather_trainable(padded);
    let r2 = bench_budget("gather+scatter trainable (tiny)", 300.0, || {
        let f = store.gather_trainable(padded);
        std::hint::black_box(&f);
    });
    println!("{}", r2.row());
    let _ = flat;
}

fn main() {
    switchlora::util::logging::init();
    let mut engine = Engine::cpu().expect("engine");
    table4();
    table5_analytic();
    table5_measured(&mut engine);
    appendix_d(&mut engine);
    appendix_f();
    marshal_bench(&mut engine);
    println!("\nbench_tables complete");
}
