//! Micro-benchmarks of the coordinator hot paths (`harness = false`):
//! switch op, freeze-mask application, ring all-reduce, host vs fused-HLO
//! Adam, SVD (the GaLore per-refresh cost), literal marshaling, the
//! kernel pool's thread-scaling table (1/2/4/8 threads ×
//! matmul/attention/full training step), and the precision layer
//! (packed-RHS matmuls; memory/comm tables per dtype).
//!
//! `--json <path>` writes a machine-readable report (the committed
//! `BENCH_kernels.json` holds the current trajectory point), including
//! the flat `tracked` table — matmul GF/s and kernel latencies — that
//! `tools/bench_check.py` gates CI on.
//!
//! These are the L3 profile the §Perf iteration worked from.

use std::path::PathBuf;

use switchlora::bench::{bench, bench_budget};
use switchlora::coordinator::data_parallel::{expected_ring_bytes,
                                             ring_all_reduce, CommLedger};
use switchlora::coordinator::trainer::default_artifacts_dir;
use switchlora::kernels;
use switchlora::model::init::{init_store, InitMode};
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::model::packed::PackedStore;
use switchlora::optim::adam::{host_step, AdamState};
use switchlora::optim::AdamHyper;
use switchlora::runtime::{Engine, ModelRuntime};
use switchlora::switchlora::schedule::SwitchSchedule;
use switchlora::switchlora::switcher::SwitchLora;
use switchlora::tensor::dtype::{DType, PackedBuf};
use switchlora::tensor::linalg::svd;
use switchlora::tensor::Tensor;
use switchlora::util::json::Json;
use switchlora::util::rng::Rng;

fn bench_switch_op() {
    println!("\n-- switch op (Algorithm 1) --");
    let Ok(man) = Manifest::for_spec(&default_artifacts_dir(), "s1m")
    else {
        println!("(s1m spec unavailable)");
        return;
    };
    let layout = std::sync::Arc::new(man.lora.clone());
    let mut store = ParamStore::zeros(layout.clone());
    let mut rng = Rng::new(0);
    init_store(&mut store, &man.linears, man.config.rank,
               InitMode::SwitchLora, &mut rng);
    let mut opt = AdamState::new(layout.n_trainable, layout.n_trainable);
    // initial-frequency schedule: every step switches r/40 vectors/matrix
    let mut sl = SwitchLora::new(&man.linears, man.config.rank, 1.0,
                                 SwitchSchedule::new(40.0, 0.0), 5, 1);
    let mut step = 0u64;
    let r = bench("apply_step (s1m, initial freq)", 3, 50, || {
        sl.apply_step(step, &mut store, &mut opt, &man.linears);
        step += 1;
    });
    println!("{}", r.row());
    println!("   switches so far: {} (≈{:.2}/step/matrix at interval 40)",
             sl.total_switches,
             sl.total_switches as f64 / (step as f64 * 2.0
                 * man.linears.len() as f64));
}

fn bench_ring() {
    println!("\n-- ring all-reduce --");
    for (w, n) in [(4usize, 1 << 16), (4, 1 << 20), (8, 1 << 20)] {
        let mut rng = Rng::new(3);
        let grads0: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let mut ledger = CommLedger::default();
        let mut grads = grads0.clone();
        let r = bench(&format!("ring w={w} n={n}"), 1, 10, || {
            grads.clone_from(&grads0);
            ring_all_reduce(&mut grads, &mut ledger, DType::F32);
        });
        let gbps = (ledger.bytes_per_round() / 1e9)
            / (r.mean_ms / 1e3);
        println!("{}   ({gbps:.2} GB/s effective)", r.row());
    }
}

fn bench_adam(engine: &mut Engine) {
    println!("\n-- AdamW: host vs fused HLO kernel --");
    let Ok(man) = Manifest::for_spec(&default_artifacts_dir(), "s1m")
    else { return };
    let Ok(rt) = ModelRuntime::load(engine, man, Variant::Lora) else {
        return;
    };
    let n = rt.padded;
    let mut rng = Rng::new(5);
    let mut p: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mask = vec![1.0f32; n];
    let h = AdamHyper::new(1e-2);
    let mut st = AdamState::new(n, n);
    let r1 = bench(&format!("host adam n={n}"), 2, 30, || {
        host_step(&mut p, &g, &mut st, &mask, &h);
    });
    println!("{}", r1.row());
    let mut st2 = AdamState::new(n, n);
    let mut p2 = p.clone();
    let r2 = bench(&format!("engine adam_step n={n}"), 2, 30, || {
        rt.adam_step(&mut p2, &g, &mut st2, &mask, &h).unwrap();
    });
    println!("{}", r2.row());
}

fn bench_svd() {
    println!("\n-- SVD (GaLore projection refresh cost) --");
    for n in [64usize, 128, 256] {
        let mut rng = Rng::new(7);
        let a = Tensor::randn(n, n, 1.0, &mut rng);
        let r = bench_budget(&format!("jacobi svd {n}x{n}"), 1000.0, || {
            std::hint::black_box(svd(&a));
        });
        println!("{}", r.row());
    }
}

fn bench_exec(engine: &mut Engine) {
    println!("\n-- executable latency per config --");
    for spec in ["tiny", "s1m", "s4m", "s8m"] {
        let Ok(man) = Manifest::for_spec(&default_artifacts_dir(), spec)
        else { continue };
        let layout = std::sync::Arc::new(man.lora.clone());
        let mut store = ParamStore::zeros(layout);
        let mut rng = Rng::new(0);
        init_store(&mut store, &man.linears, man.config.rank,
                   InitMode::SwitchLora, &mut rng);
        let Ok(rt) = ModelRuntime::load(engine, man.clone(), Variant::Lora)
        else { continue };
        let mc = man.config.clone();
        let mut it = switchlora::data::dataset::synth_batches(
            mc.vocab, 1, 0, mc.batch, mc.seq);
        let b = it.next_batch();
        let r = bench_budget(&format!(
            "lora_fwdbwd {spec} (bs{} seq{})", mc.batch, mc.seq), 2500.0,
            || {
                rt.fwdbwd(&store, &b.tokens, b.batch, b.seq_plus_1)
                    .unwrap();
            });
        println!("{}", r.row());
    }
}

/// Thread-scaling table for the shared kernel layer: the same
/// matmul / attention / full-training-step work at 1/2/4/8 pool threads,
/// with speedups versus the single-thread row.  Results are bitwise
/// identical across rows (the determinism suite proves it); only the
/// wall-clock moves.
fn bench_thread_scaling(engine: &mut Engine) {
    println!("\n-- kernel thread scaling (detected parallelism: {}) --",
             kernels::detected_parallelism());
    let prev_threads = kernels::threads();
    let mut rng = Rng::new(11);
    // matmul: an s1m-shaped linear (rows = batch·seq = 4·256, 512x512)
    let (rows, kd, m) = (1024usize, 512usize, 512usize);
    let x: Vec<f32> = (0..rows * kd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let w: Vec<f32> = (0..m * kd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let mut y = vec![0.0f32; rows * m];
    // attention: s1m-shaped heads (b·nh = 4·4, t = 256, hd = 32)
    let (bh, t, hd) = (16usize, 256usize, 32usize);
    let q: Vec<f32> = (0..bh * t * hd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let kk: Vec<f32> = (0..bh * t * hd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let v: Vec<f32> = (0..bh * t * hd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    // full step: one s1m lora fwd+bwd
    let step_setup = Manifest::for_spec(&default_artifacts_dir(), "s1m")
        .ok()
        .and_then(|man| {
            let layout = std::sync::Arc::new(man.lora.clone());
            let mut store = ParamStore::zeros(layout);
            let mut srng = Rng::new(0);
            init_store(&mut store, &man.linears, man.config.rank,
                       InitMode::SwitchLora, &mut srng);
            let mc = man.config.clone();
            let rt = ModelRuntime::load(engine, man, Variant::Lora).ok()?;
            let mut it = switchlora::data::dataset::synth_batches(
                mc.vocab, 1, 0, mc.batch, mc.seq);
            let b = it.next_batch();
            Some((rt, store, b))
        });
    println!("{:<8} {:>12} {:>7} {:>12} {:>7} {:>12} {:>7}", "threads",
             "matmul ms", "x", "attn ms", "x", "step ms", "x");
    let mut base: Option<(f64, f64, f64)> = None;
    for nt in [1usize, 2, 4, 8] {
        kernels::set_threads(nt);
        let rm = bench(&format!("addmm_nt t={nt}"), 2, 15, || {
            y.fill(0.0);
            kernels::addmm_nt(&mut y, &x, &w, rows, kd, m);
        });
        let ra = bench(&format!("attention t={nt}"), 2, 10, || {
            let (o, att) =
                kernels::causal_attention_fwd(&q, &kk, &v, bh, t, hd);
            std::hint::black_box((o, att));
        });
        let rs = match &step_setup {
            Some((rt, store, b)) => {
                bench_budget(&format!("fwdbwd t={nt}"), 1500.0, || {
                    rt.fwdbwd(store, &b.tokens, b.batch, b.seq_plus_1)
                        .unwrap();
                })
                .mean_ms
            }
            None => f64::NAN,
        };
        let b0 = *base.get_or_insert((rm.mean_ms, ra.mean_ms, rs));
        println!("{:<8} {:>12.3} {:>7.2} {:>12.3} {:>7.2} {:>12.3} \
                  {:>7.2}",
                 nt, rm.mean_ms, b0.0 / rm.mean_ms, ra.mean_ms,
                 b0.1 / ra.mean_ms, rs, b0.2 / rs);
    }
    kernels::set_threads(prev_threads);
}

/// Packed-RHS matmul cost per dtype: the dequant-on-load price of
/// serving (or training) with bf16/int8 base weights, at an s1m-shaped
/// linear — plus the `--int8-native` integer-dot path on the same
/// int8-packed weights.
fn bench_packed_matmul() {
    println!("\n-- packed-RHS addmm_nt (s1m linear, 1024x512x512) --");
    let mut rng = Rng::new(13);
    let (rows, kd, m) = (1024usize, 512usize, 512usize);
    let x: Vec<f32> = (0..rows * kd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let w: Vec<f32> = (0..m * kd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let mut y = vec![0.0f32; rows * m];
    kernels::set_int8_native(false);
    for dtype in [DType::F32, DType::Bf16, DType::I8] {
        let packed = PackedBuf::pack(&w, m, kd, dtype);
        let r = bench(&format!("addmm_nt_packed {dtype}"), 2, 15, || {
            y.fill(0.0);
            kernels::addmm_nt_packed(&mut y, &x, packed.view(), rows, kd,
                                     m);
        });
        println!("{}   (resident {} KB)", r.row(),
                 packed.resident_bytes() / 1024);
    }
    let packed = PackedBuf::pack(&w, m, kd, DType::I8);
    kernels::set_int8_native(true);
    let r = bench("addmm_nt_packed i8 (int8-native)", 2, 15, || {
        y.fill(0.0);
        kernels::addmm_nt_packed(&mut y, &x, packed.view(), rows, kd, m);
    });
    kernels::set_int8_native(false);
    println!("{}   (resident {} KB)", r.row(),
             packed.resident_bytes() / 1024);
}

/// The flat `tracked` table of headline metrics for the perf
/// trajectory: `tools/bench_check.py` compares these against the
/// committed baseline and fails CI on a large regression.  Keys ending
/// `_gflops` are higher-is-better, `_ms` lower-is-better.
fn tracked_metrics() -> Json {
    println!("\n-- tracked trajectory metrics --");
    let mut rng = Rng::new(17);
    let (rows, kd, m) = (1024usize, 512usize, 512usize);
    let gflops = |ms: f64| {
        (2.0 * (rows * kd * m) as f64) / (ms / 1e3) / 1e9
    };
    let x: Vec<f32> = (0..rows * kd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let w: Vec<f32> = (0..m * kd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let mut y = vec![0.0f32; rows * m];
    let rf = bench("tracked: addmm_nt f32 1024x512x512", 3, 20, || {
        y.fill(0.0);
        kernels::addmm_nt(&mut y, &x, &w, rows, kd, m);
    });
    let qi8 = PackedBuf::pack(&w, m, kd, DType::I8);
    kernels::set_int8_native(false);
    let rd = bench("tracked: addmm_nt_packed i8 dequant", 3, 20, || {
        y.fill(0.0);
        kernels::addmm_nt_packed(&mut y, &x, qi8.view(), rows, kd, m);
    });
    kernels::set_int8_native(true);
    let rn = bench("tracked: addmm_nt_packed i8 native", 3, 20, || {
        y.fill(0.0);
        kernels::addmm_nt_packed(&mut y, &x, qi8.view(), rows, kd, m);
    });
    kernels::set_int8_native(false);
    let (bh, t, hd) = (16usize, 256usize, 32usize);
    let q: Vec<f32> = (0..bh * t * hd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let kk: Vec<f32> = (0..bh * t * hd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let v: Vec<f32> = (0..bh * t * hd).map(|_| rng.normal_f32(0.0, 0.5))
        .collect();
    let ra = bench("tracked: attention fwd 16x256x32", 2, 10, || {
        let o = kernels::causal_attention_fwd(&q, &kk, &v, bh, t, hd);
        std::hint::black_box(o);
    });
    for r in [&rf, &rd, &rn, &ra] {
        println!("{}", r.row());
    }
    Json::obj(vec![
        ("matmul_f32_gflops", Json::num(gflops(rf.mean_ms))),
        ("matmul_i8_dequant_gflops", Json::num(gflops(rd.mean_ms))),
        ("matmul_i8_native_gflops", Json::num(gflops(rn.mean_ms))),
        ("attention_fwd_ms", Json::num(ra.mean_ms)),
    ])
}

/// Measured resident model bytes per frozen-base dtype (the
/// `--quantize-base` serving claim) for each available spec.
fn precision_memory_table() -> Json {
    let mut rows = Vec::new();
    for spec in ["tiny", "s1m"] {
        let Ok(man) = Manifest::for_spec(&default_artifacts_dir(), spec)
        else { continue };
        let Ok(store) = switchlora::model::init::seeded_store(
            &man, Variant::Lora, 0)
        else { continue };
        for dtype in [DType::F32, DType::Bf16, DType::I8] {
            let Ok(packed) = PackedStore::quantize_base(&store, dtype)
            else { continue };
            let (bp, bf) = packed.base_bytes();
            rows.push(Json::obj(vec![
                ("spec", Json::str(spec)),
                ("frozen_base", Json::str(dtype.name())),
                ("base_bytes", Json::num(bp as f64)),
                ("base_bytes_f32", Json::num(bf as f64)),
                ("total_bytes", Json::num(packed.resident_bytes()
                                          as f64)),
            ]));
        }
    }
    Json::Arr(rows)
}

/// Ring all-reduce bytes per step at each wire dtype (exact, from the
/// implementation's own chunk accounting) for lora vs full trainable
/// vectors.
fn precision_comm_table() -> Json {
    let mut rows = Vec::new();
    for spec in ["tiny", "s1m"] {
        let Ok(man) = Manifest::for_spec(&default_artifacts_dir(), spec)
        else { continue };
        for (variant, padded) in [("lora", man.adam_padded_lora),
                                  ("full", man.adam_padded_full)] {
            for wire in [DType::F32, DType::Bf16] {
                for w in [2usize, 4] {
                    rows.push(Json::obj(vec![
                        ("spec", Json::str(spec)),
                        ("variant", Json::str(variant)),
                        ("wire", Json::str(wire.name())),
                        ("workers", Json::num(w as f64)),
                        ("ring_bytes_per_step",
                         Json::num(expected_ring_bytes(padded, w, wire)
                                   as f64)),
                    ]));
                }
            }
        }
    }
    Json::Arr(rows)
}

fn main() {
    switchlora::util::logging::init();
    let args = switchlora::cli::Args::parse(std::env::args().skip(1));
    let json_path = args.get("json").map(PathBuf::from);
    if json_path.is_some() {
        switchlora::bench::record_results();
    }
    let mut engine = Engine::cpu().expect("engine");
    bench_switch_op();
    bench_ring();
    bench_adam(&mut engine);
    bench_svd();
    bench_exec(&mut engine);
    bench_thread_scaling(&mut engine);
    bench_packed_matmul();
    let tracked = tracked_metrics();
    if let Some(path) = json_path {
        switchlora::bench::write_json(&path, "bench_micro", vec![
            ("tracked", tracked),
            ("precision_memory", precision_memory_table()),
            ("precision_comm", precision_comm_table()),
        ])
        .expect("writing bench json");
        println!("json report: {}", path.display());
    }
    println!("\nbench_micro complete");
}
