//! Serving fast-path benchmarks (`harness = false`): a real in-process
//! HTTP server on a loopback socket, measured from the client side.
//!
//! * request throughput on ONE kept-alive connection vs a fresh
//!   connect-per-request (`Connection: close`) — the keep-alive claim;
//! * streamed-generation TTFT (request write → first token line) and
//!   inter-token latency, through chunked prefill and the continuous
//!   batcher;
//! * paged-KV residency: pool bytes vs the retired dense slab across
//!   live-token counts — bytes scale with tokens, not with
//!   `--max-batch × --max-context`;
//! * prefix-cache TTFT: the same long prompt sent cold and then warm —
//!   the warm request splices the sealed prefix blocks and prefills
//!   only the uncached suffix, with `/healthz` counters verifying the
//!   exact token savings.
//!
//! `--json <path>` writes the `switchlora-bench-v2` report; the
//! committed `BENCH_serve.json` holds the current trajectory point and
//! `tools/bench_check.py` gates CI on the flat `tracked` table
//! (`_req_s` higher-is-better, `_ms` / `_ms_per_tok` / `_us`
//! lower-is-better).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::Instant;

use switchlora::infer::kv_cache::KvCache;
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, Variant};
use switchlora::runtime::{InferRuntime, NativeModel};
use switchlora::serve::{AdapterRegistry, BaseSource, ServeConfig,
                        Server};
use switchlora::tensor::dtype::DType;
use switchlora::util::json::Json;

/// Read one HTTP response off a kept-alive socket: headers, then a
/// `Content-Length` body or a chunked body up to its terminator.
fn read_one_response(s: &mut TcpStream) -> Vec<u8> {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while !head.ends_with(b"\r\n\r\n") {
        assert!(s.read(&mut byte).expect("response head") > 0,
                "EOF inside response head");
        head.push(byte[0]);
    }
    let lower = String::from_utf8_lossy(&head).to_ascii_lowercase();
    let mut body = Vec::new();
    if let Some(pos) = lower.find("content-length:") {
        let n: usize = lower[pos + "content-length:".len()..]
            .lines()
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        body.resize(n, 0);
        s.read_exact(&mut body).expect("response body");
    } else if lower.contains("transfer-encoding: chunked") {
        while !body.ends_with(b"\r\n0\r\n\r\n") {
            assert!(s.read(&mut byte).expect("chunked body") > 0,
                    "EOF inside chunked body");
            body.push(byte[0]);
        }
    }
    body
}

/// Spin the server on an ephemeral port; returns (addr, join handle).
fn start_server()
    -> (String, thread::JoinHandle<anyhow::Result<()>>) {
    let man = Manifest::builtin("tiny").unwrap();
    let vocab = man.config.vocab;
    let store = seeded_store(&man, Variant::Full, 0).unwrap();
    let rt: Box<dyn InferRuntime> =
        Box::new(NativeModel::new(man, Variant::Full).unwrap());
    let cfg = ServeConfig {
        host: "127.0.0.1".to_string(),
        port: 0,
        max_batch: 2,
        queue_depth: 16,
        max_context: 256,
        default_max_new: 8,
        ..ServeConfig::default()
    };
    let server = Server::bind(cfg, rt, BaseSource::Master(store),
                              AdapterRegistry::new(), vocab)
        .unwrap();
    let addr = server.local_addr().unwrap().to_string();
    (addr, thread::spawn(move || server.run()))
}

/// req/s for `n` sequential `GET /healthz` on one kept-alive socket.
fn keepalive_req_s(addr: &str, n: usize) -> f64 {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    let req = b"GET /healthz HTTP/1.1\r\nHost: b\r\n\r\n";
    let t0 = Instant::now();
    for _ in 0..n {
        s.write_all(req).unwrap();
        read_one_response(&mut s);
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

/// req/s with a fresh TCP connect per request (`Connection: close`).
fn close_req_s(addr: &str, n: usize) -> f64 {
    let req = b"GET /healthz HTTP/1.1\r\nHost: b\r\nConnection: \
                close\r\n\r\n";
    let t0 = Instant::now();
    for _ in 0..n {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).unwrap();
        s.write_all(req).unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        assert!(buf.windows(4).any(|w| w == b"\r\n\r\n"));
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

/// One streamed generation; returns (ttft_ms, itl_ms) measured at the
/// socket: time to the first NDJSON line, then mean gap between
/// consecutive token lines (each payload line ends `}\n`).  `salt`
/// varies the prompt tokens, so two calls with different salts never
/// share a cacheable prefix while two calls with the same salt do.
fn stream_latencies(addr: &str, prompt_len: usize, max_new: usize,
                    salt: usize) -> (f64, f64) {
    let tokens: Vec<String> =
        (0..prompt_len).map(|i| ((i + salt) % 200).to_string()).collect();
    let body = format!(
        r#"{{"tokens":[{}],"max_new":{max_new},"seed":7}}"#,
        tokens.join(","));
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: b\r\nContent-Length: \
         {}\r\n\r\n{body}", body.len());
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    let t0 = Instant::now();
    s.write_all(req.as_bytes()).unwrap();
    let mut line_times = Vec::new();
    let mut prev = 0u8;
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        assert!(s.read(&mut byte).expect("stream") > 0,
                "EOF mid-stream");
        buf.push(byte[0]);
        if prev == b'}' && byte[0] == b'\n' {
            line_times.push(t0.elapsed().as_secs_f64());
        }
        prev = byte[0];
        if buf.ends_with(b"\r\n0\r\n\r\n") {
            break;
        }
    }
    // lines = max_new token lines + 1 done line
    assert!(line_times.len() == max_new + 1,
            "expected {} NDJSON lines, saw {}", max_new + 1,
            line_times.len());
    let ttft = 1e3 * line_times[0];
    let itl = 1e3 * (line_times[max_new - 1] - line_times[0])
        / (max_new - 1).max(1) as f64;
    (ttft, itl)
}

/// `(prefilled_tokens, prefix_hit_tokens)` counters from `/healthz` —
/// deltas across a request give its exact prefill work and savings.
fn healthz_prefill_stats(addr: &str) -> (u64, u64) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"GET /healthz HTTP/1.1\r\nHost: b\r\nConnection: \
                  close\r\n\r\n")
        .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let body_at = buf.windows(4).position(|w| w == b"\r\n\r\n").unwrap()
        + 4;
    let j = Json::parse(std::str::from_utf8(&buf[body_at..]).unwrap())
        .unwrap();
    let prefilled =
        j.get("prefilled_tokens").unwrap().as_usize().unwrap() as u64;
    let hit = j.get("prefix_cache").unwrap()
        .get("hit_tokens").unwrap().as_usize().unwrap() as u64;
    (prefilled, hit)
}

/// The residency table: paged-pool bytes vs the dense slab the old
/// allocator reserved up front, across live-token counts.  Bytes are
/// exact arithmetic (`blocks × block_bytes`), not timings.
fn kv_residency_rows() -> Vec<Json> {
    let man = Manifest::builtin("tiny").unwrap();
    let mc = &man.config;
    let (batch, capacity, block) = (8usize, 256usize, 32usize);
    println!("\n-- paged KV residency (batch {batch}, capacity \
              {capacity}, block {block}) --");
    println!("{:>12} {:>14} {:>14} {:>8}", "live tokens", "pool bytes",
             "slab bytes", "pool%");
    let mut rows = Vec::new();
    for live_per_seq in [0usize, 16, 64, 128] {
        let mut cache = KvCache::with_layout(
            mc.layers, batch, mc.heads, mc.head_dim(), capacity,
            DType::F32, block);
        let row = vec![0.0f32;
                       mc.heads * mc.head_dim() * live_per_seq.max(1)];
        // half the slots live, half idle — the mix a real batcher holds
        let live_slots = batch / 2;
        if live_per_seq > 0 {
            for seq in 0..live_slots {
                cache.append(0, seq, &row, &row, live_per_seq);
            }
        }
        let live = live_per_seq * live_slots;
        let (pool, slab) = (cache.bytes(), cache.slab_bytes());
        println!("{:>12} {:>14} {:>14} {:>7.1}%", live, pool, slab,
                 100.0 * pool as f64 / slab as f64);
        rows.push(Json::obj(vec![
            ("live_tokens", Json::num(live as f64)),
            ("pool_bytes", Json::num(pool as f64)),
            ("slab_bytes", Json::num(slab as f64)),
        ]));
    }
    rows
}

fn main() {
    switchlora::util::logging::init();
    let args = switchlora::cli::Args::parse(std::env::args().skip(1));
    let json_path = args.get("json").map(PathBuf::from);
    if json_path.is_some() {
        switchlora::bench::record_results();
    }
    let kv_rows = kv_residency_rows();

    let (addr, handle) = start_server();
    // connection reuse: the same request stream with and without a
    // fresh TCP handshake per request
    let n = 300;
    let _ = keepalive_req_s(&addr, 20); // warm both paths
    let _ = close_req_s(&addr, 20);
    let ka = keepalive_req_s(&addr, n);
    let cl = close_req_s(&addr, n);
    println!("\n-- /healthz request throughput ({n} requests) --");
    println!("   keep-alive {ka:>9.0} req/s   close-per-request \
              {cl:>9.0} req/s   ({:.2}x)", ka / cl.max(1e-9));

    // streamed generation latency through chunked prefill; distinct
    // salts keep the measured request prefix-COLD so this metric means
    // what it always meant with the prefix cache (default-on) running
    let (_, _) = stream_latencies(&addr, 64, 32, 1); // warm the path
    let (ttft, itl) = stream_latencies(&addr, 64, 32, 38);
    println!("\n-- streamed generation (prompt 64, max_new 32) --");
    println!("   ttft {ttft:.2}ms   inter-token {itl:.3}ms/tok");

    // prefix cache: one long prompt sent twice — the repeat splices the
    // sealed blocks and prefills only the uncached suffix
    let plen = 193; // 6 whole 32-position blocks + 1-token tail
    let (pre0, hit0) = healthz_prefill_stats(&addr);
    let (ttft_cold, _) = stream_latencies(&addr, plen, 8, 75);
    let (pre1, _) = healthz_prefill_stats(&addr);
    let (ttft_warm, _) = stream_latencies(&addr, plen, 8, 75);
    let (pre2, hit2) = healthz_prefill_stats(&addr);
    let (cold_toks, warm_toks) = (pre1 - pre0, pre2 - pre1);
    println!("\n-- prefix cache (prompt {plen}, max_new 8) --");
    println!("   cold ttft {:>9.0}us  prefilled {cold_toks} tokens",
             1e3 * ttft_cold);
    println!("   warm ttft {:>9.0}us  prefilled {warm_toks} tokens \
              ({} cached, {:.2}x ttft)",
             1e3 * ttft_warm, hit2 - hit0,
             ttft_cold / ttft_warm.max(1e-9));
    let prefix_rows = vec![
        Json::obj(vec![
            ("phase", Json::str("cold")),
            ("ttft_us", Json::num(1e3 * ttft_cold)),
            ("prefilled_tokens", Json::num(cold_toks as f64)),
        ]),
        Json::obj(vec![
            ("phase", Json::str("warm")),
            ("ttft_us", Json::num(1e3 * ttft_warm)),
            ("prefilled_tokens", Json::num(warm_toks as f64)),
            ("prefix_hit_tokens", Json::num((hit2 - hit0) as f64)),
        ]),
    ];

    // stop the server cleanly
    let mut s = TcpStream::connect(&addr).unwrap();
    s.write_all(b"POST /admin/drain HTTP/1.1\r\nHost: b\r\n\
                  Content-Length: 0\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut sink = Vec::new();
    let _ = s.read_to_end(&mut sink);
    handle.join().unwrap().unwrap();

    if let Some(path) = json_path {
        switchlora::bench::write_json(&path, "bench_serve", vec![
            ("tracked", Json::obj(vec![
                ("serve_keepalive_req_s", Json::num(ka)),
                ("serve_close_req_s", Json::num(cl)),
                ("serve_ttft_ms", Json::num(ttft)),
                ("serve_itl_ms_per_tok", Json::num(itl)),
                ("serve_ttft_cold_us", Json::num(1e3 * ttft_cold)),
                ("serve_ttft_warm_us", Json::num(1e3 * ttft_warm)),
            ])),
            ("prefix_warm", Json::Arr(prefix_rows)),
            ("kv_residency", Json::Arr(kv_rows)),
        ])
        .expect("writing bench json");
        println!("json report: {}", path.display());
    }
    println!("\nbench_serve complete");
}
