//! Host matmul on [`Tensor`]s — thin shims over the shared threaded
//! kernel layer ([`crate::kernels`]).  Since PR 1 the default backend is
//! the native CPU engine, so these are the *same* kernels the training
//! step runs on: GaLore's projections, rank analysis and the tests share
//! one cache-blocked, multi-threaded implementation with the fwd/bwd hot
//! path instead of keeping a divergent copy here.

use super::Tensor;

/// Cache-blocked `A[m,k] @ B[k,n]` with an i-k-j inner order (streams B
/// rows, accumulates into C rows — good locality for row-major data),
/// parallel over rows of C on the kernel pool.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch {}x{} @ {}x{}",
               a.rows, a.cols, b.rows, b.cols);
    let mut c = Tensor::zeros(a.rows, b.cols);
    crate::kernels::matmul_nn(&mut c.data, &a.data, &b.data, a.rows,
                              a.cols, b.cols);
    c
}

/// `A^T @ A` (n×n Gram matrix), used by the SVD substrate.
pub fn gram(a: &Tensor) -> Tensor {
    let mut g = Tensor::zeros(a.cols, a.cols);
    crate::kernels::gram(&mut g.data, &a.data, a.rows, a.cols);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        Tensor::from_fn(a.rows, b.cols, |i, j| {
            (0..a.cols).map(|k| a.at(i, k) * b.at(k, j)).sum()
        })
    }

    #[test]
    fn matches_naive() {
        prop_check("blocked matmul == naive", 25, |rng| {
            let (m, k, n) =
                (1 + rng.below(40), 1 + rng.below(90), 1 + rng.below(40));
            let a = Tensor::randn(m, k, 1.0, rng);
            let b = Tensor::randn(k, n, 1.0, rng);
            assert_close(&matmul(&a, &b).data, &naive(&a, &b).data,
                         1e-4, 1e-4)
        });
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(7, 7, 1.0, &mut rng);
        let c = matmul(&a, &Tensor::eye(7));
        assert_close(&a.data, &c.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn gram_matches_matmul() {
        prop_check("gram == A^T A", 15, |rng| {
            let (m, n) = (1 + rng.below(30), 1 + rng.below(20));
            let a = Tensor::randn(m, n, 1.0, rng);
            let g = gram(&a);
            let expect = matmul(&a.transpose(), &a);
            assert_close(&g.data, &expect.data, 1e-4, 1e-4)
        });
    }
}
