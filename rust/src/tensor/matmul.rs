//! Blocked host matmul.  Used off the hot path (GaLore projection, rank
//! analysis, tests); the training-step matmuls run inside the AOT-compiled
//! XLA executables.

use super::Tensor;

/// Cache-blocked `A[m,k] @ B[k,n]` with an i-k-j inner order (streams B rows,
/// accumulates into C rows — good locality for row-major data).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch {}x{} @ {}x{}",
               a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Tensor::zeros(m, n);
    const BK: usize = 64;
    for k0 in (0..k).step_by(BK) {
        let k1 = (k0 + BK).min(k);
        for i in 0..m {
            let a_row = a.row(i);
            let c_row = c.row_mut(i);
            for kk in k0..k1 {
                let aik = a_row[kk];
                if aik == 0.0 {
                    continue;
                }
                let b_row = b.row(kk);
                for j in 0..n {
                    c_row[j] += aik * b_row[j];
                }
            }
        }
    }
    c
}

/// `A^T @ A` (n×n Gram matrix), used by the SVD substrate.
pub fn gram(a: &Tensor) -> Tensor {
    let n = a.cols;
    let mut g = Tensor::zeros(n, n);
    for i in 0..a.rows {
        let row = a.row(i);
        for p in 0..n {
            let rp = row[p];
            if rp == 0.0 {
                continue;
            }
            let g_row = g.row_mut(p);
            for q in 0..n {
                g_row[q] += rp * row[q];
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        Tensor::from_fn(a.rows, b.cols, |i, j| {
            (0..a.cols).map(|k| a.at(i, k) * b.at(k, j)).sum()
        })
    }

    #[test]
    fn matches_naive() {
        prop_check("blocked matmul == naive", 25, |rng| {
            let (m, k, n) =
                (1 + rng.below(40), 1 + rng.below(90), 1 + rng.below(40));
            let a = Tensor::randn(m, k, 1.0, rng);
            let b = Tensor::randn(k, n, 1.0, rng);
            assert_close(&matmul(&a, &b).data, &naive(&a, &b).data,
                         1e-4, 1e-4)
        });
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(7, 7, 1.0, &mut rng);
        let c = matmul(&a, &Tensor::eye(7));
        assert_close(&a.data, &c.data, 1e-6, 1e-6).unwrap();
    }

    #[test]
    fn gram_matches_matmul() {
        prop_check("gram == A^T A", 15, |rng| {
            let (m, n) = (1 + rng.below(30), 1 + rng.below(20));
            let a = Tensor::randn(m, n, 1.0, rng);
            let g = gram(&a);
            let expect = matmul(&a.transpose(), &a);
            assert_close(&g.data, &expect.data, 1e-4, 1e-4)
        });
    }
}
