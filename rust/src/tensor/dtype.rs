//! Numeric dtypes and packed storage: the precision layer.
//!
//! The paper's systems claims — 54% less communication, 13% less memory
//! than full-rank pre-training — are about how many *bytes* move and
//! stay resident, so the rest of the stack must be able to store and
//! transport numbers at less than `f32` width.  This module is the one
//! place those representations live:
//!
//! * [`DType`] — the storage dtypes the system understands (`f32`,
//!   software `bf16`, symmetric per-row `int8` with `f32` scales).
//! * [`f32_to_bf16`]/[`bf16_to_f32`] — software bfloat16 with
//!   round-to-nearest-even, bit-compatible with hardware bf16.
//! * [`quantize_row_i8`] and [`PackedBuf`] — QLoRA-style symmetric
//!   per-row (output-channel) int8 with one `f32` scale per row.
//! * [`MatRef`] — a borrowed dtype-tagged matrix view, the RHS type of
//!   the packed matmul kernels ([`crate::kernels::addmm_nt_packed`]).
//! * [`PrecisionPolicy`] — which dtype each *role* in the system uses
//!   (master weights, compute, all-reduce wire, Adam moments, frozen
//!   base weights), resolved from the CLI flags `--precision`,
//!   `--comm-dtype`, `--moments-dtype`, `--quantize-base`.
//!
//! Invariants the consumers rely on: converting an `f32` slice to a
//! [`PackedBuf`] and back with [`PackedBuf::to_f32`] is the *exact*
//! value the packed kernels see (dequant-on-load is per-element, so
//! `packed kernel == dequantize-then-f32-kernel` bitwise), and the
//! all-`f32` policy is a strict no-op: `PackedBuf::F32` round-trips
//! bytes untouched and the policy-aware call sites take their legacy
//! paths.

use anyhow::{bail, Result};

/// A storage dtype.  `bytes()` is the wire/resident width per element
/// (int8 scale overhead is accounted where the scales live, one `f32`
/// per row).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    Bf16,
    I8,
}

impl DType {
    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::Bf16 => "bf16",
            DType::I8 => "int8",
        }
    }

    /// Bytes per element of the payload.
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::Bf16 => 2,
            DType::I8 => 1,
        }
    }

    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" | "fp32" | "float32" => DType::F32,
            "bf16" | "bfloat16" => DType::Bf16,
            "int8" | "i8" => DType::I8,
            other => bail!("unknown dtype {other:?} (expected f32, bf16 \
                            or int8)"),
        })
    }

    /// Checkpoint tag byte (format v3).  Stable across releases.
    pub fn tag(&self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::Bf16 => 1,
            DType::I8 => 2,
        }
    }

    pub fn from_tag(t: u8) -> Result<DType> {
        Ok(match t {
            0 => DType::F32,
            1 => DType::Bf16,
            2 => DType::I8,
            other => bail!("unknown dtype tag {other} in checkpoint"),
        })
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------
// Software bfloat16.
// ---------------------------------------------------------------------

/// `f32 → bf16` with round-to-nearest-even (the hardware rounding mode).
/// NaN payloads are quieted so a NaN never rounds to infinity.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // keep sign + a quiet mantissa bit so the result stays NaN
        return ((bits >> 16) as u16) | 0x0040;
    }
    // round to nearest, ties to even on the truncated 16 bits
    let round_bias = 0x7FFF + ((bits >> 16) & 1);
    ((bits.wrapping_add(round_bias)) >> 16) as u16
}

/// `bf16 → f32` (exact: bf16 is a prefix of the f32 encoding).
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round an `f32` through a dtype's representable set — the value a
/// number has after crossing a `dtype`-width wire.  `F32` is identity;
/// `I8` has no standalone scalar form (its scale is per-row) and is
/// rejected by the policy layer before reaching here.
#[inline]
pub fn round_through(x: f32, dtype: DType) -> f32 {
    match dtype {
        DType::F32 => x,
        DType::Bf16 => bf16_to_f32(f32_to_bf16(x)),
        DType::I8 => x, // per-row scaled; handled by PackedBuf
    }
}

// ---------------------------------------------------------------------
// Symmetric per-row int8.
// ---------------------------------------------------------------------

/// Quantize one row symmetrically: `scale = max|x| / 127`, `q =
/// round(x/scale)` clamped to `[-127, 127]`.  A zero row gets scale 0
/// and all-zero codes (dequantizing to exact zeros).
pub fn quantize_row_i8(row: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), out.len());
    let amax = row.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    if amax == 0.0 || !amax.is_finite() {
        out.fill(0);
        return if amax == 0.0 { 0.0 } else { f32::NAN };
    }
    let scale = amax / 127.0;
    let inv = 127.0 / amax;
    for (o, &x) in out.iter_mut().zip(row) {
        *o = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

// ---------------------------------------------------------------------
// Packed buffers and borrowed views.
// ---------------------------------------------------------------------

/// A borrowed dtype-tagged matrix view: the RHS of the packed matmul
/// kernels.  `I8` scales are per *row* of the viewed matrix.
#[derive(Clone, Copy, Debug)]
pub enum MatRef<'a> {
    F32(&'a [f32]),
    Bf16(&'a [u16]),
    I8 { q: &'a [i8], scales: &'a [f32] },
}

impl MatRef<'_> {
    pub fn dtype(&self) -> DType {
        match self {
            MatRef::F32(_) => DType::F32,
            MatRef::Bf16(_) => DType::Bf16,
            MatRef::I8 { .. } => DType::I8,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            MatRef::F32(w) => w.len(),
            MatRef::Bf16(w) => w.len(),
            MatRef::I8 { q, .. } => q.len(),
        }
    }
}

/// An owned dtype-tagged buffer: one parameter's storage in a packed
/// store, or a transient packed view of a master-precision weight.
#[derive(Clone, Debug)]
pub enum PackedBuf {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    /// row-major codes with one symmetric scale per row
    I8 { q: Vec<i8>, scales: Vec<f32>, cols: usize },
}

impl PackedBuf {
    /// Pack a row-major `[rows, cols]` f32 matrix into `dtype` storage.
    pub fn pack(data: &[f32], rows: usize, cols: usize, dtype: DType)
        -> PackedBuf {
        debug_assert_eq!(data.len(), rows * cols, "PackedBuf::pack shape");
        match dtype {
            DType::F32 => PackedBuf::F32(data.to_vec()),
            DType::Bf16 => {
                PackedBuf::Bf16(data.iter().map(|&x| f32_to_bf16(x))
                                    .collect())
            }
            DType::I8 => {
                let mut q = vec![0i8; data.len()];
                let mut scales = Vec::with_capacity(rows);
                for (r, qr) in q.chunks_exact_mut(cols).enumerate() {
                    let row = &data[r * cols..(r + 1) * cols];
                    scales.push(quantize_row_i8(row, qr));
                }
                PackedBuf::I8 { q, scales, cols }
            }
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            PackedBuf::F32(_) => DType::F32,
            PackedBuf::Bf16(_) => DType::Bf16,
            PackedBuf::I8 { .. } => DType::I8,
        }
    }

    pub fn numel(&self) -> usize {
        match self {
            PackedBuf::F32(d) => d.len(),
            PackedBuf::Bf16(d) => d.len(),
            PackedBuf::I8 { q, .. } => q.len(),
        }
    }

    /// Resident bytes of this buffer (int8 includes its f32 scales).
    pub fn resident_bytes(&self) -> usize {
        match self {
            PackedBuf::F32(d) => 4 * d.len(),
            PackedBuf::Bf16(d) => 2 * d.len(),
            PackedBuf::I8 { q, scales, .. } => q.len() + 4 * scales.len(),
        }
    }

    pub fn view(&self) -> MatRef<'_> {
        match self {
            PackedBuf::F32(d) => MatRef::F32(d),
            PackedBuf::Bf16(d) => MatRef::Bf16(d),
            PackedBuf::I8 { q, scales, .. } => {
                MatRef::I8 { q, scales }
            }
        }
    }

    /// Dequantize to f32 — exactly the values the packed kernels see
    /// (their dequant-on-load is per-element, so `packed kernel(buf) ==
    /// f32 kernel(buf.to_f32())` bitwise).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            PackedBuf::F32(d) => d.clone(),
            PackedBuf::Bf16(d) => {
                d.iter().map(|&b| bf16_to_f32(b)).collect()
            }
            PackedBuf::I8 { q, scales, cols } => {
                let mut out = Vec::with_capacity(q.len());
                for (r, qr) in q.chunks_exact(*cols).enumerate() {
                    let s = scales[r];
                    out.extend(qr.iter().map(|&c| s * c as f32));
                }
                out
            }
        }
    }
}

// ---------------------------------------------------------------------
// Precision policy.
// ---------------------------------------------------------------------

/// Which dtype each role in the system uses.  The default is pure f32
/// everywhere, and every consumer treats that default as a strict
/// no-op: bitwise-identical to the pre-precision-layer code paths.
///
/// Roles:
/// * `master` — the authoritative trainable weights (and every
///   gradient/adapter buffer).  Always `f32`; low-precision training
///   keeps full-precision masters, as in standard mixed precision.
/// * `compute` — the dtype dense base weights are *viewed* in by the
///   matmul kernels (f32 accumulate always).  `--precision bf16`.
/// * `comm` — the data-parallel all-reduce wire format
///   (`--comm-dtype`): payload values are rounded through this dtype
///   and the byte ledger counts its true width.
/// * `moments` — Adam `m`/`v` precision (`--moments-dtype`): values are
///   kept on the bf16 grid and checkpointed at 2 bytes each.
/// * `frozen_base` — storage of frozen dense weights (training) and of
///   the serving-time base weights (`--quantize-base int8`).  Defaults
///   to `compute`.
/// * `kv_cache` — storage of the serving-time KV cache (`--kv-dtype`):
///   `f32` (exact, the default), `bf16`, or `int8` (symmetric per
///   position-row scales).  Serving memory per concurrent user scales
///   with this width.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PrecisionPolicy {
    pub master: DType,
    pub compute: DType,
    pub comm: DType,
    pub moments: DType,
    pub frozen_base: DType,
    pub kv_cache: DType,
}

impl Default for PrecisionPolicy {
    fn default() -> Self {
        PrecisionPolicy {
            master: DType::F32,
            compute: DType::F32,
            comm: DType::F32,
            moments: DType::F32,
            frozen_base: DType::F32,
            kv_cache: DType::F32,
        }
    }
}

impl PrecisionPolicy {
    /// Resolve a policy from the CLI flag values.  `frozen_base`
    /// follows `compute` unless `--quantize-base` overrides it;
    /// `kv_cache` is `--kv-dtype` (default f32).
    pub fn from_flags(precision: Option<&str>, comm: Option<&str>,
                      moments: Option<&str>, quantize_base: Option<&str>,
                      kv_dtype: Option<&str>)
        -> Result<PrecisionPolicy> {
        let compute = match precision {
            Some(s) => DType::parse(s)?,
            None => DType::F32,
        };
        ensure_role("--precision", compute, &[DType::F32, DType::Bf16])?;
        let comm_d = match comm {
            Some(s) => DType::parse(s)?,
            None => DType::F32,
        };
        ensure_role("--comm-dtype", comm_d, &[DType::F32, DType::Bf16])?;
        let moments_d = match moments {
            Some(s) => DType::parse(s)?,
            None => DType::F32,
        };
        ensure_role("--moments-dtype", moments_d,
                    &[DType::F32, DType::Bf16])?;
        let frozen = match quantize_base {
            Some(s) => {
                let d = DType::parse(s)?;
                ensure_role("--quantize-base", d,
                            &[DType::Bf16, DType::I8])?;
                d
            }
            None => compute,
        };
        let kv = match kv_dtype {
            Some(s) => {
                let d = DType::parse(s)?;
                ensure_role("--kv-dtype", d,
                            &[DType::F32, DType::Bf16, DType::I8])?;
                d
            }
            None => DType::F32,
        };
        Ok(PrecisionPolicy {
            master: DType::F32,
            compute,
            comm: comm_d,
            moments: moments_d,
            frozen_base: frozen,
            kv_cache: kv,
        })
    }

    /// True when every role is f32 — the bitwise-legacy configuration.
    pub fn is_default(&self) -> bool {
        *self == PrecisionPolicy::default()
    }

    /// One-line human summary (the `info` subcommand / run banner).
    pub fn summary(&self) -> String {
        format!("master {} | compute {} | comm {} | moments {} | \
                 frozen-base {} | kv-cache {}",
                self.master, self.compute, self.comm, self.moments,
                self.frozen_base, self.kv_cache)
    }
}

fn ensure_role(flag: &str, d: DType, allowed: &[DType]) -> Result<()> {
    if !allowed.contains(&d) {
        let names: Vec<&str> = allowed.iter().map(|a| a.name()).collect();
        bail!("{flag} {}: unsupported here (allowed: {})", d.name(),
              names.join(", "));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    #[test]
    fn bf16_exact_on_representables() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, -3.25,
                  f32::INFINITY, f32::NEG_INFINITY] {
            let rt = bf16_to_f32(f32_to_bf16(x));
            assert_eq!(rt.to_bits(), x.to_bits(), "{x} not exact");
        }
        // bf16 has an 8-bit mantissa: 1 + 2^-8 is representable,
        // 1 + 2^-9 rounds to even (back down to 1.0)
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 1.0 / 256.0)),
                   1.0 + 1.0 / 256.0);
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 1.0 / 512.0)), 1.0);
        // ...while 1 + 3·2^-9 rounds up to 1 + 2^-7 (nearest even)
        assert_eq!(bf16_to_f32(f32_to_bf16(1.0 + 3.0 / 512.0)),
                   1.0 + 2.0 / 256.0);
    }

    #[test]
    fn bf16_nan_stays_nan() {
        let q = f32_to_bf16(f32::NAN);
        assert!(bf16_to_f32(q).is_nan());
        let neg = f32_to_bf16(f32::from_bits(0xFF80_0001)); // -NaN payload
        assert!(bf16_to_f32(neg).is_nan());
    }

    #[test]
    fn bf16_roundtrip_relative_error_bound() {
        prop_check("bf16 round-trip error <= 2^-8 relative", 200, |rng| {
            let x = rng.normal_f32(0.0, 10.0);
            let rt = bf16_to_f32(f32_to_bf16(x));
            let err = (rt - x).abs();
            // RNE on an 8-bit mantissa: err <= ulp/2 = 2^-9 * 2^ceil
            let bound = x.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE;
            if err > bound {
                return Err(format!("{x} -> {rt}: err {err} > {bound}"));
            }
            // idempotent: a bf16 value round-trips exactly
            if bf16_to_f32(f32_to_bf16(rt)).to_bits() != rt.to_bits() {
                return Err(format!("{rt} not idempotent"));
            }
            Ok(())
        });
    }

    #[test]
    fn i8_row_quantization_error_bound() {
        prop_check("int8 per-row |x - q·s| <= s/2", 100, |rng| {
            let n = 1 + rng.below(64);
            let amp = 0.01 + 10.0 * rng.uniform_range(0.0, 1.0);
            let row: Vec<f32> =
                (0..n).map(|_| rng.normal_f32(0.0, amp)).collect();
            let mut q = vec![0i8; n];
            let scale = quantize_row_i8(&row, &mut q);
            for (&x, &c) in row.iter().zip(&q) {
                let deq = scale * c as f32;
                let err = (x - deq).abs();
                if err > 0.5001 * scale + 1e-12 {
                    return Err(format!(
                        "x {x} q {c} scale {scale}: err {err}"));
                }
            }
            // max-abs element is coded at full range (monotone scales)
            let amax = row.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
            if (scale - amax / 127.0).abs() > 1e-12 * amax {
                return Err(format!("scale {scale} vs {}", amax / 127.0));
            }
            Ok(())
        });
    }

    #[test]
    fn i8_scales_are_monotone_in_row_magnitude() {
        // doubling a row doubles its scale exactly (power-of-two scale)
        let row = [0.3f32, -1.7, 0.05, 0.9];
        let doubled: Vec<f32> = row.iter().map(|&x| 2.0 * x).collect();
        let mut q = [0i8; 4];
        let s1 = quantize_row_i8(&row, &mut q);
        let q1 = q;
        let s2 = quantize_row_i8(&doubled, &mut q);
        assert_eq!(s2, 2.0 * s1);
        assert_eq!(q, q1, "codes are scale-invariant");
    }

    #[test]
    fn i8_zero_row_is_exact() {
        let mut q = [5i8; 3];
        let s = quantize_row_i8(&[0.0, 0.0, 0.0], &mut q);
        assert_eq!(s, 0.0);
        assert_eq!(q, [0, 0, 0]);
    }

    #[test]
    fn packed_buf_roundtrips() {
        let mut rng = Rng::new(3);
        let (rows, cols) = (5, 7);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| rng.normal_f32(0.0, 1.0))
            .collect();
        // f32 is byte-identical
        let f = PackedBuf::pack(&data, rows, cols, DType::F32);
        assert_eq!(f.to_f32(), data);
        assert_eq!(f.resident_bytes(), 4 * data.len());
        // bf16 matches the scalar round-trip elementwise
        let b = PackedBuf::pack(&data, rows, cols, DType::Bf16);
        let want: Vec<f32> =
            data.iter().map(|&x| round_through(x, DType::Bf16)).collect();
        assert_eq!(b.to_f32(), want);
        assert_eq!(b.resident_bytes(), 2 * data.len());
        // int8 respects the per-row error bound and byte accounting
        let i = PackedBuf::pack(&data, rows, cols, DType::I8);
        assert_eq!(i.resident_bytes(), data.len() + 4 * rows);
        let deq = i.to_f32();
        for r in 0..rows {
            let amax = data[r * cols..(r + 1) * cols]
                .iter()
                .fold(0.0f32, |a, &x| a.max(x.abs()));
            let s = amax / 127.0;
            for c in 0..cols {
                let err = (deq[r * cols + c] - data[r * cols + c]).abs();
                assert!(err <= 0.5001 * s, "({r},{c}) err {err} s {s}");
            }
        }
        assert_eq!(i.numel(), data.len());
    }

    #[test]
    fn policy_resolution_and_validation() {
        let d = PrecisionPolicy::from_flags(None, None, None, None, None)
            .unwrap();
        assert!(d.is_default());
        let p = PrecisionPolicy::from_flags(Some("bf16"), Some("bf16"),
                                            Some("bf16"), None, None)
            .unwrap();
        assert_eq!(p.compute, DType::Bf16);
        assert_eq!(p.comm, DType::Bf16);
        assert_eq!(p.moments, DType::Bf16);
        // frozen_base follows compute unless overridden
        assert_eq!(p.frozen_base, DType::Bf16);
        assert_eq!(p.master, DType::F32);
        // kv_cache is independent of compute: default f32
        assert_eq!(p.kv_cache, DType::F32);
        let q = PrecisionPolicy::from_flags(None, None, None,
                                            Some("int8"), Some("int8"))
            .unwrap();
        assert_eq!(q.frozen_base, DType::I8);
        assert_eq!(q.kv_cache, DType::I8);
        assert_eq!(q.compute, DType::F32);
        assert!(!q.is_default());
        // int8 is a storage dtype, not a wire/compute dtype
        assert!(PrecisionPolicy::from_flags(Some("int8"), None, None,
                                            None, None).is_err());
        assert!(PrecisionPolicy::from_flags(None, Some("int8"), None,
                                            None, None).is_err());
        assert!(PrecisionPolicy::from_flags(None, None, Some("int8"),
                                            None, None).is_err());
        // --quantize-base f32 is a no-op request: rejected for clarity
        assert!(PrecisionPolicy::from_flags(None, None, None,
                                            Some("f32"), None).is_err());
        // --kv-dtype f32 IS accepted: it names the default storage
        let kvf = PrecisionPolicy::from_flags(None, None, None, None,
                                              Some("f32")).unwrap();
        assert!(kvf.is_default());
        assert!(PrecisionPolicy::from_flags(None, None, None, None,
                                            Some("banana")).is_err());
        assert!(DType::parse("banana").is_err());
        assert!(p.summary().contains("comm bf16"));
        assert!(q.summary().contains("kv-cache int8"));
    }

    #[test]
    fn dtype_tags_roundtrip() {
        for d in [DType::F32, DType::Bf16, DType::I8] {
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
        }
        assert!(DType::from_tag(9).is_err());
    }
}
