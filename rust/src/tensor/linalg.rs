//! Dense linear algebra substrate: one-sided Jacobi SVD and Householder QR.
//!
//! Two consumers:
//! * **GaLore** (`optim/galore.rs`) needs the top-r singular vectors of each
//!   gradient matrix to build its projection (paper §3 "Other compression
//!   methods", Zhao et al. 2024b).
//! * **Rank analysis** (Figures 10/11) needs full singular-value spectra of
//!   trained weight matrices.
//!
//! The Jacobi rotation sweeps apply through the shared kernel layer
//! ([`crate::kernels::rotate_columns`]), so tall matrices parallelize
//! over rows on the same pool as everything else; the 2×2 Gram
//! accumulations stay serial because their f64 sums are order-sensitive.

use super::Tensor;

#[cfg(test)]
use super::matmul::matmul;

/// Thin SVD `A = U diag(S) V^T` via one-sided Jacobi on the columns.
///
/// Returns `(U [m,p], S [p], V [n,p])` with `p = min(m,n)` and singular
/// values sorted descending.  For `m < n` the decomposition is computed on
/// `A^T` and swapped back.
pub fn svd(a: &Tensor) -> (Tensor, Vec<f32>, Tensor) {
    if a.rows < a.cols {
        let (u, s, v) = svd(&a.transpose());
        return (v, s, u);
    }
    let m = a.rows;
    let n = a.cols;
    // Work on columns of a copy; accumulate right rotations into V.
    let mut w = a.clone();
    let mut v = Tensor::eye(n);
    let max_sweeps = 60;
    let eps = 1e-9f64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n - 1 {
            for q in p + 1..n {
                // 2x2 Gram entries over columns p, q
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let xp = w.at(i, p) as f64;
                    let xq = w.at(i, q) as f64;
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                off += apq.abs();
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                // Jacobi rotation that zeroes the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                crate::kernels::rotate_columns(&mut w.data, m, n, p, q,
                                               c, s);
                crate::kernels::rotate_columns(&mut v.data, n, n, p, q,
                                               c, s);
            }
        }
        if off < 1e-10 {
            break;
        }
    }
    // Column norms are the singular values; normalize into U.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sv: Vec<f32> = (0..n)
        .map(|j| {
            (0..m).map(|i| {
                let x = w.at(i, j) as f64;
                x * x
            }).sum::<f64>().sqrt() as f32
        })
        .collect();
    order.sort_by(|&i, &j| sv[j].partial_cmp(&sv[i]).unwrap());
    let mut u = Tensor::zeros(m, n);
    let mut v_sorted = Tensor::zeros(n, n);
    let mut s_sorted = Vec::with_capacity(n);
    for (newj, &oldj) in order.iter().enumerate() {
        let s = sv[oldj];
        s_sorted.push(s);
        let inv = if s > 1e-20 { 1.0 / s } else { 0.0 };
        for i in 0..m {
            *u.at_mut(i, newj) = w.at(i, oldj) * inv;
        }
        for i in 0..n {
            *v_sorted.at_mut(i, newj) = v.at(i, oldj);
        }
    }
    sv = s_sorted;
    (u, sv, v_sorted)
}

/// Singular values only (descending).
pub fn singular_values(a: &Tensor) -> Vec<f32> {
    svd(a).1
}

/// Householder QR: `A[m,n] = Q[m,n] R[n,n]` (thin, m >= n).
pub fn qr(a: &Tensor) -> (Tensor, Tensor) {
    assert!(a.rows >= a.cols, "thin QR needs m >= n");
    let (m, n) = (a.rows, a.cols);
    let mut r = a.clone();
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut x: Vec<f32> = (k..m).map(|i| r.at(i, k)).collect();
        let norm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        if norm > 0.0 {
            let sign = if x[0] >= 0.0 { 1.0 } else { -1.0 };
            x[0] += sign * norm;
            let vnorm = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            if vnorm > 0.0 {
                for v in x.iter_mut() {
                    *v /= vnorm;
                }
                // Apply reflection to R
                for j in k..n {
                    let dot: f32 = (k..m).map(|i| x[i - k] * r.at(i, j))
                        .sum();
                    for i in k..m {
                        *r.at_mut(i, j) -= 2.0 * x[i - k] * dot;
                    }
                }
            }
        }
        vs.push(x);
    }
    // Build thin Q by applying reflections to identity columns.
    let mut q = Tensor::zeros(m, n);
    for j in 0..n {
        let mut e = vec![0.0f32; m];
        e[j] = 1.0;
        for k in (0..n).rev() {
            let v = &vs[k];
            if v.iter().all(|&x| x == 0.0) {
                continue;
            }
            let dot: f32 = (k..m).map(|i| v[i - k] * e[i]).sum();
            for i in k..m {
                e[i] -= 2.0 * v[i - k] * dot;
            }
        }
        for i in 0..m {
            *q.at_mut(i, j) = e[i];
        }
    }
    // Zero out sub-diagonal fuzz in R.
    let mut r_thin = Tensor::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *r_thin.at_mut(i, j) = r.at(i, j);
        }
    }
    (q, r_thin)
}

/// Effective rank: #singular values above `tol * s_max`.
pub fn effective_rank(s: &[f32], tol: f32) -> usize {
    if s.is_empty() {
        return 0;
    }
    let cutoff = s[0] * tol;
    s.iter().filter(|&&x| x > cutoff).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn reconstruct(u: &Tensor, s: &[f32], v: &Tensor) -> Tensor {
        let mut us = u.clone();
        for j in 0..s.len() {
            for i in 0..us.rows {
                *us.at_mut(i, j) *= s[j];
            }
        }
        matmul(&us, &v.transpose())
    }

    #[test]
    fn svd_reconstructs() {
        prop_check("U S V^T == A", 10, |rng| {
            let (m, n) = (2 + rng.below(20), 2 + rng.below(20));
            let a = Tensor::randn(m, n, 1.0, rng);
            let (u, s, v) = svd(&a);
            let r = reconstruct(&u, &s, &v);
            assert_close(&r.data, &a.data, 5e-3, 5e-3)
        });
    }

    #[test]
    fn svd_orthonormal_u() {
        let mut rng = Rng::new(2);
        let a = Tensor::randn(20, 8, 1.0, &mut rng);
        let (u, _, v) = svd(&a);
        let utu = matmul(&u.transpose(), &u);
        let vtv = matmul(&v.transpose(), &v);
        assert_close(&utu.data, &Tensor::eye(8).data, 1e-3, 1e-3).unwrap();
        assert_close(&vtv.data, &Tensor::eye(8).data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn svd_sorted_and_known_rank() {
        let mut rng = Rng::new(3);
        // rank-2 matrix: sum of two outer products
        let u1 = Tensor::randn(16, 1, 1.0, &mut rng);
        let v1 = Tensor::randn(1, 12, 1.0, &mut rng);
        let u2 = Tensor::randn(16, 1, 1.0, &mut rng);
        let v2 = Tensor::randn(1, 12, 1.0, &mut rng);
        let mut a = matmul(&u1, &v1);
        a.axpy(1.0, &matmul(&u2, &v2));
        let s = singular_values(&a);
        for w in s.windows(2) {
            assert!(w[0] >= w[1] - 1e-5, "not sorted: {s:?}");
        }
        assert_eq!(effective_rank(&s, 1e-4), 2, "spectrum {s:?}");
    }

    #[test]
    fn svd_wide_matrix() {
        let mut rng = Rng::new(4);
        let a = Tensor::randn(6, 17, 1.0, &mut rng);
        let (u, s, v) = svd(&a);
        assert_eq!((u.rows, u.cols), (6, 6));
        assert_eq!((v.rows, v.cols), (17, 6));
        assert_eq!(s.len(), 6);
        let r = reconstruct(&u, &s, &v);
        assert_close(&r.data, &a.data, 5e-3, 5e-3).unwrap();
    }

    #[test]
    fn qr_reconstructs_and_orthogonal() {
        prop_check("QR == A and Q^T Q = I", 10, |rng| {
            let (m, n) = (3 + rng.below(20), 2 + rng.below(10));
            let (m, n) = (m.max(n), n);
            let a = Tensor::randn(m, n, 1.0, rng);
            let (q, r) = qr(&a);
            assert_close(&matmul(&q, &r).data, &a.data, 1e-3, 1e-3)?;
            assert_close(&matmul(&q.transpose(), &q).data,
                         &Tensor::eye(n).data, 1e-3, 1e-3)
        });
    }

    #[test]
    fn effective_rank_edges() {
        assert_eq!(effective_rank(&[], 0.01), 0);
        assert_eq!(effective_rank(&[5.0, 0.0], 0.01), 1);
        assert_eq!(effective_rank(&[5.0, 4.0, 0.04], 0.01), 2);
    }
}
