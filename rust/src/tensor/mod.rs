//! Host tensor substrate: row-major matrices/vectors.
//!
//! This is the coordinator-side math library — it backs the switch
//! operation (rank-1 updates on `W`), GaLore's gradient projection, the
//! host optimizer, checkpoint manipulation and the singular-value analysis
//! of Figures 10/11.  It is deliberately simple (no strides/broadcasting):
//! every shape in the system is a vector or a 2-D matrix.
//!
//! The coordinator-side [`Tensor`] is `f32` (master precision); the
//! [`dtype`] submodule provides the storage dtypes below that — software
//! `bf16` and symmetric per-row `int8` — as [`dtype::PackedBuf`] buffers
//! consumed by the packed kernels and the serving-time
//! [`crate::model::packed::PackedStore`].

pub mod dtype;
pub mod linalg;
pub mod matmul;

use crate::util::rng::Rng;

/// Row-major 2-D matrix (or 1-D vector when `rows == 1`).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Tensor { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize,
                   mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Tensor { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Tensor::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal_f32(0.0, std))
            .collect();
        Tensor { rows, cols, data }
    }

    pub fn rand_uniform(rows: usize, cols: usize, lim: f32, rng: &mut Rng)
        -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_range(-lim, lim))
            .collect();
        Tensor { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f32]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            *self.at_mut(i, j) = v[i];
        }
    }

    pub fn transpose(&self) -> Tensor {
        let mut t = Tensor::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *t.at_mut(j, i) = self.at(i, j);
            }
        }
        t
    }

    /// `self += alpha * other` (same shape).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += alpha * y;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for x in self.data.iter_mut() {
            *x *= alpha;
        }
    }

    /// Rank-1 update `self += alpha * u v^T` — the core of the switch op
    /// (Algorithm 1 lines 1 and 4): `W ← W ± b_k a_k^T`.
    pub fn rank1_update(&mut self, alpha: f32, u: &[f32], v: &[f32]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), self.cols);
        for i in 0..self.rows {
            let ui = alpha * u[i];
            let row = self.row_mut(i);
            for j in 0..v.len() {
                row[j] += ui * v[j];
            }
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| x * y).sum()
    }

    /// Maximum absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, prop_check};

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at(0, 2), 3.0);
        assert_eq!(t.at(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.col(1), vec![2., 5.]);
    }

    #[test]
    fn transpose_involution() {
        prop_check("transpose twice is identity", 20, |rng| {
            let (r, c) = (1 + rng.below(20), 1 + rng.below(20));
            let t = Tensor::randn(r, c, 1.0, rng);
            let tt = t.transpose().transpose();
            assert_close(&t.data, &tt.data, 0.0, 0.0)
        });
    }

    #[test]
    fn rank1_update_matches_dense() {
        prop_check("rank1 == dense outer product", 20, |rng| {
            let (m, n) = (1 + rng.below(12), 1 + rng.below(12));
            let mut w = Tensor::randn(m, n, 1.0, rng);
            let w0 = w.clone();
            let u: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let v: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            w.rank1_update(0.5, &u, &v);
            let expect = Tensor::from_fn(m, n,
                |i, j| w0.at(i, j) + 0.5 * u[i] * v[j]);
            assert_close(&w.data, &expect.data, 1e-6, 1e-6)
        });
    }

    #[test]
    fn set_col_roundtrip() {
        let mut t = Tensor::zeros(3, 2);
        t.set_col(1, &[1.0, 2.0, 3.0]);
        assert_eq!(t.col(1), vec![1.0, 2.0, 3.0]);
        assert_eq!(t.col(0), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(1, 3, vec![1., 2., 3.]);
        let b = Tensor::from_vec(1, 3, vec![10., 20., 30.]);
        a.axpy(0.1, &b);
        assert_eq!(a.data, vec![2., 4., 6.]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1., 2., 3.]);
    }

    #[test]
    fn frob_norm() {
        let t = Tensor::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((t.frob_norm() - 5.0).abs() < 1e-6);
    }
}
