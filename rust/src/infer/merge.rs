//! Adapter merging: fold `W ← W + s·B·A` into dense base weights.
//!
//! LoRA's headline deployment property (Hu et al. 2021) is that the
//! adapter product can be folded into the frozen base weight, so a
//! served model pays **zero added inference latency** over the dense
//! baseline.  SwitchLoRA inherits it unchanged: switching only permutes
//! which candidate vectors sit in A/B during training, the final
//! adapters are ordinary LoRA factors.
//!
//! Two paths:
//!
//! * [`merge_adapters`] — in place on a LoRA-layout store: adds `s·B·A`
//!   to every base `W` and zeroes `B`, so the unchanged LoRA forward
//!   computes the merged dense function (`x·A` is still evaluated but
//!   contributes exactly zero).  Returns a [`MergeState`] whose
//!   [`unmerge_adapters`] restores the pre-merge store *bitwise* (it
//!   keeps the original bytes rather than subtracting the delta back,
//!   which would re-round).
//! * [`merged_full_store`] — exports a LoRA store as a **full-variant**
//!   store with adapters folded in: the zero-overhead serving artifact,
//!   checkpointable via `coordinator::checkpoint` and loadable by any
//!   full-variant runtime.
//!
//! Every path composes the dense delta with [`adapter_delta`] (fixed
//! j-ascending summation), so merged weights agree bitwise between the
//! in-place and export paths.

use anyhow::{bail, ensure, Result};

use crate::model::layout::{Manifest, ParamStore, Variant};

/// Dense `s·B·A` in `[m, n]` row-major, with a fixed summation order
/// (rank index ascending) shared by all merge paths and their tests.
pub fn adapter_delta(a: &[f32], b: &[f32], m: usize, n: usize, r: usize,
                     scale: f32) -> Vec<f32> {
    debug_assert_eq!(a.len(), r * n);
    debug_assert_eq!(b.len(), m * r);
    let mut d = vec![0.0f32; m * n];
    for o in 0..m {
        let dr = &mut d[o * n..(o + 1) * n];
        for j in 0..r {
            let s = scale * b[o * r + j];
            if s == 0.0 {
                continue;
            }
            let ar = &a[j * n..(j + 1) * n];
            for (dv, av) in dr.iter_mut().zip(ar) {
                *dv += s * av;
            }
        }
    }
    d
}

/// Saved pre-merge bytes; the receipt `unmerge_adapters` redeems.
pub struct MergeState {
    saved: Vec<(String, Vec<f32>)>,
}

impl MergeState {
    /// Number of linears that were merged.
    pub fn n_merged(&self) -> usize {
        self.saved.len() / 2
    }
}

/// Fold every adapter of a LoRA-layout store into its base weight in
/// place and zero the `B` factors.  After this, the store's LoRA forward
/// equals the merged dense forward exactly.
pub fn merge_adapters(store: &mut ParamStore, manifest: &Manifest)
    -> Result<MergeState> {
    let scale = manifest.config.lora_scale() as f32;
    let mut saved = Vec::with_capacity(2 * manifest.linears.len());
    for li in &manifest.linears {
        let Some((a, b)) = store.lora_pair(li) else {
            bail!("store layout has no adapters for {:?} (already merged, \
                   or a full/cls store?)", li.name);
        };
        let r = store.layout.meta(&li.a)?.rows();
        let delta = adapter_delta(a, b, li.m, li.n, r, scale);
        saved.push((li.name.clone(), store.slice(&li.name)?.to_vec()));
        saved.push((li.b.clone(), store.slice(&li.b)?.to_vec()));
        for (w, d) in store.slice_mut(&li.name)?.iter_mut().zip(&delta) {
            *w += d;
        }
        store.slice_mut(&li.b)?.fill(0.0);
    }
    Ok(MergeState { saved })
}

/// Restore the exact pre-merge parameters saved by [`merge_adapters`].
pub fn unmerge_adapters(store: &mut ParamStore, state: &MergeState)
    -> Result<()> {
    for (name, data) in &state.saved {
        let dst = store.slice_mut(name)?;
        ensure!(dst.len() == data.len(),
                "unmerge shape drift for {name:?}");
        dst.copy_from_slice(data);
    }
    Ok(())
}

/// Export a LoRA-variant store as a full-variant store with every
/// adapter folded into its dense weight — the deployment artifact.
pub fn merged_full_store(manifest: &Manifest, lora_store: &ParamStore)
    -> Result<ParamStore> {
    let scale = manifest.config.lora_scale() as f32;
    let layout =
        std::sync::Arc::new(manifest.layout(Variant::Full)?.clone());
    let mut full = ParamStore::zeros(layout);
    // embeddings, norms, head and the base W's share names and shapes
    let copied = crate::model::init::copy_shared(lora_store, &mut full);
    ensure!(copied == full.layout.params.len(),
            "merged export copied {copied} of {} full-variant params",
            full.layout.params.len());
    for li in &manifest.linears {
        let Some((a, b)) = lora_store.lora_pair(li) else {
            bail!("store layout has no adapters for {:?}", li.name);
        };
        let r = lora_store.layout.meta(&li.a)?.rows();
        let delta = adapter_delta(a, b, li.m, li.n, r, scale);
        for (w, d) in full.slice_mut(&li.name)?.iter_mut().zip(&delta) {
            *w += d;
        }
    }
    Ok(full)
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::model::init::seeded_store;
    use crate::util::rng::Rng;

    fn lora_store(man: &Manifest, seed: u64) -> ParamStore {
        seeded_store(man, Variant::Lora, seed).unwrap()
    }

    #[test]
    fn adapter_delta_matches_naive_triple_loop() {
        let (m, n, r) = (5, 7, 3);
        let mut rng = Rng::new(1);
        let a: Vec<f32> =
            (0..r * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> =
            (0..m * r).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let s = 0.37f32;
        let d = adapter_delta(&a, &b, m, n, r, s);
        for o in 0..m {
            for kk in 0..n {
                let mut acc = 0.0f64;
                for j in 0..r {
                    acc += (b[o * r + j] as f64) * (a[j * n + kk] as f64);
                }
                let want = s as f64 * acc;
                let got = d[o * n + kk] as f64;
                assert!((got - want).abs() < 1e-5,
                        "delta[{o},{kk}]: {got} vs {want}");
            }
        }
    }

    #[test]
    fn in_place_merge_roundtrip_is_bitwise() {
        let man = Manifest::builtin("tiny").unwrap();
        let mut store = lora_store(&man, 7);
        let before = store.data.clone();
        let state = merge_adapters(&mut store, &man).unwrap();
        assert_eq!(state.n_merged(), man.linears.len());
        // merged base weights moved, B factors are zero, A untouched
        let li = &man.linears[0];
        assert_ne!(store.slice(&li.name).unwrap(),
                   &before[store.layout.meta(&li.name).unwrap().offset..]
                       [..li.m * li.n]);
        assert!(store.slice(&li.b).unwrap().iter().all(|&x| x == 0.0));
        unmerge_adapters(&mut store, &state).unwrap();
        assert_eq!(store.data, before);
    }

    #[test]
    fn merge_rejects_full_layout() {
        let man = Manifest::builtin("tiny").unwrap();
        let mut full = ParamStore::zeros(Arc::new(man.full.clone()));
        assert!(merge_adapters(&mut full, &man).is_err());
        assert!(merged_full_store(&man, &full).is_err());
    }

    #[test]
    fn export_matches_in_place_merge_bitwise() {
        let man = Manifest::builtin("tiny").unwrap();
        let store = lora_store(&man, 11);
        let full = merged_full_store(&man, &store).unwrap();
        let mut merged = store.clone();
        merge_adapters(&mut merged, &man).unwrap();
        for p in &full.layout.params {
            assert_eq!(full.slice(&p.name).unwrap(),
                       merged.slice(&p.name).unwrap(),
                       "param {} differs between export and in-place \
                        merge", p.name);
        }
    }
}
