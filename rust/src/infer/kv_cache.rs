//! Paged per-layer key/value cache for incremental autoregressive
//! decoding.
//!
//! During generation each new token only needs its *own* q/k/v plus the
//! keys and values of every earlier position — which never change once
//! computed (RoPE is applied at the absolute position before caching).
//! Caching them turns per-token decode cost from O(T²) re-forward work
//! into O(T): one attention sweep over the cache per layer.
//!
//! Storage is **paged**: K/V rows live in fixed-size blocks of
//! [`KvCache::block`] positions handed out from a shared per-layer pool,
//! and each sequence owns a *block table* (an ordered list of block ids)
//! instead of a pre-reserved `[capacity, head_dim]` strip.  One logical
//! block id spans every layer and both K and V — block `b` of layer `l`
//! lives at element offset `((b·heads + h)·block + p)·head_dim` of that
//! layer's pool buffer — so the table is shared across layers and a
//! block allocation grows all `2·layers` buffers together.  The pool
//! grows lazily one block at a time up to
//! `batch · ceil(capacity / block)` blocks, which means:
//!
//!   * resident KV bytes scale with *live tokens* (block-rounded), not
//!     with `batch × capacity` — a serve process with `--max-batch 32`
//!     no longer reserves 32 full contexts up front;
//!   * a retiring sequence returns its blocks to the free list in
//!     O(blocks), and they are immediately reusable by any peer;
//!   * allocation can never fail mid-decode: per-sequence overflow is
//!     checked against `capacity` first, so the pool ceiling is a true
//!     invariant.
//!
//! Blocks are dtype-tagged exactly like the old slab (`--kv-dtype`):
//! `f32` (exact), `bf16` (half the bytes, RNE-rounded), or `int8`
//! (quarter the bytes, symmetric per-position-row quantization with one
//! f32 scale per `(block, head, pos)` row — the same scheme the frozen
//! base uses).  Sequences advance independently (`lens` is
//! per-sequence), so ragged prompts and per-sequence stops in a batched
//! decode loop need no padding or masking.
//!
//! Attention over the cache runs on the shared kernel layer: the f32
//! mode hands the block table to [`crate::kernels::cached_attend_paged`],
//! which mirrors the contiguous [`crate::kernels::cached_attend`]
//! operation-for-operation (same dot-product, max-subtraction and
//! normalization order per row — only the *address* of each K/V row goes
//! through the table), so paged decode reproduces the contiguous logits
//! **bit-for-bit** — the PR 4 determinism contract, pinned by
//! `rust/tests/inference.rs` and the unit tests below.  Quantized modes
//! gather-dequantize the live prefix blockwise into a reused f32 scratch
//! (identical rows in identical order to the old slab walk) before the
//! same contiguous kernel.

use crate::kernels;
use crate::tensor::dtype::{bf16_to_f32, f32_to_bf16, quantize_row_i8,
                           DType};

/// Default block size in positions (`--kv-block`): 32 rows × head_dim
/// per (head, block) — small enough that short requests stay cheap,
/// large enough that the block table stays tiny.
pub const DEFAULT_KV_BLOCK: usize = 32;

/// One layer's K or V block pool in the cache's dtype.
enum KvBuf {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    /// codes plus one symmetric scale per `(block, head, pos)` head-dim
    /// row (quantized at append time; rows past a sequence's length are
    /// dead until overwritten)
    I8 { q: Vec<i8>, scales: Vec<f32> },
}

impl KvBuf {
    fn new(dtype: DType) -> KvBuf {
        match dtype {
            DType::F32 => KvBuf::F32(Vec::new()),
            DType::Bf16 => KvBuf::Bf16(Vec::new()),
            DType::I8 => KvBuf::I8 { q: Vec::new(), scales: Vec::new() },
        }
    }

    /// Append one zeroed block's worth of storage to the pool.
    fn grow(&mut self, numel: usize, rows: usize) {
        match self {
            KvBuf::F32(d) => d.resize(d.len() + numel, 0.0),
            KvBuf::Bf16(d) => d.resize(d.len() + numel, 0),
            KvBuf::I8 { q, scales } => {
                q.resize(q.len() + numel, 0);
                scales.resize(scales.len() + rows, 0.0);
            }
        }
    }

    /// Store `src` (whole head-dim rows) at element offset `dst`
    /// (`dst` is a multiple of `hd`, `src.len()` a multiple of `hd`).
    fn store_rows(&mut self, dst: usize, src: &[f32], hd: usize) {
        match self {
            KvBuf::F32(d) => {
                d[dst..dst + src.len()].copy_from_slice(src);
            }
            KvBuf::Bf16(d) => {
                for (o, &x) in d[dst..dst + src.len()].iter_mut()
                    .zip(src) {
                    *o = f32_to_bf16(x);
                }
            }
            KvBuf::I8 { q, scales } => {
                for (r, row) in src.chunks_exact(hd).enumerate() {
                    let o = dst + r * hd;
                    scales[o / hd] =
                        quantize_row_i8(row, &mut q[o..o + hd]);
                }
            }
        }
    }

    /// Dequantize whole head-dim rows `[src, src + out.len())` (element
    /// offsets) into `out`.
    fn load_rows(&self, src: usize, out: &mut [f32], hd: usize) {
        match self {
            KvBuf::F32(d) => out.copy_from_slice(&d[src..src + out.len()]),
            KvBuf::Bf16(d) => {
                for (o, &b) in out.iter_mut()
                    .zip(&d[src..src + out.len()]) {
                    *o = bf16_to_f32(b);
                }
            }
            KvBuf::I8 { q, scales } => {
                for (r, row) in out.chunks_exact_mut(hd).enumerate() {
                    let o = src + r * hd;
                    let s = scales[o / hd];
                    for (y, &c) in row.iter_mut().zip(&q[o..o + hd]) {
                        *y = s * c as f32;
                    }
                }
            }
        }
    }

    /// Resident bytes (int8 includes its per-row f32 scales).
    fn bytes(&self) -> usize {
        match self {
            KvBuf::F32(d) => 4 * d.len(),
            KvBuf::Bf16(d) => 2 * d.len(),
            KvBuf::I8 { q, scales } => q.len() + 4 * scales.len(),
        }
    }
}

/// Paged key/value cache over `layers × batch` independent sequences.
pub struct KvCache {
    pub layers: usize,
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// maximum positions per sequence
    pub capacity: usize,
    /// positions per block (`--kv-block`)
    pub block: usize,
    /// storage dtype of the K/V blocks (`--kv-dtype`)
    dtype: DType,
    /// tokens currently cached, per sequence
    lens: Vec<usize>,
    /// slot lifecycle for continuous-batching schedulers: sequence
    /// indices not currently owned by a live request, lowest on top.
    /// Purely bookkeeping — batch-at-once users (`infer::generate`)
    /// index slots directly and never touch it.
    free: Vec<usize>,
    /// per-sequence block table: `tables[seq][i]` stores positions
    /// `i·block .. (i+1)·block`; one id spans all layers and K+V
    tables: Vec<Vec<u32>>,
    /// pool block ids owned by no sequence (most recently freed on top)
    free_blocks: Vec<u32>,
    /// blocks ever allocated — the pool high-water mark
    n_blocks: usize,
    /// allocation ceiling: `batch · ceil(capacity / block)`
    max_blocks: usize,
    /// per layer: block pool, `[n_blocks · heads · block, head_dim]`
    k: Vec<KvBuf>,
    v: Vec<KvBuf>,
    /// score-row scratch reused across `attend` calls (the per-layer
    /// decode hot path would otherwise heap-allocate per call)
    scratch: Vec<f32>,
    /// dequantized `[heads, ctx, head_dim]` K/V scratch for the packed
    /// storage modes, reused across `attend` calls
    kdq: Vec<f32>,
    vdq: Vec<f32>,
}

impl KvCache {
    /// An exact f32 cache — the default storage mode.
    pub fn new(layers: usize, batch: usize, heads: usize, head_dim: usize,
               capacity: usize) -> KvCache {
        KvCache::with_dtype(layers, batch, heads, head_dim, capacity,
                            DType::F32)
    }

    /// A cache storing K/V in `dtype` (`--kv-dtype`) with the default
    /// block size.
    pub fn with_dtype(layers: usize, batch: usize, heads: usize,
                      head_dim: usize, capacity: usize, dtype: DType)
        -> KvCache {
        KvCache::with_layout(layers, batch, heads, head_dim, capacity,
                             dtype, DEFAULT_KV_BLOCK)
    }

    /// Full-layout constructor: `dtype` storage in blocks of `block`
    /// positions (`--kv-block`).  Nothing is allocated up front — the
    /// pool grows block-by-block as sequences append.
    pub fn with_layout(layers: usize, batch: usize, heads: usize,
                       head_dim: usize, capacity: usize, dtype: DType,
                       block: usize) -> KvCache {
        assert!(layers > 0 && batch > 0 && heads > 0 && head_dim > 0
                && capacity > 0, "degenerate KV cache shape");
        assert!(block > 0, "degenerate KV block size");
        KvCache {
            layers,
            batch,
            heads,
            head_dim,
            capacity,
            block,
            dtype,
            lens: vec![0; batch],
            free: (0..batch).rev().collect(),
            tables: vec![Vec::new(); batch],
            free_blocks: Vec::new(),
            n_blocks: 0,
            max_blocks: batch * capacity.div_ceil(block),
            k: (0..layers).map(|_| KvBuf::new(dtype)).collect(),
            v: (0..layers).map(|_| KvBuf::new(dtype)).collect(),
            scratch: Vec::new(),
            kdq: Vec::new(),
            vdq: Vec::new(),
        }
    }

    /// Storage dtype of the K/V blocks.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Tokens cached so far for sequence `seq`.
    pub fn len(&self, seq: usize) -> usize {
        self.lens[seq]
    }

    /// Forget all cached positions and return every block to the pool
    /// (the pool allocation itself is kept for the next batch).
    pub fn reset(&mut self) {
        for t in &mut self.tables {
            self.free_blocks.append(t);
        }
        self.lens.fill(0);
        self.free = (0..self.batch).rev().collect();
    }

    /// Claim a free sequence slot for a newly admitted request (lowest
    /// index first), or `None` when every slot is owned.  The slot
    /// starts at length 0 and owns no blocks until its first append.
    pub fn acquire(&mut self) -> Option<usize> {
        let seq = self.free.pop()?;
        self.lens[seq] = 0;
        Some(seq)
    }

    /// Return a retired request's slot to the free list and its blocks
    /// to the pool — O(blocks held), and the blocks are immediately
    /// reusable by any peer.  A request admitted into a recycled slot
    /// decodes bitwise identically to one admitted into a fresh cache
    /// (`rust/tests/serving.rs`).
    pub fn release(&mut self, seq: usize) {
        assert!(seq < self.batch, "slot {seq} out of batch {}", self.batch);
        assert!(!self.free.contains(&seq), "double release of slot {seq}");
        self.free_blocks.append(&mut self.tables[seq]);
        self.lens[seq] = 0;
        self.free.push(seq);
    }

    /// Slots currently available to [`KvCache::acquire`].
    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently owned by live sequences.
    pub fn blocks_live(&self) -> usize {
        self.n_blocks - self.free_blocks.len()
    }

    /// Allocated blocks sitting on the free list.
    pub fn blocks_free(&self) -> usize {
        self.free_blocks.len()
    }

    /// Pool high-water mark: blocks ever allocated.
    pub fn blocks_allocated(&self) -> usize {
        self.n_blocks
    }

    /// Pool ceiling: `batch · ceil(capacity / block)` blocks.
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Bytes one logical block occupies across all layers, K and V.
    pub fn block_bytes(&self) -> usize {
        let e = self.heads * self.block * self.head_dim;
        let r = self.heads * self.block;
        let per_buf = match self.dtype {
            DType::F32 => 4 * e,
            DType::Bf16 => 2 * e,
            DType::I8 => e + 4 * r,
        };
        2 * self.layers * per_buf
    }

    /// What the pre-paging `[batch·heads, capacity, head_dim]` slab
    /// would have reserved up front — the bench baseline for "resident
    /// bytes scale with live tokens".
    pub fn slab_bytes(&self) -> usize {
        let e = self.batch * self.heads * self.capacity * self.head_dim;
        let r = self.batch * self.heads * self.capacity;
        let per_buf = match self.dtype {
            DType::F32 => 4 * e,
            DType::Bf16 => 2 * e,
            DType::I8 => e + 4 * r,
        };
        2 * self.layers * per_buf
    }

    /// Cache memory footprint in bytes (serving-capacity accounting):
    /// the allocated block pool at its storage width, plus the int8
    /// per-row scales when quantized.  Grows with the live-token
    /// high-water mark, not with `batch × capacity`.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|b| b.bytes()).sum()
    }

    /// Elements one block contributes to each per-layer pool buffer.
    #[inline]
    fn blk_elems(&self) -> usize {
        self.heads * self.block * self.head_dim
    }

    /// Flat element offset of `(block id, head, position-in-block)` in a
    /// layer's pool buffer.
    #[inline]
    fn blk_off(&self, blk: usize, head: usize, p: usize) -> usize {
        ((blk * self.heads + head) * self.block + p) * self.head_dim
    }

    /// Hand out a block: recycle the most recently freed one, else grow
    /// every layer's pool by one block.  The ceiling is unreachable in
    /// correct use — per-sequence overflow is checked against `capacity`
    /// first — so this assert is an allocator invariant, not a user
    /// error path.
    fn alloc_block(&mut self) -> u32 {
        if let Some(b) = self.free_blocks.pop() {
            return b;
        }
        assert!(self.n_blocks < self.max_blocks,
                "KV pool invariant broken: {} blocks exceeds ceiling {}",
                self.n_blocks + 1, self.max_blocks);
        let id = self.n_blocks as u32;
        self.n_blocks += 1;
        let (ne, nr) = (self.blk_elems(), self.heads * self.block);
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.grow(ne, nr);
        }
        id
    }

    /// Grow `seq`'s block table until it covers positions `0..upto`.
    /// Idempotent — every layer's append calls this with the same range.
    fn ensure_blocks(&mut self, seq: usize, upto: usize) {
        while self.tables[seq].len() * self.block < upto {
            let b = self.alloc_block();
            self.tables[seq].push(b);
        }
    }

    /// Append `t_new` RoPE'd key rows and value rows for sequence `seq`
    /// at its current length.  `k_new`/`v_new` are `[heads, t_new,
    /// head_dim]` (the `to_heads` layout of one sequence's chunk).  The
    /// sequence length is NOT advanced — every layer appends at the same
    /// base position; call [`KvCache::bump`] once after the last layer.
    pub fn append(&mut self, layer: usize, seq: usize, k_new: &[f32],
                  v_new: &[f32], t_new: usize) {
        let (nh, hd, blk) = (self.heads, self.head_dim, self.block);
        let base = self.lens[seq];
        assert!(base + t_new <= self.capacity,
                "KV cache overflow: {base}+{t_new} > {}", self.capacity);
        assert_eq!(k_new.len(), nh * t_new * hd, "k chunk shape");
        assert_eq!(v_new.len(), nh * t_new * hd, "v chunk shape");
        self.ensure_blocks(seq, base + t_new);
        // walk the chunk in per-block runs of global positions
        let mut p = base;
        while p < base + t_new {
            let b = self.tables[seq][p / blk] as usize;
            let off = p % blk;
            let run = (blk - off).min(base + t_new - p);
            for h in 0..nh {
                let src = (h * t_new + (p - base)) * hd;
                let dst = self.blk_off(b, h, off);
                self.k[layer].store_rows(dst,
                                         &k_new[src..src + run * hd], hd);
                self.v[layer].store_rows(dst,
                                         &v_new[src..src + run * hd], hd);
            }
            p += run;
        }
    }

    /// Advance sequence `seq` by `t_new` cached positions (once per
    /// appended chunk, after all layers have run).
    pub fn bump(&mut self, seq: usize, t_new: usize) {
        self.lens[seq] += t_new;
        debug_assert!(self.lens[seq] <= self.capacity);
    }

    /// Causal softmax attention of a freshly-appended chunk's queries
    /// over this sequence's cache: `q` is `[heads, t_new, head_dim]`
    /// (RoPE'd at absolute positions `len..len+t_new`), its K/V already
    /// appended via [`KvCache::append`].  Chunk row `i` attends to cached
    /// positions `0..len+i+1`, which is exactly full causal attention.
    /// Returns `[heads, t_new, head_dim]`.
    ///
    /// The f32 storage mode hands the kernel the pool slices plus the
    /// block table zero-copy; packed modes gather-dequantize only the
    /// live prefix (`0..len+t_new`) of each head into reused scratch, so
    /// decode never touches dead capacity.
    pub fn attend(&mut self, layer: usize, seq: usize, q: &[f32],
                  t_new: usize) -> Vec<f32> {
        let (nh, hd, blk) = (self.heads, self.head_dim, self.block);
        let base = self.lens[seq];
        let ctx = base + t_new;
        assert_eq!(q.len(), nh * t_new * hd, "q chunk shape");
        debug_assert!(self.tables[seq].len() * blk >= ctx,
                      "attend past the appended range");
        let mut scratch = std::mem::take(&mut self.scratch);
        let o = if self.dtype == DType::F32 {
            let (kp, vp) = match (&self.k[layer], &self.v[layer]) {
                (KvBuf::F32(kd), KvBuf::F32(vd)) => {
                    (kd.as_slice(), vd.as_slice())
                }
                _ => unreachable!("f32 cache holds f32 buffers"),
            };
            kernels::cached_attend_paged(q, kp, vp, &self.tables[seq],
                                         nh, t_new, base, blk, hd,
                                         &mut scratch)
        } else {
            let mut kdq = std::mem::take(&mut self.kdq);
            let mut vdq = std::mem::take(&mut self.vdq);
            kdq.resize(nh * ctx * hd, 0.0);
            vdq.resize(nh * ctx * hd, 0.0);
            // gather-dequantize the live prefix block run by block run;
            // rows land in the same [nh, ctx, hd] order the old slab
            // walk produced, so the kernel sees identical inputs
            let mut p = 0;
            while p < ctx {
                let b = self.tables[seq][p / blk] as usize;
                let run = blk.min(ctx - p);
                for h in 0..nh {
                    let src = self.blk_off(b, h, 0);
                    let dst = (h * ctx + p) * hd;
                    self.k[layer].load_rows(
                        src, &mut kdq[dst..dst + run * hd], hd);
                    self.v[layer].load_rows(
                        src, &mut vdq[dst..dst + run * hd], hd);
                }
                p += run;
            }
            // the dequantized copy is tight: capacity == ctx
            let o = kernels::cached_attend(q, &kdq, &vdq, nh, t_new,
                                           base, ctx, hd, &mut scratch);
            self.kdq = kdq;
            self.vdq = vdq;
            o
        };
        self.scratch = scratch;
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::causal_attention_fwd;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
    }

    #[test]
    fn append_then_attend_matches_full_causal_attention() {
        prop_check("cache attend == causal_attention_fwd", 20, |rng| {
            let nh = 1 + rng.below(3);
            let hd = 2 * (1 + rng.below(4));
            let t = 2 + rng.below(6);
            let q = randv(nh * t * hd, rng);
            let k = randv(nh * t * hd, rng);
            let v = randv(nh * t * hd, rng);
            let (want, _) = causal_attention_fwd(&q, &k, &v, nh, t, hd);
            // feed the same q/k/v through the cache one token at a time,
            // with a tiny block size so the walk crosses boundaries
            let mut cache = KvCache::with_layout(1, 1, nh, hd, t,
                                                 DType::F32, 2);
            let mut got = vec![0.0f32; nh * t * hd];
            for i in 0..t {
                let pick = |x: &[f32]| -> Vec<f32> {
                    (0..nh)
                        .flat_map(|h| {
                            x[(h * t + i) * hd..(h * t + i + 1) * hd]
                                .to_vec()
                        })
                        .collect()
                };
                let (qi, ki, vi) = (pick(&q), pick(&k), pick(&v));
                cache.append(0, 0, &ki, &vi, 1);
                let oi = cache.attend(0, 0, &qi, 1);
                cache.bump(0, 1);
                for h in 0..nh {
                    got[(h * t + i) * hd..(h * t + i + 1) * hd]
                        .copy_from_slice(&oi[h * hd..(h + 1) * hd]);
                }
            }
            assert_close(&got, &want, 1e-5, 1e-6)
        });
    }

    #[test]
    fn chunked_append_equals_one_shot() {
        let mut rng = Rng::new(5);
        let (nh, hd, t) = (2, 4, 6);
        let q = randv(nh * t * hd, &mut rng);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let mut one = KvCache::new(1, 1, nh, hd, t);
        one.append(0, 0, &k, &v, t);
        let want = one.attend(0, 0, &q, t);
        // split the chunk 4 + 2, with a block size that straddles the
        // split (block 3: positions 3..6 span two blocks)
        let split = 4;
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        let mut two = KvCache::with_layout(1, 1, nh, hd, t, DType::F32, 3);
        two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split), split);
        let o1 = two.attend(0, 0, &part(&q, 0, split), split);
        two.bump(0, split);
        two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                   t - split);
        let o2 = two.attend(0, 0, &part(&q, split, t), t - split);
        two.bump(0, t - split);
        assert_eq!(two.len(0), t);
        for h in 0..nh {
            for i in 0..t {
                let w = &want[(h * t + i) * hd..(h * t + i + 1) * hd];
                let g = if i < split {
                    &o1[(h * split + i) * hd..(h * split + i + 1) * hd]
                } else {
                    let ii = i - split;
                    let tn = t - split;
                    &o2[(h * tn + ii) * hd..(h * tn + ii + 1) * hd]
                };
                assert_close(g, w, 1e-6, 1e-7).unwrap();
            }
        }
    }

    #[test]
    fn paged_decode_is_bitwise_identical_across_block_sizes() {
        // The paged attend path must reproduce the single-block
        // (contiguous) layout bit-for-bit for every storage mode: same
        // per-row values, same serial accumulation order — only the
        // addresses differ.
        let mut rng = Rng::new(77);
        let (nh, hd, t) = (3, 8, 13);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let q = randv(nh * t * hd, &mut rng);
        let pick = |x: &[f32], i: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + i) * hd..(h * t + i + 1) * hd].to_vec()
                })
                .collect()
        };
        let bits = |x: &[f32]| -> Vec<u32> {
            x.iter().map(|v| v.to_bits()).collect()
        };
        for dtype in [DType::F32, DType::Bf16, DType::I8] {
            // block 4 (boundaries mid-sequence) vs block t (one block ==
            // the old contiguous strip)
            let mut paged =
                KvCache::with_layout(1, 1, nh, hd, t, dtype, 4);
            let mut contig =
                KvCache::with_layout(1, 1, nh, hd, t, dtype, t);
            for i in 0..t {
                let (qi, ki, vi) = (pick(&q, i), pick(&k, i), pick(&v, i));
                paged.append(0, 0, &ki, &vi, 1);
                contig.append(0, 0, &ki, &vi, 1);
                let op = paged.attend(0, 0, &qi, 1);
                let oc = contig.attend(0, 0, &qi, 1);
                paged.bump(0, 1);
                contig.bump(0, 1);
                assert_eq!(bits(&op), bits(&oc),
                           "{dtype} diverged at position {i}");
            }
        }
    }

    #[test]
    fn sequences_are_independent() {
        let mut rng = Rng::new(9);
        let (nh, hd) = (2, 4);
        let mut cache = KvCache::with_layout(1, 3, nh, hd, 8,
                                             DType::F32, 2);
        let k0 = randv(nh * hd, &mut rng);
        let v0 = randv(nh * hd, &mut rng);
        cache.append(0, 0, &k0, &v0, 1);
        cache.bump(0, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (1, 0, 2));
        assert_eq!(cache.blocks_live(), 2); // one block each for 0 and 2
        cache.reset();
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (0, 0, 0));
        assert_eq!(cache.blocks_live(), 0);
        assert_eq!(cache.blocks_free(), 2); // pool retained, not shrunk
    }

    #[test]
    fn slot_lifecycle_acquire_release_reset() {
        let mut c = KvCache::new(1, 3, 1, 2, 4);
        assert_eq!(c.n_free(), 3);
        // lowest slot first, so admission order matches sequence order
        assert_eq!(c.acquire(), Some(0));
        assert_eq!(c.acquire(), Some(1));
        assert_eq!(c.acquire(), Some(2));
        assert_eq!(c.acquire(), None);
        let kv = vec![0.5f32; 2];
        c.append(0, 1, &kv, &kv, 1);
        c.bump(1, 1);
        assert_eq!(c.len(1), 1);
        // the retired slot comes back with length 0 and is reused
        // before lower-numbered never-freed slots
        c.release(1);
        assert_eq!((c.n_free(), c.len(1)), (1, 0));
        assert_eq!(c.acquire(), Some(1));
        c.release(1);
        c.release(0);
        c.release(2);
        c.reset();
        assert_eq!(c.n_free(), 3);
        assert_eq!(c.acquire(), Some(0));
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut c = KvCache::new(1, 2, 1, 2, 4);
        let s = c.acquire().unwrap();
        c.release(s);
        c.release(s);
    }

    #[test]
    fn pool_grows_with_live_tokens_and_recycles_on_release() {
        // batch 4, capacity 16, block 4 → ceiling 16 blocks; nothing is
        // reserved up front, bytes grow block-by-block with appends,
        // and released blocks are recycled before the pool grows again.
        let (nh, hd, blk) = (2, 4, 4);
        let mut c = KvCache::with_layout(2, 4, nh, hd, 16, DType::F32,
                                         blk);
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.blocks_allocated(), 0);
        assert_eq!(c.max_blocks(), 16);
        let row = vec![0.25f32; nh * hd];
        let fill = |c: &mut KvCache, seq: usize, n: usize| {
            for _ in 0..n {
                for l in 0..2 {
                    c.append(l, seq, &row, &row, 1);
                }
                c.bump(seq, 1);
            }
        };
        let s0 = c.acquire().unwrap();
        fill(&mut c, s0, 5); // 5 tokens → 2 blocks
        assert_eq!((c.blocks_live(), c.blocks_allocated()), (2, 2));
        assert_eq!(c.bytes(), 2 * c.block_bytes());
        let s1 = c.acquire().unwrap();
        fill(&mut c, s1, 4); // exactly 1 block
        assert_eq!((c.blocks_live(), c.blocks_allocated()), (3, 3));
        // release s0: its 2 blocks return in O(blocks)
        c.release(s0);
        assert_eq!((c.blocks_live(), c.blocks_free()), (1, 2));
        // a new sequence reuses freed blocks — allocation stays at 3
        let s2 = c.acquire().unwrap();
        fill(&mut c, s2, 8); // needs 2 blocks, both recycled
        assert_eq!((c.blocks_live(), c.blocks_allocated()), (3, 3));
        assert_eq!(c.bytes(), 3 * c.block_bytes());
        // drain everything: free count returns to the full allocation
        c.release(s1);
        c.release(s2);
        assert_eq!((c.blocks_live(), c.blocks_free()), (0, 3));
        // the paged pool undercuts the old up-front slab by design
        assert!(c.bytes() < c.slab_bytes(),
                "pool {} >= slab {}", c.bytes(), c.slab_bytes());
    }

    #[test]
    fn bytes_accounting() {
        // pool bytes are exact multiples of block_bytes() and grow only
        // with appends — never with batch or capacity headroom
        let (nh, hd, blk) = (4, 8, 8);
        for dtype in [DType::F32, DType::Bf16, DType::I8] {
            let mut c = KvCache::with_layout(2, 3, nh, hd, 16, dtype,
                                             blk);
            assert_eq!(c.bytes(), 0, "{dtype}: nothing reserved up front");
            let row = vec![0.5f32; nh * hd];
            for l in 0..2 {
                c.append(l, 0, &row, &row, 1);
            }
            c.bump(0, 1);
            // one token → one block, at the dtype's storage width
            let e = nh * blk * hd;
            let r = nh * blk;
            let per_buf = match dtype {
                DType::F32 => 4 * e,
                DType::Bf16 => 2 * e,
                DType::I8 => e + 4 * r,
            };
            assert_eq!(c.block_bytes(), 2 * 2 * per_buf, "{dtype}");
            assert_eq!(c.bytes(), c.block_bytes(), "{dtype}");
            assert_eq!(c.dtype(), dtype);
        }
    }

    #[test]
    fn quantized_cache_attends_close_to_f32() {
        // bf16/int8 storage perturbs K/V by at most one quantization
        // step per element; the attention output (a convex combination
        // of V rows re-weighted by slightly-off scores) stays close
        for (dtype, tol) in [(DType::Bf16, 0.02), (DType::I8, 0.08)] {
            prop_check("quantized KV attend close", 10, move |rng| {
                let nh = 1 + rng.below(3);
                let hd = 4 * (1 + rng.below(3));
                let t = 2 + rng.below(8);
                let q = randv(nh * t * hd, rng);
                let k = randv(nh * t * hd, rng);
                let v = randv(nh * t * hd, rng);
                let mut exact = KvCache::new(1, 1, nh, hd, t);
                exact.append(0, 0, &k, &v, t);
                let want = exact.attend(0, 0, &q, t);
                let mut quant =
                    KvCache::with_dtype(1, 1, nh, hd, t, dtype);
                quant.append(0, 0, &k, &v, t);
                let got = quant.attend(0, 0, &q, t);
                for (g, w) in got.iter().zip(&want) {
                    if (g - w).abs() > tol {
                        return Err(format!(
                            "{dtype}: {g} vs {w} (tol {tol})"));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn quantized_chunked_append_is_position_consistent() {
        // appending in chunks quantizes exactly the same per-position
        // rows, so chunked == one-shot bitwise for every storage mode —
        // including across block boundaries (block 3 vs one-shot's
        // identical layout)
        let mut rng = Rng::new(31);
        let (nh, hd, t, split) = (2, 8, 6, 4);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let q = randv(nh * (t - split) * hd, &mut rng);
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        for dtype in [DType::Bf16, DType::I8] {
            let mut one = KvCache::with_layout(1, 1, nh, hd, t, dtype, 3);
            one.append(0, 0, &k, &v, t);
            one.bump(0, split); // queries sit at positions split..t
            let want = one.attend(0, 0, &q, t - split);
            let mut two = KvCache::with_layout(1, 1, nh, hd, t, dtype, 3);
            two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split),
                       split);
            two.bump(0, split);
            two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                       t - split);
            let got = two.attend(0, 0, &q, t - split);
            let bits = |x: &[f32]| -> Vec<u32> {
                x.iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(bits(&got), bits(&want), "{dtype}");
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1, 2, 2);
        let kv = vec![0.0; 2];
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
    }
}
