//! Paged per-layer key/value cache for incremental autoregressive
//! decoding.
//!
//! During generation each new token only needs its *own* q/k/v plus the
//! keys and values of every earlier position — which never change once
//! computed (RoPE is applied at the absolute position before caching).
//! Caching them turns per-token decode cost from O(T²) re-forward work
//! into O(T): one attention sweep over the cache per layer.
//!
//! Storage is **paged**: K/V rows live in fixed-size blocks of
//! [`KvCache::block`] positions handed out from a shared per-layer pool,
//! and each sequence owns a *block table* (an ordered list of block ids)
//! instead of a pre-reserved `[capacity, head_dim]` strip.  One logical
//! block id spans every layer and both K and V — block `b` of layer `l`
//! lives at element offset `((b·heads + h)·block + p)·head_dim` of that
//! layer's pool buffer — so the table is shared across layers and a
//! block allocation grows all `2·layers` buffers together.  The pool
//! grows lazily one block at a time up to
//! `batch · ceil(capacity / block)` blocks, which means:
//!
//!   * resident KV bytes scale with *live tokens* (block-rounded), not
//!     with `batch × capacity` — a serve process with `--max-batch 32`
//!     no longer reserves 32 full contexts up front;
//!   * a retiring sequence returns its blocks to the free list in
//!     O(blocks), and they are immediately reusable by any peer;
//!   * allocation can never fail mid-decode: per-sequence overflow is
//!     checked against `capacity` first, so the pool ceiling is a true
//!     invariant.
//!
//! On top of the paged pool sits an optional **prefix cache**
//! ([`KvCache::enable_prefix`], `--prefix-cache`): once a block is
//! completely filled it is *sealed* — immutable and shareable — and
//! registered under a parent-chained FNV-1a hash of its token ids (the
//! vLLM lineage scheme: block `i`'s key folds block `i-1`'s key, so one
//! lookup walk matches whole prefixes, never mid-sequence content).
//! Admission ([`KvCache::admit_prefix`]) walks the chain for the longest
//! sealed prefix of an incoming prompt, bumps per-block refcounts and
//! splices the block ids into the new sequence's table, so only the
//! uncached suffix is prefilled.  [`KvCache::release`] then returns a
//! still-sealed block to an LRU *prefix pool* (budget blocks, evicted
//! leaf-first) instead of the free list, keeping it warm for the next
//! request with the same opening.  Keys are namespaced by tenant
//! (adapter) and verified against the stored token ids on lookup, and a
//! sealed block holds exactly the dtype-tagged rows a deterministic
//! prefill would recompute — so a prefix-warm decode is **bitwise
//! identical** to the cold path at f32/bf16/int8, and a hash collision
//! can never splice wrong content.  The partially-filled tail block is
//! always private, and a write aimed at a shared or sealed block
//! copies-on-write into a fresh private block first (defensive: the
//! admission cap keeps suffix writes past every shared block).
//!
//! Blocks are dtype-tagged exactly like the old slab (`--kv-dtype`):
//! `f32` (exact), `bf16` (half the bytes, RNE-rounded), or `int8`
//! (quarter the bytes, symmetric per-position-row quantization with one
//! f32 scale per `(block, head, pos)` row — the same scheme the frozen
//! base uses).  Sequences advance independently (`lens` is
//! per-sequence), so ragged prompts and per-sequence stops in a batched
//! decode loop need no padding or masking.
//!
//! Attention over the cache runs on the shared kernel layer: the f32
//! mode hands the block table to [`crate::kernels::cached_attend_paged`],
//! which mirrors the contiguous [`crate::kernels::cached_attend`]
//! operation-for-operation (same dot-product, max-subtraction and
//! normalization order per row — only the *address* of each K/V row goes
//! through the table), so paged decode reproduces the contiguous logits
//! **bit-for-bit** — the PR 4 determinism contract, pinned by
//! `rust/tests/inference.rs` and the unit tests below.  Quantized modes
//! gather-dequantize the live prefix blockwise into a reused f32 scratch
//! (identical rows in identical order to the old slab walk) before the
//! same contiguous kernel.

use std::collections::HashMap;

use crate::kernels;
use crate::tensor::dtype::{bf16_to_f32, f32_to_bf16, quantize_row_i8,
                           DType};

/// Default block size in positions (`--kv-block`): 32 rows × head_dim
/// per (head, block) — small enough that short requests stay cheap,
/// large enough that the block table stays tiny.
pub const DEFAULT_KV_BLOCK: usize = 32;

/// One layer's K or V block pool in the cache's dtype.
enum KvBuf {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    /// codes plus one symmetric scale per `(block, head, pos)` head-dim
    /// row (quantized at append time; rows past a sequence's length are
    /// dead until overwritten)
    I8 { q: Vec<i8>, scales: Vec<f32> },
}

impl KvBuf {
    fn new(dtype: DType) -> KvBuf {
        match dtype {
            DType::F32 => KvBuf::F32(Vec::new()),
            DType::Bf16 => KvBuf::Bf16(Vec::new()),
            DType::I8 => KvBuf::I8 { q: Vec::new(), scales: Vec::new() },
        }
    }

    /// Append one zeroed block's worth of storage to the pool.
    fn grow(&mut self, numel: usize, rows: usize) {
        match self {
            KvBuf::F32(d) => d.resize(d.len() + numel, 0.0),
            KvBuf::Bf16(d) => d.resize(d.len() + numel, 0),
            KvBuf::I8 { q, scales } => {
                q.resize(q.len() + numel, 0);
                scales.resize(scales.len() + rows, 0.0);
            }
        }
    }

    /// Store `src` (whole head-dim rows) at element offset `dst`
    /// (`dst` is a multiple of `hd`, `src.len()` a multiple of `hd`).
    fn store_rows(&mut self, dst: usize, src: &[f32], hd: usize) {
        match self {
            KvBuf::F32(d) => {
                d[dst..dst + src.len()].copy_from_slice(src);
            }
            KvBuf::Bf16(d) => {
                for (o, &x) in d[dst..dst + src.len()].iter_mut()
                    .zip(src) {
                    *o = f32_to_bf16(x);
                }
            }
            KvBuf::I8 { q, scales } => {
                for (r, row) in src.chunks_exact(hd).enumerate() {
                    let o = dst + r * hd;
                    scales[o / hd] =
                        quantize_row_i8(row, &mut q[o..o + hd]);
                }
            }
        }
    }

    /// Dequantize whole head-dim rows `[src, src + out.len())` (element
    /// offsets) into `out`.
    fn load_rows(&self, src: usize, out: &mut [f32], hd: usize) {
        match self {
            KvBuf::F32(d) => out.copy_from_slice(&d[src..src + out.len()]),
            KvBuf::Bf16(d) => {
                for (o, &b) in out.iter_mut()
                    .zip(&d[src..src + out.len()]) {
                    *o = bf16_to_f32(b);
                }
            }
            KvBuf::I8 { q, scales } => {
                for (r, row) in out.chunks_exact_mut(hd).enumerate() {
                    let o = src + r * hd;
                    let s = scales[o / hd];
                    for (y, &c) in row.iter_mut().zip(&q[o..o + hd]) {
                        *y = s * c as f32;
                    }
                }
            }
        }
    }

    /// Copy block `src`'s storage over block `dst`'s (the copy-on-write
    /// path): `numel` elements and `rows` scale rows per block.
    fn copy_block(&mut self, src: usize, dst: usize, numel: usize,
                  rows: usize) {
        match self {
            KvBuf::F32(d) => {
                d.copy_within(src * numel..(src + 1) * numel, dst * numel);
            }
            KvBuf::Bf16(d) => {
                d.copy_within(src * numel..(src + 1) * numel, dst * numel);
            }
            KvBuf::I8 { q, scales } => {
                q.copy_within(src * numel..(src + 1) * numel, dst * numel);
                scales.copy_within(src * rows..(src + 1) * rows,
                                   dst * rows);
            }
        }
    }

    /// Resident bytes (int8 includes its per-row f32 scales).
    fn bytes(&self) -> usize {
        match self {
            KvBuf::F32(d) => 4 * d.len(),
            KvBuf::Bf16(d) => 2 * d.len(),
            KvBuf::I8 { q, scales } => q.len() + 4 * scales.len(),
        }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Chain root for tenant namespace `ns`: adapters change the K/V a
/// prompt produces (wq/wk/wv overlays), so identical token prefixes
/// under different adapters must never share blocks.
fn ns_root(ns: &str) -> u64 {
    fnv1a(FNV_OFFSET, ns.as_bytes())
}

/// Key of the block holding `tokens` whose predecessor chain hashed to
/// `parent` — vLLM-style lineage hashing: equal keys ⇒ equal whole
/// prefixes (up to collisions, which lookup defeats by comparing the
/// stored token ids).
fn chain_hash(parent: u64, tokens: &[i32]) -> u64 {
    let mut h = parent;
    for &t in tokens {
        h = fnv1a(h, &t.to_le_bytes());
    }
    h
}

/// Registry entry for one sealed (full, immutable, shareable) block.
struct SealedMeta {
    /// chain key this block is canonical for
    hash: u64,
    /// chain key of the preceding block (`None` for a prefix head)
    parent: Option<u64>,
    /// exact token ids — lookup verifies these, so a 64-bit hash
    /// collision degrades to a miss, never to wrong K/V
    tokens: Vec<i32>,
    /// tenant namespace the rows were computed under
    ns: String,
    /// currently-registered sealed children (leaf-first eviction)
    children: u32,
    /// LRU stamp: bumped on splice, seal and pool insertion
    last_use: u64,
}

/// A point-in-time snapshot of the prefix cache — the `/healthz`
/// `prefix_cache` object and the `serve.prefix_*` metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub enabled: bool,
    /// sealed blocks spliced into admissions instead of re-prefilled
    pub hit_blocks: u64,
    /// full prompt blocks that were eligible but not cached
    pub miss_blocks: u64,
    /// prompt positions served from the cache (prefill work avoided)
    pub hit_tokens: u64,
    /// pooled blocks reclaimed by the LRU budget
    pub evicted: u64,
    /// sealed blocks currently retained with no live reference
    pub pool_blocks: usize,
    /// blocks currently referenced by two or more live sequences
    pub shared_blocks: usize,
    /// sealed (immutable, shareable) blocks, live or pooled
    pub sealed_blocks: usize,
}

/// Prefix-sharing state layered over the block pool (`--prefix-cache`).
/// Owns the content-hash registry, the per-block refcounts and the LRU
/// pool of released-but-retained blocks; the `KvCache` methods consult
/// it only when present, so `None` is a strict no-op.
struct PrefixCache {
    /// retained-block ceiling (`--prefix-cache-blocks`)
    budget: usize,
    /// canonical chain key → sealed block id
    by_hash: HashMap<u64, u32>,
    /// sealed block id → registry entry (canonical blocks only)
    meta: HashMap<u32, SealedMeta>,
    /// live references per block id (sequence tables holding it)
    refs: Vec<u32>,
    /// sealed blocks with no live reference, retained for reuse
    pool: Vec<u32>,
    /// monotonic LRU clock
    clock: u64,
    hit_blocks: u64,
    miss_blocks: u64,
    hit_tokens: u64,
    evicted: u64,
    /// per-sequence cached-token history (mirrors `lens` positions)
    toks: Vec<Vec<i32>>,
    /// per-sequence tenant namespace
    ns: Vec<String>,
    /// per-sequence chain key after the sealed table prefix
    chain: Vec<u64>,
    /// per-sequence count of sealed leading table entries
    sealed: Vec<usize>,
}

impl PrefixCache {
    fn new(budget: usize, batch: usize) -> PrefixCache {
        PrefixCache {
            budget,
            by_hash: HashMap::new(),
            meta: HashMap::new(),
            refs: Vec::new(),
            pool: Vec::new(),
            clock: 0,
            hit_blocks: 0,
            miss_blocks: 0,
            hit_tokens: 0,
            evicted: 0,
            toks: vec![Vec::new(); batch],
            ns: vec![String::new(); batch],
            chain: vec![0; batch],
            sealed: vec![0; batch],
        }
    }

    /// Mark one live reference on a freshly allocated private block.
    fn track(&mut self, b: u32) {
        let bi = b as usize;
        if self.refs.len() <= bi {
            self.refs.resize(bi + 1, 0);
        }
        self.refs[bi] = 1;
    }

    /// Drop one reference; a block nobody holds goes to the LRU pool if
    /// sealed (still discoverable by admission) or back to `free`.
    fn unref(&mut self, b: u32, free: &mut Vec<u32>) {
        let bi = b as usize;
        self.refs[bi] -= 1;
        if self.refs[bi] > 0 {
            return;
        }
        if self.meta.contains_key(&b) {
            self.clock += 1;
            self.meta.get_mut(&b).unwrap().last_use = self.clock;
            self.pool.push(b);
        } else {
            free.push(b);
        }
    }

    /// Evict pooled blocks until the pool fits the budget again.
    fn evict_over_budget(&mut self, free: &mut Vec<u32>) {
        while self.pool.len() > self.budget {
            self.evict_one(free);
        }
    }

    /// Reclaim one pooled block, leaf-first: a pooled block whose chain
    /// has registered children is a live lookup path for longer
    /// prefixes, so childless (leaf) blocks go first, oldest stamp
    /// wins; if every pooled block still parents a sealed child, fall
    /// back to the global LRU.
    fn evict_one(&mut self, free: &mut Vec<u32>) {
        let pick = self
            .pool
            .iter()
            .copied()
            .enumerate()
            .filter(|(_, b)| self.meta[b].children == 0)
            .min_by_key(|&(_, b)| self.meta[&b].last_use)
            .map(|(i, _)| i)
            .or_else(|| {
                self.pool
                    .iter()
                    .copied()
                    .enumerate()
                    .min_by_key(|&(_, b)| self.meta[&b].last_use)
                    .map(|(i, _)| i)
            });
        let Some(at) = pick else {
            return;
        };
        let b = self.pool.swap_remove(at);
        let m = self.meta.remove(&b).expect("pooled block is sealed");
        self.by_hash.remove(&m.hash);
        if let Some(ph) = m.parent {
            if let Some(&pb) = self.by_hash.get(&ph) {
                if let Some(pm) = self.meta.get_mut(&pb) {
                    pm.children = pm.children.saturating_sub(1);
                }
            }
        }
        free.push(b);
        self.evicted += 1;
    }
}

/// Paged key/value cache over `layers × batch` independent sequences.
pub struct KvCache {
    pub layers: usize,
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// maximum positions per sequence
    pub capacity: usize,
    /// positions per block (`--kv-block`)
    pub block: usize,
    /// storage dtype of the K/V blocks (`--kv-dtype`)
    dtype: DType,
    /// tokens currently cached, per sequence
    lens: Vec<usize>,
    /// slot lifecycle for continuous-batching schedulers: sequence
    /// indices not currently owned by a live request, lowest on top.
    /// Purely bookkeeping — batch-at-once users (`infer::generate`)
    /// index slots directly and never touch it.
    free: Vec<usize>,
    /// per-slot ownership bitmap: `owned[seq]` iff an [`KvCache::acquire`]
    /// claimed `seq` and no [`KvCache::release`] returned it — the O(1)
    /// double-release check on the admission hot path
    owned: Vec<bool>,
    /// per-sequence block table: `tables[seq][i]` stores positions
    /// `i·block .. (i+1)·block`; one id spans all layers and K+V
    tables: Vec<Vec<u32>>,
    /// pool block ids owned by no sequence (most recently freed on top)
    free_blocks: Vec<u32>,
    /// blocks ever allocated — the pool high-water mark
    n_blocks: usize,
    /// allocation ceiling: `batch · ceil(capacity / block)`
    max_blocks: usize,
    /// per layer: block pool, `[n_blocks · heads · block, head_dim]`
    k: Vec<KvBuf>,
    v: Vec<KvBuf>,
    /// score-row scratch reused across `attend` calls (the per-layer
    /// decode hot path would otherwise heap-allocate per call)
    scratch: Vec<f32>,
    /// dequantized `[heads, ctx, head_dim]` K/V scratch for the packed
    /// storage modes, reused across `attend` calls
    kdq: Vec<f32>,
    vdq: Vec<f32>,
    /// prefix-sharing layer (`--prefix-cache`); `None` is a strict
    /// no-op — every consultation is behind an `is_some` check
    prefix: Option<PrefixCache>,
}

impl KvCache {
    /// An exact f32 cache — the default storage mode.
    pub fn new(layers: usize, batch: usize, heads: usize, head_dim: usize,
               capacity: usize) -> KvCache {
        KvCache::with_dtype(layers, batch, heads, head_dim, capacity,
                            DType::F32)
    }

    /// A cache storing K/V in `dtype` (`--kv-dtype`) with the default
    /// block size.
    pub fn with_dtype(layers: usize, batch: usize, heads: usize,
                      head_dim: usize, capacity: usize, dtype: DType)
        -> KvCache {
        KvCache::with_layout(layers, batch, heads, head_dim, capacity,
                             dtype, DEFAULT_KV_BLOCK)
    }

    /// Full-layout constructor: `dtype` storage in blocks of `block`
    /// positions (`--kv-block`).  Nothing is allocated up front — the
    /// pool grows block-by-block as sequences append.
    pub fn with_layout(layers: usize, batch: usize, heads: usize,
                       head_dim: usize, capacity: usize, dtype: DType,
                       block: usize) -> KvCache {
        assert!(layers > 0 && batch > 0 && heads > 0 && head_dim > 0
                && capacity > 0, "degenerate KV cache shape");
        assert!(block > 0, "degenerate KV block size");
        KvCache {
            layers,
            batch,
            heads,
            head_dim,
            capacity,
            block,
            dtype,
            lens: vec![0; batch],
            free: (0..batch).rev().collect(),
            owned: vec![false; batch],
            tables: vec![Vec::new(); batch],
            free_blocks: Vec::new(),
            n_blocks: 0,
            max_blocks: batch * capacity.div_ceil(block),
            k: (0..layers).map(|_| KvBuf::new(dtype)).collect(),
            v: (0..layers).map(|_| KvBuf::new(dtype)).collect(),
            scratch: Vec::new(),
            kdq: Vec::new(),
            vdq: Vec::new(),
            prefix: None,
        }
    }

    /// Storage dtype of the K/V blocks.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Tokens cached so far for sequence `seq`.
    pub fn len(&self, seq: usize) -> usize {
        self.lens[seq]
    }

    /// Forget all cached positions and return every block to the pool
    /// (the pool allocation itself is kept for the next batch).  With
    /// prefix sharing on, the registry and retained pool are dropped
    /// too — a reset cache recognizes no prior content.
    pub fn reset(&mut self) {
        if let Some(p) = &mut self.prefix {
            // tables may share block ids: free each block exactly once,
            // when its last reference drops
            for t in &mut self.tables {
                for b in t.drain(..) {
                    p.refs[b as usize] -= 1;
                    if p.refs[b as usize] == 0 {
                        self.free_blocks.push(b);
                    }
                }
            }
            self.free_blocks.append(&mut p.pool);
            p.by_hash.clear();
            p.meta.clear();
            for t in &mut p.toks {
                t.clear();
            }
            p.sealed.fill(0);
        } else {
            for t in &mut self.tables {
                self.free_blocks.append(t);
            }
        }
        self.lens.fill(0);
        self.free = (0..self.batch).rev().collect();
        self.owned.fill(false);
    }

    /// Claim a free sequence slot for a newly admitted request (lowest
    /// index first), or `None` when every slot is owned.  The slot
    /// starts at length 0 and owns no blocks until its first append.
    pub fn acquire(&mut self) -> Option<usize> {
        let seq = self.free.pop()?;
        self.lens[seq] = 0;
        self.owned[seq] = true;
        Some(seq)
    }

    /// Return a retired request's slot to the free list and its blocks
    /// to the pool — O(blocks held), and the blocks are immediately
    /// reusable by any peer.  A request admitted into a recycled slot
    /// decodes bitwise identically to one admitted into a fresh cache
    /// (`rust/tests/serving.rs`).
    ///
    /// With prefix sharing on, each block instead drops one reference:
    /// blocks other sequences still hold stay put, and a sealed block
    /// whose last reference this was parks in the LRU prefix pool —
    /// still discoverable by [`KvCache::admit_prefix`] — rather than
    /// returning to the free list.
    pub fn release(&mut self, seq: usize) {
        assert!(seq < self.batch, "slot {seq} out of batch {}", self.batch);
        assert!(self.owned[seq], "double release of slot {seq}");
        self.owned[seq] = false;
        if let Some(p) = &mut self.prefix {
            for b in self.tables[seq].drain(..) {
                p.unref(b, &mut self.free_blocks);
            }
            p.toks[seq].clear();
            p.sealed[seq] = 0;
            p.evict_over_budget(&mut self.free_blocks);
        } else {
            self.free_blocks.append(&mut self.tables[seq]);
        }
        self.lens[seq] = 0;
        self.free.push(seq);
    }

    /// Slots currently available to [`KvCache::acquire`].
    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently owned by live sequences.
    pub fn blocks_live(&self) -> usize {
        self.n_blocks - self.free_blocks.len()
    }

    /// Allocated blocks sitting on the free list.
    pub fn blocks_free(&self) -> usize {
        self.free_blocks.len()
    }

    /// Pool high-water mark: blocks ever allocated.
    pub fn blocks_allocated(&self) -> usize {
        self.n_blocks
    }

    /// Pool ceiling: `batch · ceil(capacity / block)` blocks, plus the
    /// prefix-pool budget when prefix sharing is enabled.
    pub fn max_blocks(&self) -> usize {
        self.max_blocks
    }

    /// Turn on prefix sharing with an LRU pool of up to `budget`
    /// retained blocks (`--prefix-cache-blocks`).  Raises the pool
    /// ceiling by the budget so retained blocks never steal allocation
    /// headroom from live sequences.  Call once, on a fresh cache,
    /// before the first admission.
    pub fn enable_prefix(&mut self, budget: usize) {
        assert!(self.prefix.is_none(), "prefix cache already enabled");
        assert_eq!(self.n_blocks, 0,
                   "enable_prefix on a cache that already allocated");
        self.max_blocks += budget;
        self.prefix = Some(PrefixCache::new(budget, self.batch));
    }

    /// Whether [`KvCache::enable_prefix`] has been called.
    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Splice the longest sealed-block prefix of `prompt` (under tenant
    /// namespace `ns`) into freshly-acquired slot `seq` and return how
    /// many positions are now already cached — the caller prefills only
    /// `prompt[reused..]`.  Each candidate block is verified against
    /// its stored token ids and namespace, so a hash collision degrades
    /// to a miss, never to wrong K/V.  At least the final prompt token
    /// is always left uncached (its logits seed sampling), which also
    /// puts every suffix write past the spliced blocks — the tail block
    /// stays private.  Returns 0 when prefix sharing is off.
    pub fn admit_prefix(&mut self, seq: usize, ns: &str, prompt: &[i32])
        -> usize {
        let blk = self.block;
        let Some(p) = &mut self.prefix else {
            return 0;
        };
        debug_assert!(self.owned[seq] && self.lens[seq] == 0
                      && self.tables[seq].is_empty(),
                      "admit_prefix on a mid-flight slot");
        p.ns[seq] = ns.to_string();
        p.toks[seq].clear();
        let mut chain = ns_root(ns);
        // only whole blocks strictly before the last prompt token are
        // eligible — the final token must be prefilled for its logits
        let cap = prompt.len().saturating_sub(1) / blk * blk;
        let mut reused = 0;
        while reused + blk <= cap {
            let want = &prompt[reused..reused + blk];
            let h = chain_hash(chain, want);
            let hit = p.by_hash.get(&h).copied().filter(|b| {
                let m = &p.meta[b];
                m.ns == ns && m.tokens == want
            });
            let Some(b) = hit else {
                break;
            };
            if p.refs[b as usize] == 0 {
                let at = p.pool.iter().position(|&x| x == b)
                    .expect("unreferenced sealed block is pooled");
                p.pool.swap_remove(at);
            }
            p.refs[b as usize] += 1;
            p.clock += 1;
            p.meta.get_mut(&b).unwrap().last_use = p.clock;
            self.tables[seq].push(b);
            chain = h;
            reused += blk;
            p.hit_blocks += 1;
        }
        p.miss_blocks += ((cap - reused) / blk) as u64;
        p.hit_tokens += reused as u64;
        p.chain[seq] = chain;
        p.sealed[seq] = reused / blk;
        p.toks[seq].extend_from_slice(&prompt[..reused]);
        self.lens[seq] = reused;
        reused
    }

    /// Record the token ids whose K/V the caller just cached for `seq`
    /// (call after each prefill chunk or decode step has appended and
    /// bumped), sealing each block the moment it fills: a sealed block
    /// is immutable and registered under its parent-chained content
    /// hash for [`KvCache::admit_prefix`] to find.  If the chain key is
    /// already canonical under another block (a concurrent twin
    /// computation), this block stays private and frees normally.
    /// No-op when prefix sharing is off.
    pub fn note_tokens(&mut self, seq: usize, tokens: &[i32]) {
        let blk = self.block;
        let len = self.lens[seq];
        let Some(p) = &mut self.prefix else {
            return;
        };
        p.toks[seq].extend_from_slice(tokens);
        debug_assert_eq!(p.toks[seq].len(), len,
                         "token history out of step with cache length");
        let covered = p.toks[seq].len().min(len);
        while (p.sealed[seq] + 1) * blk <= covered {
            let i = p.sealed[seq];
            let b = self.tables[seq][i];
            let ts = &p.toks[seq][i * blk..(i + 1) * blk];
            let parent = (i > 0).then(|| p.chain[seq]);
            let h = chain_hash(p.chain[seq], ts);
            if !p.by_hash.contains_key(&h) {
                p.clock += 1;
                p.meta.insert(b, SealedMeta {
                    hash: h,
                    parent,
                    tokens: ts.to_vec(),
                    ns: p.ns[seq].clone(),
                    children: 0,
                    last_use: p.clock,
                });
                p.by_hash.insert(h, b);
                if let Some(ph) = parent {
                    if let Some(&pb) = p.by_hash.get(&ph) {
                        if let Some(pm) = p.meta.get_mut(&pb) {
                            pm.children += 1;
                        }
                    }
                }
            }
            p.chain[seq] = h;
            p.sealed[seq] += 1;
        }
    }

    /// Snapshot of the prefix cache's counters and gauges; all-zero
    /// (`enabled: false`) when prefix sharing is off.
    pub fn prefix_stats(&self) -> PrefixStats {
        match &self.prefix {
            None => PrefixStats::default(),
            Some(p) => PrefixStats {
                enabled: true,
                hit_blocks: p.hit_blocks,
                miss_blocks: p.miss_blocks,
                hit_tokens: p.hit_tokens,
                evicted: p.evicted,
                pool_blocks: p.pool.len(),
                shared_blocks:
                    p.refs.iter().filter(|&&r| r > 1).count(),
                sealed_blocks: p.meta.len(),
            },
        }
    }

    /// Bytes held by pooled (retained, unreferenced) prefix blocks —
    /// the `kv_prefix_pool` ledger row; [`KvCache::bytes`] minus this
    /// is the live/free pool's share.
    pub fn prefix_pool_bytes(&self) -> usize {
        self.prefix
            .as_ref()
            .map_or(0, |p| p.pool.len() * self.block_bytes())
    }

    /// Bytes one logical block occupies across all layers, K and V.
    pub fn block_bytes(&self) -> usize {
        let e = self.heads * self.block * self.head_dim;
        let r = self.heads * self.block;
        let per_buf = match self.dtype {
            DType::F32 => 4 * e,
            DType::Bf16 => 2 * e,
            DType::I8 => e + 4 * r,
        };
        2 * self.layers * per_buf
    }

    /// What the pre-paging `[batch·heads, capacity, head_dim]` slab
    /// would have reserved up front — the bench baseline for "resident
    /// bytes scale with live tokens".
    pub fn slab_bytes(&self) -> usize {
        let e = self.batch * self.heads * self.capacity * self.head_dim;
        let r = self.batch * self.heads * self.capacity;
        let per_buf = match self.dtype {
            DType::F32 => 4 * e,
            DType::Bf16 => 2 * e,
            DType::I8 => e + 4 * r,
        };
        2 * self.layers * per_buf
    }

    /// Cache memory footprint in bytes (serving-capacity accounting):
    /// the allocated block pool at its storage width, plus the int8
    /// per-row scales when quantized.  Grows with the live-token
    /// high-water mark, not with `batch × capacity`.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|b| b.bytes()).sum()
    }

    /// Elements one block contributes to each per-layer pool buffer.
    #[inline]
    fn blk_elems(&self) -> usize {
        self.heads * self.block * self.head_dim
    }

    /// Flat element offset of `(block id, head, position-in-block)` in a
    /// layer's pool buffer.
    #[inline]
    fn blk_off(&self, blk: usize, head: usize, p: usize) -> usize {
        ((blk * self.heads + head) * self.block + p) * self.head_dim
    }

    /// Hand out a block: recycle the most recently freed one, else grow
    /// every layer's pool by one block.  The ceiling is unreachable in
    /// correct use — per-sequence overflow is checked against `capacity`
    /// first — so this assert is an allocator invariant, not a user
    /// error path.
    fn alloc_block(&mut self) -> u32 {
        if let Some(b) = self.free_blocks.pop() {
            return b;
        }
        if self.n_blocks >= self.max_blocks {
            // unreachable while the budget invariants hold (live ≤
            // batch·ceil(capacity/block), pool ≤ budget, ceiling covers
            // both) — but if they ever don't, reclaiming a retained
            // prefix block beats aborting the batch
            if let Some(p) = &mut self.prefix {
                if !p.pool.is_empty() {
                    p.evict_one(&mut self.free_blocks);
                    if let Some(b) = self.free_blocks.pop() {
                        return b;
                    }
                }
            }
        }
        assert!(self.n_blocks < self.max_blocks,
                "KV pool invariant broken: {} blocks exceeds ceiling {}",
                self.n_blocks + 1, self.max_blocks);
        let id = self.n_blocks as u32;
        self.n_blocks += 1;
        let (ne, nr) = (self.blk_elems(), self.heads * self.block);
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.grow(ne, nr);
        }
        id
    }

    /// Grow `seq`'s block table until it covers positions `0..upto`.
    /// Idempotent — every layer's append calls this with the same range.
    fn ensure_blocks(&mut self, seq: usize, upto: usize) {
        while self.tables[seq].len() * self.block < upto {
            let b = self.alloc_block();
            if let Some(p) = &mut self.prefix {
                p.track(b);
            }
            self.tables[seq].push(b);
        }
    }

    /// Copy-on-write guard: if table entry `bi` of `seq` points at a
    /// block someone else can see — shared (refcount > 1) or sealed
    /// (registered for admission lookups) — replace it with a fresh
    /// private copy before writing.  The admission cap keeps ordinary
    /// suffix prefill past every shared block, so this is a defensive
    /// invariant, not a hot path.
    fn cow_block(&mut self, seq: usize, bi: usize) {
        let b = self.tables[seq][bi] as usize;
        let shared = match &self.prefix {
            Some(p) => p.refs[b] > 1 || p.meta.contains_key(&(b as u32)),
            None => false,
        };
        if !shared {
            return;
        }
        let nb = self.alloc_block();
        let (ne, nr) = (self.blk_elems(), self.heads * self.block);
        for buf in self.k.iter_mut().chain(self.v.iter_mut()) {
            buf.copy_block(b, nb as usize, ne, nr);
        }
        self.tables[seq][bi] = nb;
        let p = self.prefix.as_mut().unwrap();
        p.track(nb);
        p.unref(b as u32, &mut self.free_blocks);
        p.evict_over_budget(&mut self.free_blocks);
    }

    /// Append `t_new` RoPE'd key rows and value rows for sequence `seq`
    /// at its current length.  `k_new`/`v_new` are `[heads, t_new,
    /// head_dim]` (the `to_heads` layout of one sequence's chunk).  The
    /// sequence length is NOT advanced — every layer appends at the same
    /// base position; call [`KvCache::bump`] once after the last layer.
    pub fn append(&mut self, layer: usize, seq: usize, k_new: &[f32],
                  v_new: &[f32], t_new: usize) {
        let (nh, hd, blk) = (self.heads, self.head_dim, self.block);
        let base = self.lens[seq];
        assert!(base + t_new <= self.capacity,
                "KV cache overflow: {base}+{t_new} > {}", self.capacity);
        assert_eq!(k_new.len(), nh * t_new * hd, "k chunk shape");
        assert_eq!(v_new.len(), nh * t_new * hd, "v chunk shape");
        self.ensure_blocks(seq, base + t_new);
        if self.prefix.is_some() && t_new > 0 {
            for bi in base / blk..=(base + t_new - 1) / blk {
                self.cow_block(seq, bi);
            }
        }
        // walk the chunk in per-block runs of global positions
        let mut p = base;
        while p < base + t_new {
            let b = self.tables[seq][p / blk] as usize;
            let off = p % blk;
            let run = (blk - off).min(base + t_new - p);
            for h in 0..nh {
                let src = (h * t_new + (p - base)) * hd;
                let dst = self.blk_off(b, h, off);
                self.k[layer].store_rows(dst,
                                         &k_new[src..src + run * hd], hd);
                self.v[layer].store_rows(dst,
                                         &v_new[src..src + run * hd], hd);
            }
            p += run;
        }
    }

    /// Advance sequence `seq` by `t_new` cached positions (once per
    /// appended chunk, after all layers have run).
    pub fn bump(&mut self, seq: usize, t_new: usize) {
        self.lens[seq] += t_new;
        debug_assert!(self.lens[seq] <= self.capacity);
    }

    /// Causal softmax attention of a freshly-appended chunk's queries
    /// over this sequence's cache: `q` is `[heads, t_new, head_dim]`
    /// (RoPE'd at absolute positions `len..len+t_new`), its K/V already
    /// appended via [`KvCache::append`].  Chunk row `i` attends to cached
    /// positions `0..len+i+1`, which is exactly full causal attention.
    /// Returns `[heads, t_new, head_dim]`.
    ///
    /// The f32 storage mode hands the kernel the pool slices plus the
    /// block table zero-copy; packed modes gather-dequantize only the
    /// live prefix (`0..len+t_new`) of each head into reused scratch, so
    /// decode never touches dead capacity.
    pub fn attend(&mut self, layer: usize, seq: usize, q: &[f32],
                  t_new: usize) -> Vec<f32> {
        let (nh, hd, blk) = (self.heads, self.head_dim, self.block);
        let base = self.lens[seq];
        let ctx = base + t_new;
        assert_eq!(q.len(), nh * t_new * hd, "q chunk shape");
        debug_assert!(self.tables[seq].len() * blk >= ctx,
                      "attend past the appended range");
        let mut scratch = std::mem::take(&mut self.scratch);
        let o = if self.dtype == DType::F32 {
            let (kp, vp) = match (&self.k[layer], &self.v[layer]) {
                (KvBuf::F32(kd), KvBuf::F32(vd)) => {
                    (kd.as_slice(), vd.as_slice())
                }
                _ => unreachable!("f32 cache holds f32 buffers"),
            };
            kernels::cached_attend_paged(q, kp, vp, &self.tables[seq],
                                         nh, t_new, base, blk, hd,
                                         &mut scratch)
        } else {
            let mut kdq = std::mem::take(&mut self.kdq);
            let mut vdq = std::mem::take(&mut self.vdq);
            kdq.resize(nh * ctx * hd, 0.0);
            vdq.resize(nh * ctx * hd, 0.0);
            // gather-dequantize the live prefix block run by block run;
            // rows land in the same [nh, ctx, hd] order the old slab
            // walk produced, so the kernel sees identical inputs
            let mut p = 0;
            while p < ctx {
                let b = self.tables[seq][p / blk] as usize;
                let run = blk.min(ctx - p);
                for h in 0..nh {
                    let src = self.blk_off(b, h, 0);
                    let dst = (h * ctx + p) * hd;
                    self.k[layer].load_rows(
                        src, &mut kdq[dst..dst + run * hd], hd);
                    self.v[layer].load_rows(
                        src, &mut vdq[dst..dst + run * hd], hd);
                }
                p += run;
            }
            // the dequantized copy is tight: capacity == ctx
            let o = kernels::cached_attend(q, &kdq, &vdq, nh, t_new,
                                           base, ctx, hd, &mut scratch);
            self.kdq = kdq;
            self.vdq = vdq;
            o
        };
        self.scratch = scratch;
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::causal_attention_fwd;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
    }

    #[test]
    fn append_then_attend_matches_full_causal_attention() {
        prop_check("cache attend == causal_attention_fwd", 20, |rng| {
            let nh = 1 + rng.below(3);
            let hd = 2 * (1 + rng.below(4));
            let t = 2 + rng.below(6);
            let q = randv(nh * t * hd, rng);
            let k = randv(nh * t * hd, rng);
            let v = randv(nh * t * hd, rng);
            let (want, _) = causal_attention_fwd(&q, &k, &v, nh, t, hd);
            // feed the same q/k/v through the cache one token at a time,
            // with a tiny block size so the walk crosses boundaries
            let mut cache = KvCache::with_layout(1, 1, nh, hd, t,
                                                 DType::F32, 2);
            let mut got = vec![0.0f32; nh * t * hd];
            for i in 0..t {
                let pick = |x: &[f32]| -> Vec<f32> {
                    (0..nh)
                        .flat_map(|h| {
                            x[(h * t + i) * hd..(h * t + i + 1) * hd]
                                .to_vec()
                        })
                        .collect()
                };
                let (qi, ki, vi) = (pick(&q), pick(&k), pick(&v));
                cache.append(0, 0, &ki, &vi, 1);
                let oi = cache.attend(0, 0, &qi, 1);
                cache.bump(0, 1);
                for h in 0..nh {
                    got[(h * t + i) * hd..(h * t + i + 1) * hd]
                        .copy_from_slice(&oi[h * hd..(h + 1) * hd]);
                }
            }
            assert_close(&got, &want, 1e-5, 1e-6)
        });
    }

    #[test]
    fn chunked_append_equals_one_shot() {
        let mut rng = Rng::new(5);
        let (nh, hd, t) = (2, 4, 6);
        let q = randv(nh * t * hd, &mut rng);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let mut one = KvCache::new(1, 1, nh, hd, t);
        one.append(0, 0, &k, &v, t);
        let want = one.attend(0, 0, &q, t);
        // split the chunk 4 + 2, with a block size that straddles the
        // split (block 3: positions 3..6 span two blocks)
        let split = 4;
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        let mut two = KvCache::with_layout(1, 1, nh, hd, t, DType::F32, 3);
        two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split), split);
        let o1 = two.attend(0, 0, &part(&q, 0, split), split);
        two.bump(0, split);
        two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                   t - split);
        let o2 = two.attend(0, 0, &part(&q, split, t), t - split);
        two.bump(0, t - split);
        assert_eq!(two.len(0), t);
        for h in 0..nh {
            for i in 0..t {
                let w = &want[(h * t + i) * hd..(h * t + i + 1) * hd];
                let g = if i < split {
                    &o1[(h * split + i) * hd..(h * split + i + 1) * hd]
                } else {
                    let ii = i - split;
                    let tn = t - split;
                    &o2[(h * tn + ii) * hd..(h * tn + ii + 1) * hd]
                };
                assert_close(g, w, 1e-6, 1e-7).unwrap();
            }
        }
    }

    #[test]
    fn paged_decode_is_bitwise_identical_across_block_sizes() {
        // The paged attend path must reproduce the single-block
        // (contiguous) layout bit-for-bit for every storage mode: same
        // per-row values, same serial accumulation order — only the
        // addresses differ.
        let mut rng = Rng::new(77);
        let (nh, hd, t) = (3, 8, 13);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let q = randv(nh * t * hd, &mut rng);
        let pick = |x: &[f32], i: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + i) * hd..(h * t + i + 1) * hd].to_vec()
                })
                .collect()
        };
        let bits = |x: &[f32]| -> Vec<u32> {
            x.iter().map(|v| v.to_bits()).collect()
        };
        for dtype in [DType::F32, DType::Bf16, DType::I8] {
            // block 4 (boundaries mid-sequence) vs block t (one block ==
            // the old contiguous strip)
            let mut paged =
                KvCache::with_layout(1, 1, nh, hd, t, dtype, 4);
            let mut contig =
                KvCache::with_layout(1, 1, nh, hd, t, dtype, t);
            for i in 0..t {
                let (qi, ki, vi) = (pick(&q, i), pick(&k, i), pick(&v, i));
                paged.append(0, 0, &ki, &vi, 1);
                contig.append(0, 0, &ki, &vi, 1);
                let op = paged.attend(0, 0, &qi, 1);
                let oc = contig.attend(0, 0, &qi, 1);
                paged.bump(0, 1);
                contig.bump(0, 1);
                assert_eq!(bits(&op), bits(&oc),
                           "{dtype} diverged at position {i}");
            }
        }
    }

    #[test]
    fn sequences_are_independent() {
        let mut rng = Rng::new(9);
        let (nh, hd) = (2, 4);
        let mut cache = KvCache::with_layout(1, 3, nh, hd, 8,
                                             DType::F32, 2);
        let k0 = randv(nh * hd, &mut rng);
        let v0 = randv(nh * hd, &mut rng);
        cache.append(0, 0, &k0, &v0, 1);
        cache.bump(0, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (1, 0, 2));
        assert_eq!(cache.blocks_live(), 2); // one block each for 0 and 2
        cache.reset();
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (0, 0, 0));
        assert_eq!(cache.blocks_live(), 0);
        assert_eq!(cache.blocks_free(), 2); // pool retained, not shrunk
    }

    #[test]
    fn slot_lifecycle_acquire_release_reset() {
        let mut c = KvCache::new(1, 3, 1, 2, 4);
        assert_eq!(c.n_free(), 3);
        // lowest slot first, so admission order matches sequence order
        assert_eq!(c.acquire(), Some(0));
        assert_eq!(c.acquire(), Some(1));
        assert_eq!(c.acquire(), Some(2));
        assert_eq!(c.acquire(), None);
        let kv = vec![0.5f32; 2];
        c.append(0, 1, &kv, &kv, 1);
        c.bump(1, 1);
        assert_eq!(c.len(1), 1);
        // the retired slot comes back with length 0 and is reused
        // before lower-numbered never-freed slots
        c.release(1);
        assert_eq!((c.n_free(), c.len(1)), (1, 0));
        assert_eq!(c.acquire(), Some(1));
        c.release(1);
        c.release(0);
        c.release(2);
        c.reset();
        assert_eq!(c.n_free(), 3);
        assert_eq!(c.acquire(), Some(0));
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut c = KvCache::new(1, 2, 1, 2, 4);
        let s = c.acquire().unwrap();
        c.release(s);
        c.release(s);
    }

    #[test]
    fn pool_grows_with_live_tokens_and_recycles_on_release() {
        // batch 4, capacity 16, block 4 → ceiling 16 blocks; nothing is
        // reserved up front, bytes grow block-by-block with appends,
        // and released blocks are recycled before the pool grows again.
        let (nh, hd, blk) = (2, 4, 4);
        let mut c = KvCache::with_layout(2, 4, nh, hd, 16, DType::F32,
                                         blk);
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.blocks_allocated(), 0);
        assert_eq!(c.max_blocks(), 16);
        let row = vec![0.25f32; nh * hd];
        let fill = |c: &mut KvCache, seq: usize, n: usize| {
            for _ in 0..n {
                for l in 0..2 {
                    c.append(l, seq, &row, &row, 1);
                }
                c.bump(seq, 1);
            }
        };
        let s0 = c.acquire().unwrap();
        fill(&mut c, s0, 5); // 5 tokens → 2 blocks
        assert_eq!((c.blocks_live(), c.blocks_allocated()), (2, 2));
        assert_eq!(c.bytes(), 2 * c.block_bytes());
        let s1 = c.acquire().unwrap();
        fill(&mut c, s1, 4); // exactly 1 block
        assert_eq!((c.blocks_live(), c.blocks_allocated()), (3, 3));
        // release s0: its 2 blocks return in O(blocks)
        c.release(s0);
        assert_eq!((c.blocks_live(), c.blocks_free()), (1, 2));
        // a new sequence reuses freed blocks — allocation stays at 3
        let s2 = c.acquire().unwrap();
        fill(&mut c, s2, 8); // needs 2 blocks, both recycled
        assert_eq!((c.blocks_live(), c.blocks_allocated()), (3, 3));
        assert_eq!(c.bytes(), 3 * c.block_bytes());
        // drain everything: free count returns to the full allocation
        c.release(s1);
        c.release(s2);
        assert_eq!((c.blocks_live(), c.blocks_free()), (0, 3));
        // the paged pool undercuts the old up-front slab by design
        assert!(c.bytes() < c.slab_bytes(),
                "pool {} >= slab {}", c.bytes(), c.slab_bytes());
    }

    #[test]
    fn bytes_accounting() {
        // pool bytes are exact multiples of block_bytes() and grow only
        // with appends — never with batch or capacity headroom
        let (nh, hd, blk) = (4, 8, 8);
        for dtype in [DType::F32, DType::Bf16, DType::I8] {
            let mut c = KvCache::with_layout(2, 3, nh, hd, 16, dtype,
                                             blk);
            assert_eq!(c.bytes(), 0, "{dtype}: nothing reserved up front");
            let row = vec![0.5f32; nh * hd];
            for l in 0..2 {
                c.append(l, 0, &row, &row, 1);
            }
            c.bump(0, 1);
            // one token → one block, at the dtype's storage width
            let e = nh * blk * hd;
            let r = nh * blk;
            let per_buf = match dtype {
                DType::F32 => 4 * e,
                DType::Bf16 => 2 * e,
                DType::I8 => e + 4 * r,
            };
            assert_eq!(c.block_bytes(), 2 * 2 * per_buf, "{dtype}");
            assert_eq!(c.bytes(), c.block_bytes(), "{dtype}");
            assert_eq!(c.dtype(), dtype);
        }
    }

    #[test]
    fn quantized_cache_attends_close_to_f32() {
        // bf16/int8 storage perturbs K/V by at most one quantization
        // step per element; the attention output (a convex combination
        // of V rows re-weighted by slightly-off scores) stays close
        for (dtype, tol) in [(DType::Bf16, 0.02), (DType::I8, 0.08)] {
            prop_check("quantized KV attend close", 10, move |rng| {
                let nh = 1 + rng.below(3);
                let hd = 4 * (1 + rng.below(3));
                let t = 2 + rng.below(8);
                let q = randv(nh * t * hd, rng);
                let k = randv(nh * t * hd, rng);
                let v = randv(nh * t * hd, rng);
                let mut exact = KvCache::new(1, 1, nh, hd, t);
                exact.append(0, 0, &k, &v, t);
                let want = exact.attend(0, 0, &q, t);
                let mut quant =
                    KvCache::with_dtype(1, 1, nh, hd, t, dtype);
                quant.append(0, 0, &k, &v, t);
                let got = quant.attend(0, 0, &q, t);
                for (g, w) in got.iter().zip(&want) {
                    if (g - w).abs() > tol {
                        return Err(format!(
                            "{dtype}: {g} vs {w} (tol {tol})"));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn quantized_chunked_append_is_position_consistent() {
        // appending in chunks quantizes exactly the same per-position
        // rows, so chunked == one-shot bitwise for every storage mode —
        // including across block boundaries (block 3 vs one-shot's
        // identical layout)
        let mut rng = Rng::new(31);
        let (nh, hd, t, split) = (2, 8, 6, 4);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let q = randv(nh * (t - split) * hd, &mut rng);
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        for dtype in [DType::Bf16, DType::I8] {
            let mut one = KvCache::with_layout(1, 1, nh, hd, t, dtype, 3);
            one.append(0, 0, &k, &v, t);
            one.bump(0, split); // queries sit at positions split..t
            let want = one.attend(0, 0, &q, t - split);
            let mut two = KvCache::with_layout(1, 1, nh, hd, t, dtype, 3);
            two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split),
                       split);
            two.bump(0, split);
            two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                       t - split);
            let got = two.attend(0, 0, &q, t - split);
            let bits = |x: &[f32]| -> Vec<u32> {
                x.iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(bits(&got), bits(&want), "{dtype}");
        }
    }

    /// Append `toks.len()` synthetic K/V rows (one per token, derived
    /// from the token id so equal tokens ⇒ equal rows) to `seq` and
    /// record them with the prefix cache, mirroring the scheduler's
    /// prefill+note flow.
    fn feed(c: &mut KvCache, seq: usize, toks: &[i32]) {
        let (nh, hd) = (c.heads, c.head_dim);
        for &t in toks {
            let row: Vec<f32> = (0..nh * hd)
                .map(|j| (t as f32) * 0.01 + j as f32 * 0.001)
                .collect();
            for l in 0..c.layers {
                c.append(l, seq, &row, &row, 1);
            }
            c.bump(seq, 1);
            c.note_tokens(seq, &[t]);
        }
    }

    #[test]
    fn prefix_off_is_strict_noop() {
        let mut c = KvCache::with_layout(1, 2, 2, 4, 16, DType::F32, 4);
        assert!(!c.prefix_enabled());
        let s = c.acquire().unwrap();
        // admit/note are inert without enable_prefix
        assert_eq!(c.admit_prefix(s, "base", &[1, 2, 3, 4, 5]), 0);
        assert_eq!(c.len(s), 0);
        let kv = vec![0.5f32; 2 * 4];
        for _ in 0..5 {
            c.append(0, s, &kv, &kv, 1);
            c.bump(s, 1);
        }
        c.note_tokens(s, &[1, 2, 3, 4, 5]);
        c.release(s);
        // every block went straight back to the free list
        assert_eq!((c.blocks_free(), c.blocks_live()), (2, 0));
        assert_eq!(c.prefix_stats(), PrefixStats::default());
        assert_eq!(c.prefix_pool_bytes(), 0);
    }

    #[test]
    fn prefix_seal_pool_and_splice_refcounts() {
        let mut c = KvCache::with_layout(2, 3, 2, 4, 16, DType::F32, 4);
        c.enable_prefix(8);
        assert_eq!(c.max_blocks(), 3 * 4 + 8);
        let prompt: Vec<i32> = (10..19).collect(); // 9 tokens, blk 4
        let s0 = c.acquire().unwrap();
        assert_eq!(c.admit_prefix(s0, "a", &prompt), 0); // cold
        feed(&mut c, s0, &prompt);
        let st = c.prefix_stats();
        assert_eq!((st.sealed_blocks, st.pool_blocks), (2, 0));
        // release: 2 sealed blocks park in the pool, the tail frees
        c.release(s0);
        let st = c.prefix_stats();
        assert_eq!((st.pool_blocks, c.blocks_free()), (2, 1));
        assert_eq!(c.prefix_pool_bytes(), 2 * c.block_bytes());
        // warm admission reuses both sealed blocks (cap spares the
        // 9th token), leaving only a 1-token suffix to prefill
        let s1 = c.acquire().unwrap();
        assert_eq!(c.admit_prefix(s1, "a", &prompt), 8);
        assert_eq!(c.len(s1), 8);
        let st = c.prefix_stats();
        assert_eq!((st.hit_blocks, st.hit_tokens, st.pool_blocks),
                   (2, 8, 0));
        // a second tenant must NOT hit the same tokens
        let s2 = c.acquire().unwrap();
        assert_eq!(c.admit_prefix(s2, "b", &prompt), 0);
        assert_eq!(c.prefix_stats().miss_blocks, 2 + 2); // s0 cold + s2
        // a peer of the same tenant shares the spliced blocks
        c.release(s2);
        let s2 = c.acquire().unwrap();
        assert_eq!(c.admit_prefix(s2, "a", &prompt), 8);
        assert_eq!(c.prefix_stats().shared_blocks, 2);
        // dropping one sharer keeps the blocks live for the other
        c.release(s1);
        let st = c.prefix_stats();
        assert_eq!((st.shared_blocks, st.pool_blocks), (0, 0));
        c.release(s2);
        assert_eq!(c.prefix_stats().pool_blocks, 2);
    }

    #[test]
    fn prefix_warm_attend_is_bitwise_identical() {
        // spliced blocks hold exactly the rows a cold prefill stores,
        // for every storage dtype — attend output bits must match
        let mut rng = Rng::new(41);
        let (nh, hd, blk, n) = (2, 8, 4, 9);
        let prompt: Vec<i32> = (0..n as i32).map(|i| 20 + i).collect();
        let k = randv(nh * n * hd, &mut rng);
        let v = randv(nh * n * hd, &mut rng);
        let q = randv(nh * hd, &mut rng);
        let pick = |x: &[f32], i: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * n + i) * hd..(h * n + i + 1) * hd].to_vec()
                })
                .collect()
        };
        let bits = |x: &[f32]| -> Vec<u32> {
            x.iter().map(|v| v.to_bits()).collect()
        };
        for dtype in [DType::F32, DType::Bf16, DType::I8] {
            let mut c = KvCache::with_layout(1, 2, nh, hd, 16, dtype,
                                             blk);
            c.enable_prefix(8);
            // cold request: feed all n positions, sealing 2 blocks;
            // the final position's query attends over the whole cache
            let s0 = c.acquire().unwrap();
            assert_eq!(c.admit_prefix(s0, "base", &prompt), 0);
            for i in 0..n - 1 {
                c.append(0, s0, &pick(&k, i), &pick(&v, i), 1);
                c.bump(s0, 1);
                c.note_tokens(s0, &[prompt[i]]);
            }
            c.append(0, s0, &pick(&k, n - 1), &pick(&v, n - 1), 1);
            let cold = c.attend(0, s0, &q, 1);
            c.bump(s0, 1);
            c.note_tokens(s0, &[prompt[n - 1]]);
            c.release(s0);
            // warm request: splice 8 positions, re-append only the 9th
            let s1 = c.acquire().unwrap();
            assert_eq!(c.admit_prefix(s1, "base", &prompt), 8);
            c.append(0, s1, &pick(&k, 8), &pick(&v, 8), 1);
            let warm = c.attend(0, s1, &q, 1);
            assert_eq!(bits(&cold), bits(&warm), "{dtype}");
        }
    }

    #[test]
    fn prefix_lru_evicts_leaf_first() {
        let mut c = KvCache::with_layout(1, 2, 1, 4, 16, DType::F32, 4);
        c.enable_prefix(2); // room for 2 pooled blocks
        let prompt: Vec<i32> = (0..13).collect(); // 3 sealed + tail
        let s = c.acquire().unwrap();
        c.admit_prefix(s, "base", &prompt);
        feed(&mut c, s, &prompt);
        assert_eq!(c.prefix_stats().sealed_blocks, 3);
        // release parks 3 blocks but the budget holds 2: the chain's
        // LEAF (deepest block) is evicted, keeping the walkable root
        c.release(s);
        let st = c.prefix_stats();
        assert_eq!((st.pool_blocks, st.evicted, st.sealed_blocks),
                   (2, 1, 2));
        // readmission still walks the surviving 2-block prefix
        let s = c.acquire().unwrap();
        assert_eq!(c.admit_prefix(s, "base", &prompt), 8);
        c.release(s);
        // evict-then-refeed: the evicted third block's content gets
        // re-sealed and becomes canonical again under the same chain
        let s = c.acquire().unwrap();
        let got = c.admit_prefix(s, "base", &prompt);
        feed(&mut c, s, &prompt[got..]);
        assert_eq!(c.prefix_stats().sealed_blocks, 3);
        c.release(s);
    }

    #[test]
    fn concurrent_twin_blocks_stay_private() {
        // two live sequences computing the same prefix: the first to
        // seal becomes canonical; the twin is never registered and
        // returns to the free list (not the pool) on release
        let mut c = KvCache::with_layout(1, 2, 1, 4, 16, DType::F32, 4);
        c.enable_prefix(4);
        let prompt: Vec<i32> = (0..6).collect();
        let s0 = c.acquire().unwrap();
        let s1 = c.acquire().unwrap();
        // both admitted before anything is sealed — both miss
        assert_eq!(c.admit_prefix(s0, "base", &prompt), 0);
        assert_eq!(c.admit_prefix(s1, "base", &prompt), 0);
        feed(&mut c, s0, &prompt);
        feed(&mut c, s1, &prompt);
        // one canonical block despite two identical sealed-shaped fills
        assert_eq!(c.prefix_stats().sealed_blocks, 1);
        c.release(s1); // the twin frees: pool stays empty
        assert_eq!(c.prefix_stats().pool_blocks, 0);
        assert_eq!(c.blocks_free(), 2);
        c.release(s0); // the canonical block parks
        assert_eq!(c.prefix_stats().pool_blocks, 1);
    }

    #[test]
    fn prefix_cow_preserves_a_sharers_view() {
        // write aimed at a shared block: the writer gets a private
        // copy; the other sharer's attend output is bit-unchanged
        let (nh, hd, blk) = (2, 4, 4);
        let mut rng = Rng::new(17);
        let mut c = KvCache::with_layout(1, 3, nh, hd, 16, DType::F32,
                                         blk);
        c.enable_prefix(4);
        let prompt: Vec<i32> = (5..10).collect();
        let s0 = c.acquire().unwrap();
        c.admit_prefix(s0, "base", &prompt);
        feed(&mut c, s0, &prompt);
        c.release(s0);
        let sa = c.acquire().unwrap();
        let sb = c.acquire().unwrap();
        assert_eq!(c.admit_prefix(sa, "base", &prompt), 4);
        assert_eq!(c.admit_prefix(sb, "base", &prompt), 4);
        assert_eq!(c.prefix_stats().shared_blocks, 1);
        let shared = c.tables[sa][0];
        assert_eq!(shared, c.tables[sb][0]);
        // append sa's final prompt position (left un-bumped so the
        // same attend call can be replayed after the COW event)
        let row = randv(nh * hd, &mut rng);
        c.append(0, sa, &row, &row, 1);
        let q = randv(nh * hd, &mut rng);
        let before = c.attend(0, sa, &q, 1);
        // rewind sb INTO the shared block and write junk — the COW
        // guard must give sb a fresh private block first
        c.lens[sb] = 2;
        let junk = vec![9.0f32; nh * hd];
        c.append(0, sb, &junk, &junk, 1);
        assert_ne!(c.tables[sb][0], shared, "write hit the shared block");
        assert_eq!(c.tables[sa][0], shared);
        assert_eq!(c.prefix_stats().shared_blocks, 0);
        let after = c.attend(0, sa, &q, 1);
        let bits = |x: &[f32]| -> Vec<u32> {
            x.iter().map(|v| v.to_bits()).collect()
        };
        assert_eq!(bits(&before), bits(&after),
                   "sharer's rows changed under copy-on-write");
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1, 2, 2);
        let kv = vec![0.0; 2];
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
    }
}
