//! Per-layer key/value cache for incremental autoregressive decoding.
//!
//! During generation each new token only needs its *own* q/k/v plus the
//! keys and values of every earlier position — which never change once
//! computed (RoPE is applied at the absolute position before caching).
//! Caching them turns per-token decode cost from O(T²) re-forward work
//! into O(T): one attention sweep over the cache per layer.
//!
//! Layout: one `[batch·heads, capacity, head_dim]` f32 buffer per layer
//! for K and for V.  Sequences advance independently (`lens` is
//! per-sequence), so ragged prompts and per-sequence stop handling in a
//! batched decode loop need no padding or masking: attention for
//! sequence `s` simply sweeps `0..lens[s]`.
//!
//! Attention over the cache runs on the shared kernel layer
//! ([`crate::kernels::cached_attend`]), which mirrors
//! `kernels::causal_attention_fwd` operation-for-operation (same
//! dot-product, max-subtraction and normalization order), so cached
//! decode reproduces the full re-forward logits bit-for-bit — the
//! property `rust/tests/inference.rs` pins down — while long-context
//! prefill chunks parallelize over heads.

/// Key/value cache over `layers × batch` independent sequences.
pub struct KvCache {
    pub layers: usize,
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// maximum positions per sequence
    pub capacity: usize,
    /// tokens currently cached, per sequence
    lens: Vec<usize>,
    /// per layer: `[batch·heads, capacity, head_dim]`
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// score-row scratch reused across `attend` calls (the per-layer
    /// decode hot path would otherwise heap-allocate per call)
    scratch: Vec<f32>,
}

impl KvCache {
    pub fn new(layers: usize, batch: usize, heads: usize, head_dim: usize,
               capacity: usize) -> KvCache {
        assert!(layers > 0 && batch > 0 && heads > 0 && head_dim > 0
                && capacity > 0, "degenerate KV cache shape");
        let per_layer = batch * heads * capacity * head_dim;
        KvCache {
            layers,
            batch,
            heads,
            head_dim,
            capacity,
            lens: vec![0; batch],
            k: (0..layers).map(|_| vec![0.0; per_layer]).collect(),
            v: (0..layers).map(|_| vec![0.0; per_layer]).collect(),
            scratch: Vec::new(),
        }
    }

    /// Tokens cached so far for sequence `seq`.
    pub fn len(&self, seq: usize) -> usize {
        self.lens[seq]
    }

    /// Forget all cached positions (reuse the allocation for a new batch).
    pub fn reset(&mut self) {
        self.lens.fill(0);
    }

    /// Cache memory footprint in bytes (serving-capacity accounting).
    pub fn bytes(&self) -> usize {
        2 * self.layers * self.batch * self.heads * self.capacity
            * self.head_dim * std::mem::size_of::<f32>()
    }

    /// Flat offset of `(seq, head, pos)` in a layer buffer.
    #[inline]
    fn at(&self, seq: usize, head: usize, pos: usize) -> usize {
        ((seq * self.heads + head) * self.capacity + pos) * self.head_dim
    }

    /// Append `t_new` RoPE'd key rows and value rows for sequence `seq`
    /// at its current length.  `k_new`/`v_new` are `[heads, t_new,
    /// head_dim]` (the `to_heads` layout of one sequence's chunk).  The
    /// sequence length is NOT advanced — every layer appends at the same
    /// base position; call [`KvCache::bump`] once after the last layer.
    pub fn append(&mut self, layer: usize, seq: usize, k_new: &[f32],
                  v_new: &[f32], t_new: usize) {
        let (nh, hd) = (self.heads, self.head_dim);
        let base = self.lens[seq];
        assert!(base + t_new <= self.capacity,
                "KV cache overflow: {base}+{t_new} > {}", self.capacity);
        assert_eq!(k_new.len(), nh * t_new * hd, "k chunk shape");
        assert_eq!(v_new.len(), nh * t_new * hd, "v chunk shape");
        for h in 0..nh {
            let src = h * t_new * hd;
            let dst = self.at(seq, h, base);
            self.k[layer][dst..dst + t_new * hd]
                .copy_from_slice(&k_new[src..src + t_new * hd]);
            self.v[layer][dst..dst + t_new * hd]
                .copy_from_slice(&v_new[src..src + t_new * hd]);
        }
    }

    /// Advance sequence `seq` by `t_new` cached positions (once per
    /// appended chunk, after all layers have run).
    pub fn bump(&mut self, seq: usize, t_new: usize) {
        self.lens[seq] += t_new;
        debug_assert!(self.lens[seq] <= self.capacity);
    }

    /// Causal softmax attention of a freshly-appended chunk's queries
    /// over this sequence's cache: `q` is `[heads, t_new, head_dim]`
    /// (RoPE'd at absolute positions `len..len+t_new`), its K/V already
    /// appended via [`KvCache::append`].  Chunk row `i` attends to cached
    /// positions `0..len+i+1`, which is exactly full causal attention.
    /// Returns `[heads, t_new, head_dim]`.
    pub fn attend(&mut self, layer: usize, seq: usize, q: &[f32],
                  t_new: usize) -> Vec<f32> {
        let (nh, hd, cap) = (self.heads, self.head_dim, self.capacity);
        let base = self.lens[seq];
        assert_eq!(q.len(), nh * t_new * hd, "q chunk shape");
        // the heads of one sequence are contiguous: [nh, cap, hd]
        let mut scratch = std::mem::take(&mut self.scratch);
        let lo = self.at(seq, 0, 0);
        let kc = &self.k[layer][lo..lo + nh * cap * hd];
        let vc = &self.v[layer][lo..lo + nh * cap * hd];
        let o = crate::kernels::cached_attend(q, kc, vc, nh, t_new, base,
                                              cap, hd, &mut scratch);
        self.scratch = scratch;
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::causal_attention_fwd;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
    }

    #[test]
    fn append_then_attend_matches_full_causal_attention() {
        prop_check("cache attend == causal_attention_fwd", 20, |rng| {
            let nh = 1 + rng.below(3);
            let hd = 2 * (1 + rng.below(4));
            let t = 2 + rng.below(6);
            let q = randv(nh * t * hd, rng);
            let k = randv(nh * t * hd, rng);
            let v = randv(nh * t * hd, rng);
            let (want, _) = causal_attention_fwd(&q, &k, &v, nh, t, hd);
            // feed the same q/k/v through the cache one token at a time
            let mut cache = KvCache::new(1, 1, nh, hd, t);
            let mut got = vec![0.0f32; nh * t * hd];
            for i in 0..t {
                let pick = |x: &[f32]| -> Vec<f32> {
                    (0..nh)
                        .flat_map(|h| {
                            x[(h * t + i) * hd..(h * t + i + 1) * hd]
                                .to_vec()
                        })
                        .collect()
                };
                let (qi, ki, vi) = (pick(&q), pick(&k), pick(&v));
                cache.append(0, 0, &ki, &vi, 1);
                let oi = cache.attend(0, 0, &qi, 1);
                cache.bump(0, 1);
                for h in 0..nh {
                    got[(h * t + i) * hd..(h * t + i + 1) * hd]
                        .copy_from_slice(&oi[h * hd..(h + 1) * hd]);
                }
            }
            assert_close(&got, &want, 1e-5, 1e-6)
        });
    }

    #[test]
    fn chunked_append_equals_one_shot() {
        let mut rng = Rng::new(5);
        let (nh, hd, t) = (2, 4, 6);
        let q = randv(nh * t * hd, &mut rng);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let mut one = KvCache::new(1, 1, nh, hd, t);
        one.append(0, 0, &k, &v, t);
        let want = one.attend(0, 0, &q, t);
        // split the chunk 4 + 2
        let split = 4;
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        let mut two = KvCache::new(1, 1, nh, hd, t);
        two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split), split);
        let o1 = two.attend(0, 0, &part(&q, 0, split), split);
        two.bump(0, split);
        two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                   t - split);
        let o2 = two.attend(0, 0, &part(&q, split, t), t - split);
        two.bump(0, t - split);
        assert_eq!(two.len(0), t);
        for h in 0..nh {
            for i in 0..t {
                let w = &want[(h * t + i) * hd..(h * t + i + 1) * hd];
                let g = if i < split {
                    &o1[(h * split + i) * hd..(h * split + i + 1) * hd]
                } else {
                    let ii = i - split;
                    let tn = t - split;
                    &o2[(h * tn + ii) * hd..(h * tn + ii + 1) * hd]
                };
                assert_close(g, w, 1e-6, 1e-7).unwrap();
            }
        }
    }

    #[test]
    fn sequences_are_independent() {
        let mut rng = Rng::new(9);
        let (nh, hd) = (2, 4);
        let mut cache = KvCache::new(1, 3, nh, hd, 8);
        let k0 = randv(nh * hd, &mut rng);
        let v0 = randv(nh * hd, &mut rng);
        cache.append(0, 0, &k0, &v0, 1);
        cache.bump(0, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (1, 0, 2));
        cache.reset();
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (0, 0, 0));
    }

    #[test]
    fn bytes_accounting() {
        let c = KvCache::new(2, 3, 4, 8, 16);
        assert_eq!(c.bytes(), 2 * 2 * 3 * 4 * 16 * 8 * 4);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1, 2, 2);
        let kv = vec![0.0; 2];
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
    }
}
