//! Per-layer key/value cache for incremental autoregressive decoding.
//!
//! During generation each new token only needs its *own* q/k/v plus the
//! keys and values of every earlier position — which never change once
//! computed (RoPE is applied at the absolute position before caching).
//! Caching them turns per-token decode cost from O(T²) re-forward work
//! into O(T): one attention sweep over the cache per layer.
//!
//! Layout: one `[batch·heads, capacity, head_dim]` buffer per layer for
//! K and for V, in a dtype-tagged storage mode (`--kv-dtype`): `f32`
//! (the default, exact), `bf16` (half the bytes, RNE-rounded per
//! element), or `int8` (quarter the bytes, symmetric per-position-row
//! quantization with one f32 scale per `(seq, head, pos)` row — the
//! same scheme the frozen base uses).  Sequences advance independently
//! (`lens` is per-sequence), so ragged prompts and per-sequence stop
//! handling in a batched decode loop need no padding or masking:
//! attention for sequence `s` simply sweeps `0..lens[s]`.
//!
//! Attention over the cache runs on the shared kernel layer
//! ([`crate::kernels::cached_attend`]), which mirrors
//! `kernels::causal_attention_fwd` operation-for-operation (same
//! dot-product, max-subtraction and normalization order), so f32 cached
//! decode reproduces the full re-forward logits bit-for-bit — the
//! property `rust/tests/inference.rs` pins down.  Quantized modes
//! dequantize the live prefix into a reused f32 scratch before the same
//! kernel, trading a bounded representation error (pinned by tests
//! below) for serving memory that scales with concurrent users.

use crate::kernels;
use crate::tensor::dtype::{bf16_to_f32, f32_to_bf16, quantize_row_i8,
                           DType};

/// One layer's K or V storage in the cache's dtype.
enum KvBuf {
    F32(Vec<f32>),
    Bf16(Vec<u16>),
    /// codes plus one symmetric scale per `(seq, head, pos)` head-dim
    /// row (quantized at append time; rows past a sequence's length are
    /// dead until overwritten)
    I8 { q: Vec<i8>, scales: Vec<f32> },
}

impl KvBuf {
    fn new(dtype: DType, numel: usize, rows: usize) -> KvBuf {
        match dtype {
            DType::F32 => KvBuf::F32(vec![0.0; numel]),
            DType::Bf16 => KvBuf::Bf16(vec![0; numel]),
            DType::I8 => KvBuf::I8 { q: vec![0; numel],
                                     scales: vec![0.0; rows] },
        }
    }

    /// Store `src` (whole head-dim rows) at element offset `dst`
    /// (`dst` is a multiple of `hd`, `src.len()` a multiple of `hd`).
    fn store_rows(&mut self, dst: usize, src: &[f32], hd: usize) {
        match self {
            KvBuf::F32(d) => {
                d[dst..dst + src.len()].copy_from_slice(src);
            }
            KvBuf::Bf16(d) => {
                for (o, &x) in d[dst..dst + src.len()].iter_mut()
                    .zip(src) {
                    *o = f32_to_bf16(x);
                }
            }
            KvBuf::I8 { q, scales } => {
                for (r, row) in src.chunks_exact(hd).enumerate() {
                    let o = dst + r * hd;
                    scales[o / hd] =
                        quantize_row_i8(row, &mut q[o..o + hd]);
                }
            }
        }
    }

    /// Dequantize whole head-dim rows `[src, src + n)` (element
    /// offsets) into `out`.
    fn load_rows(&self, src: usize, out: &mut [f32], hd: usize) {
        match self {
            KvBuf::F32(d) => out.copy_from_slice(&d[src..src + out.len()]),
            KvBuf::Bf16(d) => {
                for (o, &b) in out.iter_mut()
                    .zip(&d[src..src + out.len()]) {
                    *o = bf16_to_f32(b);
                }
            }
            KvBuf::I8 { q, scales } => {
                for (r, row) in out.chunks_exact_mut(hd).enumerate() {
                    let o = src + r * hd;
                    let s = scales[o / hd];
                    for (y, &c) in row.iter_mut().zip(&q[o..o + hd]) {
                        *y = s * c as f32;
                    }
                }
            }
        }
    }

    /// Resident bytes (int8 includes its per-row f32 scales).
    fn bytes(&self) -> usize {
        match self {
            KvBuf::F32(d) => 4 * d.len(),
            KvBuf::Bf16(d) => 2 * d.len(),
            KvBuf::I8 { q, scales } => q.len() + 4 * scales.len(),
        }
    }
}

/// Key/value cache over `layers × batch` independent sequences.
pub struct KvCache {
    pub layers: usize,
    pub batch: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// maximum positions per sequence
    pub capacity: usize,
    /// storage dtype of the K/V buffers (`--kv-dtype`)
    dtype: DType,
    /// tokens currently cached, per sequence
    lens: Vec<usize>,
    /// slot lifecycle for continuous-batching schedulers: sequence
    /// indices not currently owned by a live request, lowest on top.
    /// Purely bookkeeping — batch-at-once users (`infer::generate`)
    /// index slots directly and never touch it.
    free: Vec<usize>,
    /// per layer: `[batch·heads, capacity, head_dim]`
    k: Vec<KvBuf>,
    v: Vec<KvBuf>,
    /// score-row scratch reused across `attend` calls (the per-layer
    /// decode hot path would otherwise heap-allocate per call)
    scratch: Vec<f32>,
    /// dequantized `[heads, ctx, head_dim]` K/V scratch for the packed
    /// storage modes, reused across `attend` calls
    kdq: Vec<f32>,
    vdq: Vec<f32>,
}

impl KvCache {
    /// An exact f32 cache — the default storage mode.
    pub fn new(layers: usize, batch: usize, heads: usize, head_dim: usize,
               capacity: usize) -> KvCache {
        KvCache::with_dtype(layers, batch, heads, head_dim, capacity,
                            DType::F32)
    }

    /// A cache storing K/V in `dtype` (`--kv-dtype`).
    pub fn with_dtype(layers: usize, batch: usize, heads: usize,
                      head_dim: usize, capacity: usize, dtype: DType)
        -> KvCache {
        assert!(layers > 0 && batch > 0 && heads > 0 && head_dim > 0
                && capacity > 0, "degenerate KV cache shape");
        let per_layer = batch * heads * capacity * head_dim;
        let rows = batch * heads * capacity;
        KvCache {
            layers,
            batch,
            heads,
            head_dim,
            capacity,
            dtype,
            lens: vec![0; batch],
            free: (0..batch).rev().collect(),
            k: (0..layers).map(|_| KvBuf::new(dtype, per_layer, rows))
                .collect(),
            v: (0..layers).map(|_| KvBuf::new(dtype, per_layer, rows))
                .collect(),
            scratch: Vec::new(),
            kdq: Vec::new(),
            vdq: Vec::new(),
        }
    }

    /// Storage dtype of the K/V buffers.
    pub fn dtype(&self) -> DType {
        self.dtype
    }

    /// Tokens cached so far for sequence `seq`.
    pub fn len(&self, seq: usize) -> usize {
        self.lens[seq]
    }

    /// Forget all cached positions (reuse the allocation for a new batch).
    pub fn reset(&mut self) {
        self.lens.fill(0);
        self.free = (0..self.batch).rev().collect();
    }

    /// Claim a free sequence slot for a newly admitted request (lowest
    /// index first), or `None` when every slot is owned.  The slot
    /// starts at length 0 — any K/V rows a previous owner left behind
    /// are dead, since attention only ever sweeps `0..len`.
    pub fn acquire(&mut self) -> Option<usize> {
        let seq = self.free.pop()?;
        self.lens[seq] = 0;
        Some(seq)
    }

    /// Return a retired request's slot to the free list.  The whole
    /// cache allocation stays put: reclaiming a slot is O(1), and a
    /// request admitted into it decodes bitwise identically to one
    /// admitted into a fresh cache (`rust/tests/serving.rs`).
    pub fn release(&mut self, seq: usize) {
        assert!(seq < self.batch, "slot {seq} out of batch {}", self.batch);
        assert!(!self.free.contains(&seq), "double release of slot {seq}");
        self.lens[seq] = 0;
        self.free.push(seq);
    }

    /// Slots currently available to [`KvCache::acquire`].
    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Cache memory footprint in bytes (serving-capacity accounting):
    /// the K and V payloads at their storage width, plus the int8
    /// per-row scales when quantized.
    pub fn bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(|b| b.bytes()).sum()
    }

    /// Flat offset of `(seq, head, pos)` in a layer buffer.
    #[inline]
    fn at(&self, seq: usize, head: usize, pos: usize) -> usize {
        ((seq * self.heads + head) * self.capacity + pos) * self.head_dim
    }

    /// Append `t_new` RoPE'd key rows and value rows for sequence `seq`
    /// at its current length.  `k_new`/`v_new` are `[heads, t_new,
    /// head_dim]` (the `to_heads` layout of one sequence's chunk).  The
    /// sequence length is NOT advanced — every layer appends at the same
    /// base position; call [`KvCache::bump`] once after the last layer.
    pub fn append(&mut self, layer: usize, seq: usize, k_new: &[f32],
                  v_new: &[f32], t_new: usize) {
        let (nh, hd) = (self.heads, self.head_dim);
        let base = self.lens[seq];
        assert!(base + t_new <= self.capacity,
                "KV cache overflow: {base}+{t_new} > {}", self.capacity);
        assert_eq!(k_new.len(), nh * t_new * hd, "k chunk shape");
        assert_eq!(v_new.len(), nh * t_new * hd, "v chunk shape");
        for h in 0..nh {
            let src = h * t_new * hd;
            let dst = self.at(seq, h, base);
            self.k[layer].store_rows(dst, &k_new[src..src + t_new * hd],
                                     hd);
            self.v[layer].store_rows(dst, &v_new[src..src + t_new * hd],
                                     hd);
        }
    }

    /// Advance sequence `seq` by `t_new` cached positions (once per
    /// appended chunk, after all layers have run).
    pub fn bump(&mut self, seq: usize, t_new: usize) {
        self.lens[seq] += t_new;
        debug_assert!(self.lens[seq] <= self.capacity);
    }

    /// Causal softmax attention of a freshly-appended chunk's queries
    /// over this sequence's cache: `q` is `[heads, t_new, head_dim]`
    /// (RoPE'd at absolute positions `len..len+t_new`), its K/V already
    /// appended via [`KvCache::append`].  Chunk row `i` attends to cached
    /// positions `0..len+i+1`, which is exactly full causal attention.
    /// Returns `[heads, t_new, head_dim]`.
    ///
    /// The f32 storage mode hands the kernel zero-copy slices; packed
    /// modes dequantize only the live prefix (`0..len+t_new`) of each
    /// head into reused scratch, so decode never touches dead capacity.
    pub fn attend(&mut self, layer: usize, seq: usize, q: &[f32],
                  t_new: usize) -> Vec<f32> {
        let (nh, hd, cap) = (self.heads, self.head_dim, self.capacity);
        let base = self.lens[seq];
        assert_eq!(q.len(), nh * t_new * hd, "q chunk shape");
        let mut scratch = std::mem::take(&mut self.scratch);
        let o = if self.dtype == DType::F32 {
            // the heads of one sequence are contiguous: [nh, cap, hd]
            let lo = self.at(seq, 0, 0);
            let (kc, vc) = match (&self.k[layer], &self.v[layer]) {
                (KvBuf::F32(kd), KvBuf::F32(vd)) => {
                    (&kd[lo..lo + nh * cap * hd],
                     &vd[lo..lo + nh * cap * hd])
                }
                _ => unreachable!("f32 cache holds f32 buffers"),
            };
            kernels::cached_attend(q, kc, vc, nh, t_new, base, cap, hd,
                                   &mut scratch)
        } else {
            let ctx = base + t_new;
            let mut kdq = std::mem::take(&mut self.kdq);
            let mut vdq = std::mem::take(&mut self.vdq);
            kdq.resize(nh * ctx * hd, 0.0);
            vdq.resize(nh * ctx * hd, 0.0);
            for h in 0..nh {
                let src = self.at(seq, h, 0);
                let dst = h * ctx * hd;
                self.k[layer].load_rows(src,
                                        &mut kdq[dst..dst + ctx * hd],
                                        hd);
                self.v[layer].load_rows(src,
                                        &mut vdq[dst..dst + ctx * hd],
                                        hd);
            }
            // the dequantized copy is tight: capacity == ctx
            let o = kernels::cached_attend(q, &kdq, &vdq, nh, t_new,
                                           base, ctx, hd, &mut scratch);
            self.kdq = kdq;
            self.vdq = vdq;
            o
        };
        self.scratch = scratch;
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::causal_attention_fwd;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.7)).collect()
    }

    #[test]
    fn append_then_attend_matches_full_causal_attention() {
        prop_check("cache attend == causal_attention_fwd", 20, |rng| {
            let nh = 1 + rng.below(3);
            let hd = 2 * (1 + rng.below(4));
            let t = 2 + rng.below(6);
            let q = randv(nh * t * hd, rng);
            let k = randv(nh * t * hd, rng);
            let v = randv(nh * t * hd, rng);
            let (want, _) = causal_attention_fwd(&q, &k, &v, nh, t, hd);
            // feed the same q/k/v through the cache one token at a time
            let mut cache = KvCache::new(1, 1, nh, hd, t);
            let mut got = vec![0.0f32; nh * t * hd];
            for i in 0..t {
                let pick = |x: &[f32]| -> Vec<f32> {
                    (0..nh)
                        .flat_map(|h| {
                            x[(h * t + i) * hd..(h * t + i + 1) * hd]
                                .to_vec()
                        })
                        .collect()
                };
                let (qi, ki, vi) = (pick(&q), pick(&k), pick(&v));
                cache.append(0, 0, &ki, &vi, 1);
                let oi = cache.attend(0, 0, &qi, 1);
                cache.bump(0, 1);
                for h in 0..nh {
                    got[(h * t + i) * hd..(h * t + i + 1) * hd]
                        .copy_from_slice(&oi[h * hd..(h + 1) * hd]);
                }
            }
            assert_close(&got, &want, 1e-5, 1e-6)
        });
    }

    #[test]
    fn chunked_append_equals_one_shot() {
        let mut rng = Rng::new(5);
        let (nh, hd, t) = (2, 4, 6);
        let q = randv(nh * t * hd, &mut rng);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let mut one = KvCache::new(1, 1, nh, hd, t);
        one.append(0, 0, &k, &v, t);
        let want = one.attend(0, 0, &q, t);
        // split the chunk 4 + 2
        let split = 4;
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        let mut two = KvCache::new(1, 1, nh, hd, t);
        two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split), split);
        let o1 = two.attend(0, 0, &part(&q, 0, split), split);
        two.bump(0, split);
        two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                   t - split);
        let o2 = two.attend(0, 0, &part(&q, split, t), t - split);
        two.bump(0, t - split);
        assert_eq!(two.len(0), t);
        for h in 0..nh {
            for i in 0..t {
                let w = &want[(h * t + i) * hd..(h * t + i + 1) * hd];
                let g = if i < split {
                    &o1[(h * split + i) * hd..(h * split + i + 1) * hd]
                } else {
                    let ii = i - split;
                    let tn = t - split;
                    &o2[(h * tn + ii) * hd..(h * tn + ii + 1) * hd]
                };
                assert_close(g, w, 1e-6, 1e-7).unwrap();
            }
        }
    }

    #[test]
    fn sequences_are_independent() {
        let mut rng = Rng::new(9);
        let (nh, hd) = (2, 4);
        let mut cache = KvCache::new(1, 3, nh, hd, 8);
        let k0 = randv(nh * hd, &mut rng);
        let v0 = randv(nh * hd, &mut rng);
        cache.append(0, 0, &k0, &v0, 1);
        cache.bump(0, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        cache.append(0, 2, &k0, &v0, 1);
        cache.bump(2, 1);
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (1, 0, 2));
        cache.reset();
        assert_eq!((cache.len(0), cache.len(1), cache.len(2)), (0, 0, 0));
    }

    #[test]
    fn slot_lifecycle_acquire_release_reset() {
        let mut c = KvCache::new(1, 3, 1, 2, 4);
        assert_eq!(c.n_free(), 3);
        // lowest slot first, so admission order matches sequence order
        assert_eq!(c.acquire(), Some(0));
        assert_eq!(c.acquire(), Some(1));
        assert_eq!(c.acquire(), Some(2));
        assert_eq!(c.acquire(), None);
        let kv = vec![0.5f32; 2];
        c.append(0, 1, &kv, &kv, 1);
        c.bump(1, 1);
        assert_eq!(c.len(1), 1);
        // the retired slot comes back with length 0 and is reused
        // before lower-numbered never-freed slots
        c.release(1);
        assert_eq!((c.n_free(), c.len(1)), (1, 0));
        assert_eq!(c.acquire(), Some(1));
        c.release(1);
        c.release(0);
        c.release(2);
        c.reset();
        assert_eq!(c.n_free(), 3);
        assert_eq!(c.acquire(), Some(0));
    }

    #[test]
    #[should_panic(expected = "double release")]
    fn double_release_panics() {
        let mut c = KvCache::new(1, 2, 1, 2, 4);
        let s = c.acquire().unwrap();
        c.release(s);
        c.release(s);
    }

    #[test]
    fn bytes_accounting() {
        let c = KvCache::new(2, 3, 4, 8, 16);
        assert_eq!(c.bytes(), 2 * 2 * 3 * 4 * 16 * 8 * 4);
        // bf16 halves the payload exactly
        let b = KvCache::with_dtype(2, 3, 4, 8, 16, DType::Bf16);
        assert_eq!(b.bytes(), c.bytes() / 2);
        // int8: 1 byte/elem + one f32 scale per (seq, head, pos) row
        let i = KvCache::with_dtype(2, 3, 4, 8, 16, DType::I8);
        let rows = 3 * 4 * 16;
        assert_eq!(i.bytes(), 2 * 2 * (rows * 8 + 4 * rows));
        assert_eq!(i.dtype(), DType::I8);
        assert_eq!(c.dtype(), DType::F32);
    }

    #[test]
    fn quantized_cache_attends_close_to_f32() {
        // bf16/int8 storage perturbs K/V by at most one quantization
        // step per element; the attention output (a convex combination
        // of V rows re-weighted by slightly-off scores) stays close
        for (dtype, tol) in [(DType::Bf16, 0.02), (DType::I8, 0.08)] {
            prop_check("quantized KV attend close", 10, move |rng| {
                let nh = 1 + rng.below(3);
                let hd = 4 * (1 + rng.below(3));
                let t = 2 + rng.below(8);
                let q = randv(nh * t * hd, rng);
                let k = randv(nh * t * hd, rng);
                let v = randv(nh * t * hd, rng);
                let mut exact = KvCache::new(1, 1, nh, hd, t);
                exact.append(0, 0, &k, &v, t);
                let want = exact.attend(0, 0, &q, t);
                let mut quant =
                    KvCache::with_dtype(1, 1, nh, hd, t, dtype);
                quant.append(0, 0, &k, &v, t);
                let got = quant.attend(0, 0, &q, t);
                for (g, w) in got.iter().zip(&want) {
                    if (g - w).abs() > tol {
                        return Err(format!(
                            "{dtype}: {g} vs {w} (tol {tol})"));
                    }
                }
                Ok(())
            });
        }
    }

    #[test]
    fn quantized_chunked_append_is_position_consistent() {
        // appending in chunks quantizes exactly the same per-position
        // rows, so chunked == one-shot bitwise for every storage mode
        let mut rng = Rng::new(31);
        let (nh, hd, t, split) = (2, 8, 6, 4);
        let k = randv(nh * t * hd, &mut rng);
        let v = randv(nh * t * hd, &mut rng);
        let q = randv(nh * (t - split) * hd, &mut rng);
        let part = |x: &[f32], lo: usize, hi: usize| -> Vec<f32> {
            (0..nh)
                .flat_map(|h| {
                    x[(h * t + lo) * hd..(h * t + hi) * hd].to_vec()
                })
                .collect()
        };
        for dtype in [DType::Bf16, DType::I8] {
            let mut one = KvCache::with_dtype(1, 1, nh, hd, t, dtype);
            one.append(0, 0, &k, &v, t);
            one.bump(0, split); // queries sit at positions split..t
            let want = one.attend(0, 0, &q, t - split);
            let mut two = KvCache::with_dtype(1, 1, nh, hd, t, dtype);
            two.append(0, 0, &part(&k, 0, split), &part(&v, 0, split),
                       split);
            two.bump(0, split);
            two.append(0, 0, &part(&k, split, t), &part(&v, split, t),
                       t - split);
            let got = two.attend(0, 0, &q, t - split);
            let bits = |x: &[f32]| -> Vec<u32> {
                x.iter().map(|v| v.to_bits()).collect()
            };
            assert_eq!(bits(&got), bits(&want), "{dtype}");
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut c = KvCache::new(1, 1, 1, 2, 2);
        let kv = vec![0.0; 2];
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
        c.bump(0, 1);
        c.append(0, 0, &kv, &kv, 1);
    }
}
