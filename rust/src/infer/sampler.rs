//! Token sampling: greedy argmax, temperature softmax, top-k filtering.
//!
//! All stochastic choices draw from an explicit `util::rng::Rng`, so a
//! generation run is bit-reproducible from `(seed, sampling params)` —
//! the determinism contract `rust/tests/inference.rs` pins down.

use crate::util::rng::Rng;

/// Sampling policy for one decode step.
///
/// * `temperature <= 0` — greedy argmax (ties break to the lowest id),
///   `top_k` is ignored.
/// * otherwise — softmax over `logits / temperature`, restricted to the
///   `top_k` highest logits when `top_k > 0` (0 means no truncation).
#[derive(Clone, Copy, Debug)]
pub struct Sampler {
    pub temperature: f32,
    pub top_k: usize,
}

impl Sampler {
    pub fn greedy() -> Sampler {
        Sampler { temperature: 0.0, top_k: 0 }
    }

    pub fn top_k(k: usize, temperature: f32) -> Sampler {
        Sampler { temperature, top_k: k }
    }

    /// Draw one token id from a logit row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        assert!(!logits.is_empty(), "empty logit row");
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        let n = logits.len();
        if self.top_k == 0 || self.top_k >= n {
            // full softmax: two O(V) passes over ascending ids, no
            // sort and no candidate allocation
            let zmax = logits.iter().fold(f32::NEG_INFINITY,
                                          |m, &z| m.max(z));
            let inv_t = 1.0 / self.temperature;
            let w = |z: f32| (((z - zmax) * inv_t) as f64).exp();
            let total: f64 = logits.iter().map(|&z| w(z)).sum();
            let mut u = rng.uniform() * total;
            for (i, &z) in logits.iter().enumerate() {
                u -= w(z);
                if u <= 0.0 {
                    return i;
                }
            }
            return n - 1;
        }
        // top-k: O(V) partial selection of the k largest (total_cmp
        // keeps the comparator a total order even on NaN logits, which
        // would make the selection panic since Rust 1.81), then a
        // softmax-CDF walk in canonical ascending-id order so the draw
        // does not depend on select_nth's internal ordering
        let mut idx: Vec<usize> = (0..n).collect();
        let k = self.top_k;
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b].total_cmp(&logits[a]).then(a.cmp(&b))
        });
        idx.truncate(k);
        idx.sort_unstable();
        let zmax = idx
            .iter()
            .fold(f32::NEG_INFINITY, |m, &i| m.max(logits[i]));
        let inv_t = 1.0 / self.temperature;
        let w = |i: usize| (((logits[i] - zmax) * inv_t) as f64).exp();
        let total: f64 = idx.iter().map(|&i| w(i)).sum();
        let mut u = rng.uniform() * total;
        for &i in &idx {
            u -= w(i);
            if u <= 0.0 {
                return i;
            }
        }
        *idx.last().expect("non-empty candidate set")
    }
}

/// First-max argmax (deterministic tie-break to the lowest id).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &z) in logits.iter().enumerate() {
        if z > bv {
            bv = z;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax_with_low_tie() {
        let mut rng = Rng::new(0);
        let s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 2.0, -1.0, 2.0], &mut rng), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0, 3.0, 1.0, 2.0, -4.0];
        let s = Sampler::top_k(2, 1.0);
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 3, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn temperature_softmax_covers_support() {
        // at high temperature every id should eventually appear
        let logits = [0.0, 0.5, -0.5, 0.2];
        let s = Sampler { temperature: 5.0, top_k: 0 };
        let mut rng = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[s.sample(&logits, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&x| x), "support not covered: {seen:?}");
    }

    #[test]
    fn sampling_is_deterministic_from_rng_state() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin())
            .collect();
        let s = Sampler::top_k(8, 0.9);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..50).map(|_| s.sample(&logits, &mut rng)).collect()
        };
        assert_eq!(draw(42), draw(42));
        assert_ne!(draw(42), draw(43));
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = [0.0, 4.0, 1.0];
        let s = Sampler { temperature: 0.05, top_k: 0 };
        let mut rng = Rng::new(11);
        let hits = (0..200)
            .filter(|_| s.sample(&logits, &mut rng) == 1)
            .count();
        assert!(hits > 190, "only {hits}/200 on the mode");
    }
}
