//! Token sampling: greedy argmax, temperature softmax, top-k and top-p
//! (nucleus) filtering.
//!
//! All stochastic choices draw from an explicit `util::rng::Rng`, so a
//! generation run is bit-reproducible from `(seed, sampling params)` —
//! the determinism contract `rust/tests/inference.rs` pins down.

use crate::util::rng::Rng;

/// Sampling policy for one decode step.
///
/// * `temperature <= 0` — greedy argmax (ties break to the lowest id),
///   `top_k`/`top_p` are ignored.
/// * otherwise — softmax over `logits / temperature`, restricted to the
///   `top_k` highest logits when `top_k > 0` (0 means no truncation),
///   then nucleus-truncated to the smallest probability-descending
///   prefix with mass ≥ `top_p` when `top_p < 1` (1 means no
///   truncation; both filters compose, top-k first).
#[derive(Clone, Copy, Debug)]
pub struct Sampler {
    pub temperature: f32,
    pub top_k: usize,
    /// nucleus mass in `(0, 1]`; `1.0` disables the filter (values
    /// `<= 0` are treated as disabled too, never as an empty support)
    pub top_p: f32,
}

impl Sampler {
    pub fn greedy() -> Sampler {
        Sampler { temperature: 0.0, top_k: 0, top_p: 1.0 }
    }

    pub fn top_k(k: usize, temperature: f32) -> Sampler {
        Sampler { temperature, top_k: k, top_p: 1.0 }
    }

    pub fn nucleus(p: f32, temperature: f32) -> Sampler {
        Sampler { temperature, top_k: 0, top_p: p }
    }

    /// Draw one token id from a logit row.
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> usize {
        assert!(!logits.is_empty(), "empty logit row");
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        let n = logits.len();
        let nucleus = self.top_p > 0.0 && self.top_p < 1.0;
        if (self.top_k == 0 || self.top_k >= n) && !nucleus {
            // full softmax: two O(V) passes over ascending ids, no
            // sort and no candidate allocation
            let zmax = logits.iter().fold(f32::NEG_INFINITY,
                                          |m, &z| m.max(z));
            let inv_t = 1.0 / self.temperature;
            let w = |z: f32| (((z - zmax) * inv_t) as f64).exp();
            let total: f64 = logits.iter().map(|&z| w(z)).sum();
            let mut u = rng.uniform() * total;
            for (i, &z) in logits.iter().enumerate() {
                u -= w(z);
                if u <= 0.0 {
                    return i;
                }
            }
            return n - 1;
        }
        // top-k: O(V) partial selection of the k largest (total_cmp
        // keeps the comparator a total order even on NaN logits, which
        // would make the selection panic since Rust 1.81), then a
        // softmax-CDF walk in canonical ascending-id order so the draw
        // does not depend on select_nth's internal ordering
        let mut idx: Vec<usize> = (0..n).collect();
        if self.top_k > 0 && self.top_k < n {
            let k = self.top_k;
            idx.select_nth_unstable_by(k - 1, |&a, &b| {
                logits[b].total_cmp(&logits[a]).then(a.cmp(&b))
            });
            idx.truncate(k);
        }
        if nucleus {
            // nucleus: order the candidates by descending probability
            // (ties to the lowest id) and keep the smallest prefix
            // whose softmax mass reaches top_p — at least the mode.
            // NaN weights never reach the threshold, so a poisoned row
            // degrades to "keep everything" instead of panicking,
            // matching the other paths' NaN posture.
            idx.sort_unstable_by(|&a, &b| {
                logits[b].total_cmp(&logits[a]).then(a.cmp(&b))
            });
            let zmax = idx
                .iter()
                .fold(f32::NEG_INFINITY, |m, &i| m.max(logits[i]));
            let inv_t = 1.0 / self.temperature;
            let w = |i: usize| (((logits[i] - zmax) * inv_t) as f64).exp();
            let total: f64 = idx.iter().map(|&i| w(i)).sum();
            let target = self.top_p as f64 * total;
            let mut acc = 0.0f64;
            let mut keep = idx.len();
            for (j, &i) in idx.iter().enumerate() {
                acc += w(i);
                if acc >= target {
                    keep = j + 1;
                    break;
                }
            }
            idx.truncate(keep);
        }
        idx.sort_unstable();
        let zmax = idx
            .iter()
            .fold(f32::NEG_INFINITY, |m, &i| m.max(logits[i]));
        let inv_t = 1.0 / self.temperature;
        let w = |i: usize| (((logits[i] - zmax) * inv_t) as f64).exp();
        let total: f64 = idx.iter().map(|&i| w(i)).sum();
        let mut u = rng.uniform() * total;
        for &i in &idx {
            u -= w(i);
            if u <= 0.0 {
                return i;
            }
        }
        *idx.last().expect("non-empty candidate set")
    }
}

/// First-max argmax (deterministic tie-break to the lowest id).
pub fn argmax(logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &z) in logits.iter().enumerate() {
        if z > bv {
            bv = z;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax_with_low_tie() {
        let mut rng = Rng::new(0);
        let s = Sampler::greedy();
        assert_eq!(s.sample(&[0.1, 2.0, -1.0, 2.0], &mut rng), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0, 3.0, 1.0, 2.0, -4.0];
        let s = Sampler::top_k(2, 1.0);
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            let t = s.sample(&logits, &mut rng);
            assert!(t == 1 || t == 3, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn temperature_softmax_covers_support() {
        // at high temperature every id should eventually appear
        let logits = [0.0, 0.5, -0.5, 0.2];
        let s = Sampler { temperature: 5.0, top_k: 0, top_p: 1.0 };
        let mut rng = Rng::new(3);
        let mut seen = [false; 4];
        for _ in 0..500 {
            seen[s.sample(&logits, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&x| x), "support not covered: {seen:?}");
    }

    #[test]
    fn sampling_is_deterministic_from_rng_state() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin())
            .collect();
        let s = Sampler::top_k(8, 0.9);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..50).map(|_| s.sample(&logits, &mut rng)).collect()
        };
        assert_eq!(draw(42), draw(42));
        assert_ne!(draw(42), draw(43));
    }

    #[test]
    fn top_p_one_is_bitwise_the_unfiltered_path() {
        // the nucleus filter off (top_p = 1.0) must not change a single
        // draw vs the pre-top-p sampler: same rng consumption, same ids
        let logits: Vec<f32> = (0..64).map(|i| (i as f32 * 0.83).cos())
            .collect();
        for (k, t) in [(0usize, 1.3f32), (8, 0.7)] {
            let base = Sampler { temperature: t, top_k: k, top_p: 1.0 };
            let off = Sampler { temperature: t, top_k: k, top_p: 0.0 };
            let mut r1 = Rng::new(19);
            let mut r2 = Rng::new(19);
            for _ in 0..100 {
                assert_eq!(base.sample(&logits, &mut r1),
                           off.sample(&logits, &mut r2));
            }
        }
    }

    #[test]
    fn top_p_restricts_support_to_the_nucleus() {
        // softmax([3, 2, 0, -1, -3]) ≈ [.69, .26, .035, .013, .002]:
        // top_p = 0.9 keeps exactly {0, 1} (0.69 < 0.9 ≤ 0.95)
        let logits = [3.0, 2.0, 0.0, -1.0, -3.0];
        let s = Sampler::nucleus(0.9, 1.0);
        let mut rng = Rng::new(5);
        let mut seen = [false; 5];
        for _ in 0..400 {
            seen[s.sample(&logits, &mut rng)] = true;
        }
        assert_eq!(seen, [true, true, false, false, false],
                   "nucleus must be exactly the top-2: {seen:?}");
        // a tiny top_p still keeps the mode
        let tight = Sampler::nucleus(1e-6, 1.0);
        for _ in 0..50 {
            assert_eq!(tight.sample(&logits, &mut rng), 0);
        }
    }

    #[test]
    fn top_p_composes_with_top_k() {
        // top-k=3 keeps {1, 3, 0} (logits 3, 2, 0); nucleus 0.7 then
        // drops id 0 (mass of {1} ≈ .705 ≥ .7 of the k-candidate total)
        let logits = [0.0, 3.0, -5.0, 2.0, -4.0];
        let s = Sampler { temperature: 1.0, top_k: 3, top_p: 0.7 };
        let mut rng = Rng::new(23);
        for _ in 0..200 {
            let t = s.sample(&logits, &mut rng);
            assert_eq!(t, 1, "nucleus within top-k must be the mode");
        }
    }

    #[test]
    fn top_p_is_nan_safe_and_deterministic() {
        let mut logits: Vec<f32> =
            (0..16).map(|i| (i as f32 * 0.41).sin()).collect();
        logits[3] = f32::NAN;
        logits[11] = f32::NAN;
        let s = Sampler::nucleus(0.5, 0.9);
        let draw = |seed: u64| -> Vec<usize> {
            let mut rng = Rng::new(seed);
            (0..50).map(|_| s.sample(&logits, &mut rng)).collect()
        };
        for t in draw(3) {
            assert!(t < logits.len());
        }
        assert_eq!(draw(3), draw(3));
    }

    #[test]
    fn low_temperature_concentrates_on_argmax() {
        let logits = [0.0, 4.0, 1.0];
        let s = Sampler { temperature: 0.05, top_k: 0, top_p: 1.0 };
        let mut rng = Rng::new(11);
        let hits = (0..200)
            .filter(|_| s.sample(&logits, &mut rng) == 1)
            .count();
        assert!(hits > 190, "only {hits}/200 on the mode");
    }
}
