//! Per-request LoRA adapter overlays for multi-tenant serving.
//!
//! Merging (`infer::merge`) bakes one adapter into the dense base —
//! perfect for single-tenant serving, useless when many tasks share one
//! machine: every tenant would need its own full-size merged copy of
//! `W`.  An [`AdapterSet`] is the other deployment shape the LoRA paper
//! describes: the base stays frozen (and quantized — one shared
//! `PackedStore`), and each request carries only its task's `(A, B)`
//! factors, applied *unmerged* in the forward path as
//! `y += scale · (x·Aᵀ)·Bᵀ` per sequence.  Task switching is then a
//! per-request lookup instead of a weight swap, and N tenants cost
//! `N · rank·(m+n)` floats on top of a single base copy.
//!
//! The overlay arithmetic in `runtime/native.rs` mirrors the stored-
//! adapter path of `lin_fwd` operation-for-operation, so serving an
//! adapter as an overlay over the (f32-viewed) base is bitwise
//! identical to decoding from the LoRA-variant store it was extracted
//! from — `rust/tests/serving.rs` pins that down.

use std::collections::HashMap;

use anyhow::{ensure, Result};

use crate::model::layout::{Manifest, ParamStore, Variant};

/// One linear's low-rank factors, shapes self-contained so overlays
/// from manifests of any rank can ride over the same base.
#[derive(Clone, Debug)]
pub struct LowRank {
    /// `[r, n]` — the down-projection applied as `x · Aᵀ`
    pub a: Vec<f32>,
    /// `[m, r]` — the up-projection applied as `(x·Aᵀ) · Bᵀ`
    pub b: Vec<f32>,
    pub r: usize,
    /// out dim (rows of W and of B)
    pub m: usize,
    /// in dim (cols of W and of A)
    pub n: usize,
}

impl LowRank {
    pub fn bytes(&self) -> usize {
        4 * (self.a.len() + self.b.len())
    }
}

/// A named adapter: every adapted linear's `(A, B)` pair plus the
/// manifest's `lora_scale`, detached from any parameter store so the
/// serving scheduler can hold many of these next to ONE shared base.
#[derive(Clone, Debug)]
pub struct AdapterSet {
    pub name: String,
    /// the manifest's `alpha / rank` scaling, applied at overlay time
    pub scale: f32,
    by_linear: HashMap<String, LowRank>,
}

impl AdapterSet {
    /// Extract the adapters of `store` (a LoRA-variant parameter store —
    /// trained, checkpointed, or seeded).  Linears the store's layout
    /// does not adapt (layerwise-hybrid methods) are simply absent from
    /// the set and serve as bare base.  The base weights of `store` are
    /// deliberately NOT captured: deployment premise is that every
    /// adapter rides the one shared frozen base.
    pub fn from_store(manifest: &Manifest, store: &ParamStore,
                      name: &str) -> Result<AdapterSet> {
        let mut by_linear = HashMap::new();
        for li in &manifest.linears {
            let Some((a, b)) = store.lora_pair(li) else { continue };
            let r = store.layout.meta(&li.a)?.rows();
            ensure!(a.len() == r * li.n && b.len() == li.m * r,
                    "adapter {name}: {} factors disagree with manifest \
                     dims (r={r}, m={}, n={})", li.name, li.m, li.n);
            ensure!(a.iter().chain(b).all(|x| x.is_finite()),
                    "adapter {name}: non-finite value in {} factors",
                    li.name);
            by_linear.insert(li.name.clone(), LowRank {
                a: a.to_vec(),
                b: b.to_vec(),
                r,
                m: li.m,
                n: li.n,
            });
        }
        ensure!(!by_linear.is_empty(),
                "adapter {name}: store has no LoRA factors to extract \
                 (wrong variant?)");
        Ok(AdapterSet {
            name: name.to_string(),
            scale: manifest.config.lora_scale() as f32,
            by_linear,
        })
    }

    /// The factors for linear `name`, if this adapter adapts it.
    pub fn get(&self, name: &str) -> Option<&LowRank> {
        self.by_linear.get(name)
    }

    pub fn n_linears(&self) -> usize {
        self.by_linear.len()
    }

    /// Resident f32 payload of this adapter's factors — the per-tenant
    /// marginal cost the serving memory ledger reports next to the one
    /// shared base.
    pub fn resident_bytes(&self) -> usize {
        self.by_linear.values().map(|lr| lr.bytes()).sum()
    }
}

/// Seed a standalone LoRA-variant store and extract its adapters — the
/// `name=seed:N` form of `serve --adapter`, used by smoke tests and
/// demos that have no trained checkpoints on hand.
pub fn seeded_adapter(manifest: &Manifest, name: &str, seed: u64)
    -> Result<AdapterSet> {
    let store =
        crate::model::init::seeded_store(manifest, Variant::Lora, seed)?;
    AdapterSet::from_store(manifest, &store, name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::seeded_store;

    #[test]
    fn extracts_every_adapted_linear() {
        let man = Manifest::builtin("tiny").unwrap();
        let store = seeded_store(&man, Variant::Lora, 3).unwrap();
        let ad = AdapterSet::from_store(&man, &store, "t1").unwrap();
        assert_eq!(ad.n_linears(), man.linears.len());
        assert_eq!(ad.scale, man.config.lora_scale() as f32);
        let mut bytes = 0usize;
        for li in &man.linears {
            let lr = ad.get(&li.name).expect("adapted linear present");
            assert_eq!((lr.m, lr.n), (li.m, li.n));
            assert_eq!(lr.a.len(), lr.r * lr.n);
            assert_eq!(lr.b.len(), lr.m * lr.r);
            let (a, b) = store.lora_pair(li).unwrap();
            assert_eq!(lr.a, a);
            assert_eq!(lr.b, b);
            bytes += 4 * (a.len() + b.len());
        }
        assert_eq!(ad.resident_bytes(), bytes);
        assert!(ad.get("l0.nonexistent").is_none());
    }

    #[test]
    fn full_variant_store_is_rejected() {
        let man = Manifest::builtin("tiny").unwrap();
        let store = seeded_store(&man, Variant::Full, 3).unwrap();
        assert!(AdapterSet::from_store(&man, &store, "t").is_err());
    }

    #[test]
    fn seeded_adapters_differ_by_seed() {
        let man = Manifest::builtin("tiny").unwrap();
        let a = seeded_adapter(&man, "a", 7).unwrap();
        let b = seeded_adapter(&man, "b", 9).unwrap();
        let name = &man.linears[0].name;
        assert_ne!(a.get(name).unwrap().a, b.get(name).unwrap().a);
    }
}
