//! The batched autoregressive generation loop.
//!
//! Drives an [`InferRuntime`]: ragged prompts prefill one sequence at a
//! time into a shared [`KvCache`], then every decode step advances *all*
//! unfinished sequences by one token (each at its own absolute
//! position).  Stop handling is per sequence — a finished sequence
//! leaves the decode batch entirely, so it costs no further compute and
//! its cache rows stop growing while the rest keep generating.
//!
//! Sampling randomness is a fresh stream per `(seed, sequence index)`,
//! so a sequence's continuation does not depend on what else shares its
//! batch — batched and single-sequence generation agree token-for-token,
//! and the same seed always reproduces the same streams.

use anyhow::{ensure, Result};

use super::adapters::AdapterSet;
use super::sampler::Sampler;
use crate::model::packed::ParamSource;
use crate::runtime::InferRuntime;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Generation-loop configuration.
#[derive(Clone, Debug)]
pub struct GenConfig {
    /// tokens to generate per sequence (counting a terminating stop)
    pub max_new: usize,
    pub sampler: Sampler,
    /// token ids that end a sequence (emitted, then the sequence stops)
    pub stop_tokens: Vec<i32>,
    pub seed: u64,
    /// KV-cache capacity ceiling (`--max-context`).  `None` sizes the
    /// cache to fit `max_new` exactly; with a ceiling, a sequence that
    /// fills the cache retires cleanly with fewer generated tokens
    /// instead of aborting the whole batch.
    pub max_context: Option<usize>,
}

impl GenConfig {
    pub fn greedy(max_new: usize) -> GenConfig {
        GenConfig {
            max_new,
            sampler: Sampler::greedy(),
            stop_tokens: Vec::new(),
            seed: 42,
            max_context: None,
        }
    }
}

/// A finished generation: prompts with their continuations, plus the
/// counters the throughput benches and the CLI report.
#[derive(Clone, Debug)]
pub struct Generation {
    /// per sequence: prompt followed by generated tokens
    pub sequences: Vec<Vec<i32>>,
    /// generated-token count per sequence (≤ `max_new`)
    pub n_generated: Vec<usize>,
    pub prefill_tokens: usize,
    pub decode_steps: usize,
}

/// Generate continuations for a batch of (possibly ragged) prompts.
/// `params` is any [`ParamSource`]: the master-precision store, or a
/// quantized `PackedStore` for `--quantize-base` serving.
pub fn generate(rt: &dyn InferRuntime, params: &dyn ParamSource,
                prompts: &[Vec<i32>], cfg: &GenConfig)
    -> Result<Generation> {
    generate_stream(rt, params, prompts, cfg, |_, _| {})
}

/// [`generate`] with a streaming callback: `on_token(seq, token)` fires
/// for every emitted token, in emission order (the CLI's live output).
pub fn generate_stream(rt: &dyn InferRuntime, params: &dyn ParamSource,
                       prompts: &[Vec<i32>], cfg: &GenConfig,
                       on_token: impl FnMut(usize, i32))
    -> Result<Generation> {
    let none: Vec<Option<&AdapterSet>> = vec![None; prompts.len()];
    generate_adapted_stream(rt, params, &none, prompts, cfg, on_token)
}

/// [`generate`] in multi-tenant shape: `params` is the ONE shared base
/// for the whole batch and `adapters[s]` is sequence `s`'s unmerged
/// low-rank overlay (`None` decodes the bare base).  This is the batch
/// semantics the `serve` scheduler runs request-by-request; tests pin
/// that a mixed-adapter batch reproduces each sequence's solo run.
pub fn generate_adapted(rt: &dyn InferRuntime, params: &dyn ParamSource,
                        adapters: &[Option<&AdapterSet>],
                        prompts: &[Vec<i32>], cfg: &GenConfig)
    -> Result<Generation> {
    generate_adapted_stream(rt, params, adapters, prompts, cfg,
                            |_, _| {})
}

/// [`generate_adapted`] with a streaming callback.
pub fn generate_adapted_stream(rt: &dyn InferRuntime,
                               params: &dyn ParamSource,
                               adapters: &[Option<&AdapterSet>],
                               prompts: &[Vec<i32>], cfg: &GenConfig,
                               mut on_token: impl FnMut(usize, i32))
    -> Result<Generation> {
    ensure!(!prompts.is_empty(), "no prompts to generate from");
    ensure!(adapters.len() == prompts.len(),
            "one adapter slot per prompt ({} != {})", adapters.len(),
            prompts.len());
    ensure!(prompts.iter().all(|p| !p.is_empty()),
            "every prompt needs at least one token");
    let b = prompts.len();
    let mut sequences: Vec<Vec<i32>> = prompts.to_vec();
    if cfg.max_new == 0 {
        return Ok(Generation {
            sequences,
            n_generated: vec![0; b],
            prefill_tokens: 0,
            decode_steps: 0,
        });
    }
    let max_prompt = prompts.iter().map(|p| p.len()).max().unwrap_or(1);
    let mut capacity = max_prompt + cfg.max_new;
    if let Some(cap) = cfg.max_context {
        ensure!(max_prompt <= cap,
                "longest prompt ({max_prompt} tokens) exceeds \
                 --max-context {cap}");
        capacity = capacity.min(cap);
    }
    let mut cache = rt.new_cache(b, capacity);
    // one independent sampling stream per (seed, sequence index)
    let mut rngs: Vec<Rng> = (0..b)
        .map(|s| Rng::new(cfg.seed).fork(s as u64))
        .collect();
    // sequences still generating; stopped ones leave the decode batch
    // entirely (no compute, no further cache growth)
    let mut active: Vec<usize> = Vec::with_capacity(b);
    let mut last = vec![0i32; b];
    let mut prefill_tokens = 0usize;
    for (s, prompt) in prompts.iter().enumerate() {
        let sp = crate::obs::span("infer", "prefill");
        let logits =
            rt.prefill_adapted(params, adapters[s], &mut cache, s,
                               prompt)?;
        sp.done();
        prefill_tokens += prompt.len();
        let tok = cfg.sampler.sample(&logits, &mut rngs[s]) as i32;
        sequences[s].push(tok);
        on_token(s, tok);
        last[s] = tok;
        if !cfg.stop_tokens.contains(&tok) {
            active.push(s);
        }
    }
    let v = rt.vocab_out();
    let mut decode_steps = 0usize;
    for _ in 1..cfg.max_new {
        // a sequence whose cache is full cannot take another decode
        // step: retire it cleanly (clamped generation) rather than
        // letting KvCache::append abort the whole batch
        active.retain(|&s| cache.len(s) < cache.capacity);
        if active.is_empty() {
            break;
        }
        let toks: Vec<i32> = active.iter().map(|&s| last[s]).collect();
        let ovs: Vec<Option<&AdapterSet>> =
            active.iter().map(|&s| adapters[s]).collect();
        let sp = crate::obs::span("infer", "decode");
        let logits =
            rt.decode_adapted(params, &ovs, &mut cache, &active, &toks)?;
        let secs = sp.done();
        decode_steps += 1;
        if crate::obs::enabled() {
            crate::obs::hist_record("decode.token_us",
                                    1e6 * secs / active.len() as f64);
            let used: usize = (0..b).map(|s| cache.len(s)).sum();
            crate::obs::event("kv", vec![
                ("used", Json::num(used as f64)),
                ("capacity", Json::num((b * cache.capacity) as f64)),
                ("bytes", Json::num(cache.bytes() as f64)),
                ("blocks_live",
                 Json::num(cache.blocks_live() as f64)),
                ("blocks_free",
                 Json::num(cache.blocks_free() as f64)),
                ("active", Json::num(active.len() as f64)),
                ("dtype", Json::str(cache.dtype().name())),
            ]);
        }
        let mut still = Vec::with_capacity(active.len());
        for (i, &s) in active.iter().enumerate() {
            let row = &logits[i * v..(i + 1) * v];
            let tok = cfg.sampler.sample(row, &mut rngs[s]) as i32;
            sequences[s].push(tok);
            on_token(s, tok);
            last[s] = tok;
            if !cfg.stop_tokens.contains(&tok) {
                still.push(s);
            }
        }
        active = still;
    }
    let n_generated = sequences
        .iter()
        .zip(prompts)
        .map(|(s, p)| s.len() - p.len())
        .collect();
    Ok(Generation { sequences, n_generated, prefill_tokens, decode_steps })
}
