//! Inference subsystem: KV-cached autoregressive generation.
//!
//! Training produced checkpoints nobody could *run*; this module is the
//! serving half of the system:
//!
//! * [`kv_cache`] — per-layer K/V cache making per-token decode cost
//!   O(context) instead of the O(context²) full re-forward.
//! * [`merge`] — fold `W + s·B·A` adapters into dense weights (LoRA's
//!   zero-added-latency deployment claim), with an exact unmerge.
//! * [`adapters`] — the multi-tenant dual of `merge`: detached per-task
//!   `(A, B)` overlays applied unmerged over ONE shared frozen base.
//! * [`sampler`] — greedy / temperature / top-k / top-p sampling,
//!   seeded.
//! * [`generate`] — the batched generation loop with ragged prompts and
//!   per-sequence stop handling; `generate_adapted` takes a per-sequence
//!   adapter overlay (the serving scheduler's entry point).
//!
//! The model side lives behind `runtime::InferRuntime` (implemented by
//! the native backend); entry points are the `generate` CLI subcommand,
//! `examples/generate.rs` and `benches/bench_infer.rs`.

pub mod adapters;
pub mod generate;
pub mod kv_cache;
pub mod merge;
pub mod sampler;

pub use adapters::{seeded_adapter, AdapterSet, LowRank};
pub use generate::{generate, generate_adapted, generate_stream,
                   GenConfig, Generation};
pub use kv_cache::{KvCache, PrefixStats};
pub use merge::{adapter_delta, merge_adapters, merged_full_store,
                unmerge_adapters, MergeState};
pub use sampler::{argmax, Sampler};
