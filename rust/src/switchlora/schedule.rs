//! Switching-frequency schedule (paper Section 2.2 "Switching frequency" +
//! Algorithm 2's `switch_num`).
//!
//! The expected number of switched vectors per matrix per step is
//! `s(step) = r / (interval₀ · e^(θ·step))`; the integer count is
//! `⌊s⌋ + Bernoulli(s − ⌊s⌋)`.  θ is set so the frequency falls to 1/3 of
//! its initial value at `ratio × total_steps` (Section 4.1: ratio = 1/10).

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SwitchSchedule {
    /// initial switching interval (steps between switches per vector)
    pub interval0: f64,
    /// exponential decay rate of the frequency
    pub theta: f64,
}

impl SwitchSchedule {
    pub fn new(interval0: f64, theta: f64) -> SwitchSchedule {
        assert!(interval0 > 0.0);
        SwitchSchedule { interval0, theta }
    }

    /// Paper parameterization: frequency drops to 1/3 of initial at
    /// `ratio * total_steps`.
    pub fn with_third_at(interval0: f64, ratio: f64, total_steps: u64)
        -> SwitchSchedule {
        let at = (ratio * total_steps as f64).max(1.0);
        SwitchSchedule::new(interval0, 3f64.ln() / at)
    }

    /// Expected switches per matrix at `step` for LoRA rank `r`.
    pub fn expected(&self, step: u64, r: usize) -> f64 {
        r as f64 / (self.interval0 * (self.theta * step as f64).exp())
    }

    /// Integer draw: ⌊s⌋ + Bernoulli(frac(s)), clamped to r.
    ///
    /// The clamp is a hard invariant: the driver feeds this straight into
    /// `Rng::sample_distinct(r, n)`, which panics for n > r.  Saturating
    /// schedules (tiny intervals, growing frequency) can push the
    /// expected count past r or to non-finite values — both short-circuit
    /// to r before any integer conversion.
    pub fn switch_count(&self, step: u64, r: usize, rng: &mut Rng) -> usize {
        let s = self.expected(step, r);
        if !s.is_finite() || s >= r as f64 {
            return r;
        }
        let base = s.floor();
        let frac = s - base;
        (base as usize + usize::from(rng.bernoulli(frac))).min(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_decays_exponentially() {
        let s = SwitchSchedule::with_third_at(40.0, 0.1, 40_000);
        let e0 = s.expected(0, 512);
        let e4k = s.expected(4_000, 512);
        let e8k = s.expected(8_000, 512);
        assert!((e0 - 512.0 / 40.0).abs() < 1e-9);
        assert!((e4k / e0 - 1.0 / 3.0).abs() < 1e-6, "{}", e4k / e0);
        assert!((e8k / e0 - 1.0 / 9.0).abs() < 1e-6);
    }

    #[test]
    fn paper_example_13_vectors() {
        // Appendix D: 1.3B, r=512, interval 40 → ≈13 switches per step.
        let s = SwitchSchedule::new(40.0, 0.0);
        assert_eq!(s.expected(0, 512).floor() as usize, 12); // 512/40 = 12.8
        let mut rng = Rng::new(0);
        let mean: f64 = (0..2000)
            .map(|_| s.switch_count(0, 512, &mut rng) as f64)
            .sum::<f64>() / 2000.0;
        assert!((mean - 12.8).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn count_bounded_by_rank() {
        let s = SwitchSchedule::new(0.01, 0.0); // absurdly frequent
        let mut rng = Rng::new(1);
        for step in 0..10 {
            assert!(s.switch_count(step, 8, &mut rng) <= 8);
        }
    }

    #[test]
    fn bernoulli_fraction_statistics() {
        // expected 0.5 → mean count ≈ 0.5
        let s = SwitchSchedule::new(2.0, 0.0);
        let mut rng = Rng::new(2);
        let mean: f64 = (0..4000)
            .map(|_| s.switch_count(0, 1, &mut rng) as f64)
            .sum::<f64>() / 4000.0;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }
}
