//! Candidate-vector store (paper Section 2.2).
//!
//! Each LoRA-adapted linear `W[m,n] + s·B[m,r]A[r,n]` keeps two ordered
//! pools: `C(B)` with `min(m,n)` column candidates for B, and `C(Aᵀ)` with
//! `min(m,n)` row candidates for A.  A switch **swaps** a LoRA vector with
//! a pool slot (Algorithm 1 line 2), so trained vectors return to the pool
//! and can be re-selected later — the total vector population is conserved.
//!
//! The pools live "offloaded" (plain host memory standing in for the
//! paper's CPU offload of spare candidates); a `OffloadLedger` counts bytes
//! moved per step in bf16-equivalents so Appendix D's offload-traffic
//! formula is *measured*, not just asserted.

use crate::model::init::switchlora_stds;
use crate::model::layout::LinearMeta;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Byte-traffic accounting for candidate offload (bf16 = 2 bytes/elem,
/// matching the paper's accounting in Appendix D / Table 5).
#[derive(Clone, Copy, Debug, Default)]
pub struct OffloadLedger {
    pub bytes_to_gpu: u64,
    pub bytes_to_cpu: u64,
    pub swaps: u64,
}

impl OffloadLedger {
    pub fn record_swap(&mut self, elems: usize) {
        // one vector fetched from the pool, one written back
        self.bytes_to_gpu += 2 * elems as u64;
        self.bytes_to_cpu += 2 * elems as u64;
        self.swaps += 1;
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_to_gpu + self.bytes_to_cpu
    }
}

/// Candidate pools for one linear layer.
pub struct LinearCandidates {
    /// pool for B columns: [m, c] column-major-by-use (Tensor row-major,
    /// we use columns), c = min(m,n)
    pub cb: Tensor,
    /// pool for A rows, stored as rows of an [c, n] tensor
    pub ca: Tensor,
    /// sequential selection cursors (paper Appendix D: sequential selection
    /// enables batched contiguous copies)
    pub next_b: usize,
    pub next_a: usize,
    pub m: usize,
    pub n: usize,
}

impl LinearCandidates {
    /// Initialize pools with the Eq. (3) distribution (same law as the live
    /// LoRA vectors — "the values of B and A ... along with their candidate
    /// vectors").
    pub fn init(li: &LinearMeta, rank: usize, rng: &mut Rng)
        -> LinearCandidates {
        let c = li.m.min(li.n);
        let (std_b, std_a) = switchlora_stds(li.m, li.n, rank, 1.0);
        let lim_b = (std_b * 3f64.sqrt()) as f32;
        let lim_a = (std_a * 3f64.sqrt()) as f32;
        let cb = Tensor::rand_uniform(li.m, c, lim_b, rng);
        let ca = Tensor::rand_uniform(c, li.n, lim_a, rng);
        LinearCandidates {
            cb,
            ca,
            // Cursors start at `rank`: conceptually slots 0..rank mirror the
            // live LoRA vectors, so the first switches bring in fresh ones.
            next_b: rank.min(c),
            next_a: rank.min(c),
            m: li.m,
            n: li.n,
        }
    }

    pub fn pool_size(&self) -> usize {
        self.cb.cols
    }

    /// Sequentially pick the next pool slot for a B switch.
    pub fn pick_b(&mut self) -> usize {
        let j = self.next_b;
        self.next_b = (self.next_b + 1) % self.pool_size();
        j
    }

    pub fn pick_a(&mut self) -> usize {
        let j = self.next_a;
        self.next_a = (self.next_a + 1) % self.pool_size();
        j
    }

    /// Swap pool slot `j` of C(B) with the provided column buffer (the live
    /// `B[:,i]`), recording offload traffic.
    pub fn swap_b(&mut self, j: usize, live_col: &mut [f32],
                  ledger: &mut OffloadLedger) {
        assert_eq!(live_col.len(), self.m);
        for (i, x) in live_col.iter_mut().enumerate() {
            std::mem::swap(x, self.cb.at_mut(i, j));
        }
        ledger.record_swap(self.m);
    }

    /// Swap pool slot `j` of C(Aᵀ) with the live `A[i,:]` row buffer.
    pub fn swap_a(&mut self, j: usize, live_row: &mut [f32],
                  ledger: &mut OffloadLedger) {
        assert_eq!(live_row.len(), self.n);
        let row = self.ca.row_mut(j);
        for (x, y) in live_row.iter_mut().zip(row.iter_mut()) {
            std::mem::swap(x, y);
        }
        ledger.record_swap(self.n);
    }

    /// Bytes this pool occupies in (simulated) CPU memory, bf16 accounting.
    pub fn resident_bytes(&self) -> u64 {
        2 * (self.cb.numel() + self.ca.numel()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn li() -> LinearMeta {
        LinearMeta { name: "w".into(), a: "w.a".into(), b: "w.b".into(),
                     m: 12, n: 8 }
    }

    #[test]
    fn pool_dimensions() {
        let mut rng = Rng::new(0);
        let c = LinearCandidates::init(&li(), 4, &mut rng);
        assert_eq!(c.pool_size(), 8); // min(12, 8)
        assert_eq!((c.cb.rows, c.cb.cols), (12, 8));
        assert_eq!((c.ca.rows, c.ca.cols), (8, 8));
        assert_eq!(c.resident_bytes(), 2 * (12 * 8 + 8 * 8) as u64);
    }

    #[test]
    fn sequential_cursor_wraps() {
        let mut rng = Rng::new(1);
        let mut c = LinearCandidates::init(&li(), 4, &mut rng);
        let picks: Vec<usize> = (0..10).map(|_| c.pick_b()).collect();
        assert_eq!(picks, vec![4, 5, 6, 7, 0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn swap_b_exchanges_and_ledgers() {
        let mut rng = Rng::new(2);
        let mut c = LinearCandidates::init(&li(), 4, &mut rng);
        let pool_before = c.cb.col(5);
        let mut live: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let live_before = live.clone();
        let mut ledger = OffloadLedger::default();
        c.swap_b(5, &mut live, &mut ledger);
        assert_eq!(live, pool_before);
        assert_eq!(c.cb.col(5), live_before);
        assert_eq!(ledger.swaps, 1);
        assert_eq!(ledger.total_bytes(), 2 * (2 * 12));
        // double swap restores
        c.swap_b(5, &mut live, &mut ledger);
        assert_eq!(live, live_before);
    }

    #[test]
    fn swap_a_roundtrip() {
        let mut rng = Rng::new(3);
        let mut c = LinearCandidates::init(&li(), 4, &mut rng);
        let mut live = vec![7.0f32; 8];
        let pool_before = c.ca.row(2).to_vec();
        let mut ledger = OffloadLedger::default();
        c.swap_a(2, &mut live, &mut ledger);
        assert_eq!(live, pool_before);
        assert_eq!(c.ca.row(2), &[7.0f32; 8][..]);
    }

    #[test]
    fn candidate_distribution_matches_eq3() {
        let mut rng = Rng::new(4);
        let lim = LinearMeta { name: "w".into(), a: "a".into(),
                               b: "b".into(), m: 128, n: 64 };
        let c = LinearCandidates::init(&lim, 16, &mut rng);
        let (std_b, std_a) = switchlora_stds(128, 64, 16, 1.0);
        let emp = |d: &[f32]| {
            let mean: f64 =
                d.iter().map(|&x| x as f64).sum::<f64>() / d.len() as f64;
            (d.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
                / d.len() as f64).sqrt()
        };
        assert!((emp(&c.cb.data) - std_b).abs() / std_b < 0.1);
        assert!((emp(&c.ca.data) - std_a).abs() / std_a < 0.1);
    }
}
