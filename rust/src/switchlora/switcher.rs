//! The switch operation (Algorithm 1) and per-step driver (Algorithm 2).
//!
//! Switching a LoRA vector must leave the function computed by the layer
//! unchanged: for the forward `y = (W + s·BA)x`,
//!
//! ```text
//! W ← W + s·b_i a_iᵀ          (merge the outgoing pair)
//! b_i ↔ C(B)[j]               (swap with the candidate pool)
//! opt_state(a_i) ← 0          (reset the *counterpart*'s Adam state)
//! W ← W − s·b_i a_iᵀ          (unmerge with the incoming vector)
//! freeze a_i for N steps
//! ```
//!
//! (and symmetrically for switching `a_i`, resetting/freezing `b_i`).  The
//! two rank-1 updates are fused into one pass with `Δ = b_old − b_new`.
//! Appendix A explains why the *counterpart* state is reset: the gradient
//! of `b_i` is `(a_iᵀx)∇_y L` — it depends on `a_i`, not on `b_i` itself,
//! so the switched-in vector's own moments stay valid while the
//! counterpart's become stale.

use crate::model::layout::{LinearMeta, ParamStore};
use crate::optim::adam::{AdamState, Span};
use crate::util::rng::Rng;

use super::candidates::{LinearCandidates, OffloadLedger};
use super::freeze::FreezeManager;
use super::schedule::SwitchSchedule;

/// Flat-span addressing for a LoRA pair within the packed trainable vector.
pub struct LoraSpans {
    /// A is [r, n]: row i is contiguous
    pub a_t_offset: usize,
    /// B is [m, r]: column i is strided by r
    pub b_t_offset: usize,
    pub m: usize,
    pub n: usize,
    pub r: usize,
}

impl LoraSpans {
    pub fn from_layout(store: &ParamStore, li: &LinearMeta, r: usize)
        -> LoraSpans {
        let a = store.layout.meta(&li.a).expect("lora A in layout");
        let b = store.layout.meta(&li.b).expect("lora B in layout");
        LoraSpans {
            a_t_offset: a.t_offset.expect("A trainable"),
            b_t_offset: b.t_offset.expect("B trainable"),
            m: li.m,
            n: li.n,
            r,
        }
    }

    pub fn a_row(&self, i: usize) -> Span {
        Span::contiguous(self.a_t_offset + i * self.n, self.n)
    }

    pub fn b_col(&self, i: usize) -> Span {
        Span { offset: self.b_t_offset + i, stride: self.r, count: self.m }
    }
}

/// All SwitchLoRA runtime state for one model.
pub struct SwitchLora {
    pub cands: Vec<LinearCandidates>,
    pub sched: SwitchSchedule,
    pub freeze: FreezeManager,
    pub ledger: OffloadLedger,
    pub n_freeze: u64,
    pub rank: usize,
    pub scale: f32,
    pub total_switches: u64,
    rng: Rng,
}

impl SwitchLora {
    pub fn new(linears: &[LinearMeta], rank: usize, scale: f32,
               sched: SwitchSchedule, n_freeze: u64, seed: u64)
        -> SwitchLora {
        let mut rng = Rng::new(seed ^ 0x5317C); // switch-stream RNG
        let cands = linears
            .iter()
            .map(|li| LinearCandidates::init(li, rank, &mut rng))
            .collect();
        SwitchLora {
            cands,
            sched,
            freeze: FreezeManager::new(),
            ledger: OffloadLedger::default(),
            n_freeze,
            rank,
            scale,
            total_switches: 0,
            rng,
        }
    }

    /// Resident candidate-pool bytes (the simulated CPU-offload footprint).
    pub fn resident_bytes(&self) -> u64 {
        self.cands.iter().map(|c| c.resident_bytes()).sum()
    }

    /// Serialize the dynamic state — switch RNG, freeze windows, candidate
    /// pools and cursors, counters — so a run resumes mid-schedule exactly
    /// (the static configuration is rebuilt from the training config).
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::util::bytes::*;
        put_u64(out, self.total_switches);
        put_u64(out, self.ledger.bytes_to_gpu);
        put_u64(out, self.ledger.bytes_to_cpu);
        put_u64(out, self.ledger.swaps);
        put_rng(out, &self.rng.state());
        let frz = self.freeze.snapshot();
        put_u64(out, frz.len() as u64);
        for (expire, span) in frz {
            put_u64(out, expire);
            put_u64(out, span.offset as u64);
            put_u64(out, span.stride as u64);
            put_u64(out, span.count as u64);
        }
        put_u64(out, self.cands.len() as u64);
        for c in &self.cands {
            put_u64(out, c.next_b as u64);
            put_u64(out, c.next_a as u64);
            put_f32s(out, &c.cb.data);
            put_f32s(out, &c.ca.data);
        }
    }

    /// Restore state written by [`Self::save_state`].  The receiver must
    /// have been freshly constructed with the same model configuration;
    /// mismatched pool shapes are rejected.
    pub fn load_state(&mut self, r: &mut crate::util::bytes::ByteReader)
        -> anyhow::Result<()> {
        use anyhow::ensure;
        self.total_switches = r.u64()?;
        self.ledger.bytes_to_gpu = r.u64()?;
        self.ledger.bytes_to_cpu = r.u64()?;
        self.ledger.swaps = r.u64()?;
        self.rng = Rng::from_state(r.rng()?);
        let n_frz = r.u64()? as usize;
        let mut frz = Vec::with_capacity(n_frz);
        for _ in 0..n_frz {
            let expire = r.u64()?;
            let span = Span {
                offset: r.u64()? as usize,
                stride: r.u64()? as usize,
                count: r.u64()? as usize,
            };
            frz.push((expire, span));
        }
        self.freeze.restore(frz);
        let n_cands = r.u64()? as usize;
        ensure!(n_cands == self.cands.len(),
                "switchlora state has {n_cands} candidate pools, model \
                 has {}", self.cands.len());
        for c in self.cands.iter_mut() {
            c.next_b = r.u64()? as usize;
            c.next_a = r.u64()? as usize;
            let cb = r.f32s()?;
            let ca = r.f32s()?;
            ensure!(cb.len() == c.cb.data.len()
                        && ca.len() == c.ca.data.len(),
                    "switchlora candidate pool shape mismatch \
                     ({}/{} vs {}/{})", cb.len(), ca.len(),
                    c.cb.data.len(), c.ca.data.len());
            c.cb.data.copy_from_slice(&cb);
            c.ca.data.copy_from_slice(&ca);
        }
        Ok(())
    }

    /// Algorithm 2 for one step (call *after* the optimizer update of
    /// `step`): for every linear, switch `switch_num` B-columns and
    /// `switch_num` A-rows against their pools.
    pub fn apply_step(&mut self, step: u64, store: &mut ParamStore,
                      opt: &mut AdamState, linears: &[LinearMeta]) {
        for (idx, li) in linears.iter().enumerate() {
            let spans = LoraSpans::from_layout(store, li, self.rank);
            // --- switch B columns ---
            let nb = self.sched.switch_count(step, self.rank, &mut self.rng);
            let is = self.rng.sample_distinct(self.rank, nb);
            for i in is {
                let j = self.cands[idx].pick_b();
                switch_b(store, opt, &mut self.freeze, &mut self.cands[idx],
                         &mut self.ledger, li, &spans, i, j, self.scale,
                         step + 1 + self.n_freeze);
                self.total_switches += 1;
                crate::obs::switch_event(step, &li.name, "b", i, j, li.m,
                                         self.cands[idx].pool_size(),
                                         self.cands[idx].next_b,
                                         step + 1 + self.n_freeze);
            }
            // --- switch A rows ---
            let na = self.sched.switch_count(step, self.rank, &mut self.rng);
            let is = self.rng.sample_distinct(self.rank, na);
            for i in is {
                let j = self.cands[idx].pick_a();
                switch_a(store, opt, &mut self.freeze, &mut self.cands[idx],
                         &mut self.ledger, li, &spans, i, j, self.scale,
                         step + 1 + self.n_freeze);
                self.total_switches += 1;
                crate::obs::switch_event(step, &li.name, "a", i, j, li.n,
                                         self.cands[idx].pool_size(),
                                         self.cands[idx].next_a,
                                         step + 1 + self.n_freeze);
            }
        }
    }
}

/// Rank-1 update `W += alpha * u vᵀ` directly on the store slice of W.
fn w_rank1(store: &mut ParamStore, li: &LinearMeta, alpha: f32, u: &[f32],
           v: &[f32]) {
    let w = store.slice_mut(&li.name).expect("W in layout");
    let n = v.len();
    for (i, &ui) in u.iter().enumerate() {
        let scaled = alpha * ui;
        if scaled == 0.0 {
            continue;
        }
        let row = &mut w[i * n..(i + 1) * n];
        for (rj, &vj) in row.iter_mut().zip(v) {
            *rj += scaled * vj;
        }
    }
}

fn read_b_col(store: &ParamStore, li: &LinearMeta, r: usize, i: usize)
    -> Vec<f32> {
    let b = store.slice(&li.b).expect("B in layout");
    (0..li.m).map(|row| b[row * r + i]).collect()
}

fn write_b_col(store: &mut ParamStore, li: &LinearMeta, r: usize, i: usize,
               col: &[f32]) {
    let b = store.slice_mut(&li.b).expect("B in layout");
    for (row, &x) in col.iter().enumerate() {
        b[row * r + i] = x;
    }
}

/// Algorithm 1 specialized to switching column `i` of B with pool slot `j`.
#[allow(clippy::too_many_arguments)]
pub fn switch_b(store: &mut ParamStore, opt: &mut AdamState,
                freeze: &mut FreezeManager, cands: &mut LinearCandidates,
                ledger: &mut OffloadLedger, li: &LinearMeta,
                spans: &LoraSpans, i: usize, j: usize, scale: f32,
                freeze_until: u64) {
    let r = spans.r;
    let b_old = read_b_col(store, li, r, i);
    let mut b_new = b_old.clone();
    cands.swap_b(j, &mut b_new, ledger); // pool[j] ← b_old, b_new ← pool[j]
    write_b_col(store, li, r, i, &b_new);
    // fused merge/unmerge: W += s·(b_old − b_new)·a_iᵀ
    let delta: Vec<f32> =
        b_old.iter().zip(&b_new).map(|(o, n)| o - n).collect();
    let a_row = {
        let a = store.slice(&li.a).expect("A in layout");
        a[i * spans.n..(i + 1) * spans.n].to_vec()
    };
    w_rank1(store, li, scale, &delta, &a_row);
    // reset the counterpart's optimizer state and freeze it
    let a_span = spans.a_row(i);
    opt.reset_span(a_span);
    freeze.freeze(a_span, freeze_until);
}

/// Algorithm 1 transposed: switching row `i` of A with pool slot `j`.
#[allow(clippy::too_many_arguments)]
pub fn switch_a(store: &mut ParamStore, opt: &mut AdamState,
                freeze: &mut FreezeManager, cands: &mut LinearCandidates,
                ledger: &mut OffloadLedger, li: &LinearMeta,
                spans: &LoraSpans, i: usize, j: usize, scale: f32,
                freeze_until: u64) {
    let a_old = {
        let a = store.slice(&li.a).expect("A in layout");
        a[i * spans.n..(i + 1) * spans.n].to_vec()
    };
    let mut a_new = a_old.clone();
    cands.swap_a(j, &mut a_new, ledger);
    {
        let a = store.slice_mut(&li.a).expect("A in layout");
        a[i * spans.n..(i + 1) * spans.n].copy_from_slice(&a_new);
    }
    let delta: Vec<f32> =
        a_old.iter().zip(&a_new).map(|(o, n)| o - n).collect();
    let b_col = read_b_col(store, li, spans.r, i);
    w_rank1(store, li, scale, &b_col, &delta);
    let b_span = spans.b_col(i);
    opt.reset_span(b_span);
    freeze.freeze(b_span, freeze_until);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta, Role};
    use crate::switchlora::schedule::SwitchSchedule;
    use crate::tensor::matmul::matmul;
    use crate::tensor::Tensor;
    use std::sync::Arc;

    const M: usize = 10;
    const N: usize = 6;
    const R: usize = 3;

    fn setup() -> (ParamStore, Vec<LinearMeta>, AdamState) {
        let layout = Layout::from_metas(vec![
            ParamMeta { name: "w".into(), shape: vec![M, N],
                        role: Role::Base, trainable: false, numel: M * N,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w.a".into(), shape: vec![R, N],
                        role: Role::LoraA, trainable: true, numel: R * N,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w.b".into(), shape: vec![M, R],
                        role: Role::LoraB, trainable: true, numel: M * R,
                        offset: 0, t_offset: None },
        ]);
        let mut store = ParamStore::zeros(Arc::new(layout));
        let mut rng = Rng::new(7);
        for x in store.data.iter_mut() {
            *x = rng.normal_f32(0.0, 1.0);
        }
        let linears = vec![LinearMeta {
            name: "w".into(), a: "w.a".into(), b: "w.b".into(), m: M, n: N,
        }];
        let opt = AdamState::new(R * N + M * R, R * N + M * R);
        (store, linears, opt)
    }

    /// effective weight s·(W + scale·B·A) as a Tensor
    fn effective(store: &ParamStore, scale: f32) -> Tensor {
        let w = store.tensor("w").unwrap();
        let a = store.tensor("w.a").unwrap();
        let b = store.tensor("w.b").unwrap();
        let mut ba = matmul(&b, &a);
        ba.scale(scale);
        let mut e = w.clone();
        e.axpy(1.0, &ba);
        e
    }

    #[test]
    fn switch_b_preserves_effective_weight() {
        let (mut store, linears, mut opt) = setup();
        let li = &linears[0];
        let spans = LoraSpans::from_layout(&store, li, R);
        let mut rng = Rng::new(1);
        let mut cands = LinearCandidates::init(li, R, &mut rng);
        let mut ledger = OffloadLedger::default();
        let mut freeze = FreezeManager::new();
        for scale in [1.0f32, 0.5] {
            let before = effective(&store, scale);
            let b_before = store.tensor("w.b").unwrap();
            switch_b(&mut store, &mut opt, &mut freeze, &mut cands,
                     &mut ledger, li, &spans, 1, 4, scale, 10);
            let after = effective(&store, scale);
            assert!(before.max_abs_diff(&after) < 1e-4,
                    "effective weight changed by {}",
                    before.max_abs_diff(&after));
            // B actually changed
            let b_after = store.tensor("w.b").unwrap();
            assert!(b_before.max_abs_diff(&b_after) > 1e-3);
        }
    }

    #[test]
    fn switch_a_preserves_effective_weight() {
        let (mut store, linears, mut opt) = setup();
        let li = &linears[0];
        let spans = LoraSpans::from_layout(&store, li, R);
        let mut rng = Rng::new(2);
        let mut cands = LinearCandidates::init(li, R, &mut rng);
        let mut ledger = OffloadLedger::default();
        let mut freeze = FreezeManager::new();
        let before = effective(&store, 1.0);
        switch_a(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
                 li, &spans, 0, 3, 1.0, 10);
        let after = effective(&store, 1.0);
        assert!(before.max_abs_diff(&after) < 1e-4);
    }

    #[test]
    fn switch_b_resets_counterpart_a_state_only() {
        let (mut store, linears, mut opt) = setup();
        let li = &linears[0];
        let spans = LoraSpans::from_layout(&store, li, R);
        for x in opt.m.iter_mut() {
            *x = 1.0;
        }
        for x in opt.s.iter_mut() {
            *x = 5.0;
        }
        let mut rng = Rng::new(3);
        let mut cands = LinearCandidates::init(li, R, &mut rng);
        let mut ledger = OffloadLedger::default();
        let mut freeze = FreezeManager::new();
        switch_b(&mut store, &mut opt, &mut freeze, &mut cands, &mut ledger,
                 li, &spans, 1, 0, 1.0, 10);
        // A row 1 zeroed; A rows 0,2 untouched; all of B untouched
        for i in spans.a_row(1).indices() {
            assert_eq!(opt.m[i], 0.0);
            assert_eq!(opt.s[i], 0.0);
        }
        for i in spans.a_row(0).indices().chain(spans.a_row(2).indices()) {
            assert_eq!(opt.m[i], 1.0);
        }
        for i in 0..R {
            for k in spans.b_col(i).indices() {
                assert_eq!(opt.m[k], 1.0, "B col {i} touched");
            }
        }
        // the counterpart is frozen
        let mut mask = vec![1.0f32; opt.len()];
        freeze.apply(5, &mut mask);
        for i in spans.a_row(1).indices() {
            assert_eq!(mask[i], 0.0);
        }
    }

    #[test]
    fn apply_step_runs_algorithm2() {
        let (mut store, linears, mut opt) = setup();
        // interval 1 → expect ~R switches per side per step
        let sched = SwitchSchedule::new(1.0, 0.0);
        let mut sl = SwitchLora::new(&linears, R, 1.0, sched, 5, 42);
        let before = effective(&store, 1.0);
        for step in 0..5 {
            sl.apply_step(step, &mut store, &mut opt, &linears);
        }
        let after = effective(&store, 1.0);
        assert!(before.max_abs_diff(&after) < 1e-3,
                "drift {}", before.max_abs_diff(&after));
        assert!(sl.total_switches >= 5 * 2, "{}", sl.total_switches);
        assert_eq!(sl.ledger.swaps, sl.total_switches);
        assert!(sl.resident_bytes() > 0);
    }

    #[test]
    fn state_roundtrip_continues_identically() {
        // save mid-run, load into a fresh same-config instance, and the
        // two must produce bitwise-identical switching from there on
        let (mut store, linears, mut opt) = setup();
        let sched = SwitchSchedule::new(2.0, 0.0);
        let mut sl = SwitchLora::new(&linears, R, 1.0, sched.clone(), 5, 7);
        for step in 0..4 {
            sl.apply_step(step, &mut store, &mut opt, &linears);
        }
        let mut blob = Vec::new();
        sl.save_state(&mut blob);
        let mut sl2 = SwitchLora::new(&linears, R, 1.0, sched, 5, 7);
        let mut r = crate::util::bytes::ByteReader::new(&blob);
        sl2.load_state(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(sl2.total_switches, sl.total_switches);
        let mut store2 = store.clone();
        let mut opt2 = opt.clone();
        for step in 4..10 {
            sl.apply_step(step, &mut store, &mut opt, &linears);
            sl2.apply_step(step, &mut store2, &mut opt2, &linears);
        }
        assert_eq!(store.data, store2.data);
        assert_eq!(opt.m, opt2.m);
        assert_eq!(sl.total_switches, sl2.total_switches);
    }

    #[test]
    fn switched_in_vectors_expand_span() {
        // After enough switches the set of distinct B columns observed
        // exceeds the rank — the full-rank-information mechanism.
        let (mut store, linears, mut opt) = setup();
        let sched = SwitchSchedule::new(1.0, 0.0);
        let mut sl = SwitchLora::new(&linears, R, 1.0, sched, 5, 43);
        let mut seen = std::collections::HashSet::new();
        let quantize = |col: &[f32]| -> Vec<i64> {
            col.iter().map(|&x| (x * 1e4) as i64).collect()
        };
        for step in 0..8 {
            let b = store.tensor("w.b").unwrap();
            for c in 0..R {
                seen.insert(quantize(&b.col(c)));
            }
            sl.apply_step(step, &mut store, &mut opt, &linears);
        }
        assert!(seen.len() > R, "only {} distinct columns", seen.len());
    }
}
