//! The paper's contribution: **SwitchLoRA** — frequent, smooth switching of
//! LoRA vectors against candidate pools, with counterpart optimizer-state
//! resets and temporary freezing (Algorithms 1 and 2), plus the ReLoRA
//! baseline resetter.

pub mod candidates;
pub mod freeze;
pub mod relora;
pub mod schedule;
pub mod switcher;
