//! Freeze manager: after a switch, the counterpart LoRA vector is frozen
//! for `N` steps (Algorithm 2 lines 8/13; paper sets N=5).  Freezing is
//! realized as zeros in the per-element mask consumed by the fused Adam
//! kernel — frozen elements neither update nor advance their step counts.

use crate::optim::adam::Span;

#[derive(Clone, Debug)]
struct Entry {
    expire_step: u64,
    span: Span,
}

#[derive(Clone, Debug, Default)]
pub struct FreezeManager {
    entries: Vec<Entry>,
}

impl FreezeManager {
    pub fn new() -> FreezeManager {
        FreezeManager { entries: Vec::new() }
    }

    /// Freeze `span` through step `until_step` (exclusive): the mask is 0
    /// for steps `< until_step`.
    pub fn freeze(&mut self, span: Span, until_step: u64) {
        self.entries.push(Entry { expire_step: until_step, span });
    }

    pub fn active_count(&self) -> usize {
        self.entries.len()
    }

    /// Snapshot the live freeze windows as `(expire_step, span)` pairs
    /// (checkpoint/resume).
    pub fn snapshot(&self) -> Vec<(u64, Span)> {
        self.entries
            .iter()
            .map(|e| (e.expire_step, e.span))
            .collect()
    }

    /// Replace the live windows with a snapshot from [`Self::snapshot`].
    pub fn restore(&mut self, entries: Vec<(u64, Span)>) {
        self.entries = entries
            .into_iter()
            .map(|(expire_step, span)| Entry { expire_step, span })
            .collect();
    }

    /// Write the freeze mask for `step`: `mask` must come in as the base
    /// mask (normally all ones over live elements, zeros over padding);
    /// active freezes zero their spans.  Expired entries are pruned.
    pub fn apply(&mut self, step: u64, mask: &mut [f32]) {
        self.entries.retain(|e| e.expire_step > step);
        for e in &self.entries {
            for i in e.span.indices() {
                mask[i] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn freezes_for_n_steps_then_expires() {
        let mut fm = FreezeManager::new();
        fm.freeze(Span::contiguous(2, 3), 5); // frozen for steps 0..4
        for step in 0..5 {
            let mut mask = vec![1.0f32; 8];
            fm.apply(step, &mut mask);
            assert_eq!(&mask[2..5], &[0.0, 0.0, 0.0], "step {step}");
            assert_eq!(mask[0], 1.0);
            assert_eq!(mask[5], 1.0);
        }
        let mut mask = vec![1.0f32; 8];
        fm.apply(5, &mut mask);
        assert!(mask.iter().all(|&x| x == 1.0));
        assert_eq!(fm.active_count(), 0);
    }

    #[test]
    fn overlapping_freezes_compose() {
        let mut fm = FreezeManager::new();
        fm.freeze(Span::contiguous(0, 2), 3);
        fm.freeze(Span { offset: 1, stride: 2, count: 2 }, 6);
        let mut mask = vec![1.0f32; 4];
        fm.apply(0, &mut mask);
        assert_eq!(mask, vec![0.0, 0.0, 1.0, 0.0]);
        let mut mask = vec![1.0f32; 4];
        fm.apply(4, &mut mask);
        assert_eq!(mask, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn strided_column_freeze() {
        let mut fm = FreezeManager::new();
        // column 1 of a 3x4 matrix at offset 0
        fm.freeze(Span { offset: 1, stride: 4, count: 3 }, 2);
        let mut mask = vec![1.0f32; 12];
        fm.apply(0, &mut mask);
        for i in 0..12 {
            assert_eq!(mask[i] == 0.0, i % 4 == 1, "index {i}");
        }
    }
}
