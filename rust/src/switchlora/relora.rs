//! ReLoRA baseline (Lialin et al. 2023), the Figure 4 comparison arm.
//!
//! Every `reset_interval` steps ReLoRA merges all adapters into the base
//! weights (`W ← W + s·BA`), re-initializes the adapters (A Kaiming, B=0),
//! zeroes **all** optimizer state of the adapters, and re-warms the lr.
//! The contrast with SwitchLoRA: resets are coarse (every vector at once,
//! thousands of steps apart) instead of smooth (a few vectors per step),
//! which is exactly the mechanism the paper's Figure 4 interrogates.

use crate::model::layout::{LinearMeta, ParamStore};
use crate::optim::adam::{AdamState, Span};
use crate::tensor::matmul::matmul;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ReLora {
    pub reset_interval: u64,
    /// lr re-warm length after each reset (ReLoRA's scheduler quirk)
    pub rewarm: u64,
    pub last_reset: u64,
    pub n_resets: u64,
}

impl ReLora {
    pub fn new(reset_interval: u64, rewarm: u64) -> ReLora {
        ReLora { reset_interval, rewarm, last_reset: 0, n_resets: 0 }
    }

    pub fn due(&self, step: u64) -> bool {
        step > 0 && step % self.reset_interval == 0
    }

    /// Merge-and-reset every adapter.  Returns number of linears reset.
    pub fn reset(&mut self, step: u64, store: &mut ParamStore,
                 opt: &mut AdamState, linears: &[LinearMeta], rank: usize,
                 scale: f32, rng: &mut Rng) -> usize {
        for li in linears {
            // W ← W + s·B·A
            let a = store.tensor(&li.a).expect("A");
            let b = store.tensor(&li.b).expect("B");
            let mut ba = matmul(&b, &a);
            ba.scale(scale);
            {
                let w = store.slice_mut(&li.name).expect("W");
                for (wi, di) in w.iter_mut().zip(&ba.data) {
                    *wi += di;
                }
            }
            // reinit adapters: A Kaiming-uniform, B = 0 (LoRA default)
            let lim = (6.0 / li.n as f64).sqrt() as f32;
            {
                let a = store.slice_mut(&li.a).expect("A");
                for x in a.iter_mut() {
                    *x = rng.uniform_range(-lim, lim);
                }
            }
            store.slice_mut(&li.b).expect("B").fill(0.0);
            // zero ALL adapter optimizer state
            let am = store.layout.meta(&li.a).unwrap();
            let bm = store.layout.meta(&li.b).unwrap();
            opt.reset_span(Span::contiguous(am.t_offset.unwrap(), am.numel));
            opt.reset_span(Span::contiguous(bm.t_offset.unwrap(), bm.numel));
        }
        let _ = rank;
        self.last_reset = step;
        self.n_resets += 1;
        linears.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta, Role};
    use crate::tensor::Tensor;
    use std::sync::Arc;

    const M: usize = 8;
    const N: usize = 6;
    const R: usize = 2;

    fn setup() -> (ParamStore, Vec<LinearMeta>, AdamState) {
        let layout = Layout::from_metas(vec![
            ParamMeta { name: "w".into(), shape: vec![M, N],
                        role: Role::Base, trainable: false, numel: M * N,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w.a".into(), shape: vec![R, N],
                        role: Role::LoraA, trainable: true, numel: R * N,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w.b".into(), shape: vec![M, R],
                        role: Role::LoraB, trainable: true, numel: M * R,
                        offset: 0, t_offset: None },
        ]);
        let mut store = ParamStore::zeros(Arc::new(layout));
        let mut rng = Rng::new(11);
        for x in store.data.iter_mut() {
            *x = rng.normal_f32(0.0, 0.5);
        }
        let linears = vec![LinearMeta {
            name: "w".into(), a: "w.a".into(), b: "w.b".into(), m: M, n: N,
        }];
        let opt = AdamState::new(R * N + M * R, R * N + M * R);
        (store, linears, opt)
    }

    fn effective(store: &ParamStore, scale: f32) -> Tensor {
        let w = store.tensor("w").unwrap();
        let mut ba = matmul(&store.tensor("w.b").unwrap(),
                            &store.tensor("w.a").unwrap());
        ba.scale(scale);
        let mut e = w;
        e.axpy(1.0, &ba);
        e
    }

    #[test]
    fn reset_preserves_effective_weight() {
        let (mut store, linears, mut opt) = setup();
        let before = effective(&store, 0.5);
        let mut rng = Rng::new(1);
        let mut rl = ReLora::new(100, 10);
        let n = rl.reset(100, &mut store, &mut opt, &linears, R, 0.5,
                         &mut rng);
        assert_eq!(n, 1);
        let after = effective(&store, 0.5);
        assert!(before.max_abs_diff(&after) < 1e-4,
                "drift {}", before.max_abs_diff(&after));
    }

    #[test]
    fn reset_zeroes_b_and_opt_state() {
        let (mut store, linears, mut opt) = setup();
        for x in opt.m.iter_mut() {
            *x = 2.0;
        }
        let mut rng = Rng::new(2);
        let mut rl = ReLora::new(100, 10);
        rl.reset(100, &mut store, &mut opt, &linears, R, 1.0, &mut rng);
        assert!(store.slice("w.b").unwrap().iter().all(|&x| x == 0.0));
        assert!(opt.m.iter().all(|&x| x == 0.0));
        assert!(opt.s.iter().all(|&x| x == 0.0));
        assert_eq!(rl.n_resets, 1);
    }

    #[test]
    fn due_schedule() {
        let rl = ReLora::new(500, 10);
        assert!(!rl.due(0));
        assert!(!rl.due(499));
        assert!(rl.due(500));
        assert!(rl.due(1000));
    }
}
