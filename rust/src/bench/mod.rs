//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` binaries built on this:
//! warm-up, repeated timed runs, mean/p50/p95 + throughput reporting.
//!
//! Pass `--json <path>` to a bench binary to also write a
//! machine-readable report (schema `switchlora-bench-v2`): every
//! [`BenchResult`] the run produced plus whatever extra tables the
//! binary attaches (e.g. the precision memory/comm tables).  By
//! convention a binary attaches a flat `tracked` table of headline
//! metrics — keys ending `_gflops` / `_tok_s` are higher-is-better,
//! `_ms` / `_ms_per_tok` lower-is-better — which is what
//! `tools/bench_check.py` gates CI on.  The committed
//! `BENCH_kernels.json` / `BENCH_infer.json` at the repo root hold the
//! current point of the perf trajectory; the report also records a
//! `host` fingerprint so the checker can tell a regression from a
//! hardware change.

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// When enabled (`record_results`), every `bench`/`bench_budget` call
/// also appends its result here for the `--json` report.
static SINK: Mutex<Option<Vec<BenchResult>>> = Mutex::new(None);

/// Start recording every bench result for a later [`write_json`].
pub fn record_results() {
    *SINK.lock().unwrap_or_else(|e| e.into_inner()) = Some(Vec::new());
}

fn record(r: &BenchResult) {
    if let Some(v) =
        SINK.lock().unwrap_or_else(|e| e.into_inner()).as_mut()
    {
        v.push(r.clone());
    }
}

/// Write the recorded results plus `tables` as a JSON report.
pub fn write_json(path: &Path, bench: &str, tables: Vec<(&str, Json)>)
    -> anyhow::Result<()> {
    let results = SINK
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .take()
        .unwrap_or_default();
    let mut pairs = vec![
        ("schema", Json::str("switchlora-bench-v2")),
        ("bench", Json::str(bench)),
        ("host", Json::str(&host_fingerprint())),
        ("threads", Json::num(crate::kernels::threads() as f64)),
        ("results",
         Json::Arr(results.iter().map(BenchResult::to_json).collect())),
    ];
    pairs.extend(tables);
    std::fs::write(path, Json::obj(pairs).to_string() + "\n")?;
    Ok(())
}

/// Coarse host fingerprint for the trajectory reports: timings are only
/// comparable when this matches, so `tools/bench_check.py` downgrades a
/// cross-host comparison to an advisory.
pub fn host_fingerprint() -> String {
    if let Ok(info) = std::fs::read_to_string("/proc/cpuinfo") {
        for line in info.lines() {
            if let Some(rest) = line.strip_prefix("model name") {
                if let Some((_, v)) = rest.split_once(':') {
                    return v.trim().to_string();
                }
            }
        }
    }
    format!("{}-{}", std::env::consts::ARCH, std::env::consts::OS)
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    /// JSON row for the `--json` report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("min_ms", Json::num(self.min_ms)),
        ])
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>7} it  mean {:>9.3} ms  p50 {:>9.3} ms  \
             p95 {:>9.3} ms  min {:>9.3} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms,
            self.min_ms
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F)
    -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[pct_index(p, samples.len())];
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        min_ms: samples[0],
    };
    record(&result);
    result
}

/// Nearest-rank index of percentile `p` (in `0.0..=1.0`) into a sorted
/// sample slice of length `n`.  Rounds to the nearest rank rather than
/// truncating: with 8 samples, p95 is the last sample (index 7) — the
/// old `as usize` cast landed on index 6 and under-reported tail
/// latency for every small-`n` run.
pub fn pct_index(p: f64, n: usize) -> usize {
    debug_assert!(n > 0, "percentile of an empty sample set");
    ((p * (n - 1) as f64).round() as usize).min(n - 1)
}

/// Adaptive variant: time-boxed to roughly `budget_ms` of measurement.
///
/// The probe run that sizes the iteration count is also the warmup —
/// its (cold) timing is discarded, and the measured loop starts hot.
/// An extra warmup iteration here would silently shrink the budget.
pub fn bench_budget<F: FnMut()>(name: &str, budget_ms: f64, mut f: F)
    -> BenchResult {
    // one probe run decides the iteration count and warms the code
    let t = Instant::now();
    f();
    let probe = t.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / probe.max(1e-3)) as usize).clamp(3, 10_000);
    bench(name, 0, iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("sleep", 1, 8, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(r.iters, 8);
        assert!(r.mean_ms >= 1.0);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p95_ms);
        assert!(r.row().contains("sleep"));
    }

    #[test]
    fn budget_runs_at_least_three() {
        let mut count = 0;
        let r = bench_budget("counter", 5.0, || {
            count += 1;
        });
        assert!(r.iters >= 3);
        // probe + timed iterations, and nothing more: the probe is the
        // warmup, so exactly one extra call beyond `iters`
        assert_eq!(count, r.iters + 1);
    }

    #[test]
    fn percentile_index_uses_nearest_rank() {
        // the old truncating cast mapped (0.95, 8) to 6; nearest-rank
        // lands on the max sample
        assert_eq!(pct_index(0.95, 8), 7);
        assert_eq!(pct_index(0.50, 8), 4); // half rounds away from zero
        assert_eq!(pct_index(0.50, 9), 4); // exact median when odd
        assert_eq!(pct_index(0.0, 5), 0);
        assert_eq!(pct_index(1.0, 5), 4);
        assert_eq!(pct_index(1.0, 1), 0);
        // never out of bounds even at the top rank of a large n
        assert_eq!(pct_index(1.0, 10_000), 9_999);
    }
}
