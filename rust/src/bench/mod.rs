//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! `cargo bench` targets use `harness = false` binaries built on this:
//! warm-up, repeated timed runs, mean/p50/p95 + throughput reporting.
//!
//! Pass `--json <path>` to a bench binary to also write a
//! machine-readable report (schema `switchlora-bench-v1`): every
//! [`BenchResult`] the run produced plus whatever extra tables the
//! binary attaches (e.g. the precision memory/comm tables).  The
//! committed `BENCH_kernels.json` / `BENCH_infer.json` at the repo root
//! accumulate the perf trajectory across PRs.

use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// When enabled (`record_results`), every `bench`/`bench_budget` call
/// also appends its result here for the `--json` report.
static SINK: Mutex<Option<Vec<BenchResult>>> = Mutex::new(None);

/// Start recording every bench result for a later [`write_json`].
pub fn record_results() {
    *SINK.lock().unwrap_or_else(|e| e.into_inner()) = Some(Vec::new());
}

fn record(r: &BenchResult) {
    if let Some(v) =
        SINK.lock().unwrap_or_else(|e| e.into_inner()).as_mut()
    {
        v.push(r.clone());
    }
}

/// Write the recorded results plus `tables` as a JSON report.
pub fn write_json(path: &Path, bench: &str, tables: Vec<(&str, Json)>)
    -> anyhow::Result<()> {
    let results = SINK
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .take()
        .unwrap_or_default();
    let mut pairs = vec![
        ("schema", Json::str("switchlora-bench-v1")),
        ("bench", Json::str(bench)),
        ("threads", Json::num(crate::kernels::threads() as f64)),
        ("results",
         Json::Arr(results.iter().map(BenchResult::to_json).collect())),
    ];
    pairs.extend(tables);
    std::fs::write(path, Json::obj(pairs).to_string() + "\n")?;
    Ok(())
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchResult {
    /// JSON row for the `--json` report.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
            ("min_ms", Json::num(self.min_ms)),
        ])
    }

    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>7} it  mean {:>9.3} ms  p50 {:>9.3} ms  \
             p95 {:>9.3} ms  min {:>9.3} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms,
            self.min_ms
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F)
    -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |p: f64| samples[(p * (samples.len() - 1) as f64) as usize];
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        p50_ms: pct(0.50),
        p95_ms: pct(0.95),
        min_ms: samples[0],
    };
    record(&result);
    result
}

/// Adaptive variant: time-boxed to roughly `budget_ms` of measurement.
pub fn bench_budget<F: FnMut()>(name: &str, budget_ms: f64, mut f: F)
    -> BenchResult {
    // one probe run decides the iteration count
    let t = Instant::now();
    f();
    let probe = t.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / probe.max(1e-3)) as usize).clamp(3, 10_000);
    bench(name, 1.min(iters), iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("sleep", 1, 8, || {
            std::thread::sleep(std::time::Duration::from_millis(1));
        });
        assert_eq!(r.iters, 8);
        assert!(r.mean_ms >= 1.0);
        assert!(r.min_ms <= r.p50_ms && r.p50_ms <= r.p95_ms);
        assert!(r.row().contains("sleep"));
    }

    #[test]
    fn budget_runs_at_least_three() {
        let mut count = 0;
        let r = bench_budget("counter", 5.0, || {
            count += 1;
        });
        assert!(r.iters >= 3);
        assert!(count >= r.iters);
    }
}
