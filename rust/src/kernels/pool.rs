//! Persistent scoped thread pool for the kernel layer — std-only (no
//! rayon/crossbeam in the offline vendor set).
//!
//! One process-global pool backs every threaded kernel.  A job is a
//! borrowed `Fn(usize)` task closure plus a task count; worker threads
//! (spawned lazily, up to the configured thread count) claim task
//! indices from a shared atomic counter, so each index runs on exactly
//! one thread.  The posting call participates itself and does not return
//! until every claimed task has finished, which is what makes borrowing
//! stack data from the closure sound (see the SAFETY notes in [`run`]).
//!
//! Thread count resolution, in priority order:
//! 1. [`set_threads`] (the `--threads N` CLI flag calls this),
//! 2. the `SWITCHLORA_THREADS` environment variable,
//! 3. detected hardware parallelism
//!    ([`std::thread::available_parallelism`]).
//!
//! Determinism contract: the pool only *distributes* task indices; it
//! never splits or reorders the work inside a task.  Kernels built on it
//! give every output element a single owning task with the same
//! accumulation order as their serial loop, so results are bitwise
//! identical for any thread count — the property
//! `rust/tests/determinism_threads.rs` pins down.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

/// Hard cap on the pool size (sanity bound for `--threads`; oversplitting
/// past this many OS threads never helps the kernels here).
pub const MAX_THREADS: usize = 64;

/// Configured thread count; 0 = not yet resolved.
static CONFIG: AtomicUsize = AtomicUsize::new(0);

/// Ignore mutex poisoning: pool state stays consistent because every
/// transition happens under the lock before any panic can propagate.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Hardware parallelism as detected at run time (1 when unknown).
pub fn detected_parallelism() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Override the kernel thread count (clamped to `1..=MAX_THREADS`).
/// Takes effect for every subsequent kernel call; 1 forces all kernels
/// inline (the serial reference path).
pub fn set_threads(n: usize) {
    CONFIG.store(n.clamp(1, MAX_THREADS), Ordering::SeqCst);
}

/// The active kernel thread count, resolving `SWITCHLORA_THREADS` or the
/// detected parallelism on first use.  Like [`set_threads`], an env
/// value of `0` clamps to 1 (the serial reference path) rather than
/// silently meaning "all cores"; unparsable values fall back to the
/// detected parallelism.
pub fn threads() -> usize {
    let c = CONFIG.load(Ordering::SeqCst);
    if c != 0 {
        return c;
    }
    let n = std::env::var("SWITCHLORA_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .map(|n| n.max(1))
        .unwrap_or_else(detected_parallelism)
        .min(MAX_THREADS);
    // first-wins, so a concurrent explicit `set_threads` is not clobbered
    let _ = CONFIG.compare_exchange(0, n, Ordering::SeqCst,
                                    Ordering::SeqCst);
    CONFIG.load(Ordering::SeqCst)
}

thread_local! {
    /// Depth of serial scopes on this thread.  Pool workers and
    /// data-parallel shard threads run with this raised so nested kernel
    /// calls stay inline instead of re-entering (and deadlocking on) the
    /// single-job pool.
    static SERIAL_DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// Whether this thread is inside a serial scope (kernels stay inline).
pub fn in_serial() -> bool {
    SERIAL_DEPTH.with(|c| c.get() > 0)
}

struct SerialGuard;

impl Drop for SerialGuard {
    fn drop(&mut self) {
        SERIAL_DEPTH.with(|c| c.set(c.get() - 1));
    }
}

fn serial_guard() -> SerialGuard {
    SERIAL_DEPTH.with(|c| c.set(c.get() + 1));
    SerialGuard
}

/// Run `f` with every kernel call on this thread forced inline — the
/// per-shard mode of data-parallel worker threads (each shard owns one
/// OS thread; its kernels must not contend for the shared pool).
pub fn serial<R>(f: impl FnOnce() -> R) -> R {
    let _g = serial_guard();
    f()
}

/// One posted job: the lifetime-erased task closure plus its shared
/// index counter.  Copies of this exist only while the posting [`run`]
/// call is on the stack — `run` returns only after every participant has
/// checked out — so the erased references never outlive their frame.
#[derive(Clone, Copy)]
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    next: &'static AtomicUsize,
    n_tasks: usize,
}

struct PoolState {
    /// bumped per job; lets sleeping workers distinguish "new job" from
    /// spurious wakeups
    epoch: u64,
    job: Option<Job>,
    /// participants (caller + joined workers) still executing
    running: usize,
    /// participants that claimed the current job
    joined: usize,
    /// participant cap for the current job (= requested thread count)
    max_join: usize,
    /// worker threads spawned so far (they live for the process)
    spawned: usize,
    /// a worker's task closure panicked
    panicked: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

static POOL: OnceLock<Arc<Shared>> = OnceLock::new();

/// Serializes unit tests that toggle the process-global thread count
/// (cargo runs tests concurrently; results would still match — that is
/// the determinism contract — but tests asserting exact `threads()`
/// values would race).
#[cfg(test)]
pub(crate) static TEST_SERIALIZE: Mutex<()> = Mutex::new(());

/// Serializes job submission: the pool runs one job at a time.  Nested
/// submissions cannot deadlock because every participant executes tasks
/// inside a serial scope, which routes inner kernel calls inline.
static SUBMIT: Mutex<()> = Mutex::new(());

fn run_tasks(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n_tasks {
            break;
        }
        (job.f)(i);
    }
}

fn worker(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock(&shared.state);
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(j) = st.job {
                        if st.joined < st.max_join {
                            st.joined += 1;
                            st.running += 1;
                            break j;
                        }
                    }
                    // job already finished or fully staffed: sleep on
                }
                st = shared
                    .work_cv
                    .wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            }
        };
        let _g = serial_guard();
        let ok = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| run_tasks(&job)))
            .is_ok();
        let mut st = lock(&shared.state);
        if !ok {
            st.panicked = true;
        }
        st.running -= 1;
        if st.running == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Run `f(0) .. f(n_tasks - 1)` across the pool and wait for all of
/// them.  Every index is claimed by exactly one thread, so kernels that
/// give each task a disjoint output region with a fixed internal order
/// produce bitwise-identical results at any thread count.  Falls back to
/// an inline loop when the pool is configured for one thread, when
/// called inside a serial scope (pool workers, data-parallel shard
/// threads), or when there is at most one task.
pub fn run(n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    let want = threads();
    if n_tasks <= 1 || want <= 1 || in_serial() {
        crate::obs::pool_tally(n_tasks, false);
        let _g = serial_guard();
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    crate::obs::pool_tally(n_tasks, true);
    let shared = POOL.get_or_init(|| {
        Arc::new(Shared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                running: 0,
                joined: 0,
                max_join: 0,
                spawned: 0,
                panicked: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        })
    });
    let _submit = lock(&SUBMIT);
    let next = AtomicUsize::new(0);
    // SAFETY: pure lifetime erasure.  The erased references point into
    // this stack frame; `run` does not return (even on panic — see the
    // catch_unwind below) until `running` has dropped to zero, i.e. no
    // worker can still hold or reach them.
    let job = unsafe {
        Job {
            f: std::mem::transmute::<&(dyn Fn(usize) + Sync),
                                     &'static (dyn Fn(usize) + Sync)>(f),
            next: std::mem::transmute::<&AtomicUsize,
                                        &'static AtomicUsize>(&next),
            n_tasks,
        }
    };
    {
        let mut st = lock(&shared.state);
        while st.spawned < want - 1 {
            st.spawned += 1;
            let sh = Arc::clone(shared);
            std::thread::Builder::new()
                .name(format!("swl-kernel-{}", st.spawned))
                .spawn(move || worker(sh))
                .expect("spawning kernel pool worker");
        }
        st.epoch += 1;
        st.job = Some(job);
        st.joined = 1; // the caller participates
        st.running = 1;
        st.max_join = want;
        st.panicked = false;
        shared.work_cv.notify_all();
    }
    // participate; the serial scope keeps nested kernel calls inline
    let caller_res = {
        let _g = serial_guard();
        std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| run_tasks(&job)))
    };
    let mut st = lock(&shared.state);
    st.running -= 1;
    while st.running > 0 {
        st = shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    st.job = None;
    let worker_panicked = st.panicked;
    drop(st);
    if let Err(p) = caller_res {
        std::panic::resume_unwind(p);
    }
    if worker_panicked {
        panic!("kernel pool worker task panicked");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn every_task_runs_exactly_once() {
        let _t = lock(&TEST_SERIALIZE);
        let prev = threads();
        set_threads(4);
        for n in [0usize, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU32> =
                (0..n).map(|_| AtomicU32::new(0)).collect();
            run(n, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
            }
        }
        set_threads(prev);
    }

    #[test]
    fn serial_scope_forces_inline() {
        let _t = lock(&TEST_SERIALIZE);
        let prev = threads();
        set_threads(4);
        serial(|| {
            assert!(in_serial());
            let main_id = std::thread::current().id();
            run(32, &|_| {
                assert_eq!(std::thread::current().id(), main_id,
                           "serial scope must not fan out");
            });
        });
        assert!(!in_serial());
        set_threads(prev);
    }

    #[test]
    fn set_threads_clamps() {
        let _t = lock(&TEST_SERIALIZE);
        let prev = threads();
        set_threads(0);
        assert_eq!(threads(), 1);
        set_threads(MAX_THREADS + 100);
        assert_eq!(threads(), MAX_THREADS);
        set_threads(prev);
    }

    #[test]
    fn nested_run_inside_task_stays_inline() {
        let _t = lock(&TEST_SERIALIZE);
        let prev = threads();
        set_threads(4);
        let outer = AtomicU32::new(0);
        let inner = AtomicU32::new(0);
        run(8, &|_| {
            outer.fetch_add(1, Ordering::Relaxed);
            // a kernel calling a kernel: must inline, not deadlock
            run(4, &|_| {
                inner.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(outer.load(Ordering::Relaxed), 8);
        assert_eq!(inner.load(Ordering::Relaxed), 32);
        set_threads(prev);
    }
}
