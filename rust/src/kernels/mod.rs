//! Shared compute kernels: one cache-blocked, multi-threaded
//! matmul/attention substrate for the whole system.
//!
//! Before this layer existed the tree carried three divergent matmul
//! copies (`runtime/native.rs::addmm_*`, `tensor/matmul.rs`, and the
//! attention inner loops) plus a fourth attention loop in the KV cache.
//! Every consumer now calls through here: the native training fwd/bwd,
//! KV-cached prefill/decode, GaLore's projection math, the `Tensor`
//! wrappers, rank analysis and the Jacobi SVD's rotation sweeps — so one
//! optimization (or one thread pool) reaches all of them.
//!
//! **Determinism contract.**  Parallelism only ever partitions *output
//! rows* across tasks; each output element is computed by exactly one
//! task with the same inner accumulation order as the serial loop.  No
//! cross-thread reduction exists anywhere, so every kernel is bitwise
//! identical at any thread count — threaded training reproduces the
//! serial loss curves exactly, and the resume guarantees of the
//! checkpoint subsystem survive unchanged
//! (`rust/tests/determinism_threads.rs`).
//!
//! **Vectorization layout.**  Every hot inner loop is one of two
//! shapes: a lane-split [`dot`] (eight independent accumulators, fixed
//! pairwise reduction — removes the serial FP dependence chain that
//! blocks packed FMAs) or a unit-stride [`axpy`].  The packed kernels
//! hoist their dtype dispatch out of the k-loop entirely: a weight row
//! is dequantized once into a contiguous f32 panel and the panel goes
//! through the same [`dot`]/[`axpy`] the f32 kernels use, which keeps
//! `packed(buf) == f32(buf.to_f32())` bitwise by construction.  An
//! optional int8×int8→i32 path ([`set_int8_native`], `--int8-native`)
//! trades that bitwise equality for integer throughput with a bounded,
//! tested error.
//!
//! Thread control: `--threads N` / `SWITCHLORA_THREADS` / detected
//! parallelism — see [`pool`].  Kernels stay inline below a minimum task
//! size, so tiny shapes (single-token decode, 2×2 tests) never pay the
//! dispatch cost.

pub mod pool;

pub use pool::{detected_parallelism, in_serial, serial, set_threads,
               threads};

use std::sync::atomic::{AtomicU8, Ordering};

use crate::tensor::dtype::{bf16_to_f32, quantize_row_i8, MatRef};

/// Minimum useful task size in multiply-adds: below roughly this much
/// work per task, pool dispatch costs more than it saves, so kernels run
/// inline.  A threshold never affects results (see the determinism
/// contract above), only where the work runs.
const MIN_TASK_WORK: usize = 1 << 14;

/// Lane count of the split accumulators in [`dot`]/[`dot_i8`].  Eight
/// f32 lanes fill one AVX2 register (two NEON registers), which is what
/// lets LLVM emit packed FMAs; the final reduction is a fixed pairwise
/// tree, so the result is one well-defined value at any thread count
/// and on any target.
const DOT_LANES: usize = 8;

/// Inner product with [`DOT_LANES`] independent accumulators.  The
/// naive `acc += a·b` loop is a serial FP dependence chain the
/// vectorizer must not reassociate; splitting the sum into fixed lanes
/// (lane `l` owns elements `l, l+8, l+16, …`) removes the chain while
/// keeping one deterministic accumulation order — the tail past the
/// last full block is folded in after the pairwise tree.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len(), "dot length");
    let ac = a.chunks_exact(DOT_LANES);
    let bc = b.chunks_exact(DOT_LANES);
    let mut tail = 0.0f32;
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    let mut lanes = [0.0f32; DOT_LANES];
    for (av, bv) in ac.zip(bc) {
        for (l, acc) in lanes.iter_mut().enumerate() {
            *acc += av[l] * bv[l];
        }
    }
    let head = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
    head + tail
}

/// Integer inner product for the int8-native path: widen each code to
/// `i32` and accumulate in `i32` lanes.  Integer addition is exact, so
/// lane order is irrelevant here; the only requirement is
/// `k ≤ I8_NATIVE_MAX_K` so `k·127²` cannot overflow.
#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len(), "dot_i8 length");
    let ac = a.chunks_exact(DOT_LANES);
    let bc = b.chunks_exact(DOT_LANES);
    let mut tail = 0i32;
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += *x as i32 * *y as i32;
    }
    let mut lanes = [0i32; DOT_LANES];
    for (av, bv) in ac.zip(bc) {
        for (l, acc) in lanes.iter_mut().enumerate() {
            *acc += av[l] as i32 * bv[l] as i32;
        }
    }
    lanes.iter().sum::<i32>() + tail
}

/// Largest inner dimension the int8-native dot accepts: past this the
/// worst-case `k·127·127` magnitude could overflow `i32`, so
/// [`addmm_nt_packed`] falls back to the dequantizing reference path
/// (always correct, just slower).
pub const I8_NATIVE_MAX_K: usize = (i32::MAX / (127 * 127)) as usize;

/// `y += s·x` over contiguous slices — the unit-stride update shared by
/// the axpy-style kernels (`addmm_nn`/`addmm_tn`/`gram`/`matmul_nn`,
/// attention's weighted sums).  Elementwise, so it vectorizes without
/// any reassociation: bitwise identical to the scalar loop it replaces.
#[inline]
fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    for (yj, xj) in y.iter_mut().zip(x) {
        *yj += s * xj;
    }
}

/// Runtime switch for the int8×int8→i32 matmul path: 0 = unset (read
/// `SWITCHLORA_INT8_NATIVE` on first query), 1 = off, 2 = on.
static INT8_NATIVE: AtomicU8 = AtomicU8::new(0);

/// Enable/disable the int8-native matmul path (`--int8-native`).  Off
/// by default: the dequantize-on-load path stays the bitwise reference
/// (`packed == f32(w.to_f32())`), while the native path re-quantizes
/// each activation row and accumulates in i32, trading a bounded
/// rounding error (see [`addmm_nt_packed`]) for integer throughput.
pub fn set_int8_native(on: bool) {
    INT8_NATIVE.store(if on { 2 } else { 1 }, Ordering::SeqCst);
}

/// Whether the int8-native path is engaged — `--int8-native`, the
/// `SWITCHLORA_INT8_NATIVE` env var (`1`/`true`/`on`), or
/// [`set_int8_native`].
pub fn int8_native() -> bool {
    match INT8_NATIVE.load(Ordering::SeqCst) {
        0 => {
            let on = std::env::var("SWITCHLORA_INT8_NATIVE")
                .map(|v| {
                    v == "1"
                        || v.eq_ignore_ascii_case("true")
                        || v.eq_ignore_ascii_case("on")
                })
                .unwrap_or(false);
            set_int8_native(on);
            on
        }
        1 => false,
        _ => true,
    }
}

/// Raw mutable base pointer that may cross into pool tasks.  Each task
/// reborrows a *disjoint* row range, which is what makes the aliasing
/// sound; the `unsafe impl`s only assert that shipping the pointer to
/// another thread is fine (f32 buffers have no thread affinity).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);

unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Reborrow rows `lo..hi` of the row-major `[_, row_len]` buffer.
    /// The returned lifetime is unbounded by construction; every use
    /// here keeps it inside one pool task.
    ///
    /// SAFETY: the caller must hand every task a disjoint `lo..hi`
    /// range, and the buffer must outlive the pool job (guaranteed by
    /// `pool::run` returning only after all tasks finish).
    unsafe fn rows<'a>(self, lo: usize, hi: usize, row_len: usize)
        -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(lo * row_len),
                                       (hi - lo) * row_len)
    }
}

/// Partition `0..rows` into contiguous chunks sized from `work_per_row`
/// (multiply-adds) and run `f(lo, hi)` per chunk on the pool; small jobs
/// run as one inline `f(0, rows)` call.  Chunks oversplit ~4× past the
/// thread count so the pool's atomic index claiming load-balances ragged
/// work (e.g. causal attention rows).
fn par_rows(rows: usize, work_per_row: usize,
            f: impl Fn(usize, usize) + Sync) {
    if rows == 0 {
        return;
    }
    let nt = pool::threads();
    if nt <= 1
        || pool::in_serial()
        || rows.saturating_mul(work_per_row) < 2 * MIN_TASK_WORK
    {
        f(0, rows);
        return;
    }
    let min_rows = MIN_TASK_WORK.div_ceil(work_per_row.max(1)).max(1);
    let chunks = rows.div_ceil(min_rows).min(4 * nt).max(1);
    let per = rows.div_ceil(chunks);
    pool::run(chunks, &|c| {
        let lo = c * per;
        let hi = ((c + 1) * per).min(rows);
        if lo < hi {
            f(lo, hi);
        }
    });
}

// ---------------------------------------------------------------------
// Matmul family on row-major flat buffers.
// ---------------------------------------------------------------------

/// `y[rows,m] += x[rows,k] @ w[m,k]ᵀ` — the linear-layer orientation
/// (`W` stored `[out, in]`).  Parallel over rows of `y`.
pub fn addmm_nt(y: &mut [f32], x: &[f32], w: &[f32], rows: usize,
                k: usize, m: usize) {
    debug_assert_eq!(y.len(), rows * m, "addmm_nt y shape");
    debug_assert_eq!(x.len(), rows * k, "addmm_nt x shape");
    debug_assert_eq!(w.len(), m * k, "addmm_nt w shape");
    let yp = SendPtr(y.as_mut_ptr());
    par_rows(rows, k * m, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `y`
        let yc = unsafe { yp.rows(lo, hi, m) };
        for (i, yr) in yc.chunks_exact_mut(m).enumerate() {
            let xr = &x[(lo + i) * k..(lo + i + 1) * k];
            for (o, yo) in yr.iter_mut().enumerate() {
                *yo += dot(xr, &w[o * k..(o + 1) * k]);
            }
        }
    });
}

/// `y[rows,k] += x[rows,m] @ w[m,k]` (no transpose).  Parallel over rows
/// of `y`.
pub fn addmm_nn(y: &mut [f32], x: &[f32], w: &[f32], rows: usize,
                m: usize, k: usize) {
    debug_assert_eq!(y.len(), rows * k, "addmm_nn y shape");
    debug_assert_eq!(x.len(), rows * m, "addmm_nn x shape");
    debug_assert_eq!(w.len(), m * k, "addmm_nn w shape");
    let yp = SendPtr(y.as_mut_ptr());
    par_rows(rows, m * k, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `y`
        let yc = unsafe { yp.rows(lo, hi, k) };
        for (i, yr) in yc.chunks_exact_mut(k).enumerate() {
            let xr = &x[(lo + i) * m..(lo + i + 1) * m];
            for (o, &s) in xr.iter().enumerate() {
                if s == 0.0 {
                    continue;
                }
                axpy(yr, s, &w[o * k..(o + 1) * k]);
            }
        }
    });
}

/// `wg[m,k] += dy[rows,m]ᵀ @ x[rows,k]` — weight-gradient accumulation.
/// Parallel over rows of `wg` (the `m` outputs); each element still
/// accumulates over `i = 0..rows` in ascending order, exactly like the
/// serial loop.
pub fn addmm_tn(wg: &mut [f32], dy: &[f32], x: &[f32], rows: usize,
                m: usize, k: usize) {
    debug_assert_eq!(wg.len(), m * k, "addmm_tn wg shape");
    debug_assert_eq!(dy.len(), rows * m, "addmm_tn dy shape");
    debug_assert_eq!(x.len(), rows * k, "addmm_tn x shape");
    let wp = SendPtr(wg.as_mut_ptr());
    par_rows(m, rows * k, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `wg`
        let wc = unsafe { wp.rows(lo, hi, k) };
        for i in 0..rows {
            let dyr = &dy[i * m..(i + 1) * m];
            let xr = &x[i * k..(i + 1) * k];
            for o in lo..hi {
                let s = dyr[o];
                if s == 0.0 {
                    continue;
                }
                axpy(&mut wc[(o - lo) * k..(o - lo + 1) * k], s, xr);
            }
        }
    });
}

/// `c[m,n] += a[m,k] @ b[k,n]`, cache-blocked over `k` with an i-k-j
/// inner order (streams `b` rows, accumulates into `c` rows).  Parallel
/// over rows of `c`.
pub fn matmul_nn(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize,
                 n: usize) {
    debug_assert_eq!(c.len(), m * n, "matmul_nn c shape");
    debug_assert_eq!(a.len(), m * k, "matmul_nn a shape");
    debug_assert_eq!(b.len(), k * n, "matmul_nn b shape");
    const BK: usize = 64;
    let cp = SendPtr(c.as_mut_ptr());
    par_rows(m, k * n, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `c`
        let cc = unsafe { cp.rows(lo, hi, n) };
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for (i, c_row) in cc.chunks_exact_mut(n).enumerate() {
                let a_row = &a[(lo + i) * k..(lo + i + 1) * k];
                for kk in k0..k1 {
                    let aik = a_row[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    axpy(c_row, aik, &b[kk * n..(kk + 1) * n]);
                }
            }
        }
    });
}

/// `g[n,n] += a[rows,n]ᵀ @ a[rows,n]` (Gram matrix — the SVD substrate's
/// workhorse).  Parallel over rows of `g`; per-element accumulation over
/// the data rows stays in ascending order.
pub fn gram(g: &mut [f32], a: &[f32], rows: usize, n: usize) {
    debug_assert_eq!(g.len(), n * n, "gram g shape");
    debug_assert_eq!(a.len(), rows * n, "gram a shape");
    let gp = SendPtr(g.as_mut_ptr());
    par_rows(n, rows * n, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `g`
        let gc = unsafe { gp.rows(lo, hi, n) };
        for i in 0..rows {
            let row = &a[i * n..(i + 1) * n];
            for p in lo..hi {
                let rp = row[p];
                if rp == 0.0 {
                    continue;
                }
                axpy(&mut gc[(p - lo) * n..(p - lo + 1) * n], rp, row);
            }
        }
    });
}

/// Apply a two-column Jacobi/Givens rotation to columns `p`, `q` of the
/// row-major `a[rows, cols]` (the inner loop of the one-sided Jacobi
/// SVD).  Elementwise over rows, so bitwise thread-count independent.
pub fn rotate_columns(a: &mut [f32], rows: usize, cols: usize, p: usize,
                      q: usize, c: f64, s: f64) {
    debug_assert_eq!(a.len(), rows * cols, "rotate_columns shape");
    debug_assert!(p < cols && q < cols, "rotate_columns column index");
    let ap = SendPtr(a.as_mut_ptr());
    par_rows(rows, 8, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `a`
        let ac = unsafe { ap.rows(lo, hi, cols) };
        for r in ac.chunks_exact_mut(cols) {
            let xp = r[p] as f64;
            let xq = r[q] as f64;
            r[p] = (c * xp - s * xq) as f32;
            r[q] = (s * xp + c * xq) as f32;
        }
    });
}

// ---------------------------------------------------------------------
// Packed-RHS matmuls (the precision layer).
//
// Same row ownership and per-element accumulation order as the f32
// kernels above — the determinism contract holds unchanged — but the
// weight operand is a dtype-tagged [`MatRef`].  The dtype dispatch is
// hoisted all the way out of the hot loops: each weight row is
// dequantized once into a contiguous f32 panel ([`dequant_row`], a
// branch-free unit-stride loop) and the panel then goes through the
// same lane-split [`dot`]/[`axpy`] the f32 kernels use.  Dequant stays
// per-element in value, so for any packed buffer `b`:
// `packed_kernel(b) == f32_kernel(b.to_f32())` **bitwise**, and an
// `F32` view delegates straight to the f32 kernel (a strict no-op for
// the default all-f32 policy).  The optional int8-native path is the
// one deliberate exception — approximate, bounded, and off by default.
// ---------------------------------------------------------------------

/// Dequantize row `o` of a packed weight into the f32 `panel`
/// (`panel.len() == k`).  One dispatch per row; the per-element loop is
/// branch-free and unit-stride on both sides, producing exactly the
/// values `to_f32()` would for that row.
#[inline]
fn dequant_row(w: MatRef<'_>, o: usize, k: usize, panel: &mut [f32]) {
    match w {
        MatRef::F32(wf) => panel.copy_from_slice(&wf[o * k..(o + 1) * k]),
        MatRef::Bf16(wq) => {
            for (p, &b) in panel.iter_mut().zip(&wq[o * k..(o + 1) * k]) {
                *p = bf16_to_f32(b);
            }
        }
        MatRef::I8 { q, scales } => {
            let sc = scales[o];
            for (p, &b) in panel.iter_mut().zip(&q[o * k..(o + 1) * k]) {
                *p = sc * b as f32;
            }
        }
    }
}

/// `y[rows,m] += x[rows,k] @ w[m,k]ᵀ` with a packed weight operand (the
/// linear-layer orientation; `w` row `o` holds output channel `o`, so
/// int8 per-row scales are per output channel).  Parallel over rows of
/// `y`, f32 accumulation.
///
/// With [`int8_native`] engaged and an `I8` operand, takes the
/// int8×int8→i32 path instead: the activation row is re-quantized once
/// (same symmetric per-row scheme as the weights), whole output rows
/// run as integer dots, and each output gets one `sx·sw[o]` rescale.
/// That path is *not* bitwise equal to the reference — its error per
/// output is bounded by the activation quantization step,
/// `|Δy| ≤ (sx/2)·Σ_j |w_deq[o,j]|` to first order (pinned by a test
/// below) — and falls back to the reference when `k >`
/// [`I8_NATIVE_MAX_K`].
pub fn addmm_nt_packed(y: &mut [f32], x: &[f32], w: MatRef<'_>,
                       rows: usize, k: usize, m: usize) {
    debug_assert_eq!(y.len(), rows * m, "addmm_nt_packed y shape");
    debug_assert_eq!(x.len(), rows * k, "addmm_nt_packed x shape");
    debug_assert_eq!(w.numel(), m * k, "addmm_nt_packed w shape");
    match w {
        MatRef::F32(wf) => {
            addmm_nt(y, x, wf, rows, k, m);
            return;
        }
        MatRef::I8 { q, scales } => {
            debug_assert_eq!(scales.len(), m, "addmm_nt_packed scales");
            if int8_native() && k <= I8_NATIVE_MAX_K {
                addmm_nt_i8_native(y, x, q, scales, rows, k, m);
                return;
            }
        }
        MatRef::Bf16(_) => {}
    }
    let yp = SendPtr(y.as_mut_ptr());
    par_rows(rows, k * m, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `y`
        let yc = unsafe { yp.rows(lo, hi, m) };
        // Weight row `o` is dequantized once per task and shared by all
        // owned activation rows (the old loop re-dequantized it per
        // output element).  Each y element is still written by exactly
        // one task with the same [`dot`] the f32 kernel uses, so the
        // bitwise contract and the determinism contract both hold.
        let mut panel = vec![0.0f32; k];
        for o in 0..m {
            dequant_row(w, o, k, &mut panel);
            for (i, yr) in yc.chunks_exact_mut(m).enumerate() {
                let xr = &x[(lo + i) * k..(lo + i + 1) * k];
                yr[o] += dot(xr, &panel);
            }
        }
    });
}

/// Int8-native body of [`addmm_nt_packed`].  Row ownership and the
/// one-task-per-element rule are unchanged, so the path is thread-count
/// invariant; a non-finite activation row quantizes to a NaN scale and
/// poisons its outputs, matching f32 NaN propagation.
fn addmm_nt_i8_native(y: &mut [f32], x: &[f32], q: &[i8], sw: &[f32],
                      rows: usize, k: usize, m: usize) {
    let yp = SendPtr(y.as_mut_ptr());
    par_rows(rows, k * m, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `y`
        let yc = unsafe { yp.rows(lo, hi, m) };
        let mut qx = vec![0i8; k];
        for (i, yr) in yc.chunks_exact_mut(m).enumerate() {
            let xr = &x[(lo + i) * k..(lo + i + 1) * k];
            let sx = quantize_row_i8(xr, &mut qx);
            if sx == 0.0 {
                continue; // exact-zero activation row adds nothing
            }
            for (o, yo) in yr.iter_mut().enumerate() {
                let acc = dot_i8(&qx, &q[o * k..(o + 1) * k]);
                *yo += (sx * sw[o]) * acc as f32;
            }
        }
    });
}

/// `y[rows,k] += x[rows,m] @ w[m,k]` (no transpose) with a packed
/// weight operand; int8 per-row scales are per row of `w`.  Parallel
/// over rows of `y`, f32 accumulation, same zero-skip as the f32
/// kernel (decided on the f32 `x` values, so the skip pattern matches
/// the dequantize-then-`addmm_nn` reference exactly).
///
/// No int8-native variant exists for this orientation: the per-row
/// weight scales multiply different rows of the *sum* here, so they
/// cannot be factored out of an integer accumulator — and this kernel
/// only runs in training backward passes, never on the serving path.
pub fn addmm_nn_packed(y: &mut [f32], x: &[f32], w: MatRef<'_>,
                       rows: usize, m: usize, k: usize) {
    debug_assert_eq!(y.len(), rows * k, "addmm_nn_packed y shape");
    debug_assert_eq!(x.len(), rows * m, "addmm_nn_packed x shape");
    debug_assert_eq!(w.numel(), m * k, "addmm_nn_packed w shape");
    if let MatRef::F32(wf) = w {
        addmm_nn(y, x, wf, rows, m, k);
        return;
    }
    if let MatRef::I8 { scales, .. } = w {
        debug_assert_eq!(scales.len(), m, "addmm_nn_packed scales");
    }
    let yp = SendPtr(y.as_mut_ptr());
    par_rows(rows, m * k, |lo, hi| {
        // SAFETY: tasks receive disjoint row ranges of `y`
        let yc = unsafe { yp.rows(lo, hi, k) };
        // `w` row `o` scales column `o` of `x`.  Looping `o` outer
        // amortizes one dequant per task over all owned rows while each
        // y-row still accumulates in ascending-`o` order — the same
        // per-element order as `addmm_nn`, so the bitwise contract
        // holds.  A row whose column of `x` is entirely zero is never
        // dequantized at all (the f32 kernel's zero-skip, hoisted).
        let mut panel = vec![0.0f32; k];
        for o in 0..m {
            if (lo..hi).all(|i| x[i * m + o] == 0.0) {
                continue;
            }
            dequant_row(w, o, k, &mut panel);
            for (i, yr) in yc.chunks_exact_mut(k).enumerate() {
                let s = x[(lo + i) * m + o];
                if s == 0.0 {
                    continue;
                }
                axpy(yr, s, &panel);
            }
        }
    });
}

// ---------------------------------------------------------------------
// Attention primitives.
// ---------------------------------------------------------------------

/// Causal softmax attention over `[bh, t, hd]` q/k/v (q/k already
/// RoPE-rotated).  Returns `(o, att)` with the probability rows saved
/// for the backward pass.  Parallel over the `bh·t` query rows; each
/// row's score/softmax/weighted-sum runs in the serial order.
pub fn causal_attention_fwd(q: &[f32], k: &[f32], v: &[f32], bh: usize,
                            t: usize, hd: usize) -> (Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (hd as f32).sqrt();
    let mut o = vec![0.0; bh * t * hd];
    let mut att = vec![0.0; bh * t * t];
    let op = SendPtr(o.as_mut_ptr());
    let ap = SendPtr(att.as_mut_ptr());
    par_rows(bh * t, t * hd, |lo, hi| {
        // SAFETY: tasks receive disjoint (group, position) row ranges of
        // both `o` and `att`
        let oc = unsafe { op.rows(lo, hi, hd) };
        let ac = unsafe { ap.rows(lo, hi, t) };
        for r in lo..hi {
            let (g, i) = (r / t, r % t);
            let kg = &k[g * t * hd..(g + 1) * t * hd];
            let vg = &v[g * t * hd..(g + 1) * t * hd];
            let qi = &q[r * hd..(r + 1) * hd];
            let arow = &mut ac[(r - lo) * t..(r - lo + 1) * t];
            let mut zmax = f32::NEG_INFINITY;
            for j in 0..=i {
                let z = dot(qi, &kg[j * hd..(j + 1) * hd]) * scale;
                arow[j] = z;
                zmax = zmax.max(z);
            }
            let mut denom = 0.0f32;
            for aj in arow.iter_mut().take(i + 1) {
                *aj = (*aj - zmax).exp();
                denom += *aj;
            }
            let orow = &mut oc[(r - lo) * hd..(r - lo + 1) * hd];
            for j in 0..=i {
                arow[j] /= denom;
                axpy(orow, arow[j], &vg[j * hd..(j + 1) * hd]);
            }
        }
    });
    (o, att)
}

/// Backward of [`causal_attention_fwd`]: returns `(dq, dk, dv)` (dq/dk
/// still RoPE-rotated — the caller unrotates).  Parallel over the `bh`
/// groups only: `dk`/`dv` rows accumulate contributions from every query
/// position of their group, and that sum must keep the serial (ascending
/// `i`) order to stay bitwise deterministic.
#[allow(clippy::too_many_arguments)]
pub fn causal_attention_bwd(dout: &[f32], q: &[f32], k: &[f32],
                            v: &[f32], att: &[f32], bh: usize, t: usize,
                            hd: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = vec![0.0; bh * t * hd];
    let mut dk = vec![0.0; bh * t * hd];
    let mut dv = vec![0.0; bh * t * hd];
    let dqp = SendPtr(dq.as_mut_ptr());
    let dkp = SendPtr(dk.as_mut_ptr());
    let dvp = SendPtr(dv.as_mut_ptr());
    par_rows(bh, 3 * t * t * hd, |lo, hi| {
        // SAFETY: tasks receive disjoint group ranges of dq/dk/dv
        let dqc = unsafe { dqp.rows(lo, hi, t * hd) };
        let dkc = unsafe { dkp.rows(lo, hi, t * hd) };
        let dvc = unsafe { dvp.rows(lo, hi, t * hd) };
        let mut datt = vec![0.0f32; t];
        for g in lo..hi {
            let base = g * t * hd;
            let qg = &q[base..base + t * hd];
            let kg = &k[base..base + t * hd];
            let vg = &v[base..base + t * hd];
            let goff = (g - lo) * t * hd;
            for i in 0..t {
                let doi = &dout[base + i * hd..base + (i + 1) * hd];
                let arow = &att[(g * t + i) * t..(g * t + i + 1) * t];
                // dV[j] += a_ij·dO_i ; datt_ij = dO_i·v_j
                let mut row_dot = 0.0f32;
                for j in 0..=i {
                    let p = arow[j];
                    let vj = &vg[j * hd..(j + 1) * hd];
                    let dvj = &mut dvc[goff + j * hd..goff + (j + 1) * hd];
                    axpy(dvj, p, doi);
                    let d = dot(doi, vj);
                    datt[j] = d;
                    row_dot += p * d;
                }
                // dz = a·(datt − Σ a·datt); dq_i += dz·k_j·s;
                // dk_j += dz·q_i·s
                let qi = &qg[i * hd..(i + 1) * hd];
                for j in 0..=i {
                    let dz = arow[j] * (datt[j] - row_dot) * scale;
                    if dz == 0.0 {
                        continue;
                    }
                    let kj = &kg[j * hd..(j + 1) * hd];
                    axpy(&mut dqc[goff + i * hd..goff + (i + 1) * hd],
                         dz, kj);
                    axpy(&mut dkc[goff + j * hd..goff + (j + 1) * hd],
                         dz, qi);
                }
            }
        }
    });
    (dq, dk, dv)
}

/// Causal attention of a `[heads, t_new, hd]` query chunk over one
/// sequence's KV cache (layout `[heads, capacity, hd]`, the per-sequence
/// slice of a cache layer).  Query row `i` sits at absolute position
/// `base + i` and attends to cached positions `0..base + i + 1`.
/// Parallel over heads; `scratch` backs the score row on the serial
/// path so the single-token decode loop stays allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn cached_attend(q: &[f32], kc: &[f32], vc: &[f32], nh: usize,
                     t_new: usize, base: usize, cap: usize, hd: usize,
                     scratch: &mut Vec<f32>) -> Vec<f32> {
    debug_assert_eq!(q.len(), nh * t_new * hd, "cached_attend q shape");
    debug_assert_eq!(kc.len(), nh * cap * hd, "cached_attend k shape");
    debug_assert_eq!(vc.len(), nh * cap * hd, "cached_attend v shape");
    let scale = 1.0 / (hd as f32).sqrt();
    let mut o = vec![0.0f32; nh * t_new * hd];
    let work_per_head = t_new * (base + t_new) * hd;
    if nh <= 1
        || pool::threads() <= 1
        || pool::in_serial()
        || nh.saturating_mul(work_per_head) < 2 * MIN_TASK_WORK
    {
        scratch.resize(base + t_new, 0.0);
        attend_heads(&mut o, q, kc, vc, 0, nh, t_new, base, cap, hd,
                     scale, scratch);
        return o;
    }
    let op = SendPtr(o.as_mut_ptr());
    par_rows(nh, work_per_head, |lo, hi| {
        // SAFETY: tasks receive disjoint head ranges of `o`
        let oc = unsafe { op.rows(lo, hi, t_new * hd) };
        let mut zrow = vec![0.0f32; base + t_new];
        attend_heads(oc, q, kc, vc, lo, hi, t_new, base, cap, hd, scale,
                     &mut zrow);
    });
    o
}

/// Serial body of [`cached_attend`] for heads `lo..hi`, writing into the
/// head-sliced output `o` (`[hi-lo, t_new, hd]`).  Mirrors
/// [`causal_attention_fwd`] operation-for-operation (same dot-product,
/// max-subtraction and normalization order) so cached decode reproduces
/// the full re-forward logits bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn attend_heads(o: &mut [f32], q: &[f32], kc: &[f32], vc: &[f32],
                lo: usize, hi: usize, t_new: usize, base: usize,
                cap: usize, hd: usize, scale: f32, zrow: &mut [f32]) {
    for h in lo..hi {
        let kg = &kc[h * cap * hd..(h + 1) * cap * hd];
        let vg = &vc[h * cap * hd..(h + 1) * cap * hd];
        for i in 0..t_new {
            let qi = &q[(h * t_new + i) * hd..(h * t_new + i + 1) * hd];
            let ctx = base + i + 1;
            let mut zmax = f32::NEG_INFINITY;
            for (j, zj) in zrow.iter_mut().take(ctx).enumerate() {
                let z = dot(qi, &kg[j * hd..(j + 1) * hd]) * scale;
                *zj = z;
                zmax = zmax.max(z);
            }
            let mut denom = 0.0f32;
            for zj in zrow.iter_mut().take(ctx) {
                *zj = (*zj - zmax).exp();
                denom += *zj;
            }
            let orow = &mut o[((h - lo) * t_new + i) * hd
                              ..((h - lo) * t_new + i + 1) * hd];
            for (j, zj) in zrow.iter().take(ctx).enumerate() {
                axpy(orow, zj / denom, &vg[j * hd..(j + 1) * hd]);
            }
        }
    }
}

/// Causal attention of a `[heads, t_new, hd]` query chunk over one
/// sequence's **paged** KV cache.  `kp`/`vp` are whole per-layer block
/// pools laid out `[n_blocks, heads, block, hd]`, and `table[i]` names
/// the block holding the sequence's positions `i·block..(i+1)·block`.
/// Query row `i` sits at absolute position `base + i` and attends to
/// cached positions `0..base + i + 1`.
///
/// Mirrors [`cached_attend`] operation-for-operation — the same
/// dot-product, max-subtraction, exp/denominator and `axpy` accumulation
/// in the same ascending-`j` order per row; only the *address* of each
/// K/V row is resolved through the block table — so the paged path
/// reproduces the contiguous path bit-for-bit at any thread count (the
/// PR 4 determinism contract, pinned by the unit tests below).
#[allow(clippy::too_many_arguments)]
pub fn cached_attend_paged(q: &[f32], kp: &[f32], vp: &[f32],
                           table: &[u32], nh: usize, t_new: usize,
                           base: usize, block: usize, hd: usize,
                           scratch: &mut Vec<f32>) -> Vec<f32> {
    let ctx = base + t_new;
    debug_assert_eq!(q.len(), nh * t_new * hd, "paged attend q shape");
    debug_assert!(table.len() * block >= ctx, "block table too short");
    let scale = 1.0 / (hd as f32).sqrt();
    let mut o = vec![0.0f32; nh * t_new * hd];
    let work_per_head = t_new * ctx * hd;
    if nh <= 1
        || pool::threads() <= 1
        || pool::in_serial()
        || nh.saturating_mul(work_per_head) < 2 * MIN_TASK_WORK
    {
        scratch.resize(ctx, 0.0);
        attend_heads_paged(&mut o, q, kp, vp, table, 0, nh, nh, t_new,
                           base, block, hd, scale, scratch);
        return o;
    }
    let op = SendPtr(o.as_mut_ptr());
    par_rows(nh, work_per_head, |lo, hi| {
        // SAFETY: tasks receive disjoint head ranges of `o`
        let oc = unsafe { op.rows(lo, hi, t_new * hd) };
        let mut zrow = vec![0.0f32; ctx];
        attend_heads_paged(oc, q, kp, vp, table, lo, hi, nh, t_new,
                           base, block, hd, scale, &mut zrow);
    });
    o
}

/// Serial body of [`cached_attend_paged`] for heads `lo..hi`, writing
/// into the head-sliced output `o` (`[hi-lo, t_new, hd]`).  Identical to
/// [`attend_heads`] except that each K/V row address goes through the
/// block table: position `j` of head `h` lives at element offset
/// `((table[j/block]·nh + h)·block + j%block)·hd` of the pool.
#[allow(clippy::too_many_arguments)]
fn attend_heads_paged(o: &mut [f32], q: &[f32], kp: &[f32], vp: &[f32],
                      table: &[u32], lo: usize, hi: usize, nh: usize,
                      t_new: usize, base: usize, block: usize, hd: usize,
                      scale: f32, zrow: &mut [f32]) {
    let row = |h: usize, j: usize| -> usize {
        ((table[j / block] as usize * nh + h) * block + j % block) * hd
    };
    for h in lo..hi {
        for i in 0..t_new {
            let qi = &q[(h * t_new + i) * hd..(h * t_new + i + 1) * hd];
            let ctx = base + i + 1;
            let mut zmax = f32::NEG_INFINITY;
            for (j, zj) in zrow.iter_mut().take(ctx).enumerate() {
                let ko = row(h, j);
                let z = dot(qi, &kp[ko..ko + hd]) * scale;
                *zj = z;
                zmax = zmax.max(z);
            }
            let mut denom = 0.0f32;
            for zj in zrow.iter_mut().take(ctx) {
                *zj = (*zj - zmax).exp();
                denom += *zj;
            }
            let orow = &mut o[((h - lo) * t_new + i) * hd
                              ..((h - lo) * t_new + i + 1) * hd];
            for (j, zj) in zrow.iter().take(ctx).enumerate() {
                let vo = row(h, j);
                axpy(orow, zj / denom, &vp[vo..vo + hd]);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Shard fan-out (data-parallel workers).
// ---------------------------------------------------------------------

/// Map `f` over `items` with one contiguous chunk per pool thread — the
/// data-parallel shard fan-out.  Chunks run as tasks on the persistent
/// pool (no per-call thread spawns on the training hot path), and pool
/// participants always execute tasks inside a serial scope, so per-item
/// kernel calls stay inline on their shard's thread instead of
/// re-entering the pool.  Results come back in input order, and
/// per-item work is identical to the serial path, so losses/gradients
/// match the interleaved schedule bitwise.  Falls back to a plain
/// serial map for one item, one thread, or when already inside a
/// serial/pool scope.
pub fn scoped_map<I: Sync, T: Send>(items: &[I],
                                    f: impl Fn(&I) -> T + Sync)
    -> Vec<T> {
    let nt = pool::threads();
    if items.len() <= 1 || nt <= 1 || pool::in_serial() {
        return items.iter().map(f).collect();
    }
    let n_chunks = nt.min(items.len());
    // balanced boundaries lo = c·len/n: every chunk non-empty
    let bound = |c: usize| c * items.len() / n_chunks;
    let slots: Vec<std::sync::Mutex<Option<Vec<T>>>> =
        (0..n_chunks).map(|_| std::sync::Mutex::new(None)).collect();
    pool::run(n_chunks, &|c| {
        let out: Vec<T> =
            items[bound(c)..bound(c + 1)].iter().map(&f).collect();
        *slots[c].lock().unwrap_or_else(|e| e.into_inner()) = Some(out);
    });
    slots
        .into_iter()
        .flat_map(|m| {
            m.into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("every pool task fills its slot")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.8)).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// Compute `f` once on the pool (4 threads) and once forced serial;
    /// the results must be bitwise identical.  Restores the prior
    /// (CLI/env/detected) thread configuration afterwards.
    fn assert_thread_invariant<R>(f: impl Fn() -> R, key: impl Fn(&R)
        -> Vec<u32>) {
        let _t = pool::TEST_SERIALIZE
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let prev = threads();
        set_threads(4);
        let par = f();
        let ser = serial(&f);
        set_threads(prev);
        assert_eq!(key(&par), key(&ser),
                   "threaded result differs from serial");
    }

    #[test]
    fn addmm_nt_threaded_matches_serial_bitwise() {
        let mut rng = Rng::new(1);
        let (rows, k, m) = (37, 53, 41);
        let x = randv(rows * k, &mut rng);
        let w = randv(m * k, &mut rng);
        let y0 = randv(rows * m, &mut rng);
        assert_thread_invariant(
            || {
                let mut y = y0.clone();
                addmm_nt(&mut y, &x, &w, rows, k, m);
                y
            },
            |y| bits(y));
    }

    #[test]
    fn addmm_nn_and_tn_threaded_match_serial_bitwise() {
        let mut rng = Rng::new(2);
        let (rows, m, k) = (33, 47, 29);
        let x = randv(rows * m, &mut rng);
        let w = randv(m * k, &mut rng);
        let dy = randv(rows * m, &mut rng);
        let xs = randv(rows * k, &mut rng);
        assert_thread_invariant(
            || {
                let mut y = vec![0.0; rows * k];
                addmm_nn(&mut y, &x, &w, rows, m, k);
                let mut wg = vec![0.0; m * k];
                addmm_tn(&mut wg, &dy, &xs, rows, m, k);
                (y, wg)
            },
            |(y, wg)| {
                let mut b = bits(y);
                b.extend(bits(wg));
                b
            });
    }

    #[test]
    fn matmul_nn_matches_naive_and_is_thread_invariant() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (23, 130, 19);
        let a = randv(m * k, &mut rng);
        let b = randv(k * n, &mut rng);
        let naive: Vec<f32> = (0..m * n)
            .map(|idx| {
                let (i, j) = (idx / n, idx % n);
                (0..k).map(|kk| a[i * k + kk] * b[kk * n + j])
                    .sum::<f32>()
            })
            .collect();
        assert_thread_invariant(
            || {
                let mut c = vec![0.0; m * n];
                matmul_nn(&mut c, &a, &b, m, k, n);
                c
            },
            |c| bits(c));
        let mut c = vec![0.0; m * n];
        serial(|| matmul_nn(&mut c, &a, &b, m, k, n));
        for (x, y) in c.iter().zip(&naive) {
            assert!((x - y).abs() <= 1e-4 + 1e-4 * y.abs(),
                    "matmul {x} vs naive {y}");
        }
    }

    #[test]
    fn gram_threaded_matches_serial_bitwise() {
        let mut rng = Rng::new(4);
        let (rows, n) = (61, 43);
        let a = randv(rows * n, &mut rng);
        assert_thread_invariant(
            || {
                let mut g = vec![0.0; n * n];
                gram(&mut g, &a, rows, n);
                g
            },
            |g| bits(g));
    }

    #[test]
    fn attention_fwd_bwd_threaded_match_serial_bitwise() {
        let mut rng = Rng::new(5);
        let (bh, t, hd) = (6, 33, 8);
        let q = randv(bh * t * hd, &mut rng);
        let k = randv(bh * t * hd, &mut rng);
        let v = randv(bh * t * hd, &mut rng);
        let dout = randv(bh * t * hd, &mut rng);
        assert_thread_invariant(
            || {
                let (o, att) = causal_attention_fwd(&q, &k, &v, bh, t, hd);
                let (dq, dk, dv) =
                    causal_attention_bwd(&dout, &q, &k, &v, &att, bh, t,
                                         hd);
                (o, att, dq, dk, dv)
            },
            |(o, att, dq, dk, dv)| {
                let mut b = bits(o);
                for part in [att, dq, dk, dv] {
                    b.extend(bits(part));
                }
                b
            });
    }

    #[test]
    fn cached_attend_threaded_matches_serial_bitwise() {
        let mut rng = Rng::new(6);
        let (nh, t_new, base, cap, hd) = (5, 6, 120, 128, 16);
        let q = randv(nh * t_new * hd, &mut rng);
        let kc = randv(nh * cap * hd, &mut rng);
        let vc = randv(nh * cap * hd, &mut rng);
        assert_thread_invariant(
            || {
                let mut scratch = Vec::new();
                cached_attend(&q, &kc, &vc, nh, t_new, base, cap, hd,
                              &mut scratch)
            },
            |o| bits(o));
    }

    #[test]
    fn paged_attend_matches_contiguous_bitwise() {
        // Scatter the contiguous [nh, cap, hd] cache into a block pool
        // with a deliberately shuffled block order; the paged kernel
        // must reproduce the contiguous kernel bit-for-bit (same serial
        // accumulation order per row — only the addresses differ).
        let mut rng = Rng::new(11);
        let (nh, t_new, base, hd, block) = (5, 6, 120, 16, 32);
        let ctx = base + t_new;
        let cap = ctx; // tight contiguous reference
        let q = randv(nh * t_new * hd, &mut rng);
        let kc = randv(nh * cap * hd, &mut rng);
        let vc = randv(nh * cap * hd, &mut rng);
        let n_blocks = ctx.div_ceil(block);
        // table[i] = shuffled id, so pool order != position order
        let table: Vec<u32> =
            (0..n_blocks).map(|i| (n_blocks - 1 - i) as u32).collect();
        let mut kp = vec![0.0f32; n_blocks * nh * block * hd];
        let mut vp = vec![0.0f32; n_blocks * nh * block * hd];
        for j in 0..ctx {
            let b = table[j / block] as usize;
            for h in 0..nh {
                let src = (h * cap + j) * hd;
                let dst = ((b * nh + h) * block + j % block) * hd;
                kp[dst..dst + hd].copy_from_slice(&kc[src..src + hd]);
                vp[dst..dst + hd].copy_from_slice(&vc[src..src + hd]);
            }
        }
        let mut scratch = Vec::new();
        let want = cached_attend(&q, &kc, &vc, nh, t_new, base, cap, hd,
                                 &mut scratch);
        let got = cached_attend_paged(&q, &kp, &vp, &table, nh, t_new,
                                      base, block, hd, &mut scratch);
        assert_eq!(bits(&got), bits(&want),
                   "paged attend diverged from contiguous");
        // and the paged kernel itself is thread-invariant
        assert_thread_invariant(
            || {
                let mut s = Vec::new();
                cached_attend_paged(&q, &kp, &vp, &table, nh, t_new,
                                    base, block, hd, &mut s)
            },
            |o| bits(o));
    }

    #[test]
    fn rotate_columns_matches_scalar_reference() {
        let mut rng = Rng::new(7);
        // large enough that the parallel path engages (8 madds/row)
        let (rows, cols) = (4501, 6);
        let a0 = randv(rows * cols, &mut rng);
        let (c, s) = (0.8f64, 0.6f64);
        let mut want = a0.clone();
        for r in want.chunks_exact_mut(cols) {
            let (xp, xq) = (r[1] as f64, r[4] as f64);
            r[1] = (c * xp - s * xq) as f32;
            r[4] = (s * xp + c * xq) as f32;
        }
        assert_thread_invariant(
            || {
                let mut a = a0.clone();
                rotate_columns(&mut a, rows, cols, 1, 4, c, s);
                a
            },
            |a| bits(a));
        let mut a = a0;
        serial(|| rotate_columns(&mut a, rows, cols, 1, 4, c, s));
        assert_eq!(bits(&a), bits(&want));
    }

    #[test]
    fn packed_f32_view_is_the_f32_kernel_bitwise() {
        let mut rng = Rng::new(8);
        let (rows, k, m) = (13, 29, 17);
        let x = randv(rows * k, &mut rng);
        let w = randv(m * k, &mut rng);
        let dy = randv(rows * m, &mut rng);
        let mut y1 = vec![0.0; rows * m];
        addmm_nt(&mut y1, &x, &w, rows, k, m);
        let mut y2 = vec![0.0; rows * m];
        addmm_nt_packed(&mut y2, &x, MatRef::F32(&w), rows, k, m);
        assert_eq!(bits(&y1), bits(&y2));
        let mut d1 = vec![0.0; rows * k];
        addmm_nn(&mut d1, &dy, &w, rows, m, k);
        let mut d2 = vec![0.0; rows * k];
        addmm_nn_packed(&mut d2, &dy, MatRef::F32(&w), rows, m, k);
        assert_eq!(bits(&d1), bits(&d2));
    }

    #[test]
    fn packed_kernels_match_dequantize_then_f32_bitwise() {
        use crate::tensor::dtype::{DType, PackedBuf};
        // hold the test lock: the int8-native tests below toggle the
        // process-global flag, and this test pins the reference path
        let _t = pool::TEST_SERIALIZE
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::new(9);
        let (rows, k, m) = (11, 37, 23);
        let x = randv(rows * k, &mut rng);
        let w = randv(m * k, &mut rng);
        let dy = randv(rows * m, &mut rng);
        for dtype in [DType::Bf16, DType::I8] {
            let packed = PackedBuf::pack(&w, m, k, dtype);
            let wd = packed.to_f32();
            let mut want = randv(rows * m, &mut rng);
            let mut got = want.clone();
            addmm_nt(&mut want, &x, &wd, rows, k, m);
            addmm_nt_packed(&mut got, &x, packed.view(), rows, k, m);
            assert_eq!(bits(&want), bits(&got), "{dtype:?} nt");
            let mut dwant = vec![0.0; rows * k];
            addmm_nn(&mut dwant, &dy, &wd, rows, m, k);
            let mut dgot = vec![0.0; rows * k];
            addmm_nn_packed(&mut dgot, &dy, packed.view(), rows, m, k);
            assert_eq!(bits(&dwant), bits(&dgot), "{dtype:?} nn");
        }
    }

    #[test]
    fn packed_kernels_are_thread_invariant() {
        use crate::tensor::dtype::{DType, PackedBuf};
        let mut rng = Rng::new(10);
        let (rows, k, m) = (37, 53, 41);
        let x = randv(rows * k, &mut rng);
        let dy = randv(rows * m, &mut rng);
        let w = randv(m * k, &mut rng);
        for dtype in [DType::Bf16, DType::I8] {
            let packed = PackedBuf::pack(&w, m, k, dtype);
            assert_thread_invariant(
                || {
                    let mut y = vec![0.0; rows * m];
                    addmm_nt_packed(&mut y, &x, packed.view(), rows, k,
                                    m);
                    let mut d = vec![0.0; rows * k];
                    addmm_nn_packed(&mut d, &dy, packed.view(), rows, m,
                                    k);
                    (y, d)
                },
                |(y, d)| {
                    let mut b = bits(y);
                    b.extend(bits(d));
                    b
                });
        }
    }

    #[test]
    fn int8_native_error_bounded_by_activation_quant_step() {
        use crate::tensor::dtype::{DType, PackedBuf};
        let mut rng = Rng::new(12);
        let (rows, k, m) = (17, 64, 13);
        let x = randv(rows * k, &mut rng);
        let w = randv(m * k, &mut rng);
        let packed = PackedBuf::pack(&w, m, k, DType::I8);
        let wd = packed.to_f32();
        let (q, sw) = match packed.view() {
            MatRef::I8 { q, scales } => (q, scales),
            _ => unreachable!(),
        };
        let mut reference = vec![0.0; rows * m];
        addmm_nt(&mut reference, &x, &wd, rows, k, m);
        let mut native = vec![0.0; rows * m];
        addmm_nt_i8_native(&mut native, &x, q, sw, rows, k, m);
        // the only approximation is the activation re-quantization:
        // |Δy[i,o]| ≤ (sx/2)·Σ_j |w_deq[o,j]|, plus fp slack
        let mut qx = vec![0i8; k];
        for i in 0..rows {
            let xr = &x[i * k..(i + 1) * k];
            let sx = quantize_row_i8(xr, &mut qx);
            for o in 0..m {
                let wsum: f32 = wd[o * k..(o + 1) * k]
                    .iter()
                    .map(|v| v.abs())
                    .sum();
                let bound = 0.505 * sx * wsum + 1e-4;
                let err = (native[i * m + o] - reference[i * m + o]).abs();
                assert!(err <= bound,
                        "({i},{o}): err {err} > bound {bound}");
            }
        }
        // the native path obeys the determinism contract too
        assert_thread_invariant(
            || {
                let mut y = vec![0.0; rows * m];
                addmm_nt_i8_native(&mut y, &x, q, sw, rows, k, m);
                y
            },
            |y| bits(y));
    }

    #[test]
    fn int8_native_flag_dispatches_and_restores() {
        use crate::tensor::dtype::{DType, PackedBuf};
        // the flag is process-global: serialize against every test that
        // pins the reference path
        let _t = pool::TEST_SERIALIZE
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let mut rng = Rng::new(11);
        let (rows, k, m) = (5, 40, 9);
        let x = randv(rows * k, &mut rng);
        let w = randv(m * k, &mut rng);
        let packed = PackedBuf::pack(&w, m, k, DType::I8);
        let (q, sw) = match packed.view() {
            MatRef::I8 { q, scales } => (q, scales),
            _ => unreachable!(),
        };
        let mut direct = vec![0.0; rows * m];
        addmm_nt_i8_native(&mut direct, &x, q, sw, rows, k, m);
        set_int8_native(true);
        let mut via_flag = vec![0.0; rows * m];
        addmm_nt_packed(&mut via_flag, &x, packed.view(), rows, k, m);
        set_int8_native(false);
        assert_eq!(bits(&direct), bits(&via_flag),
                   "flag on: packed nt takes the native path");
        // flag off again: back to the bitwise dequantizing reference
        let mut reference = vec![0.0; rows * m];
        addmm_nt(&mut reference, &x, &packed.to_f32(), rows, k, m);
        let mut off = vec![0.0; rows * m];
        addmm_nt_packed(&mut off, &x, packed.view(), rows, k, m);
        assert_eq!(bits(&reference), bits(&off),
                   "flag off: packed nt is the reference");
    }

    #[test]
    fn scoped_map_preserves_order_and_values() {
        let items: Vec<usize> = (0..23).collect();
        let _t = pool::TEST_SERIALIZE
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        let prev = threads();
        set_threads(4);
        let par = scoped_map(&items, |&i| i * i);
        set_threads(1);
        let ser = scoped_map(&items, |&i| i * i);
        set_threads(prev);
        let want: Vec<usize> = items.iter().map(|&i| i * i).collect();
        assert_eq!(par, want);
        assert_eq!(ser, want);
    }
}
