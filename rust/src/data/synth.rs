//! Synthetic pre-training corpus (the C4 stand-in).
//!
//! Token streams combine three statistical layers so that models with more
//! usable update rank have measurable headroom (the property Table 2 /
//! Figure 2 depend on):
//!
//! 1. **Zipfian unigram head** — token frequencies follow Zipf(s), like
//!    natural text.  Learnable by the embedding/head alone.
//! 2. **Latent-state bigram structure** — a hidden Markov chain over `k`
//!    latent states, each emitting from its own Zipf-permuted distribution
//!    with sticky transitions.  Requires the FFN/attention stack to model.
//! 3. **Induction spans** — with probability `copy_p` the stream enters a
//!    copy phase that replays a span seen earlier in the window.  Only
//!    attention (induction heads) can exploit this; it is the strongest
//!    rank-hungry signal.
//!
//! Generation is deterministic in `(seed, shard)` and streams are unbounded,
//! mirroring a sharded C4 loader.

use crate::util::rng::{Rng, Zipf};

#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub vocab: usize,
    /// Zipf exponent for unigram head.
    pub zipf_s: f64,
    /// number of latent Markov states
    pub states: usize,
    /// probability of staying in the current latent state
    pub sticky: f64,
    /// probability per token of starting an induction copy span
    pub copy_p: f64,
    /// copied span length range
    pub copy_len: (usize, usize),
    /// how far back the copy source may start
    pub copy_window: usize,
}

impl SynthConfig {
    pub fn for_vocab(vocab: usize) -> Self {
        SynthConfig {
            vocab,
            zipf_s: 1.1,
            states: 8,
            sticky: 0.9,
            copy_p: 0.03,
            copy_len: (4, 16),
            copy_window: 48,
        }
    }
}

/// Unbounded deterministic token stream.
pub struct CorpusGen {
    cfg: SynthConfig,
    rng: Rng,
    zipf: Zipf,
    /// per-state permutations of the zipf ranks
    perms: Vec<Vec<u32>>,
    state: usize,
    /// recent history ring for induction copies
    history: Vec<u32>,
    /// active copy: (source_offset_back, remaining)
    copying: Option<(usize, usize)>,
}

impl CorpusGen {
    pub fn new(cfg: SynthConfig, seed: u64, shard: u64) -> Self {
        // Structural randomness (state emission tables) depends only on
        // `seed`, so all shards speak the *same* language; the stream path
        // depends on (seed, shard).
        let mut struct_rng = Rng::new(seed ^ 0x5173_C0DE);
        let mut perms: Vec<Vec<u32>> = Vec::with_capacity(cfg.states);
        for _ in 0..cfg.states {
            let mut p: Vec<u32> = (0..cfg.vocab as u32).collect();
            struct_rng.shuffle(&mut p);
            perms.push(p);
        }
        let rng = Rng::new(seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(shard.wrapping_mul(0xD1B5_4A32_D192_ED03) | 1));
        let zipf = Zipf::new(cfg.vocab, cfg.zipf_s);
        CorpusGen {
            cfg,
            rng,
            zipf,
            perms,
            state: 0,
            history: Vec::new(),
            copying: None,
        }
    }

    /// Next token of the stream.
    pub fn next_token(&mut self) -> u32 {
        let tok = if let Some((back, remaining)) = self.copying {
            let idx = self.history.len().checked_sub(back);
            let t = idx
                .and_then(|i| self.history.get(i).copied())
                .unwrap_or_else(|| self.fresh_token());
            self.copying = if remaining > 1 {
                Some((back, remaining - 1))
            } else {
                None
            };
            t
        } else {
            if self.history.len() > self.cfg.copy_window
                && self.rng.bernoulli(self.cfg.copy_p)
            {
                let (lo, hi) = self.cfg.copy_len;
                let len = lo + self.rng.below(hi - lo + 1);
                let back = len
                    + self.rng.below(self.cfg.copy_window.max(len + 1) - len);
                self.copying = Some((back.max(1), len));
            }
            self.fresh_token()
        };
        self.history.push(tok);
        if self.history.len() > 4 * self.cfg.copy_window {
            self.history.drain(..2 * self.cfg.copy_window);
        }
        tok
    }

    fn fresh_token(&mut self) -> u32 {
        // latent-state transition
        if !self.rng.bernoulli(self.cfg.sticky) {
            self.state = self.rng.below(self.cfg.states);
        }
        let rank = self.zipf.sample(&mut self.rng);
        self.perms[self.state][rank]
    }

    /// Fill a buffer with the next `buf.len()` tokens.
    pub fn fill(&mut self, buf: &mut [i32]) {
        for b in buf.iter_mut() {
            *b = self.next_token() as i32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn take(seed: u64, shard: u64, n: usize) -> Vec<u32> {
        let mut g = CorpusGen::new(SynthConfig::for_vocab(256), seed, shard);
        (0..n).map(|_| g.next_token()).collect()
    }

    #[test]
    fn deterministic_per_seed_shard() {
        assert_eq!(take(1, 0, 500), take(1, 0, 500));
        assert_ne!(take(1, 0, 500), take(1, 1, 500));
        assert_ne!(take(1, 0, 500), take(2, 0, 500));
    }

    #[test]
    fn tokens_in_vocab() {
        for t in take(3, 7, 2000) {
            assert!(t < 256);
        }
    }

    #[test]
    fn zipf_head_present() {
        let toks = take(5, 0, 30_000);
        let mut counts = vec![0usize; 256];
        for t in &toks {
            counts[*t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // heavy head: top-16 tokens should dominate uniform share
        let top: usize = counts[..16].iter().sum();
        assert!(top > toks.len() / 4, "top16 share {top}/{}", toks.len());
    }

    #[test]
    fn induction_spans_exist() {
        // with copy_p > 0 there must be verbatim repeats of length >= 4
        let toks = take(9, 0, 4000);
        let mut found = false;
        'outer: for i in 0..toks.len() - 8 {
            for back in 4..48.min(i) {
                if (0..6).all(|d| {
                    i >= back && toks[i + d] == toks[i + d - back]
                }) {
                    found = true;
                    break 'outer;
                }
            }
        }
        assert!(found, "no induction spans found");
    }

    #[test]
    fn shards_share_language_statistics() {
        // same seed, different shards → similar unigram distributions
        let a = take(11, 0, 30_000);
        let b = take(11, 3, 30_000);
        let hist = |xs: &[u32]| {
            let mut h = vec![0f64; 256];
            for x in xs {
                h[*x as usize] += 1.0 / xs.len() as f64;
            }
            h
        };
        let (ha, hb) = (hist(&a), hist(&b));
        let l1: f64 = ha.iter().zip(&hb).map(|(x, y)| (x - y).abs()).sum();
        assert!(l1 < 0.15, "shard unigram L1 distance {l1}");
    }
}
