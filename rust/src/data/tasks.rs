//! GLUE-analog downstream task suite (the Tables 7/8 substitute).
//!
//! Five synthetic sequence-classification tasks over the pre-training token
//! distribution, graded in difficulty the way GLUE tasks are.  Each task
//! yields `(tokens[seq], label)` pairs with balanced labels; fine-tuning a
//! pre-trained checkpoint on them measures representation transfer exactly
//! as the paper's GLUE full fine-tuning does:
//!
//! | task        | labels | skill probed                                  |
//! |-------------|--------|-----------------------------------------------|
//! | `majority`  | 4      | bag-of-tokens pooling (easy, SST2-ish)        |
//! | `contains`  | 2      | pattern detection (QNLI-ish)                  |
//! | `pairmatch` | 2      | two-segment comparison (MRPC/QQP-ish)         |
//! | `parity`    | 2      | counting mod 2 (hard, CoLA-ish)               |
//! | `recall`    | 4      | induction: recall token after a marker (RTE-ish) |

use super::synth::{CorpusGen, SynthConfig};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Majority,
    Contains,
    PairMatch,
    Parity,
    Recall,
}

impl Task {
    pub const ALL: [Task; 5] = [Task::Majority, Task::Contains,
                                Task::PairMatch, Task::Parity, Task::Recall];

    pub fn name(&self) -> &'static str {
        match self {
            Task::Majority => "majority",
            Task::Contains => "contains",
            Task::PairMatch => "pairmatch",
            Task::Parity => "parity",
            Task::Recall => "recall",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        Task::ALL.iter().copied().find(|t| t.name() == s)
    }

    pub fn n_classes(&self) -> usize {
        match self {
            Task::Majority | Task::Recall => 4,
            _ => 2,
        }
    }
}

/// Generates labelled examples for one task.
pub struct TaskGen {
    pub task: Task,
    vocab: usize,
    seq: usize,
    corpus: CorpusGen,
    rng: Rng,
}

impl TaskGen {
    pub fn new(task: Task, vocab: usize, seq: usize, seed: u64) -> Self {
        let corpus = CorpusGen::new(SynthConfig::for_vocab(vocab),
                                    seed ^ 0x7A5C, seed);
        TaskGen { task, vocab, seq, corpus, rng: Rng::new(seed) }
    }

    /// One example: (tokens of length seq, label < n_classes).
    pub fn example(&mut self) -> (Vec<i32>, i32) {
        match self.task {
            Task::Majority => self.gen_majority(),
            Task::Contains => self.gen_contains(),
            Task::PairMatch => self.gen_pairmatch(),
            Task::Parity => self.gen_parity(),
            Task::Recall => self.gen_recall(),
        }
    }

    /// A batch of examples: (tokens `[n, seq]` row-major, labels `[n]`).
    pub fn batch(&mut self, n: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(n * self.seq);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let (t, l) = self.example();
            toks.extend_from_slice(&t);
            labels.push(l);
        }
        (toks, labels)
    }

    fn base_seq(&mut self) -> Vec<i32> {
        let mut buf = vec![0i32; self.seq];
        self.corpus.fill(&mut buf);
        buf
    }

    /// Token-class quartile of a token (labels for majority/recall tasks).
    pub fn class_of(&self, tok: i32) -> usize {
        (tok as usize * 4) / self.vocab
    }

    /// Label = most frequent token-class quartile; ties broken by planting.
    fn gen_majority(&mut self) -> (Vec<i32>, i32) {
        let label = self.rng.below(4) as i32;
        let mut toks = self.base_seq();
        // overwrite a random 40% of positions with tokens from the label
        // class so the majority is unambiguous
        let k = self.seq * 2 / 5;
        let quarter = self.vocab / 4;
        for pos in self.rng.sample_distinct(self.seq, k) {
            let t = label as usize * quarter + self.rng.below(quarter);
            toks[pos] = t as i32;
        }
        (toks, label)
    }

    /// Label = whether the fixed trigram pattern occurs.
    fn gen_contains(&mut self) -> (Vec<i32>, i32) {
        let pat = [1i32, 3, 5]; // fixed, rare under zipf-permuted corpus
        let mut toks = self.base_seq();
        // clear natural occurrences to control the label exactly
        for i in 0..self.seq.saturating_sub(2) {
            if toks[i..i + 3] == pat {
                toks[i] = (toks[i] + 7) % self.vocab as i32;
            }
        }
        let label = self.rng.below(2) as i32;
        if label == 1 {
            let pos = self.rng.below(self.seq - 3);
            toks[pos..pos + 3].copy_from_slice(&pat);
        }
        (toks, label)
    }

    /// First half vs second half equality (with a separator position).
    fn gen_pairmatch(&mut self) -> (Vec<i32>, i32) {
        let half = self.seq / 2;
        let mut toks = self.base_seq();
        let label = self.rng.below(2) as i32;
        if label == 1 {
            for i in 0..half.min(self.seq - half) {
                toks[half + i] = toks[i];
            }
        } else {
            // ensure at least a few mismatches
            let mut diff = 0;
            for i in 0..half.min(self.seq - half) {
                if toks[half + i] != toks[i] {
                    diff += 1;
                }
            }
            if diff < 3 {
                for _ in 0..3 {
                    let i = self.rng.below(half);
                    toks[half + i] =
                        (toks[i] + 1 + self.rng.below(self.vocab - 1) as i32)
                            % self.vocab as i32;
                }
            }
        }
        (toks, label)
    }

    /// Parity of the count of the marker token 2.
    fn gen_parity(&mut self) -> (Vec<i32>, i32) {
        let marker = 2i32;
        let mut toks = self.base_seq();
        for t in toks.iter_mut() {
            if *t == marker {
                *t = 9;
            }
        }
        let count = 1 + self.rng.below(8);
        for pos in self.rng.sample_distinct(self.seq, count) {
            toks[pos] = marker;
        }
        (toks, (count % 2) as i32)
    }

    /// Induction recall: marker token appears twice; the label is the class
    /// of the token that followed its first occurrence.
    fn gen_recall(&mut self) -> (Vec<i32>, i32) {
        let marker = 4i32;
        let mut toks = self.base_seq();
        for t in toks.iter_mut() {
            if *t == marker {
                *t = 11;
            }
        }
        let quarter = self.vocab / 4;
        let label = self.rng.below(4) as i32;
        let value = (label as usize * quarter + self.rng.below(quarter))
            as i32;
        let first = 1 + self.rng.below(self.seq / 2 - 2);
        toks[first] = marker;
        toks[first + 1] = value;
        // second marker near the end cues the recall
        toks[self.seq - 1] = marker;
        (toks, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_task(task: Task) {
        let mut g = TaskGen::new(task, 512, 64, 42);
        let mut counts = vec![0usize; task.n_classes()];
        for _ in 0..200 {
            let (toks, label) = g.example();
            assert_eq!(toks.len(), 64);
            assert!(toks.iter().all(|&t| (0..512).contains(&t)));
            assert!((label as usize) < task.n_classes());
            counts[label as usize] += 1;
        }
        // labels roughly balanced
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 200 / task.n_classes() / 3,
                    "{} label {i} count {c}", task.name());
        }
    }

    #[test]
    fn all_tasks_well_formed() {
        for t in Task::ALL {
            check_task(t);
        }
    }

    #[test]
    fn contains_label_is_checkable() {
        let mut g = TaskGen::new(Task::Contains, 512, 64, 7);
        for _ in 0..100 {
            let (toks, label) = g.example();
            let found = toks.windows(3).any(|w| w == [1, 3, 5]);
            assert_eq!(found, label == 1);
        }
    }

    #[test]
    fn pairmatch_label_is_checkable() {
        let mut g = TaskGen::new(Task::PairMatch, 512, 64, 8);
        for _ in 0..100 {
            let (toks, label) = g.example();
            let same = (0..32).all(|i| toks[i] == toks[32 + i]);
            assert_eq!(same, label == 1);
        }
    }

    #[test]
    fn parity_label_is_checkable() {
        let mut g = TaskGen::new(Task::Parity, 512, 64, 9);
        for _ in 0..100 {
            let (toks, label) = g.example();
            let count = toks.iter().filter(|&&t| t == 2).count();
            assert_eq!((count % 2) as i32, label);
        }
    }

    #[test]
    fn recall_label_is_checkable() {
        let mut g = TaskGen::new(Task::Recall, 512, 64, 10);
        for _ in 0..100 {
            let (toks, label) = g.example();
            let first = toks.iter().position(|&t| t == 4).unwrap();
            let value = toks[first + 1];
            assert_eq!((value as usize * 4 / 512) as i32, label);
            assert_eq!(toks[63], 4);
        }
    }

    #[test]
    fn batch_layout() {
        let mut g = TaskGen::new(Task::Majority, 512, 32, 1);
        let (toks, labels) = g.batch(5);
        assert_eq!(toks.len(), 5 * 32);
        assert_eq!(labels.len(), 5);
    }

    #[test]
    fn task_names_roundtrip() {
        for t in Task::ALL {
            assert_eq!(Task::from_name(t.name()), Some(t));
        }
        assert_eq!(Task::from_name("nope"), None);
    }
}
