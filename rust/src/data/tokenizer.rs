//! Tokenizers: byte-level identity and a small trainable BPE.
//!
//! The synthetic corpus is already token ids, but the CLI also accepts raw
//! text files (`--data path.txt`); those go through byte-level BPE trained
//! on a prefix of the file, so the full pipeline (train tokenizer → encode →
//! pre-train) works on real text too.

use std::collections::HashMap;

/// Common interface for the data pipeline (and the generation CLI's
/// token streaming).
pub trait Tokenizer {
    fn vocab_size(&self) -> usize;
    fn encode(&self, text: &str) -> Vec<i32>;
    /// Decode ids back to text (lossy where the byte stream is not valid
    /// UTF-8 — generated tokens are arbitrary bytes).
    fn decode(&self, ids: &[i32]) -> String;
}

/// Identity over raw bytes, clamped into the model vocab.
pub struct ByteTokenizer {
    vocab: usize,
}

impl ByteTokenizer {
    pub fn new(vocab: usize) -> Self {
        assert!(vocab >= 256 || vocab > 0);
        ByteTokenizer { vocab }
    }
}

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| (b as usize % self.vocab) as i32).collect()
    }

    fn decode(&self, ids: &[i32]) -> String {
        // ids beyond the byte range (vocab > 256 presets) have no byte
        // identity — skip them rather than alias via wraparound
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&i| i >= 0 && (i as usize) < self.vocab.min(256))
            .map(|&i| i as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Byte-level BPE: 256 base tokens + learned merges.
pub struct BpeTokenizer {
    /// merge table: (left, right) -> merged id, in training order
    merges: Vec<(u32, u32)>,
    rank: HashMap<(u32, u32), u32>,
    vocab: usize,
}

impl BpeTokenizer {
    /// Train merges on `text` until `vocab` tokens exist (vocab >= 257).
    pub fn train(text: &str, vocab: usize) -> Self {
        assert!(vocab > 256, "BPE vocab must exceed 256 byte tokens");
        let mut ids: Vec<u32> = text.bytes().map(u32::from).collect();
        let mut merges = Vec::new();
        let mut next_id = 256u32;
        while (next_id as usize) < vocab && ids.len() >= 2 {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: max count, then smallest pair
            let best = counts
                .iter()
                .max_by_key(|(&pair, &c)| {
                    (c, std::cmp::Reverse((pair.0, pair.1)))
                })
                .map(|(&p, &c)| (p, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break;
            }
            merges.push(pair);
            ids = Self::apply_merge(&ids, pair, next_id);
            next_id += 1;
        }
        let rank = merges
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();
        BpeTokenizer { merges, rank, vocab }
    }

    fn apply_merge(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(ids.len());
        let mut i = 0;
        while i < ids.len() {
            if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                out.push(new_id);
                i += 2;
            } else {
                out.push(ids[i]);
                i += 1;
            }
        }
        out
    }

    pub fn n_merges(&self) -> usize {
        self.merges.len()
    }

    /// Expand one id to its byte sequence (recursing through merges).
    fn expand(&self, id: u32, out: &mut Vec<u8>) {
        if id < 256 {
            out.push(id as u8);
        } else {
            let (l, r) = self.merges[(id - 256) as usize];
            self.expand(l, out);
            self.expand(r, out);
        }
    }
}

impl Tokenizer for BpeTokenizer {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn encode(&self, text: &str) -> Vec<i32> {
        let mut ids: Vec<u32> = text.bytes().map(u32::from).collect();
        // iteratively apply lowest-rank available merge (standard BPE encode)
        loop {
            let mut best: Option<(u32, usize)> = None; // (rank, pos)
            for (i, w) in ids.windows(2).enumerate() {
                if let Some(&r) = self.rank.get(&(w[0], w[1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((r, _)) = best else { break };
            let pair = self.merges[r as usize];
            ids = Self::apply_merge(&ids, pair, 256 + r);
        }
        ids.into_iter().map(|x| x as i32).collect()
    }

    fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        // ids past the learned merges (vocab was not filled) are skipped
        for &id in ids {
            if id >= 0 && (id as usize) < 256 + self.n_merges() {
                self.expand(id as u32, &mut bytes);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_tokenizer_roundtrip_range() {
        let t = ByteTokenizer::new(256);
        let ids = t.encode("hello ☃");
        assert_eq!(ids.len(), "hello ☃".len());
        assert!(ids.iter().all(|&i| (0..256).contains(&i)));
    }

    #[test]
    fn byte_tokenizer_clamps_small_vocab() {
        let t = ByteTokenizer::new(64);
        assert!(t.encode("\u{ff}").iter().all(|&i| i < 64));
    }

    #[test]
    fn bpe_learns_frequent_pairs() {
        let text = "ababababab cdcdcdcd ababab";
        let t = BpeTokenizer::train(text, 260);
        assert!(t.n_merges() > 0);
        let ids = t.encode("abab");
        assert!(ids.len() < 4, "merge not applied: {ids:?}");
    }

    #[test]
    fn bpe_encode_is_deterministic_and_compresses() {
        let text: String = "the quick brown fox jumps over the lazy dog. "
            .repeat(50);
        let t = BpeTokenizer::train(&text, 300);
        let a = t.encode(&text);
        let b = t.encode(&text);
        assert_eq!(a, b);
        assert!(a.len() < text.len(), "{} !< {}", a.len(), text.len());
        assert!(a.iter().all(|&i| (i as usize) < t.vocab_size()));
    }

    #[test]
    fn bpe_handles_unseen_bytes() {
        let t = BpeTokenizer::train("aaaa bbbb", 258);
        let ids = t.encode("zzzz");
        assert_eq!(ids, vec![b'z' as i32; 4]);
    }

    #[test]
    fn byte_decode_roundtrips() {
        let t = ByteTokenizer::new(256);
        let text = "hello, generation!";
        assert_eq!(t.decode(&t.encode(text)), text);
        // ids with no byte identity are skipped, not wrapped
        let wide = ByteTokenizer::new(512);
        assert_eq!(wide.decode(&[300, b'A' as i32, -1]), "A");
    }

    #[test]
    fn bpe_decode_roundtrips_through_merges() {
        let text: String = "the quick brown fox jumps over the lazy dog. "
            .repeat(30);
        let t = BpeTokenizer::train(&text, 300);
        let ids = t.encode(&text);
        assert!(ids.len() < text.len());
        assert_eq!(t.decode(&ids), text);
        // out-of-range ids are skipped, not panicked on
        assert_eq!(t.decode(&[-1, 30_000, b'a' as i32]), "a");
    }
}
