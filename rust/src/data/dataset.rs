//! Batching: turn token streams into `[batch, seq+1]` i32 training batches
//! with deterministic per-worker sharding (the data-parallel contract).

use super::synth::{CorpusGen, SynthConfig};

/// Anything that can produce an endless token stream.
pub trait TokenSource: Send {
    fn fill(&mut self, buf: &mut [i32]);
}

impl TokenSource for CorpusGen {
    fn fill(&mut self, buf: &mut [i32]) {
        CorpusGen::fill(self, buf)
    }
}

/// Cyclic reader over a fixed token buffer (for text-file corpora).
pub struct CyclicSource {
    tokens: Vec<i32>,
    pos: usize,
}

impl CyclicSource {
    pub fn new(tokens: Vec<i32>, start: usize) -> Self {
        assert!(!tokens.is_empty());
        let pos = start % tokens.len();
        CyclicSource { tokens, pos }
    }
}

impl TokenSource for CyclicSource {
    fn fill(&mut self, buf: &mut [i32]) {
        for b in buf.iter_mut() {
            *b = self.tokens[self.pos];
            self.pos = (self.pos + 1) % self.tokens.len();
        }
    }
}

/// A batch of training windows: `batch` rows of `seq + 1` tokens
/// (inputs = `[:, :-1]`, targets = `[:, 1:]`, split inside the HLO).
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq_plus_1: usize,
    pub tokens: Vec<i32>,
}

/// Iterator of batches over a token source.
pub struct BatchIter<S: TokenSource> {
    source: S,
    batch: usize,
    seq_plus_1: usize,
}

impl<S: TokenSource> BatchIter<S> {
    pub fn new(source: S, batch: usize, seq: usize) -> Self {
        BatchIter { source, batch, seq_plus_1: seq + 1 }
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = vec![0i32; self.batch * self.seq_plus_1];
        self.source.fill(&mut tokens);
        Batch { batch: self.batch, seq_plus_1: self.seq_plus_1, tokens }
    }
}

/// Convenience: a sharded synthetic-corpus batch iterator for one worker.
pub fn synth_batches(vocab: usize, seed: u64, shard: u64, batch: usize,
                     seq: usize) -> BatchIter<CorpusGen> {
    let gen = CorpusGen::new(SynthConfig::for_vocab(vocab), seed, shard);
    BatchIter::new(gen, batch, seq)
}

/// A fixed evaluation set: `n_batches` pre-drawn batches from a held-out
/// shard, reused at every evaluation so losses are comparable across steps
/// (paper: "evaluation of validation loss is performed on 10M tokens").
pub struct EvalSet {
    pub batches: Vec<Batch>,
}

impl EvalSet {
    pub fn synth(vocab: usize, seed: u64, batch: usize, seq: usize,
                 n_batches: usize) -> Self {
        // Shard u64::MAX is reserved for eval and never used for training.
        let mut it = synth_batches(vocab, seed, u64::MAX, batch, seq);
        let batches = (0..n_batches).map(|_| it.next_batch()).collect();
        EvalSet { batches }
    }

    pub fn n_tokens(&self) -> usize {
        self.batches
            .iter()
            .map(|b| b.batch * (b.seq_plus_1 - 1))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut it = synth_batches(256, 1, 0, 4, 32);
        let b = it.next_batch();
        assert_eq!(b.tokens.len(), 4 * 33);
        assert_eq!((b.batch, b.seq_plus_1), (4, 33));
        assert!(b.tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn batches_advance() {
        let mut it = synth_batches(256, 1, 0, 2, 16);
        let a = it.next_batch();
        let b = it.next_batch();
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn shards_differ_workers_reproducible() {
        let mk = |shard| {
            let mut it = synth_batches(512, 7, shard, 2, 16);
            it.next_batch().tokens
        };
        assert_eq!(mk(0), mk(0));
        assert_ne!(mk(0), mk(1));
    }

    #[test]
    fn cyclic_source_wraps() {
        let mut s = CyclicSource::new(vec![1, 2, 3], 0);
        let mut buf = [0i32; 7];
        s.fill(&mut buf);
        assert_eq!(buf, [1, 2, 3, 1, 2, 3, 1]);
    }

    #[test]
    fn eval_set_fixed() {
        let a = EvalSet::synth(256, 3, 2, 16, 3);
        let b = EvalSet::synth(256, 3, 2, 16, 3);
        assert_eq!(a.batches.len(), 3);
        assert_eq!(a.n_tokens(), 3 * 2 * 16);
        for (x, y) in a.batches.iter().zip(&b.batches) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
