//! Data substrate: synthetic pre-training corpus, tokenizer, batching,
//! and the GLUE-analog downstream task suite.
//!
//! The paper pre-trains on C4; this environment has no large corpus, so
//! `synth.rs` generates a structured synthetic language whose learnability
//! profile exercises the same distinction the paper measures (full-rank vs
//! rank-limited updates).

pub mod dataset;
pub mod synth;
pub mod tasks;
pub mod tokenizer;
