//! # SwitchLoRA — switched low-rank adaptation pre-training system
//!
//! A production-grade reproduction of *“SwitchLoRA: Switched Low-Rank
//! Adaptation Can Learn Full-Rank Information”* (2024).  The crate is the
//! whole system: training orchestration, the switching algorithm (paper
//! Alg. 1/2), optimizer-state resets and freezes, candidate-vector
//! management with offload accounting, a simulated data-parallel runtime
//! with ring all-reduce, baselines (full-rank, LoRA, ReLoRA, GaLore),
//! evaluation, resumable checkpointing, metrics, the CLI, an
//! inference subsystem (`infer`): KV-cached autoregressive generation
//! with adapter merging and batched decode, and a serving subsystem
//! (`serve`): a continuous-batching HTTP model server that multiplexes
//! named LoRA adapters over ONE shared (quantized) frozen base.
//!
//! Training methods are first-class plugins ([`methods`]): the trainer
//! drives only the [`methods::TrainingMethod`] trait, and every method —
//! the paper's SwitchLoRA, the baselines, the composable warm-start
//! wrapper and the PreLoRA-style layerwise hybrid — registers by name.
//! See the README's "Adding a training method" walkthrough.
//!
//! Model execution is pluggable (`runtime::Engine`):
//!
//! * **native** (default) — a pure-Rust implementation of the LLaMA-lite
//!   decoder with LoRA adapters and a hand-written backward pass
//!   (`runtime/native.rs`).  No Python, XLA library or AOT artifacts are
//!   needed; `cargo test` trains every method end-to-end on any machine.
//! * **pjrt** (`--features pjrt`) — the original AOT path: JAX + Pallas
//!   kernels (`python/compile/`) lowered to HLO text, loaded through the
//!   PJRT C API (`xla` crate).  Python never runs on the training path.
//!
//! Both backends consume the same manifest-driven parameter layout
//! (`model/layout.rs`), either parsed from `manifest.json` artifacts or
//! synthesized in-process from the builtin configs, so the coordinator is
//! backend-agnostic.
//!
//! All hot-path math — training fwd/bwd, KV-cached decode, GaLore's
//! projections, the Jacobi SVD sweeps — runs on one shared, cache-blocked,
//! multi-threaded kernel layer ([`kernels`]): a persistent std-only
//! thread pool (`--threads N` / `SWITCHLORA_THREADS`, default = detected
//! parallelism) whose kernels are bitwise deterministic at any thread
//! count, and which also fans data-parallel workers out onto real OS
//! threads so `--workers W` scales wall-clock.
//!
//! See the top-level `README.md` for backend selection, the experiment
//! drivers under `examples/`, and `ROADMAP.md` for where this is headed.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod infer;
pub mod kernels;
pub mod methods;
pub mod model;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod switchlora;
pub mod tensor;
pub mod util;
