//! # SwitchLoRA — switched low-rank adaptation pre-training system
//!
//! A production-grade reproduction of *“SwitchLoRA: Switched Low-Rank
//! Adaptation Can Learn Full-Rank Information”* (2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 1 (Pallas)** — tiled matmul / fused LoRA-linear / fused AdamW
//!   kernels (`python/compile/kernels/`), AOT-lowered to HLO text.
//! * **Layer 2 (JAX)** — LLaMA-family decoder with LoRA adapters
//!   (`python/compile/model.py`), lowered per variant by
//!   `python/compile/aot.py`.
//! * **Layer 3 (this crate)** — the coordinator: training orchestration, the
//!   switching algorithm (paper Alg. 1/2), optimizer-state resets and
//!   freezes, candidate-vector management with offload accounting, a
//!   simulated data-parallel runtime with ring all-reduce, baselines
//!   (full-rank, LoRA, ReLoRA, GaLore), evaluation, checkpointing, metrics
//!   and the CLI.
//!
//! Python never runs on the training path: the binary loads the HLO
//! artifacts through the PJRT C API (`xla` crate) and drives everything
//! from Rust.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod switchlora;
pub mod tensor;
pub mod util;
