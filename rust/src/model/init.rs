//! Parameter initialization (paper Section 2.2 Eq. (3) / Appendix A).
//!
//! * `SwitchLora` — the paper's init: both A and B (and every candidate
//!   vector) drawn uniform with std from Eq. (3):
//!   `std[B] = (r/√(mn))^(1/4) · gain^(1/2)` and
//!   `std[A] = (√(mr)/(n√n))^(1/4) · gain^(1/2)`
//! * `LoraDefault` — Hu et al. 2022: A Kaiming-uniform, B = 0 (the Figure 9
//!   ablation baseline).
//!
//! Base weights / embeddings / heads use N(0, 0.02²) (the small-LLaMA
//! convention the paper inherits from ReLoRA); norms start at 1.

use std::collections::HashMap;

use anyhow::Result;

use super::layout::{LinearMeta, Manifest, ParamStore, Role, Variant};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitMode {
    SwitchLora,
    LoraDefault,
}

pub const BASE_STD: f32 = 0.02;

/// Eq. (3) standard deviations: returns (std_B, std_A) for a linear with
/// out-dim `m`, in-dim `n`, LoRA rank `r`.
pub fn switchlora_stds(m: usize, n: usize, r: usize, gain: f64)
    -> (f64, f64) {
    let (m, n, r) = (m as f64, n as f64, r as f64);
    let std_b = (r / (m * n).sqrt()).powf(0.25) * gain.sqrt();
    let std_a = ((m * r).sqrt() / (n * n.sqrt())).powf(0.25) * gain.sqrt();
    (std_b, std_a)
}

/// Uniform(-lim, lim) has std lim/√3; invert to hit a target std.
fn uniform_lim_for_std(std: f64) -> f32 {
    (std * 3.0_f64.sqrt()) as f32
}

fn fill_uniform(buf: &mut [f32], lim: f32, rng: &mut Rng) {
    for x in buf.iter_mut() {
        *x = rng.uniform_range(-lim, lim);
    }
}

fn fill_normal(buf: &mut [f32], std: f32, rng: &mut Rng) {
    for x in buf.iter_mut() {
        *x = rng.normal_f32(0.0, std);
    }
}

/// Map each LoRA param name to its linear's (m, n).
pub fn lora_dims(linears: &[LinearMeta]) -> HashMap<String, (usize, usize)> {
    let mut map = HashMap::new();
    for li in linears {
        map.insert(li.a.clone(), (li.m, li.n));
        map.insert(li.b.clone(), (li.m, li.n));
    }
    map
}

/// Initialize every parameter in the store.
pub fn init_store(store: &mut ParamStore, linears: &[LinearMeta], rank: usize,
                  mode: InitMode, rng: &mut Rng) {
    let dims = lora_dims(linears);
    let metas: Vec<_> = store.layout.params.clone();
    for p in &metas {
        let buf = &mut store.data[p.offset..p.offset + p.numel];
        match p.role {
            Role::Norm => buf.fill(1.0),
            Role::Embed | Role::Head | Role::ClsHead | Role::Base => {
                fill_normal(buf, BASE_STD, rng);
            }
            Role::LoraA => {
                let (m, n) = dims[&p.name];
                match mode {
                    InitMode::SwitchLora => {
                        let (_, std_a) = switchlora_stds(m, n, rank, 1.0);
                        fill_uniform(buf, uniform_lim_for_std(std_a), rng);
                    }
                    InitMode::LoraDefault => {
                        // Kaiming-uniform with fan_in = n
                        let lim = (6.0 / n as f64).sqrt() as f32;
                        fill_uniform(buf, lim, rng);
                    }
                }
            }
            Role::LoraB => {
                let (m, n) = dims[&p.name];
                match mode {
                    InitMode::SwitchLora => {
                        let (std_b, _) = switchlora_stds(m, n, rank, 1.0);
                        fill_uniform(buf, uniform_lim_for_std(std_b), rng);
                    }
                    InitMode::LoraDefault => buf.fill(0.0),
                }
            }
        }
    }
}

/// Fresh store for one variant of a manifest, seeded with the standard
/// SwitchLoRA init — the shared setup of the generate CLI, examples,
/// benches and tests.
pub fn seeded_store(manifest: &Manifest, variant: Variant, seed: u64)
    -> Result<ParamStore> {
    let layout =
        std::sync::Arc::new(manifest.layout(variant)?.clone());
    let mut store = ParamStore::zeros(layout);
    let mut rng = Rng::new(seed);
    init_store(&mut store, &manifest.linears, manifest.config.rank,
               InitMode::SwitchLora, &mut rng);
    Ok(store)
}

/// Copy shared parameters between two stores by name (e.g. pre-trained LoRA
/// store → full/cls store for fine-tuning, after merging adapters).
pub fn copy_shared(src: &ParamStore, dst: &mut ParamStore) -> usize {
    let mut copied = 0;
    let names: Vec<String> =
        dst.layout.params.iter().map(|p| p.name.clone()).collect();
    for name in names {
        if let (Ok(s), Ok(_)) = (src.slice(&name), dst.layout.meta(&name)) {
            let s = s.to_vec();
            let d = dst.slice_mut(&name).unwrap();
            if s.len() == d.len() {
                d.copy_from_slice(&s);
                copied += 1;
            }
        }
    }
    copied
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta};
    use std::sync::Arc;

    fn toy() -> (ParamStore, Vec<LinearMeta>) {
        let layout = Layout::from_metas(vec![
            ParamMeta { name: "n0".into(), shape: vec![8], role: Role::Norm,
                        trainable: true, numel: 8, offset: 0,
                        t_offset: None },
            ParamMeta { name: "w".into(), shape: vec![32, 16],
                        role: Role::Base, trainable: false, numel: 512,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w.a".into(), shape: vec![4, 16],
                        role: Role::LoraA, trainable: true, numel: 64,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w.b".into(), shape: vec![32, 4],
                        role: Role::LoraB, trainable: true, numel: 128,
                        offset: 0, t_offset: None },
        ]);
        let store = ParamStore::zeros(Arc::new(layout));
        let linears = vec![LinearMeta {
            name: "w".into(), a: "w.a".into(), b: "w.b".into(), m: 32, n: 16,
        }];
        (store, linears)
    }

    fn std_of(xs: &[f32]) -> f64 {
        let n = xs.len() as f64;
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
        (xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n)
            .sqrt()
    }

    #[test]
    fn switchlora_init_hits_eq3_stds() {
        let (mut s, lins) = toy();
        let mut rng = Rng::new(0);
        init_store(&mut s, &lins, 4, InitMode::SwitchLora, &mut rng);
        let (std_b, std_a) = switchlora_stds(32, 16, 4, 1.0);
        assert!((std_of(s.slice("w.a").unwrap()) - std_a).abs() / std_a < 0.3);
        assert!((std_of(s.slice("w.b").unwrap()) - std_b).abs() / std_b < 0.3);
        assert!(s.slice("n0").unwrap().iter().all(|&x| x == 1.0));
        assert!((std_of(s.slice("w").unwrap()) - 0.02).abs() < 0.01);
    }

    #[test]
    fn lora_default_has_zero_b() {
        let (mut s, lins) = toy();
        let mut rng = Rng::new(1);
        init_store(&mut s, &lins, 4, InitMode::LoraDefault, &mut rng);
        assert!(s.slice("w.b").unwrap().iter().all(|&x| x == 0.0));
        assert!(std_of(s.slice("w.a").unwrap()) > 0.0);
    }

    #[test]
    fn stds_formula_spot_check() {
        let (std_b, std_a) = switchlora_stds(64, 128, 16, 1.0);
        let want_b = (16.0 / (64.0f64 * 128.0).sqrt()).powf(0.25);
        let want_a =
            ((64.0f64 * 16.0).sqrt() / (128.0 * 128.0f64.sqrt())).powf(0.25);
        assert!((std_b - want_b).abs() < 1e-12);
        assert!((std_a - want_a).abs() < 1e-12);
    }

    #[test]
    fn copy_shared_by_name() {
        let (mut a, lins) = toy();
        let mut rng = Rng::new(2);
        init_store(&mut a, &lins, 4, InitMode::SwitchLora, &mut rng);
        let (mut b, _) = toy();
        let n = copy_shared(&a, &mut b);
        assert_eq!(n, 4);
        assert_eq!(a.slice("w").unwrap(), b.slice("w").unwrap());
    }
}
