//! Manifest-driven parameter layout: the Python↔Rust contract.
//!
//! `aot.py` serializes `model.param_spec(...)` into `manifest.json`; this
//! module parses it into a `Layout` (ordered parameter metadata with flat
//! offsets) and a `ParamStore` (one contiguous f32 buffer holding every
//! parameter).  The trainable subset additionally gets a second, packed
//! flat addressing (`t_offset`) used by the fused Adam executable and the
//! gradient all-reduce.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::config::ModelConfig;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Embed,
    Norm,
    Base,
    LoraA,
    LoraB,
    Head,
    ClsHead,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "embed" => Role::Embed,
            "norm" => Role::Norm,
            "base" => Role::Base,
            "lora_a" => Role::LoraA,
            "lora_b" => Role::LoraB,
            "head" => Role::Head,
            "cls_head" => Role::ClsHead,
            _ => bail!("unknown role {s:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub role: Role,
    pub trainable: bool,
    pub numel: usize,
    /// offset into the full flat store
    pub offset: usize,
    /// offset into the packed trainable vector (None if frozen)
    pub t_offset: Option<usize>,
}

impl ParamMeta {
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        if self.shape.len() > 1 { self.shape[1] } else { 1 }
    }
}

/// One LoRA-adapted linear (drives the switch algorithm).
#[derive(Clone, Debug)]
pub struct LinearMeta {
    pub name: String,
    pub a: String,
    pub b: String,
    /// out dim (rows of W and of B)
    pub m: usize,
    /// in dim (cols of W, cols of A)
    pub n: usize,
}

/// Ordered parameter layout with flat offsets.
#[derive(Clone, Debug)]
pub struct Layout {
    pub params: Vec<ParamMeta>,
    pub by_name: HashMap<String, usize>,
    pub total: usize,
    pub n_trainable: usize,
}

impl Layout {
    pub fn from_metas(mut params: Vec<ParamMeta>) -> Layout {
        // Trainable parameters are packed FIRST in the store, in layout
        // order, so that the store prefix [0, n_trainable) *is* the packed
        // trainable vector (offset == t_offset) — gather/scatter for the
        // fused Adam kernel and the gradient all-reduce become single
        // memcpys (§Perf L3).  Frozen parameters follow.
        let mut t_offset = 0;
        for p in params.iter_mut() {
            if p.trainable {
                p.offset = t_offset;
                p.t_offset = Some(t_offset);
                t_offset += p.numel;
            }
        }
        let n_trainable = t_offset;
        let mut offset = n_trainable;
        for p in params.iter_mut() {
            if !p.trainable {
                p.offset = offset;
                p.t_offset = None;
                offset += p.numel;
            }
        }
        let by_name = params
            .iter()
            .enumerate()
            .map(|(i, p)| (p.name.clone(), i))
            .collect();
        Layout { params, by_name, total: offset, n_trainable }
    }

    fn from_json(arr: &[Json]) -> Result<Layout> {
        let mut metas = Vec::with_capacity(arr.len());
        for j in arr {
            let shape: Vec<usize> = j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?;
            metas.push(ParamMeta {
                name: j.get("name")?.as_str()?.to_string(),
                role: Role::parse(j.get("role")?.as_str()?)?,
                trainable: j.get("trainable")?.as_bool()?,
                numel: j.get("numel")?.as_usize()?,
                shape,
                offset: 0,
                t_offset: None,
            });
        }
        for m in &metas {
            let numel: usize = m.shape.iter().product();
            if numel != m.numel {
                bail!("param {}: numel {} != shape product {numel}",
                      m.name, m.numel);
            }
        }
        Ok(Layout::from_metas(metas))
    }

    pub fn meta(&self, name: &str) -> Result<&ParamMeta> {
        self.by_name
            .get(name)
            .map(|&i| &self.params[i])
            .ok_or_else(|| anyhow!("unknown param {name:?}"))
    }

    /// Trainable params in order (the grad-output order of fwdbwd HLO).
    pub fn trainable(&self) -> impl Iterator<Item = &ParamMeta> {
        self.params.iter().filter(|p| p.trainable)
    }
}

/// Which model variant a layout/artifact belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Lora,
    Full,
    Cls,
}

impl Variant {
    pub fn key(&self) -> &'static str {
        match self {
            Variant::Lora => "lora",
            Variant::Full => "full",
            Variant::Cls => "cls",
        }
    }
}

/// Parsed `manifest.json` for one AOT'd spec.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub variants: Vec<String>,
    pub lora: Layout,
    pub full: Layout,
    pub cls: Option<Layout>,
    pub linears: Vec<LinearMeta>,
    pub adam_padded_lora: usize,
    pub adam_padded_full: usize,
    pub adam_padded_cls: Option<usize>,
}

/// Fused-Adam buffer padding (mirrors `kernels/adam.py::padded_size`,
/// BLOCK = 8192).  Must match exactly: a builtin manifest and an on-disk
/// one for the same spec have to agree on buffer sizes, or optimizer
/// state checkpointed under one fails `adam_step`'s padding check under
/// the other.  Public so methods that rewrite layouts (the layerwise
/// hybrid) can recompute the padding for their trainable count.
pub fn adam_pad(n: usize) -> usize {
    n.div_ceil(8192) * 8192
}

/// Build the canonical ordered parameter list for one variant, mirroring
/// `python/compile/model.py::param_spec` field-for-field.  This is what
/// lets the native backend run with no manifest.json on disk: both sides
/// derive the same layout from the same config.
fn spec_params(c: &ModelConfig, lora: bool, cls: bool) -> Vec<ParamMeta> {
    let meta = |name: String, shape: Vec<usize>, role, trainable| {
        let numel = shape.iter().product();
        ParamMeta { name, shape, role, trainable, numel, offset: 0,
                    t_offset: None }
    };
    let (h, ff, r) = (c.hidden, c.ff, c.rank);
    let mut out = vec![meta("embed".into(), vec![c.vocab, h], Role::Embed,
                           true)];
    let push_linear = |out: &mut Vec<ParamMeta>, name: String, m: usize,
                       n: usize| {
        out.push(meta(name.clone(), vec![m, n], Role::Base, !lora));
        if lora {
            out.push(meta(format!("{name}.a"), vec![r, n], Role::LoraA,
                          true));
            out.push(meta(format!("{name}.b"), vec![m, r], Role::LoraB,
                          true));
        }
    };
    for i in 0..c.layers {
        out.push(meta(format!("l{i}.attn_norm"), vec![h], Role::Norm, true));
        for w in ["wq", "wk", "wv", "wo"] {
            push_linear(&mut out, format!("l{i}.{w}"), h, h);
        }
        out.push(meta(format!("l{i}.mlp_norm"), vec![h], Role::Norm, true));
        push_linear(&mut out, format!("l{i}.w_gate"), ff, h);
        push_linear(&mut out, format!("l{i}.w_up"), ff, h);
        push_linear(&mut out, format!("l{i}.w_down"), h, ff);
    }
    out.push(meta("final_norm".into(), vec![h], Role::Norm, true));
    if cls {
        out.push(meta("cls_head".into(), vec![c.n_cls, h], Role::ClsHead,
                      true));
    } else {
        out.push(meta("lm_head".into(), vec![c.vocab, h], Role::Head, true));
    }
    out
}

fn spec_linears(c: &ModelConfig) -> Vec<LinearMeta> {
    let mut out = Vec::with_capacity(7 * c.layers);
    for i in 0..c.layers {
        for (w, m, n) in [("wq", c.hidden, c.hidden),
                          ("wk", c.hidden, c.hidden),
                          ("wv", c.hidden, c.hidden),
                          ("wo", c.hidden, c.hidden),
                          ("w_gate", c.ff, c.hidden),
                          ("w_up", c.ff, c.hidden),
                          ("w_down", c.hidden, c.ff)] {
            let name = format!("l{i}.{w}");
            out.push(LinearMeta {
                a: format!("{name}.a"),
                b: format!("{name}.b"),
                name,
                m,
                n,
            });
        }
    }
    out
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))
            .with_context(|| format!("manifest in {}", dir.display()))?;
        let config = ModelConfig::from_json(j.get("config")?)?;
        let lora = Layout::from_json(j.get("params_lora")?.as_arr()?)?;
        let full = Layout::from_json(j.get("params_full")?.as_arr()?)?;
        let cls = match j.opt("params_cls") {
            Some(arr) => Some(Layout::from_json(arr.as_arr()?)?),
            None => None,
        };
        let mut linears = Vec::new();
        for lj in j.get("linears")?.as_arr()? {
            linears.push(LinearMeta {
                name: lj.get("name")?.as_str()?.to_string(),
                a: lj.get("a")?.as_str()?.to_string(),
                b: lj.get("b")?.as_str()?.to_string(),
                m: lj.get("m")?.as_usize()?,
                n: lj.get("n")?.as_usize()?,
            });
        }
        let variants = j
            .get("variants")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            config,
            variants,
            lora,
            full,
            cls,
            linears,
            adam_padded_lora: j.get("adam_padded_lora")?.as_usize()?,
            adam_padded_full: j.get("adam_padded_full")?.as_usize()?,
            adam_padded_cls: match j.opt("adam_padded_cls") {
                Some(v) => Some(v.as_usize()?),
                None => None,
            },
        })
    }

    /// Synthesize a manifest directly from a model config — the native
    /// backend's path when no AOT artifacts exist.  Layouts, linears and
    /// padding match what `aot.py` would have serialized for this config.
    pub fn synthesize(config: ModelConfig) -> Manifest {
        let lora = Layout::from_metas(spec_params(&config, true, false));
        let full = Layout::from_metas(spec_params(&config, false, false));
        let cls = Layout::from_metas(spec_params(&config, false, true));
        let linears = spec_linears(&config);
        let variants = ["lora_fwdbwd", "lora_eval", "full_fwdbwd",
                        "full_eval", "cls_fwdbwd", "cls_eval"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        Manifest {
            dir: PathBuf::from("<builtin>").join(&config.name),
            variants,
            adam_padded_lora: adam_pad(lora.n_trainable),
            adam_padded_full: adam_pad(full.n_trainable),
            adam_padded_cls: Some(adam_pad(cls.n_trainable)),
            cls: Some(cls),
            lora,
            full,
            linears,
            config,
        }
    }

    /// The built-in (artifact-free) manifest for a spec name, accepting
    /// the same `name[_rR]` naming as the AOT pipeline.
    pub fn builtin(spec: &str) -> Result<Manifest> {
        let config = ModelConfig::builtin(spec).ok_or_else(|| {
            anyhow!("unknown spec {spec:?}: no artifacts and no builtin \
                     preset of that name")
        })?;
        Ok(Manifest::synthesize(config))
    }

    /// Load `artifacts_dir/spec/manifest.json` if it exists, otherwise
    /// fall back to the synthesized builtin manifest — the resolution
    /// order every entry point (trainer, CLI, examples, benches) uses.
    pub fn for_spec(artifacts_dir: &Path, spec: &str) -> Result<Manifest> {
        let dir = artifacts_dir.join(spec);
        if dir.join("manifest.json").exists() {
            Manifest::load(&dir)
        } else {
            Manifest::builtin(spec)
        }
    }

    pub fn layout(&self, v: Variant) -> Result<&Layout> {
        match v {
            Variant::Lora => Ok(&self.lora),
            Variant::Full => Ok(&self.full),
            Variant::Cls => self
                .cls
                .as_ref()
                .ok_or_else(|| anyhow!("manifest has no cls variant")),
        }
    }

    pub fn adam_padded(&self, v: Variant) -> Result<usize> {
        match v {
            Variant::Lora => Ok(self.adam_padded_lora),
            Variant::Full => Ok(self.adam_padded_full),
            Variant::Cls => self
                .adam_padded_cls
                .ok_or_else(|| anyhow!("manifest has no cls variant")),
        }
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Path of the shared fused-Adam artifact for a trainable size.
    pub fn adam_hlo_path(&self, padded: usize) -> PathBuf {
        self.dir
            .parent()
            .unwrap_or(&self.dir)
            .join(format!("adam_{padded}.hlo.txt"))
    }
}

/// One contiguous f32 buffer holding every parameter of a layout.
#[derive(Clone, Debug)]
pub struct ParamStore {
    pub layout: std::sync::Arc<Layout>,
    pub data: Vec<f32>,
}

impl ParamStore {
    pub fn zeros(layout: std::sync::Arc<Layout>) -> ParamStore {
        let data = vec![0.0; layout.total];
        ParamStore { layout, data }
    }

    pub fn slice(&self, name: &str) -> Result<&[f32]> {
        let m = self.layout.meta(name)?;
        Ok(&self.data[m.offset..m.offset + m.numel])
    }

    pub fn slice_mut(&mut self, name: &str) -> Result<&mut [f32]> {
        let m = self.layout.meta(name)?.clone();
        Ok(&mut self.data[m.offset..m.offset + m.numel])
    }

    /// Copy a parameter out as a Tensor (rank-analysis / checkpoints).
    pub fn tensor(&self, name: &str) -> Result<crate::tensor::Tensor> {
        let m = self.layout.meta(name)?;
        Ok(crate::tensor::Tensor::from_vec(
            m.rows(),
            m.cols(),
            self.slice(name)?.to_vec(),
        ))
    }

    /// Merge-aware view of one adapted linear: the `(A, B)` factor
    /// slices, or `None` when this store's layout carries no adapters
    /// for it (full/cls variants, or an already-exported merged store).
    pub fn lora_pair(&self, li: &LinearMeta) -> Option<(&[f32], &[f32])> {
        let a = self.layout.meta(&li.a).ok()?;
        let b = self.layout.meta(&li.b).ok()?;
        Some((
            &self.data[a.offset..a.offset + a.numel],
            &self.data[b.offset..b.offset + b.numel],
        ))
    }

    /// Gather the packed trainable vector (padded to `padded` with zeros).
    /// Because trainable params are packed first (offset == t_offset) this
    /// is a single memcpy of the store prefix.
    pub fn gather_trainable(&self, padded: usize) -> Vec<f32> {
        let n = self.layout.n_trainable;
        let mut out = vec![0.0; padded.max(n)];
        out[..n].copy_from_slice(&self.data[..n]);
        out
    }

    /// Scatter a packed trainable vector back into the store (single
    /// memcpy of the trainable prefix).
    pub fn scatter_trainable(&mut self, flat: &[f32]) {
        let n = self.layout.n_trainable;
        self.data[..n].copy_from_slice(&flat[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn toy_layout() -> Layout {
        Layout::from_metas(vec![
            ParamMeta { name: "w".into(), shape: vec![2, 3], role: Role::Base,
                        trainable: false, numel: 6, offset: 0,
                        t_offset: None },
            ParamMeta { name: "a".into(), shape: vec![1, 3],
                        role: Role::LoraA, trainable: true, numel: 3,
                        offset: 0, t_offset: None },
            ParamMeta { name: "b".into(), shape: vec![2, 1],
                        role: Role::LoraB, trainable: true, numel: 2,
                        offset: 0, t_offset: None },
        ])
    }

    #[test]
    fn offsets_trainable_first() {
        let l = toy_layout();
        assert_eq!(l.total, 11);
        assert_eq!(l.n_trainable, 5);
        // trainable packed first (offset == t_offset), frozen after
        assert_eq!(l.meta("a").unwrap().offset, 0);
        assert_eq!(l.meta("b").unwrap().offset, 3);
        assert_eq!(l.meta("w").unwrap().offset, 5);
        assert_eq!(l.meta("a").unwrap().t_offset, Some(0));
        assert_eq!(l.meta("b").unwrap().t_offset, Some(3));
        assert_eq!(l.meta("w").unwrap().t_offset, None);
        for p in l.trainable() {
            assert_eq!(p.offset, p.t_offset.unwrap());
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let l = Arc::new(toy_layout());
        let mut s = ParamStore::zeros(l);
        for (i, x) in s.data.iter_mut().enumerate() {
            *x = i as f32;
        }
        let flat = s.gather_trainable(8);
        assert_eq!(flat.len(), 8);
        assert_eq!(&flat[..5], &[0., 1., 2., 3., 4.]);
        assert_eq!(&flat[5..], &[0., 0., 0.]);
        let mut flat2 = flat.clone();
        for x in flat2.iter_mut() {
            *x += 100.0;
        }
        s.scatter_trainable(&flat2);
        assert_eq!(s.slice("a").unwrap(), &[100., 101., 102.]);
        assert_eq!(s.slice("b").unwrap(), &[103., 104.]);
        assert_eq!(s.slice("w").unwrap(), &[5., 6., 7., 8., 9., 10.]);
    }

    #[test]
    fn builtin_manifest_mirrors_python_spec() {
        let man = Manifest::builtin("tiny").unwrap();
        assert_eq!(man.config.name, "tiny");
        assert_eq!(man.linears.len(), 7 * man.config.layers);
        assert!(man.lora.n_trainable < man.full.n_trainable);
        assert!(man.adam_padded_lora >= man.lora.n_trainable);
        // same block size as kernels/adam.py::padded_size
        assert_eq!(man.adam_padded_lora % 8192, 0);
        // parameter ordering: embed first, then l0.attn_norm, l0.wq...
        assert_eq!(man.lora.params[0].name, "embed");
        assert_eq!(man.lora.params[1].name, "l0.attn_norm");
        assert_eq!(man.lora.params[2].name, "l0.wq");
        assert_eq!(man.lora.params[3].name, "l0.wq.a");
        assert_eq!(man.lora.params[4].name, "l0.wq.b");
        assert_eq!(man.full.params[2].name, "l0.wq");
        assert_eq!(man.full.params[3].name, "l0.wk");
        // roles/shapes per linear, both variants
        for li in &man.linears {
            let w = man.lora.meta(&li.name).unwrap();
            let a = man.lora.meta(&li.a).unwrap();
            let b = man.lora.meta(&li.b).unwrap();
            assert_eq!(w.shape, vec![li.m, li.n]);
            assert_eq!(a.shape, vec![man.config.rank, li.n]);
            assert_eq!(b.shape, vec![li.m, man.config.rank]);
            assert!(!w.trainable && a.trainable && b.trainable);
            assert!(man.full.meta(&li.name).unwrap().trainable);
            assert!(man.full.meta(&li.a).is_err());
        }
        // cls variant swaps the lm head for a class head
        let cls = man.cls.as_ref().unwrap();
        assert!(cls.meta("cls_head").is_ok());
        assert!(cls.meta("lm_head").is_err());
        assert!(man.full.meta("lm_head").is_ok());
        // rank-override spec
        let hr = Manifest::builtin("tiny_r32").unwrap();
        assert_eq!(hr.config.rank, 32);
        assert!(hr.lora.n_trainable > man.lora.n_trainable);
    }

    #[test]
    fn lora_pair_views_follow_the_layout() {
        let man = Manifest::builtin("tiny").unwrap();
        let li = &man.linears[0];
        assert!(man.lora.meta(&li.a).is_ok() && man.full.meta(&li.a).is_err());
        let store = ParamStore::zeros(Arc::new(man.lora.clone()));
        let (a, b) = store.lora_pair(li).unwrap();
        assert_eq!(a.len(), man.config.rank * li.n);
        assert_eq!(b.len(), li.m * man.config.rank);
        let full = ParamStore::zeros(Arc::new(man.full.clone()));
        assert!(full.lora_pair(li).is_none());
    }

    #[test]
    fn for_spec_falls_back_to_builtin() {
        let dir = std::env::temp_dir().join("switchlora_no_artifacts");
        let man = Manifest::for_spec(&dir, "tiny").unwrap();
        assert_eq!(man.config.name, "tiny");
        assert!(Manifest::for_spec(&dir, "not_a_spec").is_err());
    }

    #[test]
    fn load_real_manifest_if_built() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/tiny");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.config.name, "tiny");
        assert!(man.lora.n_trainable < man.full.n_trainable);
        assert_eq!(man.linears.len(), 7 * man.config.layers);
        assert!(man.adam_padded_lora >= man.lora.n_trainable);
        // every linear's params exist with consistent shapes
        for li in &man.linears {
            let w = man.lora.meta(&li.name).unwrap();
            let a = man.lora.meta(&li.a).unwrap();
            let b = man.lora.meta(&li.b).unwrap();
            assert_eq!(w.shape, vec![li.m, li.n]);
            assert_eq!(a.shape[1], li.n);
            assert_eq!(b.shape[0], li.m);
            assert!(!w.trainable && a.trainable && b.trainable);
        }
    }
}
