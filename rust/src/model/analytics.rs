//! Analytic parameter / memory / communication models — the machinery
//! behind the paper's Table 4, Table 5 and Appendices D/F.
//!
//! These are closed-form functions of a `ModelConfig`, evaluated against
//! the paper's exact architectures (`ModelConfig::paper_presets()`).  Unit
//! tests pin them to the paper's published numbers; `bench_tables` prints
//! the regenerated tables.

use super::config::ModelConfig;

/// Trainable parameters of the full-rank model (everything).
pub fn full_params(c: &ModelConfig) -> u64 {
    let (v, h, ff, l) = (c.vocab as u64, c.hidden as u64, c.ff as u64,
                         c.layers as u64);
    let embed = v * h;
    let head = v * h;
    let per_layer = 4 * h * h     // wq wk wv wo
        + 3 * h * ff              // gate, up, down
        + 2 * h;                  // two RMSNorm gains
    embed + head + l * per_layer + h // final norm
}

/// Trainable parameters under (Switch)LoRA with rank `r`:
/// embeddings + norms + head stay trainable; every linear contributes
/// r·(m+n) adapter parameters while its base W is frozen.
pub fn lora_trainable_params(c: &ModelConfig, r: u64) -> u64 {
    let (v, h, ff, l) = (c.vocab as u64, c.hidden as u64, c.ff as u64,
                         c.layers as u64);
    let embed = v * h;
    let head = v * h;
    let norms = l * 2 * h + h;
    // per layer: 4 h×h linears and gate/up (ff×h), down (h×ff)
    let adapters_per_layer = 4 * r * (h + h) + 2 * r * (ff + h)
        + r * (h + ff);
    embed + head + norms + l * adapters_per_layer
}

/// Bytes moved per training step per worker by data-parallel gradient
/// synchronization (Appendix F): ring all-reduce moves ≈ 2·(w-1)/w of the
/// gradient bytes per worker; gradients are bf16 (2 bytes).
pub fn dp_comm_bytes_per_step(trainable: u64, workers: u64) -> u64 {
    if workers <= 1 {
        return 0;
    }
    let grad_bytes = 2 * trainable;
    2 * grad_bytes * (workers - 1) / workers
}

/// Communication saving of (Switch)LoRA vs full-rank (the abstract's
/// "cutting communication overhead by 54%" claim).
pub fn comm_saving_fraction(c: &ModelConfig, r: u64) -> f64 {
    1.0 - lora_trainable_params(c, r) as f64 / full_params(c) as f64
}

/// GPU memory model (Table 5 shape), bytes per GPU:
///   weights 2Ψ_total (bf16) + grads 2Ψ_train
///   + Adam states 12Ψ_train / world  (fp32 m, v + fp32 master weights,
///     sharded ZeRO-style across the `world` GPUs — Table 5 uses 4 A800s)
///   + activations ≈ C_ACT · bs · seq · hidden · layers · 2 bytes.
/// C_ACT=33.2 calibrated once against the paper's full-rank 1.3B/bs=16 row;
/// every other row/column is then prediction, not fit.
pub const C_ACT: f64 = 33.2;

#[derive(Clone, Copy, Debug)]
pub struct MemoryEstimate {
    pub weights: u64,
    pub grads: u64,
    pub optimizer: u64,
    pub activations: u64,
}

impl MemoryEstimate {
    pub fn total(&self) -> u64 {
        self.weights + self.grads + self.optimizer + self.activations
    }
}

pub fn memory_model(c: &ModelConfig, trainable: u64, bs_per_gpu: u64,
                    world: u64) -> MemoryEstimate {
    let total = full_params(c);
    let act = (C_ACT
        * bs_per_gpu as f64
        * c.seq as f64
        * c.hidden as f64
        * c.layers as f64
        * 2.0) as u64;
    MemoryEstimate {
        weights: 2 * total,
        grads: 2 * trainable,
        optimizer: 12 * trainable / world.max(1),
        activations: act,
    }
}

/// Appendix D: candidate-vector bytes offloaded to CPU per step,
/// `switch_freq × (r / hidden) × Ψ_total × 2 bytes`.
pub fn offload_bytes_per_step(c: &ModelConfig, r: u64, switch_freq: f64)
    -> u64 {
    (switch_freq * (r as f64 / c.hidden as f64) * full_params(c) as f64
        * 2.0) as u64
}

/// Total candidate-store bytes (both C(B) and C(A^T) for every linear,
/// min(m,n) vectors each, bf16) — what actually sits in CPU memory.
pub fn candidate_store_bytes(c: &ModelConfig) -> u64 {
    let (h, ff, l) = (c.hidden as u64, c.ff as u64, c.layers as u64);
    let per_linear = |m: u64, n: u64| m.min(n) * (m + n) * 2;
    l * (4 * per_linear(h, h) + 2 * per_linear(ff, h) + per_linear(h, ff))
}

/// Step-time model (Table 5 shape): compute term ∝ fwd+bwd FLOPs (identical
/// across methods) + optimizer term ∝ trainable + DP communication term.
/// Returns relative units; `bench_tables` reports ratios, which is the
/// paper-reproducible quantity on different hardware.
pub fn step_time_model(c: &ModelConfig, trainable: u64, workers: u64,
                       interconnect_gbps: f64) -> f64 {
    let flops = 6.0
        * full_params(c) as f64
        * (c.batch as f64 * c.seq as f64); // fwd+bwd ≈ 6·N per token
    let compute = flops / 300e12; // A800-class bf16 sustained
    let opt = trainable as f64 * 16.0 / 2e12; // 16B touched per element
    let comm = dp_comm_bytes_per_step(trainable, workers) as f64
        / (interconnect_gbps * 1e9 / 8.0);
    compute + opt + comm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn pct_diff(a: f64, b: f64) -> f64 {
        (a - b).abs() / b
    }

    #[test]
    fn table4_full_param_counts() {
        // Paper Table 4: 250M→247.5M, 350M→368.2M, 1.3B→1339.5M.
        let cases = [("p250m", 247.5e6), ("p350m", 368.2e6),
                     ("p1b", 1339.5e6)];
        for (name, want) in cases {
            let c = ModelConfig::paper_preset(name).unwrap();
            let got = full_params(&c) as f64;
            assert!(pct_diff(got, want) < 0.02,
                    "{name}: got {got:.1} want {want}");
        }
    }

    #[test]
    fn table4_lora_trainable_counts() {
        // Paper Table 4: 250M r=128 → 98.9M, r=256 → 148.4M;
        // 350M r=128 → 125.6M, r=256 → 185.4M; 1.3B r=256 → 370.7M,
        // r=512 → 609.7M.
        let cases = [
            ("p250m", 128, 98.9e6), ("p250m", 256, 148.4e6),
            ("p350m", 128, 125.6e6), ("p350m", 256, 185.4e6),
            ("p1b", 256, 370.7e6), ("p1b", 512, 609.7e6),
        ];
        for (name, r, want) in cases {
            let c = ModelConfig::paper_preset(name).unwrap();
            let got = lora_trainable_params(&c, r) as f64;
            assert!(pct_diff(got, want) < 0.03,
                    "{name} r={r}: got {:.1}M want {:.1}M",
                    got / 1e6, want / 1e6);
        }
    }

    #[test]
    fn table5_trainable_columns() {
        // Table 5: 1.3B full 1339M / lora 610M; 3B 2686M/1162M;
        // 7B 6739M/2822M (rank = hidden/4).
        let cases = [("p1b", 1339e6, 610e6), ("p3b", 2686e6, 1162e6),
                     ("p7b", 6739e6, 2822e6)];
        for (name, full_want, lora_want) in cases {
            let c = ModelConfig::paper_preset(name).unwrap();
            let r = (c.hidden / 4) as u64;
            assert!(pct_diff(full_params(&c) as f64, full_want) < 0.03,
                    "{name} full");
            assert!(
                pct_diff(lora_trainable_params(&c, r) as f64, lora_want)
                    < 0.06,
                "{name} lora: got {:.0}M want {:.0}M",
                lora_trainable_params(&c, r) as f64 / 1e6, lora_want / 1e6);
        }
    }

    #[test]
    fn abstract_comm_saving_54pct() {
        let c = ModelConfig::paper_preset("p1b").unwrap();
        let saving = comm_saving_fraction(&c, 512);
        assert!((saving - 0.54).abs() < 0.03, "saving {saving}");
    }

    #[test]
    fn table5_memory_shape() {
        // Full-rank 1.3B bs=16 world=4 → 36.1GB (calibration row);
        // LoRA r=512 → 31.8GB (prediction).  Accept 5% on prediction.
        let c = ModelConfig::paper_preset("p1b").unwrap();
        let full = memory_model(&c, full_params(&c), 16, 4).total() as f64;
        assert!(pct_diff(full, 36.1e9) < 0.05, "full {:.1}GB", full / 1e9);
        let lora =
            memory_model(&c, lora_trainable_params(&c, 512), 16, 4).total()
                as f64;
        assert!(pct_diff(lora, 31.8e9) < 0.05, "lora {:.1}GB", lora / 1e9);
        assert!(lora < full);
        // abstract: "memory usage by 13%" on 1.3B
        let saving = 1.0 - lora / full;
        assert!((saving - 0.13).abs() < 0.05, "mem saving {saving}");
    }

    #[test]
    fn table5_memory_gap_grows_with_size() {
        // Paper: the LoRA/full memory gap widens from 1.3B to 7B as the
        // per-GPU batch (and thus the activation share) shrinks.
        let save = |name: &str, bs: u64| {
            let c = ModelConfig::paper_preset(name).unwrap();
            let r = (c.hidden / 4) as u64;
            let f = memory_model(&c, full_params(&c), bs, 4).total() as f64;
            let l = memory_model(&c, lora_trainable_params(&c, r), bs, 4)
                .total() as f64;
            1.0 - l / f
        };
        let s1 = save("p1b", 16);
        let s3 = save("p3b", 4);
        let s7 = save("p7b", 1);
        assert!(s1 < s3 && s3 < s7, "{s1} {s3} {s7}");
        // paper 7B row: 1 - 47.3/78.0 = 0.39
        assert!((s7 - 0.39).abs() < 0.08, "7B saving {s7}");
    }

    #[test]
    fn appendix_d_offload_estimate() {
        // Paper: 1.3B, freq 1/40, r=512, h=2048 → ≈16.25MB per step.
        let c = ModelConfig::paper_preset("p1b").unwrap();
        let bytes = offload_bytes_per_step(&c, 512, 1.0 / 40.0) as f64;
        assert!(pct_diff(bytes, 16.25e6) < 0.05, "{:.2}MB", bytes / 1e6);
    }

    #[test]
    fn candidate_store_scales() {
        let c1 = ModelConfig::paper_preset("p1b").unwrap();
        let c7 = ModelConfig::paper_preset("p7b").unwrap();
        assert!(candidate_store_bytes(&c7) > candidate_store_bytes(&c1));
    }

    #[test]
    fn step_time_lora_not_slower() {
        let c = ModelConfig::paper_preset("p7b").unwrap();
        let full = step_time_model(&c, full_params(&c), 4, 64.0);
        let lora = step_time_model(
            &c, lora_trainable_params(&c, (c.hidden / 4) as u64), 4, 64.0);
        assert!(lora < full);
    }

    #[test]
    fn dp_comm_zero_for_single_worker() {
        assert_eq!(dp_comm_bytes_per_step(1_000_000, 1), 0);
        assert!(dp_comm_bytes_per_step(1_000_000, 4) > 0);
    }
}
