//! Model configuration: parsed from `manifest.json` (runnable configs) or
//! constructed from the paper's Table 1 / Table 9 presets (analytics only).

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub seq: usize,
    pub rank: usize,
    pub lora_alpha: f64,
    pub batch: usize,
    pub n_cls: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn lora_scale(&self) -> f64 {
        self.lora_alpha / self.rank as f64
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            hidden: j.get("hidden")?.as_usize()?,
            layers: j.get("layers")?.as_usize()?,
            heads: j.get("heads")?.as_usize()?,
            ff: j.get("ff")?.as_usize()?,
            seq: j.get("seq")?.as_usize()?,
            rank: j.get("rank")?.as_usize()?,
            lora_alpha: j.get("lora_alpha")?.as_f64()?,
            batch: j.get("batch")?.as_usize()?,
            n_cls: j.get("n_cls")?.as_usize()?,
        })
    }

    fn preset(name: &str, vocab: usize, hidden: usize, layers: usize,
              heads: usize, ff: usize, seq: usize, rank: usize,
              batch: usize) -> ModelConfig {
        ModelConfig {
            name: name.to_string(), vocab, hidden, layers, heads, ff, seq,
            rank, lora_alpha: rank as f64, batch, n_cls: 4,
        }
    }

    /// The paper's architectures (Table 1 + Table 9).  Never lowered to
    /// HLO here — they drive the analytic Tables 4/5 reproduction.
    pub fn paper_presets() -> Vec<ModelConfig> {
        vec![
            Self::preset("p130m", 32000, 768, 12, 12, 2048, 256, 128, 600),
            Self::preset("p250m", 32000, 768, 24, 16, 2560, 512, 128, 1152),
            Self::preset("p350m", 32000, 1024, 24, 16, 2736, 512, 128, 1152),
            Self::preset("p1b", 32000, 2048, 24, 32, 5461, 512, 512, 1536),
            Self::preset("p3b", 32000, 2560, 32, 32, 6826, 512, 640, 1536),
            Self::preset("p7b", 32000, 4096, 32, 32, 11008, 512, 1024, 1536),
        ]
    }

    pub fn paper_preset(name: &str) -> Option<ModelConfig> {
        Self::paper_presets().into_iter().find(|c| c.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_from_json() {
        let j = Json::parse(
            r#"{"name":"tiny","vocab":256,"hidden":64,"layers":2,
                "heads":4,"ff":128,"seq":64,"rank":16,"lora_alpha":16.0,
                "batch":8,"n_cls":4,"head_dim":16}"#).unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.hidden, 64);
        assert_eq!(c.head_dim(), 16);
        assert_eq!(c.lora_scale(), 1.0);
    }

    #[test]
    fn paper_presets_match_table1() {
        let p = ModelConfig::paper_preset("p1b").unwrap();
        assert_eq!((p.hidden, p.heads, p.layers, p.batch, p.seq),
                   (2048, 32, 24, 1536, 512));
        let p7 = ModelConfig::paper_preset("p7b").unwrap();
        assert_eq!((p7.hidden, p7.layers), (4096, 32));
    }
}
