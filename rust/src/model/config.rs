//! Model configuration: parsed from `manifest.json` (runnable configs) or
//! constructed from the paper's Table 1 / Table 9 presets (analytics only).

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ff: usize,
    pub seq: usize,
    pub rank: usize,
    pub lora_alpha: f64,
    pub batch: usize,
    pub n_cls: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    pub fn lora_scale(&self) -> f64 {
        self.lora_alpha / self.rank as f64
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.get("name")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            hidden: j.get("hidden")?.as_usize()?,
            layers: j.get("layers")?.as_usize()?,
            heads: j.get("heads")?.as_usize()?,
            ff: j.get("ff")?.as_usize()?,
            seq: j.get("seq")?.as_usize()?,
            rank: j.get("rank")?.as_usize()?,
            lora_alpha: j.get("lora_alpha")?.as_f64()?,
            batch: j.get("batch")?.as_usize()?,
            n_cls: j.get("n_cls")?.as_usize()?,
        })
    }

    fn preset(name: &str, vocab: usize, hidden: usize, layers: usize,
              heads: usize, ff: usize, seq: usize, rank: usize,
              batch: usize) -> ModelConfig {
        ModelConfig {
            name: name.to_string(), vocab, hidden, layers, heads, ff, seq,
            rank, lora_alpha: rank as f64, batch, n_cls: 4,
        }
    }

    /// The runnable testbed architectures, mirroring
    /// `python/compile/configs.py` `CONFIGS` exactly.  These back the
    /// native CPU engine when no AOT artifacts are present: the native
    /// backend synthesizes a manifest from them (`Manifest::builtin`), so
    /// the full training loop runs with no Python, XLA or artifacts.
    pub fn runnable_presets() -> Vec<ModelConfig> {
        vec![
            Self::preset("tiny", 256, 64, 2, 4, 128, 64, 16, 8),
            Self::preset("s1m", 512, 128, 4, 4, 256, 64, 32, 8),
            Self::preset("s4m", 512, 256, 4, 8, 512, 64, 64, 8),
            Self::preset("s8m", 1024, 256, 8, 8, 512, 128, 64, 4),
        ]
    }

    /// Resolve a spec name to a runnable preset, accepting the aot.py
    /// rank-override naming scheme (`tiny_r32` ⇒ tiny with rank=alpha=32).
    pub fn builtin(spec: &str) -> Option<ModelConfig> {
        if let Some(c) =
            Self::runnable_presets().into_iter().find(|c| c.name == spec)
        {
            return Some(c);
        }
        let (base, rank) = spec.rsplit_once("_r")?;
        let rank: usize = rank.parse().ok()?;
        let mut c = Self::runnable_presets()
            .into_iter()
            .find(|c| c.name == base)?;
        if rank == 0 {
            return None;
        }
        c.name = spec.to_string();
        c.rank = rank;
        c.lora_alpha = rank as f64;
        Some(c)
    }

    /// The paper's architectures (Table 1 + Table 9).  Never lowered to
    /// HLO here — they drive the analytic Tables 4/5 reproduction.
    pub fn paper_presets() -> Vec<ModelConfig> {
        vec![
            Self::preset("p130m", 32000, 768, 12, 12, 2048, 256, 128, 600),
            Self::preset("p250m", 32000, 768, 24, 16, 2560, 512, 128, 1152),
            Self::preset("p350m", 32000, 1024, 24, 16, 2736, 512, 128, 1152),
            Self::preset("p1b", 32000, 2048, 24, 32, 5461, 512, 512, 1536),
            Self::preset("p3b", 32000, 2560, 32, 32, 6826, 512, 640, 1536),
            Self::preset("p7b", 32000, 4096, 32, 32, 11008, 512, 1024, 1536),
        ]
    }

    pub fn paper_preset(name: &str) -> Option<ModelConfig> {
        Self::paper_presets().into_iter().find(|c| c.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_from_json() {
        let j = Json::parse(
            r#"{"name":"tiny","vocab":256,"hidden":64,"layers":2,
                "heads":4,"ff":128,"seq":64,"rank":16,"lora_alpha":16.0,
                "batch":8,"n_cls":4,"head_dim":16}"#).unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.hidden, 64);
        assert_eq!(c.head_dim(), 16);
        assert_eq!(c.lora_scale(), 1.0);
    }

    #[test]
    fn builtin_specs_resolve() {
        let t = ModelConfig::builtin("tiny").unwrap();
        assert_eq!((t.vocab, t.hidden, t.layers, t.heads, t.ff, t.seq,
                    t.rank, t.batch),
                   (256, 64, 2, 4, 128, 64, 16, 8));
        let hr = ModelConfig::builtin("tiny_r32").unwrap();
        assert_eq!(hr.name, "tiny_r32");
        assert_eq!(hr.rank, 32);
        assert_eq!(hr.lora_alpha, 32.0);
        assert_eq!(hr.hidden, t.hidden);
        assert!(ModelConfig::builtin("nope").is_none());
        assert!(ModelConfig::builtin("nope_r8").is_none());
    }

    #[test]
    fn paper_presets_match_table1() {
        let p = ModelConfig::paper_preset("p1b").unwrap();
        assert_eq!((p.hidden, p.heads, p.layers, p.batch, p.seq),
                   (2048, 32, 24, 1536, 512));
        let p7 = ModelConfig::paper_preset("p7b").unwrap();
        assert_eq!((p7.hidden, p7.layers), (4096, 32));
    }
}
