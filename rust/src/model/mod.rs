//! Model-side substrate: configuration presets, the manifest-driven
//! parameter layout (the Python↔Rust contract), initialization rules, and
//! the analytic parameter/memory/communication models behind the paper's
//! Tables 4/5 and Appendices D/F.

pub mod analytics;
pub mod config;
pub mod init;
pub mod layout;
pub mod packed;
