//! Dtype-polymorphic parameter access: the [`ParamSource`] trait and the
//! packed serving store.
//!
//! The master [`ParamStore`] is one contiguous `f32` buffer — right for
//! training, wasteful for serving a frozen model.  [`PackedStore`] holds
//! the same layout with each parameter in its own dtype-tagged
//! [`PackedBuf`]: `Role::Base` dense weights (the frozen majority of a
//! LoRA model, or every linear of a merged export) compressed to `bf16`
//! or symmetric per-row `int8`, everything the forward still needs at
//! full precision (embeddings, norms, adapters, heads) kept `f32`.
//!
//! [`ParamSource`] is how the model consumes either: [`MatRef`] views
//! for matmul weights (the packed kernels dequantize on load) and `f32`
//! slices for the parameter roles that stay master-precision.  A
//! `&ParamStore` coerces to `&dyn ParamSource` at every call site, so
//! the f32 path is unchanged — and bitwise identical, since an `F32`
//! view delegates to the original kernels.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::layout::{Layout, ParamStore, Role};
use crate::tensor::dtype::{DType, MatRef, PackedBuf};

/// Read access to a set of named parameters, at whatever precision each
/// one is stored in.
pub trait ParamSource {
    /// A parameter as a dtype-tagged matrix view (matmul RHS).
    fn mat(&self, name: &str) -> Result<MatRef<'_>>;

    /// A parameter that must be stored in `f32` (embeddings, norms,
    /// LoRA factors, heads — the master-precision roles).  Errors when
    /// the parameter is packed to a lower dtype.
    fn f32s(&self, name: &str) -> Result<&[f32]>;
}

impl ParamSource for ParamStore {
    fn mat(&self, name: &str) -> Result<MatRef<'_>> {
        Ok(MatRef::F32(self.slice(name)?))
    }

    fn f32s(&self, name: &str) -> Result<&[f32]> {
        self.slice(name)
    }
}

/// A layout's parameters with per-parameter dtype-tagged storage — the
/// serving artifact behind `--quantize-base`.
#[derive(Clone, Debug)]
pub struct PackedStore {
    pub layout: Arc<Layout>,
    /// one buffer per `layout.params` entry, same order
    bufs: Vec<PackedBuf>,
}

impl PackedStore {
    /// Pack a store, compressing every `Role::Base` dense weight to
    /// `base_dtype` (per-row scales for int8 follow the weight's output
    /// channels) and keeping every other role `f32`.
    ///
    /// Fails fast when a to-be-packed parameter contains a non-finite
    /// value, naming it: `quantize_row_i8` packs an inf/NaN row to an
    /// all-zero payload with a NaN scale (and bf16 keeps the non-finite
    /// value outright), so the corruption would otherwise surface only
    /// as silent NaN logits at serving time.
    pub fn quantize_base(store: &ParamStore, base_dtype: DType)
        -> Result<PackedStore> {
        let mut bufs = Vec::with_capacity(store.layout.params.len());
        for p in &store.layout.params {
            let data = &store.data[p.offset..p.offset + p.numel];
            let dtype = if p.role == Role::Base {
                base_dtype
            } else {
                DType::F32
            };
            if dtype != DType::F32 {
                if let Some(i) = data.iter().position(|x| !x.is_finite())
                {
                    bail!("cannot quantize param {:?} to {}: \
                           non-finite value {} at element {i} of {}",
                          p.name, dtype, data[i], p.numel);
                }
            }
            bufs.push(PackedBuf::pack(data, p.rows(), p.cols(), dtype));
        }
        Ok(PackedStore { layout: store.layout.clone(), bufs })
    }

    fn buf(&self, name: &str) -> Result<&PackedBuf> {
        let i = *self
            .layout
            .by_name
            .get(name)
            .ok_or_else(|| anyhow!("unknown param {name:?}"))?;
        Ok(&self.bufs[i])
    }

    /// Total resident bytes of all parameters (int8 scales included).
    pub fn resident_bytes(&self) -> usize {
        self.bufs.iter().map(|b| b.resident_bytes()).sum()
    }

    /// `(packed, f32)` resident bytes of the `Role::Base` segment — the
    /// compression the serving tables report.
    pub fn base_bytes(&self) -> (usize, usize) {
        let mut packed = 0;
        let mut full = 0;
        for (p, b) in self.layout.params.iter().zip(&self.bufs) {
            if p.role == Role::Base {
                packed += b.resident_bytes();
                full += 4 * p.numel;
            }
        }
        (packed, full)
    }

    /// Expand back to a master-precision store holding exactly the
    /// values the packed kernels compute with (dequantized per element).
    pub fn dequantized(&self) -> ParamStore {
        let mut out = ParamStore::zeros(self.layout.clone());
        for (p, b) in self.layout.params.iter().zip(&self.bufs) {
            out.data[p.offset..p.offset + p.numel]
                .copy_from_slice(&b.to_f32());
        }
        out
    }
}

impl ParamSource for PackedStore {
    fn mat(&self, name: &str) -> Result<MatRef<'_>> {
        Ok(self.buf(name)?.view())
    }

    fn f32s(&self, name: &str) -> Result<&[f32]> {
        match self.buf(name)? {
            PackedBuf::F32(d) => Ok(d),
            b => bail!("param {name:?} is packed as {}; this access \
                        path requires master-precision f32", b.dtype()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::seeded_store;
    use crate::model::layout::{Manifest, Variant};

    #[test]
    fn f32_packing_is_lossless_and_transparent() {
        let man = Manifest::builtin("tiny").unwrap();
        let store = seeded_store(&man, Variant::Lora, 3).unwrap();
        let packed =
            PackedStore::quantize_base(&store, DType::F32).unwrap();
        assert_eq!(packed.dequantized().data, store.data);
        assert_eq!(packed.resident_bytes(), 4 * store.layout.total);
        // f32s works for every param when nothing is compressed
        for p in &store.layout.params {
            assert_eq!(packed.f32s(&p.name).unwrap(),
                       store.slice(&p.name).unwrap());
        }
    }

    #[test]
    fn int8_compresses_only_the_base_segment() {
        let man = Manifest::builtin("tiny").unwrap();
        let store = seeded_store(&man, Variant::Lora, 4).unwrap();
        let packed =
            PackedStore::quantize_base(&store, DType::I8).unwrap();
        let (base_packed, base_full) = packed.base_bytes();
        assert!(base_full > 0);
        // ~4x on the base segment (1 byte/elem + one f32 scale per row)
        assert!((base_packed as f64) < base_full as f64 / 3.5,
                "base {base_packed} vs f32 {base_full}");
        // non-base roles stay exact
        for p in &store.layout.params {
            if p.role != Role::Base {
                assert_eq!(packed.f32s(&p.name).unwrap(),
                           store.slice(&p.name).unwrap(), "{}", p.name);
            } else {
                assert!(packed.f32s(&p.name).is_err());
                assert_eq!(packed.mat(&p.name).unwrap().dtype(),
                           DType::I8);
            }
        }
        // total shrinks accordingly
        assert!(packed.resident_bytes() < 4 * store.layout.total);
    }

    #[test]
    fn unknown_param_errors() {
        let man = Manifest::builtin("tiny").unwrap();
        let store = seeded_store(&man, Variant::Lora, 5).unwrap();
        let packed =
            PackedStore::quantize_base(&store, DType::Bf16).unwrap();
        assert!(packed.mat("nope").is_err());
        assert!(packed.f32s("nope").is_err());
    }

    #[test]
    fn non_finite_base_params_fail_fast_naming_the_param() {
        use crate::util::prop::prop_check;
        let man = Manifest::builtin("tiny").unwrap();
        prop_check("non-finite base fails fast", 12, move |rng| {
            let mut store = seeded_store(&man, Variant::Lora, 6).unwrap();
            // poison one random element of one random base param
            let bases: Vec<usize> = store
                .layout
                .params
                .iter()
                .enumerate()
                .filter(|(_, p)| p.role == Role::Base)
                .map(|(i, _)| i)
                .collect();
            let p = store.layout.params[bases[rng.below(bases.len())]]
                .clone();
            let at = p.offset + rng.below(p.numel);
            let bad = if rng.below(2) == 0 {
                f32::NAN
            } else {
                f32::INFINITY
            };
            store.data[at] = bad;
            for dtype in [DType::Bf16, DType::I8] {
                let err = PackedStore::quantize_base(&store, dtype)
                    .expect_err("poisoned base must not pack");
                let msg = format!("{err}");
                if !msg.contains(&p.name) {
                    return Err(format!(
                        "error {msg:?} does not name {:?}", p.name));
                }
            }
            // the same poison in a non-base param packs fine (it stays
            // f32 — exact — and is the training layer's concern)
            let mut ok = seeded_store(&man, Variant::Lora, 6).unwrap();
            let np = ok
                .layout
                .params
                .iter()
                .find(|p| p.role != Role::Base)
                .cloned()
                .expect("tiny manifest has non-base params");
            ok.data[np.offset] = bad;
            if let Err(e) = PackedStore::quantize_base(&ok, DType::I8) {
                return Err(format!("non-base poison rejected: {e}"));
            }
            Ok(())
        });
    }
}
