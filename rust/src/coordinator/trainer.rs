//! Training orchestrator: the leader loop tying together data, runtime,
//! optimizer, and the pluggable training method.
//!
//! One `Trainer::run` executes the paper's Algorithm 2 end to end:
//! ```text
//! method.pre_run                             (warm-start protocols)
//! for step:                                  (Alg. 2 line 1)
//!   lr ← method.lr_adjust(schedule(step))
//!   per-worker fwd+bwd on its shard          (one OS thread per shard)
//!   ring all-reduce of gradients             (measured comm bytes)
//!   method.optim_step                        (default: fused AdamW with
//!                                             the method's freeze mask;
//!                                             GaLore: host SVD optimizer)
//!   method.post_step                         (SwitchLoRA switching,
//!                                             ReLoRA merge-and-reset)
//! ```
//! plus periodic fixed-set evaluation, CSV metrics, optional periodic
//! resumable checkpoints (`ckpt_every`/`resume`) and a final report.
//!
//! The loop knows nothing about any concrete method: every
//! method-specific behavior — variant selection, default learning rate,
//! gradient masking, the optimizer update itself, post-step mutation,
//! counters and resumable state — goes through the
//! [`TrainingMethod`](crate::methods::TrainingMethod) trait, and methods
//! are instantiated by name through the
//! [`methods`](crate::methods) registry.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::coordinator::checkpoint::{self, MethodState, TrainerState};
use crate::coordinator::data_parallel::{ring_all_reduce, CommLedger};
use crate::coordinator::eval::eval_loss;
use crate::coordinator::metrics::{self, perplexity, CsvWriter, Ema};
use crate::data::dataset::{synth_batches, BatchIter, EvalSet};
use crate::data::synth::CorpusGen;
use crate::methods::{self, MethodCtx, TrainingMethod};
use crate::model::init::{init_store, InitMode};
use crate::model::layout::{Manifest, ParamStore};
use crate::optim::adam::AdamState;
use crate::optim::schedule::LrSchedule;
use crate::optim::AdamHyper;
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::dtype::PrecisionPolicy;
use crate::util::rng::Rng;

pub use crate::methods::Method;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// artifact spec directory name (e.g. "s1m", "s4m_r8")
    pub spec: String,
    pub artifacts_dir: PathBuf,
    pub method: Method,
    pub steps: u64,
    pub peak_lr: f32,
    pub warmup: u64,
    pub weight_decay: f32,
    pub seed: u64,
    /// simulated data-parallel workers (gradient sharding + ring allreduce)
    pub workers: usize,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub init: InitMode,
    /// full-rank warm-start steps before low-rank training (Figure 4);
    /// realized by wrapping the method in the `warmstart` plugin
    pub full_warmup_steps: u64,
    /// optional CSV path for the per-step loss curve
    pub metrics_csv: Option<PathBuf>,
    /// log every k steps
    pub log_every: u64,
    /// write a resumable checkpoint every k steps (0 = off); requires
    /// `ckpt_path`
    pub ckpt_every: u64,
    /// where periodic checkpoints go; a literal `{step}` in the file
    /// name is replaced with the step count at save time (otherwise the
    /// latest snapshot overwrites the previous one)
    pub ckpt_path: Option<PathBuf>,
    /// resume from this checkpoint: weights, optimizer state, method
    /// state and the step clock are restored, then training continues to
    /// `steps` (the config must otherwise match the original run)
    pub resume: Option<PathBuf>,
    /// precision policy (`--precision` / `--comm-dtype` /
    /// `--moments-dtype` / `--quantize-base`); the all-f32 default is
    /// bitwise identical to the pre-precision-layer trainer
    pub precision: PrecisionPolicy,
}

impl TrainConfig {
    pub fn new(spec: &str, method: Method, steps: u64) -> TrainConfig {
        TrainConfig {
            spec: spec.to_string(),
            artifacts_dir: default_artifacts_dir(),
            method,
            steps,
            peak_lr: 0.0, // 0 ⇒ the method's default lr
            warmup: 100.min(steps / 10).max(1),
            weight_decay: 0.0,
            seed: 42,
            workers: 1,
            eval_every: 0, // 0 ⇒ steps/10
            eval_batches: 8,
            init: InitMode::SwitchLora,
            full_warmup_steps: 0,
            metrics_csv: None,
            log_every: 50,
            ckpt_every: 0,
            ckpt_path: None,
            resume: None,
            precision: PrecisionPolicy::default(),
        }
    }
}

pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SWITCHLORA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Outcome of a run: loss curves, final metrics, systems counters.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub method: String,
    pub spec: String,
    /// (step, train loss EMA)
    pub train_curve: Vec<(u64, f64)>,
    /// (step, eval loss)
    pub eval_curve: Vec<(u64, f64)>,
    pub final_eval_loss: f64,
    pub final_ppl: f64,
    pub elapsed_secs: f64,
    pub mean_step_ms: f64,
    pub comm: CommLedger,
    /// method-reported named counters (e.g. `switches`,
    /// `offload_bytes`, `resets`, `projected_matrices`)
    pub counters: Vec<(String, u64)>,
    pub n_trainable: usize,
}

impl RunResult {
    /// A method counter by name (0 when the method does not report it).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(k, _)| k == name)
            .map(|&(_, v)| v)
            .unwrap_or(0)
    }
}

/// The training driver.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub manifest: Manifest,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        // AOT artifacts when present; otherwise the builtin (native-only)
        // manifest, so training runs on a clean machine.
        let manifest = Manifest::for_spec(&cfg.artifacts_dir, &cfg.spec)
            .with_context(|| format!("resolving spec {}", cfg.spec))?;
        Ok(Trainer { cfg, manifest })
    }

    /// Run the configured training; returns curves + counters, plus the
    /// final parameter store (for checkpointing / fine-tuning).
    pub fn run(&self, engine: &mut Engine)
        -> Result<(RunResult, ParamStore)> {
        let cfg = &self.cfg;
        let mc = &self.manifest.config;

        // ---- method (via the registry) ----
        let mspec = if cfg.full_warmup_steps > 0 {
            cfg.method.clone().warm_started(cfg.full_warmup_steps)
        } else {
            cfg.method.clone()
        };
        let ctx = MethodCtx {
            manifest: &self.manifest,
            steps: cfg.steps,
            seed: cfg.seed,
        };
        let mut method = methods::build(&mspec, &ctx)?;
        // methods may substitute their own manifest (layerwise hybrids)
        let manifest =
            method.manifest().unwrap_or(&self.manifest).clone();
        let variant = method.variant();
        let layout = std::sync::Arc::new(
            manifest.layout(variant)?.clone());
        let mut rng = Rng::new(cfg.seed);

        // ---- state ----
        let mut store = ParamStore::zeros(layout.clone());
        init_store(&mut store, &manifest.linears, mc.rank, cfg.init,
                   &mut rng);
        if !cfg.precision.is_default() {
            crate::info!("precision policy: {}", cfg.precision.summary());
        }
        let rt = ModelRuntime::load_with(engine, manifest.clone(), variant,
                                         cfg.precision)?;
        let padded = rt.padded;
        let mut opt = AdamState::with_moments(layout.n_trainable, padded,
                                              cfg.precision.moments);
        let mut base_mask = vec![0.0f32; padded];
        for x in base_mask.iter_mut().take(layout.n_trainable) {
            *x = 1.0;
        }

        let peak_lr = if cfg.peak_lr > 0.0 {
            cfg.peak_lr
        } else {
            method.default_lr()
        };
        let sched = LrSchedule::cosine(peak_lr, cfg.warmup, cfg.steps);
        ensure!(cfg.ckpt_every == 0 || cfg.ckpt_path.is_some(),
                "ckpt_every > 0 requires a ckpt_path");

        // ---- resume or pre-run (warm start) ----
        let mut ema = Ema::new(0.05);
        let mut comm = CommLedger::default();
        let start_step = match &cfg.resume {
            Some(path) => self.restore(path, method.as_mut(), &mut store,
                                       &mut opt, &mut ema, &mut comm,
                                       &mut rng, padded)?,
            None => {
                method.pre_run(cfg, &self.manifest, engine, &mut store)?;
                0
            }
        };
        ensure!(start_step <= cfg.steps,
                "checkpoint is {start_step} steps in, but this run is \
                 configured for only {} steps", cfg.steps);

        // ---- memory ledger ----
        // what this run keeps resident, decomposed by component and
        // dtype: f32 master store (frozen + trainable), Adam moment
        // buffers, and the method's candidate pools if it has any
        let pool_bytes = method
            .counters()
            .iter()
            .find(|(k, _)| k == "pool_resident_bytes")
            .map(|&(_, v)| v)
            .unwrap_or(0);
        let mem_rows = crate::obs::train_mem_rows(
            layout.total, layout.n_trainable, padded, pool_bytes);
        crate::obs::memory_event("train", &mem_rows);
        crate::debuglog!(
            "resident memory: {}",
            crate::util::human_bytes(crate::obs::mem_total(&mem_rows)));

        // ---- data ----
        let mut workers: Vec<BatchIter<CorpusGen>> = (0..cfg.workers)
            .map(|w| synth_batches(mc.vocab, cfg.seed, w as u64, mc.batch,
                                   mc.seq))
            .collect();
        // fast-forward the data streams past the batches the original
        // run already consumed, so resumed steps see identical data
        for w in workers.iter_mut() {
            for _ in 0..start_step {
                w.next_batch();
            }
        }
        let eval_set = EvalSet::synth(mc.vocab, cfg.seed, mc.batch, mc.seq,
                                      cfg.eval_batches);

        // ---- metrics ----
        const CSV_COLS: [&str; 6] =
            ["step", "loss", "ema", "lr", "eval_loss", "comm_bytes"];
        let mut csv = match &cfg.metrics_csv {
            // resuming mid-run: append, keeping the pre-kill curve rows
            Some(p) if start_step > 0 => {
                Some(CsvWriter::append(p, &CSV_COLS)?)
            }
            Some(p) => Some(CsvWriter::create(p, &CSV_COLS)?),
            None => None,
        };
        let mut train_curve = Vec::new();
        let mut eval_curve = Vec::new();
        let eval_every = if cfg.eval_every > 0 {
            cfg.eval_every
        } else {
            (cfg.steps / 10).max(1)
        };
        let hyper0 = AdamHyper {
            weight_decay: cfg.weight_decay,
            ..AdamHyper::new(peak_lr)
        };

        let t0 = Instant::now();
        // per-phase wall-clock accumulators (seconds) for the
        // heartbeat's throughput figures and the end-of-run profile;
        // the obs spans reuse the same clock reads
        let (mut ph_data, mut ph_fwdbwd, mut ph_ar, mut ph_opt,
             mut ph_switch, mut ph_eval, mut ph_ckpt) =
            (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let tokens_per_step = (cfg.workers * mc.batch * mc.seq) as f64;
        for step in start_step..cfg.steps {
            let _step_span = crate::obs::span("step", "step");
            // learning rate (method hook: e.g. ReLoRA local re-warm)
            let lr = method.lr_adjust(step, sched.lr(step), &sched);
            let hyper = hyper0.with_lr(lr);

            // ---- gradients (data-parallel) ----
            // One batch per worker; fwdbwd_multi runs each shard on its
            // own OS thread (native backend, kernel pool) or shares the
            // marshaled parameter literals (PJRT, §Perf L3).
            let sp = crate::obs::phase("data");
            let batches: Vec<_> =
                workers.iter_mut().map(|w| w.next_batch()).collect();
            let views: Vec<(&[i32], usize, usize)> = batches
                .iter()
                .map(|b| (b.tokens.as_slice(), b.batch, b.seq_plus_1))
                .collect();
            ph_data += sp.done();
            // forward/backward spans are recorded inside the backend
            // (per shard thread); this combined reading feeds the
            // heartbeat
            let tfb = Instant::now();
            let results = rt.fwdbwd_multi(&store, &views)?;
            ph_fwdbwd += tfb.elapsed().as_secs_f64();
            let mut losses = 0.0f64;
            let mut grads: Vec<Vec<f32>> =
                Vec::with_capacity(cfg.workers);
            for (l, g) in results {
                losses += l as f64;
                grads.push(g);
            }
            let loss = losses / cfg.workers as f64;
            // measured all-reduce traffic for THIS step (the ledger is
            // cumulative): what the comm_bytes CSV column logs
            let bytes_before = comm.bytes;
            let sp = crate::obs::phase("allreduce");
            ring_all_reduce(&mut grads, &mut comm, cfg.precision.comm);
            ph_ar += sp.done();
            let step_comm_bytes = comm.bytes - bytes_before;
            let grad = &grads[0];

            // ---- optimizer (method hook) ----
            let sp = crate::obs::phase("optim");
            method.optim_step(step, &rt, &mut store, grad, &mut opt,
                              &base_mask, &hyper)?;
            ph_opt += sp.done();

            // ---- method post-step (switching, resets) ----
            let sp = crate::obs::phase("switch");
            method.post_step(step, &mut store, &mut opt, &mut rng)?;
            ph_switch += sp.done();

            // ---- metrics / eval ----
            let e = ema.update(loss);
            train_curve.push((step, e));
            let mut eval_s = String::new();
            if (step + 1) % eval_every == 0 || step + 1 == cfg.steps {
                let sp = crate::obs::phase("eval");
                let el = eval_loss(&rt, &store, &eval_set)? as f64;
                ph_eval += sp.done();
                eval_curve.push((step, el));
                eval_s = format!("{el:.4}");
                // heartbeat: live throughput and ETA from the phase
                // clock (replaces the single end-of-run mean_step_ms
                // as the way to see how fast a run is going)
                let done_steps = (step + 1 - start_step) as f64;
                let wall = t0.elapsed().as_secs_f64().max(1e-9);
                let sps = done_steps / wall;
                let remaining =
                    (cfg.steps - step - 1) as f64 / sps.max(1e-9);
                crate::info!(
                    "[{}/{}] step {step} loss {loss:.4} ema {e:.4} \
                     eval {el:.4} ppl {:.2} lr {lr:.2e} comm {}/step | \
                     {sps:.2} steps/s {:.0} tok/s eta {}",
                    cfg.method.name(), cfg.spec, perplexity(el),
                    crate::util::human_bytes_f64(
                        comm.bytes as f64 / (step + 1) as f64),
                    sps * tokens_per_step, metrics::eta(remaining));
            } else if step % cfg.log_every == 0 {
                crate::debuglog!("step {step} loss {loss:.4} ema {e:.4}");
            }
            if let Some(c) = csv.as_mut() {
                c.row(&[step.to_string(), format!("{loss:.6}"),
                        format!("{e:.6}"), format!("{lr:.6e}"), eval_s,
                        step_comm_bytes.to_string()])?;
            }

            // ---- periodic resumable checkpoint ----
            if cfg.ckpt_every > 0
                && ((step + 1) % cfg.ckpt_every == 0
                    || step + 1 == cfg.steps)
            {
                let sp = crate::obs::phase("checkpoint");
                let path = cfg.ckpt_path.as_ref().expect("checked above");
                self.save_resumable(path, method.as_ref(), &store, &opt,
                                    step + 1, &ema, &comm, &rng)?;
                ph_ckpt += sp.done();
            }
        }
        if let Some(c) = csv.as_mut() {
            c.flush()?;
        }

        let elapsed = t0.elapsed().as_secs_f64();
        let steps_run = cfg.steps - start_step;
        crate::obs::run_summary(steps_run, comm.bytes, comm.rounds,
                                elapsed);
        if steps_run > 0 {
            let ms = |s: f64| 1e3 * s / steps_run as f64;
            crate::info!(
                "phase profile (ms/step): data {:.1} fwd+bwd {:.1} \
                 allreduce {:.1} optim {:.1} switch {:.1} eval {:.1} \
                 checkpoint {:.1}",
                ms(ph_data), ms(ph_fwdbwd), ms(ph_ar), ms(ph_opt),
                ms(ph_switch), ms(ph_eval), ms(ph_ckpt));
        }
        let final_eval = eval_curve
            .last()
            .map(|&(_, l)| l)
            .unwrap_or(f64::NAN);
        let result = RunResult {
            method: cfg.method.name().to_string(),
            spec: cfg.spec.clone(),
            train_curve,
            eval_curve,
            final_eval_loss: final_eval,
            final_ppl: perplexity(final_eval),
            elapsed_secs: elapsed,
            mean_step_ms: 1e3 * elapsed / steps_run.max(1) as f64,
            comm,
            counters: method.counters(),
            n_trainable: layout.n_trainable,
        };
        Ok((result, store))
    }

    /// Restore a resumable checkpoint into the freshly initialized run
    /// state; returns the step to resume from.
    #[allow(clippy::too_many_arguments)]
    fn restore(&self, path: &Path, method: &mut dyn TrainingMethod,
               store: &mut ParamStore, opt: &mut AdamState,
               ema: &mut Ema, comm: &mut CommLedger, rng: &mut Rng,
               padded: usize) -> Result<u64> {
        let ck = checkpoint::load(path)
            .with_context(|| format!("resuming from {}", path.display()))?;
        let rep = ck.restore_into(store);
        ensure!(rep.loaded > 0,
                "checkpoint {} shares no parameters with this run \
                 ({} missing, {} shape-mismatched)", path.display(),
                rep.missing, rep.mismatched);
        // validate the optimizer moments against the runtime's padded
        // fused-Adam buffer size before accepting them (a checkpoint
        // from a different padding would corrupt the update otherwise)
        if let Some(o) =
            ck.opt_validated(store.layout.n_trainable, padded)?
        {
            ensure!(o.moments_dtype == self.cfg.precision.moments,
                    "checkpoint {} keeps Adam moments in {}, but this \
                     run asked for --moments-dtype {}; resume with the \
                     original precision flags", path.display(),
                    o.moments_dtype, self.cfg.precision.moments);
            *opt = o;
        }
        if let Some(ms) = &ck.method {
            ensure!(ms.name == method.name(),
                    "checkpoint {} was written by method {:?}; this run \
                     trains {:?}", path.display(), ms.name,
                    method.name());
            ensure!(ms.version == method.state_version(),
                    "method state version {} in {} (current: {})",
                    ms.version, path.display(), method.state_version());
            method.load_state(&ms.payload)?;
        }
        let start = match &ck.trainer {
            Some(ts) => {
                // a mid-run checkpoint came from this exact run shape:
                // every parameter must restore, and every store
                // parameter must be covered — partial matches mean a
                // different spec/rank/method, and the validated
                // optimizer-moment length alone cannot catch layouts
                // that share a fused-Adam padding bucket
                ensure!(rep.missing == 0 && rep.mismatched == 0
                            && rep.loaded == store.layout.params.len(),
                        "mid-run checkpoint {} does not match this run's \
                         layout ({} loaded of {} expected, {} missing, \
                         {} mismatched) — was it written by a different \
                         spec or rank?", path.display(), rep.loaded,
                        store.layout.params.len(), rep.missing,
                        rep.mismatched);
                ensure!(ck.opt.is_some(),
                        "mid-run checkpoint {} lacks optimizer state",
                        path.display());
                ensure!(ck.method.is_some(),
                        "mid-run checkpoint {} lacks method state",
                        path.display());
                ema.restore(ts.ema_value, ts.ema_primed);
                comm.bytes = ts.comm_bytes;
                comm.rounds = ts.comm_rounds;
                *rng = Rng::from_state(ts.rng);
                ts.next_step
            }
            // weights-only checkpoint: warm initialization, fresh clock
            None => 0,
        };
        crate::info!(
            "resumed {} from {}: step {start}, {} params loaded \
             ({} missing, {} mismatched), optimizer {}",
            method.name(), path.display(), rep.loaded, rep.missing,
            rep.mismatched,
            if ck.opt.is_some() { "restored" } else { "fresh" });
        Ok(start)
    }

    /// Write a resumable checkpoint: weights + optimizer + method state
    /// + trainer state.  A literal `{step}` in the file name is replaced
    /// with `next_step` so periodic snapshots can be kept side by side.
    #[allow(clippy::too_many_arguments)]
    fn save_resumable(&self, path: &Path, method: &dyn TrainingMethod,
                      store: &ParamStore, opt: &AdamState,
                      next_step: u64, ema: &Ema, comm: &CommLedger,
                      rng: &Rng) -> Result<()> {
        let mut payload = Vec::new();
        method.save_state(&mut payload)?;
        let ms = MethodState {
            name: method.name().to_string(),
            version: method.state_version(),
            payload,
        };
        let (ema_value, ema_primed) = ema.state();
        let ts = TrainerState {
            next_step,
            rng: rng.state(),
            ema_value,
            ema_primed,
            comm_bytes: comm.bytes,
            comm_rounds: comm.rounds,
        };
        let p = path.to_string_lossy();
        let path = if p.contains("{step}") {
            PathBuf::from(p.replace("{step}", &next_step.to_string()))
        } else {
            path.to_path_buf()
        };
        checkpoint::save_full(&path, &self.cfg.spec, store, Some(opt),
                              Some(&ms), Some(&ts))?;
        crate::debuglog!("checkpoint at step {next_step}: {}",
                         path.display());
        Ok(())
    }
}
