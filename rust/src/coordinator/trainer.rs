//! Training orchestrator: the leader loop tying together data, runtime,
//! optimizer, and the method-specific machinery (SwitchLoRA switching,
//! ReLoRA resets, GaLore projection, plain LoRA / full-rank baselines).
//!
//! One `Trainer::run` executes the paper's Algorithm 2 end to end:
//! ```text
//! for step:                                  (Alg. 2 line 1)
//!   lr ← schedule(step)
//!   per-worker fwd+bwd on its shard          (data-parallel sim)
//!   ring all-reduce of gradients             (measured comm bytes)
//!   fused AdamW with freeze mask             (Alg. 2 line 2 + freezes)
//!   method post-step:
//!     SwitchLoRA: switch vectors             (Alg. 2 lines 3–15)
//!     ReLoRA: merge-and-reset when due
//! ```
//! plus periodic fixed-set evaluation, CSV metrics and a final report.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::data_parallel::{ring_all_reduce, CommLedger};
use crate::coordinator::eval::eval_loss;
use crate::coordinator::metrics::{perplexity, CsvWriter, Ema};
use crate::data::dataset::{synth_batches, BatchIter, EvalSet};
use crate::data::synth::CorpusGen;
use crate::model::init::{copy_shared, init_store, InitMode};
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::galore::Galore;
use crate::optim::schedule::LrSchedule;
use crate::optim::AdamHyper;
use crate::runtime::{Engine, ModelRuntime};
use crate::switchlora::relora::ReLora;
use crate::switchlora::schedule::SwitchSchedule;
use crate::switchlora::switcher::SwitchLora;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SwitchParams {
    /// initial switching interval (paper: 40)
    pub interval0: f64,
    /// fraction of total steps at which frequency reaches 1/3 (paper: 0.1)
    pub ratio: f64,
    /// freeze length N after a switch (paper: 5)
    pub n_freeze: u64,
}

impl Default for SwitchParams {
    fn default() -> Self {
        SwitchParams { interval0: 40.0, ratio: 0.1, n_freeze: 5 }
    }
}

#[derive(Clone, Debug)]
pub struct ReLoraParams {
    pub reset_interval: u64,
    pub rewarm: u64,
}

#[derive(Clone, Debug)]
pub struct GaloreParams {
    pub rank: usize,
    pub update_freq: u64,
    pub scale: f32,
}

#[derive(Clone, Debug)]
pub enum Method {
    Full,
    Lora,
    SwitchLora(SwitchParams),
    ReLora(ReLoraParams),
    Galore(GaloreParams),
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Full => "full",
            Method::Lora => "lora",
            Method::SwitchLora(_) => "switchlora",
            Method::ReLora(_) => "relora",
            Method::Galore(_) => "galore",
        }
    }

    pub fn variant(&self) -> Variant {
        match self {
            Method::Full | Method::Galore(_) => Variant::Full,
            _ => Variant::Lora,
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "full" => Method::Full,
            "lora" => Method::Lora,
            "switchlora" => Method::SwitchLora(SwitchParams::default()),
            "relora" => Method::ReLora(ReLoraParams {
                reset_interval: 500,
                rewarm: 50,
            }),
            "galore" => Method::Galore(GaloreParams {
                rank: 0, // 0 ⇒ use the config's LoRA rank
                update_freq: 200,
                scale: 0.25,
            }),
            _ => return None,
        })
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// artifact spec directory name (e.g. "s1m", "s4m_r8")
    pub spec: String,
    pub artifacts_dir: PathBuf,
    pub method: Method,
    pub steps: u64,
    pub peak_lr: f32,
    pub warmup: u64,
    pub weight_decay: f32,
    pub seed: u64,
    /// simulated data-parallel workers (gradient sharding + ring allreduce)
    pub workers: usize,
    pub eval_every: u64,
    pub eval_batches: usize,
    pub init: InitMode,
    /// full-rank warm-start steps before low-rank training (Figure 4)
    pub full_warmup_steps: u64,
    /// optional CSV path for the per-step loss curve
    pub metrics_csv: Option<PathBuf>,
    /// log every k steps
    pub log_every: u64,
}

impl TrainConfig {
    pub fn new(spec: &str, method: Method, steps: u64) -> TrainConfig {
        TrainConfig {
            spec: spec.to_string(),
            artifacts_dir: default_artifacts_dir(),
            method,
            steps,
            peak_lr: 0.0, // 0 ⇒ method default below
            warmup: 100.min(steps / 10).max(1),
            weight_decay: 0.0,
            seed: 42,
            workers: 1,
            eval_every: 0, // 0 ⇒ steps/10
            eval_batches: 8,
            init: InitMode::SwitchLora,
            full_warmup_steps: 0,
            metrics_csv: None,
            log_every: 50,
        }
    }

    /// Paper Section 4.1 learning rates: full 1e-3, LoRA 1e-2,
    /// SwitchLoRA 2e-2 (GaLore appendix C.3: 1e-2).
    pub fn method_default_lr(method: &Method) -> f32 {
        match method {
            Method::Full => 1e-3,
            Method::Lora => 1e-2,
            Method::SwitchLora(_) => 2e-2,
            Method::ReLora(_) => 1e-2,
            Method::Galore(_) => 1e-2,
        }
    }
}

pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SWITCHLORA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// Outcome of a run: loss curves, final metrics, systems counters.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub method: String,
    pub spec: String,
    /// (step, train loss EMA)
    pub train_curve: Vec<(u64, f64)>,
    /// (step, eval loss)
    pub eval_curve: Vec<(u64, f64)>,
    pub final_eval_loss: f64,
    pub final_ppl: f64,
    pub elapsed_secs: f64,
    pub mean_step_ms: f64,
    pub comm: CommLedger,
    pub offload_bytes: u64,
    pub total_switches: u64,
    pub n_trainable: usize,
}

/// The training driver.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub manifest: Manifest,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        // AOT artifacts when present; otherwise the builtin (native-only)
        // manifest, so training runs on a clean machine.
        let manifest = Manifest::for_spec(&cfg.artifacts_dir, &cfg.spec)
            .with_context(|| format!("resolving spec {}", cfg.spec))?;
        Ok(Trainer { cfg, manifest })
    }

    /// Run the configured training; returns curves + counters, plus the
    /// final parameter store (for checkpointing / fine-tuning).
    pub fn run(&self, engine: &mut Engine)
        -> Result<(RunResult, ParamStore)> {
        let cfg = &self.cfg;
        let mc = &self.manifest.config;
        let variant = cfg.method.variant();
        let layout = std::sync::Arc::new(
            self.manifest.layout(variant)?.clone());
        let mut rng = Rng::new(cfg.seed);

        // ---- state ----
        let mut store = ParamStore::zeros(layout.clone());
        init_store(&mut store, &self.manifest.linears, mc.rank, cfg.init,
                   &mut rng);
        let rt = ModelRuntime::load(engine, self.manifest.clone(), variant)?;
        let padded = rt.padded;
        let mut opt = AdamState::new(layout.n_trainable, padded);
        let mut base_mask = vec![0.0f32; padded];
        for x in base_mask.iter_mut().take(layout.n_trainable) {
            *x = 1.0;
        }

        // ---- method machinery ----
        let peak_lr = if cfg.peak_lr > 0.0 {
            cfg.peak_lr
        } else {
            TrainConfig::method_default_lr(&cfg.method)
        };
        let sched = LrSchedule::cosine(peak_lr, cfg.warmup, cfg.steps);
        let mut switcher = match &cfg.method {
            Method::SwitchLora(p) => Some(SwitchLora::new(
                &self.manifest.linears,
                mc.rank,
                mc.lora_scale() as f32,
                SwitchSchedule::with_third_at(p.interval0, p.ratio,
                                              cfg.steps),
                p.n_freeze,
                cfg.seed,
            )),
            _ => None,
        };
        let mut relora = match &cfg.method {
            Method::ReLora(p) => Some(ReLora::new(p.reset_interval,
                                                  p.rewarm)),
            _ => None,
        };
        let mut galore = match &cfg.method {
            Method::Galore(p) => {
                let rank = if p.rank == 0 { mc.rank } else { p.rank };
                Some(Galore::new(&layout, rank, p.update_freq, p.scale))
            }
            _ => None,
        };

        // ---- full-rank warm start (Figure 4 protocol) ----
        if cfg.full_warmup_steps > 0 && variant == Variant::Lora {
            let warm = self.full_warm_start(engine, cfg.full_warmup_steps)?;
            let copied = copy_shared(&warm, &mut store);
            crate::info!("full-rank warm start: {} steps, {} params carried",
                         cfg.full_warmup_steps, copied);
        }

        // ---- data ----
        let mut workers: Vec<BatchIter<CorpusGen>> = (0..cfg.workers)
            .map(|w| synth_batches(mc.vocab, cfg.seed, w as u64, mc.batch,
                                   mc.seq))
            .collect();
        let eval_set = EvalSet::synth(mc.vocab, cfg.seed, mc.batch, mc.seq,
                                      cfg.eval_batches);

        // ---- metrics ----
        let mut csv = match &cfg.metrics_csv {
            Some(p) => Some(CsvWriter::create(
                p, &["step", "loss", "ema", "lr", "eval_loss",
                     "comm_bytes"])?),
            None => None,
        };
        let mut ema = Ema::new(0.05);
        let mut comm = CommLedger::default();
        let mut train_curve = Vec::new();
        let mut eval_curve = Vec::new();
        let eval_every = if cfg.eval_every > 0 {
            cfg.eval_every
        } else {
            (cfg.steps / 10).max(1)
        };
        let hyper0 = AdamHyper {
            weight_decay: cfg.weight_decay,
            ..AdamHyper::new(peak_lr)
        };

        let t0 = Instant::now();
        for step in 0..cfg.steps {
            // learning rate (with ReLoRA local re-warm after resets)
            let mut lr = sched.lr(step);
            if let Some(rl) = &relora {
                if rl.n_resets > 0 {
                    lr = sched.with_restart(step, rl.last_reset, rl.rewarm);
                }
            }
            let hyper = hyper0.with_lr(lr);

            // ---- gradients (data-parallel) ----
            // One batch per worker; parameter literals marshaled once for
            // all workers (fwdbwd_multi, §Perf L3).
            let batches: Vec<_> =
                workers.iter_mut().map(|w| w.next_batch()).collect();
            let views: Vec<(&[i32], usize, usize)> = batches
                .iter()
                .map(|b| (b.tokens.as_slice(), b.batch, b.seq_plus_1))
                .collect();
            let results = rt.fwdbwd_multi(&store, &views)?;
            let mut losses = 0.0f64;
            let mut grads: Vec<Vec<f32>> =
                Vec::with_capacity(cfg.workers);
            for (l, g) in results {
                losses += l as f64;
                grads.push(g);
            }
            let loss = losses / cfg.workers as f64;
            // measured all-reduce traffic for THIS step (the ledger is
            // cumulative): what the comm_bytes CSV column logs
            let bytes_before = comm.bytes;
            ring_all_reduce(&mut grads, &mut comm);
            let step_comm_bytes = comm.bytes - bytes_before;
            let grad = &grads[0];

            // ---- optimizer ----
            if let Some(gl) = galore.as_mut() {
                // host optimizer (needs SVD between grad and update)
                let mut flat = store.gather_trainable(padded);
                gl.step(step, &mut flat[..layout.n_trainable],
                        &grad[..layout.n_trainable], &hyper);
                store.scatter_trainable(&flat);
            } else {
                let mut mask = base_mask.clone();
                if let Some(sw) = switcher.as_mut() {
                    sw.freeze.apply(step, &mut mask);
                }
                let mut flat = store.gather_trainable(padded);
                rt.adam_step(&mut flat, grad, &mut opt, &mask, &hyper)?;
                store.scatter_trainable(&flat);
            }

            // ---- method post-step ----
            if let Some(sw) = switcher.as_mut() {
                sw.apply_step(step, &mut store, &mut opt,
                              &self.manifest.linears);
            }
            if let Some(rl) = relora.as_mut() {
                if rl.due(step) {
                    let n = rl.reset(step, &mut store, &mut opt,
                                     &self.manifest.linears, mc.rank,
                                     mc.lora_scale() as f32, &mut rng);
                    crate::info!("step {step}: ReLoRA reset {n} adapters");
                }
            }

            // ---- metrics / eval ----
            let e = ema.update(loss);
            train_curve.push((step, e));
            let mut eval_s = String::new();
            if (step + 1) % eval_every == 0 || step + 1 == cfg.steps {
                let el = eval_loss(&rt, &store, &eval_set)? as f64;
                eval_curve.push((step, el));
                eval_s = format!("{el:.4}");
                crate::info!(
                    "[{}/{}] step {step} loss {loss:.4} ema {e:.4} \
                     eval {el:.4} ppl {:.2} lr {lr:.2e} comm {}/step",
                    cfg.method.name(), cfg.spec, perplexity(el),
                    crate::util::human_bytes(comm.bytes / (step + 1)));
            } else if step % cfg.log_every == 0 {
                crate::debuglog!("step {step} loss {loss:.4} ema {e:.4}");
            }
            if let Some(c) = csv.as_mut() {
                c.row(&[step.to_string(), format!("{loss:.6}"),
                        format!("{e:.6}"), format!("{lr:.6e}"), eval_s,
                        step_comm_bytes.to_string()])?;
            }
        }
        if let Some(c) = csv.as_mut() {
            c.flush()?;
        }

        let elapsed = t0.elapsed().as_secs_f64();
        let final_eval = eval_curve
            .last()
            .map(|&(_, l)| l)
            .unwrap_or(f64::NAN);
        let result = RunResult {
            method: cfg.method.name().to_string(),
            spec: cfg.spec.clone(),
            train_curve,
            eval_curve,
            final_eval_loss: final_eval,
            final_ppl: perplexity(final_eval),
            elapsed_secs: elapsed,
            mean_step_ms: 1e3 * elapsed / cfg.steps.max(1) as f64,
            comm,
            offload_bytes: switcher
                .as_ref()
                .map(|s| s.ledger.total_bytes())
                .unwrap_or(0),
            total_switches: switcher
                .as_ref()
                .map(|s| s.total_switches)
                .unwrap_or(0),
            n_trainable: layout.n_trainable,
        };
        Ok((result, store))
    }

    /// Short full-rank run used as warm start (Figure 4 protocol); returns
    /// its parameter store for transplanting into the LoRA store.
    fn full_warm_start(&self, engine: &mut Engine, steps: u64)
        -> Result<ParamStore> {
        let mut sub = self.cfg.clone();
        sub.method = Method::Full;
        sub.steps = steps;
        sub.full_warmup_steps = 0;
        sub.peak_lr = 0.0;
        sub.metrics_csv = None;
        sub.eval_every = steps; // single eval at the end
        let t = Trainer { cfg: sub, manifest: self.manifest.clone() };
        let (_, store) = t.run(engine)?;
        Ok(store)
    }
}
