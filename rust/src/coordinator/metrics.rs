//! Metrics: CSV loss-curve writers (the Figure 2/3/4/6/8/9 data files) and
//! run summaries, including the measured all-reduce traffic the paper's
//! 54%-less-communication claim is about.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::data_parallel::CommLedger;
use crate::tensor::dtype::DType;
use crate::util::{human_bytes, human_bytes_f64};

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
    pub rows: u64,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut out = BufWriter::new(f);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, cols: header.len(), rows: 0 })
    }

    /// Open for appending — the resume path: existing rows (the curve up
    /// to the checkpoint) are kept, and the header is written only when
    /// the file is new or empty.
    pub fn append(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let existing =
            std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("appending to {}", path.display()))?;
        let mut out = BufWriter::new(f);
        if existing == 0 {
            writeln!(out, "{}", header.join(","))?;
        }
        Ok(CsvWriter { out, cols: header.len(), rows: 0 })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        anyhow::ensure!(values.len() == self.cols,
                        "row has {} cols, header has {}", values.len(),
                        self.cols);
        writeln!(self.out, "{}", values.join(","))?;
        self.rows += 1;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush().map_err(Into::into)
    }
}

/// Exponential moving average of the training loss.
#[derive(Clone, Copy, Debug)]
pub struct Ema {
    pub value: f64,
    alpha: f64,
    primed: bool,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        Ema { value: 0.0, alpha, primed: false }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        if !self.primed {
            self.value = x;
            self.primed = true;
        } else {
            self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        }
        self.value
    }

    /// Snapshot `(value, primed)` for checkpoint/resume.
    pub fn state(&self) -> (f64, bool) {
        (self.value, self.primed)
    }

    /// Restore a snapshot taken with [`Ema::state`]; the next `update`
    /// continues the average exactly where the saved run left off.
    pub fn restore(&mut self, value: f64, primed: bool) {
        self.value = value;
        self.primed = primed;
    }
}

/// Perplexity from a mean NLL.
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// One-line per-step communication summary for run reports and the CLI
/// — the visible form of the paper's claim that all-reduce traffic is
/// proportional to trainable parameters.  `wire` is the dtype the bytes
/// were counted at (`--comm-dtype`), so the headline states what moved.
pub fn comm_summary(comm: &CommLedger, steps: u64, wire: DType) -> String {
    // f64 rate: integer division used to truncate sub-KB-per-step runs
    // (e.g. a small adapter over many steps) to a misleading "0B/step"
    let per_step = if steps == 0 {
        0.0
    } else {
        comm.bytes as f64 / steps as f64
    };
    format!("{}/step measured all-reduce traffic ({} total over {} \
             rounds, {} wire)",
            human_bytes_f64(per_step), human_bytes(comm.bytes),
            comm.rounds, wire)
}

/// Compact remaining-time estimate for the heartbeat line
/// ("42s", "3m07s", "2h05m").
pub fn eta(secs: f64) -> String {
    if !secs.is_finite() || secs < 0.0 {
        return "?".to_string();
    }
    let s = secs.round() as u64;
    if s >= 3600 {
        format!("{}h{:02}m", s / 3600, (s % 3600) / 60)
    } else if s >= 60 {
        format!("{}m{:02}s", s / 60, s % 60)
    } else {
        format!("{s}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("switchlora_test_metrics");
        let path = dir.join("m.csv");
        {
            let mut w =
                CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&["0".into(), "5.5".into()]).unwrap();
            w.row(&["1".into(), "5.4".into()]).unwrap();
            assert!(w.row(&["oops".into()]).is_err());
            w.flush().unwrap();
            assert_eq!(w.rows, 2);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("step,loss\n"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_keeps_existing_rows() {
        let dir = std::env::temp_dir().join("switchlora_test_metrics_app");
        let path = dir.join("resume.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.row(&["0".into(), "5.0".into()]).unwrap();
            w.flush().unwrap();
        }
        {
            // resume: append without truncating or re-writing the header
            let mut w = CsvWriter::append(&path, &["step", "loss"]).unwrap();
            w.row(&["1".into(), "4.0".into()]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n0,5.0\n1,4.0\n");
        // appending to a fresh file still writes the header
        let p2 = dir.join("fresh.csv");
        let mut w = CsvWriter::append(&p2, &["a"]).unwrap();
        w.row(&["1".into()]).unwrap();
        w.flush().unwrap();
        assert!(std::fs::read_to_string(&p2)
            .unwrap()
            .starts_with("a\n"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ema_tracks() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        assert_eq!(e.update(0.0), 5.0);
        assert_eq!(e.update(5.0), 5.0);
    }

    #[test]
    fn perplexity_of_uniform() {
        let v: f64 = 256.0;
        assert!((perplexity(v.ln()) - v).abs() < 1e-6);
    }

    #[test]
    fn comm_summary_reports_per_step_rate() {
        let comm = CommLedger { bytes: 4096 * 100, rounds: 100 };
        let s = comm_summary(&comm, 100, DType::F32);
        assert!(s.contains("4.0KB/step"), "{s}");
        assert!(s.contains("100 rounds"), "{s}");
        assert!(s.contains("f32 wire"), "{s}");
        assert!(comm_summary(&comm, 0, DType::Bf16)
            .contains("0B/step"));
        assert!(comm_summary(&comm, 0, DType::Bf16)
            .contains("bf16 wire"));
    }

    #[test]
    fn comm_summary_keeps_sub_byte_rates() {
        // 512 bytes over 1024 steps used to truncate to "0B/step"
        let comm = CommLedger { bytes: 512, rounds: 1024 };
        let s = comm_summary(&comm, 1024, DType::Bf16);
        assert!(s.contains("0.5B/step"), "{s}");
    }

    #[test]
    fn eta_renders_compactly() {
        assert_eq!(eta(42.4), "42s");
        assert_eq!(eta(187.0), "3m07s");
        assert_eq!(eta(7500.0), "2h05m");
        assert_eq!(eta(f64::INFINITY), "?");
        assert_eq!(eta(-1.0), "?");
    }
}
