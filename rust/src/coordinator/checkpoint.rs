//! Checkpoints: binary save/load of a `ParamStore` (+ optional optimizer
//! state, method state and trainer state), keyed by parameter name so
//! stores with different layouts (e.g. LoRA pre-train → merged full
//! fine-tune) can exchange weights.
//!
//! Format v2 (little-endian, magic `SWLORA2`):
//! ```text
//! magic "SWLORA2\0" | config-name len+bytes | n_params
//! per param: name len+bytes | numel u64 | f32 data
//! opt flag u8;     if 1: n u64 | m | v | s      (f32 arrays of length n)
//! method flag u8;  if 1: name | version u32 | payload len u64 + bytes
//! trainer flag u8; if 1: len u64 + `util::bytes` payload of
//!                  (next_step u64 | rng | ema f64 + primed u8 |
//!                   comm bytes + rounds u64)
//! ```
//!
//! The method/trainer sections make a run resumable mid-schedule
//! (`--ckpt-every` / `--resume`): the method payload is whatever the
//! `TrainingMethod::save_state` hook wrote (freeze timers, candidate
//! pools, projection state, ...), and the trainer section carries the
//! step clock, the loss EMA, the leader RNG and the comm ledger.
//! Version-1 files (magic `SWLORA1`, weights + optimizer only) still
//! load; their method/trainer sections read as absent.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::model::layout::ParamStore;
use crate::optim::adam::AdamState;
use crate::util::bytes;
use crate::util::rng::RngState;

const MAGIC_V2: &[u8; 8] = b"SWLORA2\0";
const MAGIC_V1: &[u8; 8] = b"SWLORA1\0";

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).context("non-utf8 string in checkpoint")
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    // bulk copy via bytemuck-free manual chunking
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// The resumable state of a training method, as written by
/// `TrainingMethod::save_state`: the registry name it must match on
/// resume, a payload version, and the opaque payload bytes.
#[derive(Clone, Debug)]
pub struct MethodState {
    /// method name (must equal the resuming run's method)
    pub name: String,
    /// payload schema version (must equal the method's `state_version`)
    pub version: u32,
    /// the method's serialized dynamic state
    pub payload: Vec<u8>,
}

/// The trainer's own resumable state: where to pick the loop back up and
/// the cross-step accumulators that are not derivable from the config.
#[derive(Clone, Debug)]
pub struct TrainerState {
    /// first step the resumed loop runs (== steps already completed)
    pub next_step: u64,
    /// leader RNG (init draws + any method draws already consumed)
    pub rng: RngState,
    /// training-loss EMA value
    pub ema_value: f64,
    /// whether the EMA has seen at least one sample
    pub ema_primed: bool,
    /// cumulative all-reduce traffic so far
    pub comm_bytes: u64,
    /// cumulative all-reduce rounds so far
    pub comm_rounds: u64,
}

/// Save weights only (plus optional optimizer state) — the plain
/// `--out` checkpoint path.
pub fn save(path: &Path, config_name: &str, store: &ParamStore,
            opt: Option<&AdamState>) -> Result<()> {
    save_full(path, config_name, store, opt, None, None)
}

/// Save a full (optionally resumable) checkpoint.  `method` and
/// `trainer` are present for `--ckpt-every` mid-run snapshots and absent
/// for final weight exports.
pub fn save_full(path: &Path, config_name: &str, store: &ParamStore,
                 opt: Option<&AdamState>, method: Option<&MethodState>,
                 trainer: Option<&TrainerState>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_V2)?;
    write_str(&mut w, config_name)?;
    write_u64(&mut w, store.layout.params.len() as u64)?;
    for p in &store.layout.params {
        write_str(&mut w, &p.name)?;
        write_f32s(&mut w, &store.data[p.offset..p.offset + p.numel])?;
    }
    match opt {
        Some(o) => {
            w.write_all(&[1u8])?;
            write_f32s(&mut w, &o.m)?;
            write_f32s(&mut w, &o.v)?;
            write_f32s(&mut w, &o.s)?;
        }
        None => w.write_all(&[0u8])?,
    }
    match method {
        Some(m) => {
            w.write_all(&[1u8])?;
            write_str(&mut w, &m.name)?;
            w.write_all(&m.version.to_le_bytes())?;
            write_u64(&mut w, m.payload.len() as u64)?;
            w.write_all(&m.payload)?;
        }
        None => w.write_all(&[0u8])?,
    }
    match trainer {
        Some(t) => {
            w.write_all(&[1u8])?;
            let mut payload = Vec::new();
            bytes::put_u64(&mut payload, t.next_step);
            bytes::put_rng(&mut payload, &t.rng);
            bytes::put_f64(&mut payload, t.ema_value);
            bytes::put_u8(&mut payload, u8::from(t.ema_primed));
            bytes::put_u64(&mut payload, t.comm_bytes);
            bytes::put_u64(&mut payload, t.comm_rounds);
            write_u64(&mut w, payload.len() as u64)?;
            w.write_all(&payload)?;
        }
        None => w.write_all(&[0u8])?,
    }
    w.flush()?;
    Ok(())
}

/// Checkpoint contents, layout-agnostic.
pub struct Checkpoint {
    pub config_name: String,
    pub params: Vec<(String, Vec<f32>)>,
    pub opt: Option<AdamState>,
    /// resumable method state (v2 mid-run checkpoints only)
    pub method: Option<MethodState>,
    /// resumable trainer state (v2 mid-run checkpoints only)
    pub trainer: Option<TrainerState>,
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let v2 = &magic == MAGIC_V2;
    if !v2 && &magic != MAGIC_V1 {
        bail!("{} is not a switchlora checkpoint", path.display());
    }
    let config_name = read_str(&mut r)?;
    let n = read_u64(&mut r)? as usize;
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let name = read_str(&mut r)?;
        let data = read_f32s(&mut r)?;
        params.push((name, data));
    }
    let opt = if read_u8(&mut r)? == 1 {
        let m = read_f32s(&mut r)?;
        let v = read_f32s(&mut r)?;
        let s = read_f32s(&mut r)?;
        Some(AdamState { m, v, s })
    } else {
        None
    };
    let (method, trainer) = if v2 {
        let method = if read_u8(&mut r)? == 1 {
            let name = read_str(&mut r)?;
            let mut vb = [0u8; 4];
            r.read_exact(&mut vb)?;
            let len = read_u64(&mut r)? as usize;
            let mut payload = vec![0u8; len];
            r.read_exact(&mut payload)?;
            Some(MethodState {
                name,
                version: u32::from_le_bytes(vb),
                payload,
            })
        } else {
            None
        };
        let trainer = if read_u8(&mut r)? == 1 {
            let len = read_u64(&mut r)? as usize;
            let mut payload = vec![0u8; len];
            r.read_exact(&mut payload)?;
            let mut b = bytes::ByteReader::new(&payload);
            let ts = TrainerState {
                next_step: b.u64()?,
                rng: b.rng()?,
                ema_value: b.f64()?,
                ema_primed: b.u8()? == 1,
                comm_bytes: b.u64()?,
                comm_rounds: b.u64()?,
            };
            b.finish()?;
            Some(ts)
        } else {
            None
        };
        (method, trainer)
    } else {
        (None, None)
    };
    Ok(Checkpoint { config_name, params, opt, method, trainer })
}

/// Outcome of [`Checkpoint::restore_into`]: how many checkpointed params
/// were copied, how many the target layout does not name at all, and how
/// many exist under the same name but with a different element count
/// (each mismatch is also logged with the offending parameter's name).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RestoreReport {
    /// params copied into the store
    pub loaded: usize,
    /// params absent from the target layout
    pub missing: usize,
    /// params present by name but with a different numel — skipped
    pub mismatched: usize,
}

impl Checkpoint {
    /// Copy parameters into a store by name.  A parameter whose name the
    /// layout knows but whose size disagrees is *not* silently treated as
    /// missing: it is counted separately and a warning names it, since it
    /// usually means the checkpoint came from a different spec/rank.
    pub fn restore_into(&self, store: &mut ParamStore) -> RestoreReport {
        let mut rep = RestoreReport::default();
        for (name, data) in &self.params {
            match store.layout.meta(name) {
                Ok(meta) if meta.numel == data.len() => {
                    let (off, n) = (meta.offset, meta.numel);
                    store.data[off..off + n].copy_from_slice(data);
                    rep.loaded += 1;
                }
                Ok(meta) => {
                    crate::warnlog!(
                        "checkpoint param {name:?}: {} elements but the \
                         target layout expects {} — skipped (different \
                         spec or rank?)", data.len(), meta.numel);
                    rep.mismatched += 1;
                }
                Err(_) => rep.missing += 1,
            }
        }
        rep
    }

    /// Return the checkpointed optimizer state after validating it
    /// against the runtime's buffer sizes: the fused-Adam kernel requires
    /// all moment arrays padded to exactly `padded` (>= `n_trainable`).
    /// A checkpoint written under a different padding would otherwise
    /// scatter moments to the wrong lanes and silently corrupt the run.
    pub fn opt_validated(&self, n_trainable: usize, padded: usize)
        -> Result<Option<AdamState>> {
        let Some(o) = &self.opt else { return Ok(None) };
        ensure!(o.m.len() == o.v.len() && o.m.len() == o.s.len(),
                "checkpoint optimizer state is internally inconsistent: \
                 m/v/s lengths {}/{}/{}", o.m.len(), o.v.len(),
                o.s.len());
        ensure!(o.m.len() == padded,
                "checkpoint optimizer state has {} elements but this \
                 runtime pads the fused-Adam buffers to {padded} \
                 (trainable {n_trainable}); it was written under a \
                 different padding and cannot be resumed safely",
                o.m.len());
        Ok(Some(o.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta, Role};
    use std::sync::Arc;

    fn toy_store(fill: f32) -> ParamStore {
        let layout = Layout::from_metas(vec![
            ParamMeta { name: "w".into(), shape: vec![2, 3],
                        role: Role::Base, trainable: true, numel: 6,
                        offset: 0, t_offset: None },
            ParamMeta { name: "n".into(), shape: vec![4], role: Role::Norm,
                        trainable: true, numel: 4, offset: 0,
                        t_offset: None },
        ]);
        let mut s = ParamStore::zeros(Arc::new(layout));
        for (i, x) in s.data.iter_mut().enumerate() {
            *x = fill + i as f32;
        }
        s
    }

    #[test]
    fn save_load_roundtrip_with_opt() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt");
        let path = dir.join("a.ckpt");
        let store = toy_store(10.0);
        let mut opt = AdamState::new(10, 16);
        opt.m[3] = 0.5;
        save(&path, "tiny", &store, Some(&opt)).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.config_name, "tiny");
        assert_eq!(ck.params.len(), 2);
        assert!(ck.method.is_none() && ck.trainer.is_none());
        let o = ck.opt.as_ref().unwrap();
        assert_eq!(o.m.len(), 16);
        assert_eq!(o.m[3], 0.5);
        let mut dst = toy_store(0.0);
        let rep = ck.restore_into(&mut dst);
        assert_eq!(rep, RestoreReport { loaded: 2, missing: 0,
                                        mismatched: 0 });
        assert_eq!(dst.data, store.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resumable_sections_roundtrip() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_v2");
        let path = dir.join("r.ckpt");
        let store = toy_store(1.0);
        let opt = AdamState::new(10, 16);
        let ms = MethodState {
            name: "switchlora".into(),
            version: 3,
            payload: vec![1, 2, 3, 4, 5],
        };
        let ts = TrainerState {
            next_step: 77,
            rng: RngState { s: [1, 2, 3, 4], spare_normal: Some(-0.25) },
            ema_value: 5.5,
            ema_primed: true,
            comm_bytes: 999,
            comm_rounds: 12,
        };
        save_full(&path, "tiny", &store, Some(&opt), Some(&ms), Some(&ts))
            .unwrap();
        let ck = load(&path).unwrap();
        let m = ck.method.as_ref().unwrap();
        assert_eq!((m.name.as_str(), m.version), ("switchlora", 3));
        assert_eq!(m.payload, vec![1, 2, 3, 4, 5]);
        let t = ck.trainer.as_ref().unwrap();
        assert_eq!(t.next_step, 77);
        assert_eq!(t.rng, ts.rng);
        assert_eq!((t.ema_value, t.ema_primed), (5.5, true));
        assert_eq!((t.comm_bytes, t.comm_rounds), (999, 12));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_legacy_v1_files() {
        // hand-write a v1 (SWLORA1) file with the old layout
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.ckpt");
        let store = toy_store(3.0);
        {
            let f = std::fs::File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            w.write_all(b"SWLORA1\0").unwrap();
            write_str(&mut w, "tiny").unwrap();
            write_u64(&mut w, store.layout.params.len() as u64).unwrap();
            for p in &store.layout.params {
                write_str(&mut w, &p.name).unwrap();
                write_f32s(&mut w,
                           &store.data[p.offset..p.offset + p.numel])
                    .unwrap();
            }
            w.write_all(&[0u8]).unwrap(); // no optimizer state
            w.flush().unwrap();
        }
        let ck = load(&path).unwrap();
        assert_eq!(ck.config_name, "tiny");
        assert!(ck.opt.is_none());
        assert!(ck.method.is_none() && ck.trainer.is_none());
        let mut dst = toy_store(0.0);
        let rep = ck.restore_into(&mut dst);
        assert_eq!(rep.loaded, 2);
        assert_eq!(dst.data, store.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_restore_distinguishes_missing_from_mismatch() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt2");
        let path = dir.join("b.ckpt");
        let store = toy_store(1.0);
        save(&path, "x", &store, None).unwrap();
        let mut ck = load(&path).unwrap();
        ck.params.push(("ghost".into(), vec![1.0])); // absent from layout
        ck.params.push(("n".into(), vec![1.0, 2.0])); // wrong numel (4)
        let mut dst = toy_store(0.0);
        let rep = ck.restore_into(&mut dst);
        assert_eq!(rep, RestoreReport { loaded: 2, missing: 1,
                                        mismatched: 1 });
        // the mismatched param was NOT partially copied
        assert_eq!(dst.slice("n").unwrap(), store.slice("n").unwrap());
        assert!(ck.opt.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn opt_validation_rejects_foreign_padding() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt3b");
        let path = dir.join("p.ckpt");
        let store = toy_store(0.0);
        let opt = AdamState::new(10, 16);
        save(&path, "x", &store, Some(&opt)).unwrap();
        let ck = load(&path).unwrap();
        // matching padding: accepted
        assert!(ck.opt_validated(10, 16).unwrap().is_some());
        // a runtime that pads to a different size: rejected loudly
        let err = ck.opt_validated(10, 8192).unwrap_err().to_string();
        assert!(err.contains("8192"), "{err}");
        // no optimizer state at all is fine (weights-only checkpoint)
        let ck2 = Checkpoint { config_name: "x".into(), params: vec![],
                               opt: None, method: None, trainer: None };
        assert!(ck2.opt_validated(10, 16).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
