//! Checkpoints: binary save/load of a `ParamStore` (+ optional optimizer
//! state, method state and trainer state), keyed by parameter name so
//! stores with different layouts (e.g. LoRA pre-train → merged full
//! fine-tune) can exchange weights.
//!
//! Format v3 (little-endian, magic `SWLORA3`) tags every tensor with a
//! storage dtype:
//! ```text
//! magic "SWLORA3\0" | config-name len+bytes | n_params
//! per param: name len+bytes | dtype u8 | numel u64 | payload
//!            (f32: 4B/elem; bf16: 2B/elem; int8: 1B/elem codes,
//!             then rows u64 + rows f32 scales)
//! opt flag u8;     if 1: moments dtype u8 | m | v (at that width) |
//!                  s (f32s)
//! method flag u8;  if 1: name | version u32 | payload len u64 + bytes
//! trainer flag u8; if 1: len u64 + `util::bytes` payload of
//!                  (next_step u64 | rng | ema f64 + primed u8 |
//!                   comm bytes + rounds u64)
//! ```
//!
//! Master weight checkpoints are written f32 (resume must round-trip
//! bitwise); the dtype tags carry `--moments-dtype bf16` Adam moments
//! at 2 bytes each and let the loader accept bf16/int8-tagged tensors
//! from packed exports.  Loading dequantizes everything to f32.
//!
//! The method/trainer sections make a run resumable mid-schedule
//! (`--ckpt-every` / `--resume`): the method payload is whatever the
//! `TrainingMethod::save_state` hook wrote (freeze timers, candidate
//! pools, projection state, ...), and the trainer section carries the
//! step clock, the loss EMA, the leader RNG and the comm ledger.
//! Version-2 files (magic `SWLORA2`, untagged f32 tensors) and
//! version-1 files (`SWLORA1`, weights + optimizer only) still load.
//!
//! Reads are hardened: the file is slurped once (its real size bounds
//! every allocation) and each declared length/numel is validated
//! against the bytes actually remaining *before* any buffer is
//! allocated, so a corrupt or truncated header fails with a clear
//! error instead of an OOM-sized `Vec`.

use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::model::layout::ParamStore;
use crate::optim::adam::AdamState;
use crate::tensor::dtype::{bf16_to_f32, f32_to_bf16, DType};
use crate::util::bytes;
use crate::util::rng::RngState;

const MAGIC_V3: &[u8; 8] = b"SWLORA3\0";
const MAGIC_V2: &[u8; 8] = b"SWLORA2\0";
const MAGIC_V1: &[u8; 8] = b"SWLORA1\0";

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    // bulk copy via bytemuck-free manual chunking
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

/// Length-prefixed bf16 array: values are converted f32→bf16 on write
/// (exact for on-grid values, e.g. `--moments-dtype bf16` states).
fn write_bf16s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    let mut buf = Vec::with_capacity(xs.len() * 2);
    for x in xs {
        buf.extend_from_slice(&f32_to_bf16(*x).to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

/// Bounds-checked cursor over a fully-read checkpoint file.  Every
/// length or numel the header declares is validated against the bytes
/// actually remaining *before* any allocation happens, so corruption
/// surfaces as a clean error, never as an OOM-sized `Vec`.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(buf: &'a [u8]) -> Cur<'a> {
        Cur { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        ensure!(n <= self.remaining(),
                "corrupt or truncated checkpoint: {what} needs {n} more \
                 bytes but only {} remain", self.remaining());
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    fn str(&mut self, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        let b = self.take(len, what)?;
        String::from_utf8(b.to_vec())
            .context("non-utf8 string in checkpoint")
    }

    /// Declared element count, validated against the remaining bytes at
    /// `width` per element before anything is allocated.
    fn checked_len(&self, n: u64, width: usize, what: &str)
        -> Result<usize> {
        let n = usize::try_from(n)
            .map_err(|_| anyhow::anyhow!("{what}: absurd length {n}"))?;
        let bytes = n.checked_mul(width).ok_or_else(|| {
            anyhow::anyhow!("{what}: length {n} overflows")
        })?;
        ensure!(bytes <= self.remaining(),
                "corrupt or truncated checkpoint: {what} declares {n} \
                 elements ({bytes} bytes) but only {} bytes remain",
                self.remaining());
        Ok(n)
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.u64(what)?;
        let n = self.checked_len(n, 4, what)?;
        let b = self.take(n * 4, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// bf16 payload, widened to f32 (exact).
    fn bf16s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.u64(what)?;
        let n = self.checked_len(n, 2, what)?;
        let b = self.take(n * 2, what)?;
        Ok(b.chunks_exact(2)
            .map(|c| bf16_to_f32(u16::from_le_bytes([c[0], c[1]])))
            .collect())
    }

    /// int8 payload (codes + per-row scales), dequantized to f32.
    fn i8s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.u64(what)?;
        let n = self.checked_len(n, 1, what)?;
        let codes = self.take(n, what)?.to_vec();
        let rows = self.u64(what)?;
        let rows = self.checked_len(rows, 4, what)?;
        ensure!(rows > 0 && n % rows == 0,
                "corrupt checkpoint: {what} has {n} int8 codes over \
                 {rows} rows");
        let scales = self.f32s_exact(rows, what)?;
        let cols = n / rows;
        let mut out = Vec::with_capacity(n);
        for (r, chunk) in codes.chunks_exact(cols).enumerate() {
            let sc = scales[r];
            out.extend(chunk.iter().map(|&c| sc * c as i8 as f32));
        }
        Ok(out)
    }

    /// `n` raw f32s with no length prefix (int8 scale arrays).
    fn f32s_exact(&mut self, n: usize, what: &str) -> Result<Vec<f32>> {
        let b = self.take(n * 4, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// The resumable state of a training method, as written by
/// `TrainingMethod::save_state`: the registry name it must match on
/// resume, a payload version, and the opaque payload bytes.
#[derive(Clone, Debug)]
pub struct MethodState {
    /// method name (must equal the resuming run's method)
    pub name: String,
    /// payload schema version (must equal the method's `state_version`)
    pub version: u32,
    /// the method's serialized dynamic state
    pub payload: Vec<u8>,
}

/// The trainer's own resumable state: where to pick the loop back up and
/// the cross-step accumulators that are not derivable from the config.
#[derive(Clone, Debug)]
pub struct TrainerState {
    /// first step the resumed loop runs (== steps already completed)
    pub next_step: u64,
    /// leader RNG (init draws + any method draws already consumed)
    pub rng: RngState,
    /// training-loss EMA value
    pub ema_value: f64,
    /// whether the EMA has seen at least one sample
    pub ema_primed: bool,
    /// cumulative all-reduce traffic so far
    pub comm_bytes: u64,
    /// cumulative all-reduce rounds so far
    pub comm_rounds: u64,
}

/// Save weights only (plus optional optimizer state) — the plain
/// `--out` checkpoint path.
pub fn save(path: &Path, config_name: &str, store: &ParamStore,
            opt: Option<&AdamState>) -> Result<()> {
    save_full(path, config_name, store, opt, None, None)
}

/// Save a full (optionally resumable) checkpoint.  `method` and
/// `trainer` are present for `--ckpt-every` mid-run snapshots and absent
/// for final weight exports.
pub fn save_full(path: &Path, config_name: &str, store: &ParamStore,
                 opt: Option<&AdamState>, method: Option<&MethodState>,
                 trainer: Option<&TrainerState>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC_V3)?;
    write_str(&mut w, config_name)?;
    write_u64(&mut w, store.layout.params.len() as u64)?;
    for p in &store.layout.params {
        write_str(&mut w, &p.name)?;
        // master weights are checkpointed f32: resume must round-trip
        // the authoritative parameters bitwise
        w.write_all(&[DType::F32.tag()])?;
        write_f32s(&mut w, &store.data[p.offset..p.offset + p.numel])?;
    }
    match opt {
        Some(o) => {
            w.write_all(&[1u8])?;
            w.write_all(&[o.moments_dtype.tag()])?;
            match o.moments_dtype {
                // bf16 moments live on the bf16 grid, so the 2-byte
                // payload is exact — half the optimizer footprint
                DType::Bf16 => {
                    write_bf16s(&mut w, &o.m)?;
                    write_bf16s(&mut w, &o.v)?;
                }
                _ => {
                    write_f32s(&mut w, &o.m)?;
                    write_f32s(&mut w, &o.v)?;
                }
            }
            write_f32s(&mut w, &o.s)?;
        }
        None => w.write_all(&[0u8])?,
    }
    match method {
        Some(m) => {
            w.write_all(&[1u8])?;
            write_str(&mut w, &m.name)?;
            w.write_all(&m.version.to_le_bytes())?;
            write_u64(&mut w, m.payload.len() as u64)?;
            w.write_all(&m.payload)?;
        }
        None => w.write_all(&[0u8])?,
    }
    match trainer {
        Some(t) => {
            w.write_all(&[1u8])?;
            let mut payload = Vec::new();
            bytes::put_u64(&mut payload, t.next_step);
            bytes::put_rng(&mut payload, &t.rng);
            bytes::put_f64(&mut payload, t.ema_value);
            bytes::put_u8(&mut payload, u8::from(t.ema_primed));
            bytes::put_u64(&mut payload, t.comm_bytes);
            bytes::put_u64(&mut payload, t.comm_rounds);
            write_u64(&mut w, payload.len() as u64)?;
            w.write_all(&payload)?;
        }
        None => w.write_all(&[0u8])?,
    }
    w.flush()?;
    Ok(())
}

/// Checkpoint contents, layout-agnostic.
pub struct Checkpoint {
    pub config_name: String,
    pub params: Vec<(String, Vec<f32>)>,
    pub opt: Option<AdamState>,
    /// resumable method state (v2+ mid-run checkpoints only)
    pub method: Option<MethodState>,
    /// resumable trainer state (v2+ mid-run checkpoints only)
    pub trainer: Option<TrainerState>,
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    // slurp once: the file's real size bounds every later allocation
    let buf = std::fs::read(path)
        .with_context(|| format!("opening {}", path.display()))?;
    if buf.len() < 8 {
        bail!("{} is not a switchlora checkpoint", path.display());
    }
    let mut magic = [0u8; 8];
    magic.copy_from_slice(&buf[..8]);
    let version: u32 = if &magic == MAGIC_V3 {
        3
    } else if &magic == MAGIC_V2 {
        2
    } else if &magic == MAGIC_V1 {
        1
    } else {
        bail!("{} is not a switchlora checkpoint", path.display());
    };
    let mut r = Cur::new(&buf[8..]);
    let config_name = r.str("config name")?;
    let n = r.u64("param count")? as usize;
    // every param record costs >= 13 bytes; reject absurd counts before
    // reserving anything
    ensure!(n <= r.remaining() / 13 + 1,
            "corrupt checkpoint: {n} params declared in {} bytes",
            r.remaining());
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let name = r.str("param name")?;
        let data = if version >= 3 {
            let dtype = DType::from_tag(r.u8("param dtype")?)?;
            match dtype {
                DType::F32 => r.f32s(&name)?,
                DType::Bf16 => r.bf16s(&name)?,
                DType::I8 => r.i8s(&name)?,
            }
        } else {
            r.f32s(&name)?
        };
        params.push((name, data));
    }
    let opt = if r.u8("optimizer flag")? == 1 {
        let dtype = if version >= 3 {
            DType::from_tag(r.u8("moments dtype")?)?
        } else {
            DType::F32
        };
        let (m, v) = match dtype {
            DType::F32 => (r.f32s("opt.m")?, r.f32s("opt.v")?),
            DType::Bf16 => (r.bf16s("opt.m")?, r.bf16s("opt.v")?),
            DType::I8 => bail!("int8 Adam moments are not a thing this \
                                format supports"),
        };
        let s = r.f32s("opt.s")?;
        Some(AdamState::from_parts(m, v, s, dtype))
    } else {
        None
    };
    let (method, trainer) = if version >= 2 {
        let method = if r.u8("method flag")? == 1 {
            let name = r.str("method name")?;
            let ver = r.u32("method version")?;
            let len = r.u64("method payload")?;
            let len = r.checked_len(len, 1, "method payload")?;
            let payload = r.take(len, "method payload")?.to_vec();
            Some(MethodState { name, version: ver, payload })
        } else {
            None
        };
        let trainer = if r.u8("trainer flag")? == 1 {
            let len = r.u64("trainer payload")?;
            let len = r.checked_len(len, 1, "trainer payload")?;
            let payload = r.take(len, "trainer payload")?;
            let mut b = bytes::ByteReader::new(payload);
            let ts = TrainerState {
                next_step: b.u64()?,
                rng: b.rng()?,
                ema_value: b.f64()?,
                ema_primed: b.u8()? == 1,
                comm_bytes: b.u64()?,
                comm_rounds: b.u64()?,
            };
            b.finish()?;
            Some(ts)
        } else {
            None
        };
        (method, trainer)
    } else {
        (None, None)
    };
    Ok(Checkpoint { config_name, params, opt, method, trainer })
}

/// Outcome of [`Checkpoint::restore_into`]: how many checkpointed params
/// were copied, how many the target layout does not name at all, and how
/// many exist under the same name but with a different element count
/// (each mismatch is also logged with the offending parameter's name).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RestoreReport {
    /// params copied into the store
    pub loaded: usize,
    /// params absent from the target layout
    pub missing: usize,
    /// params present by name but with a different numel — skipped
    pub mismatched: usize,
}

impl Checkpoint {
    /// Copy parameters into a store by name.  A parameter whose name the
    /// layout knows but whose size disagrees is *not* silently treated as
    /// missing: it is counted separately and a warning names it, since it
    /// usually means the checkpoint came from a different spec/rank.
    pub fn restore_into(&self, store: &mut ParamStore) -> RestoreReport {
        let mut rep = RestoreReport::default();
        for (name, data) in &self.params {
            match store.layout.meta(name) {
                Ok(meta) if meta.numel == data.len() => {
                    let (off, n) = (meta.offset, meta.numel);
                    store.data[off..off + n].copy_from_slice(data);
                    rep.loaded += 1;
                }
                Ok(meta) => {
                    crate::warnlog!(
                        "checkpoint param {name:?}: {} elements but the \
                         target layout expects {} — skipped (different \
                         spec or rank?)", data.len(), meta.numel);
                    rep.mismatched += 1;
                }
                Err(_) => rep.missing += 1,
            }
        }
        rep
    }

    /// Return the checkpointed optimizer state after validating it
    /// against the runtime's buffer sizes: the fused-Adam kernel requires
    /// all moment arrays padded to exactly `padded` (>= `n_trainable`).
    /// A checkpoint written under a different padding would otherwise
    /// scatter moments to the wrong lanes and silently corrupt the run.
    pub fn opt_validated(&self, n_trainable: usize, padded: usize)
        -> Result<Option<AdamState>> {
        let Some(o) = &self.opt else { return Ok(None) };
        ensure!(o.m.len() == o.v.len() && o.m.len() == o.s.len(),
                "checkpoint optimizer state is internally inconsistent: \
                 m/v/s lengths {}/{}/{}", o.m.len(), o.v.len(),
                o.s.len());
        ensure!(o.m.len() == padded,
                "checkpoint optimizer state has {} elements but this \
                 runtime pads the fused-Adam buffers to {padded} \
                 (trainable {n_trainable}); it was written under a \
                 different padding and cannot be resumed safely",
                o.m.len());
        Ok(Some(o.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta, Role};
    use std::sync::Arc;

    fn toy_store(fill: f32) -> ParamStore {
        let layout = Layout::from_metas(vec![
            ParamMeta { name: "w".into(), shape: vec![2, 3],
                        role: Role::Base, trainable: true, numel: 6,
                        offset: 0, t_offset: None },
            ParamMeta { name: "n".into(), shape: vec![4], role: Role::Norm,
                        trainable: true, numel: 4, offset: 0,
                        t_offset: None },
        ]);
        let mut s = ParamStore::zeros(Arc::new(layout));
        for (i, x) in s.data.iter_mut().enumerate() {
            *x = fill + i as f32;
        }
        s
    }

    #[test]
    fn save_load_roundtrip_with_opt() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt");
        let path = dir.join("a.ckpt");
        let store = toy_store(10.0);
        let mut opt = AdamState::new(10, 16);
        opt.m[3] = 0.5;
        save(&path, "tiny", &store, Some(&opt)).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.config_name, "tiny");
        assert_eq!(ck.params.len(), 2);
        assert!(ck.method.is_none() && ck.trainer.is_none());
        let o = ck.opt.as_ref().unwrap();
        assert_eq!(o.m.len(), 16);
        assert_eq!(o.m[3], 0.5);
        let mut dst = toy_store(0.0);
        let rep = ck.restore_into(&mut dst);
        assert_eq!(rep, RestoreReport { loaded: 2, missing: 0,
                                        mismatched: 0 });
        assert_eq!(dst.data, store.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resumable_sections_roundtrip() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_v2");
        let path = dir.join("r.ckpt");
        let store = toy_store(1.0);
        let opt = AdamState::new(10, 16);
        let ms = MethodState {
            name: "switchlora".into(),
            version: 3,
            payload: vec![1, 2, 3, 4, 5],
        };
        let ts = TrainerState {
            next_step: 77,
            rng: RngState { s: [1, 2, 3, 4], spare_normal: Some(-0.25) },
            ema_value: 5.5,
            ema_primed: true,
            comm_bytes: 999,
            comm_rounds: 12,
        };
        save_full(&path, "tiny", &store, Some(&opt), Some(&ms), Some(&ts))
            .unwrap();
        let ck = load(&path).unwrap();
        let m = ck.method.as_ref().unwrap();
        assert_eq!((m.name.as_str(), m.version), ("switchlora", 3));
        assert_eq!(m.payload, vec![1, 2, 3, 4, 5]);
        let t = ck.trainer.as_ref().unwrap();
        assert_eq!(t.next_step, 77);
        assert_eq!(t.rng, ts.rng);
        assert_eq!((t.ema_value, t.ema_primed), (5.5, true));
        assert_eq!((t.comm_bytes, t.comm_rounds), (999, 12));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_legacy_v1_files() {
        // hand-write a v1 (SWLORA1) file with the old layout
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.ckpt");
        let store = toy_store(3.0);
        {
            let f = std::fs::File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            w.write_all(b"SWLORA1\0").unwrap();
            write_str(&mut w, "tiny").unwrap();
            write_u64(&mut w, store.layout.params.len() as u64).unwrap();
            for p in &store.layout.params {
                write_str(&mut w, &p.name).unwrap();
                write_f32s(&mut w,
                           &store.data[p.offset..p.offset + p.numel])
                    .unwrap();
            }
            w.write_all(&[0u8]).unwrap(); // no optimizer state
            w.flush().unwrap();
        }
        let ck = load(&path).unwrap();
        assert_eq!(ck.config_name, "tiny");
        assert!(ck.opt.is_none());
        assert!(ck.method.is_none() && ck.trainer.is_none());
        let mut dst = toy_store(0.0);
        let rep = ck.restore_into(&mut dst);
        assert_eq!(rep.loaded, 2);
        assert_eq!(dst.data, store.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_restore_distinguishes_missing_from_mismatch() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt2");
        let path = dir.join("b.ckpt");
        let store = toy_store(1.0);
        save(&path, "x", &store, None).unwrap();
        let mut ck = load(&path).unwrap();
        ck.params.push(("ghost".into(), vec![1.0])); // absent from layout
        ck.params.push(("n".into(), vec![1.0, 2.0])); // wrong numel (4)
        let mut dst = toy_store(0.0);
        let rep = ck.restore_into(&mut dst);
        assert_eq!(rep, RestoreReport { loaded: 2, missing: 1,
                                        mismatched: 1 });
        // the mismatched param was NOT partially copied
        assert_eq!(dst.slice("n").unwrap(), store.slice("n").unwrap());
        assert!(ck.opt.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn opt_validation_rejects_foreign_padding() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt3b");
        let path = dir.join("p.ckpt");
        let store = toy_store(0.0);
        let opt = AdamState::new(10, 16);
        save(&path, "x", &store, Some(&opt)).unwrap();
        let ck = load(&path).unwrap();
        // matching padding: accepted
        assert!(ck.opt_validated(10, 16).unwrap().is_some());
        // a runtime that pads to a different size: rejected loudly
        let err = ck.opt_validated(10, 8192).unwrap_err().to_string();
        assert!(err.contains("8192"), "{err}");
        // no optimizer state at all is fine (weights-only checkpoint)
        let ck2 = Checkpoint { config_name: "x".into(), params: vec![],
                               opt: None, method: None, trainer: None };
        assert!(ck2.opt_validated(10, 16).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn loads_legacy_v2_files() {
        // hand-write a v2 (SWLORA2) file: untagged f32 params, f32
        // optimizer arrays, empty method/trainer sections
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_v2rd");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old2.ckpt");
        let store = toy_store(4.0);
        let opt = AdamState::new(10, 12);
        {
            let f = std::fs::File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            w.write_all(b"SWLORA2\0").unwrap();
            write_str(&mut w, "tiny").unwrap();
            write_u64(&mut w, store.layout.params.len() as u64).unwrap();
            for p in &store.layout.params {
                write_str(&mut w, &p.name).unwrap();
                write_f32s(&mut w,
                           &store.data[p.offset..p.offset + p.numel])
                    .unwrap();
            }
            w.write_all(&[1u8]).unwrap();
            write_f32s(&mut w, &opt.m).unwrap();
            write_f32s(&mut w, &opt.v).unwrap();
            write_f32s(&mut w, &opt.s).unwrap();
            w.write_all(&[0u8]).unwrap(); // no method state
            w.write_all(&[0u8]).unwrap(); // no trainer state
            w.flush().unwrap();
        }
        let ck = load(&path).unwrap();
        assert_eq!(ck.config_name, "tiny");
        let o = ck.opt.as_ref().unwrap();
        assert_eq!(o.moments_dtype, crate::tensor::dtype::DType::F32);
        assert_eq!(o.m, opt.m);
        let mut dst = toy_store(0.0);
        assert_eq!(ck.restore_into(&mut dst).loaded, 2);
        assert_eq!(dst.data, store.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bf16_moments_roundtrip_exactly_and_halve_the_payload() {
        use crate::tensor::dtype::{round_through, DType};
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_bf16m");
        let p16 = dir.join("m16.ckpt");
        let p32 = dir.join("m32.ckpt");
        let store = toy_store(1.0);
        let mut o16 = AdamState::with_moments(10, 16, DType::Bf16);
        let mut o32 = AdamState::new(10, 16);
        for i in 0..16 {
            let x = 0.321 * (i as f32 - 7.5);
            // host_step keeps bf16 moments on-grid; mirror that here
            o16.m[i] = round_through(x, DType::Bf16);
            o16.v[i] = round_through(x * x, DType::Bf16);
            o32.m[i] = x;
            o32.v[i] = x * x;
        }
        save(&p16, "t", &store, Some(&o16)).unwrap();
        save(&p32, "t", &store, Some(&o32)).unwrap();
        let got = load(&p16).unwrap().opt.unwrap();
        assert_eq!(got.moments_dtype, DType::Bf16);
        // on-grid values survive the 2-byte payload bit for bit
        assert_eq!(got.m, o16.m);
        assert_eq!(got.v, o16.v);
        assert_eq!(got.s, o16.s);
        // and the file really is smaller: 2 arrays × 16 elems × 2 bytes
        let sz16 = std::fs::metadata(&p16).unwrap().len();
        let sz32 = std::fs::metadata(&p32).unwrap().len();
        assert_eq!(sz32 - sz16, 2 * 16 * 2, "{sz32} vs {sz16}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v3_reads_bf16_and_int8_tagged_params() {
        use crate::tensor::dtype::{DType, PackedBuf};
        // hand-write a v3 file with one bf16 and one int8 param — the
        // dtype-tagged payloads a packed export would carry
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_v3t");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tagged.ckpt");
        let wdata: Vec<f32> = (0..6).map(|i| 0.25 * i as f32 - 0.7)
            .collect();
        let ndata = [1.0f32, -2.5, 0.125, 3.0];
        {
            let f = std::fs::File::create(&path).unwrap();
            let mut w = BufWriter::new(f);
            w.write_all(b"SWLORA3\0").unwrap();
            write_str(&mut w, "tiny").unwrap();
            write_u64(&mut w, 2).unwrap();
            // "w": int8, 2 rows x 3 cols
            write_str(&mut w, "w").unwrap();
            w.write_all(&[DType::I8.tag()]).unwrap();
            let packed = PackedBuf::pack(&wdata, 2, 3, DType::I8);
            let PackedBuf::I8 { q, scales, .. } = &packed else {
                unreachable!()
            };
            write_u64(&mut w, q.len() as u64).unwrap();
            for c in q {
                w.write_all(&(*c as u8).to_le_bytes()).unwrap();
            }
            write_u64(&mut w, scales.len() as u64).unwrap();
            for sc in scales {
                w.write_all(&sc.to_le_bytes()).unwrap();
            }
            // "n": bf16
            write_str(&mut w, "n").unwrap();
            w.write_all(&[DType::Bf16.tag()]).unwrap();
            write_bf16s(&mut w, &ndata).unwrap();
            w.write_all(&[0u8]).unwrap(); // no optimizer
            w.write_all(&[0u8]).unwrap(); // no method
            w.write_all(&[0u8]).unwrap(); // no trainer
            w.flush().unwrap();
        }
        let ck = load(&path).unwrap();
        let packed = PackedBuf::pack(&wdata, 2, 3, DType::I8);
        assert_eq!(ck.params[0].1, packed.to_f32(), "int8 dequant");
        assert_eq!(ck.params[1].1, ndata, "bf16 (on-grid) exact");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_and_corrupt_headers_fail_cleanly() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ckpt");
        let store = toy_store(2.0);
        save(&path, "tiny", &store, Some(&AdamState::new(10, 16)))
            .unwrap();
        let full = std::fs::read(&path).unwrap();
        // truncation anywhere inside the file errors instead of OOMing
        for frac in [0.3, 0.6, 0.95] {
            let cut = (full.len() as f64 * frac) as usize;
            std::fs::write(&path, &full[..cut]).unwrap();
            let err = load(&path).unwrap_err().to_string();
            assert!(err.contains("truncated") || err.contains("corrupt")
                        || err.contains("checkpoint"),
                    "cut at {cut}: {err}");
        }
        // a header declaring an OOM-sized array must fail the length
        // validation (declared bytes > the whole remaining file)
        let mut evil = full.clone();
        // first param record: after magic(8) + "tiny"(4+4) + count(8)
        // comes name "w" (4+1) + dtype(1), then the u64 numel — poison it
        let numel_at = 8 + 8 + 8 + 5 + 1;
        evil[numel_at..numel_at + 8]
            .copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &evil).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("overflows") || err.contains("declares")
                    || err.contains("absurd"),
                "poisoned numel: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
