//! Checkpoints: binary save/load of a `ParamStore` (+ optional optimizer
//! state), keyed by parameter name so stores with different layouts (e.g.
//! LoRA pre-train → merged full fine-tune) can exchange weights.
//!
//! Format (little-endian):
//!   magic "SWLORA1\0" | config-name len+bytes | n_params
//!   then per param: name len+bytes | numel u64 | f32 data
//!   then opt flag u8; if 1: n u64 | m | v | s  (f32 arrays of length n)

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::layout::ParamStore;
use crate::optim::adam::AdamState;

const MAGIC: &[u8; 8] = b"SWLORA1\0";

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf).context("non-utf8 string in checkpoint")
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    // bulk copy via bytemuck-free manual chunking
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)?;
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let n = u64::from_le_bytes(len) as usize;
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn save(path: &Path, config_name: &str, store: &ParamStore,
            opt: Option<&AdamState>) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    write_str(&mut w, config_name)?;
    w.write_all(&(store.layout.params.len() as u64).to_le_bytes())?;
    for p in &store.layout.params {
        write_str(&mut w, &p.name)?;
        write_f32s(&mut w, &store.data[p.offset..p.offset + p.numel])?;
    }
    match opt {
        Some(o) => {
            w.write_all(&[1u8])?;
            write_f32s(&mut w, &o.m)?;
            write_f32s(&mut w, &o.v)?;
            write_f32s(&mut w, &o.s)?;
        }
        None => w.write_all(&[0u8])?,
    }
    w.flush()?;
    Ok(())
}

/// Checkpoint contents, layout-agnostic.
pub struct Checkpoint {
    pub config_name: String,
    pub params: Vec<(String, Vec<f32>)>,
    pub opt: Option<AdamState>,
}

pub fn load(path: &Path) -> Result<Checkpoint> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{} is not a switchlora checkpoint", path.display());
    }
    let config_name = read_str(&mut r)?;
    let mut nbuf = [0u8; 8];
    r.read_exact(&mut nbuf)?;
    let n = u64::from_le_bytes(nbuf) as usize;
    let mut params = Vec::with_capacity(n);
    for _ in 0..n {
        let name = read_str(&mut r)?;
        let data = read_f32s(&mut r)?;
        params.push((name, data));
    }
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let opt = if flag[0] == 1 {
        let m = read_f32s(&mut r)?;
        let v = read_f32s(&mut r)?;
        let s = read_f32s(&mut r)?;
        Some(AdamState { m, v, s })
    } else {
        None
    };
    Ok(Checkpoint { config_name, params, opt })
}

impl Checkpoint {
    /// Copy parameters into a store by name; returns (#loaded, #missing).
    pub fn restore_into(&self, store: &mut ParamStore) -> (usize, usize) {
        let mut loaded = 0;
        let mut missing = 0;
        for (name, data) in &self.params {
            match store.layout.meta(name) {
                Ok(meta) if meta.numel == data.len() => {
                    let (off, n) = (meta.offset, meta.numel);
                    store.data[off..off + n].copy_from_slice(data);
                    loaded += 1;
                }
                _ => missing += 1,
            }
        }
        (loaded, missing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta, Role};
    use std::sync::Arc;

    fn toy_store(fill: f32) -> ParamStore {
        let layout = Layout::from_metas(vec![
            ParamMeta { name: "w".into(), shape: vec![2, 3],
                        role: Role::Base, trainable: true, numel: 6,
                        offset: 0, t_offset: None },
            ParamMeta { name: "n".into(), shape: vec![4], role: Role::Norm,
                        trainable: true, numel: 4, offset: 0,
                        t_offset: None },
        ]);
        let mut s = ParamStore::zeros(Arc::new(layout));
        for (i, x) in s.data.iter_mut().enumerate() {
            *x = fill + i as f32;
        }
        s
    }

    #[test]
    fn save_load_roundtrip_with_opt() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt");
        let path = dir.join("a.ckpt");
        let store = toy_store(10.0);
        let mut opt = AdamState::new(10, 16);
        opt.m[3] = 0.5;
        save(&path, "tiny", &store, Some(&opt)).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.config_name, "tiny");
        assert_eq!(ck.params.len(), 2);
        let o = ck.opt.as_ref().unwrap();
        assert_eq!(o.m.len(), 16);
        assert_eq!(o.m[3], 0.5);
        let mut dst = toy_store(0.0);
        let (loaded, missing) = ck.restore_into(&mut dst);
        assert_eq!((loaded, missing), (2, 0));
        assert_eq!(dst.data, store.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_restore_counts_missing() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt2");
        let path = dir.join("b.ckpt");
        let store = toy_store(1.0);
        save(&path, "x", &store, None).unwrap();
        let mut ck = load(&path).unwrap();
        ck.params.push(("ghost".into(), vec![1.0]));
        let mut dst = toy_store(0.0);
        let (loaded, missing) = ck.restore_into(&mut dst);
        assert_eq!((loaded, missing), (2, 1));
        assert!(ck.opt.is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("switchlora_test_ckpt3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
