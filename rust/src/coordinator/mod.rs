//! L3 coordinator: training orchestration (`trainer.rs`), the simulated
//! data-parallel runtime with ring all-reduce (`data_parallel.rs`),
//! evaluation (`eval.rs`), checkpointing (`checkpoint.rs`) and metrics
//! (`metrics.rs`).

pub mod checkpoint;
pub mod data_parallel;
pub mod eval;
pub mod metrics;
pub mod trainer;
