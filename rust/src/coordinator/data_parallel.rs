//! Data-parallel runtime: real per-worker OS threads, real ring
//! all-reduce, measured bytes.
//!
//! The paper's communication claim (Appendix F, the abstract's "54% less
//! communication") is about data-parallel gradient synchronization, whose
//! volume is proportional to the number of *trainable* parameters.  This
//! module makes that measurable: `w` workers each produce a gradient vector
//! for their shard; `ring_all_reduce` then runs the standard two-phase ring
//! (reduce-scatter + all-gather) over the actual buffers, counting every
//! byte that crosses a "link".
//!
//! Workers are no longer interleaved on one thread: the native backend's
//! `fwdbwd_multi` fans each shard's fwd/bwd onto its own OS thread
//! (`kernels::scoped_map`, capped by `--threads`) before the all-reduce,
//! so `--workers W` scales wall-clock.  Per-shard arithmetic is
//! unchanged and the ring still runs on the leader after all shards
//! finish, so losses and the byte ledger are bitwise identical to the
//! interleaved schedule (`rust/tests/determinism_threads.rs` pins this).
//!
//! **Wire dtype.**  The ring is parameterized by the payload dtype
//! (`--comm-dtype`): with `f32` every element crosses a link at 4 bytes
//! and values are untouched (the bitwise-legacy path); with `bf16` each
//! payload is rounded through bf16 before it crosses (round-to-nearest-
//! even, the paper's gradient wire format) and the ledger counts 2
//! bytes/element — exactly half the f32 volume, which the comm tests
//! pin down.  The ledger, `expected_ring_bytes` and the CSV/eval-log
//! comm columns all report *true* bytes at the configured width.

use crate::tensor::dtype::{round_through, DType};

/// Per-step communication ledger.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommLedger {
    /// total bytes that crossed links this run
    pub bytes: u64,
    /// number of all-reduce invocations
    pub rounds: u64,
}

impl CommLedger {
    pub fn bytes_per_round(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.bytes as f64 / self.rounds as f64
        }
    }
}

/// In-place ring all-reduce (average) across `grads` (one vector per
/// worker, all the same length).  After the call every worker holds the
/// element-wise mean.  `wire` is the link dtype: `F32` moves exact
/// values at 4 bytes/element; `Bf16` rounds every payload element
/// through bf16 as it crosses a link and counts 2 bytes/element.
/// Returns bytes moved at the wire width.
pub fn ring_all_reduce(grads: &mut [Vec<f32>], ledger: &mut CommLedger,
                       wire: DType) -> u64 {
    assert!(matches!(wire, DType::F32 | DType::Bf16),
            "ring wire dtype must be f32 or bf16");
    let w = grads.len();
    assert!(w > 0);
    let n = grads[0].len();
    assert!(grads.iter().all(|g| g.len() == n), "ragged gradient vectors");
    if w == 1 {
        ledger.rounds += 1;
        crate::obs::comm_round(0, n, 1, wire);
        return 0;
    }
    let width = wire.bytes() as u64;
    // a payload element as it arrives on the other side of a link
    let onto_wire = |xs: &[f32]| -> Vec<f32> {
        match wire {
            DType::F32 => xs.to_vec(),
            _ => xs.iter().map(|&x| round_through(x, wire)).collect(),
        }
    };
    // chunk boundaries: chunk c = [starts[c], starts[c+1])
    let starts: Vec<usize> = (0..=w).map(|c| c * n / w).collect();
    let mut moved = 0u64;
    // --- phase 1: reduce-scatter ---
    // round t: worker i sends chunk (i - t) to worker (i + 1)
    for t in 0..w - 1 {
        // compute all sends first (simultaneous round)
        let mut sends: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(w);
        for i in 0..w {
            let c = (i + w - t) % w;
            let (s, e) = (starts[c], starts[c + 1]);
            sends.push(((i + 1) % w, c, onto_wire(&grads[i][s..e])));
            moved += width * (e - s) as u64;
        }
        for (dst, c, data) in sends {
            let (s, e) = (starts[c], starts[c + 1]);
            for (x, y) in grads[dst][s..e].iter_mut().zip(&data) {
                *x += y;
            }
        }
    }
    // now worker i holds the fully-reduced chunk (i + 1) % w
    // --- phase 2: all-gather ---
    // the reduced chunk leaves its owner through the wire dtype; round
    // the owner's local copy the same way (rounding is idempotent), so
    // every worker ends the all-reduce with identical values — worker
    // divergence here would silently fork a data-parallel run
    if !matches!(wire, DType::F32) {
        for (i, g) in grads.iter_mut().enumerate() {
            let c = (i + 1) % w;
            for x in g[starts[c]..starts[c + 1]].iter_mut() {
                *x = round_through(*x, wire);
            }
        }
    }
    for t in 0..w - 1 {
        let mut sends: Vec<(usize, usize, Vec<f32>)> = Vec::with_capacity(w);
        for i in 0..w {
            let c = (i + 1 + w - t) % w;
            let (s, e) = (starts[c], starts[c + 1]);
            sends.push(((i + 1) % w, c, onto_wire(&grads[i][s..e])));
            moved += width * (e - s) as u64;
        }
        for (dst, c, data) in sends {
            let (s, e) = (starts[c], starts[c + 1]);
            grads[dst][s..e].copy_from_slice(&data);
        }
    }
    // average
    let inv = 1.0 / w as f32;
    for g in grads.iter_mut() {
        for x in g.iter_mut() {
            *x *= inv;
        }
    }
    ledger.bytes += moved;
    ledger.rounds += 1;
    crate::obs::comm_round(moved, n, w, wire);
    moved
}

/// Theoretical ring volume at a wire dtype: 2·(w−1)/w of the buffer per
/// worker, summed, at `wire.bytes()` per element.  Chunks are n/w ± 1,
/// so the accounting mirrors the implementation's exact chunk
/// boundaries instead of approximating.
pub fn expected_ring_bytes(n_elems: usize, w: usize, wire: DType) -> u64 {
    if w <= 1 {
        return 0;
    }
    let width = wire.bytes() as u64;
    let starts: Vec<usize> = (0..=w).map(|c| c * n_elems / w).collect();
    // reduce-scatter: (w−1) rounds, every worker sends one chunk per round
    let mut total = 0u64;
    for t in 0..(w - 1) {
        for i in 0..w {
            let c = (i + w - t) % w;
            total += width * (starts[c + 1] - starts[c]) as u64;
        }
    }
    total * 2 // the all-gather phase moves the same volume
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn make_grads(w: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..w)
            .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect()
    }

    #[test]
    fn all_workers_get_the_mean() {
        for (w, n) in [(2, 10), (3, 17), (4, 64), (5, 5)] {
            let mut grads = make_grads(w, n, w as u64);
            let want: Vec<f32> = (0..n)
                .map(|j| {
                    grads.iter().map(|g| g[j]).sum::<f32>() / w as f32
                })
                .collect();
            let mut ledger = CommLedger::default();
            ring_all_reduce(&mut grads, &mut ledger, DType::F32);
            for (i, g) in grads.iter().enumerate() {
                for (a, b) in g.iter().zip(&want) {
                    assert!((a - b).abs() < 1e-4,
                            "worker {i}: {a} vs {b} (w={w} n={n})");
                }
            }
        }
    }

    #[test]
    fn byte_volume_matches_theory() {
        for (w, n) in [(2, 1000), (4, 999), (8, 4096)] {
            let mut grads = make_grads(w, n, 7);
            let mut ledger = CommLedger::default();
            let moved = ring_all_reduce(&mut grads, &mut ledger,
                                        DType::F32);
            assert_eq!(moved, expected_ring_bytes(n, w, DType::F32));
            // aggregate volume ≈ 2 phases · (w−1) rounds · w senders ·
            // (n/w elems) · 4 bytes = 8·(w−1)·n bytes
            let approx = 8.0 * (w - 1) as f64 * n as f64;
            assert!((moved as f64 - approx).abs() / approx < 0.05,
                    "w={w}: {moved} vs {approx}");
        }
    }

    #[test]
    fn bf16_wire_moves_exactly_half_the_bytes() {
        // the --comm-dtype bf16 ledger claim: same ring, same chunking,
        // half the measured volume — exactly, not approximately
        for (w, n) in [(2, 1000), (3, 997), (4, 4096), (5, 63)] {
            let mut a = make_grads(w, n, 11);
            let mut b = a.clone();
            let mut ledger = CommLedger::default();
            let f32_moved = ring_all_reduce(&mut a, &mut ledger,
                                            DType::F32);
            let bf16_moved = ring_all_reduce(&mut b, &mut ledger,
                                             DType::Bf16);
            assert_eq!(f32_moved, 2 * bf16_moved, "w={w} n={n}");
            assert_eq!(bf16_moved, expected_ring_bytes(n, w, DType::Bf16));
            assert_eq!(expected_ring_bytes(n, w, DType::F32),
                       2 * expected_ring_bytes(n, w, DType::Bf16));
            assert_eq!(ledger.bytes, f32_moved + bf16_moved);
        }
    }

    #[test]
    fn bf16_wire_still_averages_correctly() {
        let (w, n) = (4, 257);
        let mut grads = make_grads(w, n, 5);
        let want: Vec<f32> = (0..n)
            .map(|j| grads.iter().map(|g| g[j]).sum::<f32>() / w as f32)
            .collect();
        let mut ledger = CommLedger::default();
        ring_all_reduce(&mut grads, &mut ledger, DType::Bf16);
        for g in &grads {
            for (a, b) in g.iter().zip(&want) {
                // bf16 rounding error scales with the ~N(0,1) summand
                // magnitudes (not the mean), so the bound needs an
                // absolute term: measured worst case is ~0.01 here
                assert!((a - b).abs() <= 0.05 * b.abs() + 0.02,
                        "{a} vs {b}");
            }
        }
        // all workers agree exactly (the all-gather broadcast wins)
        for g in &grads[1..] {
            assert_eq!(g, &grads[0]);
        }
    }

    #[test]
    fn single_worker_is_free() {
        let mut grads = make_grads(1, 100, 1);
        let before = grads[0].clone();
        let mut ledger = CommLedger::default();
        assert_eq!(ring_all_reduce(&mut grads, &mut ledger, DType::F32),
                   0);
        assert_eq!(grads[0], before);
        assert_eq!(ledger.rounds, 1);
    }

    #[test]
    fn lora_reduces_measured_traffic_proportionally() {
        // The paper's claim, measured: traffic ratio == trainable ratio.
        let (full_n, lora_n, w) = (10_000, 4_600, 4);
        let mut a = make_grads(w, full_n, 2);
        let mut b = make_grads(w, lora_n, 3);
        let mut ledger = CommLedger::default();
        let full_bytes =
            ring_all_reduce(&mut a, &mut ledger, DType::F32) as f64;
        let lora_bytes =
            ring_all_reduce(&mut b, &mut ledger, DType::F32) as f64;
        let ratio = lora_bytes / full_bytes;
        assert!((ratio - 0.46).abs() < 0.01, "ratio {ratio}");
        assert_eq!(ledger.rounds, 2);
    }
}
