//! Evaluation: fixed-set validation loss / perplexity.

use anyhow::Result;

use crate::data::dataset::EvalSet;
use crate::model::layout::ParamStore;
use crate::runtime::ModelRuntime;

/// Mean validation loss over the (fixed) evaluation set.  Parameter
/// literals are marshaled once for the whole set (§Perf L3).
pub fn eval_loss(rt: &ModelRuntime, store: &ParamStore, set: &EvalSet)
    -> Result<f32> {
    let batches: Vec<(&[i32], usize, usize)> = set
        .batches
        .iter()
        .map(|b| (b.tokens.as_slice(), b.batch, b.seq_plus_1))
        .collect();
    let losses = rt.eval_loss_multi(store, &batches)?;
    Ok((losses.iter().map(|&l| l as f64).sum::<f64>()
        / losses.len() as f64) as f32)
}

/// Classification accuracy + loss over pre-drawn (tokens, labels) batches.
pub fn eval_cls(rt: &ModelRuntime, store: &ParamStore,
                batches: &[(Vec<i32>, Vec<i32>)], seq: usize)
    -> Result<(f32, f32)> {
    let mut loss = 0.0f64;
    let mut correct = 0.0f64;
    let mut total = 0usize;
    for (toks, labels) in batches {
        let bsz = labels.len();
        let (l, c) = rt.cls_eval(store, toks, labels, bsz, seq)?;
        loss += l as f64;
        correct += c as f64;
        total += bsz;
    }
    Ok(((loss / batches.len() as f64) as f32,
        (correct / total as f64) as f32))
}
