//! Little-endian byte (de)serialization helpers for resumable state.
//!
//! Method state (`TrainingMethod::save_state`) and the trainer's own
//! resume section are packed into flat byte payloads embedded in the
//! checkpoint file.  These helpers keep every payload in one dialect:
//! length-prefixed arrays/strings, fixed-width little-endian scalars,
//! and a cursor-style reader that errors (instead of panicking) on
//! truncated input so a corrupt checkpoint surfaces as a clean error.

use anyhow::{anyhow, bail, Context, Result};

use crate::util::rng::RngState;

/// Append a `u8`.
pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

/// Append a little-endian `u32`.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `u64`.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `f64`.
pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a length-prefixed `f32` array.
pub fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append a length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend_from_slice(s.as_bytes());
}

/// Append a full RNG snapshot (the one encoding every resumable
/// component shares: four state words + the optional Box-Muller spare).
pub fn put_rng(out: &mut Vec<u8>, st: &RngState) {
    for w in st.s {
        put_u64(out, w);
    }
    match st.spare_normal {
        Some(z) => {
            put_u8(out, 1);
            put_f64(out, z);
        }
        None => put_u8(out, 0),
    }
}

/// Cursor over a byte payload; every read is bounds-checked.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Start reading `buf` from the beginning.
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked: a corrupt length prefix must error, never wrap
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow!("truncated state payload: wanted {n} bytes at \
                         offset {}, have {}", self.pos,
                        self.buf.len() - self.pos)
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    /// Read a little-endian `f64`.
    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes(b.try_into().expect("8 bytes")))
    }

    /// Read a length-prefixed `f32` array.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let nbytes = n.checked_mul(4).ok_or_else(|| {
            anyhow!("corrupt f32-array length {n} in state payload")
        })?;
        let b = self.take(nbytes)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Read a length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).context("non-utf8 string in state")
    }

    /// Read an RNG snapshot written by [`put_rng`].
    pub fn rng(&mut self) -> Result<RngState> {
        let mut s = [0u64; 4];
        for w in s.iter_mut() {
            *w = self.u64()?;
        }
        let spare_normal = if self.u8()? == 1 {
            Some(self.f64()?)
        } else {
            None
        };
        Ok(RngState { s, spare_normal })
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Assert the whole payload was consumed (trailing garbage means a
    /// version mismatch the length prefix didn't catch).
    pub fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("state payload has {} unread trailing bytes",
                  self.buf.len() - self.pos);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_types() {
        let mut out = Vec::new();
        put_u8(&mut out, 7);
        put_u32(&mut out, 0xDEAD);
        put_u64(&mut out, u64::MAX - 3);
        put_f64(&mut out, -0.5);
        put_f32s(&mut out, &[1.0, -2.5, 3.25]);
        put_str(&mut out, "switchlora");
        let mut r = ByteReader::new(&out);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f64().unwrap(), -0.5);
        assert_eq!(r.f32s().unwrap(), vec![1.0, -2.5, 3.25]);
        assert_eq!(r.str().unwrap(), "switchlora");
        r.finish().unwrap();
    }

    #[test]
    fn truncation_errors_cleanly() {
        let mut out = Vec::new();
        put_u64(&mut out, 100); // claims a 100-element array follows
        let mut r = ByteReader::new(&out);
        assert!(r.f32s().is_err());
    }

    #[test]
    fn corrupt_length_prefixes_error_not_wrap() {
        // a near-usize::MAX length must error, not overflow into a tiny
        // read (n * 4 wraps) or an out-of-bounds panic (pos + n wraps)
        let mut out = Vec::new();
        put_u64(&mut out, u64::MAX - 2);
        out.extend_from_slice(&[0u8; 16]);
        let mut r = ByteReader::new(&out);
        assert!(r.f32s().is_err());
        let mut out2 = Vec::new();
        put_u64(&mut out2, u64::MAX - 2);
        let mut r2 = ByteReader::new(&out2);
        assert!(r2.str().is_err());
    }

    #[test]
    fn rng_state_roundtrip() {
        use crate::util::rng::RngState;
        for st in [
            RngState { s: [1, 2, 3, u64::MAX], spare_normal: Some(0.75) },
            RngState { s: [9, 8, 7, 6], spare_normal: None },
        ] {
            let mut out = Vec::new();
            put_rng(&mut out, &st);
            let mut r = ByteReader::new(&out);
            assert_eq!(r.rng().unwrap(), st);
            r.finish().unwrap();
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut out = Vec::new();
        put_u32(&mut out, 1);
        put_u8(&mut out, 9);
        let mut r = ByteReader::new(&out);
        r.u32().unwrap();
        assert_eq!(r.remaining(), 1);
        assert!(r.finish().is_err());
    }
}
