//! Lightweight leveled logging + wall-clock timers.
//!
//! Level is read once from `SWITCHLORA_LOG` (error|warn|info|debug|trace,
//! default info).  Output goes to stderr so CSV/table output on stdout stays
//! machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    INIT.get_or_init(|| {
        START.get_or_init(Instant::now);
        if let Ok(v) = std::env::var("SWITCHLORA_LOG") {
            let lvl = match v.to_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

pub fn set_level(l: Level) {
    init();
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    init();
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments) {
    if enabled(l) {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! errorlog {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   format_args!($($arg)*))
    };
}

/// Scope timer: accumulates elapsed time across start/stop cycles.
#[derive(Debug, Clone)]
pub struct Timer {
    pub name: &'static str,
    total: f64,
    count: u64,
    started: Option<Instant>,
}

impl Timer {
    pub fn new(name: &'static str) -> Self {
        Timer { name, total: 0.0, count: 0, started: None }
    }

    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.total += t.elapsed().as_secs_f64();
            self.count += 1;
        }
    }

    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }

    pub fn total_secs(&self) -> f64 {
        self.total
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ms(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            1e3 * self.total / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates() {
        let mut t = Timer::new("x");
        for _ in 0..3 {
            t.time(|| std::thread::sleep(std::time::Duration::from_millis(2)));
        }
        assert_eq!(t.count(), 3);
        assert!(t.total_secs() >= 0.006);
        assert!(t.mean_ms() >= 2.0);
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut t = Timer::new("y");
        t.stop();
        assert_eq!(t.count(), 0);
    }
}
