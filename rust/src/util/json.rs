//! Minimal JSON substrate (parser + writer) — `serde_json` is not in the
//! offline vendor set, and the manifest/metrics formats only need a small,
//! strict subset of JSON.
//!
//! Supports: objects, arrays, strings (with `\uXXXX` escapes), numbers,
//! booleans, null.  Numbers are stored as `f64`; every integer in our
//! manifests is < 2^53 so round-tripping is exact.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&s).with_context(|| format!("parsing {}", path.display()))
    }

    // ----- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    // ----- constructors ---------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- writer -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, found {:?}", c as char, self.i,
                  self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                        b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| {
            format!("bad number {s:?} at byte {start}")
        })?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp)
                                .ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // re-decode UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = (start + width).min(self.b.len());
                        let s = std::str::from_utf8(&self.b[start..end])?;
                        out.push_str(s);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' found {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' found {:?}", c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(),
                   Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#)
            .unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize().unwrap(), 1);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"s4m","shape":[256,64],"trainable":true,
                      "lr":0.02,"nested":{"x":[]},"s":"q\"uote"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse(r#""été café ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "été café ☕");
    }

    #[test]
    fn integers_roundtrip_exactly() {
        let j = Json::parse("5513216").unwrap();
        assert_eq!(j.to_string(), "5513216");
        assert_eq!(j.as_usize().unwrap(), 5_513_216);
    }
}
