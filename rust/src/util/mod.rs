//! Shared utilities: RNG, JSON, logging/timing, property-test harness,
//! byte (de)serialization for resumable state.

pub mod bytes;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;

/// Format a byte count as a human-readable string.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// [`human_bytes`] for fractional byte quantities (rates like
/// bytes/step): keeps sub-unit precision instead of truncating small
/// rates to "0B".
pub fn human_bytes_f64(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    if b.is_nan() || b <= 0.0 {
        return "0B".to_string();
    }
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 && v.fract() == 0.0 {
        format!("{}B", v as u64)
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Replace control characters with `·` so decoded model output (arbitrary
/// bytes under a random or half-trained checkpoint) stays terminal-safe.
pub fn printable(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_control() { '·' } else { c })
        .collect()
}

/// Format a parameter count with M/B suffixes (paper-table style).
pub fn human_params(n: u64) -> String {
    if n >= 1_000_000_000 {
        format!("{:.1}B", n as f64 / 1e9)
    } else if n >= 1_000_000 {
        format!("{:.1}M", n as f64 / 1e6)
    } else if n >= 1_000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(2048), "2.0KB");
        assert_eq!(human_bytes(95_600_000), "91.2MB");
    }

    #[test]
    fn fractional_bytes_keep_sub_unit_precision() {
        assert_eq!(human_bytes_f64(0.0), "0B");
        assert_eq!(human_bytes_f64(0.5), "0.5B");
        assert_eq!(human_bytes_f64(512.0), "512B");
        assert_eq!(human_bytes_f64(4096.0), "4.0KB");
        assert_eq!(human_bytes_f64(2048.0 * 1024.0), "2.0MB");
        assert_eq!(human_bytes_f64(-3.0), "0B");
    }

    #[test]
    fn printable_scrubs_control_chars() {
        assert_eq!(printable("a\nb\u{7}c"), "a·b·c");
        assert_eq!(printable("plain text"), "plain text");
    }

    #[test]
    fn params_formatting() {
        assert_eq!(human_params(1_339_500_000), "1.3B");
        assert_eq!(human_params(610_000_000), "610.0M");
        assert_eq!(human_params(999), "999");
    }
}
