//! Deterministic RNG substrate: xoshiro256** seeded via splitmix64.
//!
//! Every stochastic component in the system (init, data generation, switch
//! index sampling, data-parallel sharding) takes an explicit `Rng` so runs
//! are bit-reproducible from a single seed.  No external crates — the
//! offline vendor set has no `rand`.

/// xoshiro256** PRNG (Blackman & Vigna), seeded with splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// A serializable snapshot of a [`Rng`]'s full state (for checkpoint /
/// resume): the four xoshiro words plus the cached Box-Muller spare, so a
/// restored generator continues the exact same stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    /// the xoshiro256** state words
    pub s: [u64; 4],
    /// cached second normal from Box-Muller, if one is pending
    pub spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Snapshot the generator state (checkpoint/resume).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator from a [`RngState`] snapshot; it continues the
    /// stream exactly where `state()` left off.
    pub fn from_state(st: RngState) -> Rng {
        Rng { s: st.s, spare_normal: st.spare_normal }
    }

    /// Derive an independent child stream (e.g. per worker / per layer).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).  n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64).
        (self.next_u64() % n as u64) as usize
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let t = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * t.sin());
            return r * t.cos();
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        if k * 4 >= n {
            // dense: partial Fisher-Yates
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            // sparse: rejection
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.below(n);
                if seen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }

    /// Sample from an (unnormalized) discrete distribution.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf(s) sampler over [0, n) using inverse-CDF binary search.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cdf.partition_point(|&c| c < u)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_snapshot_resumes_stream() {
        let mut a = Rng::new(9);
        for _ in 0..7 {
            a.normal(); // leaves a Box-Muller spare pending
        }
        let st = a.state();
        let mut b = Rng::from_state(st);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_eq!(a.normal(), b.normal());
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(9);
        for (n, k) in [(10, 10), (100, 3), (5, 0), (1000, 900)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in sample");
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(11);
        let z = Zipf::new(100, 1.2);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[60]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
