//! Property-based testing harness (proptest is not in the offline vendor
//! set; this provides the same methodology: seeded generative cases with a
//! reproduction message on failure).
//!
//! ```ignore
//! prop_check("matmul associates with identity", 100, |rng| {
//!     let n = 1 + rng.below(16);
//!     // ... build case, return Err(msg) on violation ...
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Base seed: override with SWITCHLORA_PROP_SEED to replay a failure.
fn base_seed() -> u64 {
    std::env::var("SWITCHLORA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `cases` generated checks.  The property receives a fresh seeded RNG
/// per case; return `Err(description)` to fail.  Panics with the case seed
/// so failures are replayable via `SWITCHLORA_PROP_SEED`.
pub fn prop_check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} \
                 (SWITCHLORA_PROP_SEED={base}): {msg}"
            );
        }
    }
}

/// Convenience: assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32)
    -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!(
                "element {i}: {x} vs {y} (|diff|={} tol={tol})",
                (x - y).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        prop_check("u64 xor self is zero", 50, |rng| {
            let x = rng.next_u64();
            if x ^ x == 0 {
                Ok(())
            } else {
                Err("xor broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn fails_false_property() {
        prop_check("always fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6)
            .is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-5, 1e-6).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-5, 1e-6).is_err());
    }
}
