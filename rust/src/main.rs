//! `switchlora` — the leader binary / launcher.
//!
//! ```text
//! switchlora pretrain --spec s1m --method switchlora --steps 400
//!            [--lr 2e-2] [--workers 4] [--full-warmup 0] [--out ckpt.bin]
//!            [--csv curve.csv] [--init switchlora|lora_default]
//!            [--ckpt-every 100 [--ckpt-path resume.ckpt]]
//!            [--resume resume.ckpt]
//!            [--precision f32|bf16] [--comm-dtype f32|bf16]
//!            [--moments-dtype f32|bf16]
//!   `--threads N` (any subcommand; or SWITCHLORA_THREADS=N) sizes the
//!   kernel thread pool — default is the detected hardware parallelism,
//!   1 forces the serial reference path; results are bitwise identical
//!   either way.  `--workers W` shards each step across W data-parallel
//!   workers, each on its own OS thread.
//!   methods (see `switchlora info` for the live registry):
//!     full | lora
//!     switchlora  [--interval0 40] [--ratio 0.1] [--nfreeze 5]
//!     relora      [--reset-interval 500] [--rewarm 50]
//!     galore      [--galore-rank 0] [--update-freq 200]
//!                 [--galore-scale 0.25]
//!     prelora     [--full-layers K]      # first K layers full-rank
//!     warmstart   [--inner lora] [--warm-steps 100] + inner's flags
//!   `--ckpt-every N` writes a resumable checkpoint (weights + optimizer
//!   + method state + step clock) every N steps; `--resume` continues a
//!   killed run mid-schedule with identical losses.  A literal `{step}`
//!   in --ckpt-path keeps every snapshot instead of overwriting.
//! switchlora finetune --spec s1m --ckpt ckpt.bin --from lora
//!            [--tasks majority,contains,...] [--steps 150] [--lr 1e-3]
//! switchlora eval --spec s1m --ckpt ckpt.bin --variant lora
//! switchlora rank --spec s1m --ckpt ckpt.bin --variant lora
//! switchlora generate --spec tiny [--ckpt ckpt.bin] [--variant lora]
//!            [--merge] [--quantize-base int8|bf16] [--int8-native]
//!            [--kv-dtype f32|bf16|int8] [--max-context N]
//!            [--prompt "text"] [--max-new 64] [--batch 4]
//!            [--temperature 0.8] [--top-k 40] [--stop 0,10] [--seed 42]
//! switchlora serve --spec tiny [--ckpt ckpt.bin [--base-variant full|lora]]
//!            [--adapter NAME=PATH | NAME=seed:N]...   # repeatable
//!            [--host 127.0.0.1] [--port 8080] [--max-batch 4]
//!            [--queue-depth 16] [--max-context 256] [--max-new 64]
//!            [--prefill-chunk 32] [--kv-block 32]
//!            [--prefix-cache on|off] [--prefix-cache-blocks 128]
//!            [--quantize-base int8|bf16|f32]   # default: int8
//!   continuous-batching HTTP server: N named LoRA adapters multiplexed
//!   over ONE shared (int8 by default) frozen base.  POST /v1/generate
//!   streams NDJSON tokens; GET /healthz, GET /v1/adapters, POST
//!   /admin/drain; SIGTERM drains gracefully.
//! switchlora report TRACE.jsonl  # summarize a --trace-out trace
//! switchlora tables            # analytic Tables 4/5 + App. D/F
//! switchlora info              # list specs + the method registry
//! ```
//!
//! Any subcommand accepts `--trace-out PATH [--trace-format
//! jsonl|chrome]`: a structured telemetry trace (phase spans, comm
//! rounds, switch audit, memory ledgers) with zero effect on the math —
//! traced runs are bitwise identical to untraced ones.  `jsonl` feeds
//! `switchlora report` / `tools/trace_check.py`; `chrome` loads in
//! Perfetto or `chrome://tracing`.

use std::path::PathBuf;

use anyhow::{bail, Result};

use switchlora::cli::{check_spec, csv_list, Args};
use switchlora::coordinator::checkpoint;
use switchlora::coordinator::metrics::comm_summary;
use switchlora::coordinator::trainer::{default_artifacts_dir, TrainConfig};
use switchlora::data::tasks::Task;
use switchlora::data::tokenizer::{ByteTokenizer, Tokenizer};
use switchlora::exp;
use switchlora::infer::{generate_stream, merged_full_store, GenConfig,
                        Sampler};
use switchlora::model::analytics as an;
use switchlora::model::config::ModelConfig;
use switchlora::model::init::{seeded_store, InitMode};
use switchlora::model::layout::{Manifest, ParamStore, Variant};
use switchlora::model::packed::{PackedStore, ParamSource};
use switchlora::runtime::{load_infer_with, Engine};
use switchlora::serve::{AdapterRegistry, BaseSource, ServeConfig, Server};
use switchlora::tensor::dtype::{DType, PrecisionPolicy};
use switchlora::util::{human_bytes, human_params, printable};

fn main() {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    if let Err(e) = dispatch(&args) {
        switchlora::errorlog!("{e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    // global: size the kernel thread pool before any compute runs
    if args.get("threads").is_some() {
        let n = args.parse_num("threads", 0usize)?;
        if n == 0 {
            bail!("--threads must be >= 1 (1 = serial reference path)");
        }
        switchlora::kernels::set_threads(n);
    }
    // global: engage the int8×int8→i32 matmul path for int8-packed
    // weights (also: SWITCHLORA_INT8_NATIVE=1)
    if args.flag("int8-native") {
        switchlora::kernels::set_int8_native(true);
    }
    // global: structured tracing.  `--trace-out PATH` opens the sink
    // before any compute; the sink is finished (registries dumped,
    // chrome array closed, file flushed) after the subcommand returns,
    // success or not.
    if let Some(path) = args.get("trace-out") {
        let fmt = switchlora::obs::TraceFormat::parse(
            &args.get_or("trace-format", "jsonl"))?;
        switchlora::obs::enable(std::path::Path::new(&path), fmt)?;
        switchlora::info!("tracing to {path}");
    }
    let out = match args.subcommand().unwrap_or("help") {
        "pretrain" => cmd_pretrain(args),
        "finetune" => cmd_finetune(args),
        "eval" => cmd_eval(args),
        "rank" => cmd_rank(args),
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "report" => cmd_report(args),
        "tables" => cmd_tables(),
        "info" => cmd_info(args),
        _ => {
            print!("{HELP}");
            Ok(())
        }
    };
    match switchlora::obs::finish() {
        Ok(()) => out,
        Err(e) => out.and(Err(e)),
    }
}

/// `switchlora report TRACE.jsonl` — summarize a trace into the
/// per-phase / communication / switch-audit / memory tables.
fn cmd_report(args: &Args) -> Result<()> {
    let path = match args.positional.get(1) {
        Some(p) => p.clone(),
        None => args.req("trace")?.to_string(),
    };
    let rep =
        switchlora::obs::report::summarize(std::path::Path::new(&path))?;
    print!("{}", rep.render());
    Ok(())
}

const HELP: &str = "switchlora — switched low-rank adaptation pre-training\n\
subcommands: pretrain finetune eval rank generate serve report tables \
info\n\
training methods are pluggable: `switchlora info` lists the registry,\n\
and `pretrain --method NAME` + per-method flags select one\n\
backend: native CPU by default (no artifacts needed); build with\n\
`--features pjrt` and set SWITCHLORA_BACKEND=pjrt for the AOT/PJRT path\n\
threading: `--threads N` / SWITCHLORA_THREADS=N size the kernel pool\n\
(default: detected parallelism; results are bitwise thread-invariant)\n\
precision: `--precision bf16` views frozen base weights in bf16,\n\
`--comm-dtype bf16` halves the measured all-reduce bytes,\n\
`--moments-dtype bf16` keeps Adam moments at bf16, and\n\
`generate --quantize-base int8` serves from ~4x smaller frozen weights\n\
(add --int8-native for integer-arithmetic matmuls, --kv-dtype \
bf16|int8\n\
for a quantized KV cache, --max-context N to cap cache capacity)\n\
(default is pure f32 everywhere and bitwise-identical to older builds)\n\
serving: `serve --adapter NAME=PATH` (repeatable; NAME=seed:N for a\n\
seeded demo adapter) runs a continuous-batching HTTP server that\n\
multiplexes every named LoRA adapter over ONE shared frozen base\n\
(int8 by default) — POST /v1/generate streams NDJSON tokens with\n\
per-request adapter/seed/temperature/top-k/top-p; 429 + Retry-After\n\
under backpressure; SIGTERM or POST /admin/drain drains gracefully;\n\
KV lives in a paged block pool (--kv-block N positions/block), long\n\
prompts prefill in --prefill-chunk N slices interleaved with decode,\n\
sealed KV blocks are shared across same-tenant prompts via a\n\
refcounted prefix cache (--prefix-cache on|off, LRU pool of\n\
--prefix-cache-blocks N), and connections are HTTP/1.1 keep-alive\n\
telemetry: `--trace-out run.jsonl` on any subcommand records phase\n\
spans, comm rounds, switch audits and memory ledgers (math untouched);\n\
`--trace-format chrome` emits a Perfetto/chrome://tracing file, and\n\
`switchlora report run.jsonl` prints the summary tables\n\
see `rust/src/main.rs` header or README.md for full flag reference\n";

/// Resolve the precision policy shared by the training/serving
/// subcommands from the global flags.
fn policy_from_args(args: &Args) -> Result<PrecisionPolicy> {
    PrecisionPolicy::from_flags(args.get("precision"),
                                args.get("comm-dtype"),
                                args.get("moments-dtype"),
                                args.get("quantize-base"),
                                args.get("kv-dtype"))
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let spec = args.get_or("spec", "tiny");
    let artifacts = default_artifacts_dir();
    check_spec(&artifacts, &spec)?;
    let method = switchlora::methods::from_args(args)?;
    let steps = args.parse_num("steps", 200u64)?;
    let mut cfg = TrainConfig::new(&spec, method, steps);
    cfg.peak_lr = args.parse_num("lr", 0.0f32)?;
    cfg.warmup = args.parse_num("warmup", cfg.warmup)?;
    cfg.weight_decay = args.parse_num("wd", 0.0f32)?;
    cfg.seed = args.parse_num("seed", 42u64)?;
    cfg.workers = args.parse_num("workers", 1usize)?;
    cfg.eval_every = args.parse_num("eval-every", 0u64)?;
    cfg.full_warmup_steps = args.parse_num("full-warmup", 0u64)?;
    cfg.init = match args.get_or("init", "switchlora").as_str() {
        "switchlora" => InitMode::SwitchLora,
        "lora_default" => InitMode::LoraDefault,
        other => bail!("unknown --init {other:?}"),
    };
    cfg.metrics_csv = args.get("csv").map(PathBuf::from);
    cfg.ckpt_every = args.parse_num("ckpt-every", 0u64)?;
    cfg.ckpt_path = args.get("ckpt-path").map(PathBuf::from);
    if cfg.ckpt_every > 0 && cfg.ckpt_path.is_none() {
        cfg.ckpt_path = Some(PathBuf::from(format!(
            "{spec}_{}_resume.ckpt", cfg.method.name())));
    }
    cfg.resume = args.get("resume").map(PathBuf::from);
    cfg.precision = policy_from_args(args)?;
    let mut engine = Engine::cpu()?;
    switchlora::info!("execution backend: {} ({} kernel thread(s), {} \
                       detected)", engine.backend_name(),
                      switchlora::kernels::threads(),
                      switchlora::kernels::detected_parallelism());
    let (res, store) = exp::pretrain(&mut engine, cfg.clone())?;
    // stdout carries only the machine-readable results table; run
    // commentary goes through the leveled logger (stderr)
    print!("{}", exp::results_table("pretrain", &[res.clone()]));
    switchlora::info!("precision: {}", cfg.precision.summary());
    switchlora::info!("comm: {}", comm_summary(&res.comm, steps,
                                               cfg.precision.comm));
    if !res.counters.is_empty() {
        let line = res
            .counters
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join("  ");
        switchlora::info!("method counters: {line}");
    }
    switchlora::info!("offload bytes/step: {}  switches: {}",
                      human_bytes((res.counter("offload_bytes") as f64
                                   / steps as f64) as u64),
                      res.counter("switches"));
    if let Some(out) = args.get("out") {
        checkpoint::save(&PathBuf::from(out), &spec, &store, None)?;
        switchlora::info!("checkpoint written to {out}");
    }
    Ok(())
}

fn load_store(manifest: &Manifest, variant: Variant, ckpt: &str)
    -> Result<ParamStore> {
    let layout =
        std::sync::Arc::new(manifest.layout(variant)?.clone());
    let mut store = ParamStore::zeros(layout);
    let ck = checkpoint::load(&PathBuf::from(ckpt))?;
    let rep = ck.restore_into(&mut store);
    switchlora::info!("checkpoint: {} params loaded, {} absent, {} \
                       shape-mismatched", rep.loaded, rep.missing,
                      rep.mismatched);
    Ok(store)
}

fn variant_from_args(args: &Args) -> Result<Variant> {
    Ok(match args.get_or("variant", "lora").as_str() {
        "lora" => Variant::Lora,
        "full" => Variant::Full,
        "cls" => Variant::Cls,
        other => bail!("unknown --variant {other:?}"),
    })
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let spec = args.get_or("spec", "tiny");
    let artifacts = default_artifacts_dir();
    check_spec(&artifacts, &spec)?;
    let manifest = Manifest::for_spec(&artifacts, &spec)?;
    let from = match args.get_or("from", "lora").as_str() {
        "lora" => Variant::Lora,
        "full" => Variant::Full,
        other => bail!("--from must be lora|full, got {other:?}"),
    };
    let store = load_store(&manifest, from, args.req("ckpt")?)?;
    let tasks: Vec<Task> = csv_list(&args.get_or(
        "tasks", "majority,contains,pairmatch,parity,recall"))
        .iter()
        .map(|t| Task::from_name(t)
            .ok_or_else(|| anyhow::anyhow!("unknown task {t:?}")))
        .collect::<Result<_>>()?;
    let steps = args.parse_num("steps", 150u64)?;
    let lr = args.parse_num("lr", 1e-3f32)?;
    let seed = args.parse_num("seed", 42u64)?;
    let mut engine = Engine::cpu()?;
    let results = exp::finetune::glue_suite(&mut engine, &manifest, &store,
                                            from, &tasks, steps, lr, seed)?;
    println!("\n{:<12} {:>8} {:>8}", "task", "acc", "loss");
    let mut mean = 0.0;
    for r in &results {
        println!("{:<12} {:>8.3} {:>8.4}", r.task.name(), r.accuracy,
                 r.loss);
        mean += r.accuracy;
    }
    println!("{:<12} {:>8.3}", "average", mean / results.len() as f32);
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let spec = args.get_or("spec", "tiny");
    let artifacts = default_artifacts_dir();
    check_spec(&artifacts, &spec)?;
    let manifest = Manifest::for_spec(&artifacts, &spec)?;
    let variant = variant_from_args(args)?;
    let store = load_store(&manifest, variant, args.req("ckpt")?)?;
    let mut engine = Engine::cpu()?;
    let rt = switchlora::runtime::ModelRuntime::load(&mut engine,
                                                     manifest.clone(),
                                                     variant)?;
    let mc = &manifest.config;
    let set = switchlora::data::dataset::EvalSet::synth(
        mc.vocab, args.parse_num("seed", 42u64)?, mc.batch, mc.seq,
        args.parse_num("batches", 16usize)?);
    let loss = switchlora::coordinator::eval::eval_loss(&rt, &store, &set)?;
    println!("eval loss {loss:.4}  ppl {:.2}  ({} tokens)",
             (loss as f64).exp(), set.n_tokens());
    Ok(())
}

fn cmd_rank(args: &Args) -> Result<()> {
    let spec = args.get_or("spec", "tiny");
    let artifacts = default_artifacts_dir();
    check_spec(&artifacts, &spec)?;
    let manifest = Manifest::for_spec(&artifacts, &spec)?;
    let variant = variant_from_args(args)?;
    let store = load_store(&manifest, variant, args.req("ckpt")?)?;
    let rows = exp::rank::analyze(&store, &manifest, variant)?;
    println!("singular-value spectra ({} variant):\n{}", variant.key(),
             exp::rank::table(&rows));
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let spec = args.get_or("spec", "tiny");
    let artifacts = default_artifacts_dir();
    check_spec(&artifacts, &spec)?;
    let manifest = Manifest::for_spec(&artifacts, &spec)?;
    let mc = manifest.config.clone();
    let mut variant = match args.get_or("variant", "lora").as_str() {
        "lora" => Variant::Lora,
        "full" => Variant::Full,
        other => bail!("--variant must be lora|full for generation, \
                        got {other:?}"),
    };
    let seed = args.parse_num("seed", 42u64)?;
    let mut store = match args.get("ckpt") {
        Some(ckpt) => load_store(&manifest, variant, ckpt)?,
        None => {
            // no checkpoint: a seeded random init still drives the whole
            // generation pipeline end to end
            switchlora::info!("no --ckpt given: generating from a seeded \
                               random init");
            seeded_store(&manifest, variant, seed)?
        }
    };
    if args.flag("merge") {
        if variant != Variant::Lora {
            bail!("--merge folds LoRA adapters into dense weights: \
                   use --variant lora");
        }
        store = merged_full_store(&manifest, &store)?;
        variant = Variant::Full;
        switchlora::info!("adapters merged (W ← W + s·B·A): decoding \
                           with zero adapter overhead");
    }
    // --quantize-base int8|bf16: serve from a packed store — dense base
    // weights compressed (per-row symmetric int8 or bf16), everything
    // the forward needs at full precision kept f32
    let policy = policy_from_args(args)?;
    let packed = if policy.frozen_base != DType::F32 {
        let p = PackedStore::quantize_base(&store, policy.frozen_base)?;
        let (bp, bf) = p.base_bytes();
        switchlora::info!(
            "base weights quantized to {}: {} -> {} resident ({:.2}x); \
             whole model {} -> {}", policy.frozen_base,
            human_bytes(bf as u64), human_bytes(bp as u64),
            bf as f64 / (bp.max(1)) as f64,
            human_bytes(4 * store.layout.total as u64),
            human_bytes(p.resident_bytes() as u64));
        Some(p)
    } else {
        None
    };
    if let Some(p) = &packed {
        switchlora::obs::memory_event(
            "serve",
            &switchlora::obs::packed_mem_rows(p, policy.frozen_base));
    }
    let params: &dyn ParamSource = match &packed {
        Some(p) => p,
        None => &store,
    };
    let engine = Engine::cpu()?;
    let rt = load_infer_with(&engine, manifest.clone(), variant, policy)?;
    let tok = ByteTokenizer::new(mc.vocab);
    let prompt = tok.encode(&args.get_or("prompt", "The quick brown fox"));
    if prompt.is_empty() {
        bail!("--prompt must encode to at least one token");
    }
    let batch = args.parse_num("batch", 1usize)?.max(1);
    let prompts = vec![prompt; batch];
    let stop_tokens: Vec<i32> = csv_list(&args.get_or("stop", ""))
        .iter()
        .map(|s| s.parse().map_err(|e| anyhow::anyhow!("--stop {s:?}: {e}")))
        .collect::<Result<_>>()?;
    let max_context = match args.get("max-context") {
        Some(_) => {
            let n = args.parse_num("max-context", 0usize)?;
            if n == 0 {
                bail!("--max-context must be >= 1");
            }
            Some(n)
        }
        None => None,
    };
    let cfg = GenConfig {
        max_new: args.parse_num("max-new", 64usize)?,
        sampler: Sampler {
            temperature: args.parse_num("temperature", 0.0f32)?,
            top_k: args.parse_num("top-k", 0usize)?,
            top_p: args.parse_num("top-p", 1.0f32)?,
        },
        stop_tokens,
        seed,
        max_context,
    };
    switchlora::info!(
        "spec {spec} [{}]: {} sequence(s), prompt {} tokens, \
         max-new {}, temperature {}, top-k {}, top-p {}",
        variant.key(), batch, prompts[0].len(), cfg.max_new,
        cfg.sampler.temperature, cfg.sampler.top_k, cfg.sampler.top_p);
    // ids above 255 have no byte identity, so wide-vocab specs
    // (s1m/s4m/s8m) stream raw token ids instead of decoded text
    let as_text = mc.vocab <= 256;
    let render = |ids: &[i32]| -> String {
        if as_text {
            printable(&tok.decode(ids))
        } else {
            ids.iter()
                .map(|i| i.to_string())
                .collect::<Vec<_>>()
                .join(" ")
        }
    };
    let t0 = std::time::Instant::now();
    print!("[seq 0] ");
    // stream the first sequence's tokens as they are decoded; byte
    // tokens buffer until they complete a UTF-8 sequence so multi-byte
    // characters stream the same way the summary line renders them
    let mut pending: Vec<u8> = Vec::new();
    let gen = generate_stream(rt.as_ref(), params, &prompts, &cfg,
                              |s, t| {
        if s != 0 {
            return;
        }
        if as_text {
            if (0..256).contains(&t) {
                pending.push(t as u8);
            }
            loop {
                match std::str::from_utf8(&pending) {
                    Ok(valid) => {
                        print!("{}", printable(valid));
                        pending.clear();
                        break;
                    }
                    Err(e) => {
                        let n = e.valid_up_to();
                        if n > 0 {
                            let valid = std::str::from_utf8(&pending[..n])
                                .expect("validated prefix");
                            print!("{}", printable(valid));
                        }
                        match e.error_len() {
                            Some(bad) => {
                                // invalid sequence: replacement char
                                print!("\u{FFFD}");
                                pending.drain(..n + bad);
                            }
                            None => {
                                // incomplete: wait for the next token
                                pending.drain(..n);
                                break;
                            }
                        }
                    }
                }
            }
        } else {
            print!("{} ", t);
        }
        use std::io::Write;
        let _ = std::io::stdout().flush();
    })?;
    if as_text && !pending.is_empty() {
        // generation ended mid multi-byte sequence
        print!("\u{FFFD}");
    }
    println!();
    let dt = t0.elapsed().as_secs_f64();
    for (s, seq) in gen.sequences.iter().enumerate() {
        let new = &seq[prompts[s].len()..];
        println!("[seq {s}] {:>3} tokens | {}", new.len(), render(new));
    }
    let total: usize = gen.n_generated.iter().sum();
    switchlora::info!(
        "prefill {} tokens, {} batched decode steps, {} tokens \
         generated in {dt:.2}s ({:.1} tok/s)",
        gen.prefill_tokens, gen.decode_steps, total,
        total as f64 / dt.max(1e-9));
    Ok(())
}

/// `switchlora serve` — the continuous-batching multi-tenant model
/// server.  One shared frozen base (int8 by default — the deployment
/// premise; `--quantize-base f32` opts out), N named adapters applied
/// unmerged per request, NDJSON token streaming over std-only HTTP.
fn cmd_serve(args: &Args) -> Result<()> {
    let spec = args.get_or("spec", "tiny");
    let artifacts = default_artifacts_dir();
    check_spec(&artifacts, &spec)?;
    let manifest = Manifest::for_spec(&artifacts, &spec)?;
    let mc = manifest.config.clone();
    let seed = args.parse_num("seed", 42u64)?;
    // the base is always served as the Full variant: adapters arrive
    // per-request as overlays, never baked into the stored weights
    let store = match args.get("ckpt") {
        Some(ckpt) => match args.get_or("base-variant", "full").as_str() {
            "full" => load_store(&manifest, Variant::Full, ckpt)?,
            "lora" => {
                // keep only the dense weights a LoRA checkpoint shares
                // with the Full layout; its adapters are dropped, NOT
                // merged — register them with --adapter to serve them
                let lora = load_store(&manifest, Variant::Lora, ckpt)?;
                let layout = std::sync::Arc::new(
                    manifest.layout(Variant::Full)?.clone());
                let mut full = ParamStore::zeros(layout);
                let copied =
                    switchlora::model::init::copy_shared(&lora,
                                                         &mut full);
                if copied == 0 {
                    bail!("--base-variant lora: checkpoint shares no \
                           tensors with the full layout");
                }
                switchlora::info!(
                    "base from lora checkpoint: {copied} shared \
                     tensors copied; adapters dropped (serve them \
                     with --adapter NAME=<ckpt>)");
                full
            }
            other => bail!("--base-variant must be full|lora, got \
                            {other:?}"),
        },
        None => {
            switchlora::info!("no --ckpt given: serving a seeded \
                               random base (demo mode)");
            seeded_store(&manifest, Variant::Full, seed)?
        }
    };
    let mut registry = AdapterRegistry::new();
    for aspec in args.get_all("adapter") {
        registry.load_spec(&manifest, aspec)?;
    }
    if registry.is_empty() {
        switchlora::info!("no --adapter given: serving the bare base \
                           only");
    }
    // serve defaults the frozen base to int8 — pass an explicit
    // --quantize-base f32 to serve the master-precision store
    let mut policy = policy_from_args(args)?;
    if args.get("quantize-base").is_none() {
        policy.frozen_base = DType::I8;
    }
    let base = if policy.frozen_base != DType::F32 {
        let p = PackedStore::quantize_base(&store, policy.frozen_base)?;
        let (bp, bf) = p.base_bytes();
        switchlora::info!(
            "base weights quantized to {}: {} -> {} resident ({:.2}x)",
            policy.frozen_base, human_bytes(bf as u64),
            human_bytes(bp as u64), bf as f64 / (bp.max(1)) as f64);
        BaseSource::Packed { store: p, dtype: policy.frozen_base }
    } else {
        BaseSource::Master(store)
    };
    let engine = Engine::cpu()?;
    let rt =
        load_infer_with(&engine, manifest.clone(), Variant::Full,
                        policy)?;
    let cfg = ServeConfig {
        host: args.get_or("host", "127.0.0.1"),
        port: args.parse_num("port", 8080u16)?,
        max_batch: args.parse_num("max-batch", 4usize)?,
        queue_depth: args.parse_num("queue-depth", 16usize)?,
        max_context: args.parse_num("max-context", 256usize)?,
        default_max_new: args.parse_num("max-new", 64usize)?,
        prefill_chunk: args.parse_num("prefill-chunk", 32usize)?,
        kv_block: args.parse_num(
            "kv-block",
            switchlora::infer::kv_cache::DEFAULT_KV_BLOCK)?,
        prefix_cache: match args.get_or("prefix-cache", "on").as_str() {
            "on" => true,
            "off" => false,
            other => bail!("--prefix-cache must be on|off, got \
                            {other:?}"),
        },
        prefix_cache_blocks: args.parse_num("prefix-cache-blocks",
                                            128usize)?,
    };
    Server::bind(cfg, rt, base, registry, mc.vocab)?.run()
}

fn cmd_tables() -> Result<()> {
    // Table 4
    println!("== Table 4: trainable parameters (paper architectures) ==");
    println!("{:<8} {:>12} {:>14} {:>14}", "model", "full",
             "lora r=h/8", "lora r=h/4");
    for c in ModelConfig::paper_presets() {
        let full = an::full_params(&c);
        let r1 = (c.hidden / 8) as u64;
        let r2 = (c.hidden / 4) as u64;
        println!("{:<8} {:>12} {:>14} {:>14}", c.name, human_params(full),
                 human_params(an::lora_trainable_params(&c, r1)),
                 human_params(an::lora_trainable_params(&c, r2)));
    }
    // Table 5
    println!("\n== Table 5: memory model (4 GPUs, rank=h/4) ==");
    println!("{:<8} {:>4} {:<11} {:>12} {:>10} {:>12} {:>12}",
             "model", "bs", "method", "trainable", "mem", "comm/step",
             "offload/step");
    for (name, bs) in [("p1b", 16u64), ("p3b", 4), ("p7b", 1)] {
        let c = ModelConfig::paper_preset(name).unwrap();
        let r = (c.hidden / 4) as u64;
        for (meth, tr) in [("full", an::full_params(&c)),
                           ("switchlora",
                            an::lora_trainable_params(&c, r))] {
            let mem = an::memory_model(&c, tr, bs, 4).total();
            let comm = an::dp_comm_bytes_per_step(tr, 4);
            let off = if meth == "switchlora" {
                an::offload_bytes_per_step(&c, r, 1.0 / 40.0)
            } else {
                0
            };
            println!("{:<8} {:>4} {:<11} {:>12} {:>10} {:>12} {:>12}",
                     name, bs, meth, human_params(tr), human_bytes(mem),
                     human_bytes(comm), human_bytes(off));
        }
    }
    // Appendix F headline
    let c = ModelConfig::paper_preset("p1b").unwrap();
    println!("\nAppendix F: 1.3B r=512 communication saving: {:.1}% \
              (paper: 54%)",
             100.0 * an::comm_saving_fraction(&c, 512));
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("training methods (--method NAME):");
    for m in switchlora::methods::registry() {
        let opts = if m.option_keys.is_empty() {
            String::new()
        } else {
            format!("  [--{}]", m.option_keys.join(" --"))
        };
        println!("  {:<11} {}{opts}", m.name, m.summary);
    }
    println!("\nparallelism: {} detected, {} active kernel thread(s) \
              (override: --threads N or SWITCHLORA_THREADS=N)",
             switchlora::kernels::detected_parallelism(),
             switchlora::kernels::threads());
    let policy = policy_from_args(args)?;
    println!("\nprecision policy: {}{}", policy.summary(),
             if policy.is_default() {
                 "  (defaults; set --precision/--comm-dtype/\
                  --moments-dtype/--quantize-base)"
             } else {
                 ""
             });
    let artifacts = default_artifacts_dir();
    println!("\nartifacts dir: {}", artifacts.display());
    let mut specs: Vec<String> = std::fs::read_dir(&artifacts)
        .map(|rd| {
            rd.filter_map(|e| e.ok())
                .filter(|e| e.path().join("manifest.json").exists())
                .map(|e| e.file_name().to_string_lossy().into_owned())
                .collect()
        })
        .unwrap_or_default();
    // builtin presets run on the native backend with no artifacts
    for c in ModelConfig::runnable_presets() {
        if !specs.contains(&c.name) {
            specs.push(c.name);
        }
    }
    specs.sort();
    for s in specs {
        let man = Manifest::for_spec(&artifacts, &s)?;
        let kind = if man.dir.starts_with("<builtin>") {
            "builtin"
        } else {
            "artifacts"
        };
        println!(
            "  {:<10} h={:<4} L={:<2} vocab={:<5} seq={:<4} r={:<4} \
             trainable lora/full = {} / {}  [{kind}]",
            s, man.config.hidden, man.config.layers, man.config.vocab,
            man.config.seq, man.config.rank,
            human_params(man.lora.n_trainable as u64),
            human_params(man.full.n_trainable as u64));
    }
    Ok(())
}
