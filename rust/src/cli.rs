//! Minimal CLI argument substrate (clap is not in the offline vendor set):
//! `--key value` options, `--flag` booleans, positional subcommands.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// every `--key value` pair in arrival order; unlike `options`
    /// (last-wins), this keeps repeats — `serve --adapter a=.. --adapter
    /// b=..` reads them back with [`Args::get_all`]
    pub multi: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`.  A token `--k` followed by a non-`--` token is an
    /// option; a `--k` followed by another `--` token (or end) is a flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let toks: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(key) = t.strip_prefix("--") {
                if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(),
                                       toks[i + 1].clone());
                    out.multi.push((key.to_string(),
                                    toks[i + 1].clone()));
                    i += 2;
                } else {
                    out.flags.push(key.to_string());
                    i += 1;
                }
            } else {
                out.positional.push(t.clone());
                i += 1;
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Every value given for a repeatable option, in command-line order.
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.multi
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T)
        -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn subcommand(&self) -> Result<&str> {
        self.positional
            .first()
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing subcommand"))
    }
}

/// Parse a comma-separated list.
pub fn csv_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .collect()
}

/// Validate a spec name: either AOT artifacts exist under the artifacts
/// dir, or the spec is a builtin preset the native backend can synthesize.
pub fn check_spec(artifacts: &std::path::Path, spec: &str) -> Result<()> {
    let p = artifacts.join(spec).join("manifest.json");
    if !p.exists()
        && crate::model::config::ModelConfig::builtin(spec).is_none()
    {
        bail!("spec {spec:?} not found: no artifacts at {} and no builtin \
               preset of that name",
              p.display());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_flags_positionals() {
        let a = parse("pretrain --spec s1m --steps 100 --verbose \
                       --lr 0.02 extra");
        assert_eq!(a.subcommand().unwrap(), "pretrain");
        assert_eq!(a.get("spec"), Some("s1m"));
        assert_eq!(a.parse_num::<u64>("steps", 0).unwrap(), 100);
        assert_eq!(a.parse_num::<f32>("lr", 0.0).unwrap(), 0.02);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pretrain", "extra"]);
    }

    #[test]
    fn repeated_options_keep_every_value_in_order() {
        let a = parse("serve --adapter a=one.ckpt --max-batch 4 \
                       --adapter b=seed:7");
        // the map keeps last-wins semantics for single-valued options...
        assert_eq!(a.get("adapter"), Some("b=seed:7"));
        // ...while get_all sees every occurrence, in order
        assert_eq!(a.get_all("adapter"),
                   vec!["a=one.ckpt", "b=seed:7"]);
        assert_eq!(a.get_all("max-batch"), vec!["4"]);
        assert!(a.get_all("absent").is_empty());
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x");
        assert_eq!(a.get_or("spec", "tiny"), "tiny");
        assert!(a.req("spec").is_err());
        assert_eq!(a.parse_num::<u64>("steps", 7).unwrap(), 7);
        let b = parse("x --steps banana");
        assert!(b.parse_num::<u64>("steps", 0).is_err());
    }

    #[test]
    fn csv_parsing() {
        assert_eq!(csv_list("a, b,,c"), vec!["a", "b", "c"]);
    }

    #[test]
    fn check_spec_accepts_builtins_without_artifacts() {
        let dir = std::env::temp_dir().join("switchlora_no_artifacts_cli");
        assert!(check_spec(&dir, "tiny").is_ok());
        assert!(check_spec(&dir, "s1m_r64").is_ok());
        assert!(check_spec(&dir, "bogus").is_err());
    }
}
