//! AdamW state over the packed trainable vector, with **per-element step
//! counts** and span-level resets — the Appendix D optimizer modification
//! generalized from per-row/column to per-element granularity.
//!
//! The actual hot-path update runs inside the fused Pallas/HLO kernel
//! (`python/compile/kernels/adam.py`); `host_step` here implements the
//! identical math for (a) the GaLore baseline (whose projection needs host
//! control between grad and update) and (b) differential testing of the
//! kernel (`rust/tests/test_runtime.rs`).

use super::AdamHyper;
use crate::tensor::dtype::{round_through, DType};

/// A (possibly strided) span of elements in the packed trainable vector.
/// `stride == 1` is a contiguous row; LoRA-B columns have `stride == rank`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub offset: usize,
    pub stride: usize,
    pub count: usize,
}

impl Span {
    pub fn contiguous(offset: usize, count: usize) -> Span {
        Span { offset, stride: 1, count }
    }

    pub fn indices(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.count).map(move |k| self.offset + k * self.stride)
    }

    pub fn end(&self) -> usize {
        if self.count == 0 {
            self.offset
        } else {
            self.offset + (self.count - 1) * self.stride + 1
        }
    }
}

/// Adam moments + per-element step counts, padded like the kernel buffers.
///
/// `moments_dtype` is the *precision* of the first/second moments: with
/// `Bf16`, every value written to `m`/`v` is kept on the bf16 grid
/// (rounded-to-nearest-even on each update) and checkpoints store them
/// as 2-byte payloads — the memory-reduction lever of `--moments-dtype
/// bf16`.  The backing buffers stay `f32` so every consumer (span
/// resets, the switch algorithm, serialization) indexes them uniformly;
/// the numerics are identical to a true 16-bit store because bf16→f32
/// is exact.  Step counts `s` always stay f32 (they are small integers).
#[derive(Clone, Debug)]
pub struct AdamState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// per-element step counts (f32 to match the kernel layout)
    pub s: Vec<f32>,
    /// storage precision of `m`/`v` (`F32` or `Bf16`)
    pub moments_dtype: DType,
}

impl AdamState {
    /// `n` live elements padded to `padded` (padding lanes get step=1 so
    /// bias correction never divides by zero — they are masked anyway).
    pub fn new(n: usize, padded: usize) -> AdamState {
        Self::with_moments(n, padded, DType::F32)
    }

    /// [`AdamState::new`] with an explicit moment precision
    /// (`--moments-dtype`).
    pub fn with_moments(n: usize, padded: usize, moments_dtype: DType)
        -> AdamState {
        debug_assert!(matches!(moments_dtype, DType::F32 | DType::Bf16),
                      "moment precision must be f32 or bf16");
        let padded = padded.max(n);
        let mut s = vec![0.0; padded];
        for x in s.iter_mut().skip(n) {
            *x = 1.0;
        }
        AdamState {
            m: vec![0.0; padded],
            v: vec![0.0; padded],
            s,
            moments_dtype,
        }
    }

    /// Reassemble a state from checkpointed arrays.
    pub fn from_parts(m: Vec<f32>, v: Vec<f32>, s: Vec<f32>,
                      moments_dtype: DType) -> AdamState {
        AdamState { m, v, s, moments_dtype }
    }

    pub fn len(&self) -> usize {
        self.m.len()
    }

    pub fn is_empty(&self) -> bool {
        self.m.is_empty()
    }

    /// Zero the moments and step counts of a span — the Algorithm 1 line 3
    /// `opt_state(Q_i,:) ← 0`.
    pub fn reset_span(&mut self, span: Span) {
        for i in span.indices() {
            self.m[i] = 0.0;
            self.v[i] = 0.0;
            self.s[i] = 0.0;
        }
    }
}

/// One AdamW step on host buffers; bit-compatible with the fused kernel:
///   s' = s + mask;  m' = mask?(b1 m + (1-b1) g):m;  v' likewise;
///   p' = p - mask·lr·( m̂/(√v̂+eps) + wd·p ).
pub fn host_step(p: &mut [f32], g: &[f32], st: &mut AdamState, mask: &[f32],
                 h: &AdamHyper) {
    let n = p.len();
    assert!(g.len() >= n && mask.len() >= n && st.len() >= n);
    // bf16 moments: every stored value lives on the bf16 grid, and the
    // update consumes the *stored* (rounded) value so the state alone
    // determines the trajectory — exactly what a 16-bit buffer would do
    let bf16_moments = st.moments_dtype == DType::Bf16;
    for i in 0..n {
        let mk = mask[i];
        let s_new = st.s[i] + mk;
        let mut m_new = mk * (h.beta1 * st.m[i] + (1.0 - h.beta1) * g[i])
            + (1.0 - mk) * st.m[i];
        let mut v_new =
            mk * (h.beta2 * st.v[i] + (1.0 - h.beta2) * g[i] * g[i])
                + (1.0 - mk) * st.v[i];
        if bf16_moments {
            m_new = round_through(m_new, DType::Bf16);
            v_new = round_through(v_new, DType::Bf16);
        }
        // Frozen lanes can have s == 0 (reset + freeze of a switched
        // vector); clamp the bias-correction clock so 1-b^0 never divides.
        // Live lanes (mask == 1) always have s_new >= 1.
        let s_c = s_new.max(1.0);
        let c1 = 1.0 - h.beta1.powf(s_c);
        let c2 = 1.0 - h.beta2.powf(s_c);
        let mhat = m_new / c1;
        let vhat = v_new / c2;
        let upd = mhat / (vhat.sqrt() + h.eps) + h.weight_decay * p[i];
        p[i] -= mk * h.lr * upd;
        st.m[i] = m_new;
        st.v[i] = v_new;
        st.s[i] = s_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_indices() {
        let s = Span { offset: 10, stride: 4, count: 3 };
        assert_eq!(s.indices().collect::<Vec<_>>(), vec![10, 14, 18]);
        assert_eq!(s.end(), 19);
        let c = Span::contiguous(5, 3);
        assert_eq!(c.indices().collect::<Vec<_>>(), vec![5, 6, 7]);
    }

    #[test]
    fn first_step_is_signed_lr() {
        // From zero state, update magnitude == lr (bias-corrected).
        let mut p = vec![0.0f32; 4];
        let g = vec![2.0, -3.0, 0.5, 1.0];
        let mut st = AdamState::new(4, 4);
        let h = AdamHyper::new(0.01);
        host_step(&mut p, &g, &mut st, &[1.0; 4], &h);
        for (x, gg) in p.iter().zip(&g) {
            assert!((x.abs() - 0.01).abs() < 1e-4, "{x} {gg}");
            assert_eq!(x.signum(), -gg.signum());
        }
        assert!(st.s.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn masked_elements_fully_inert() {
        let mut p = vec![1.0f32, 2.0];
        let mut st = AdamState::new(2, 2);
        st.m[1] = 0.5;
        st.v[1] = 0.3;
        st.s[1] = 7.0;
        let h = AdamHyper::new(0.1);
        host_step(&mut p, &[1.0, 1.0], &mut st, &[1.0, 0.0], &h);
        assert_ne!(p[0], 1.0);
        assert_eq!(p[1], 2.0);
        assert_eq!((st.m[1], st.v[1], st.s[1]), (0.5, 0.3, 7.0));
    }

    #[test]
    fn reset_span_strided() {
        let mut st = AdamState::new(12, 12);
        for i in 0..12 {
            st.m[i] = 1.0;
            st.v[i] = 1.0;
            st.s[i] = 5.0;
        }
        // a "column" of a 3x4 row-major matrix: offset 2, stride 4, count 3
        st.reset_span(Span { offset: 2, stride: 4, count: 3 });
        for i in 0..12 {
            let zeroed = i % 4 == 2;
            assert_eq!(st.m[i] == 0.0, zeroed, "index {i}");
            assert_eq!(st.s[i] == 0.0, zeroed, "index {i}");
        }
    }

    #[test]
    fn reset_then_step_restarts_bias_correction() {
        let mut p = vec![0.0f32];
        let mut st = AdamState::new(1, 1);
        let h = AdamHyper::new(0.01);
        for _ in 0..10 {
            host_step(&mut p, &[1.0], &mut st, &[1.0], &h);
        }
        st.reset_span(Span::contiguous(0, 1));
        let before = p[0];
        host_step(&mut p, &[1.0], &mut st, &[1.0], &h);
        // after reset, first-step bias correction applies again: full-lr step
        assert!(((before - p[0]) - 0.01).abs() < 1e-4);
        assert_eq!(st.s[0], 1.0);
    }

    #[test]
    fn padding_lanes_have_step_one() {
        let st = AdamState::new(3, 8);
        assert_eq!(&st.s[..3], &[0.0, 0.0, 0.0]);
        assert!(st.s[3..].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn bf16_moments_stay_on_the_bf16_grid() {
        use crate::tensor::dtype::{bf16_to_f32, f32_to_bf16};
        let n = 16;
        let mut p = vec![0.1f32; n];
        let g: Vec<f32> = (0..n).map(|i| 0.3 + 0.17 * i as f32).collect();
        let mut st = AdamState::with_moments(n, n, DType::Bf16);
        let h = AdamHyper::new(0.01);
        let ones = vec![1.0f32; n];
        for _ in 0..5 {
            host_step(&mut p, &g, &mut st, &ones, &h);
        }
        for (&m, &v) in st.m.iter().zip(&st.v) {
            assert_eq!(m, bf16_to_f32(f32_to_bf16(m)), "m off-grid");
            assert_eq!(v, bf16_to_f32(f32_to_bf16(v)), "v off-grid");
        }
        // the rounded trajectory still tracks the f32 one closely
        let mut p32 = vec![0.1f32; n];
        let mut st32 = AdamState::new(n, n);
        for _ in 0..5 {
            host_step(&mut p32, &g, &mut st32, &ones, &h);
        }
        for (a, b) in p.iter().zip(&p32) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn f32_moments_default_is_unchanged() {
        // AdamState::new == with_moments(F32): the legacy path, bitwise
        let mut p1 = vec![0.5f32; 4];
        let mut p2 = p1.clone();
        let g = vec![1.0, -2.0, 0.25, 3.0];
        let h = AdamHyper::new(0.02);
        let mut s1 = AdamState::new(4, 8);
        let mut s2 = AdamState::with_moments(4, 8, DType::F32);
        assert_eq!(s1.moments_dtype, DType::F32);
        for _ in 0..3 {
            host_step(&mut p1, &g, &mut s1, &[1.0; 4], &h);
            host_step(&mut p2, &g, &mut s2, &[1.0; 4], &h);
        }
        assert_eq!(p1, p2);
        assert_eq!(s1.m, s2.m);
    }

    #[test]
    fn weight_decay_pulls_toward_zero() {
        let mut p = vec![10.0f32];
        let mut st = AdamState::new(1, 1);
        let mut h = AdamHyper::new(0.1);
        h.weight_decay = 0.1;
        host_step(&mut p, &[0.0], &mut st, &[1.0], &h);
        assert!(p[0] < 10.0);
    }
}
