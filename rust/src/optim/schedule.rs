//! Learning-rate schedules.  The paper uses cosine decay with linear
//! warm-up (Section 4.1: "cosine learning rate schedule with 100 warm-up
//! steps"); ReLoRA additionally re-warms after each reset, which
//! `with_restart` supports.

#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant(f32),
    CosineWarmup {
        peak: f32,
        warmup: u64,
        total: u64,
        /// floor as a fraction of peak (0.1 ⇒ decay to 10% of peak)
        min_ratio: f32,
    },
}

impl LrSchedule {
    pub fn cosine(peak: f32, warmup: u64, total: u64) -> LrSchedule {
        LrSchedule::CosineWarmup { peak, warmup, total, min_ratio: 0.1 }
    }

    pub fn lr(&self, step: u64) -> f32 {
        match *self {
            LrSchedule::Constant(x) => x,
            LrSchedule::CosineWarmup { peak, warmup, total, min_ratio } => {
                if warmup > 0 && step < warmup {
                    return peak * (step + 1) as f32 / warmup as f32;
                }
                let t = (step.min(total) - warmup) as f32
                    / (total.saturating_sub(warmup)).max(1) as f32;
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                let floor = peak * min_ratio;
                floor + (peak - floor) * cos
            }
        }
    }

    /// ReLoRA-style local re-warm: after a reset at `reset_step`, ramp the
    /// scheduled lr linearly back up over `rewarm` steps.
    pub fn with_restart(&self, step: u64, reset_step: u64, rewarm: u64)
        -> f32 {
        let base = self.lr(step);
        if rewarm == 0 || step < reset_step {
            return base;
        }
        let since = step - reset_step;
        if since >= rewarm {
            base
        } else {
            base * (since + 1) as f32 / rewarm as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::cosine(1.0, 10, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = LrSchedule::cosine(1.0, 10, 100);
        assert!((s.lr(10) - 1.0).abs() < 1e-3);
        let mid = s.lr(55);
        assert!(mid < 1.0 && mid > 0.1);
        assert!((s.lr(100) - 0.1).abs() < 1e-3);
        // clamps beyond total
        assert!((s.lr(500) - 0.1).abs() < 1e-3);
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = LrSchedule::cosine(0.02, 100, 4000);
        let mut prev = f32::MAX;
        for step in (100..4000).step_by(100) {
            let lr = s.lr(step);
            assert!(lr <= prev + 1e-9);
            prev = lr;
        }
    }

    #[test]
    fn restart_rewarms() {
        let s = LrSchedule::Constant(1.0);
        assert!((s.with_restart(1000, 1000, 10) - 0.1).abs() < 1e-6);
        assert!((s.with_restart(1004, 1000, 10) - 0.5).abs() < 1e-6);
        assert_eq!(s.with_restart(1010, 1000, 10), 1.0);
        // before the reset, unaffected
        assert_eq!(s.with_restart(999, 1000, 10), 1.0);
    }
}
