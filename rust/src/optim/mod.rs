//! Optimizers: host AdamW (mirror of the fused L1 kernel, also the GaLore
//! backend), learning-rate schedules, and the GaLore baseline projector.

pub mod adam;
pub mod galore;
pub mod schedule;

/// AdamW hyper-parameters, matching the fused kernel's `hyper` vector
/// `(lr, beta1, beta2, eps, weight_decay)`.
#[derive(Clone, Copy, Debug)]
pub struct AdamHyper {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl AdamHyper {
    pub fn new(lr: f32) -> Self {
        AdamHyper { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8,
                    weight_decay: 0.0 }
    }

    pub fn with_lr(&self, lr: f32) -> Self {
        AdamHyper { lr, ..*self }
    }

    pub fn to_vec(&self) -> Vec<f32> {
        vec![self.lr, self.beta1, self.beta2, self.eps, self.weight_decay]
    }
}
