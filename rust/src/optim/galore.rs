//! GaLore baseline (Zhao et al. 2024b): gradient low-rank projection.
//!
//! For every 2-D weight matrix of the full-rank model, gradients are
//! projected onto a rank-`r` subspace obtained from the SVD of the gradient
//! (refreshed every `update_freq` steps); Adam runs in the projected space
//! and the update is projected back.  Non-matrix parameters (embeddings,
//! norms, heads are *kept* full-rank Adam, following the GaLore paper which
//! projects only the attention/MLP matrices).
//!
//! This is the comparison arm of the paper's Table 6: the accuracy loss of
//! SVD gradient compression vs SwitchLoRA's candidate switching.

use crate::model::layout::{Layout, Role};
use crate::optim::adam::{host_step, AdamState};
use crate::optim::AdamHyper;
use crate::tensor::linalg::svd;
use crate::tensor::Tensor;

/// Serialize one Adam state (moments + step counts).
fn put_adam(out: &mut Vec<u8>, st: &AdamState) {
    crate::util::bytes::put_f32s(out, &st.m);
    crate::util::bytes::put_f32s(out, &st.v);
    crate::util::bytes::put_f32s(out, &st.s);
}

/// Restore an Adam state of the exact same size.
fn get_adam(r: &mut crate::util::bytes::ByteReader, st: &mut AdamState)
    -> anyhow::Result<()> {
    let m = r.f32s()?;
    let v = r.f32s()?;
    let s = r.f32s()?;
    anyhow::ensure!(m.len() == st.m.len() && v.len() == st.v.len()
                        && s.len() == st.s.len(),
                    "optimizer-moment length mismatch: {} vs {}",
                    m.len(), st.m.len());
    st.m = m;
    st.v = v;
    st.s = s;
    Ok(())
}

/// Projection state for one matrix parameter.
struct MatState {
    /// parameter name (for state-restore diagnostics)
    name: String,
    /// t_offset of the parameter in the packed trainable vector
    t_offset: usize,
    m: usize,
    n: usize,
    /// projection matrix: [m, r] if m <= n (project rows), else [n, r]
    p: Option<Tensor>,
    /// Adam state over the projected gradient (r*n or m*r elements)
    adam: AdamState,
}

pub struct Galore {
    pub rank: usize,
    pub update_freq: u64,
    /// GaLore's update scale α (their default 0.25)
    pub scale: f32,
    mats: Vec<MatState>,
    /// Adam state for every non-projected trainable element, indexed by the
    /// packed trainable layout (projected spans are simply unused).
    dense: AdamState,
    dense_mask: Vec<f32>,
}

impl Galore {
    /// `layout` must be the full-rank variant layout (all params trainable).
    pub fn new(layout: &Layout, rank: usize, update_freq: u64, scale: f32)
        -> Galore {
        let mut mats = Vec::new();
        let mut dense_mask = vec![1.0f32; layout.n_trainable];
        for p in layout.trainable() {
            if p.role == Role::Base && p.shape.len() == 2 {
                let (m, n) = (p.shape[0], p.shape[1]);
                let proj_elems = if m <= n { rank * n } else { m * rank };
                mats.push(MatState {
                    name: p.name.clone(),
                    t_offset: p.t_offset.unwrap(),
                    m,
                    n,
                    p: None,
                    adam: AdamState::new(proj_elems, proj_elems),
                });
                let t = p.t_offset.unwrap();
                for x in dense_mask[t..t + p.numel].iter_mut() {
                    *x = 0.0;
                }
            }
        }
        Galore {
            rank,
            update_freq,
            scale,
            mats,
            dense: AdamState::new(layout.n_trainable, layout.n_trainable),
            dense_mask,
        }
    }

    pub fn n_projected_matrices(&self) -> usize {
        self.mats.len()
    }

    /// Elements of optimizer state actually held (the memory-saving claim):
    /// projected moments + dense moments for non-matrix params.
    pub fn optimizer_state_elems(&self) -> usize {
        let proj: usize = self.mats.iter().map(|m| m.adam.len()).sum();
        let dense = self
            .dense_mask
            .iter()
            .filter(|&&x| x == 1.0)
            .count();
        proj + dense
    }

    /// Serialize the dynamic state — per-matrix projections and Adam
    /// moments plus the dense moments — for checkpoint/resume.
    pub fn save_state(&self, out: &mut Vec<u8>) {
        use crate::util::bytes::*;
        put_adam(out, &self.dense);
        put_u64(out, self.mats.len() as u64);
        for ms in &self.mats {
            match &ms.p {
                Some(p) => {
                    put_u8(out, 1);
                    put_u64(out, p.rows as u64);
                    put_u64(out, p.cols as u64);
                    put_f32s(out, &p.data);
                }
                None => put_u8(out, 0),
            }
            put_adam(out, &ms.adam);
        }
    }

    /// Restore state written by [`Self::save_state`] into a freshly
    /// constructed instance of the same configuration.
    pub fn load_state(&mut self, r: &mut crate::util::bytes::ByteReader)
        -> anyhow::Result<()> {
        use anyhow::ensure;
        get_adam(r, &mut self.dense)?;
        let n = r.u64()? as usize;
        ensure!(n == self.mats.len(),
                "galore state has {n} projected matrices, model has {}",
                self.mats.len());
        for ms in self.mats.iter_mut() {
            ms.p = if r.u8()? == 1 {
                let rows = r.u64()? as usize;
                let cols = r.u64()? as usize;
                let data = r.f32s()?;
                ensure!(data.len() == rows * cols,
                        "galore projection for {}: {} elements vs shape \
                         {rows}x{cols}", ms.name, data.len());
                Some(Tensor::from_vec(rows, cols, data))
            } else {
                None
            };
            get_adam(r, &mut ms.adam)?;
        }
        Ok(())
    }

    /// One optimizer step: `params` and `grads` are packed trainable
    /// vectors of the full-rank layout.
    pub fn step(&mut self, step: u64, params: &mut [f32], grads: &[f32],
                h: &AdamHyper) {
        // 1) dense Adam for the non-projected parameters
        host_step(params, grads, &mut self.dense, &self.dense_mask, h);
        // 2) projected Adam per matrix
        for ms in self.mats.iter_mut() {
            let (m, n) = (ms.m, ms.n);
            let g = Tensor::from_vec(
                m, n, grads[ms.t_offset..ms.t_offset + m * n].to_vec());
            // refresh projection from the SVD of the current gradient
            if ms.p.is_none() || step % self.update_freq == 0 {
                let (u, _s, v) = svd(&g);
                let take = |t: &Tensor, r: usize| {
                    let r = r.min(t.cols);
                    let mut p = Tensor::zeros(t.rows, r);
                    for i in 0..t.rows {
                        for j in 0..r {
                            *p.at_mut(i, j) = t.at(i, j);
                        }
                    }
                    p
                };
                ms.p = Some(if m <= n {
                    take(&u, self.rank)
                } else {
                    take(&v, self.rank)
                });
            }
            let p = ms.p.as_ref().unwrap();
            // project gradient on the shared kernels: the transposed
            // orientations go straight to addmm_tn/addmm_nt instead of
            // materializing `p.transpose()` first
            let r_c = p.cols;
            let proj = if m <= n {
                // [r, n] = pᵀ[r,m] @ g[m,n]
                let mut c = Tensor::zeros(r_c, n);
                crate::kernels::addmm_tn(&mut c.data, &p.data, &g.data,
                                         m, r_c, n);
                c
            } else {
                // [m, r] = g[m,n] @ p[n,r]
                let mut c = Tensor::zeros(m, r_c);
                crate::kernels::matmul_nn(&mut c.data, &g.data, &p.data,
                                          m, n, r_c);
                c
            };
            // Adam in projected space (moments persist across steps; the
            // projection refresh is the inconsistency the paper points at)
            let mut upd = vec![0.0f32; proj.numel()];
            let ones = vec![1.0f32; proj.numel()];
            let hh = AdamHyper { lr: 1.0, ..*h }; // unit-lr normalized dir
            host_step(&mut upd, &proj.data, &mut ms.adam, &ones, &hh);
            // upd now holds -normalized_update; project back and apply with
            // lr * scale
            let upd_t = Tensor::from_vec(proj.rows, proj.cols, upd);
            let mut full = Tensor::zeros(m, n);
            if m <= n {
                // [m, n] = p[m,r] @ upd[r,n]
                crate::kernels::matmul_nn(&mut full.data, &p.data,
                                          &upd_t.data, m, r_c, n);
            } else {
                // [m, n] = upd[m,r] @ p[n,r]ᵀ
                crate::kernels::addmm_nt(&mut full.data, &upd_t.data,
                                         &p.data, m, r_c, n);
            }
            let dst = &mut params[ms.t_offset..ms.t_offset + m * n];
            for (d, u) in dst.iter_mut().zip(&full.data) {
                // `full` holds the *negative* update (host_step subtracted
                // from a zero vector), scaled by unit lr.
                *d += h.lr * self.scale * u;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::{Layout, ParamMeta};

    fn toy_layout() -> Layout {
        Layout::from_metas(vec![
            ParamMeta { name: "w1".into(), shape: vec![8, 16],
                        role: Role::Base, trainable: true, numel: 128,
                        offset: 0, t_offset: None },
            ParamMeta { name: "norm".into(), shape: vec![16],
                        role: Role::Norm, trainable: true, numel: 16,
                        offset: 0, t_offset: None },
            ParamMeta { name: "w2".into(), shape: vec![16, 8],
                        role: Role::Base, trainable: true, numel: 128,
                        offset: 0, t_offset: None },
        ])
    }

    #[test]
    fn projects_only_base_matrices() {
        let l = toy_layout();
        let g = Galore::new(&l, 4, 10, 0.25);
        assert_eq!(g.n_projected_matrices(), 2);
        // projected state is smaller than full moments for the matrices
        assert!(g.optimizer_state_elems() < l.n_trainable);
    }

    #[test]
    fn step_moves_params_downhill() {
        let l = toy_layout();
        let mut g = Galore::new(&l, 4, 10, 1.0);
        let h = AdamHyper::new(0.05);
        // quadratic loss 0.5||p - target||^2, grad = p - target
        let mut rngv = crate::util::rng::Rng::new(0);
        let target: Vec<f32> =
            (0..l.n_trainable).map(|_| rngv.normal_f32(0.0, 1.0)).collect();
        let mut p = vec![0.0f32; l.n_trainable];
        let loss = |p: &[f32]| -> f32 {
            p.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        let l0 = loss(&p);
        for step in 0..50 {
            let grads: Vec<f32> =
                p.iter().zip(&target).map(|(a, b)| a - b).collect();
            g.step(step, &mut p, &grads, &h);
        }
        let l1 = loss(&p);
        assert!(l1 < l0 * 0.8, "loss {l0} -> {l1}");
    }

    #[test]
    fn rank_limits_update_rank() {
        // A single step's update matrix must have rank <= galore rank.
        let l = Layout::from_metas(vec![ParamMeta {
            name: "w".into(), shape: vec![12, 12], role: Role::Base,
            trainable: true, numel: 144, offset: 0, t_offset: None,
        }]);
        let mut g = Galore::new(&l, 2, 100, 1.0);
        let h = AdamHyper::new(0.1);
        let mut rngv = crate::util::rng::Rng::new(3);
        let grads: Vec<f32> =
            (0..144).map(|_| rngv.normal_f32(0.0, 1.0)).collect();
        let mut p = vec![0.0f32; 144];
        g.step(0, &mut p, &grads, &h);
        let upd = Tensor::from_vec(12, 12, p);
        let sv = crate::tensor::linalg::singular_values(&upd);
        let eff = crate::tensor::linalg::effective_rank(&sv, 1e-3);
        assert!(eff <= 2, "effective rank {eff}, spectrum {sv:?}");
    }
}
