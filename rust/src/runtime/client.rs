//! PJRT client wrapper and executable cache (`pjrt` feature only).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::util::logging::Timer;

/// Owns the PJRT client and caches compiled executables by artifact path.
///
/// PJRT handles are not `Send`; the engine lives on the coordinator thread
/// (on this single-core testbed there is nothing to gain from cross-thread
/// execution; the data-parallel simulator interleaves workers instead).
pub struct PjrtEngine {
    pub client: xla::PjRtClient,
    cache: HashMap<PathBuf, Rc<Executable>>,
}

impl PjrtEngine {
    pub fn cpu() -> Result<PjrtEngine> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client, cache: HashMap::new() })
    }

    /// Load-and-compile an HLO-text artifact (cached).
    pub fn load(&mut self, path: &Path) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.get(path) {
            return Ok(e.clone());
        }
        let mut t = Timer::new("compile");
        t.start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        t.stop();
        crate::info!("compiled {} in {:.2}s", path.display(),
                     t.total_secs());
        let e = Rc::new(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        });
        self.cache.insert(path.to_path_buf(), e.clone());
        Ok(e)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.len()
    }
}

/// A compiled HLO module.  All our modules are lowered with
/// `return_tuple=True`, so execution returns one tuple literal that we
/// unpack into per-output literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple().map_err(Into::into)
    }
}

/// Build an f32 literal of the given dims from a host slice.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {dims:?} != len {}",
                    data.len());
    let d64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d64)?)
}

/// Build an i32 literal of the given dims from a host slice.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "literal shape {dims:?} != len {}",
                    data.len());
    let d64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d64)?)
}

/// Extract an f32 vector from a literal.
pub fn lit_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(Into::into)
}

/// Extract a scalar f32.
pub fn lit_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(Into::into)
}
