//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them from the Rust hot path.  Python is never involved at runtime.
//!
//! * `client.rs` — PJRT CPU client wrapper + executable cache (HLO text →
//!   `HloModuleProto::from_text_file` → compile; text is the interchange
//!   format because xla_extension 0.5.1 rejects jax≥0.5 serialized protos).
//! * `exec.rs` — literal marshaling and the typed step interfaces
//!   (`ModelRuntime::fwdbwd`, `eval_loss`, `adam_step`, `cls_*`).

pub mod client;
pub mod exec;

pub use client::{Engine, Executable};
pub use exec::ModelRuntime;
