//! Pluggable execution engine.
//!
//! The coordinator talks to the model through two types:
//!
//! * [`Engine`] — backend selection.  The default build ships the pure-Rust
//!   **native** backend (`native.rs`): the tiny/LLaMA-lite decoder with a
//!   hand-written backward pass, running on any machine with no Python,
//!   XLA library or AOT artifacts.  The original **PJRT** path (load
//!   AOT-compiled HLO-text artifacts through the PJRT C API) lives behind
//!   the `pjrt` cargo feature in `client.rs`/`exec.rs`.
//! * [`ModelRuntime`] — one model variant bound to a backend; the typed
//!   step interface (`fwdbwd`, `eval_loss`, `adam_step`, `cls_*`) the
//!   trainer, evaluator and fine-tuner drive.
//! * [`InferRuntime`] — the inference surface: KV-cached prefill/decode
//!   for autoregressive generation (`infer::generate` drives it; native
//!   backend only).
//!
//! Both backends implement the [`StepRuntime`] trait and share the same
//! host-side state contract: parameters live in a `ParamStore` laid out by
//! the manifest, gradients come back packed into the flat trainable vector
//! (padded to the fused-Adam size), so the optimizer, all-reduce and
//! switch logic are backend-agnostic.
//!
//! Backend selection at run time: `Engine::cpu()` returns the native
//! backend unless the binary was built with `--features pjrt` *and*
//! `SWITCHLORA_BACKEND=pjrt` is set.
//!
//! Native compute runs on the shared threaded kernel layer
//! ([`crate::kernels`]): `--threads N` / `SWITCHLORA_THREADS` size the
//! pool, and results are bitwise identical at any thread count.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod exec;
pub mod native;

use std::cell::Cell;

use anyhow::{ensure, Result};

pub use native::NativeModel;

use crate::infer::adapters::AdapterSet;
use crate::infer::kv_cache::KvCache;
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::model::packed::ParamSource;
use crate::optim::adam::AdamState;
use crate::optim::AdamHyper;
use crate::tensor::dtype::PrecisionPolicy;

/// The engine/runtime contract every backend implements: forward+backward
/// with loss and packed gradients, eval loss, the classification variants,
/// and a fused-AdamW step over the padded trainable vector.
pub trait StepRuntime {
    /// One fwd+bwd: returns (loss, grads packed+padded).
    fn fwdbwd(&self, store: &ParamStore, tokens: &[i32], batch: usize,
              seq_plus_1: usize) -> Result<(f32, Vec<f32>)>;

    /// Evaluation loss on one batch.
    fn eval_loss(&self, store: &ParamStore, tokens: &[i32], batch: usize,
                 seq_plus_1: usize) -> Result<f32>;

    /// Classification fwd+bwd (cls variant only).
    fn cls_fwdbwd(&self, store: &ParamStore, tokens: &[i32],
                  labels: &[i32], batch: usize, seq: usize)
        -> Result<(f32, Vec<f32>)>;

    /// Classification eval: (mean loss, #correct) on one batch.
    fn cls_eval(&self, store: &ParamStore, tokens: &[i32], labels: &[i32],
                batch: usize, seq: usize) -> Result<(f32, f32)>;

    /// Fused AdamW step on the packed trainable vector.  All buffers must
    /// be padded to the runtime's padded size.
    fn adam_step(&self, params: &mut [f32], grads: &[f32],
                 opt: &mut AdamState, mask: &[f32], hyper: &AdamHyper)
        -> Result<()>;

    /// Fwd+bwd over several batches with the SAME parameters (the
    /// data-parallel inner loop).  The default is a sequential
    /// (interleaved-worker) loop; backends override it — PJRT to share
    /// parameter marshaling across executions (§Perf L3), native to run
    /// each shard on its own OS thread via the kernel pool
    /// (`kernels::scoped_map`), which keeps losses and gradients bitwise
    /// identical to this default while letting `--workers W` scale
    /// wall-clock.
    fn fwdbwd_multi(&self, store: &ParamStore,
                    batches: &[(&[i32], usize, usize)])
        -> Result<Vec<(f32, Vec<f32>)>> {
        batches
            .iter()
            .map(|&(tokens, batch, sp1)| {
                self.fwdbwd(store, tokens, batch, sp1)
            })
            .collect()
    }

    /// Eval loss over several batches with the same parameters.
    fn eval_loss_multi(&self, store: &ParamStore,
                       batches: &[(&[i32], usize, usize)])
        -> Result<Vec<f32>> {
        batches
            .iter()
            .map(|&(tokens, batch, sp1)| {
                self.eval_loss(store, tokens, batch, sp1)
            })
            .collect()
    }
}

/// The inference surface alongside [`StepRuntime`]: KV-cached
/// autoregressive decoding.  A cache produced by `new_cache` is threaded
/// through `prefill` (whole-prompt chunks, one sequence at a time — the
/// prompts may be ragged) and `decode` (one token for *every* sequence
/// per step, each at its own absolute position).  Per-token decode cost
/// is O(context) instead of the O(context²) of re-running the full
/// forward; `infer::generate` drives this loop, and adapter merging
/// (`infer::merge`) removes even the LoRA adapter arithmetic from the
/// decode path.
///
/// The `_adapted` entry points separate per-sequence adapter state from
/// the shared base: `params` stays ONE `&dyn ParamSource` for the whole
/// batch while each sequence optionally carries its own
/// [`AdapterSet`] overlay, applied unmerged inside the forward — the
/// multi-tenant serving contract (`serve`), where N tasks share one
/// quantized base with zero duplication.  The adapter-less `prefill`/
/// `decode` are provided wrappers, so single-tenant callers (and every
/// pre-serving test and bench) are unchanged.
///
/// `Send + Sync` is part of the contract: a serving scheduler owns the
/// runtime on its own thread while handler threads hold the shared
/// queue, so the runtime must be movable across threads.
pub trait InferRuntime: Send + Sync {
    /// Run a prompt chunk for sequence `seq`, extending its cache,
    /// applying `adapter`'s low-rank overlay (if any) to every adapted
    /// linear.  Returns the last position's LM logits `[vocab]`.
    /// Parameters come through [`ParamSource`]: a master-precision
    /// `ParamStore` or a quantized serving `PackedStore`
    /// (`--quantize-base`) — the packed kernels dequantize base weights
    /// on load.
    fn prefill_adapted(&self, params: &dyn ParamSource,
                       adapter: Option<&AdapterSet>, cache: &mut KvCache,
                       seq: usize, tokens: &[i32]) -> Result<Vec<f32>>;

    /// One KV-cached decode step over the listed sequences (`seqs`
    /// strictly increasing, one token each), each under its own adapter
    /// overlay (`adapters[i]` pairs with `seqs[i]`; `None` decodes the
    /// bare base).  Finished sequences are simply left off the list —
    /// they pay no compute and their cache rows stop growing.  Returns
    /// logits `[seqs.len(), vocab]` in list order.
    fn decode_adapted(&self, params: &dyn ParamSource,
                      adapters: &[Option<&AdapterSet>],
                      cache: &mut KvCache, seqs: &[usize],
                      tokens: &[i32]) -> Result<Vec<f32>>;

    /// [`InferRuntime::prefill_adapted`] with no overlay.
    fn prefill(&self, params: &dyn ParamSource, cache: &mut KvCache,
               seq: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        self.prefill_adapted(params, None, cache, seq, tokens)
    }

    /// [`InferRuntime::decode_adapted`] with no overlays.
    fn decode(&self, params: &dyn ParamSource, cache: &mut KvCache,
              seqs: &[usize], tokens: &[i32]) -> Result<Vec<f32>> {
        let none: Vec<Option<&AdapterSet>> = vec![None; seqs.len()];
        self.decode_adapted(params, &none, cache, seqs, tokens)
    }

    /// An empty cache shaped for this model: `batch` sequences of up to
    /// `capacity` positions, K/V paged in `block`-position blocks
    /// (`--kv-block`) allocated lazily from a shared pool.
    fn new_cache_blocked(&self, batch: usize, capacity: usize,
                         block: usize) -> KvCache;

    /// [`InferRuntime::new_cache_blocked`] at the default block size.
    fn new_cache(&self, batch: usize, capacity: usize) -> KvCache {
        self.new_cache_blocked(batch, capacity,
                               crate::infer::kv_cache::DEFAULT_KV_BLOCK)
    }

    /// Width of the LM head (the sampler's domain).
    fn vocab_out(&self) -> usize;
}

/// Bind `variant` of `manifest` to an inference runtime on `engine`'s
/// backend.  KV-cached generation is native-only today: the PJRT
/// artifacts are training-shaped (fixed `[batch, seq+1]` executables
/// with no incremental entry point).
pub fn load_infer(engine: &Engine, manifest: Manifest, variant: Variant)
    -> Result<Box<dyn InferRuntime>> {
    load_infer_with(engine, manifest, variant, PrecisionPolicy::default())
}

/// [`load_infer`] under a precision policy: `policy.kv_cache` sets the
/// KV-cache storage dtype (`--kv-dtype`) of every cache the runtime
/// creates, and `policy.frozen_base` how dense weights are viewed.
pub fn load_infer_with(engine: &Engine, manifest: Manifest,
                       variant: Variant, policy: PrecisionPolicy)
    -> Result<Box<dyn InferRuntime>> {
    match engine {
        Engine::Native => {
            Ok(Box::new(NativeModel::with_policy(manifest, variant,
                                                 policy)?))
        }
        #[cfg(feature = "pjrt")]
        Engine::Pjrt(_) => anyhow::bail!(
            "KV-cached inference requires the native backend \
             (unset SWITCHLORA_BACKEND)"),
    }
}

/// Backend selector.  Holds whatever per-process state the backend needs
/// (the PJRT client + executable cache for `pjrt`; nothing for native).
pub enum Engine {
    /// Pure-Rust interpreter over the `tensor`-style host buffers.
    Native,
    /// PJRT client driving AOT-compiled HLO artifacts.
    #[cfg(feature = "pjrt")]
    Pjrt(client::PjrtEngine),
}

impl Engine {
    /// The default CPU engine for this build: native, unless the `pjrt`
    /// feature is compiled in and `SWITCHLORA_BACKEND=pjrt` is set.
    pub fn cpu() -> Result<Engine> {
        #[cfg(feature = "pjrt")]
        if std::env::var("SWITCHLORA_BACKEND").as_deref() == Ok("pjrt") {
            return Self::pjrt();
        }
        Ok(Engine::Native)
    }

    /// The native backend, unconditionally.
    pub fn native() -> Engine {
        Engine::Native
    }

    /// The PJRT backend (requires `--features pjrt` and AOT artifacts).
    #[cfg(feature = "pjrt")]
    pub fn pjrt() -> Result<Engine> {
        Ok(Engine::Pjrt(client::PjrtEngine::cpu()?))
    }

    pub fn backend_name(&self) -> &'static str {
        match self {
            Engine::Native => "native",
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(_) => "pjrt",
        }
    }
}

/// One model variant bound to a backend: the object the trainer drives.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub variant: Variant,
    /// padded trainable size of the fused-Adam buffers
    pub padded: usize,
    /// executions counter (for perf accounting)
    pub n_execs: Cell<u64>,
    inner: Box<dyn StepRuntime>,
}

impl ModelRuntime {
    /// Bind `variant` of `manifest` to `engine`'s backend with the
    /// default (all-f32, bitwise-legacy) precision policy.
    pub fn load(engine: &mut Engine, manifest: Manifest, variant: Variant)
        -> Result<ModelRuntime> {
        Self::load_with(engine, manifest, variant,
                        PrecisionPolicy::default())
    }

    /// Bind `variant` of `manifest` to `engine`'s backend under a
    /// precision policy (frozen base weights viewed in
    /// `policy.frozen_base` by the packed kernels).  Only the native
    /// backend is dtype-aware; PJRT artifacts are compiled f32.
    pub fn load_with(engine: &mut Engine, manifest: Manifest,
                     variant: Variant, policy: PrecisionPolicy)
        -> Result<ModelRuntime> {
        let inner: Box<dyn StepRuntime> = match engine {
            Engine::Native => {
                Box::new(native::NativeModel::with_policy(
                    manifest.clone(), variant, policy)?)
            }
            #[cfg(feature = "pjrt")]
            Engine::Pjrt(e) => {
                ensure!(policy.is_default(),
                        "precision policies need the native backend \
                         (the PJRT artifacts are compiled f32)");
                Box::new(exec::PjrtRuntime::load(e, manifest.clone(),
                                                 variant)?)
            }
        };
        let padded = manifest.adam_padded(variant)?;
        Ok(ModelRuntime {
            manifest,
            variant,
            padded,
            n_execs: Cell::new(0),
            inner,
        })
    }

    fn bump(&self, n: u64) {
        self.n_execs.set(self.n_execs.get() + n);
    }

    /// One fwd+bwd: returns (loss, grads packed+padded to `self.padded`).
    pub fn fwdbwd(&self, store: &ParamStore, tokens: &[i32], batch: usize,
                  seq_plus_1: usize) -> Result<(f32, Vec<f32>)> {
        self.bump(1);
        self.inner.fwdbwd(store, tokens, batch, seq_plus_1)
    }

    /// Fwd+bwd over several batches with the same parameters.
    pub fn fwdbwd_multi(&self, store: &ParamStore,
                        batches: &[(&[i32], usize, usize)])
        -> Result<Vec<(f32, Vec<f32>)>> {
        self.bump(batches.len() as u64);
        self.inner.fwdbwd_multi(store, batches)
    }

    /// Evaluation loss on one batch.
    pub fn eval_loss(&self, store: &ParamStore, tokens: &[i32],
                     batch: usize, seq_plus_1: usize) -> Result<f32> {
        self.bump(1);
        self.inner.eval_loss(store, tokens, batch, seq_plus_1)
    }

    /// Eval loss over several batches with the same parameters.
    pub fn eval_loss_multi(&self, store: &ParamStore,
                           batches: &[(&[i32], usize, usize)])
        -> Result<Vec<f32>> {
        self.bump(batches.len() as u64);
        self.inner.eval_loss_multi(store, batches)
    }

    /// Classification fwd+bwd (cls variant only).
    pub fn cls_fwdbwd(&self, store: &ParamStore, tokens: &[i32],
                      labels: &[i32], batch: usize, seq: usize)
        -> Result<(f32, Vec<f32>)> {
        ensure!(self.variant == Variant::Cls,
                "cls_fwdbwd requires the cls variant");
        self.bump(1);
        self.inner.cls_fwdbwd(store, tokens, labels, batch, seq)
    }

    /// Classification eval: (mean loss, #correct) on one batch.
    pub fn cls_eval(&self, store: &ParamStore, tokens: &[i32],
                    labels: &[i32], batch: usize, seq: usize)
        -> Result<(f32, f32)> {
        ensure!(self.variant == Variant::Cls,
                "cls_eval needs cls variant");
        self.bump(1);
        self.inner.cls_eval(store, tokens, labels, batch, seq)
    }

    /// Fused AdamW step on the packed trainable vector.  `params`,
    /// `grads`, `opt.{m,v,s}` and `mask` must all be padded to
    /// `self.padded`.
    pub fn adam_step(&self, params: &mut [f32], grads: &[f32],
                     opt: &mut AdamState, mask: &[f32],
                     hyper: &AdamHyper) -> Result<()> {
        self.bump(1);
        self.inner.adam_step(params, grads, opt, mask, hyper)
    }
}
