//! Native CPU execution backend: the LLaMA-lite decoder (embedding → N
//! blocks of [RMSNorm → causal RoPE attention → residual, RMSNorm →
//! SwiGLU MLP → residual] → RMSNorm → LM/cls head) with LoRA-adapted
//! linears, implemented directly on host `f32` buffers with a hand-written
//! backward pass.
//!
//! This is the default engine: it executes the exact architecture that
//! `python/compile/model.py` lowers to HLO (same parameter layout, same
//! math, `W + s·BA` adapters per Section 2.1), but needs no Python, XLA
//! library or AOT artifacts — `cargo test` exercises the full training
//! loop on any machine.  The backward formulas are verified two ways:
//! property tests diff every op against central-difference numerical
//! gradients (`rust/tests/native_grads.rs`), and the lora/full variants
//! are cross-checked against each other with zeroed adapters
//! (`rust/tests/integration_runtime.rs`).
//!
//! All math runs on the shared kernel layer ([`crate::kernels`]): the
//! matmul family and the attention primitives are cache-blocked and
//! multi-threaded there, with a determinism contract — every output
//! element is owned by exactly one task with a fixed accumulation order
//! — so runs are bitwise deterministic from a seed *at any thread
//! count*, a property the trainer's determinism tests pin down.  Multi-
//! batch entry points (`fwdbwd_multi`/`eval_loss_multi`) additionally
//! fan shards out onto real OS threads, which is what makes the
//! coordinator's `--workers W` scale wall-clock.

use anyhow::{bail, ensure, Result};

use super::{InferRuntime, StepRuntime};
use crate::infer::adapters::AdapterSet;
use crate::infer::kv_cache::KvCache;
use crate::kernels::{self, addmm_nn, addmm_nn_packed, addmm_nt,
                     addmm_nt_packed, addmm_tn};
use crate::model::layout::{Layout, Manifest, ParamStore, Variant};
use crate::model::packed::ParamSource;
use crate::optim::adam::{host_step, AdamState};
use crate::optim::AdamHyper;
use crate::tensor::dtype::{DType, MatRef, PackedBuf, PrecisionPolicy};

// The attention primitives live in the shared kernel layer; re-exported
// here so gradient tests and the KV cache keep addressing them as part
// of the native backend's op set.
pub use crate::kernels::{causal_attention_bwd, causal_attention_fwd};

const RMS_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------
// Ops: each with an explicit backward, unit-testable in isolation.
// ---------------------------------------------------------------------

/// `y = x @ Wᵀ` for a plain linear (`w` is `[m,k]`).
pub fn linear_fwd(x: &[f32], w: &[f32], rows: usize, k: usize, m: usize)
    -> Vec<f32> {
    let mut y = vec![0.0; rows * m];
    addmm_nt(&mut y, x, w, rows, k, m);
    y
}

/// LoRA linear forward `y = x Wᵀ + s·(x Aᵀ) Bᵀ`; returns `(y, xa)` with
/// `xa = x Aᵀ` saved for the backward pass.
#[allow(clippy::too_many_arguments)]
pub fn lora_linear_fwd(x: &[f32], w: &[f32], a: &[f32], b: &[f32],
                       scale: f32, rows: usize, n_in: usize, m_out: usize,
                       r: usize) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0; rows * m_out];
    addmm_nt(&mut y, x, w, rows, n_in, m_out);
    let xa = linear_fwd(x, a, rows, n_in, r);
    let mut yb = vec![0.0; rows * m_out];
    addmm_nt(&mut yb, &xa, b, rows, r, m_out);
    for (yi, bi) in y.iter_mut().zip(&yb) {
        *yi += scale * bi;
    }
    (y, xa)
}

/// Gradients of one (possibly LoRA-adapted) linear.
pub struct LinearGrads {
    pub dx: Vec<f32>,
    /// base-weight gradient (only when requested: full-rank variant)
    pub dw: Option<Vec<f32>>,
    pub da: Option<Vec<f32>>,
    pub db: Option<Vec<f32>>,
}

/// Backward of `linear_fwd`.
pub fn linear_bwd(dy: &[f32], x: &[f32], w: &[f32], rows: usize, k: usize,
                  m: usize, want_dw: bool) -> LinearGrads {
    let mut dx = vec![0.0; rows * k];
    addmm_nn(&mut dx, dy, w, rows, m, k);
    let dw = want_dw.then(|| {
        let mut g = vec![0.0; m * k];
        addmm_tn(&mut g, dy, x, rows, m, k);
        g
    });
    LinearGrads { dx, dw, da: None, db: None }
}

/// Backward of `lora_linear_fwd`:
/// `dX = dY W + s·(dY B) A`, `dA = s·(dY B)ᵀ X`, `dB = s·dYᵀ (X Aᵀ)`,
/// and optionally `dW = dYᵀ X` (frozen in the LoRA variant).
#[allow(clippy::too_many_arguments)]
pub fn lora_linear_bwd(dy: &[f32], x: &[f32], xa: &[f32], w: &[f32],
                       a: &[f32], b: &[f32], scale: f32, rows: usize,
                       n_in: usize, m_out: usize, r: usize, want_dw: bool)
    -> LinearGrads {
    let mut g = linear_bwd(dy, x, w, rows, n_in, m_out, want_dw);
    // dyb = s·(dY @ B)  [rows, r]  (B is [m, r]: "nn" orientation)
    let mut dyb = vec![0.0; rows * r];
    addmm_nn(&mut dyb, dy, b, rows, m_out, r);
    for v in dyb.iter_mut() {
        *v *= scale;
    }
    addmm_nn(&mut g.dx, &dyb, a, rows, r, n_in);
    let mut da = vec![0.0; r * n_in];
    addmm_tn(&mut da, &dyb, x, rows, r, n_in);
    let mut db = vec![0.0; m_out * r];
    addmm_tn(&mut db, dy, xa, rows, m_out, r);
    for v in db.iter_mut() {
        *v *= scale;
    }
    g.da = Some(da);
    g.db = Some(db);
    g
}

/// RMSNorm forward `y = x · rsqrt(mean(x²)+ε) · g`; returns `(y, inv)`
/// with the per-row `rsqrt` saved for the backward pass.
pub fn rms_norm_fwd(x: &[f32], g: &[f32], rows: usize, h: usize)
    -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0.0; rows * h];
    let mut inv = vec![0.0; rows];
    for i in 0..rows {
        let xr = &x[i * h..(i + 1) * h];
        let mut ms = 0.0f32;
        for v in xr {
            ms += v * v;
        }
        let r = 1.0 / (ms / h as f32 + RMS_EPS).sqrt();
        inv[i] = r;
        let yr = &mut y[i * h..(i + 1) * h];
        for j in 0..h {
            yr[j] = xr[j] * r * g[j];
        }
    }
    (y, inv)
}

/// Backward of `rms_norm_fwd`: returns `(dx, dg)`.
pub fn rms_norm_bwd(dy: &[f32], x: &[f32], inv: &[f32], g: &[f32],
                    rows: usize, h: usize) -> (Vec<f32>, Vec<f32>) {
    let mut dx = vec![0.0; rows * h];
    let mut dg = vec![0.0; h];
    for i in 0..rows {
        let xr = &x[i * h..(i + 1) * h];
        let dyr = &dy[i * h..(i + 1) * h];
        let r = inv[i];
        // du = dY·g;  t = Σ du·x;  dx = r·du − x·r³·t/H
        let mut t = 0.0f32;
        for j in 0..h {
            t += dyr[j] * g[j] * xr[j];
        }
        let c = r * r * r * t / h as f32;
        let dxr = &mut dx[i * h..(i + 1) * h];
        for j in 0..h {
            dxr[j] = r * dyr[j] * g[j] - xr[j] * c;
            dg[j] += dyr[j] * xr[j] * r;
        }
    }
    (dx, dg)
}

/// In-place rotary embedding on `[bh, t, hd]` (pairs `(j, j+hd/2)`,
/// position = the middle index — mirrors `model.py::_rope`).
pub fn rope_fwd(x: &mut [f32], bh: usize, t: usize, hd: usize) {
    rope_apply(x, bh, t, hd, 0, false);
}

/// Forward rotation at absolute positions `pos0..pos0+t` — the KV-cached
/// incremental path, where a chunk's rows sit at an offset into the
/// sequence.  `rope_fwd` is the `pos0 = 0` special case, so cached and
/// full-context forwards rotate identically.
pub fn rope_fwd_at(x: &mut [f32], bh: usize, t: usize, hd: usize,
                   pos0: usize) {
    rope_apply(x, bh, t, hd, pos0, false);
}

/// Backward (= inverse rotation: RoPE is orthogonal per pair).
pub fn rope_bwd(dx: &mut [f32], bh: usize, t: usize, hd: usize) {
    rope_apply(dx, bh, t, hd, 0, true);
}

fn rope_apply(x: &mut [f32], bh: usize, t: usize, hd: usize, pos0: usize,
              inverse: bool) {
    let half = hd / 2;
    debug_assert_eq!(half * 2, hd, "RoPE needs even head dim");
    // cos/sin table [t, half]
    let mut cs = vec![(0.0f32, 0.0f32); t * half];
    for p in 0..t {
        for f in 0..half {
            let freq = 1.0 / 10000.0f32.powf(f as f32 / half as f32);
            let ang = (pos0 + p) as f32 * freq;
            let (s, c) = ang.sin_cos();
            cs[p * half + f] = (c, if inverse { -s } else { s });
        }
    }
    for g in 0..bh {
        for p in 0..t {
            let row = &mut x[(g * t + p) * hd..(g * t + p + 1) * hd];
            for f in 0..half {
                let (c, s) = cs[p * half + f];
                let (x1, x2) = (row[f], row[f + half]);
                row[f] = x1 * c - x2 * s;
                row[f + half] = x1 * s + x2 * c;
            }
        }
    }
}

/// Mean softmax cross-entropy over `[rows, v]` logits with integer
/// targets.  Returns `(loss, dlogits)` with `dlogits` already divided by
/// `rows` (the mean's normalizer), plus the per-row argmax (for cls
/// accuracy).
pub fn softmax_xent(logits: &[f32], targets: &[i32], rows: usize, v: usize)
    -> (f32, Vec<f32>, Vec<usize>) {
    let mut dlogits = vec![0.0; rows * v];
    let mut argmax = vec![0usize; rows];
    let mut loss = 0.0f64;
    let inv_rows = 1.0 / rows as f32;
    for i in 0..rows {
        let zr = &logits[i * v..(i + 1) * v];
        let mut zmax = f32::NEG_INFINITY;
        let mut amax = 0usize;
        for (j, &z) in zr.iter().enumerate() {
            if z > zmax {
                zmax = z;
                amax = j;
            }
        }
        argmax[i] = amax;
        let mut denom = 0.0f32;
        for &z in zr {
            denom += (z - zmax).exp();
        }
        let lse = zmax + denom.ln();
        let tgt = targets[i] as usize;
        loss += (lse - zr[tgt]) as f64;
        let dr = &mut dlogits[i * v..(i + 1) * v];
        for j in 0..v {
            dr[j] = ((zr[j] - lse).exp()
                     - if j == tgt { 1.0 } else { 0.0 }) * inv_rows;
        }
    }
    ((loss / rows as f64) as f32, dlogits, argmax)
}

// ---------------------------------------------------------------------
// Head-layout transforms: [B,T,nh·hd] flat ↔ [B·nh, T, hd].
// ---------------------------------------------------------------------

fn to_heads(x: &[f32], b: usize, t: usize, nh: usize, hd: usize)
    -> Vec<f32> {
    let h = nh * hd;
    let mut out = vec![0.0; b * t * h];
    for bi in 0..b {
        for ti in 0..t {
            for hi in 0..nh {
                let src = (bi * t + ti) * h + hi * hd;
                let dst = ((bi * nh + hi) * t + ti) * hd;
                out[dst..dst + hd].copy_from_slice(&x[src..src + hd]);
            }
        }
    }
    out
}

fn from_heads(x: &[f32], b: usize, t: usize, nh: usize, hd: usize)
    -> Vec<f32> {
    let h = nh * hd;
    let mut out = vec![0.0; b * t * h];
    for bi in 0..b {
        for ti in 0..t {
            for hi in 0..nh {
                let src = ((bi * nh + hi) * t + ti) * hd;
                let dst = (bi * t + ti) * h + hi * hd;
                out[dst..dst + hd].copy_from_slice(&x[src..src + hd]);
            }
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn dsilu(x: f32) -> f32 {
    let s = 1.0 / (1.0 + (-x).exp());
    s * (1.0 + x * (1.0 - s))
}

// ---------------------------------------------------------------------
// The model.
// ---------------------------------------------------------------------

/// Saved activations of one decoder block (consumed by the backward
/// sweep in reverse layer order).
struct LayerActs {
    x_in: Vec<f32>,
    xn1: Vec<f32>,
    inv1: Vec<f32>,
    /// q/k (RoPE-rotated) and v in `[B·nh, T, hd]` layout
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    /// attention output back in `[N, H]` layout (input to wo)
    o2: Vec<f32>,
    x_mid: Vec<f32>,
    xn2: Vec<f32>,
    inv2: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    act: Vec<f32>,
    /// per-linear `x Aᵀ` saves, keyed like `lin_idx` (LoRA variant only)
    xa: [Vec<f32>; 7],
}

/// Order of the seven LoRA-adapted linears inside a block, matching
/// `Manifest::linears` (wq wk wv wo w_gate w_up w_down).
const LIN_NAMES: [&str; 7] = ["wq", "wk", "wv", "wo", "w_gate", "w_up",
                              "w_down"];

/// Result of the output-head pass (pooling, head linear, loss): what the
/// backward sweep and the eval paths both need.
struct HeadPass {
    loss: f32,
    correct: usize,
    /// head parameter name ("lm_head" / "cls_head")
    name: &'static str,
    /// logit rows: B·T for LM, B for cls
    rows: usize,
    /// the head's input activations `[rows, H]`
    head_in: Vec<f32>,
    /// d loss / d logits `[rows, v_out]`
    dlogits: Vec<f32>,
    v_out: usize,
}

/// The native engine's per-variant model instance.
pub struct NativeModel {
    pub manifest: Manifest,
    pub variant: Variant,
    pub padded: usize,
    /// Precision policy: which dtype frozen base weights are viewed in
    /// by the matmul kernels.  The all-f32 default takes the legacy
    /// code paths bitwise.
    pub policy: PrecisionPolicy,
}

impl NativeModel {
    pub fn new(manifest: Manifest, variant: Variant)
        -> Result<NativeModel> {
        Self::with_policy(manifest, variant, PrecisionPolicy::default())
    }

    /// [`NativeModel::new`] with an explicit precision policy.  Only
    /// `policy.frozen_base` changes this model's arithmetic: a *frozen*
    /// dense weight (one that carries LoRA adapters) is repacked to that
    /// dtype before each matmul, amortized over the batch; trainable
    /// dense weights, adapters, norms, embeddings and heads always stay
    /// master f32.  Serving paths avoid the per-call repack by handing
    /// the model an already-packed [`crate::model::packed::PackedStore`].
    pub fn with_policy(manifest: Manifest, variant: Variant,
                       policy: PrecisionPolicy) -> Result<NativeModel> {
        let mc = &manifest.config;
        ensure!(mc.hidden % mc.heads == 0,
                "hidden {} not divisible by heads {}", mc.hidden, mc.heads);
        ensure!(mc.head_dim() % 2 == 0,
                "RoPE needs an even head dim, got {}", mc.head_dim());
        let padded = manifest.adam_padded(variant)?;
        // validate the layout names the forward pass will look up
        let layout = manifest.layout(variant)?;
        for name in ["embed", "final_norm"] {
            layout.meta(name)?;
        }
        layout.meta(if variant == Variant::Cls { "cls_head" }
                    else { "lm_head" })?;
        Ok(NativeModel { manifest, variant, padded, policy })
    }

    fn layout(&self) -> &Layout {
        self.manifest
            .layout(self.variant)
            .expect("variant validated in new()")
    }

    /// Whether linear `name` carries LoRA adapters, decided *per linear*
    /// from the layout rather than globally from the variant: the pure
    /// lora layout adapts every linear, full/cls none, and hybrid
    /// layouts (layerwise full+LoRA methods) mix both in one model.
    fn adapted(&self, name: &str) -> bool {
        self.layout().by_name.contains_key(&format!("{name}.a"))
    }

    /// Forward through the decoder stack.  Returns
    /// `(xf, xf_in, invf, acts)`: final normed hidden `[N,H]`, its
    /// pre-norm input, the final-norm rsqrt, and per-layer activations.
    fn forward(&self, store: &ParamStore, inp: &[i32], b: usize, t: usize)
        -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<LayerActs>)> {
        let mc = &self.manifest.config;
        let (h, nh) = (mc.hidden, mc.heads);
        let hd = mc.head_dim();
        let scale = mc.lora_scale() as f32;
        let n = b * t;
        let embed = store.slice("embed")?;
        let mut x = vec![0.0f32; n * h];
        for (i, &tok) in inp.iter().enumerate() {
            let tok = tok as usize;
            ensure!(tok < mc.vocab, "token {tok} out of vocab {}", mc.vocab);
            x[i * h..(i + 1) * h]
                .copy_from_slice(&embed[tok * h..(tok + 1) * h]);
        }
        let mut acts = Vec::with_capacity(mc.layers);
        for li in 0..mc.layers {
            let mut xa: [Vec<f32>; 7] = Default::default();
            let x_in = x.clone();
            let (xn1, inv1) = rms_norm_fwd(
                &x, store.slice(&format!("l{li}.attn_norm"))?, n, h);
            let mut qkv: [Vec<f32>; 3] = Default::default();
            for (w_i, slot) in qkv.iter_mut().enumerate() {
                let (y, s) =
                    self.lin_fwd(store, li, w_i, &xn1, n, scale)?;
                *slot = y;
                xa[w_i] = s;
            }
            let [yq, yk, yv] = qkv;
            let mut q = to_heads(&yq, b, t, nh, hd);
            let mut k = to_heads(&yk, b, t, nh, hd);
            let v = to_heads(&yv, b, t, nh, hd);
            rope_fwd(&mut q, b * nh, t, hd);
            rope_fwd(&mut k, b * nh, t, hd);
            let (o, att) = causal_attention_fwd(&q, &k, &v, b * nh, t, hd);
            let o2 = from_heads(&o, b, t, nh, hd);
            let (yo, s) = self.lin_fwd(store, li, 3, &o2, n, scale)?;
            xa[3] = s;
            for (xi, yi) in x.iter_mut().zip(&yo) {
                *xi += yi;
            }
            let x_mid = x.clone();
            let (xn2, inv2) = rms_norm_fwd(
                &x, store.slice(&format!("l{li}.mlp_norm"))?, n, h);
            let (gate, s) = self.lin_fwd(store, li, 4, &xn2, n, scale)?;
            xa[4] = s;
            let (up, s) = self.lin_fwd(store, li, 5, &xn2, n, scale)?;
            xa[5] = s;
            let act: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let (ydown, s) = self.lin_fwd(store, li, 6, &act, n, scale)?;
            xa[6] = s;
            for (xi, yi) in x.iter_mut().zip(&ydown) {
                *xi += yi;
            }
            acts.push(LayerActs {
                x_in, xn1, inv1, q, k, v, att, o2, x_mid, xn2, inv2, gate,
                up, act, xa,
            });
        }
        let xf_in = x;
        let (xf, invf) =
            rms_norm_fwd(&xf_in, store.slice("final_norm")?, n, h);
        Ok((xf, xf_in, invf, acts))
    }

    /// View of a dense base weight for the matmul kernels.  When the
    /// weight is *frozen* (it carries adapters) and the policy asks for
    /// a sub-f32 `frozen_base`, an f32 master view is repacked to that
    /// dtype (`owned` keeps the transient buffer alive); already-packed
    /// sources (a serving [`crate::model::packed::PackedStore`]) and
    /// trainable dense weights pass through untouched.
    ///
    /// Deliberately NOT cached across calls: the switch op mutates `W`
    /// through the store with no notification here, and a stale packed
    /// copy would be silently (bitwise-)wrong after a switch — while
    /// detecting staleness costs as much as repacking.  The repack is
    /// O(m·n) against the matmul's O(rows·m·n), under 1% of step time
    /// at training batch shapes; latency-critical serving avoids it
    /// entirely by pre-packing (`PackedStore`).
    fn base_view<'a>(&self, wv: MatRef<'a>, frozen: bool, m: usize,
                     n: usize, owned: &'a mut Option<PackedBuf>)
        -> MatRef<'a> {
        if frozen && self.policy.frozen_base != DType::F32 {
            if let MatRef::F32(w) = wv {
                let packed =
                    PackedBuf::pack(w, m, n, self.policy.frozen_base);
                return owned.insert(packed).view();
            }
        }
        wv
    }

    /// Apply block linear `lin_idx` (see `LIN_NAMES`) of layer `li`.
    /// The base weight comes through [`ParamSource::mat`] at whatever
    /// dtype it is stored in; adapters are always master f32.
    fn lin_fwd(&self, src: &dyn ParamSource, li: usize, lin_idx: usize,
               x: &[f32], rows: usize, scale: f32)
        -> Result<(Vec<f32>, Vec<f32>)> {
        let (name, m, n_in) = self.lin_dims(li, lin_idx);
        let adapted = self.adapted(&name);
        let mut owned = None;
        let wv = self.base_view(src.mat(&name)?, adapted, m, n_in,
                                &mut owned);
        let mut y = vec![0.0; rows * m];
        addmm_nt_packed(&mut y, x, wv, rows, n_in, m);
        if adapted {
            let a = src.f32s(&format!("{name}.a"))?;
            let bb = src.f32s(&format!("{name}.b"))?;
            let r = self.manifest.config.rank;
            let xa = linear_fwd(x, a, rows, n_in, r);
            let mut yb = vec![0.0; rows * m];
            addmm_nt(&mut yb, &xa, bb, rows, r, m);
            for (yi, bi) in y.iter_mut().zip(&yb) {
                *yi += scale * bi;
            }
            Ok((y, xa))
        } else {
            Ok((y, Vec::new()))
        }
    }

    fn lin_dims(&self, li: usize, lin_idx: usize)
        -> (String, usize, usize) {
        let mc = &self.manifest.config;
        let (m, n_in) = match lin_idx {
            0..=3 => (mc.hidden, mc.hidden),
            4 | 5 => (mc.ff, mc.hidden),
            _ => (mc.hidden, mc.ff),
        };
        (format!("l{li}.{}", LIN_NAMES[lin_idx]), m, n_in)
    }

    /// Add `ad`'s low-rank delta for linear `name` onto the base output
    /// `y` (`[rows, m]`, inputs `x` `[rows, n_in]`): `y += scale ·
    /// (x·Aᵀ)·Bᵀ`.  Deliberately the SAME operation order as the
    /// stored-adapter branch of `lin_fwd` (zero-initialized `x·Aᵀ`
    /// buffer, zero-initialized `·Bᵀ` buffer, then one scaled
    /// accumulation), so an overlay over the f32-viewed base is bitwise
    /// identical to running that adapter from its LoRA-variant store —
    /// the serving-parity invariant `rust/tests/serving.rs` pins.
    /// An adapter that doesn't cover `name` (layerwise-hybrid sets) is
    /// a no-op.
    fn apply_overlay(&self, y: &mut [f32], x: &[f32], rows: usize,
                     m: usize, n_in: usize, name: &str, ad: &AdapterSet)
        -> Result<()> {
        let Some(lr) = ad.get(name) else { return Ok(()) };
        ensure!(lr.m == m && lr.n == n_in,
                "adapter {} disagrees with {name}: overlay [{}, {}] vs \
                 base [{m}, {n_in}]", ad.name, lr.m, lr.n);
        let xa = linear_fwd(x, &lr.a, rows, n_in, lr.r);
        let mut yb = vec![0.0; rows * m];
        addmm_nt(&mut yb, &xa, &lr.b, rows, lr.r, m);
        for (yi, bi) in y[..rows * m].iter_mut().zip(&yb) {
            *yi += ad.scale * bi;
        }
        Ok(())
    }

    /// `lin_fwd` with one adapter overlay shared by every row — the
    /// prefill shape (all rows belong to one sequence).
    fn lin_fwd_uni(&self, src: &dyn ParamSource, li: usize,
                   lin_idx: usize, x: &[f32], rows: usize, scale: f32,
                   ov: Option<&AdapterSet>) -> Result<Vec<f32>> {
        let (mut y, _) = self.lin_fwd(src, li, lin_idx, x, rows, scale)?;
        if let Some(ad) = ov {
            let (name, m, n_in) = self.lin_dims(li, lin_idx);
            self.apply_overlay(&mut y, x, rows, m, n_in, &name, ad)?;
        }
        Ok(y)
    }

    /// `lin_fwd` with a per-row adapter overlay — the decode shape (row
    /// `i` belongs to sequence `i` of the step's list).  The kernels
    /// compute each output row independently of its batch company, so
    /// row-at-a-time overlay application below is bitwise identical to
    /// the batched `lin_fwd_uni` path a solo run takes.
    fn lin_fwd_rows(&self, src: &dyn ParamSource, li: usize,
                    lin_idx: usize, x: &[f32], rows: usize, scale: f32,
                    ovs: &[Option<&AdapterSet>]) -> Result<Vec<f32>> {
        let (mut y, _) = self.lin_fwd(src, li, lin_idx, x, rows, scale)?;
        if ovs.iter().any(|o| o.is_some()) {
            let (name, m, n_in) = self.lin_dims(li, lin_idx);
            debug_assert_eq!(ovs.len(), rows);
            for (i, ov) in ovs.iter().enumerate() {
                if let Some(ad) = ov {
                    self.apply_overlay(&mut y[i * m..(i + 1) * m],
                                       &x[i * n_in..(i + 1) * n_in],
                                       1, m, n_in, &name, ad)?;
                }
            }
        }
        Ok(y)
    }

    /// Backward of block linear `lin_idx`, accumulating parameter grads
    /// into `flat` (packed trainable vector) and returning `dx`.  The
    /// base weight is consumed through the same dtype view as the
    /// forward (`dX`'s base term dequantizes on load); adapter and
    /// dense-weight gradients stay master f32.
    #[allow(clippy::too_many_arguments)]
    fn lin_bwd(&self, src: &dyn ParamSource, flat: &mut [f32], li: usize,
               lin_idx: usize, dy: &[f32], x: &[f32], xa: &[f32],
               rows: usize, scale: f32) -> Result<Vec<f32>> {
        let (name, m, n_in) = self.lin_dims(li, lin_idx);
        let adapted = self.adapted(&name);
        let layout = self.layout();
        let mut owned = None;
        let wv = self.base_view(src.mat(&name)?, adapted, m, n_in,
                                &mut owned);
        // dX's base term: dY @ W (dequant-on-load when W is packed)
        let mut dx = vec![0.0; rows * n_in];
        addmm_nn_packed(&mut dx, dy, wv, rows, m, n_in);
        if adapted {
            let a = src.f32s(&format!("{name}.a"))?;
            let bb = src.f32s(&format!("{name}.b"))?;
            let r = self.manifest.config.rank;
            // dyb = s·(dY @ B)  [rows, r]
            let mut dyb = vec![0.0; rows * r];
            addmm_nn(&mut dyb, dy, bb, rows, m, r);
            for v in dyb.iter_mut() {
                *v *= scale;
            }
            addmm_nn(&mut dx, &dyb, a, rows, r, n_in);
            let mut da = vec![0.0; r * n_in];
            addmm_tn(&mut da, &dyb, x, rows, r, n_in);
            let mut db = vec![0.0; m * r];
            addmm_tn(&mut db, dy, xa, rows, m, r);
            for v in db.iter_mut() {
                *v *= scale;
            }
            accumulate(flat, layout, &format!("{name}.a"), &da)?;
            accumulate(flat, layout, &format!("{name}.b"), &db)?;
            Ok(dx)
        } else {
            let mut dw = vec![0.0; m * n_in];
            addmm_tn(&mut dw, dy, x, rows, m, n_in);
            accumulate(flat, layout, &name, &dw)?;
            Ok(dx)
        }
    }

    /// Output-head pass shared by fwdbwd and eval: pool the last position
    /// (cls) or pass every position through (LM), apply the head linear
    /// and the cross-entropy loss.  Targets are bounds-checked here — the
    /// one place invalid labels/targets could otherwise index out of
    /// range.
    fn head_pass(&self, store: &ParamStore, xf: &[f32], targets: &[i32],
                 b: usize, t: usize, cls: bool) -> Result<HeadPass> {
        let h = self.manifest.config.hidden;
        let n = b * t;
        let (name, rows, head_in): (&'static str, usize, Vec<f32>) =
            if cls {
                let mut pooled = vec![0.0f32; b * h];
                for bi in 0..b {
                    let src = (bi * t + t - 1) * h;
                    pooled[bi * h..(bi + 1) * h]
                        .copy_from_slice(&xf[src..src + h]);
                }
                ("cls_head", b, pooled)
            } else {
                ("lm_head", n, xf.to_vec())
            };
        let head = store.slice(name)?;
        let v_out = self.layout().meta(name)?.rows();
        ensure!(targets.len() == rows,
                "{} targets for {rows} {name} rows", targets.len());
        for &tg in targets {
            ensure!(tg >= 0 && (tg as usize) < v_out,
                    "target {tg} out of range for {name} ({v_out} \
                     classes)");
        }
        let logits = linear_fwd(&head_in, head, rows, h, v_out);
        let (loss, dlogits, argmax) =
            softmax_xent(&logits, targets, rows, v_out);
        let correct = argmax
            .iter()
            .zip(targets.iter())
            .filter(|&(&am, &tg)| am == tg as usize)
            .count();
        Ok(HeadPass { loss, correct, name, rows, head_in, dlogits, v_out })
    }

    /// Shared fwd+bwd core; `targets` is per-position next tokens for the
    /// LM variants or per-sequence labels for cls.
    fn fwdbwd_inner(&self, store: &ParamStore, inp: &[i32],
                    targets: &[i32], b: usize, t: usize, cls: bool)
        -> Result<(f32, Vec<f32>, usize)> {
        let mc = &self.manifest.config;
        let (h, nh) = (mc.hidden, mc.heads);
        let hd = mc.head_dim();
        let scale = mc.lora_scale() as f32;
        let n = b * t;
        let layout = self.layout();
        let sp = crate::obs::phase("forward");
        let (xf, xf_in, invf, acts) = self.forward(store, inp, b, t)?;
        sp.done();
        // everything from the head pass to the embedding scatter is the
        // backward sweep; early `?` returns record the span at drop
        let sp = crate::obs::phase("backward");

        let mut flat =
            vec![0.0f32; self.padded.max(layout.n_trainable)];
        // ---- head + loss ----
        let hp = self.head_pass(store, &xf, targets, b, t, cls)?;
        let loss = hp.loss;
        let gh = linear_bwd(&hp.dlogits, &hp.head_in,
                            store.slice(hp.name)?, hp.rows, h, hp.v_out,
                            true);
        accumulate(&mut flat, layout, hp.name, &gh.dw.unwrap())?;
        let dxf = if cls {
            let mut d = vec![0.0f32; n * h];
            for bi in 0..b {
                let dst = (bi * t + t - 1) * h;
                d[dst..dst + h]
                    .copy_from_slice(&gh.dx[bi * h..(bi + 1) * h]);
            }
            d
        } else {
            gh.dx
        };

        // ---- final norm ----
        let (dx0, dgf) = rms_norm_bwd(&dxf, &xf_in, &invf,
                                      store.slice("final_norm")?, n, h);
        accumulate(&mut flat, layout, "final_norm", &dgf)?;
        let mut dx = dx0;

        // ---- blocks, reverse order ----
        for li in (0..mc.layers).rev() {
            let a = &acts[li];
            // MLP block: x = x_mid + down(silu(gate)·up)
            let dact = self.lin_bwd(store, &mut flat, li, 6, &dx, &a.act,
                                    &a.xa[6], n, scale)?;
            let mut dgate = vec![0.0f32; dact.len()];
            let mut dup = vec![0.0f32; dact.len()];
            for (i, &d) in dact.iter().enumerate() {
                dgate[i] = d * a.up[i] * dsilu(a.gate[i]);
                dup[i] = d * silu(a.gate[i]);
            }
            let mut dxn2 = self.lin_bwd(store, &mut flat, li, 4, &dgate,
                                        &a.xn2, &a.xa[4], n, scale)?;
            let dxn2_up = self.lin_bwd(store, &mut flat, li, 5, &dup,
                                       &a.xn2, &a.xa[5], n, scale)?;
            for (u, v) in dxn2.iter_mut().zip(&dxn2_up) {
                *u += v;
            }
            let (dxm, dg2) = rms_norm_bwd(
                &dxn2, &a.x_mid, &a.inv2,
                store.slice(&format!("l{li}.mlp_norm"))?, n, h);
            accumulate(&mut flat, layout, &format!("l{li}.mlp_norm"),
                       &dg2)?;
            for (u, v) in dx.iter_mut().zip(&dxm) {
                *u += v;
            }
            // attention block: x = x_in + wo(attn(rope(q), rope(k), v))
            let do2 = self.lin_bwd(store, &mut flat, li, 3, &dx, &a.o2,
                                   &a.xa[3], n, scale)?;
            let do_h = to_heads(&do2, b, t, nh, hd);
            let (mut dq, mut dk, dv) = causal_attention_bwd(
                &do_h, &a.q, &a.k, &a.v, &a.att, b * nh, t, hd);
            rope_bwd(&mut dq, b * nh, t, hd);
            rope_bwd(&mut dk, b * nh, t, hd);
            let mut dxn1 = vec![0.0f32; n * h];
            for (w_i, dhead) in [dq, dk, dv].iter().enumerate() {
                let dy = from_heads(dhead, b, t, nh, hd);
                let dxi = self.lin_bwd(store, &mut flat, li, w_i, &dy,
                                       &a.xn1, &a.xa[w_i], n, scale)?;
                for (u, v) in dxn1.iter_mut().zip(&dxi) {
                    *u += v;
                }
            }
            let (dxin, dg1) = rms_norm_bwd(
                &dxn1, &a.x_in, &a.inv1,
                store.slice(&format!("l{li}.attn_norm"))?, n, h);
            accumulate(&mut flat, layout, &format!("l{li}.attn_norm"),
                       &dg1)?;
            for (u, v) in dx.iter_mut().zip(&dxin) {
                *u += v;
            }
        }

        // ---- embedding scatter ----
        let em = layout.meta("embed")?;
        let eo = em.t_offset.ok_or_else(|| {
            anyhow::anyhow!("embed must be trainable")
        })?;
        for (i, &tok) in inp.iter().enumerate() {
            let dst = eo + tok as usize * h;
            let src = &dx[i * h..(i + 1) * h];
            let dslice = &mut flat[dst..dst + h];
            for (u, v) in dslice.iter_mut().zip(src) {
                *u += v;
            }
        }
        sp.done();
        Ok((loss, flat, hp.correct))
    }

    /// Forward-only loss (shared by LM eval and cls eval).
    fn loss_inner(&self, store: &ParamStore, inp: &[i32], targets: &[i32],
                  b: usize, t: usize, cls: bool) -> Result<(f32, usize)> {
        let (xf, _, _, _) = self.forward(store, inp, b, t)?;
        let hp = self.head_pass(store, &xf, targets, b, t, cls)?;
        Ok((hp.loss, hp.correct))
    }

    /// Split `[batch, seq+1]` LM tokens into inputs and shifted targets.
    fn split_lm(&self, tokens: &[i32], batch: usize, seq_plus_1: usize)
        -> Result<(Vec<i32>, Vec<i32>, usize)> {
        ensure!(seq_plus_1 >= 2, "need at least 2 tokens per row");
        ensure!(tokens.len() == batch * seq_plus_1,
                "tokens len {} != {batch}x{seq_plus_1}", tokens.len());
        let t = seq_plus_1 - 1;
        let mut inp = Vec::with_capacity(batch * t);
        let mut tgt = Vec::with_capacity(batch * t);
        for bi in 0..batch {
            let row = &tokens[bi * seq_plus_1..(bi + 1) * seq_plus_1];
            inp.extend_from_slice(&row[..t]);
            tgt.extend_from_slice(&row[1..]);
        }
        Ok((inp, tgt, t))
    }

    fn ensure_cls(&self) -> Result<()> {
        if self.variant != Variant::Cls {
            bail!("cls step requires the cls variant");
        }
        Ok(())
    }
}

/// Accumulate a parameter gradient into the packed trainable vector.
fn accumulate(flat: &mut [f32], layout: &Layout, name: &str, g: &[f32])
    -> Result<()> {
    let m = layout.meta(name)?;
    let t = m.t_offset.ok_or_else(|| {
        anyhow::anyhow!("gradient for frozen param {name}")
    })?;
    ensure!(g.len() == m.numel, "grad {name} len {} != {}", g.len(),
            m.numel);
    let dst = &mut flat[t..t + m.numel];
    for (u, v) in dst.iter_mut().zip(g) {
        *u += v;
    }
    Ok(())
}

impl StepRuntime for NativeModel {
    fn fwdbwd(&self, store: &ParamStore, tokens: &[i32], batch: usize,
              seq_plus_1: usize) -> Result<(f32, Vec<f32>)> {
        ensure!(self.variant != Variant::Cls,
                "LM fwdbwd on the cls variant");
        let (inp, tgt, t) = self.split_lm(tokens, batch, seq_plus_1)?;
        let (loss, flat, _) =
            self.fwdbwd_inner(store, &inp, &tgt, batch, t, false)?;
        Ok((loss, flat))
    }

    fn eval_loss(&self, store: &ParamStore, tokens: &[i32], batch: usize,
                 seq_plus_1: usize) -> Result<f32> {
        let (inp, tgt, t) = self.split_lm(tokens, batch, seq_plus_1)?;
        let (loss, _) =
            self.loss_inner(store, &inp, &tgt, batch, t, false)?;
        Ok(loss)
    }

    fn cls_fwdbwd(&self, store: &ParamStore, tokens: &[i32],
                  labels: &[i32], batch: usize, seq: usize)
        -> Result<(f32, Vec<f32>)> {
        self.ensure_cls()?;
        ensure!(tokens.len() == batch * seq && labels.len() == batch,
                "cls batch shape mismatch");
        let (loss, flat, _) =
            self.fwdbwd_inner(store, tokens, labels, batch, seq, true)?;
        Ok((loss, flat))
    }

    fn cls_eval(&self, store: &ParamStore, tokens: &[i32], labels: &[i32],
                batch: usize, seq: usize) -> Result<(f32, f32)> {
        self.ensure_cls()?;
        ensure!(tokens.len() == batch * seq && labels.len() == batch,
                "cls batch shape mismatch");
        let (loss, correct) =
            self.loss_inner(store, tokens, labels, batch, seq, true)?;
        Ok((loss, correct as f32))
    }

    fn adam_step(&self, params: &mut [f32], grads: &[f32],
                 opt: &mut AdamState, mask: &[f32], hyper: &AdamHyper)
        -> Result<()> {
        let n = self.padded;
        ensure!(params.len() == n && grads.len() == n && opt.len() == n
                && mask.len() == n,
                "adam buffers must be padded to {n}");
        host_step(params, grads, opt, mask, hyper);
        Ok(())
    }

    /// Data-parallel inner loop: one OS thread per shard (up to the
    /// configured kernel thread count), each computing its batch with
    /// in-shard kernels forced serial so shards don't contend for the
    /// pool.  Per-shard arithmetic is identical to the interleaved
    /// schedule, so losses and gradients match it bitwise — only the
    /// wall-clock changes.
    fn fwdbwd_multi(&self, store: &ParamStore,
                    batches: &[(&[i32], usize, usize)])
        -> Result<Vec<(f32, Vec<f32>)>> {
        kernels::scoped_map(batches, |&(tokens, batch, sp1)| {
            self.fwdbwd(store, tokens, batch, sp1)
        })
        .into_iter()
        .collect()
    }

    /// Eval batches fan out the same way as training shards.
    fn eval_loss_multi(&self, store: &ParamStore,
                       batches: &[(&[i32], usize, usize)])
        -> Result<Vec<f32>> {
        kernels::scoped_map(batches, |&(tokens, batch, sp1)| {
            self.eval_loss(store, tokens, batch, sp1)
        })
        .into_iter()
        .collect()
    }
}

// ---------------------------------------------------------------------
// Inference: KV-cached incremental forward (prefill + batched decode).
// ---------------------------------------------------------------------

impl NativeModel {
    fn ensure_lm(&self) -> Result<()> {
        if self.variant == Variant::Cls {
            bail!("generation requires an LM head (lora/full variant)");
        }
        Ok(())
    }

    /// Full-context forward returning LM logits `[b·t, vocab]` at every
    /// position (no loss) — the all-positions reference the adapter-merge
    /// tests compare against.
    pub fn forward_logits(&self, store: &ParamStore, inp: &[i32],
                          b: usize, t: usize) -> Result<Vec<f32>> {
        self.ensure_lm()?;
        ensure!(inp.len() == b * t, "tokens len {} != {b}x{t}", inp.len());
        let (xf, _, _, _) = self.forward(store, inp, b, t)?;
        let h = self.manifest.config.hidden;
        let v_out = self.layout().meta("lm_head")?.rows();
        Ok(linear_fwd(&xf, store.slice("lm_head")?, b * t, h, v_out))
    }

    /// Last-position LM logits `[b, vocab]` of a full-context forward
    /// through the *training* code path — the independent reference the
    /// per-step KV-cache parity test diffs the cached decode against.
    pub fn forward_last_logits(&self, store: &ParamStore, inp: &[i32],
                               b: usize, t: usize) -> Result<Vec<f32>> {
        self.ensure_lm()?;
        ensure!(inp.len() == b * t, "tokens len {} != {b}x{t}", inp.len());
        let (xf, _, _, _) = self.forward(store, inp, b, t)?;
        let h = self.manifest.config.hidden;
        let v_out = self.layout().meta("lm_head")?.rows();
        let mut last = vec![0.0f32; b * h];
        for bi in 0..b {
            let src = (bi * t + t - 1) * h;
            last[bi * h..(bi + 1) * h].copy_from_slice(&xf[src..src + h]);
        }
        Ok(linear_fwd(&last, store.slice("lm_head")?, b, h, v_out))
    }

    /// One decoder-stack pass over a chunk of `t_new` new tokens of
    /// sequence `seq`, reusing (and extending) the KV cache.  Returns the
    /// final-norm hidden rows `[t_new, h]`; the caller applies the head.
    ///
    /// Row-for-row this is the same arithmetic as `forward`: every
    /// position's activations depend only on its own row and on earlier
    /// K/V (which the cache holds already RoPE'd at their absolute
    /// positions), so cached and full-context logits agree — the
    /// invariant `rust/tests/inference.rs` checks at every decode step.
    /// Parameters come through [`ParamSource`], so the same code serves
    /// a master-precision `ParamStore` and a quantized `PackedStore`;
    /// `adapter` is this sequence's unmerged low-rank overlay (the
    /// multi-tenant serving path), applied on top of whatever adapters
    /// the store itself carries.
    fn forward_cached(&self, src: &dyn ParamSource,
                      adapter: Option<&AdapterSet>, cache: &mut KvCache,
                      seq: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let mc = &self.manifest.config;
        let (h, nh) = (mc.hidden, mc.heads);
        let hd = mc.head_dim();
        let scale = mc.lora_scale() as f32;
        let t = tokens.len();
        ensure!(t > 0, "empty decode chunk");
        ensure!(seq < cache.batch,
                "sequence {seq} out of cache batch {}", cache.batch);
        let base = cache.len(seq);
        ensure!(base + t <= cache.capacity,
                "KV cache capacity {} exceeded by {base}+{t}",
                cache.capacity);
        let embed = src.f32s("embed")?;
        let mut x = vec![0.0f32; t * h];
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            ensure!(tok < mc.vocab, "token {tok} out of vocab {}", mc.vocab);
            x[i * h..(i + 1) * h]
                .copy_from_slice(&embed[tok * h..(tok + 1) * h]);
        }
        for li in 0..mc.layers {
            let (xn1, _) = rms_norm_fwd(
                &x, src.f32s(&format!("l{li}.attn_norm"))?, t, h);
            let yq = self.lin_fwd_uni(src, li, 0, &xn1, t, scale,
                                      adapter)?;
            let yk = self.lin_fwd_uni(src, li, 1, &xn1, t, scale,
                                      adapter)?;
            let yv = self.lin_fwd_uni(src, li, 2, &xn1, t, scale,
                                      adapter)?;
            let mut q = to_heads(&yq, 1, t, nh, hd);
            let mut k = to_heads(&yk, 1, t, nh, hd);
            let v = to_heads(&yv, 1, t, nh, hd);
            rope_fwd_at(&mut q, nh, t, hd, base);
            rope_fwd_at(&mut k, nh, t, hd, base);
            cache.append(li, seq, &k, &v, t);
            let o = cache.attend(li, seq, &q, t);
            let o2 = from_heads(&o, 1, t, nh, hd);
            let yo = self.lin_fwd_uni(src, li, 3, &o2, t, scale,
                                      adapter)?;
            for (xi, yi) in x.iter_mut().zip(&yo) {
                *xi += yi;
            }
            let (xn2, _) = rms_norm_fwd(
                &x, src.f32s(&format!("l{li}.mlp_norm"))?, t, h);
            let gate = self.lin_fwd_uni(src, li, 4, &xn2, t, scale,
                                        adapter)?;
            let up = self.lin_fwd_uni(src, li, 5, &xn2, t, scale,
                                      adapter)?;
            let act: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let ydown = self.lin_fwd_uni(src, li, 6, &act, t, scale,
                                         adapter)?;
            for (xi, yi) in x.iter_mut().zip(&ydown) {
                *xi += yi;
            }
        }
        cache.bump(seq, t);
        let (xf, _) = rms_norm_fwd(&x, src.f32s("final_norm")?, t, h);
        Ok(xf)
    }
}

impl InferRuntime for NativeModel {
    fn prefill_adapted(&self, src: &dyn ParamSource,
                       adapter: Option<&AdapterSet>, cache: &mut KvCache,
                       seq: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        self.ensure_lm()?;
        let h = self.manifest.config.hidden;
        let xf = self.forward_cached(src, adapter, cache, seq, tokens)?;
        let v_out = self.layout().meta("lm_head")?.rows();
        let last = &xf[(tokens.len() - 1) * h..];
        Ok(linear_fwd(last, src.f32s("lm_head")?, 1, h, v_out))
    }

    // NOTE: this body deliberately mirrors `forward`/`forward_cached`
    // per layer (batched rows=len(seqs), t=1 head-layout identity); any
    // model-definition change must land in all three, and the per-step
    // parity tests in `rust/tests/inference.rs` pin the invariant.
    fn decode_adapted(&self, src: &dyn ParamSource,
                      adapters: &[Option<&AdapterSet>],
                      cache: &mut KvCache, seqs: &[usize],
                      tokens: &[i32]) -> Result<Vec<f32>> {
        self.ensure_lm()?;
        let mc = &self.manifest.config;
        let (h, nh) = (mc.hidden, mc.heads);
        let hd = mc.head_dim();
        let scale = mc.lora_scale() as f32;
        let b = seqs.len();
        ensure!(b > 0, "decode with no active sequences");
        ensure!(tokens.len() == b,
                "decode step wants one token per listed sequence \
                 ({} != {b})", tokens.len());
        ensure!(adapters.len() == b,
                "decode step wants one adapter slot per listed sequence \
                 ({} != {b})", adapters.len());
        ensure!(seqs.windows(2).all(|w| w[0] < w[1]),
                "decode sequence list must be strictly increasing");
        // per-sequence absolute positions, read before any append
        for &s in seqs {
            ensure!(s < cache.batch,
                    "sequence {s} out of cache batch {}", cache.batch);
            let l = cache.len(s);
            ensure!(l < cache.capacity,
                    "KV cache capacity {} exhausted for sequence {s}",
                    cache.capacity);
            ensure!(l > 0, "decode before prefill for sequence {s}");
        }
        let lens: Vec<usize> = seqs.iter().map(|&s| cache.len(s)).collect();
        let embed = src.f32s("embed")?;
        let mut x = vec![0.0f32; b * h];
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            ensure!(tok < mc.vocab, "token {tok} out of vocab {}", mc.vocab);
            x[i * h..(i + 1) * h]
                .copy_from_slice(&embed[tok * h..(tok + 1) * h]);
        }
        for li in 0..mc.layers {
            let (xn1, _) = rms_norm_fwd(
                &x, src.f32s(&format!("l{li}.attn_norm"))?, b, h);
            let mut q =
                self.lin_fwd_rows(src, li, 0, &xn1, b, scale, adapters)?;
            let mut k =
                self.lin_fwd_rows(src, li, 1, &xn1, b, scale, adapters)?;
            let v =
                self.lin_fwd_rows(src, li, 2, &xn1, b, scale, adapters)?;
            // for t = 1 the `[1, nh·hd]` row IS the `[nh, 1, hd]` head
            // layout, so no to_heads/from_heads transposition is needed
            let mut o2 = vec![0.0f32; b * h];
            for (i, &s) in seqs.iter().enumerate() {
                let row = i * h..(i + 1) * h;
                rope_fwd_at(&mut q[row.clone()], nh, 1, hd, lens[i]);
                rope_fwd_at(&mut k[row.clone()], nh, 1, hd, lens[i]);
                cache.append(li, s, &k[row.clone()], &v[row.clone()], 1);
                let os = cache.attend(li, s, &q[row.clone()], 1);
                o2[row].copy_from_slice(&os);
            }
            let yo =
                self.lin_fwd_rows(src, li, 3, &o2, b, scale, adapters)?;
            for (xi, yi) in x.iter_mut().zip(&yo) {
                *xi += yi;
            }
            let (xn2, _) = rms_norm_fwd(
                &x, src.f32s(&format!("l{li}.mlp_norm"))?, b, h);
            let gate =
                self.lin_fwd_rows(src, li, 4, &xn2, b, scale, adapters)?;
            let up =
                self.lin_fwd_rows(src, li, 5, &xn2, b, scale, adapters)?;
            let act: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let ydown =
                self.lin_fwd_rows(src, li, 6, &act, b, scale, adapters)?;
            for (xi, yi) in x.iter_mut().zip(&ydown) {
                *xi += yi;
            }
        }
        for &s in seqs {
            cache.bump(s, 1);
        }
        let (xf, _) = rms_norm_fwd(&x, src.f32s("final_norm")?, b, h);
        let v_out = self.layout().meta("lm_head")?.rows();
        Ok(linear_fwd(&xf, src.f32s("lm_head")?, b, h, v_out))
    }

    fn new_cache_blocked(&self, batch: usize, capacity: usize,
                         block: usize) -> KvCache {
        let mc = &self.manifest.config;
        KvCache::with_layout(mc.layers, batch, mc.heads, mc.head_dim(),
                             capacity, self.policy.kv_cache, block)
    }

    fn vocab_out(&self) -> usize {
        self.manifest.config.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, prop_check};
    use crate::util::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 0.5)).collect()
    }

    #[test]
    fn rope_roundtrip_is_identity() {
        prop_check("rope_bwd inverts rope_fwd", 20, |rng| {
            let (bh, t) = (1 + rng.below(4), 1 + rng.below(6));
            let hd = 2 * (1 + rng.below(4));
            let x0 = randv(bh * t * hd, rng);
            let mut x = x0.clone();
            rope_fwd(&mut x, bh, t, hd);
            rope_bwd(&mut x, bh, t, hd);
            assert_close(&x, &x0, 1e-5, 1e-5)
        });
    }

    #[test]
    fn rope_preserves_norm() {
        prop_check("rope is orthogonal", 20, |rng| {
            let (bh, t, hd) = (2, 1 + rng.below(5), 8);
            let x0 = randv(bh * t * hd, rng);
            let mut x = x0.clone();
            rope_fwd(&mut x, bh, t, hd);
            let n0: f32 = x0.iter().map(|v| v * v).sum();
            let n1: f32 = x.iter().map(|v| v * v).sum();
            if (n0 - n1).abs() > 1e-3 * n0.max(1.0) {
                return Err(format!("norm changed {n0} -> {n1}"));
            }
            Ok(())
        });
    }

    #[test]
    fn attention_rows_are_causal_and_normalized() {
        let mut rng = Rng::new(3);
        let (bh, t, hd) = (2, 5, 4);
        let q = randv(bh * t * hd, &mut rng);
        let k = randv(bh * t * hd, &mut rng);
        let v = randv(bh * t * hd, &mut rng);
        let (_, att) = causal_attention_fwd(&q, &k, &v, bh, t, hd);
        for g in 0..bh {
            for i in 0..t {
                let row = &att[(g * t + i) * t..(g * t + i + 1) * t];
                let s: f32 = row[..=i].iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "row sum {s}");
                assert!(row[i + 1..].iter().all(|&p| p == 0.0),
                        "future leak at ({g},{i})");
            }
        }
    }

    #[test]
    fn lora_linear_matches_dense_composition() {
        prop_check("lora linear == W + s·BA applied densely", 20, |rng| {
            let (rows, n_in, m, r) = (1 + rng.below(6), 1 + rng.below(8),
                                      1 + rng.below(8), 1 + rng.below(4));
            let x = randv(rows * n_in, rng);
            let w = randv(m * n_in, rng);
            let a = randv(r * n_in, rng);
            let b = randv(m * r, rng);
            let s = 0.7;
            let (y, _) = lora_linear_fwd(&x, &w, &a, &b, s, rows, n_in, m,
                                         r);
            // dense: w_eff[o,k] = w[o,k] + s Σ_j b[o,j] a[j,k]
            let mut weff = w.clone();
            for o in 0..m {
                for kk in 0..n_in {
                    let mut acc = 0.0;
                    for j in 0..r {
                        acc += b[o * r + j] * a[j * n_in + kk];
                    }
                    weff[o * n_in + kk] += s * acc;
                }
            }
            let yd = linear_fwd(&x, &weff, rows, n_in, m);
            assert_close(&y, &yd, 1e-4, 1e-4)
        });
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let v = 16;
        let logits = vec![0.0f32; 3 * v];
        let (loss, dl, _) = softmax_xent(&logits, &[1, 5, 9], 3, v);
        assert!((loss - (v as f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero
        for i in 0..3 {
            let s: f32 = dl[i * v..(i + 1) * v].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }
}
