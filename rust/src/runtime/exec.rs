//! Typed execution over the AOT artifacts (`pjrt` feature only): the
//! PJRT implementation of the [`StepRuntime`] engine trait.
//!
//! `PjrtRuntime` binds a manifest + variant to its compiled executables
//! and marshals between the coordinator's host state (`ParamStore`, packed
//! gradient/optimizer vectors) and XLA literals.  HLO signatures (defined
//! by `python/compile/model.py` / `aot.py`):
//!
//! * `*_fwdbwd(params..., tokens[B,S+1])        -> (loss, grads...)`
//! * `*_eval(params..., tokens[B,S+1])          -> (loss,)`
//! * `cls_fwdbwd(params..., tok[B,S], lab[B])   -> (loss, grads...)`
//! * `cls_eval(params..., tok[B,S], lab[B])     -> (loss, correct)`
//! * `adam_N(p,g,m,v,s,mask,hyper)              -> (p',m',v',s')`
//!
//! Gradients come back as one literal per trainable parameter, in layout
//! order; `fwdbwd` packs them into the flat trainable vector (padded to the
//! fused-Adam size) so the optimizer, all-reduce and switch logic all share
//! one addressing scheme.

use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use super::client::{lit_f32, lit_i32, lit_scalar, lit_to_f32, Executable,
                    PjrtEngine};
use super::StepRuntime;
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::AdamHyper;

pub struct PjrtRuntime {
    pub manifest: Manifest,
    pub variant: Variant,
    fwdbwd: Rc<Executable>,
    eval: Rc<Executable>,
    adam: Rc<Executable>,
    /// padded trainable size of the fused Adam executable
    pub padded: usize,
}

impl PjrtRuntime {
    /// Load the executables of `variant` from `manifest` through `engine`.
    pub fn load(engine: &mut PjrtEngine, manifest: Manifest,
                variant: Variant) -> Result<PjrtRuntime> {
        let key = variant.key();
        let fwdbwd = engine.load(&manifest.hlo_path(&format!(
            "{key}_fwdbwd")))?;
        let eval = engine.load(&manifest.hlo_path(&format!("{key}_eval")))?;
        let padded = manifest.adam_padded(variant)?;
        let adam = engine.load(&manifest.adam_hlo_path(padded))?;
        Ok(PjrtRuntime { manifest, variant, fwdbwd, eval, adam, padded })
    }

    fn param_literals(&self, store: &ParamStore)
        -> Result<Vec<xla::Literal>> {
        let mut lits = Vec::with_capacity(store.layout.params.len() + 2);
        for p in &store.layout.params {
            lits.push(lit_f32(
                &store.data[p.offset..p.offset + p.numel],
                &p.shape,
            )?);
        }
        Ok(lits)
    }

    fn pack_grads(&self, store: &ParamStore, out: Vec<xla::Literal>)
        -> Result<(f32, Vec<f32>)> {
        let trainable: Vec<_> = store.layout.trainable().collect();
        ensure!(out.len() == 1 + trainable.len(),
                "{}: expected {} outputs, got {}", self.fwdbwd.name,
                1 + trainable.len(), out.len());
        let loss = lit_scalar(&out[0])?;
        let mut flat = vec![0.0f32; self.padded.max(
            store.layout.n_trainable)];
        for (lit, p) in out[1..].iter().zip(&trainable) {
            let g = lit_to_f32(lit)
                .with_context(|| format!("grad of {}", p.name))?;
            ensure!(g.len() == p.numel, "grad {} len {} != {}", p.name,
                    g.len(), p.numel);
            let t = p.t_offset.unwrap();
            flat[t..t + p.numel].copy_from_slice(&g);
        }
        Ok((loss, flat))
    }
}

impl StepRuntime for PjrtRuntime {
    fn fwdbwd(&self, store: &ParamStore, tokens: &[i32], batch: usize,
              seq_plus_1: usize) -> Result<(f32, Vec<f32>)> {
        let mut inputs = self.param_literals(store)?;
        inputs.push(lit_i32(tokens, &[batch, seq_plus_1])?);
        let out = self.fwdbwd.run(&inputs)?;
        self.pack_grads(store, out)
    }

    /// Parameter literals are marshaled **once** and reused for every
    /// worker's execution (§Perf L3 — cuts per-step host→literal copies
    /// from `workers × |params|` to `|params|`).
    fn fwdbwd_multi(&self, store: &ParamStore,
                    batches: &[(&[i32], usize, usize)])
        -> Result<Vec<(f32, Vec<f32>)>> {
        let mut inputs = self.param_literals(store)?;
        let mut out = Vec::with_capacity(batches.len());
        for &(tokens, batch, seq_plus_1) in batches {
            inputs.push(lit_i32(tokens, &[batch, seq_plus_1])?);
            let res = self.fwdbwd.run(&inputs)?;
            inputs.pop();
            out.push(self.pack_grads(store, res)?);
        }
        Ok(out)
    }

    fn eval_loss(&self, store: &ParamStore, tokens: &[i32], batch: usize,
                 seq_plus_1: usize) -> Result<f32> {
        let mut inputs = self.param_literals(store)?;
        inputs.push(lit_i32(tokens, &[batch, seq_plus_1])?);
        let out = self.eval.run(&inputs)?;
        lit_scalar(&out[0])
    }

    fn eval_loss_multi(&self, store: &ParamStore,
                       batches: &[(&[i32], usize, usize)])
        -> Result<Vec<f32>> {
        let mut inputs = self.param_literals(store)?;
        let mut out = Vec::with_capacity(batches.len());
        for &(tokens, batch, seq_plus_1) in batches {
            inputs.push(lit_i32(tokens, &[batch, seq_plus_1])?);
            let res = self.eval.run(&inputs)?;
            inputs.pop();
            out.push(lit_scalar(&res[0])?);
        }
        Ok(out)
    }

    fn cls_fwdbwd(&self, store: &ParamStore, tokens: &[i32],
                  labels: &[i32], batch: usize, seq: usize)
        -> Result<(f32, Vec<f32>)> {
        ensure!(self.variant == Variant::Cls,
                "cls_fwdbwd requires the cls variant");
        let mut inputs = self.param_literals(store)?;
        inputs.push(lit_i32(tokens, &[batch, seq])?);
        inputs.push(lit_i32(labels, &[batch])?);
        let out = self.fwdbwd.run(&inputs)?;
        self.pack_grads(store, out)
    }

    fn cls_eval(&self, store: &ParamStore, tokens: &[i32], labels: &[i32],
                batch: usize, seq: usize) -> Result<(f32, f32)> {
        ensure!(self.variant == Variant::Cls, "cls_eval needs cls variant");
        let mut inputs = self.param_literals(store)?;
        inputs.push(lit_i32(tokens, &[batch, seq])?);
        inputs.push(lit_i32(labels, &[batch])?);
        let out = self.eval.run(&inputs)?;
        Ok((lit_scalar(&out[0])?, lit_scalar(&out[1])?))
    }

    /// Fused AdamW step via the L1 kernel executable.
    fn adam_step(&self, params: &mut [f32], grads: &[f32],
                 opt: &mut AdamState, mask: &[f32], hyper: &AdamHyper)
        -> Result<()> {
        let n = self.padded;
        ensure!(params.len() == n && grads.len() == n && opt.len() == n
                && mask.len() == n,
                "adam buffers must be padded to {n}");
        let inputs = [
            lit_f32(params, &[n])?,
            lit_f32(grads, &[n])?,
            lit_f32(&opt.m, &[n])?,
            lit_f32(&opt.v, &[n])?,
            lit_f32(&opt.s, &[n])?,
            lit_f32(mask, &[n])?,
            lit_f32(&hyper.to_vec(), &[5])?,
        ];
        let out = self.adam.run(&inputs)?;
        ensure!(out.len() == 4, "adam returned {} outputs", out.len());
        params.copy_from_slice(&lit_to_f32(&out[0])?);
        opt.m.copy_from_slice(&lit_to_f32(&out[1])?);
        opt.v.copy_from_slice(&lit_to_f32(&out[2])?);
        opt.s.copy_from_slice(&lit_to_f32(&out[3])?);
        Ok(())
    }
}
