//! SwitchLoRA as a [`TrainingMethod`] plugin: the paper's Algorithms 1+2
//! (candidate switching with counterpart optimizer-state resets and
//! freeze windows), driven entirely through the trait hooks —
//! `grad_mask` applies the freeze mask, `post_step` runs the switching,
//! and `save_state`/`load_state` make a run resumable mid-schedule with
//! its freeze timers, candidate pools and switch RNG intact.

use anyhow::Result;

use super::{Method, MethodCtx, TrainingMethod};
use crate::model::layout::{LinearMeta, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::switchlora::schedule::SwitchSchedule;
use crate::switchlora::switcher::SwitchLora;
use crate::util::bytes::ByteReader;
use crate::util::rng::Rng;

/// SwitchLoRA hyper-parameters (paper Section 4.1 defaults).
#[derive(Clone, Debug)]
pub struct SwitchParams {
    /// initial switching interval (paper: 40)
    pub interval0: f64,
    /// fraction of total steps at which frequency reaches 1/3 (paper: 0.1)
    pub ratio: f64,
    /// freeze length N after a switch (paper: 5)
    pub n_freeze: u64,
}

impl Default for SwitchParams {
    fn default() -> Self {
        SwitchParams { interval0: 40.0, ratio: 0.1, n_freeze: 5 }
    }
}

/// The SwitchLoRA method: owns the switch machinery and the linear list
/// it operates on.
pub struct SwitchLoraMethod {
    sl: SwitchLora,
    linears: Vec<LinearMeta>,
}

impl TrainingMethod for SwitchLoraMethod {
    fn name(&self) -> &str {
        "switchlora"
    }

    fn variant(&self) -> Variant {
        Variant::Lora
    }

    fn default_lr(&self) -> f32 {
        // paper Section 4.1
        2e-2
    }

    fn grad_mask(&mut self, step: u64, mask: &mut [f32]) {
        self.sl.freeze.apply(step, mask);
    }

    fn post_step(&mut self, step: u64, store: &mut ParamStore,
                 opt: &mut AdamState, _rng: &mut Rng) -> Result<()> {
        self.sl.apply_step(step, store, opt, &self.linears);
        Ok(())
    }

    fn counters(&self) -> Vec<(String, u64)> {
        vec![
            ("switches".into(), self.sl.total_switches),
            ("offload_bytes".into(), self.sl.ledger.total_bytes()),
            ("pool_resident_bytes".into(), self.sl.resident_bytes()),
        ]
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<()> {
        self.sl.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        self.sl.load_state(&mut r)?;
        r.finish()
    }
}

/// Registry factory: parse `interval0` / `ratio` / `nfreeze` options and
/// build the switch machinery for the manifest's linears.
pub(super) fn build(spec: &Method, ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    let d = SwitchParams::default();
    let p = SwitchParams {
        interval0: spec.opt_num("interval0", d.interval0)?,
        ratio: spec.opt_num("ratio", d.ratio)?,
        n_freeze: spec.opt_num("nfreeze", d.n_freeze)?,
    };
    let mc = &ctx.manifest.config;
    let sl = SwitchLora::new(
        &ctx.manifest.linears,
        mc.rank,
        mc.lora_scale() as f32,
        SwitchSchedule::with_third_at(p.interval0, p.ratio, ctx.steps),
        p.n_freeze,
        ctx.seed,
    );
    Ok(Box::new(SwitchLoraMethod {
        sl,
        linears: ctx.manifest.linears.clone(),
    }))
}
