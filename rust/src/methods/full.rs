//! Full-rank AdamW baseline: every parameter trainable, no adapters.

use anyhow::Result;

use super::{Method, MethodCtx, TrainingMethod};
use crate::model::layout::Variant;

/// The full-rank baseline method (the paper's reference arm).
pub struct FullRank;

impl TrainingMethod for FullRank {
    fn name(&self) -> &str {
        "full"
    }

    fn variant(&self) -> Variant {
        Variant::Full
    }

    fn default_lr(&self) -> f32 {
        // paper Section 4.1
        1e-3
    }
}

/// Registry factory.
pub(super) fn build(_spec: &Method, _ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    Ok(Box::new(FullRank))
}
