//! Composable full-rank warm start (the paper's Figure 4 protocol):
//! wrap any low-rank method in a short full-rank pre-phase whose weights
//! are transplanted into the wrapped method's store before step 0.
//!
//! This replaces the old recursive `full_warm_start` special case inside
//! the trainer: the wrapper is itself a [`TrainingMethod`] that runs the
//! warm phase in [`TrainingMethod::pre_run`] and delegates every other
//! hook to the inner method, so `--full-warmup` composes with *any*
//! registered method (and resumed runs skip the warm phase entirely —
//! the checkpoint already contains warm-started weights).

use anyhow::{bail, Result};

use super::{Method, MethodCtx, TrainingMethod};
use crate::coordinator::trainer::{TrainConfig, Trainer};
use crate::model::init::copy_shared;
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::schedule::LrSchedule;
use crate::optim::AdamHyper;
use crate::runtime::{Engine, ModelRuntime};
use crate::util::rng::Rng;

/// Inner method used when `--method warmstart` gives no `--inner`.
pub const DEFAULT_INNER: &str = "lora";

/// Warm-start wrapper: `steps` of full-rank training, then the inner
/// method takes over on the transplanted weights.
pub struct WarmStart {
    inner: Box<dyn TrainingMethod>,
    steps: u64,
    label: String,
}

impl TrainingMethod for WarmStart {
    fn name(&self) -> &str {
        &self.label
    }

    fn variant(&self) -> Variant {
        self.inner.variant()
    }

    fn default_lr(&self) -> f32 {
        self.inner.default_lr()
    }

    fn manifest(&self) -> Option<&Manifest> {
        self.inner.manifest()
    }

    fn pre_run(&mut self, cfg: &TrainConfig, manifest: &Manifest,
               engine: &mut Engine, store: &mut ParamStore)
        -> Result<()> {
        if self.steps == 0 || self.inner.variant() != Variant::Lora {
            // full-variant methods are already full-rank; nothing to warm
            return Ok(());
        }
        let mut sub = cfg.clone();
        sub.method = Method::full();
        sub.steps = self.steps;
        sub.full_warmup_steps = 0;
        sub.peak_lr = 0.0; // 0 => the full method's default lr
        sub.metrics_csv = None;
        sub.eval_every = self.steps; // single eval at the end
        sub.ckpt_every = 0;
        sub.ckpt_path = None;
        sub.resume = None;
        let t = Trainer { cfg: sub, manifest: manifest.clone() };
        let (_, warm) = t.run(engine)?;
        let copied = copy_shared(&warm, store);
        crate::info!("full-rank warm start: {} steps, {} params carried",
                     self.steps, copied);
        self.inner.pre_run(cfg, manifest, engine, store)
    }

    fn lr_adjust(&self, step: u64, lr: f32, sched: &LrSchedule) -> f32 {
        self.inner.lr_adjust(step, lr, sched)
    }

    fn grad_mask(&mut self, step: u64, mask: &mut [f32]) {
        self.inner.grad_mask(step, mask);
    }

    #[allow(clippy::too_many_arguments)]
    fn optim_step(&mut self, step: u64, rt: &ModelRuntime,
                  store: &mut ParamStore, grad: &[f32],
                  opt: &mut AdamState, base_mask: &[f32],
                  hyper: &AdamHyper) -> Result<()> {
        self.inner
            .optim_step(step, rt, store, grad, opt, base_mask, hyper)
    }

    fn post_step(&mut self, step: u64, store: &mut ParamStore,
                 opt: &mut AdamState, rng: &mut Rng) -> Result<()> {
        self.inner.post_step(step, store, opt, rng)
    }

    fn counters(&self) -> Vec<(String, u64)> {
        let mut c = self.inner.counters();
        c.push(("warm_steps".into(), self.steps));
        c
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<()> {
        self.inner.save_state(out)
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        self.inner.load_state(bytes)
    }

    fn state_version(&self) -> u32 {
        self.inner.state_version()
    }
}

/// Registry factory: build the inner method from the same option map
/// (minus the wrapper's own `inner` / `warm-steps` keys).
pub(super) fn build(spec: &Method, ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    let inner_name =
        spec.opt("inner").unwrap_or(DEFAULT_INNER).to_string();
    if inner_name == "warmstart" {
        bail!("warmstart cannot wrap itself");
    }
    let mut inner_spec = spec.clone();
    inner_spec.name = inner_name;
    inner_spec.opts.remove("inner");
    inner_spec.opts.remove("warm-steps");
    let inner = super::build(&inner_spec, ctx)?;
    let steps = spec.opt_num("warm-steps", 100u64)?;
    let label = format!("warmstart+{}", inner.name());
    Ok(Box::new(WarmStart { inner, steps, label }))
}
