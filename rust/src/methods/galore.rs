//! GaLore as a [`TrainingMethod`] plugin (Zhao et al. 2024b): the method
//! that demonstrates the overridable `optim_step` hook — instead of the
//! fused AdamW it runs the host optimizer, which needs SVD control
//! between gradient and update to project onto a low-rank subspace.

use anyhow::Result;

use super::{Method, MethodCtx, TrainingMethod};
use crate::model::layout::{ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::galore::Galore;
use crate::optim::AdamHyper;
use crate::runtime::ModelRuntime;
use crate::util::bytes::ByteReader;

/// GaLore hyper-parameters.
#[derive(Clone, Debug)]
pub struct GaloreParams {
    /// projection rank; 0 means "use the config's LoRA rank"
    pub rank: usize,
    /// steps between SVD projection refreshes
    pub update_freq: u64,
    /// GaLore's update scale α (their default 0.25)
    pub scale: f32,
}

impl Default for GaloreParams {
    fn default() -> Self {
        GaloreParams { rank: 0, update_freq: 200, scale: 0.25 }
    }
}

/// The GaLore method: a host projector-optimizer over the full-rank
/// layout (the shared fused-Adam state stays untouched).
pub struct GaloreMethod {
    g: Galore,
}

impl TrainingMethod for GaloreMethod {
    fn name(&self) -> &str {
        "galore"
    }

    fn variant(&self) -> Variant {
        Variant::Full
    }

    fn default_lr(&self) -> f32 {
        // GaLore appendix C.3
        1e-2
    }

    #[allow(clippy::too_many_arguments)]
    fn optim_step(&mut self, step: u64, rt: &ModelRuntime,
                  store: &mut ParamStore, grad: &[f32],
                  _opt: &mut AdamState, _base_mask: &[f32],
                  hyper: &AdamHyper) -> Result<()> {
        let n = store.layout.n_trainable;
        let mut flat = store.gather_trainable(rt.padded);
        self.g.step(step, &mut flat[..n], &grad[..n], hyper);
        store.scatter_trainable(&flat);
        Ok(())
    }

    fn counters(&self) -> Vec<(String, u64)> {
        vec![
            ("projected_matrices".into(),
             self.g.n_projected_matrices() as u64),
            ("opt_state_elems".into(),
             self.g.optimizer_state_elems() as u64),
        ]
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<()> {
        self.g.save_state(out);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        self.g.load_state(&mut r)?;
        r.finish()
    }
}

/// Registry factory: parse `galore-rank` / `update-freq` /
/// `galore-scale` options and size the projector from the full layout.
pub(super) fn build(spec: &Method, ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    let d = GaloreParams::default();
    let p = GaloreParams {
        rank: spec.opt_num("galore-rank", d.rank)?,
        update_freq: spec.opt_num("update-freq", d.update_freq)?,
        scale: spec.opt_num("galore-scale", d.scale)?,
    };
    let mc = &ctx.manifest.config;
    let rank = if p.rank == 0 { mc.rank } else { p.rank };
    let layout = ctx.manifest.layout(Variant::Full)?;
    Ok(Box::new(GaloreMethod {
        g: Galore::new(layout, rank, p.update_freq, p.scale),
    }))
}
