//! PreLoRA-style layerwise full+LoRA hybrid (Thapa et al.): the first
//! `full_layers` transformer layers train their linears full-rank while
//! the remaining layers keep frozen bases with LoRA adapters.
//!
//! This is the proof that the plugin API generalizes beyond the seed
//! methods: the hybrid provides its *own manifest* — the lora-variant
//! layout rewritten so selected linears drop their adapters and become
//! trainable — and the native backend decides adapter-vs-dense per
//! linear from that layout, so no trainer or backend special cases are
//! needed.

use std::collections::HashSet;

use anyhow::{ensure, Result};

use super::{Method, MethodCtx, TrainingMethod};
use crate::model::layout::{adam_pad, Layout, Manifest, Role, Variant};

/// Layerwise-hybrid hyper-parameters.
#[derive(Clone, Debug, Default)]
pub struct PreLoraParams {
    /// number of leading layers trained full-rank (the rest are LoRA)
    pub full_layers: usize,
}

/// The layerwise hybrid method.  Stateless per step — all the work is in
/// the rewritten manifest it hands the trainer.
pub struct PreLora {
    manifest: Manifest,
    full_layers: usize,
    n_dense: usize,
    n_adapted: usize,
}

/// Layer index of a parameter/linear named `l<i>.<...>`.
fn layer_of(name: &str) -> Option<usize> {
    let rest = name.strip_prefix('l')?;
    let digits: String =
        rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    if digits.is_empty() || !rest[digits.len()..].starts_with('.') {
        return None;
    }
    digits.parse().ok()
}

/// Rewrite the manifest's lora-variant layout: linears of layers below
/// `full_layers` lose their adapters and train their base weights
/// directly; everything else is unchanged.  The fused-Adam padding is
/// recomputed for the new trainable count.
fn hybrid_manifest(man: &Manifest, full_layers: usize) -> Result<Manifest> {
    let dense: HashSet<&str> = man
        .linears
        .iter()
        .filter(|li| layer_of(&li.name).is_some_and(|l| l < full_layers))
        .map(|li| li.name.as_str())
        .collect();
    let mut metas = Vec::with_capacity(man.lora.params.len());
    for p in &man.lora.params {
        let adapter_base = p
            .name
            .strip_suffix(".a")
            .or_else(|| p.name.strip_suffix(".b"));
        match p.role {
            Role::LoraA | Role::LoraB
                if adapter_base.is_some_and(|b| dense.contains(b)) =>
            {
                // adapters of a dense layer: dropped from the layout
            }
            Role::Base if dense.contains(p.name.as_str()) => {
                let mut m = p.clone();
                m.trainable = true;
                metas.push(m);
            }
            _ => metas.push(p.clone()),
        }
    }
    let lora = Layout::from_metas(metas);
    ensure!(lora.n_trainable > 0, "hybrid layout has no trainable params");
    Ok(Manifest {
        adam_padded_lora: adam_pad(lora.n_trainable),
        lora,
        ..man.clone()
    })
}

impl TrainingMethod for PreLora {
    fn name(&self) -> &str {
        "prelora"
    }

    fn variant(&self) -> Variant {
        // the hybrid layout lives in the manifest's lora slot
        Variant::Lora
    }

    fn default_lr(&self) -> f32 {
        // full-rank layers dominate the trainable mass; use the
        // full-rank lr for stability
        1e-3
    }

    fn manifest(&self) -> Option<&Manifest> {
        Some(&self.manifest)
    }

    fn counters(&self) -> Vec<(String, u64)> {
        vec![
            ("full_layers".into(), self.full_layers as u64),
            ("dense_linears".into(), self.n_dense as u64),
            ("adapted_linears".into(), self.n_adapted as u64),
        ]
    }
}

/// Registry factory: parse `full-layers` (default: the first half of the
/// stack) and rewrite the layout.
pub(super) fn build(spec: &Method, ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    let layers = ctx.manifest.config.layers;
    let full_layers =
        spec.opt_num("full-layers", ((layers + 1) / 2) as u64)? as usize;
    ensure!(full_layers <= layers,
            "--full-layers {full_layers} exceeds the model's {layers} \
             layers");
    let manifest = hybrid_manifest(ctx.manifest, full_layers)?;
    let n_dense = manifest
        .linears
        .iter()
        .filter(|li| layer_of(&li.name).is_some_and(|l| l < full_layers))
        .count();
    let n_adapted = manifest.linears.len() - n_dense;
    Ok(Box::new(PreLora { manifest, full_layers, n_dense, n_adapted }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_parse() {
        assert_eq!(layer_of("l0.wq"), Some(0));
        assert_eq!(layer_of("l12.w_down"), Some(12));
        assert_eq!(layer_of("embed"), None);
        assert_eq!(layer_of("lm_head"), None);
        assert_eq!(layer_of("final_norm"), None);
    }

    #[test]
    fn hybrid_layout_mixes_dense_and_adapted() {
        let man = Manifest::builtin("tiny").unwrap();
        let hy = hybrid_manifest(&man, 1).unwrap();
        // layer 0 linears: dense trainable base, no adapters
        let w0 = hy.lora.meta("l0.wq").unwrap();
        assert!(w0.trainable && w0.t_offset.is_some());
        assert!(hy.lora.meta("l0.wq.a").is_err());
        // later layers keep frozen base + adapters
        let last = man.config.layers - 1;
        let wl = hy.lora.meta(&format!("l{last}.wq")).unwrap();
        assert!(!wl.trainable);
        assert!(hy.lora.meta(&format!("l{last}.wq.a")).unwrap().trainable);
        // trainable mass sits strictly between pure lora and full
        assert!(hy.lora.n_trainable > man.lora.n_trainable);
        assert!(hy.lora.n_trainable < man.full.n_trainable);
        assert_eq!(hy.adam_padded_lora % 8192, 0);
        assert!(hy.adam_padded_lora >= hy.lora.n_trainable);
    }

    #[test]
    fn hybrid_extremes_match_pure_variants() {
        let man = Manifest::builtin("tiny").unwrap();
        let all_lora = hybrid_manifest(&man, 0).unwrap();
        assert_eq!(all_lora.lora.n_trainable, man.lora.n_trainable);
        let all_full =
            hybrid_manifest(&man, man.config.layers).unwrap();
        assert_eq!(all_full.lora.n_trainable, man.full.n_trainable);
    }
}
