//! Plain-LoRA baseline: fixed adapters, frozen base weights (Hu et al.).

use anyhow::Result;

use super::{Method, MethodCtx, TrainingMethod};
use crate::model::layout::Variant;

/// The plain-LoRA baseline method (the paper's Figure 2 low arm).
pub struct PlainLora;

impl TrainingMethod for PlainLora {
    fn name(&self) -> &str {
        "lora"
    }

    fn variant(&self) -> Variant {
        Variant::Lora
    }

    fn default_lr(&self) -> f32 {
        // paper Section 4.1
        1e-2
    }
}

/// Registry factory.
pub(super) fn build(_spec: &Method, _ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    Ok(Box::new(PlainLora))
}
