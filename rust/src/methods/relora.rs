//! ReLoRA as a [`TrainingMethod`] plugin (Lialin et al. 2023): periodic
//! merge-and-reset of every adapter plus a local learning-rate re-warm —
//! the restart-scheduled contrast arm to SwitchLoRA's smooth switching.

use anyhow::Result;

use super::{Method, MethodCtx, TrainingMethod};
use crate::model::layout::{LinearMeta, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::schedule::LrSchedule;
use crate::switchlora::relora::ReLora;
use crate::util::bytes::{put_u64, ByteReader};
use crate::util::rng::Rng;

/// ReLoRA hyper-parameters.
#[derive(Clone, Debug)]
pub struct ReLoraParams {
    /// steps between merge-and-reset events
    pub reset_interval: u64,
    /// lr re-warm length after each reset (ReLoRA's scheduler quirk)
    pub rewarm: u64,
}

impl Default for ReLoraParams {
    fn default() -> Self {
        ReLoraParams { reset_interval: 500, rewarm: 50 }
    }
}

/// The ReLoRA method: the resetter plus the layer/scale context the
/// reset needs.
pub struct ReLoraMethod {
    rl: ReLora,
    linears: Vec<LinearMeta>,
    rank: usize,
    scale: f32,
}

impl TrainingMethod for ReLoraMethod {
    fn name(&self) -> &str {
        "relora"
    }

    fn variant(&self) -> Variant {
        Variant::Lora
    }

    fn default_lr(&self) -> f32 {
        1e-2
    }

    fn lr_adjust(&self, step: u64, lr: f32, sched: &LrSchedule) -> f32 {
        if self.rl.n_resets > 0 {
            sched.with_restart(step, self.rl.last_reset, self.rl.rewarm)
        } else {
            lr
        }
    }

    fn post_step(&mut self, step: u64, store: &mut ParamStore,
                 opt: &mut AdamState, rng: &mut Rng) -> Result<()> {
        if self.rl.due(step) {
            let n = self.rl.reset(step, store, opt, &self.linears,
                                  self.rank, self.scale, rng);
            crate::info!("step {step}: ReLoRA reset {n} adapters");
        }
        Ok(())
    }

    fn counters(&self) -> Vec<(String, u64)> {
        vec![("resets".into(), self.rl.n_resets)]
    }

    fn save_state(&self, out: &mut Vec<u8>) -> Result<()> {
        put_u64(out, self.rl.last_reset);
        put_u64(out, self.rl.n_resets);
        Ok(())
    }

    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = ByteReader::new(bytes);
        self.rl.last_reset = r.u64()?;
        self.rl.n_resets = r.u64()?;
        r.finish()
    }
}

/// Registry factory: parse `reset-interval` / `rewarm` options.
pub(super) fn build(spec: &Method, ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    let d = ReLoraParams::default();
    let p = ReLoraParams {
        reset_interval: spec.opt_num("reset-interval", d.reset_interval)?,
        rewarm: spec.opt_num("rewarm", d.rewarm)?,
    };
    let mc = &ctx.manifest.config;
    Ok(Box::new(ReLoraMethod {
        rl: ReLora::new(p.reset_interval, p.rewarm),
        linears: ctx.manifest.linears.clone(),
        rank: mc.rank,
        scale: mc.lora_scale() as f32,
    }))
}
